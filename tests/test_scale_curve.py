"""Scale-curve engine + sparse fleet-scale guarantees.

Pins the ``sweep --scale-curve`` output contract (CSV schema, monotone
bottleneck growth), the projection rules of :mod:`repro.scale`, the
16384-device no-dense-materialization bound, and the ``project_links``
representation dispatch (clear ``TypeError`` on anything else).
"""
import tracemalloc

import numpy as np
import pytest

from repro import scale
from repro.core import comm_matrix
from repro.core.events import CollectiveOp, Shape
from repro.core.export import csv_exporter, html_exporter
from repro.core.sparse import SparseCommMatrix
from repro.core.topology import DCN_FABRIC, MeshTopology


def ddp_ops(num_ops=8, base=8):
    """Deterministic DDP-shaped base stream (whole-mesh AllReduce +
    AllGather) -- same shape the paper configs project."""
    return [CollectiveOp(
        kind="all-reduce" if i % 3 else "all-gather", name=f"d{i}",
        result_shapes=[Shape("f32", (4096 + 512 * i,))],
        replica_groups=[list(range(base))], weight=float(1 + i % 4))
        for i in range(num_ops)]


class FakeReport:
    """The slice of CommReport the scale engine reads."""

    def __init__(self, ops, base=8, algorithm="ring", config="ddp_test"):
        self.compiled_ops = ops
        self.num_devices = base
        self.algorithm = algorithm
        self.name = config
        self.meta = {"config": config}


# ---------------------------------------------------------------------------
# fleet topologies
# ---------------------------------------------------------------------------
class TestFleetTopology:
    def test_single_pod_sizes(self):
        t = MeshTopology.fleet(256)
        assert t.axis_sizes == (16, 16) and t.num_pods == 1

    def test_multi_pod_sizes(self):
        for d, pods in ((1024, 4), (4096, 16), (16384, 64)):
            t = MeshTopology.fleet(d)
            assert t.num_devices == d
            assert t.num_pods == pods
            assert t.axis_names == ("pod", "data", "model")
            assert t.devices_per_pod == 256

    def test_rejects_bad_sizes(self):
        with pytest.raises(ValueError):
            MeshTopology.fleet(0)
        with pytest.raises(ValueError):
            MeshTopology.fleet(300)     # > one pod, not a pod multiple


# ---------------------------------------------------------------------------
# projection rules
# ---------------------------------------------------------------------------
class TestScaleOps:
    def test_group_block_expansion(self):
        op = CollectiveOp(kind="all-reduce", name="x",
                          result_shapes=[Shape("f32", (8,))],
                          replica_groups=[[0, 1], [2, 3]])
        out = scale.scale_op(op, 4)
        assert out.replica_groups == [[0, 1, 2, 3, 4, 5, 6, 7],
                                      [8, 9, 10, 11, 12, 13, 14, 15]]
        # group count preserved, size scaled, still a partition
        assert len(out.replica_groups) == len(op.replica_groups)

    def test_permute_pairs_scale_injectively(self):
        op = CollectiveOp(kind="collective-permute", name="p",
                          result_shapes=[Shape("f32", (8,))],
                          replica_groups=[],
                          source_target_pairs=[(0, 1), (1, 0)])
        out = scale.scale_op(op, 16)
        assert out.source_target_pairs == [(0, 16), (16, 0)]
        assert all(s != t for s, t in out.source_target_pairs)

    def test_a2a_groups_stay_pod_sized(self):
        op = CollectiveOp(kind="all-to-all", name="a",
                          result_shapes=[Shape("f32", (8,))],
                          replica_groups=[list(range(8))])
        out = scale.scale_op(op, 2048)     # 8 -> 16384 devices
        assert all(len(g) <= scale.POD_DEVICES for g in out.replica_groups)
        assert sum(len(g) for g in out.replica_groups) == 16384

    def test_factor_one_is_identity(self):
        op = ddp_ops(1)[0]
        assert scale.scale_op(op, 1) is op

    def test_rejects_non_multiple(self):
        with pytest.raises(ValueError):
            scale.scale_ops(ddp_ops(), 8, 100)
        with pytest.raises(ValueError):
            scale.scale_ops(ddp_ops(), 8, 4)

    def test_irregular_vector_tiles_and_renormalizes(self):
        """A per-rank vector expands by ``np.repeat(vec, F) / F``: the
        total is preserved, each base rank's share spreads over its clone
        block, and the skew ratio survives the projection (the old code
        path would have flattened the hot expert into the mean)."""
        vec = [6000.0, 1000.0, 500.0, 500.0]
        op = CollectiveOp(kind="all-gather", name="v",
                          result_shapes=[Shape("f32", (8,))],
                          replica_groups=[[0, 1, 2, 3]],
                          bytes_per_rank_vec=vec)
        out = scale.scale_op(op, 8)
        got = out.byte_vector()
        assert got is not None and got.size == 32
        assert got.sum() == pytest.approx(8000.0)
        np.testing.assert_allclose(got.reshape(4, 8).sum(axis=1), vec)
        assert out.skew() == pytest.approx(op.skew())

    def test_uniform_vector_matches_scalar_at_scale(self):
        base = CollectiveOp(kind="all-gather", name="u",
                            result_shapes=[Shape("f32", (1024,))],
                            replica_groups=[[0, 1, 2, 3]])
        per = base.payload_bytes / 4
        uni = CollectiveOp(kind="all-gather", name="u",
                           result_shapes=[Shape("f32", (1024,))],
                           replica_groups=[[0, 1, 2, 3]],
                           bytes_per_rank_vec=[per] * 4)
        ms = comm_matrix.matrix_for_ops([scale.scale_op(base, 8)], 32)
        mu = comm_matrix.matrix_for_ops([scale.scale_op(uni, 8)], 32)
        assert (ms == mu).all()

    def test_irregular_a2a_chunks_slice_the_vector(self):
        """Pod-chunked irregular a2a: one op per chunk index, each
        carrying its positional slice of the expanded vector times the
        chunk count (the irregular twin of scalar chunking, where every
        chunk op keeps the full base payload)."""
        n = 8
        total = float(n * 100)
        vec = [total * 0.6] + [total * 0.4 / (n - 1)] * (n - 1)
        op = CollectiveOp(kind="all-to-all", name="a",
                          result_shapes=[Shape("f32", (8,))],
                          replica_groups=[list(range(n))],
                          bytes_per_rank_vec=vec)
        factor = 2 * scale.POD_DEVICES // n       # 2 pod chunks
        out = scale.scale_op(op, factor)
        assert isinstance(out, list) and len(out) == 2
        expanded = np.repeat(np.asarray(vec), factor) / factor
        for j, chunk in enumerate(out):
            assert all(len(g) == scale.POD_DEVICES
                       for g in chunk.replica_groups)
            np.testing.assert_allclose(
                chunk.byte_vector(),
                expanded[j * scale.POD_DEVICES:
                         (j + 1) * scale.POD_DEVICES] * 2)
        # the hot base rank's clones land in chunk 0
        assert out[0].byte_vector().sum() > out[1].byte_vector().sum()
        flat = scale.scale_ops([op], n, n * factor)
        assert len(flat) == 2


# ---------------------------------------------------------------------------
# the curve: CSV schema golden + monotone growth
# ---------------------------------------------------------------------------
EXPECTED_HEADER = ("config,algorithm,devices,pods,ops,wire_bytes,ici_ms,"
                   "dcn_ms,overlap_ms,bottleneck_link,bottleneck_ms,nnz,"
                   "build_ms")


@pytest.fixture(scope="module")
def curve_points():
    rep = FakeReport(ddp_ops())
    return scale.scale_curve([rep], (256, 1024, 4096))


class TestScaleCurve:
    def test_csv_schema_golden(self, curve_points, tmp_path):
        path = csv_exporter.export_scale_csv(
            [p.row() for p in curve_points], str(tmp_path / "sc.csv"))
        lines = open(path).read().strip().splitlines()
        assert lines[0] == EXPECTED_HEADER
        assert len(lines) == 1 + len(curve_points)
        for line in lines[1:]:
            cells = line.split(",")
            assert len(cells) == len(EXPECTED_HEADER.split(","))
            # typed columns parse: devices/pods/ops/nnz int, times float
            assert int(cells[2]) in (256, 1024, 4096)
            int(cells[3]), int(cells[4]), int(cells[11])
            float(cells[5]), float(cells[6]), float(cells[7])
            float(cells[8]), float(cells[10]), float(cells[12])
        # rows sorted by (config, algorithm, devices) for stable diffs
        devices = [int(line.split(",")[2]) for line in lines[1:]]
        assert devices == sorted(devices)

    def test_monotone_bottleneck_and_overlap(self, curve_points):
        pts = sorted(curve_points, key=lambda p: p.devices)
        bn = [p.bottleneck_ms for p in pts]
        ov = [p.overlap_ms for p in pts]
        wire = [p.wire_bytes for p in pts]
        assert all(b1 >= b0 * (1 - 1e-9) for b0, b1 in zip(bn, bn[1:]))
        assert all(o1 >= o0 * (1 - 1e-9) for o0, o1 in zip(ov, ov[1:]))
        assert all(w1 > w0 for w0, w1 in zip(wire, wire[1:]))

    def test_points_are_sparse_and_labeled(self, curve_points):
        for p in curve_points:
            assert p.config == "ddp_test" and p.algorithm == "ring"
            assert p.nnz > 0 and p.nnz < (p.devices + 1) ** 2
            assert p.bottleneck_link != "-"

    def test_skips_non_multiples(self):
        logged = []
        pts = scale.scale_curve([FakeReport(ddp_ops(), base=8)], (100,),
                                log=logged.append)
        assert pts == [] and any("skip" in m for m in logged)

    def test_html_panel(self, curve_points, tmp_path):
        path = html_exporter.export_scale_html(
            [p.row() for p in curve_points], str(tmp_path / "sc.html"))
        doc = open(path).read()
        assert "ddp_test" in doc and "<svg" in doc
        assert "bottleneck link" in doc
        for p in curve_points:
            assert f"{p.devices:,}" in doc

    def test_table_renders(self, curve_points):
        out = scale.scale_table(curve_points)
        assert "bottleneck link" in out and "ddp_test" in out


# ---------------------------------------------------------------------------
# 16384 devices: no dense (d+1)^2 materialization anywhere on the path
# ---------------------------------------------------------------------------
class TestFleetScaleSmoke:
    def test_16k_point_peak_memory_bounded(self):
        rep = FakeReport(ddp_ops(num_ops=6))
        tracemalloc.start()
        p = scale.scale_point(rep, 16384)
        _, peak = tracemalloc.get_traced_memory()
        tracemalloc.stop()
        peak_mb = peak / 2**20
        # the dense (16385)^2 float64 matrix alone is ~2100 MiB
        assert peak_mb < 300, (
            f"16k-device scale point peaked at {peak_mb:.0f} MiB -- "
            "something materialized a dense fleet-scale array")
        assert p.devices == 16384 and p.pods == 64
        assert p.nnz > 0 and p.dcn_ms > 0
        assert p.bottleneck_link.startswith(("dcn:", "ici:"))


# ---------------------------------------------------------------------------
# project_links representation dispatch (satellite fix + regression)
# ---------------------------------------------------------------------------
class TestProjectLinksDispatch:
    def test_rejects_other_types_with_clear_error(self):
        topo = MeshTopology(axis_names=("data",), axis_sizes=(4,))
        with pytest.raises(TypeError, match=(
                r"project_links expects a dense \(d\+1\)x\(d\+1\) "
                r"np\.ndarray or a SparseCommMatrix, not list")):
            comm_matrix.project_links([[0.0] * 5] * 5, topo)
        with pytest.raises(TypeError, match="not NoneType"):
            comm_matrix.project_links(None, topo)

    def test_accepts_both_representations(self):
        topo = MeshTopology(axis_names=("data",), axis_sizes=(4,))
        dense = np.zeros((5, 5))
        dense[1, 2] = 64.0
        sp = SparseCommMatrix(4, np.array([1]), np.array([2]),
                              np.array([64.0]))
        lu_d = comm_matrix.project_links(dense, topo)
        lu_s = comm_matrix.project_links(sp, topo)
        assert lu_d.total_bytes() == lu_s.total_bytes() == 64.0

    def test_sparse_dcn_projection(self):
        """Cross-pod sparse entries charge DCN uplink + downlink."""
        topo = MeshTopology(axis_names=("pod", "data"), axis_sizes=(2, 2))
        sp = SparseCommMatrix(4, np.array([1]), np.array([3]),
                              np.array([128.0]))     # dev 0 -> dev 2
        lu = comm_matrix.project_links(sp, topo)
        up = [l for l in lu.bytes_by_link
              if l.kind == "dcn" and l.dst == DCN_FABRIC and l.src == 0]
        down = [l for l in lu.bytes_by_link
                if l.kind == "dcn" and l.src == DCN_FABRIC and l.dst == 2]
        assert lu.bytes_by_link[up[0]] == 128.0
        assert lu.bytes_by_link[down[0]] == 128.0
