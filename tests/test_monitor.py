"""End-to-end monitor: traced + compiled + matrices + roofline."""
import json

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import monitor_fn, roofline_of

pytestmark = pytest.mark.compile   # whole module drives XLA compiles


@pytest.fixture(scope="module")
def report(mesh8):
    def step(w, x):
        y = x @ w
        return (y ** 2).mean()

    ws = NamedSharding(mesh8, P(None, "model"))
    xs = NamedSharding(mesh8, P("data", None))
    return monitor_fn(
        jax.value_and_grad(step),
        jax.ShapeDtypeStruct((256, 256), jnp.float32),
        jax.ShapeDtypeStruct((128, 256), jnp.float32),
        mesh=mesh8, name="toy", in_shardings=(ws, xs))


class TestMonitor:
    def test_compiled_collectives_found(self, report):
        assert report.compiled_ops
        assert "all-reduce" in report.compiled_summary

    def test_matrix_shape_and_host_row(self, report):
        assert report.matrix.shape == (9, 9)
        assert report.matrix[0].sum() == 0  # no host transfers registered

    def test_render_contains_tables(self, report):
        txt = report.render()
        assert "traced vs compiled" in txt
        assert "comm matrix" in txt

    def test_roofline_terms_positive(self, report):
        rl = roofline_of(report, arch="toy", mesh_name="4x2",
                         model_flops=2 * 256 * 256 * 128 * 3)
        assert rl.compute_s > 0 and rl.memory_s > 0
        assert rl.dominant in ("compute", "memory", "collective")

    def test_roofline_overlap_bound(self, report):
        """Link-overlap model: per-tier sums partition the serialized
        collective time; the overlap bound never exceeds the serialized
        roofline and the per-link busy diagnostics are populated."""
        rl = roofline_of(report, arch="toy", mesh_name="4x2")
        assert rl.collective_ici_s + rl.collective_dcn_s == \
            pytest.approx(rl.collective_s_topo)
        assert rl.collective_overlap_s <= rl.collective_s_topo + 1e-15
        assert rl.bound_overlap_s <= max(rl.bound_time_s,
                                         rl.collective_s_topo) + 1e-15
        # single-pod mesh: everything rides ICI, overlap == serialized
        assert rl.collective_dcn_s == 0.0
        assert rl.collective_overlap_s == pytest.approx(rl.collective_s_topo)
        assert rl.ici_busy_s > 0 and rl.dcn_busy_s == 0.0
        from repro.core import roofline
        row = roofline.to_row(rl)
        assert {"collective_ici_s", "collective_dcn_s",
                "collective_overlap_s", "bound_overlap_s"} <= set(row)

    def test_report_tier_split(self, report):
        ici_s, dcn_s = report.collective_seconds_split()
        assert ici_s + dcn_s == pytest.approx(report.collective_seconds())
        assert report.collective_overlap_seconds() == \
            pytest.approx(max(ici_s, dcn_s))
        assert "tier overlap" in report.link_table()

    def test_save_json(self, report, tmp_path):
        p = tmp_path / "report.json"
        report.save(str(p))
        data = json.loads(p.read_text())
        assert data["name"] == "toy"
        assert "summary" in data and "matrix" in data
        assert len(data["matrix"]) == 9

    def test_host_transfers_fill_row0(self, mesh8):
        from repro.core.events import HostTransfer
        rep = monitor_fn(
            lambda x: (x * 2).sum(),
            jax.ShapeDtypeStruct((8, 8), jnp.float32), mesh=mesh8,
            host_transfers=[HostTransfer("h2d", 2, 4096)])
        assert rep.matrix[0, 3] == 4096

    def test_shape_dtype_structs_no_allocation(self, mesh8):
        # monitoring with SDS stand-ins must not materialize arrays
        rep = monitor_fn(
            lambda x: x.sum(),
            jax.ShapeDtypeStruct((1 << 14, 1 << 14), jnp.float32),
            mesh=mesh8)  # 1 GiB array never allocated
        assert rep.cost is not None
