"""Sparse == dense equivalence plus SparseCommMatrix unit behavior.

The sparse COO path is only allowed to exist because it is **element-exact**
against the dense builder: both accumulate per-cell contributions in the
same encounter order (the sparse coalesce uses a stable sort + sequential
``reduceat``), so equality is bitwise, not approximate.  The suite pins
that over randomized op streams, all three algorithms, phase tags, 1/2/4-pod
meshes and the PR-5 multi-axis per-phase schedules.

``hypothesis`` is an optional [test] extra: the randomized-stream tests run
over a deterministic seed grid on a bare interpreter, and hypothesis (when
present) drives the same generator over a much wider draw space.
"""
import warnings

import numpy as np
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
    HAVE_HYPOTHESIS = True
except ImportError:           # [test] extra absent: the seed grid still runs
    HAVE_HYPOTHESIS = False

from repro.core import comm_matrix
from repro.core.decompose import (HierarchicalFallbackWarning,
                                  schedules_for_ops)
from repro.core.events import CollectiveOp, HostTransfer, Shape
from repro.core.sparse import (SparseAccumulator, SparseCommMatrix,
                               from_dense, is_sparse)
from repro.core.topology import MeshTopology
from repro.core.views import CommView

# 1-, 2- and 4-pod meshes (pod = DCN axis); device ids follow the jax
# row-major convention the topology model assumes
MESHES = {
    "1pod": MeshTopology(axis_names=("data", "model"), axis_sizes=(4, 2)),
    "2pod": MeshTopology(axis_names=("pod", "data", "model"),
                         axis_sizes=(2, 4, 2)),
    "4pod": MeshTopology(axis_names=("pod", "data", "model"),
                         axis_sizes=(4, 4, 2)),
}

KINDS = ("all-reduce", "all-gather", "reduce-scatter",
         "collective-broadcast", "all-to-all", "collective-permute")
PHASES = ("", "fwd", "bwd")
ALGORITHMS = ("ring", "tree", "hierarchical")


def make_stream(mesh_key: str, seed: int, num_ops: int = 5):
    """(ops, topo): a seeded randomized stream against one of the meshes --
    mixed kinds, permuted groups, loop-trip weights, phase tags."""
    topo = MESHES[mesh_key]
    d = topo.num_devices
    rng = np.random.default_rng(seed)
    ops = []
    for i in range(num_ops):
        kind = KINDS[int(rng.integers(len(KINDS)))]
        elems = int(rng.integers(1, 1 << 12))
        weight = float(rng.integers(1, 17))
        phase = PHASES[int(rng.integers(len(PHASES)))]
        if kind == "collective-permute":
            perm = rng.permutation(d)
            pairs = [(int(perm[j]), int(perm[(j + 1) % d]))
                     for j in range(d)]
            ops.append(CollectiveOp(
                kind=kind, name=f"op{i}",
                result_shapes=[Shape("f32", (elems,))],
                replica_groups=[], source_target_pairs=pairs,
                weight=weight, phase=phase))
            continue
        gsize = int(rng.choice([s for s in (2, 4, 8, d) if s <= d]))
        devs = rng.permutation(d)
        groups = [sorted(int(x) for x in devs[k:k + gsize])
                  for k in range(0, d, gsize)]
        ops.append(CollectiveOp(
            kind=kind, name=f"op{i}",
            result_shapes=[Shape("f32", (elems,))],
            replica_groups=groups, weight=weight, phase=phase))
    return ops, topo


def _both(ops, d, algorithm, topo):
    with warnings.catch_warnings():
        # hierarchical refusals fall back identically on both paths; the
        # warning itself is pinned elsewhere (test_comm_matrix)
        warnings.simplefilter("ignore", HierarchicalFallbackWarning)
        dense = comm_matrix.matrix_for_ops(ops, d, algorithm, topo=topo)
        sparse = comm_matrix.matrix_for_ops(ops, d, algorithm, topo=topo,
                                            sparse=True)
    return dense, sparse


def check_element_exact(mesh_key, seed, algorithm):
    ops, topo = make_stream(mesh_key, seed)
    dense, sparse = _both(ops, topo.num_devices, algorithm, topo)
    assert is_sparse(sparse) and not is_sparse(dense)
    np.testing.assert_array_equal(sparse.to_dense(), dense)


def check_per_phase(mesh_key, seed):
    ops, topo = make_stream(mesh_key, seed)
    for phase in PHASES:
        sub = [op for op in ops if op.phase == phase]
        dense, sparse = _both(sub, topo.num_devices, "ring", topo)
        np.testing.assert_array_equal(sparse.to_dense(), dense)


def check_schedules(mesh_key, seed):
    ops, topo = make_stream(mesh_key, seed)
    d = topo.num_devices
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", HierarchicalFallbackWarning)
        scheds = schedules_for_ops(ops, "ring", topo, warn=False)
        dense = comm_matrix.matrix_for_schedules(ops, scheds, d)
        sparse = comm_matrix.matrix_for_schedules(ops, scheds, d,
                                                  sparse=True)
    np.testing.assert_array_equal(sparse.to_dense(), dense)


class TestSparseDenseEquivalence:
    """Deterministic seed grid -- always runs, even without hypothesis."""

    @pytest.mark.parametrize("mesh_key", sorted(MESHES))
    @pytest.mark.parametrize("algorithm", ALGORITHMS)
    @pytest.mark.parametrize("seed", range(4))
    def test_element_exact_over_streams(self, mesh_key, algorithm, seed):
        check_element_exact(mesh_key, seed, algorithm)

    @pytest.mark.parametrize("mesh_key", sorted(MESHES))
    @pytest.mark.parametrize("seed", range(3))
    def test_per_phase_views_element_exact(self, mesh_key, seed):
        """Per-phase bindings (PR-4 sessions): filtering by phase tag then
        building sparse equals the dense per-phase matrix."""
        check_per_phase(mesh_key, seed)

    @pytest.mark.parametrize("mesh_key", sorted(MESHES))
    @pytest.mark.parametrize("seed", range(3))
    def test_matrix_for_schedules_element_exact(self, mesh_key, seed):
        """The pre-built-schedule entry point (what CommView calls):
        multi-axis per-phase schedules included, since full-mesh groups on
        these topologies decompose into one ring phase per torus axis."""
        check_schedules(mesh_key, seed)

    @pytest.mark.parametrize("n", [8, 16, 32])
    def test_multiaxis_full_mesh_schedule(self, n):
        """Full-mesh groups with a topology -> per-axis ring phases (the
        PR-5 decomposition); sparse must track the dense placement."""
        topo = MeshTopology(axis_names=("data", "model"),
                            axis_sizes=(n // 2, 2))
        op = CollectiveOp(kind="all-reduce", name="ma",
                          result_shapes=[Shape("f32", (1024,))],
                          replica_groups=[list(range(n))])
        dense, sparse = _both([op], n, "ring", topo)
        np.testing.assert_array_equal(sparse.to_dense(), dense)
        assert sparse.sum() == pytest.approx(dense.sum())

    def test_host_transfers_match(self):
        transfers = [HostTransfer("h2d", 0, 100), HostTransfer("h2d", 3, 50),
                     HostTransfer("d2h", 1, 25), HostTransfer("d2h", 1, 10)]
        dense = np.zeros((5, 5))
        comm_matrix.add_host_transfers(dense, transfers)
        sparse = comm_matrix.add_host_transfers(
            SparseCommMatrix(4), transfers)
        np.testing.assert_array_equal(sparse.to_dense(), dense)

    def test_per_primitive_matches(self):
        ops, topo = [
            CollectiveOp(kind="all-reduce", name="a",
                         result_shapes=[Shape("f32", (64,))],
                         replica_groups=[[0, 1, 2, 3]]),
            CollectiveOp(kind="all-gather", name="b",
                         result_shapes=[Shape("f32", (64,))],
                         replica_groups=[[0, 1], [2, 3]]),
        ], MESHES["1pod"]
        dense = comm_matrix.per_primitive_matrices(ops, 8, topo=topo)
        sparse = comm_matrix.per_primitive_matrices(ops, 8, topo=topo,
                                                    sparse=True)
        assert sorted(dense) == sorted(sparse)
        for k in dense:
            np.testing.assert_array_equal(sparse[k].to_dense(), dense[k])

    @pytest.mark.parametrize("mesh_key", sorted(MESHES))
    def test_link_projection_identical(self, mesh_key):
        """Both representations project to the same per-link byte view."""
        ops, topo = make_stream(mesh_key, seed=7)
        dense, sparse = _both(ops, topo.num_devices, "ring", topo)
        lu_d = comm_matrix.project_links(dense, topo)
        lu_s = comm_matrix.project_links(sparse, topo)
        assert lu_d.bytes_by_link.keys() == lu_s.bytes_by_link.keys()
        for link, b in lu_d.bytes_by_link.items():
            assert lu_s.bytes_by_link[link] == pytest.approx(b, rel=1e-12)
        np.testing.assert_allclose(lu_s.sparse_matrix().to_dense(),
                                   lu_d.matrix(), rtol=1e-12)


if HAVE_HYPOTHESIS:
    class TestSparseDenseProperty:
        """Hypothesis drives the same generator over a wider draw space."""

        @given(mesh_key=st.sampled_from(sorted(MESHES)),
               seed=st.integers(0, 2**31 - 1),
               algorithm=st.sampled_from(list(ALGORITHMS)))
        @settings(max_examples=80, deadline=None)
        def test_element_exact_over_streams(self, mesh_key, seed, algorithm):
            check_element_exact(mesh_key, seed, algorithm)

        @given(mesh_key=st.sampled_from(sorted(MESHES)),
               seed=st.integers(0, 2**31 - 1))
        @settings(max_examples=30, deadline=None)
        def test_per_phase_views_element_exact(self, mesh_key, seed):
            check_per_phase(mesh_key, seed)

        @given(mesh_key=st.sampled_from(sorted(MESHES)),
               seed=st.integers(0, 2**31 - 1))
        @settings(max_examples=30, deadline=None)
        def test_matrix_for_schedules_element_exact(self, mesh_key, seed):
            check_schedules(mesh_key, seed)


class TestSparseCommMatrixUnit:
    def test_coalesce_and_accessors(self):
        m = SparseCommMatrix(4,
                             np.array([1, 2, 1, 0]),
                             np.array([2, 1, 2, 3]),
                             np.array([5.0, 7.0, 3.0, 2.0]))
        assert m.nnz == 3                    # (1,2) entries merged
        assert m.sum() == 17.0 and m.max() == 8.0
        assert m.shape == (5, 5) and m.num_devices == 4
        dense = m.to_dense()
        assert dense[1, 2] == 8.0 and dense[2, 1] == 7.0
        np.testing.assert_array_equal(m.row_sums(), dense.sum(axis=1))
        np.testing.assert_array_equal(m.col_sums(), dense.sum(axis=0))

    def test_device_entries_skip_host(self):
        m = SparseCommMatrix(4, np.array([0, 1, 2]), np.array([1, 0, 3]),
                             np.array([9.0, 4.0, 6.0]))
        src, dst, val = m.device_entries()
        assert src.tolist() == [1] and dst.tolist() == [2]
        assert val.tolist() == [6.0]

    def test_validation(self):
        with pytest.raises(ValueError):
            SparseCommMatrix(2, np.array([5]), np.array([0]),
                             np.array([1.0]))
        with pytest.raises(ValueError):
            SparseCommMatrix(2, np.array([0]), np.array([-1]),
                             np.array([1.0]))

    @pytest.mark.parametrize("d,seed", [(4, 0), (8, 1), (33, 2)])
    def test_from_dense_round_trip(self, d, seed):
        rng = np.random.default_rng(seed)
        dense = np.where(rng.random((d + 1, d + 1)) < 0.2,
                         rng.random((d + 1, d + 1)) * 1e6, 0.0)
        np.testing.assert_array_equal(from_dense(dense).to_dense(), dense)

    @pytest.mark.parametrize("d,seed", [(8, 0), (64, 1), (100, 2)])
    def test_coarsen_matches_dense_coarsening(self, d, seed):
        """Sparse coarsening (heatmap path) must equal coarsening the
        equivalent dense matrix -- same blocks, same host row/col."""
        from repro.core.reporter import coarsen_matrix
        rng = np.random.default_rng(seed)
        dense = np.where(rng.random((d + 1, d + 1)) < 0.3,
                         rng.random((d + 1, d + 1)) * 1e9, 0.0)
        hm_d, k_d = coarsen_matrix(dense, max_devices=8)
        hm_s, k_s = coarsen_matrix(from_dense(dense), max_devices=8)
        assert k_d == k_s
        np.testing.assert_allclose(hm_s, hm_d, rtol=1e-12)

    def test_accumulator_squash_bounded(self):
        acc = SparseAccumulator(4)
        for _ in range(10):
            acc.add(np.array([1, 2]), np.array([2, 1]),
                    np.array([1.0, 2.0]))
        m = acc.build()
        assert m.nnz == 2
        assert m.to_dense()[1, 2] == 10.0 and m.to_dense()[2, 1] == 20.0

    def test_to_csv_rows_long_form(self):
        m = SparseCommMatrix(2, np.array([0, 1]), np.array([1, 2]),
                             np.array([4.0, 8.0]))
        rows = m.to_csv_rows()
        assert rows == ["host,gpu0,4", "gpu0,gpu1,8"]

    def test_view_auto_cutover(self):
        op = CollectiveOp(kind="all-reduce", name="x",
                          result_shapes=[Shape("f32", (8,))],
                          replica_groups=[[0, 1]])
        assert CommView([op], 8).use_sparse is False
        assert CommView([op], 8, sparse=True).use_sparse is True
        assert is_sparse(CommView([op], 8, sparse=True).matrix)
        assert CommView([op], 4096).use_sparse is True
        assert CommView([op], 2048).use_sparse is False
