"""The modeled-vs-measured compare layer and its CLI.

Covers the matching rules (exact ``(phase, name)`` first, then per-kind
FIFO), the rel-err / size-class math, the committed-fixture CI gate
(``serve_trace.csv`` vs ``serve_report.json`` stays below the pinned
0.15 bound -- the deltas baked into the fixture peak at 8.7%), and the
``repro compare`` exit-code contract: 0 clean, 1 threshold, 2 usage.
"""
import json
import os

import pytest

from repro import cli
from repro.core import CommReport
from repro.core.trace import load_trace
from repro.core.trace.compare import (CompareResult, CompareRow, compare,
                                      size_class)

FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures")
SERVE_CSV = os.path.join(FIXTURES, "serve_trace.csv")
SERVE_REPORT = os.path.join(FIXTURES, "serve_report.json")

#: the bound the CI gate pins; fixture deltas peak at 0.08/0.92 = 8.7%
CI_REL_ERR_BOUND = 0.15


# ---------------------------------------------------------------------------
# row / bucket math
# ---------------------------------------------------------------------------
class TestRowMath:
    def test_rel_err(self):
        r = CompareRow(name="ar.1", kind="all-reduce", phase="fwd",
                       payload_bytes=4096, modeled_s=0.9e-3,
                       measured_s=1.0e-3)
        assert r.rel_err == pytest.approx(0.1)

    def test_rel_err_none_when_unmodeled_or_zero(self):
        r = CompareRow("a", "all-reduce", "", 1, None, 1.0)
        assert r.rel_err is None
        r = CompareRow("a", "all-reduce", "", 1, 1.0, 0.0)
        assert r.rel_err is None

    @pytest.mark.parametrize("nbytes,label", [
        (0, "<64KiB"),
        (64 * 1024 - 1, "<64KiB"),
        (64 * 1024, "64KiB-1MiB"),
        ((1 << 20) - 1, "64KiB-1MiB"),
        (1 << 20, "1-16MiB"),
        ((16 << 20) - 1, "1-16MiB"),
        (16 << 20, ">=16MiB"),
        (1 << 30, ">=16MiB"),
    ])
    def test_size_class_boundaries(self, nbytes, label):
        assert size_class(nbytes) == label

    def test_bucket_stats_and_table(self):
        rows = [
            CompareRow("ar.1", "all-reduce", "fwd", 1024, 1.0e-3, 1.1e-3),
            CompareRow("ar.2", "all-reduce", "bwd", 2 << 20, 2.0e-3,
                       1.9e-3),
            CompareRow("ag.1", "all-gather", "fwd", 512, 0.5e-3, 0.5e-3),
        ]
        res = CompareResult(rows=rows, measured_label="m",
                            modeled_label="M")
        s = res.stats()
        assert s["count"] == 3
        assert s["max_rel_err"] == pytest.approx(abs(1.1 - 1.0) / 1.1)
        assert set(res.by_kind()) == {"all-reduce", "all-gather"}
        assert set(res.by_size_class()) == {"<64KiB", "1-16MiB"}
        txt = res.table(title="hdr")
        assert "hdr" in txt and "ar.1" in txt and "RelErr" in txt
        assert "3 matched" in txt
        d = res.to_dict()
        assert len(d["rows"]) == 3 and d["stats"]["count"] == 3


# ---------------------------------------------------------------------------
# matching
# ---------------------------------------------------------------------------
def _measured_report(ops_spec, num_devices=8, name="measured"):
    """A topology-free report whose ops carry measured_s."""
    from repro.core.trace.base import TraceImport
    from repro.core.trace.normalize import measured_op

    ops = [measured_op(kind, payload_bytes=nbytes,
                       groups=[list(range(num_devices))], name=opname,
                       measured_s=sec, phase=phase)
           for (opname, kind, nbytes, sec, phase) in ops_spec]
    return TraceImport(name=name, num_devices=num_devices,
                       ops=ops).report()


@pytest.fixture(scope="module")
def serve_model():
    return CommReport.load(SERVE_REPORT)


class TestMatching:
    def test_exact_phase_name_match_beats_fifo(self, serve_model):
        # copy two modeled ops' identities exactly, but list them in
        # reverse order: (phase, name) matching must pair them right
        # (name alone is ambiguous -- prefill and decode reuse HLO names)
        mview = serve_model.view()
        secs = dict(zip([(op.phase, op.name) for op in mview.ops],
                        mview.op_seconds()))
        picks = [op for op in mview.ops if op.kind == "all-reduce"][:2]
        assert len(picks) == 2
        spec = [(op.name, op.kind, op.payload_bytes,
                 secs[(op.phase, op.name)] * 1.05, op.phase)
                for op in reversed(picks)]
        res = compare(_measured_report(spec), serve_model)
        by_key = {(r.phase, r.name): r for r in res.rows}
        for op in picks:
            row = by_key[(op.phase, op.name)]
            assert row.modeled_s == \
                pytest.approx(secs[(op.phase, op.name)])
            assert row.rel_err == pytest.approx(0.05 / 1.05, rel=1e-6)

    def test_fifo_matches_kth_measured_to_kth_modeled(self, serve_model):
        # nvprof-style names never match HLO names: program order within
        # a kind is the signal
        mview = serve_model.view()
        kinds = [op.kind for op in mview.ops]
        secs = mview.op_seconds()
        idx = [i for i, k in enumerate(kinds) if k == "all-to-all"][:2]
        assert len(idx) == 2
        spec = [(f"ncclAllToAll.r{j}", "all-to-all",
                 mview.ops[i].payload_bytes, secs[i] * 1.02, "")
                for j, i in enumerate(idx)]
        res = compare(_measured_report(spec), serve_model)
        assert [r.modeled_s for r in res.rows] == \
            [pytest.approx(secs[i]) for i in idx]
        assert res.unmatched_measured == 0

    def test_unmatched_counts(self, serve_model):
        # a kind the serve report has none of stays unmatched; leftover
        # modeled ops are counted on the other side
        n_model = len(serve_model.compiled_ops)
        assert not any(op.kind == "all-gather"
                       for op in serve_model.compiled_ops)
        spec = [("x.1", "all-gather", 1024, 1e-3, ""),
                ("y.1", "all-reduce", 1024, 1e-3, "")]
        res = compare(_measured_report(spec), serve_model)
        assert res.unmatched_measured == 1
        assert res.unmatched_modeled == n_model - 1
        assert len(res.rows) == 1

    def test_no_overlap_raises(self, serve_model):
        spec = [("x.1", "all-gather", 1024, 1e-3, "")]
        with pytest.raises(ValueError, match="matched"):
            compare(_measured_report(spec), serve_model)

    def test_no_measured_ops_raises(self, serve_model):
        with pytest.raises(ValueError, match="no measured ops"):
            compare(serve_model, serve_model)

    def test_own_model_needs_topology(self):
        spec = [("x.1", "all-gather", 1024, 1e-3, "")]
        with pytest.raises(ValueError, match="no topology"):
            compare(_measured_report(spec))

    def test_own_model_of_own_export(self, tmp_path):
        # our own Perfetto export carries topology + measured_s: its
        # import compares against its own cost model with zero error
        # (the export stamps modeled durations when ops carry none)
        from repro.core.export.perfetto import export_perfetto

        rep = CommReport.load(
            os.path.join(FIXTURES, "translation_report.json"))
        path = export_perfetto(rep, str(tmp_path / "t.trace.json"))
        res = load_trace(path).report().compare()
        assert res.rows
        # only the exporter's microsecond rounding separates the sides
        assert res.max_rel_err() < 1e-3


# ---------------------------------------------------------------------------
# the committed-fixture CI gate
# ---------------------------------------------------------------------------
class TestFixtureGate:
    def test_serve_csv_vs_serve_report_below_bound(self, serve_model):
        measured = load_trace(SERVE_CSV).report()
        res = compare(measured, serve_model)
        s = res.stats()
        assert s["count"] == len(serve_model.compiled_ops)
        assert s["unmatched_measured"] == 0
        assert s["unmatched_modeled"] == 0
        assert 0 < s["mean_rel_err"] < CI_REL_ERR_BOUND
        assert 0 < s["max_rel_err"] < CI_REL_ERR_BOUND

    def test_gate_survives_a_v9_save_load_cycle(self, tmp_path,
                                                serve_model):
        measured = load_trace(SERVE_CSV).report()
        p = str(tmp_path / "imported.json")
        measured.save(p)
        res = compare(CommReport.load(p), serve_model)
        assert res.max_rel_err() < CI_REL_ERR_BOUND


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------
class TestExports:
    @pytest.fixture()
    def result(self, serve_model):
        return compare(load_trace(SERVE_CSV).report(), serve_model)

    def test_csv_export_header_and_rows(self, result, tmp_path):
        from repro.core.export.csv_exporter import (COMPARE_COLUMNS,
                                                    export_compare_csv)

        path = export_compare_csv(result, str(tmp_path / "cmp.csv"))
        with open(path) as f:
            lines = f.read().splitlines()
        assert lines[0] == ",".join(COMPARE_COLUMNS)
        assert len(lines) == 1 + len(result.rows)

    def test_html_export(self, result, tmp_path):
        from repro.core.export.html_exporter import export_compare_html

        path = export_compare_html([result], str(tmp_path / "cmp.html"))
        html = open(path).read()
        assert "Modeled vs measured" in html
        assert "size class" in html

    def test_measured_panel_in_report_html(self, tmp_path):
        # an imported report's regular HTML export grows the compare
        # panel; a purely modeled report's does not
        from repro.core.export.html_exporter import export_html

        rep = load_trace(os.path.join(
            FIXTURES, "translation_trace.json")).report()
        html = open(export_html(rep, str(tmp_path / "m.html"))).read()
        assert "modeled vs measured" in html
        modeled = CommReport.load(
            os.path.join(FIXTURES, "translation_report.json"))
        html2 = open(export_html(modeled,
                                 str(tmp_path / "p.html"))).read()
        assert "modeled vs measured" not in html2

    def test_reporter_compare_table(self, result):
        from repro.core import reporter

        txt = reporter.compare_table(result, title="T")
        assert txt.startswith("T")
        assert "RelErr" in txt


# ---------------------------------------------------------------------------
# CLI: repro compare
# ---------------------------------------------------------------------------
class TestCli:
    def test_exit_0_and_table_on_stdout(self, capsys):
        rc = cli.main(["compare", SERVE_CSV, SERVE_REPORT,
                       "--fail-on", f"rel-err={CI_REL_ERR_BOUND}"])
        out, err = capsys.readouterr()
        assert rc == 0
        assert "RelErr" in out and "matched" in out
        assert "imported" in err        # logs stay on stderr

    def test_exit_1_when_threshold_hit(self, capsys):
        rc = cli.main(["compare", SERVE_CSV, SERVE_REPORT,
                       "--fail-on", "rel-err=0.01"])
        _, err = capsys.readouterr()
        assert rc == 1
        assert "exceeds --fail-on" in err

    def test_json_stdout_is_pure(self, capsys, tmp_path):
        save = str(tmp_path / "imported.json")
        rc = cli.main(["compare", SERVE_CSV, SERVE_REPORT, "--json",
                       "--save-import", save])
        out, err = capsys.readouterr()
        assert rc == 0
        doc = json.loads(out)            # stdout parses as one document
        assert doc["stats"]["count"] > 0
        assert doc["rows"]
        assert "imported" in err
        # the saved import feeds a second, trace-free compare run
        rc = cli.main(["compare", save, SERVE_REPORT,
                       "--fail-on", f"rel-err={CI_REL_ERR_BOUND}"])
        assert rc == 0

    def test_exports_land_in_out_dir(self, capsys, tmp_path):
        rc = cli.main(["compare", SERVE_CSV, SERVE_REPORT,
                       "--formats", "csv,html", "--out", str(tmp_path)])
        _, err = capsys.readouterr()
        assert rc == 0
        assert os.path.exists(tmp_path / "serve_trace_compare.csv")
        assert os.path.exists(tmp_path / "serve_trace_compare.html")
        assert "[csv]" in err and "[html]" in err

    def test_exit_2_on_bad_threshold(self, capsys):
        rc = cli.main(["compare", SERVE_CSV, SERVE_REPORT,
                       "--fail-on", "latency=9"])
        _, err = capsys.readouterr()
        assert rc == 2
        assert "rel-err=<float>" in err

    def test_exit_2_on_bad_format(self, capsys):
        rc = cli.main(["compare", SERVE_CSV, SERVE_REPORT,
                       "--fmt", "vtune"])
        _, err = capsys.readouterr()
        assert rc == 2
        assert "valid formats" in err

    def test_exit_2_on_missing_trace(self, capsys):
        rc = cli.main(["compare", "/nonexistent/trace.json",
                       SERVE_REPORT])
        _, err = capsys.readouterr()
        assert rc == 2
        assert "not found" in err

    def test_exit_2_on_unknown_config(self, capsys):
        rc = cli.main(["compare", SERVE_CSV, "no_such_config"])
        _, err = capsys.readouterr()
        assert rc == 2
        assert "known configs" in err

    def test_exit_2_on_malformed_trace(self, capsys, tmp_path):
        bad = tmp_path / "bad.jsonl"
        bad.write_text('{"kind": "all-reduce", "dur": 1.0, "by')
        rc = cli.main(["compare", str(bad), SERVE_REPORT])
        _, err = capsys.readouterr()
        assert rc == 2
        assert "line 1" in err
