"""Session API: multi-phase capture, lazy CommView bindings, schema v4, and
the monitor_fn compatibility contract (golden equality with a single-phase
session)."""
import json
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import (CommReport, CommView, MonitorSession, comm_matrix,
                        hlo_parser, monitor_fn, roofline_of)
from repro.core.events import CollectiveOp, HostTransfer, Shape


def mk_op(kind="all-reduce", elems=64, groups=None, pairs=None, phase=""):
    return CollectiveOp(kind=kind, name="t",
                        result_shapes=[Shape("f32", (elems,))],
                        replica_groups=groups or [[0, 1, 2, 3]],
                        source_target_pairs=pairs or [], phase=phase)


class TestCommView:
    """The lazy view: memoized artifacts, cheap re-binding, validation."""

    def test_matches_functional_layer(self):
        ops = [mk_op("all-reduce"), mk_op("all-gather", groups=[[0, 1]])]
        v = CommView(ops, 4)
        np.testing.assert_allclose(
            v.matrix, comm_matrix.matrix_for_ops(ops, 4))
        assert v.summary == hlo_parser.summarize(ops)
        assert v.total_wire_bytes() == hlo_parser.total_wire_bytes(ops)
        assert set(v.per_primitive) == {"all-reduce", "all-gather"}

    def test_memoized(self):
        v = CommView([mk_op()], 4)
        assert v.matrix is v.matrix
        assert v.per_primitive is v.per_primitive
        assert v.summary is v.summary

    def test_rebind_is_lazy_and_shares_ops(self):
        v = CommView([mk_op()], 4)
        _ = v.matrix
        t = v.rebind("tree")
        assert t.ops == v.ops and t.ops[0] is v.ops[0]
        assert not t._memo, "rebinding must not compute anything eagerly"
        assert not np.allclose(t.matrix, v.matrix)
        assert v.rebind("ring") is v

    def test_host_transfers_in_matrix(self):
        v = CommView([mk_op()], 4,
                     host_transfers=[HostTransfer("h2d", 1, 512)])
        assert v.matrix[0, 2] == 512

    def test_no_topo_degenerates(self):
        v = CommView([mk_op()], 4)
        assert v.link_utilization() is None
        assert v.link_matrix() is None
        assert v.collective_seconds() == 0.0
        assert v.link_seconds() == 0.0

    def test_unknown_algorithm_rejected(self):
        with pytest.raises(ValueError, match="unknown algorithm"):
            CommView([mk_op()], 4, algorithm="nccl")


class TestAlgorithmValidation:
    """Satellite: every entry point rejects unknown algorithm strings."""

    def test_session_ctor(self):
        with pytest.raises(ValueError, match="unknown algorithm"):
            MonitorSession(algorithm="treee")

    def test_session_view(self):
        with pytest.raises(ValueError, match="unknown algorithm"):
            MonitorSession().view("treee")

    def test_matrix_for_ops(self):
        with pytest.raises(ValueError, match="unknown algorithm"):
            comm_matrix.matrix_for_ops([mk_op()], 4, "collnet")

    def test_report_view(self):
        rep = _hand_report()
        with pytest.raises(ValueError, match="unknown algorithm"):
            rep.view("nccl")

    @pytest.mark.compile
    def test_monitor_fn(self, mesh8):
        with pytest.raises(ValueError, match="unknown algorithm"):
            monitor_fn(lambda x: x.sum(),
                       jax.ShapeDtypeStruct((8,), jnp.float32),
                       mesh=mesh8, algorithm="treee")


class TestPermuteNumGroups:
    """Satellite: multi-group collective-permutes scale like every other
    kind (wire totals AND matrix placement)."""

    def test_wire_bytes_scale_with_groups(self):
        pairs = [(0, 1), (1, 0)]
        one = mk_op("collective-permute", groups=[[0, 1]], pairs=pairs)
        two = mk_op("collective-permute", groups=[[0, 1], [2, 3]],
                    pairs=pairs)
        assert two.num_groups == 2
        assert two.wire_bytes_total() == 2 * one.wire_bytes_total()

    def test_matrix_total_matches_wire_total(self):
        op = mk_op("collective-permute", groups=[[0, 1], [2, 3]],
                   pairs=[(0, 1), (1, 0)])
        mat = comm_matrix.matrix_for_ops([op], 4)
        assert mat.sum() == pytest.approx(op.wire_bytes_total())

    def test_groupless_permute_unchanged(self):
        op = mk_op("collective-permute", groups=[], pairs=[(0, 1)])
        assert op.wire_bytes_total() == op.result_bytes


def _hand_report(phases=()):
    ops = [mk_op(phase=p) for p in (phases or ("",))]
    from repro.core.events import PhaseRecord
    v = CommView(ops, 4)
    return CommReport(
        name="hand", num_devices=4, traced=[], compiled_ops=ops,
        traced_summary={}, compiled_summary=v.summary, matrix=v.matrix,
        per_primitive=v.per_primitive, cost={}, memory_stats=None,
        trace_seconds=0.0, compile_seconds=0.0,
        phases=[PhaseRecord(name=p, num_captures=1) for p in phases])


class TestReportPhases:
    """Phase bookkeeping on hand-built reports (no compilation)."""

    def test_phase_names_from_records(self):
        rep = _hand_report(phases=("fwd", "bwd"))
        assert rep.phase_names() == ["fwd", "bwd"]

    def test_phase_names_from_op_tags_when_no_records(self):
        rep = _hand_report()
        rep.compiled_ops[0].phase = "legacy"
        rep.phases = []
        assert rep.phase_names() == ["legacy"]

    def test_unknown_phase_rejected(self):
        rep = _hand_report(phases=("fwd",))
        with pytest.raises(KeyError, match="unknown phase"):
            rep.view(phase="bwd")

    def test_phase_view_filters_ops(self):
        rep = _hand_report(phases=("fwd", "bwd"))
        v = rep.view(phase="fwd")
        assert all(op.phase == "fwd" for op in v.ops)
        assert len(v.ops) == 1

    def test_default_view_seeded_from_snapshot(self):
        rep = _hand_report(phases=("fwd",))
        assert rep.view().matrix is rep.matrix
        assert rep.view().summary is rep.compiled_summary

    def test_phase_table_marks_empty_phase(self):
        rep = _hand_report(phases=("fwd",))
        from repro.core.events import PhaseRecord
        rep.phases.append(PhaseRecord(name="optim", num_captures=1))
        txt = rep.phase_table()
        assert "optim" in txt and "(none)" in txt

    def test_phase_diff_renders_delta(self):
        rep = _hand_report(phases=("fwd", "bwd"))
        txt = rep.phase_diff("fwd", "bwd")
        assert "fwd calls" in txt and "bwd wire" in txt and "Δ wire" in txt


@pytest.fixture(scope="module")
def phased_session(mesh8):
    """fwd / bwd / optim phases: fwd + bwd communicate, optim is local."""
    ws = NamedSharding(mesh8, P(None, "model"))
    xs = NamedSharding(mesh8, P("data", None))
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)

    def fwd(w, x):
        return ((x @ w) ** 2).mean()

    def optim(w):
        return w * 0.9

    sess = MonitorSession(mesh=mesh8, name="phased")
    with sess:
        with sess.phase("fwd"):
            sess.capture(fwd, w, x, in_shardings=(ws, xs))
        with sess.phase("bwd"):
            sess.capture(jax.value_and_grad(fwd), w, x,
                         in_shardings=(ws, xs))
        with sess.phase("optim"):
            sess.capture(optim, w, in_shardings=(ws,))
    return sess


@pytest.mark.compile
class TestMonitorSession:
    def test_phase_order_and_records(self, phased_session):
        sess = phased_session
        assert sess.phase_names() == ["fwd", "bwd", "optim"]
        assert all(sess._phases[p].num_captures == 1
                   for p in sess.phase_names())
        assert sess.compile_seconds > 0

    def test_ops_are_phase_tagged(self, phased_session):
        phases = {op.phase for op in phased_session.compiled_ops}
        assert phases <= {"fwd", "bwd", "optim"}
        assert "bwd" in phases

    def test_per_phase_sums_equal_whole(self, phased_session):
        sess = phased_session
        total = sum(sess.view(phase=p).matrix for p in sess.phase_names())
        np.testing.assert_allclose(total, sess.view().matrix)
        whole = sess.view().summary
        per = {}
        for p in sess.phase_names():
            for kind, row in sess.view(phase=p).summary.items():
                agg = per.setdefault(kind, {"calls": 0, "wire_bytes": 0.0})
                agg["calls"] += row["calls"]
                agg["wire_bytes"] += row["wire_bytes"]
        for kind, row in whole.items():
            assert per[kind]["calls"] == row["calls"]
            assert per[kind]["wire_bytes"] == pytest.approx(
                row["wire_bytes"])

    def test_rebinding_recompiles_nothing(self, phased_session):
        sess = phased_session
        n_captures = len(sess.captures)
        ring = sess.view()
        tree = sess.view("tree")
        hier = sess.view("hierarchical")
        assert tree.ops == sess.compiled_ops
        assert not np.allclose(tree.matrix, ring.matrix)
        assert hier.link_utilization() is not None
        assert len(sess.captures) == n_captures
        assert sess.view("tree") is tree            # memoized per binding

    def test_report_snapshot_and_render(self, phased_session):
        rep = phased_session.report()
        assert rep.phase_names() == ["fwd", "bwd", "optim"]
        txt = rep.render()
        assert "per-phase compiled collectives" in txt
        assert "optim" in txt

    def test_empty_phase_view_is_empty(self, phased_session):
        v = phased_session.view(phase="optim")
        assert v.matrix.sum() == 0.0 and v.summary == {}

    def test_host_transfer_list_reused_across_phases(self, mesh8):
        """Untagged transfers are copied per phase -- reusing one list must
        not mutate the caller's objects or double-count under one phase."""
        transfers = [HostTransfer("h2d", 0, 1024)]
        sess = MonitorSession(mesh=mesh8, name="ht")
        with sess.phase("a"):
            sess.add_host_transfers(transfers)
        with sess.phase("b"):
            sess.add_host_transfers(transfers)
        assert transfers[0].phase == ""            # caller object untouched
        assert sess.view(phase="a").matrix[0, 1] == 1024
        assert sess.view(phase="b").matrix[0, 1] == 1024
        assert sess.view().matrix[0, 1] == 2048
        # a pre-tagged transfer registers its phase for per-phase views
        sess.add_host_transfers([HostTransfer("d2h", 2, 64, phase="c")])
        assert "c" in sess.phase_names()
        assert sess.view(phase="c").matrix[3, 0] == 64

    def test_multi_capture_roofline_analyzes_per_module(self, phased_session):
        """Each capture's module is analyzed separately (concatenation
        would clobber same-named computations): the session roofline's
        totals equal the sum of per-capture analyses."""
        from repro.core import hlo_cost
        rep = phased_session.report()
        assert len(rep._hlo_texts) == len(phased_session.captures)
        per_module = [hlo_cost.analyze_hlo(t) for t in rep._hlo_texts]
        rl = roofline_of(rep, arch="phased", mesh_name="4x2")
        assert rl.flops_per_device == pytest.approx(
            sum(h.flops for h in per_module))
        assert rl.bytes_per_device == pytest.approx(
            sum(h.bytes_hbm for h in per_module))


@pytest.mark.compile
class TestCompatContract:
    """monitor_fn(...) must stay artifact-for-artifact equal to a
    single-phase MonitorSession over the same function."""

    @pytest.fixture(scope="class")
    def pair(self, mesh8):
        ws = NamedSharding(mesh8, P(None, "model"))
        xs = NamedSharding(mesh8, P("data", None))
        args = (jax.ShapeDtypeStruct((256, 256), jnp.float32),
                jax.ShapeDtypeStruct((128, 256), jnp.float32))

        def step(w, x):
            return ((x @ w) ** 2).mean()

        fn = jax.value_and_grad(step)
        old = monitor_fn(fn, *args, mesh=mesh8, name="toy",
                         in_shardings=(ws, xs),
                         host_transfers=[HostTransfer("h2d", 0, 128)])
        with MonitorSession(mesh=mesh8, name="toy") as sess:
            sess.capture(fn, *args, in_shardings=(ws, xs),
                         host_transfers=[HostTransfer("h2d", 0, 128)])
        return old, sess.report()

    def test_golden_equality(self, pair):
        old, new = pair
        np.testing.assert_allclose(old.matrix, new.matrix)
        assert old.compiled_summary == new.compiled_summary
        assert old.traced_summary == new.traced_summary
        assert set(old.per_primitive) == set(new.per_primitive)
        for k in old.per_primitive:
            np.testing.assert_allclose(old.per_primitive[k],
                                       new.per_primitive[k])
        np.testing.assert_allclose(old.link_matrix(), new.link_matrix())
        assert old.collective_seconds() == new.collective_seconds()
        assert old.collective_seconds_split() == \
            new.collective_seconds_split()
        assert old.total_wire_bytes() == new.total_wire_bytes()

    def test_monitor_fn_is_single_phase_session(self, pair):
        old, _ = pair
        assert old.phase_names() == ["main"]
        assert all(op.phase == "main" for op in old.compiled_ops)


@pytest.mark.compile
class TestSchemaRoundTrip:
    def test_phases_survive_save_load(self, phased_session, tmp_path):
        rep = phased_session.report()
        p = str(tmp_path / "v6.json")
        rep.save(p)
        d = json.loads(open(p).read())
        assert d["schema"] == "repro.comm_report.v9"
        assert [ph["name"] for ph in d["phases"]] == ["fwd", "bwd", "optim"]
        assert all("phase" in op for op in d["ops"])
        back = CommReport.load(p)
        assert back.phase_names() == rep.phase_names()
        for ph in rep.phase_names():
            np.testing.assert_allclose(back.view(phase=ph).matrix,
                                       rep.view(phase=ph).matrix)

    @pytest.mark.parametrize("old_schema", ["repro.comm_report.v1",
                                            "repro.comm_report.v2",
                                            "repro.comm_report.v3",
                                            "repro.comm_report.v4"])
    def test_older_schemas_still_load(self, phased_session, tmp_path,
                                      old_schema):
        rep = phased_session.report()
        p = str(tmp_path / "old.json")
        rep.save(p)
        d = json.loads(open(p).read())
        d["schema"] = old_schema
        d.pop("phases", None)
        for op in d["ops"]:
            op.pop("phase", None)
        for key in ("links", "link_matrix", "link_summary", "link_tiers",
                    "overlap", "hlo_gz"):
            d.pop(key, None)
        with open(p, "w") as f:
            json.dump(d, f)
        back = CommReport.load(p)
        assert back.phases == [] and back.phase_names() == []
        np.testing.assert_allclose(back.matrix, rep.matrix)
        assert back.collective_seconds() == rep.collective_seconds()

    def test_include_hlo_roofline_on_loaded(self, phased_session, tmp_path):
        rep = phased_session.report()
        p = str(tmp_path / "hlo.json")
        rep.save(p, include_hlo=True)
        d = json.loads(open(p).read())
        # one compressed module per capture (kept separate: computation
        # names are only unique within a module)
        assert len(d["hlo_gz"]) == len(phased_session.captures)
        back = CommReport.load(p)
        rl = roofline_of(back, arch="phased", mesh_name="4x2")
        live = roofline_of(rep, arch="phased", mesh_name="4x2")
        assert rl.compute_s > 0
        assert rl.flops_per_device == pytest.approx(live.flops_per_device)

    def test_hlo_not_persisted_by_default(self, phased_session, tmp_path):
        rep = phased_session.report()
        p = str(tmp_path / "nohlo.json")
        rep.save(p)
        assert "hlo_gz" not in json.loads(open(p).read())
        with pytest.raises(ValueError, match="include_hlo"):
            roofline_of(CommReport.load(p))


@pytest.mark.compile
class TestPhaseConsumers:
    def test_html_phase_tabs(self, phased_session, tmp_path):
        from repro.core import export
        p = str(tmp_path / "tabs.html")
        export.export_html(phased_session.report(), p)
        text = open(p).read()
        assert "class='tabs'" in text
        assert "all phases" in text
        for ph in ("fwd", "bwd", "optim"):
            assert f">{ph}</label>" in text
        assert "type='radio'" in text

    def test_perfetto_phase_lane(self, phased_session):
        from repro.core import export
        doc = export.chrome_trace(phased_session.report())
        events = doc["traceEvents"]
        lanes = [e for e in events if e.get("cat") == "phase"]
        lane_names = [e["name"] for e in lanes]
        # optim moves no bytes -> no span on the collective clock
        assert lane_names == ["fwd", "bwd"]
        meta = [e for e in events if e["ph"] == "M"
                and e["args"].get("name") == "phases"]
        assert meta, "phase lane thread metadata missing"
        ops = [e for e in events if e.get("cat") == "collective"]
        assert all("phase" in e["args"] for e in ops)
        json.dumps(doc)

    def test_sweep_table_by_phase(self, phased_session):
        from repro.sweep import SweepResult
        rep = phased_session.report()
        res = SweepResult(reports=[rep], failures=[], cache_hits=0,
                          compiles=1)
        table = res.summary_table(by_phase=True)
        assert "phase" in table.splitlines()[0]
        assert "fwd" in table and "bwd" in table and "optim" in table
        # one row per phase (+ header + separator)
        assert len(table.splitlines()) == 2 + 3
        both = res.summary_table(by_link=True, by_phase=True)
        assert "busiest link" in both and "overlap ms" in both
