"""Trace-ingestion subsystem (repro.core.trace): frontends, malformed
inputs, and the bitwise round-trip of our own Perfetto exports.

The malformed cases are the contract of ISSUE 9's satellite: truncated
JSON, unknown device ids, negative / overlapping timestamps, and a CSV
without a byte column each raise a :class:`TraceParseError` that names
the offending record -- never a silent zero-row matrix.  The fixture
round-trip test is the fast half of the CI compare gate: importing
``tests/fixtures/translation_trace.json`` (our own export of the
committed translation report) must reproduce the report's comm matrix
**bitwise**.
"""
import json
import os

import numpy as np
import pytest

from repro.core import CommReport
from repro.core.trace import (FORMATS, JsonlSource, NvprofCsvSource,
                              PerfettoSource, TraceParseError, load_trace,
                              sniff_format, source_for)
from repro.core.trace.normalize import (DeviceMap, align_clocks,
                                        collective_kind, measured_op)

FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures")


# ---------------------------------------------------------------------------
# normalization
# ---------------------------------------------------------------------------
class TestNormalize:
    @pytest.mark.parametrize("raw,kind", [
        ("ncclAllReduceRingLLKernel_sum_f32(...)", "all-reduce"),
        ("all-reduce.17", "all-reduce"),
        ("psum", "all-reduce"),
        ("CrossReplicaSum", "all-reduce"),
        ("ncclAllGatherRingLLKernel_f32", "all-gather"),
        ("reduce-scatter.2", "reduce-scatter"),
        ("ragged-all-to-all.1", "ragged-all-to-all"),
        ("all-to-all.9", "all-to-all"),
        ("collective-permute.3", "collective-permute"),
        ("ppermute", "collective-permute"),
        ("ncclBroadcastRingLLKernel_f32", "collective-broadcast"),
        ("fusion.123", None),
        ("gemm_kernel", None),
    ])
    def test_collective_kind(self, raw, kind):
        assert collective_kind(raw) == kind

    @pytest.mark.parametrize("label,dev", [
        ("Tesla V100-SXM2-16GB (3)", 3),
        ("/device:TPU:5", 5),
        ("GPU 2", 2),
        ("gpu7", 7),
        ("4", 4),
        (6, 6),
    ])
    def test_device_map_parses_labels(self, label, dev):
        assert DeviceMap(8).resolve(label) == dev

    def test_device_map_out_of_range(self):
        with pytest.raises(TraceParseError, match="out of range"):
            DeviceMap(4).resolve("GPU 7", record="row 3")

    def test_device_map_unmappable_label(self):
        with pytest.raises(TraceParseError, match="cannot map device"):
            DeviceMap(8).resolve("mystery accelerator")

    def test_device_map_explicit_mapping_wins(self):
        dm = DeviceMap(8, {"mystery accelerator": 5})
        assert dm.resolve("mystery accelerator") == 5
        assert dm.seen == {5}

    def test_align_clocks_global_vs_per_device(self):
        ts = {0: [10.0, 12.0], 1: [3.0, 20.0]}
        assert align_clocks(ts, "global") == {0: 3.0, 1: 3.0}
        assert align_clocks(ts, "per-device") == {0: 10.0, 1: 3.0}
        with pytest.raises(ValueError, match="clock-align"):
            align_clocks(ts, "sideways")

    @pytest.mark.parametrize("kind", [
        "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
        "collective-broadcast", "ragged-all-to-all"])
    def test_measured_op_payload_roundtrips_exactly(self, kind):
        # the whole point of measured_op: payload_bytes inverts exactly,
        # including the divide-by-N kinds (equal per-rank byte vector)
        for payload in (1, 7, 4096, 1 << 20, (1 << 20) + 3):
            op = measured_op(kind, payload_bytes=payload,
                             groups=[[0, 1, 2, 3]], measured_s=1e-3)
            assert op.payload_bytes == payload, (kind, payload)
            assert op.measured_s == 1e-3


# ---------------------------------------------------------------------------
# JSONL frontend
# ---------------------------------------------------------------------------
def _write(tmp_path, name, text):
    p = tmp_path / name
    p.write_text(text)
    return str(p)


class TestJsonl:
    def test_parse_with_header_units_and_corr(self, tmp_path):
        lines = [
            {"trace": {"name": "run1", "num_devices": 4,
                       "time_unit": "us"}},
            # one all-reduce seen from two ranks (shared corr): merges
            # into one op, measured = worst rank (max), group = devices
            {"kind": "all-reduce", "device": 0, "ts": 0, "dur": 250.0,
             "bytes": 4096, "corr": 7, "phase": "fwd"},
            {"kind": "all-reduce", "device": 1, "ts": 0, "dur": 300.0,
             "bytes": 4096, "corr": 7, "phase": "fwd"},
            {"kind": "all-gather", "name": "ag.1", "device": 0, "ts": 400,
             "dur": 100.0, "bytes": 1024, "group": [0, 1, 2, 3]},
            {"kind": "h2d", "device": 2, "bytes": 512},
        ]
        path = _write(tmp_path, "t.jsonl",
                      "\n".join(json.dumps(r) for r in lines))
        assert sniff_format(path) == "jsonl"
        imp = load_trace(path)
        assert imp.name == "run1"
        assert imp.num_devices == 4
        assert [op.kind for op in imp.ops] == ["all-reduce", "all-gather"]
        ar, ag = imp.ops
        assert ar.measured_s == pytest.approx(300e-6)   # worst rank, in us
        assert ar.payload_bytes == 4096
        assert ar.phase == "fwd"
        assert ar.replica_groups == [[0, 1]]            # seen devices
        assert ag.replica_groups == [[0, 1, 2, 3]]      # explicit group
        assert len(imp.host_transfers) == 1
        assert imp.host_transfers[0].direction == "h2d"
        assert imp.meta["source"] == "jsonl"

    def test_report_builds_nonzero_matrix(self, tmp_path):
        path = _write(tmp_path, "t.jsonl", json.dumps(
            {"kind": "all-reduce", "dur": 1.0, "bytes": 4096,
             "group": [0, 1, 2, 3]}))
        rep = load_trace(path).report()
        assert rep.matrix.shape == (5, 5)
        assert rep.matrix.sum() > 0
        assert rep.compiled_ops[0].measured_s == 1.0
        assert rep.measured_seconds() == 1.0

    def test_truncated_json_line_names_the_line(self, tmp_path):
        path = _write(tmp_path, "t.jsonl",
                      '{"kind": "all-reduce", "dur": 1.0, "bytes": 4096}\n'
                      '{"kind": "all-gather", "dur": 0.5, "by')
        with pytest.raises(TraceParseError, match="line 2") as ei:
            load_trace(path)
        assert "truncated or invalid JSON" in str(ei.value)

    def test_unknown_device_id_names_the_line(self, tmp_path):
        path = _write(tmp_path, "t.jsonl", "\n".join([
            json.dumps({"trace": {"num_devices": 4}}),
            json.dumps({"kind": "all-reduce", "device": 9, "dur": 1.0,
                        "bytes": 64}),
        ]))
        with pytest.raises(TraceParseError, match="line 2"):
            load_trace(path)

    def test_negative_timestamp_names_the_line(self, tmp_path):
        path = _write(tmp_path, "t.jsonl", json.dumps(
            {"kind": "all-reduce", "device": 0, "ts": -5.0, "dur": 1.0,
             "bytes": 64}))
        with pytest.raises(TraceParseError, match="line 1"):
            load_trace(path)

    def test_negative_duration_names_the_line(self, tmp_path):
        path = _write(tmp_path, "t.jsonl", json.dumps(
            {"kind": "all-reduce", "dur": -1.0, "bytes": 64}))
        with pytest.raises(TraceParseError, match="'dur' is negative"):
            load_trace(path)

    def test_overlapping_timestamps_name_both_lines(self, tmp_path):
        # device 0's stream is sequential by schema; two events that
        # overlap in time are malformed
        path = _write(tmp_path, "t.jsonl", "\n".join([
            json.dumps({"kind": "all-reduce", "device": 0, "ts": 0.0,
                        "dur": 10.0, "bytes": 64}),
            json.dumps({"kind": "all-gather", "device": 0, "ts": 5.0,
                        "dur": 10.0, "bytes": 64}),
        ]))
        with pytest.raises(TraceParseError,
                           match="overlapping events on device 0"):
            load_trace(path)

    def test_missing_bytes_field(self, tmp_path):
        path = _write(tmp_path, "t.jsonl", json.dumps(
            {"kind": "all-reduce", "dur": 1.0}))
        with pytest.raises(TraceParseError, match="'bytes'"):
            load_trace(path)

    def test_unknown_kind_is_an_error_not_a_skip(self, tmp_path):
        path = _write(tmp_path, "t.jsonl", json.dumps(
            {"kind": "warp-drive", "dur": 1.0, "bytes": 64}))
        with pytest.raises(TraceParseError, match="unknown collective"):
            load_trace(path)


# ---------------------------------------------------------------------------
# nvprof CSV frontend
# ---------------------------------------------------------------------------
_CSV_HEADER = ('"Start","Duration","Size","SrcDev","DstDev","Device",'
               '"Name","Correlation_ID"')


def _csv(tmp_path, rows, units="s,ms,MB,,,,,", header=_CSV_HEADER):
    lines = ["==123== NVPROF is profiling process 123", header]
    if units:
        lines.append(units)
    lines.extend(rows)
    return _write(tmp_path, "t.csv", "\n".join(lines) + "\n")


class TestNvprofCsv:
    def test_sniff_and_kernel_clustering(self, tmp_path):
        dev = "Tesla V100-SXM2-16GB ({})"
        # one all-reduce observed from 4 ranks via a shared corr id
        rows = [f'0.0,2.{r},4.0,,,"{dev.format(r)}",'
                f'"ncclAllReduceRingLLKernel_sum_f32(...)",55'
                for r in range(4)]
        path = _csv(tmp_path, rows)
        assert sniff_format(path) == "nvprof"
        imp = load_trace(path)
        assert len(imp.ops) == 1
        op = imp.ops[0]
        assert op.kind == "all-reduce"
        assert op.replica_groups == [[0, 1, 2, 3]]
        # units row: ms durations, MB sizes; measured = worst rank
        assert op.measured_s == pytest.approx(2.3e-3)
        assert op.payload_bytes == 4 * 1024 ** 2

    def test_default_units_without_units_row(self, tmp_path):
        path = _csv(tmp_path,
                    ['0.0,2.0,4.0,,,"GPU 0","ncclAllGather",9'], units="")
        op = load_trace(path, num_devices=2).ops[0]
        assert op.measured_s == pytest.approx(2e-3)       # nvprof: ms
        assert op.payload_bytes == 4 * 1024 ** 2          # nvprof: MB

    def test_ptop_rows_merge_into_one_permute(self, tmp_path):
        dev = "Tesla V100-SXM2-16GB ({})"
        rows = [f'0.0,1.0,2.0,"{dev.format(s)}","{dev.format(d)}",,'
                f'"[CUDA memcpy PtoP]",77'
                for s, d in ((0, 1), (1, 2), (2, 3), (3, 0))]
        imp = load_trace(_csv(tmp_path, rows))
        assert len(imp.ops) == 1
        op = imp.ops[0]
        assert op.kind == "collective-permute"
        assert sorted(op.source_target_pairs) == [(0, 1), (1, 2), (2, 3),
                                                  (3, 0)]
        assert op.payload_bytes == 2 * 1024 ** 2

    def test_htod_dtoh_become_host_transfers(self, tmp_path):
        rows = ['0.0,0.1,1.0,,,"GPU 0","[CUDA memcpy HtoD]",1',
                '0.2,0.1,2.0,,,"GPU 0","[CUDA memcpy DtoH]",2']
        imp = load_trace(_csv(tmp_path, rows), num_devices=1)
        assert [t.direction for t in imp.host_transfers] == ["h2d", "d2h"]
        assert imp.host_transfers[0].nbytes == 1024 ** 2
        assert not imp.ops

    def test_missing_byte_column_is_an_error(self, tmp_path):
        # "a CSV with a missing byte column degrades with a clear
        # TraceParseError", not a zero-row matrix
        path = _csv(tmp_path,
                    ['0.0,2.0,"GPU 0","ncclAllReduce",5'],
                    units="s,ms,,,",
                    header='"Start","Duration","Device","Name",'
                           '"Correlation_ID"')
        with pytest.raises(TraceParseError, match="no byte column") as ei:
            load_trace(path)
        assert "ncclAllReduce" in str(ei.value)   # names the record

    def test_negative_duration_names_the_row(self, tmp_path):
        path = _csv(tmp_path, ['0.0,-2.0,4.0,,,"GPU 0","ncclAllReduce",5'])
        with pytest.raises(TraceParseError, match="negative duration"):
            load_trace(path)

    def test_missing_header_row(self, tmp_path):
        path = _write(tmp_path, "t.csv", "==1== banner only\n")
        with pytest.raises(TraceParseError, match="no CSV rows"):
            load_trace(path, fmt="nvprof")

    def test_compute_kernels_are_skipped(self, tmp_path):
        rows = ['0.0,9.0,,,,"GPU 0","volta_sgemm_128x64_nn",3',
                '1.0,2.0,4.0,,,"GPU 0","ncclAllReduce",5']
        imp = load_trace(_csv(tmp_path, rows), num_devices=1)
        assert [op.kind for op in imp.ops] == ["all-reduce"]


# ---------------------------------------------------------------------------
# Perfetto frontend
# ---------------------------------------------------------------------------
class TestPerfettoGeneric:
    def _trace(self, events):
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def _procs(self, n):
        return [{"name": "process_name", "ph": "M", "pid": p, "tid": 0,
                 "args": {"name": f"/device:TPU:{p}"}} for p in range(n)]

    def test_jax_profiler_shape(self, tmp_path):
        # X events named like HLO collectives, one process lane per
        # device, bytes in args -- the jax profiler's trace-viewer shape
        evs = self._procs(1) + [
            {"name": "all-reduce.1", "ph": "X", "pid": 0, "tid": 1,
             "ts": 10, "dur": 250, "args": {"bytes_accessed": 4096,
                                            "device": 0,
                                            "group": [0, 1]}},
            {"name": "fusion.7", "ph": "X", "pid": 0, "tid": 1,
             "ts": 300, "dur": 50, "args": {}},
        ]
        path = _write(tmp_path, "t.json", json.dumps(self._trace(evs)))
        assert sniff_format(path) == "perfetto"
        imp = load_trace(path, num_devices=2)
        assert len(imp.ops) == 1                # fusion is not a collective
        op = imp.ops[0]
        assert op.kind == "all-reduce"
        assert op.measured_s == pytest.approx(250e-6)    # chrome us
        assert op.payload_bytes == 4096
        assert imp.meta["exact_reimport"] is False

    def test_truncated_json_document(self, tmp_path):
        path = _write(tmp_path, "t.json",
                      '{"traceEvents": [{"name": "all-reduce.1", "ph"')
        with pytest.raises(TraceParseError,
                           match="truncated or invalid JSON"):
            load_trace(path, fmt="perfetto")

    def test_collective_without_bytes_is_an_error(self, tmp_path):
        evs = [{"name": "all-reduce.1", "ph": "X", "pid": 0, "tid": 0,
                "ts": 0, "dur": 10, "args": {}}]
        path = _write(tmp_path, "t.json", json.dumps(self._trace(evs)))
        with pytest.raises(TraceParseError,
                           match="no byte annotation") as ei:
            load_trace(path)
        assert "all-reduce.1" in str(ei.value)

    def test_negative_timestamp_is_an_error(self, tmp_path):
        evs = [{"name": "all-reduce.1", "ph": "X", "pid": 0, "tid": 0,
                "ts": -4, "dur": 10, "args": {"bytes": 64}}]
        path = _write(tmp_path, "t.json", json.dumps(self._trace(evs)))
        with pytest.raises(TraceParseError, match="negative timestamp"):
            load_trace(path)

    def test_unknown_pid_is_an_error(self, tmp_path):
        evs = [{"name": "all-reduce.1", "ph": "X", "pid": 3, "tid": 0,
                "ts": 0, "dur": 1, "args": {"bytes": 64}}]
        path = _write(tmp_path, "t.json", json.dumps(self._trace(evs)))
        with pytest.raises(TraceParseError, match="pid 9 not in trace"):
            load_trace(path, pid=9)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
class TestRegistry:
    def test_formats(self):
        assert set(FORMATS) == {"perfetto", "nvprof", "jsonl"}
        assert source_for("perfetto") is PerfettoSource
        assert source_for("nvprof") is NvprofCsvSource
        assert source_for("jsonl") is JsonlSource

    def test_unknown_format_lists_valid_names(self):
        with pytest.raises(ValueError, match="valid formats"):
            source_for("vtune")

    def test_missing_file(self):
        with pytest.raises(FileNotFoundError):
            load_trace("/nonexistent/trace.json")

    def test_unsniffable_file_lists_formats(self, tmp_path):
        path = _write(tmp_path, "t.bin", "\x00\x01\x02 not a trace")
        with pytest.raises(TraceParseError, match="pass fmt="):
            load_trace(path)


# ---------------------------------------------------------------------------
# the round-trip gate: our own Perfetto export re-imports bitwise
# ---------------------------------------------------------------------------
class TestRoundTrip:
    def test_fixture_roundtrip_bitwise(self):
        # fast half of the CI compare gate: the committed trace fixture
        # (export of translation_report.json) reproduces the report's
        # matrix bitwise -- no XLA, no tolerance
        rep = CommReport.load(
            os.path.join(FIXTURES, "translation_report.json"))
        imp = load_trace(os.path.join(FIXTURES, "translation_trace.json"))
        assert imp.meta["exact_reimport"] is True
        back = imp.report()
        assert back.num_devices == rep.num_devices
        assert np.array_equal(np.asarray(back.matrix),
                              np.asarray(rep.matrix))
        assert set(back.per_primitive) == set(rep.per_primitive)
        for kind, mat in rep.per_primitive.items():
            assert np.array_equal(np.asarray(back.per_primitive[kind]),
                                  np.asarray(mat)), kind

    def test_fixture_roundtrip_carries_measured_seconds(self):
        imp = load_trace(os.path.join(FIXTURES, "translation_trace.json"))
        assert imp.ops and all(op.measured_s is not None
                               for op in imp.ops)
        assert all(op.measured_s > 0 for op in imp.ops)
        # phases and host transfers survive via the repro_report meta
        rep = CommReport.load(
            os.path.join(FIXTURES, "translation_report.json"))
        assert [p.name for p in imp.phases] == \
            [p.name for p in rep.phases]
        assert len(imp.host_transfers) == len(rep.host_transfers)

    def test_export_reimport_in_memory(self, tmp_path):
        from repro.core.export.perfetto import export_perfetto

        rep = CommReport.load(
            os.path.join(FIXTURES, "serve_report.json"))
        path = export_perfetto(rep, str(tmp_path / "serve.trace.json"))
        back = load_trace(path).report()
        assert np.array_equal(np.asarray(back.matrix),
                              np.asarray(rep.matrix))

    def test_v9_report_roundtrip_preserves_measured(self, tmp_path):
        # save/load of an imported report keeps measured_s + trace_meta
        imp = load_trace(os.path.join(FIXTURES, "serve_trace.csv"))
        rep = imp.report()
        p = str(tmp_path / "imported.json")
        rep.save(p)
        with open(p) as f:
            assert json.load(f)["schema"] == "repro.comm_report.v9"
        back = CommReport.load(p)
        assert back.trace_meta["source"] == "nvprof"
        assert [op.measured_s for op in back.compiled_ops] == \
            [op.measured_s for op in rep.compiled_ops]
        assert np.array_equal(np.asarray(back.matrix),
                              np.asarray(rep.matrix))


@pytest.mark.compile
class TestAcceptanceCompile:
    def test_paper_config_export_reimports_bitwise(self, tmp_path):
        # ISSUE 9 acceptance: export the paper config's Perfetto trace
        # and re-import it; the comm matrix must be identical bitwise
        from repro import sweep as sweep_mod
        from repro.core.export.perfetto import export_perfetto

        result = sweep_mod.run_sweep(["paper"], ["4x2"], ["ring"],
                                     use_cache=False)
        assert not result.failures, result.failures
        rep = result.reports[0]
        path = export_perfetto(rep, str(tmp_path / "paper.trace.json"))
        back = load_trace(path).report()
        assert np.array_equal(np.asarray(back.matrix),
                              np.asarray(rep.matrix))
        for kind, mat in rep.per_primitive.items():
            assert np.array_equal(np.asarray(back.per_primitive[kind]),
                                  np.asarray(mat)), kind
