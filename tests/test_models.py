"""Per-architecture smoke tests: reduced configs, one forward/train step on
CPU, shape + finiteness asserts; prefill-vs-decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import build_model
from repro.parallel import Sharder
from repro.compat import make_mesh

pytestmark = pytest.mark.compile   # whole module drives XLA compiles

ARCHS = list(configs.ARCH_IDS)


def make_batch(cfg, b=2, s=32, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    batch = {"tokens": jax.random.randint(ks[0], (b, s), 0, cfg.vocab_size),
             "labels": jax.random.randint(ks[1], (b, s), 0, cfg.vocab_size)}
    if cfg.input_mode == "embeddings":
        batch["embeds"] = jax.random.normal(
            ks[2], (b, s, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.fixture(scope="module")
def shd(mesh8):
    return Sharder(mesh8)


@pytest.mark.parametrize("arch", ARCHS)
class TestArchSmoke:
    def test_forward_and_train_step(self, arch, shd):
        cfg = configs.config(arch, reduced=True)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        batch = make_batch(cfg)

        def loss(p, b):
            return model.loss_fn(p, b, shd)[0]

        val, grads = jax.jit(jax.value_and_grad(loss))(params, batch)
        assert jnp.isfinite(val), f"{arch}: loss not finite"
        # gradient step moves the loss
        p2 = jax.tree.map(lambda p, g: p - 0.3 * g.astype(p.dtype),
                          params, grads)
        val2 = jax.jit(loss)(p2, batch)
        assert jnp.isfinite(val2)
        assert float(val2) < float(val), f"{arch}: grad step didn't descend"
        # gradient structure matches params; every leaf finite
        for g in jax.tree.leaves(grads):
            assert jnp.all(jnp.isfinite(g.astype(jnp.float32)))

    def test_decode_step_shapes(self, arch, shd):
        cfg = configs.config(arch, reduced=True)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        b = 2
        cache = model.init_cache(b, 16)
        batch = {"tokens": jnp.zeros((b, 1), jnp.int32)}
        if cfg.input_mode == "embeddings":
            batch["embeds"] = jnp.zeros((b, 1, cfg.d_model), jnp.bfloat16)
        logits, cache2 = jax.jit(
            lambda p, c, bb: model.decode_step(p, c, bb, shd))(
            params, cache, batch)
        assert logits.shape == (b, 1, cfg.vocab_size)
        assert jnp.all(jnp.isfinite(logits.astype(jnp.float32)))
        assert int(cache2["len"]) == 1

    def test_full_config_param_count_sane(self, arch, shd):
        cfg = configs.config(arch)
        model = build_model(cfg)
        from repro.models.common import count_params
        n = count_params(model.specs())
        # within 3x of the architecture's nameplate (approximations OK)
        names = {"grok_1_314b": 314e9, "llama4_maverick_400b_a17b": 400e9,
                 "codeqwen15_7b": 7e9, "granite_3_2b": 2.5e9,
                 "qwen3_8b": 8e9, "granite_20b": 20e9, "xlstm_1_3b": 1.3e9,
                 "chameleon_34b": 34e9, "musicgen_medium": 1.5e9,
                 "recurrentgemma_2b": 2.7e9}
        nameplate = names[arch]
        assert nameplate / 3 < n < nameplate * 3, \
            f"{arch}: {n/1e9:.1f}B vs nameplate {nameplate/1e9:.0f}B"


class TestPrefillDecodeConsistency:
    """Prefill(tokens) must equal step-by-step decode — the strongest
    correctness property linking the parallel and recurrent forms."""

    @pytest.mark.parametrize("arch", ["granite_3_2b", "qwen3_8b",
                                      "xlstm_1_3b", "recurrentgemma_2b"])
    def test_prefill_matches_stepwise_decode(self, arch, shd):
        import dataclasses
        # fp32 compute so the tolerance tests logic, not bf16 rounding
        cfg = dataclasses.replace(configs.config(arch, reduced=True),
                                  compute_dtype="float32")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(1))
        b, s = 2, 8
        toks = jax.random.randint(jax.random.PRNGKey(2), (b, s), 0,
                                  cfg.vocab_size)
        pf_logits, _ = jax.jit(
            lambda p, bb: model.prefill(p, bb, shd))(params, {"tokens": toks})

        cache = model.init_cache(b, s)
        step = jax.jit(lambda p, c, bb: model.decode_step(p, c, bb, shd))
        for t in range(s):
            logits, cache = step(params, cache, {"tokens": toks[:, t:t + 1]})
        np.testing.assert_allclose(
            np.asarray(pf_logits, np.float32),
            np.asarray(logits[:, 0], np.float32), rtol=2e-2, atol=2e-2)


class TestXLSTMMath:
    def test_mlstm_parallel_equals_sequential(self):
        from repro.models.xlstm import (mlstm_decode_step, mlstm_final_state,
                                        mlstm_parallel)
        b, s, nh, dh = 2, 24, 2, 8
        ks = jax.random.split(jax.random.PRNGKey(0), 5)
        q = jax.random.normal(ks[0], (b, s, nh, dh))
        k = jax.random.normal(ks[1], (b, s, nh, dh))
        v = jax.random.normal(ks[2], (b, s, nh, dh))
        log_f = jax.nn.log_sigmoid(jax.random.normal(ks[3], (b, s, nh)) + 1)
        it = jax.random.normal(ks[4], (b, s, nh)) * 0.5

        par = mlstm_parallel(q, k, v, log_f, it, chunk=8)
        state = {"C": jnp.zeros((b, nh, dh, dh)),
                 "n": jnp.zeros((b, nh, dh)), "m": jnp.full((b, nh), -1e30)}
        outs = []
        for t in range(s):
            h, state = mlstm_decode_step(q[:, t], k[:, t], v[:, t],
                                         log_f[:, t], it[:, t], state)
            outs.append(h)
        seq = jnp.stack(outs, axis=1)
        assert jnp.max(jnp.abs(par - seq)) < 1e-4
        # final state from the closed form matches the recurrence (probe)
        fs = mlstm_final_state(k, v, log_f, it)
        probe = jax.random.normal(ks[0], (b, nh, dh))

        def read(st):
            num = jnp.einsum("bhde,bhe->bhd", st["C"], probe)
            den = jnp.abs(jnp.einsum("bhd,bhd->bh", st["n"], probe))
            return num / jnp.maximum(den, jnp.exp(-st["m"]))[..., None]

        assert jnp.max(jnp.abs(read(fs) - read(state))) < 1e-4

    def test_rglru_state_fold(self):
        """Splitting a sequence must equal processing it whole."""
        from repro.models.common import ModelConfig
        from repro.models.rglru import recurrent_block, rglru_spec, init_rec_state
        from repro.models.common import init_params
        from repro.parallel import Sharder
        import jax
        mesh = make_mesh((1,), ("data",))
        shd1 = Sharder(mesh)
        cfg = ModelConfig(name="t", family="hybrid", n_layers=1, d_model=32,
                          n_heads=2, n_kv_heads=1, d_ff=64, vocab_size=64,
                          attn_window=8, d_rnn=32)
        p = init_params(rglru_spec(cfg), jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32),
                              jnp.float32)
        full, st_full = recurrent_block(p, x, cfg, shd1,
                                        state=init_rec_state(cfg, 2))
        st = init_rec_state(cfg, 2)
        o1, st = recurrent_block(p, x[:, :8], cfg, shd1, state=st)
        o2, st = recurrent_block(p, x[:, 8:], cfg, shd1, state=st)
        np.testing.assert_allclose(np.asarray(full[:, 8:]), np.asarray(o2),
                                   rtol=2e-3, atol=2e-3)
