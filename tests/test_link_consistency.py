"""Matrix/model/link consistency -- the physical-link subsystem contract.

Property-style (grid-parametrized, no compilation, no optional deps):

* for every (kind, algorithm, topology) cell, ``matrix_for_ops`` row sums
  equal ``cost_models.device_send_bytes`` times the op weight -- and for the
  symmetric algorithms that equals ``wire_bytes_per_rank`` per participating
  device;
* hierarchical matrices place cross-pod bytes ONLY on DCN edges (and
  intra-pod bytes only inside pods);
* link projection conserves bytes (single-hop edges), charges transit hops,
  and the host row never leaks onto the fabric.
"""
import numpy as np
import pytest

from repro.core import comm_matrix, cost_models
from repro.core.events import CollectiveOp, HostTransfer, Shape
from repro.core.topology import DCN_FABRIC, MeshTopology

KINDS = ("all-reduce", "all-gather", "reduce-scatter",
         "collective-broadcast", "all-to-all")
ALGORITHMS = ("ring", "tree", "hierarchical")

ONE_POD = MeshTopology(axis_names=("data",), axis_sizes=(8,))
TWO_POD = MeshTopology(axis_names=("pod", "data", "model"),
                       axis_sizes=(2, 2, 2))
FOUR_POD = MeshTopology(axis_names=("pod", "data"), axis_sizes=(4, 2))
TOPOLOGIES = {"one_pod": ONE_POD, "two_pod": TWO_POD, "four_pod": FOUR_POD}


def mk_op(kind, elems=256, group=None, weight=1.0):
    op = CollectiveOp(kind=kind, name="t",
                      result_shapes=[Shape("f32", (elems,))],
                      replica_groups=[group or list(range(8))])
    op.weight = weight
    return op


class TestRowSumConsistency:
    """matrix_for_ops row sums == device_send_bytes * weight, every cell."""

    @pytest.mark.parametrize("kind", KINDS)
    @pytest.mark.parametrize("algorithm", ALGORITHMS)
    @pytest.mark.parametrize("topo_name", sorted(TOPOLOGIES))
    def test_row_sums_match_device_model(self, kind, algorithm, topo_name):
        topo = TOPOLOGIES[topo_name]
        op = mk_op(kind, weight=3.0)
        group = op.replica_groups[0]
        mat = comm_matrix.matrix_for_ops([op], topo.num_devices, algorithm,
                                         topo=topo)
        expected = cost_models.device_send_bytes(
            kind, op.payload_bytes, group, algorithm, topo=topo)
        rows = mat[1:, 1:].sum(axis=1)
        for d in group:
            assert rows[d] == pytest.approx(expected[d] * op.weight), \
                f"device {d}: row {rows[d]} != model {expected[d] * op.weight}"

    @pytest.mark.parametrize("kind", KINDS)
    @pytest.mark.parametrize("topo_name", sorted(TOPOLOGIES))
    def test_ring_rows_equal_table1_per_rank(self, kind, topo_name):
        """For the symmetric ring placement the per-device model IS the
        paper-Table-1 per-rank entry."""
        topo = TOPOLOGIES[topo_name]
        op = mk_op(kind)
        mat = comm_matrix.matrix_for_ops([op], topo.num_devices, "ring",
                                         topo=topo)
        per_rank = cost_models.wire_bytes_per_rank(
            kind, op.payload_bytes, 8, "ring")
        for d in range(8):
            assert mat[d + 1, 1:].sum() == pytest.approx(per_rank)

    def test_hierarchical_rows_equal_pods_aware_per_rank(self):
        op = mk_op("all-reduce")
        mat = comm_matrix.matrix_for_ops([op], 8, "hierarchical",
                                         topo=TWO_POD)
        per_rank = cost_models.wire_bytes_per_rank(
            "all-reduce", op.payload_bytes, 8, "hierarchical", pods=2)
        for d in range(8):
            assert mat[d + 1, 1:].sum() == pytest.approx(per_rank)

    @pytest.mark.parametrize("algorithm", ALGORITHMS)
    def test_disjoint_groups_stay_disjoint(self, algorithm):
        op = mk_op("all-reduce", group=[0, 1, 2, 3])
        op.replica_groups = [[0, 1, 2, 3], [4, 5, 6, 7]]
        mat = comm_matrix.matrix_for_ops([op], 8, algorithm,
                                         topo=TWO_POD)[1:, 1:]
        assert mat[:4, 4:].sum() == 0 and mat[4:, :4].sum() == 0

    @pytest.mark.parametrize("algorithm", ALGORITHMS)
    def test_matrix_total_matches_group_total(self, algorithm):
        for kind in KINDS:
            op = mk_op(kind)
            pods = len(TWO_POD.pod_partition(op.replica_groups[0]))
            mat = comm_matrix.matrix_for_ops([op], 8, algorithm,
                                             topo=TWO_POD)
            total = cost_models.wire_bytes_group_total(
                kind, op.payload_bytes, 8, algorithm, pods=pods)
            assert mat.sum() == pytest.approx(total), (kind, algorithm)

    @pytest.mark.parametrize("algorithm", ALGORITHMS)
    def test_host_row_untouched_by_collectives(self, algorithm):
        """The DCN/host row of the logical matrix belongs to host transfers
        alone; collective placement never writes it."""
        op = mk_op("all-reduce")
        mat = comm_matrix.matrix_for_ops([op], 8, algorithm, topo=TWO_POD)
        assert mat[0].sum() == 0 and mat[:, 0].sum() == 0
        comm_matrix.add_host_transfers(mat, [HostTransfer("h2d", 1, 512),
                                             HostTransfer("d2h", 2, 128)])
        assert mat[0, 2] == 512 and mat[3, 0] == 128


class TestHierarchicalPlacement:
    def test_cross_pod_bytes_only_on_dcn_edges(self):
        """Acceptance criterion: every cross-pod entry of a hierarchical
        matrix routes exclusively over DCN links, every intra-pod entry
        over ICI."""
        op = mk_op("all-reduce")
        mat = comm_matrix.matrix_for_ops([op], 8, "hierarchical",
                                         topo=TWO_POD)[1:, 1:]
        for i in range(8):
            for j in range(8):
                if mat[i, j] <= 0:
                    continue
                links = TWO_POD.route(i, j)
                cross = TWO_POD.pod_index(i) != TWO_POD.pod_index(j)
                kinds = {l.kind for l in links}
                assert kinds == ({"dcn"} if cross else {"ici"}), (i, j)

    def test_cross_pod_share_is_shard_sized(self):
        """Only the reduce-scattered S/m shard exchange crosses DCN."""
        op = mk_op("all-reduce")
        s = op.payload_bytes
        mat = comm_matrix.matrix_for_ops([op], 8, "hierarchical",
                                         topo=TWO_POD)[1:, 1:]
        cross = sum(mat[i, j] for i in range(8) for j in range(8)
                    if TWO_POD.pod_index(i) != TWO_POD.pod_index(j))
        p, m = 2, 4
        expected = 8 * 2.0 * (p - 1) * (s / m) / p
        assert cross == pytest.approx(expected)
        # and it is strictly less than what a ring would push across
        ring = comm_matrix.matrix_for_ops([op], 8, "ring",
                                          topo=TWO_POD)[1:, 1:]
        ring_cross = sum(ring[i, j] for i in range(8) for j in range(8)
                         if TWO_POD.pod_index(i) != TWO_POD.pod_index(j))
        assert cross < ring_cross

    def test_uneven_split_falls_back_to_ring(self):
        """A group that does not split evenly across pods degenerates to
        ring placement, exactly like wire_bytes_per_rank's _hier_split."""
        group = [0, 1, 2, 4, 5]        # 3 in pod 0, 2 in pod 1
        op = mk_op("all-reduce", group=group)
        hier = comm_matrix.matrix_for_ops([op], 8, "hierarchical",
                                          topo=TWO_POD)
        ring = comm_matrix.matrix_for_ops([op], 8, "ring", topo=TWO_POD)
        np.testing.assert_allclose(hier, ring)

    def test_without_topo_hierarchical_degenerates_to_ring(self):
        op = mk_op("all-reduce")
        hier = comm_matrix.matrix_for_ops([op], 8, "hierarchical")
        ring = comm_matrix.matrix_for_ops([op], 8, "ring")
        np.testing.assert_allclose(hier, ring)


class TestTreePlacement:
    @pytest.mark.parametrize("kind", ("all-reduce", "all-gather",
                                      "reduce-scatter",
                                      "collective-broadcast"))
    def test_tree_traffic_only_on_tree_edges(self, kind):
        op = mk_op(kind)
        mat = comm_matrix.matrix_for_ops([op], 8, "tree")[1:, 1:]
        tree_pairs = set()
        for i in range(1, 8):
            tree_pairs |= {(i, (i - 1) // 2), ((i - 1) // 2, i)}
        for i in range(8):
            for j in range(8):
                if (i, j) not in tree_pairs:
                    assert mat[i, j] == 0, (i, j)

    def test_tree_roles_differ(self):
        """Root (2 children, no parent) and a leaf send different amounts."""
        op = mk_op("all-reduce")
        s = op.payload_bytes
        mat = comm_matrix.matrix_for_ops([op], 8, "tree")[1:, 1:]
        assert mat[0].sum() == pytest.approx(2 * s)      # root: S per child
        assert mat[7].sum() == pytest.approx(s)          # leaf: S up only

    def test_broadcast_tree_is_downward_only(self):
        op = mk_op("collective-broadcast")
        mat = comm_matrix.matrix_for_ops([op], 8, "tree")[1:, 1:]
        assert mat[7].sum() == 0                         # leaves send nothing
        assert mat[0].sum() > 0


class TestLinkProjection:
    def test_link_enumeration(self):
        # 8-device 1-axis ring: 8 devices x 2 directions
        assert len(ONE_POD.links()) == 16
        assert all(l.kind == "ici" for l in ONE_POD.links())
        # two-pod mesh: 2 ici axes x 8 devices x 2 dirs collapse on size-2
        # rings to 1 directed link per (src,dst,axis) pair + 16 dcn links
        kinds = {l.kind for l in TWO_POD.links()}
        assert kinds == {"ici", "dcn"}
        assert sum(1 for l in TWO_POD.links() if l.kind == "dcn") == 16

    def test_route_intra_pod_is_ici_only(self):
        for dst in range(1, 4):
            links = TWO_POD.route(0, dst)
            assert links and all(l.kind == "ici" for l in links)
            assert links[0].src == 0 and links[-1].dst == dst
            for a, b in zip(links, links[1:]):
                assert a.dst == b.src                     # contiguous path

    def test_route_cross_pod_is_uplink_plus_downlink(self):
        links = TWO_POD.route(0, 7)
        assert [l.kind for l in links] == ["dcn", "dcn"]
        assert links[0].src == 0 and links[0].dst == DCN_FABRIC
        assert links[1].src == DCN_FABRIC and links[1].dst == 7

    def test_projection_conserves_single_hop_bytes(self):
        """A matrix whose edges are all physical neighbours projects with
        no inflation; the host row never reaches the fabric."""
        topo = ONE_POD
        mat = np.zeros((9, 9))
        mat[1, 2] = 100.0           # 0 -> 1: one hop on the data ring
        mat[0, 3] = 999.0           # host -> device: must be ignored
        lu = comm_matrix.project_links(mat, topo)
        assert lu.total_bytes() == pytest.approx(100.0)
        assert lu.total_bytes("ici") == pytest.approx(100.0)

    def test_projection_charges_transit_hops(self):
        topo = ONE_POD
        mat = np.zeros((9, 9))
        mat[1, 4] = 10.0            # 0 -> 3: three hops on an 8-ring
        lu = comm_matrix.project_links(mat, topo)
        assert lu.total_bytes() == pytest.approx(30.0)

    def test_shorter_way_around_the_ring(self):
        links = ONE_POD.route(0, 7)  # one hop backwards, not 7 forwards
        assert len(links) == 1 and links[0].dst == 7

    def test_link_matrix_layout(self):
        op = mk_op("all-reduce")
        lu = comm_matrix.link_utilization_for_ops([op], TWO_POD,
                                                  "hierarchical")
        lm = lu.matrix()
        assert lm.shape == (9, 9)
        # DCN tier lives in row/col 0 of the *link* matrix
        assert lm[1:, 0].sum() > 0 and lm[0, 1:].sum() > 0
        assert lm[1:, 0].sum() == pytest.approx(lm[0, 1:].sum())
        # ici entries only on physical neighbours
        for i in range(8):
            for j in range(8):
                if lm[i + 1, j + 1] > 0:
                    assert any(l.src == i and l.dst == j
                               for l in TWO_POD.links() if l.kind == "ici")

    def test_contention_time_is_bottleneck_link(self):
        op = mk_op("all-reduce")
        lu = comm_matrix.link_utilization_for_ops([op], TWO_POD, "ring")
        t = cost_models.contention_time([op], TWO_POD, "ring")
        assert t == pytest.approx(lu.bottleneck_seconds())
        link, secs = lu.bottleneck()
        assert secs == pytest.approx(
            lu.bytes_by_link[link] / TWO_POD.link_bandwidth(link))

    def test_zero_traffic_has_no_bottleneck(self):
        """Links are pre-seeded at 0 bytes; an idle fabric must report no
        bottleneck link rather than an arbitrary zero-byte one."""
        lu = comm_matrix.project_links(np.zeros((9, 9)), ONE_POD)
        assert lu.bottleneck() is None
        assert lu.bottleneck_seconds() == 0.0
        for row in lu.summary().values():
            assert row["busiest_link"] == ""

    def test_weight_scales_links(self):
        op1, op16 = mk_op("all-reduce"), mk_op("all-reduce", weight=16.0)
        lu1 = comm_matrix.link_utilization_for_ops([op1], ONE_POD, "ring")
        lu16 = comm_matrix.link_utilization_for_ops([op16], ONE_POD, "ring")
        assert lu16.total_bytes() == pytest.approx(16 * lu1.total_bytes())


class TestCollectiveTimeFaithful:
    """The requested algorithm is billed, even across DCN (satellite fix)."""

    def _op(self, group):
        return mk_op("all-reduce", group=group)

    def test_intra_pod_uses_ici(self):
        op = self._op([0, 1, 2, 3])    # pod 0 only
        t = cost_models.collective_time(op, TWO_POD, "ring")
        per_rank = cost_models.wire_bytes_per_rank(
            "all-reduce", op.payload_bytes, 4, "ring")
        assert t == pytest.approx(per_rank / TWO_POD.ring_bw_per_chip(False))

    def test_ring_across_dcn_pays_full_payload_on_dcn(self):
        op = self._op(list(range(8)))
        t = cost_models.collective_time(op, TWO_POD, "ring")
        per_rank = cost_models.wire_bytes_per_rank(
            "all-reduce", op.payload_bytes, 8, "ring")
        assert t == pytest.approx(per_rank / TWO_POD.ring_bw_per_chip(True))

    def test_tree_across_dcn_pays_full_payload_on_dcn(self):
        op = self._op(list(range(8)))
        t = cost_models.collective_time(op, TWO_POD, "tree")
        assert t == pytest.approx(
            2.0 * op.payload_bytes / TWO_POD.ring_bw_per_chip(True))

    def test_hierarchical_across_dcn_splits_tiers(self):
        op = self._op(list(range(8)))
        s = op.payload_bytes
        t = cost_models.collective_time(op, TWO_POD, "hierarchical")
        p, m = 2, 4
        intra = 2.0 * (m - 1) * s / m / TWO_POD.ring_bw_per_chip(False)
        cross = 2.0 * (p - 1) * (s / m) / p / TWO_POD.ring_bw_per_chip(True)
        assert t == pytest.approx(intra + cross)
        # the point of hierarchy: strictly faster than ring across DCN
        assert t < cost_models.collective_time(op, TWO_POD, "ring")

    def test_algorithms_differ_across_dcn(self):
        op = self._op(list(range(8)))
        times = {a: cost_models.collective_time(op, TWO_POD, a)
                 for a in ALGORITHMS}
        assert len({round(v, 15) for v in times.values()}) == 3

    def test_total_time_is_execution_weighted(self):
        op1, op16 = self._op(list(range(8))), self._op(list(range(8)))
        op16.weight = 16.0
        t1 = cost_models.total_time([op1], TWO_POD, "ring")
        t16 = cost_models.total_time([op16], TWO_POD, "ring")
        assert t16 == pytest.approx(16 * t1)
