"""Matrix/model/link consistency -- the physical-link subsystem contract.

Property-style (grid-parametrized, no compilation, no optional deps):

* for every (kind, algorithm, topology) cell, ``matrix_for_ops`` row sums
  equal ``cost_models.device_send_bytes`` times the op weight -- and for the
  symmetric algorithms that equals ``wire_bytes_per_rank`` per participating
  device;
* hierarchical matrices (all four decomposable kinds, on 1-, 2- and 4-pod
  meshes) place cross-pod bytes ONLY on DCN edges, and the link-matrix DCN
  row/col sums equal the cross-pod bytes ``collective_time`` bills;
* routing is wrap-aware (``len(route) == torus_distance``, size-2 axes
  collapse onto one link with both cables' bandwidth) and ``project_links``
  only ever charges enumerated links;
* the overlap model: ``max(ici_s, dcn_s) <= collective_time`` with equality
  exactly when a single tier carries the traffic;
* link projection conserves bytes (single-hop edges), charges transit hops,
  and the host row never leaks onto the fabric.
"""
import numpy as np
import pytest

from repro.core import comm_matrix, cost_models, decompose
from repro.core.comm_matrix import HierarchicalFallbackWarning
from repro.core.events import CollectiveOp, HostTransfer, Shape
from repro.core.topology import DCN_FABRIC, MeshTopology

KINDS = ("all-reduce", "all-gather", "reduce-scatter",
         "collective-broadcast", "all-to-all")
HIER_KINDS = cost_models.HIERARCHICAL_KINDS
ALGORITHMS = ("ring", "tree", "hierarchical")

ONE_POD = MeshTopology(axis_names=("data",), axis_sizes=(8,))
TWO_POD = MeshTopology(axis_names=("pod", "data", "model"),
                       axis_sizes=(2, 2, 2))
FOUR_POD = MeshTopology(axis_names=("pod", "data"), axis_sizes=(4, 2))
TOPOLOGIES = {"one_pod": ONE_POD, "two_pod": TWO_POD, "four_pod": FOUR_POD}


def mk_op(kind, elems=256, group=None, weight=1.0):
    op = CollectiveOp(kind=kind, name="t",
                      result_shapes=[Shape("f32", (elems,))],
                      replica_groups=[group or list(range(8))])
    op.weight = weight
    return op


class TestRowSumConsistency:
    """matrix_for_ops row sums == device_send_bytes * weight, every cell."""

    @pytest.mark.parametrize("kind", KINDS)
    @pytest.mark.parametrize("algorithm", ALGORITHMS)
    @pytest.mark.parametrize("topo_name", sorted(TOPOLOGIES))
    def test_row_sums_match_device_model(self, kind, algorithm, topo_name):
        topo = TOPOLOGIES[topo_name]
        op = mk_op(kind, weight=3.0)
        group = op.replica_groups[0]
        mat = comm_matrix.matrix_for_ops([op], topo.num_devices, algorithm,
                                         topo=topo)
        expected = cost_models.device_send_bytes(
            kind, op.payload_bytes, group, algorithm, topo=topo)
        rows = mat[1:, 1:].sum(axis=1)
        for d in group:
            assert rows[d] == pytest.approx(expected[d] * op.weight), \
                f"device {d}: row {rows[d]} != model {expected[d] * op.weight}"

    @pytest.mark.parametrize("kind", KINDS)
    @pytest.mark.parametrize("topo_name", sorted(TOPOLOGIES))
    def test_ring_rows_equal_table1_per_rank(self, kind, topo_name):
        """For the symmetric ring placement the per-device model IS the
        paper-Table-1 per-rank entry."""
        topo = TOPOLOGIES[topo_name]
        op = mk_op(kind)
        mat = comm_matrix.matrix_for_ops([op], topo.num_devices, "ring",
                                         topo=topo)
        per_rank = cost_models.wire_bytes_per_rank(
            kind, op.payload_bytes, 8, "ring")
        for d in range(8):
            assert mat[d + 1, 1:].sum() == pytest.approx(per_rank)

    def test_hierarchical_rows_equal_pods_aware_per_rank(self):
        op = mk_op("all-reduce")
        mat = comm_matrix.matrix_for_ops([op], 8, "hierarchical",
                                         topo=TWO_POD)
        per_rank = cost_models.wire_bytes_per_rank(
            "all-reduce", op.payload_bytes, 8, "hierarchical", pods=2)
        for d in range(8):
            assert mat[d + 1, 1:].sum() == pytest.approx(per_rank)

    @pytest.mark.parametrize("algorithm", ALGORITHMS)
    def test_disjoint_groups_stay_disjoint(self, algorithm):
        op = mk_op("all-reduce", group=[0, 1, 2, 3])
        op.replica_groups = [[0, 1, 2, 3], [4, 5, 6, 7]]
        mat = comm_matrix.matrix_for_ops([op], 8, algorithm,
                                         topo=TWO_POD)[1:, 1:]
        assert mat[:4, 4:].sum() == 0 and mat[4:, :4].sum() == 0

    @pytest.mark.parametrize("algorithm", ALGORITHMS)
    def test_matrix_total_matches_group_total(self, algorithm):
        for kind in KINDS:
            op = mk_op(kind)
            pods = len(TWO_POD.pod_partition(op.replica_groups[0]))
            mat = comm_matrix.matrix_for_ops([op], 8, algorithm,
                                             topo=TWO_POD)
            total = cost_models.wire_bytes_group_total(
                kind, op.payload_bytes, 8, algorithm, pods=pods)
            assert mat.sum() == pytest.approx(total), (kind, algorithm)

    @pytest.mark.parametrize("algorithm", ALGORITHMS)
    def test_host_row_untouched_by_collectives(self, algorithm):
        """The DCN/host row of the logical matrix belongs to host transfers
        alone; collective placement never writes it."""
        op = mk_op("all-reduce")
        mat = comm_matrix.matrix_for_ops([op], 8, algorithm, topo=TWO_POD)
        assert mat[0].sum() == 0 and mat[:, 0].sum() == 0
        comm_matrix.add_host_transfers(mat, [HostTransfer("h2d", 1, 512),
                                             HostTransfer("d2h", 2, 128)])
        assert mat[0, 2] == 512 and mat[3, 0] == 128


class TestHierarchicalPlacement:
    """Per-kind hierarchical phase placement, on 1-, 2- and 4-pod meshes."""

    @pytest.mark.parametrize("kind", HIER_KINDS)
    @pytest.mark.parametrize("topo_name", sorted(TOPOLOGIES))
    def test_cross_pod_bytes_only_on_dcn_edges(self, kind, topo_name):
        """Acceptance criterion: every cross-pod entry of a hierarchical
        matrix routes exclusively over DCN links, every intra-pod entry
        over ICI -- for every decomposable kind."""
        topo = TOPOLOGIES[topo_name]
        op = mk_op(kind)
        mat = comm_matrix.matrix_for_ops([op], 8, "hierarchical",
                                         topo=topo)[1:, 1:]
        for i in range(8):
            for j in range(8):
                if mat[i, j] <= 0:
                    continue
                links = topo.route(i, j)
                cross = topo.pod_index(i) != topo.pod_index(j)
                kinds = {l.kind for l in links}
                assert kinds == ({"dcn"} if cross else {"ici"}), (i, j)

    @pytest.mark.parametrize("kind", HIER_KINDS)
    @pytest.mark.parametrize("topo_name", ["two_pod", "four_pod"])
    def test_cross_pod_share_is_shard_sized(self, kind, topo_name):
        """Only the shard exchange crosses DCN: 2(p-1)/n * S per rank for
        all-reduce, (p-1)/n * S for the one-phase kinds -- strictly less
        than the flat ring pushes across."""
        topo = TOPOLOGIES[topo_name]
        op = mk_op(kind)
        s = op.payload_bytes
        p = topo.num_pods
        phases = 2.0 if kind == "all-reduce" else 1.0
        mat = comm_matrix.matrix_for_ops([op], 8, "hierarchical",
                                         topo=topo)[1:, 1:]
        cross = sum(mat[i, j] for i in range(8) for j in range(8)
                    if topo.pod_index(i) != topo.pod_index(j))
        expected = 8 * phases * (p - 1) * s / 8
        assert cross == pytest.approx(expected)
        # and it is strictly less than what a ring would push across
        ring = comm_matrix.matrix_for_ops([op], 8, "ring",
                                          topo=topo)[1:, 1:]
        ring_cross = sum(ring[i, j] for i in range(8) for j in range(8)
                         if topo.pod_index(i) != topo.pod_index(j))
        assert cross < ring_cross

    @pytest.mark.parametrize("kind", HIER_KINDS)
    @pytest.mark.parametrize("topo_name", sorted(TOPOLOGIES))
    def test_dcn_link_rows_match_billed_cross_bytes(self, kind, topo_name):
        """THE acceptance criterion: the link matrix's DCN row/col sums
        (each device's uplink/downlink bytes) equal the cross-pod bytes
        ``collective_time`` bills -- its DCN-tier *bandwidth* seconds times
        the per-chip DCN share (links carry bytes, so the latency term is
        excluded from the recovery).  On a single pod both sides are
        zero."""
        topo = TOPOLOGIES[topo_name]
        op = mk_op(kind, weight=3.0)
        lu = comm_matrix.link_utilization_for_ops([op], topo, "hierarchical")
        lm = lu.matrix()
        ici_s, dcn_s = cost_models.collective_time_split(
            op, topo, "hierarchical", include_latency=False)
        cross_per_rank = dcn_s * topo.ring_bw_per_chip(True) * op.weight
        for d in range(topo.num_devices):
            assert lm[d + 1, 0] == pytest.approx(cross_per_rank), \
                f"uplink row sum of device {d}"
            assert lm[0, d + 1] == pytest.approx(cross_per_rank), \
                f"downlink col sum of device {d}"
        if topo.num_pods == 1:
            assert dcn_s == 0.0 and lm[:, 0].sum() == 0.0

    @pytest.mark.parametrize("kind", HIER_KINDS)
    def test_uneven_split_warns_and_falls_back_to_ring(self, kind):
        """A cross-pod group that does not split evenly across pods warns
        (never silently degenerates) and places flat ring edges -- and
        ``collective_time`` refuses to bill the decomposition in exactly
        the same case (one shared predicate)."""
        group = [0, 1, 2, 4, 5]        # 3 in pod 0, 2 in pod 1
        op = mk_op(kind, group=group)
        decompose.reset_fallback_warnings()   # warnings dedup per session
        with pytest.warns(HierarchicalFallbackWarning):
            hier = comm_matrix.matrix_for_ops([op], 8, "hierarchical",
                                              topo=TWO_POD)
        ring = comm_matrix.matrix_for_ops([op], 8, "ring", topo=TWO_POD)
        np.testing.assert_allclose(hier, ring)
        # billing agrees with the placement: flat ring payload at the
        # per-chip DCN share, no phantom ICI/DCN decomposition
        # (bandwidth term -- the latency hops ride on DCN too)
        ici_s, dcn_s = cost_models.collective_time_split(
            op, TWO_POD, "hierarchical", include_latency=False)
        per_rank = cost_models.wire_bytes_per_rank(
            kind, op.payload_bytes, len(group), "ring")
        assert ici_s == 0.0
        assert dcn_s == pytest.approx(
            per_rank / TWO_POD.ring_bw_per_chip(True))

    def test_shared_predicate_has_no_divergence(self):
        """matrix totals, summaries and billing all degenerate together on
        an uneven split: summarize()'s wire bytes equal the matrix total."""
        from repro.core import hlo_parser
        group = [0, 1, 2, 4, 5]
        op = mk_op("all-gather", group=group)
        decompose.reset_fallback_warnings()   # warnings dedup per session
        with pytest.warns(HierarchicalFallbackWarning):
            mat = comm_matrix.matrix_for_ops([op], 8, "hierarchical",
                                             topo=TWO_POD)
        summary = hlo_parser.summarize([op], "hierarchical", topo=TWO_POD)
        assert mat.sum() == pytest.approx(
            summary["all-gather"]["wire_bytes"])

    def test_without_topo_hierarchical_degenerates_to_ring(self):
        op = mk_op("all-reduce")
        hier = comm_matrix.matrix_for_ops([op], 8, "hierarchical")
        ring = comm_matrix.matrix_for_ops([op], 8, "ring")
        np.testing.assert_allclose(hier, ring)

    def test_heterogeneous_groups_decided_per_group(self):
        """An op whose replica groups straddle pods differently is decided
        group by group: [0,1] stays intra-pod (pure ICI time) while [3,4]
        crosses pods (DCN billed AND DCN edges placed) -- billing,
        summaries and the matrix all see the same per-group split."""
        from repro.core import hlo_parser
        op = mk_op("all-reduce", group=[0, 1])
        op.replica_groups = [[0, 1], [3, 4]]   # intra-pod + cross-pod
        ici_s, dcn_s = cost_models.collective_time_split(
            op, TWO_POD, "hierarchical")
        assert ici_s > 0, "intra-pod group must occupy ICI"
        assert dcn_s > 0, "cross-pod group must be billed on DCN"
        mat = comm_matrix.matrix_for_ops([op], 8, "hierarchical",
                                         topo=TWO_POD)
        cross = sum(mat[i + 1, j + 1] for i in range(8) for j in range(8)
                    if TWO_POD.pod_index(i) != TWO_POD.pod_index(j))
        assert cross > 0, "the matrix must place the DCN bytes billed above"
        summary = hlo_parser.summarize([op], "hierarchical", topo=TWO_POD)
        assert mat.sum() == pytest.approx(
            summary["all-reduce"]["wire_bytes"])


class TestHierarchicalAllToAll:
    """Hierarchical a2a (intra-pod exchange + pod-leader DCN relay) and the
    cross-pod permute relay: byte conservation against the billing model,
    with the DCN share pinned in closed form -- for scalar AND irregular
    (per-rank vector) payloads."""

    @pytest.mark.parametrize("topo_name", ["two_pod", "four_pod"])
    @pytest.mark.parametrize("skewed", [False, True],
                             ids=["scalar", "skewed-vec"])
    def test_a2a_dcn_share_and_total(self, topo_name, skewed):
        """DCN carries exactly (p-1)/p * S -- the bytes whose destination
        lives in another pod -- regardless of how the per-rank vector
        skews the sources; the matrix total equals the billing model's
        group total."""
        topo = TOPOLOGIES[topo_name]
        p = topo.num_pods
        op = mk_op("all-to-all", weight=2.0)
        s = op.payload_bytes
        if skewed:
            op.bytes_per_rank_vec = [s * 0.6] + [s * 0.4 / 7] * 7
        mat = comm_matrix.matrix_for_ops([op], 8, "hierarchical",
                                         topo=topo)[1:, 1:]
        cross = sum(mat[i, j] for i in range(8) for j in range(8)
                    if topo.pod_index(i) != topo.pod_index(j))
        assert cross == pytest.approx((p - 1) / p * s * op.weight)
        total = cost_models.wire_bytes_group_total(
            "all-to-all", s, 8, "hierarchical", pods=p,
            vec=op.byte_vector())
        assert mat.sum() == pytest.approx(total * op.weight)

    @pytest.mark.parametrize("topo_name", ["two_pod", "four_pod"])
    def test_a2a_dcn_edges_are_rank_aligned(self, topo_name):
        """a2a is personalized: every byte must reach its pod either way,
        so the decomposition cannot shrink the DCN *bytes* (they match the
        flat placement's cross-pod total) -- what it buys is structure:
        each rank exchanges only with its positional peer in every other
        pod (p*(p-1)*m aligned flows), never with arbitrary remote
        devices."""
        topo = TOPOLOGIES[topo_name]
        p = topo.num_pods
        m = 8 // p
        op = mk_op("all-to-all")
        pods = topo.pod_partition(list(range(8)))
        rank_of = {d: pod.index(d) for pod in pods for d in pod}

        def cross(algorithm):
            mat = comm_matrix.matrix_for_ops([op], 8, algorithm,
                                             topo=topo)[1:, 1:]
            return {(i, j): mat[i, j] for i in range(8) for j in range(8)
                    if mat[i, j] > 0
                    and topo.pod_index(i) != topo.pod_index(j)}

        hier = cross("hierarchical")
        assert sum(hier.values()) == pytest.approx(
            sum(cross("ring").values()))
        assert len(hier) == p * (p - 1) * m
        for i, j in hier:
            assert rank_of[i] == rank_of[j], (i, j)

    def test_permute_relay_conserves_pair_bytes(self):
        """A cross-pod permute pair relays src -> leader -> leader -> dst;
        every hop carries the pair's full result bytes and intra-pod
        pairs stay direct."""
        op = CollectiveOp(
            kind="collective-permute", name="t",
            result_shapes=[Shape("f32", (256,))], replica_groups=[],
            source_target_pairs=[(1, 7), (2, 3)], weight=2.0)
        nb = op.payload_bytes * op.weight
        mat = comm_matrix.matrix_for_ops([op], 8, "hierarchical",
                                         topo=TWO_POD)[1:, 1:]
        # intra-pod pair: one direct edge
        assert mat[2, 3] == pytest.approx(nb)
        # cross-pod pair 1 -> 7: src 1 -> leader 0 (ici), leader 0 ->
        # leader 4 (dcn), leader 4 -> dst 7 (ici)
        assert mat[1, 0] == pytest.approx(nb)
        assert mat[0, 4] == pytest.approx(nb)
        assert mat[4, 7] == pytest.approx(nb)
        cross = sum(mat[i, j] for i in range(8) for j in range(8)
                    if TWO_POD.pod_index(i) != TWO_POD.pod_index(j))
        assert cross == pytest.approx(nb)      # exactly one DCN crossing
        # and the DCN edges are ICI/DCN-pure, like every hierarchical kind
        for i in range(8):
            for j in range(8):
                if mat[i, j] <= 0:
                    continue
                kinds = {l.kind for l in TWO_POD.route(i, j)}
                cross_pair = TWO_POD.pod_index(i) != TWO_POD.pod_index(j)
                assert kinds == ({"dcn"} if cross_pair else {"ici"}), (i, j)


class TestTreePlacement:
    @pytest.mark.parametrize("kind", ("all-reduce", "all-gather",
                                      "reduce-scatter",
                                      "collective-broadcast"))
    def test_tree_traffic_only_on_tree_edges(self, kind):
        op = mk_op(kind)
        mat = comm_matrix.matrix_for_ops([op], 8, "tree")[1:, 1:]
        tree_pairs = set()
        for i in range(1, 8):
            tree_pairs |= {(i, (i - 1) // 2), ((i - 1) // 2, i)}
        for i in range(8):
            for j in range(8):
                if (i, j) not in tree_pairs:
                    assert mat[i, j] == 0, (i, j)

    def test_tree_roles_differ(self):
        """Root (2 children, no parent) and a leaf send different amounts."""
        op = mk_op("all-reduce")
        s = op.payload_bytes
        mat = comm_matrix.matrix_for_ops([op], 8, "tree")[1:, 1:]
        assert mat[0].sum() == pytest.approx(2 * s)      # root: S per child
        assert mat[7].sum() == pytest.approx(s)          # leaf: S up only

    def test_broadcast_tree_is_downward_only(self):
        op = mk_op("collective-broadcast")
        mat = comm_matrix.matrix_for_ops([op], 8, "tree")[1:, 1:]
        assert mat[7].sum() == 0                         # leaves send nothing
        assert mat[0].sum() > 0


class TestLinkProjection:
    def test_link_enumeration(self):
        # 8-device 1-axis ring: 8 devices x 2 directions
        assert len(ONE_POD.links()) == 16
        assert all(l.kind == "ici" for l in ONE_POD.links())
        # two-pod mesh: 2 ici axes x 8 devices x 2 dirs collapse on size-2
        # rings to 1 directed link per (src,dst,axis) pair + 16 dcn links
        kinds = {l.kind for l in TWO_POD.links()}
        assert kinds == {"ici", "dcn"}
        assert sum(1 for l in TWO_POD.links() if l.kind == "dcn") == 16

    def test_route_intra_pod_is_ici_only(self):
        for dst in range(1, 4):
            links = TWO_POD.route(0, dst)
            assert links and all(l.kind == "ici" for l in links)
            assert links[0].src == 0 and links[-1].dst == dst
            for a, b in zip(links, links[1:]):
                assert a.dst == b.src                     # contiguous path

    def test_route_cross_pod_is_uplink_plus_downlink(self):
        links = TWO_POD.route(0, 7)
        assert [l.kind for l in links] == ["dcn", "dcn"]
        assert links[0].src == 0 and links[0].dst == DCN_FABRIC
        assert links[1].src == DCN_FABRIC and links[1].dst == 7

    def test_projection_conserves_single_hop_bytes(self):
        """A matrix whose edges are all physical neighbours projects with
        no inflation; the host row never reaches the fabric."""
        topo = ONE_POD
        mat = np.zeros((9, 9))
        mat[1, 2] = 100.0           # 0 -> 1: one hop on the data ring
        mat[0, 3] = 999.0           # host -> device: must be ignored
        lu = comm_matrix.project_links(mat, topo)
        assert lu.total_bytes() == pytest.approx(100.0)
        assert lu.total_bytes("ici") == pytest.approx(100.0)

    def test_projection_charges_transit_hops(self):
        topo = ONE_POD
        mat = np.zeros((9, 9))
        mat[1, 4] = 10.0            # 0 -> 3: three hops on an 8-ring
        lu = comm_matrix.project_links(mat, topo)
        assert lu.total_bytes() == pytest.approx(30.0)

    def test_shorter_way_around_the_ring(self):
        links = ONE_POD.route(0, 7)  # one hop backwards, not 7 forwards
        assert len(links) == 1 and links[0].dst == 7

    def test_link_matrix_layout(self):
        op = mk_op("all-reduce")
        lu = comm_matrix.link_utilization_for_ops([op], TWO_POD,
                                                  "hierarchical")
        lm = lu.matrix()
        assert lm.shape == (9, 9)
        # DCN tier lives in row/col 0 of the *link* matrix
        assert lm[1:, 0].sum() > 0 and lm[0, 1:].sum() > 0
        assert lm[1:, 0].sum() == pytest.approx(lm[0, 1:].sum())
        # ici entries only on physical neighbours
        for i in range(8):
            for j in range(8):
                if lm[i + 1, j + 1] > 0:
                    assert any(l.src == i and l.dst == j
                               for l in TWO_POD.links() if l.kind == "ici")

    def test_contention_time_is_bottleneck_link(self):
        op = mk_op("all-reduce")
        lu = comm_matrix.link_utilization_for_ops([op], TWO_POD, "ring")
        t = cost_models.contention_time([op], TWO_POD, "ring")
        assert t == pytest.approx(lu.bottleneck_seconds())
        link, secs = lu.bottleneck()
        assert secs == pytest.approx(
            lu.bytes_by_link[link] / TWO_POD.link_bandwidth(link))

    def test_zero_traffic_has_no_bottleneck(self):
        """Links are pre-seeded at 0 bytes; an idle fabric must report no
        bottleneck link rather than an arbitrary zero-byte one."""
        lu = comm_matrix.project_links(np.zeros((9, 9)), ONE_POD)
        assert lu.bottleneck() is None
        assert lu.bottleneck_seconds() == 0.0
        for row in lu.summary().values():
            assert row["busiest_link"] == ""

    def test_weight_scales_links(self):
        op1, op16 = mk_op("all-reduce"), mk_op("all-reduce", weight=16.0)
        lu1 = comm_matrix.link_utilization_for_ops([op1], ONE_POD, "ring")
        lu16 = comm_matrix.link_utilization_for_ops([op16], ONE_POD, "ring")
        assert lu16.total_bytes() == pytest.approx(16 * lu1.total_bytes())


class TestWrapAwareRouting:
    """route() takes the shorter torus direction per axis; size-2 axes
    collapse both directions onto ONE link with both cables' bandwidth."""

    MESH_4X4 = MeshTopology(axis_names=("data", "model"), axis_sizes=(4, 4))

    @pytest.mark.parametrize("topo_name", sorted(TOPOLOGIES))
    def test_route_length_is_torus_distance(self, topo_name):
        topo = TOPOLOGIES[topo_name]
        for i in range(topo.num_devices):
            for j in range(topo.num_devices):
                if topo.pod_index(i) != topo.pod_index(j):
                    continue
                assert len(topo.route(i, j)) == topo.torus_distance(i, j), \
                    (i, j)

    def test_route_never_takes_the_long_way(self):
        topo = self.MESH_4X4
        for i in range(16):
            for j in range(16):
                hops = topo.route(i, j)
                assert len(hops) == topo.torus_distance(i, j) <= 4
                for a, b in zip(hops, hops[1:]):
                    assert a.dst == b.src

    def test_size2_axis_is_one_hop_one_link(self):
        """Satellite fix: both directions around a size-2 axis are the SAME
        single collapsed link -- never two distinct hops."""
        topo = TWO_POD                       # data and model axes are size 2
        d0, d1 = 0, 1                        # model-axis neighbours in pod 0
        # +1 and -1 around a size-2 ring reach the same neighbour ...
        assert topo.neighbor(d0, "model", +1) == \
            topo.neighbor(d0, "model", -1) == d1
        # ... and the enumeration holds exactly ONE link for the pair
        pair_links = [l for l in topo.links() if l.kind == "ici"
                      and l.src == d0 and l.dst == d1]
        assert len(pair_links) == 1
        fwd = topo.route(d0, d1)
        back = topo.route(d1, d0)
        assert len(fwd) == 1 and len(back) == 1
        assert fwd[0] == pair_links[0]
        # the collapsed link aggregates both physical cables
        assert topo.link_multiplicity(fwd[0]) == 2
        assert topo.link_bandwidth(fwd[0]) == \
            topo.hw.ici_bw * topo.hw.ici_links_per_axis
        # a size>2 axis keeps per-cable bandwidth
        link8 = ONE_POD.route(0, 1)[0]
        assert ONE_POD.link_multiplicity(link8) == 1
        assert ONE_POD.link_bandwidth(link8) == ONE_POD.hw.ici_bw

    @pytest.mark.parametrize("topo_name", sorted(TOPOLOGIES))
    def test_route_emits_only_enumerated_links(self, topo_name):
        """project_links' enforcement invariant, checked directly."""
        topo = TOPOLOGIES[topo_name]
        enumerated = set(topo.links())
        for i in range(topo.num_devices):
            for j in range(topo.num_devices):
                for link in topo.route(i, j):
                    assert link in enumerated, link.name

    def test_project_links_rejects_foreign_links(self):
        """A route outside the enumeration must raise, not silently invent
        fabric (the satellite's assert-and-enforce)."""
        class BadTopo(MeshTopology):
            def route(self, src, dst):
                from repro.core.topology import Link
                return [Link("ici", src, dst, "ghost-axis")]

        bad = BadTopo(axis_names=("data",), axis_sizes=(8,))
        mat = np.zeros((9, 9))
        mat[1, 5] = 64.0
        with pytest.raises(ValueError, match="not an enumerated"):
            comm_matrix.project_links(mat, bad)

    def test_bidirectional_ring_matches_cost_model(self):
        """The over-count fix: a ring over consecutive torus neighbours now
        streams both directions, so the bottleneck link carries HALF the
        per-rank bytes and contention_time equals collective_time's
        bandwidth term (before: 2x on size>2 axes; the latency hops are a
        separate, link-free term)."""
        op = mk_op("all-reduce")
        t_flat = cost_models.collective_time(op, ONE_POD, "ring",
                                             include_latency=False)
        t_link = cost_models.contention_time([op], ONE_POD, "ring")
        assert t_link == pytest.approx(t_flat)

    def test_size2_ring_matches_cost_model(self):
        """Same consistency on a size-2 axis: the collapsed link carries
        the full per-rank bytes at both cables' bandwidth."""
        pair = MeshTopology(axis_names=("data",), axis_sizes=(2,))
        op = mk_op("all-reduce", group=[0, 1])
        t_flat = cost_models.collective_time(op, pair, "ring",
                                             include_latency=False)
        t_link = cost_models.contention_time([op], pair, "ring")
        assert t_link == pytest.approx(t_flat)


class TestOverlapModel:
    """Link-level overlap: compute ∥ ICI ∥ DCN instead of serialized sums."""

    @pytest.mark.parametrize("kind", HIER_KINDS)
    @pytest.mark.parametrize("algorithm", ALGORITHMS)
    @pytest.mark.parametrize("topo_name", sorted(TOPOLOGIES))
    def test_overlap_bound_le_serialized(self, kind, algorithm, topo_name):
        """Acceptance criterion: the overlapped communication bound never
        exceeds the serialized sum of per-collective times, with equality
        exactly when a single tier carries all the traffic."""
        topo = TOPOLOGIES[topo_name]
        op = mk_op(kind, weight=2.0)
        ici_s, dcn_s = cost_models.total_time_split([op], topo, algorithm)
        serial = cost_models.total_time([op], topo, algorithm)
        assert ici_s + dcn_s == pytest.approx(serial)
        overlap = max(ici_s, dcn_s)
        assert overlap <= serial + 1e-15
        if ici_s > 0 and dcn_s > 0:
            assert overlap < serial            # both tiers busy: strict
        else:
            assert overlap == pytest.approx(serial)

    def test_hierarchical_multi_pod_overlaps_tiers(self):
        """On a multi-pod mesh the hierarchical split is the only algorithm
        with BOTH tiers busy -- the overlap bound is strictly better."""
        op = mk_op("all-reduce")
        ici_s, dcn_s = cost_models.total_time_split([op], TWO_POD,
                                                    "hierarchical")
        assert ici_s > 0 and dcn_s > 0
        assert max(ici_s, dcn_s) < ici_s + dcn_s
        # ring/tree across pods: everything is billed on the DCN tier
        for alg in ("ring", "tree"):
            i_s, d_s = cost_models.total_time_split([op], TWO_POD, alg)
            assert i_s == 0.0 and d_s > 0

    def test_busy_seconds_per_tier(self):
        """LinkUtilization.busy_seconds splits the fabric by tier and its
        overall bottleneck is one of the tiers."""
        op = mk_op("all-reduce")
        lu = comm_matrix.link_utilization_for_ops([op], TWO_POD,
                                                  "hierarchical")
        ici_busy = lu.busy_seconds("ici")
        dcn_busy = lu.busy_seconds("dcn")
        assert ici_busy > 0 and dcn_busy > 0
        assert lu.busy_seconds() == pytest.approx(max(ici_busy, dcn_busy))
        assert lu.bottleneck_seconds() == pytest.approx(lu.busy_seconds())
        tiers = lu.tier_summary()
        assert tiers["ici"]["busy_seconds"] == pytest.approx(ici_busy)
        assert tiers["dcn"]["bytes"] == pytest.approx(lu.total_bytes("dcn"))

    def test_report_split_and_overlap_seconds(self):
        """CommReport threads the split through: ici+dcn == serialized,
        overlap == max -- no topology means zeros."""
        from repro.core.monitor import CommReport
        from repro.core import hlo_parser
        op = mk_op("all-reduce")
        rep = CommReport(
            name="hand", num_devices=8, traced=[], compiled_ops=[op],
            traced_summary={},
            compiled_summary=hlo_parser.summarize([op], "hierarchical",
                                                  topo=TWO_POD),
            matrix=comm_matrix.matrix_for_ops([op], 8, "hierarchical",
                                              topo=TWO_POD),
            per_primitive={}, cost={}, memory_stats=None,
            trace_seconds=0.0, compile_seconds=0.0, topo=TWO_POD,
            algorithm="hierarchical")
        ici_s, dcn_s = rep.collective_seconds_split()
        assert ici_s + dcn_s == pytest.approx(rep.collective_seconds())
        assert rep.collective_overlap_seconds() == \
            pytest.approx(max(ici_s, dcn_s))
        assert rep.collective_overlap_seconds() <= rep.collective_seconds()


class TestCollectiveTimeFaithful:
    """The requested algorithm is billed, even across DCN (satellite fix).

    Bandwidth terms are pinned with ``include_latency=False``; the default
    (latency-inclusive) billing is pinned separately in
    :class:`TestLatencyTerms`.
    """

    def _op(self, group):
        return mk_op("all-reduce", group=group)

    def test_intra_pod_uses_ici(self):
        op = self._op([0, 1, 2, 3])    # pod 0 only
        t = cost_models.collective_time(op, TWO_POD, "ring",
                                        include_latency=False)
        per_rank = cost_models.wire_bytes_per_rank(
            "all-reduce", op.payload_bytes, 4, "ring")
        assert t == pytest.approx(per_rank / TWO_POD.ring_bw_per_chip(False))

    def test_ring_across_dcn_pays_full_payload_on_dcn(self):
        op = self._op(list(range(8)))
        t = cost_models.collective_time(op, TWO_POD, "ring",
                                        include_latency=False)
        per_rank = cost_models.wire_bytes_per_rank(
            "all-reduce", op.payload_bytes, 8, "ring")
        assert t == pytest.approx(per_rank / TWO_POD.ring_bw_per_chip(True))

    def test_tree_across_dcn_pays_full_payload_on_dcn(self):
        op = self._op(list(range(8)))
        t = cost_models.collective_time(op, TWO_POD, "tree",
                                        include_latency=False)
        assert t == pytest.approx(
            2.0 * op.payload_bytes / TWO_POD.ring_bw_per_chip(True))

    def test_hierarchical_across_dcn_splits_tiers(self):
        op = self._op(list(range(8)))
        s = op.payload_bytes
        t = cost_models.collective_time(op, TWO_POD, "hierarchical",
                                        include_latency=False)
        p, m = 2, 4
        intra = 2.0 * (m - 1) * s / m / TWO_POD.ring_bw_per_chip(False)
        cross = 2.0 * (p - 1) * (s / m) / p / TWO_POD.ring_bw_per_chip(True)
        assert t == pytest.approx(intra + cross)
        # the point of hierarchy: strictly faster than ring across DCN
        assert t < cost_models.collective_time(op, TWO_POD, "ring",
                                               include_latency=False)

    def test_algorithms_differ_across_dcn(self):
        op = self._op(list(range(8)))
        times = {a: cost_models.collective_time(op, TWO_POD, a)
                 for a in ALGORITHMS}
        assert len({round(v, 15) for v in times.values()}) == 3

    def test_total_time_is_execution_weighted(self):
        op1, op16 = self._op(list(range(8))), self._op(list(range(8)))
        op16.weight = 16.0
        t1 = cost_models.total_time([op1], TWO_POD, "ring")
        t16 = cost_models.total_time([op16], TWO_POD, "ring")
        assert t16 == pytest.approx(16 * t1)


class TestLatencyTerms:
    """The schedule's per-phase ``latency_hops``, billed by default at the
    tier's per-hop latency (tentpole: ``latency_model`` hops finally wired
    into ``collective_time_split``)."""

    def test_default_includes_latency(self):
        """collective_time == bandwidth term + hops * per-hop latency, with
        ring hops matching the closed-form ``latency_model``."""
        op = mk_op("all-reduce")           # single-axis 8-ring on ONE_POD
        bw = cost_models.collective_time(op, ONE_POD, "ring",
                                         include_latency=False)
        full = cost_models.collective_time(op, ONE_POD, "ring")
        hops = cost_models.latency_model("all-reduce", 8, "ring")
        assert full == pytest.approx(
            bw + hops * ONE_POD.hw.ici_hop_latency_s)

    def test_tree_latency_is_logarithmic(self):
        op = mk_op("all-reduce")
        bw = cost_models.collective_time(op, ONE_POD, "tree",
                                         include_latency=False)
        full = cost_models.collective_time(op, ONE_POD, "tree")
        hops = cost_models.latency_model("all-reduce", 8, "tree")
        assert full == pytest.approx(
            bw + hops * ONE_POD.hw.ici_hop_latency_s)

    def test_hierarchical_latency_splits_tiers(self):
        """Intra-pod hops pay ICI latency, the cross-pod exchange pays DCN
        latency -- and the TWO_POD intra subgroups (2x2, per-axis) pay
        2*(2-1)+2*(2-1) = 4 ICI hops instead of the flattened ring's 6."""
        op = mk_op("all-reduce")
        i_bw, d_bw = cost_models.collective_time_split(
            op, TWO_POD, "hierarchical", include_latency=False)
        i, d = cost_models.collective_time_split(op, TWO_POD,
                                                 "hierarchical")
        assert i - i_bw == pytest.approx(4 * TWO_POD.hw.ici_hop_latency_s)
        assert d - d_bw == pytest.approx(2 * TWO_POD.hw.dcn_hop_latency_s)

    def test_per_axis_reduces_latency_hops(self):
        """A multi-axis group's per-axis schedule pays 2*sum(size-1) serial
        hops -- strictly fewer than the flattened ring's 2*(n-1)."""
        from repro.core.decompose import decompose
        mesh44 = MeshTopology(axis_names=("data", "model"),
                              axis_sizes=(4, 4))
        op = mk_op("all-reduce", group=list(range(16)))
        sched = decompose(op, "ring", mesh44)
        assert sched.latency_hops("ici") == 2 * (3 + 3)
        flat = decompose(op, "ring", None)
        assert flat.latency_hops() == 2 * 15
        assert flat.latency_hops() == cost_models.latency_model(
            "all-reduce", 16, "ring")
