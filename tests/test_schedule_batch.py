"""Batched schedule-evaluation engine: bitwise parity with the per-op path.

The engine (signature-memoized ``cached_decompose``, deduping
``schedules_for_ops``, columnar ``ScheduleBatch``) promises every consumer
**bitwise-identical** artifacts -- matrices (dense and sparse), billing
totals, per-tier timing -- while decomposing once per distinct op shape.
This suite pins that promise on a deterministic grid (all op kinds x all
algorithms x 1/2/4-pod meshes x uniform/skewed byte vectors), exercises
the cache's correctness edges (topology/algorithm in the signature, weight
out of it; no collisions between equal-device-count meshes), and checks
the bounded-LRU mechanics plus fallback-warning replay through cache hits.
A hypothesis-widened generator rides along when the library is available.
"""
import dataclasses
import warnings

import numpy as np
import pytest

from repro.core import comm_matrix, cost_models
from repro.core.decompose import (BoundedCache, HierarchicalFallbackWarning,
                                  ScheduleBatch, cached_decompose,
                                  clear_schedule_cache, decompose,
                                  op_signature, reset_fallback_warnings,
                                  schedule_cache, schedules_for_ops,
                                  topo_signature)
from repro.core.cost_models import clear_billing_caches
from repro.core.events import CollectiveOp, Shape
from repro.core.topology import MeshTopology

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

KINDS = ("all-reduce", "all-gather", "reduce-scatter",
         "collective-broadcast", "all-to-all", "collective-permute")
ALGS = ("ring", "tree", "hierarchical")

MESHES = {
    "1pod": MeshTopology(axis_names=("data", "model"), axis_sizes=(4, 2)),
    "2pod": MeshTopology(axis_names=("pod", "data", "model"),
                         axis_sizes=(2, 4, 2)),
    "4pod": MeshTopology(axis_names=("pod", "data", "model"),
                         axis_sizes=(4, 4, 2)),
}


def make_stream(mesh_key: str, seed: int, num_ops: int = 6,
                skewed: bool = False) -> list[CollectiveOp]:
    """Mixed-kind op stream with repeated shapes: every op is emitted
    twice (fresh name/weight), so the dedupe path is always exercised."""
    topo = MESHES[mesh_key]
    d = int(np.prod(topo.axis_sizes))
    rng = np.random.default_rng(seed)
    protos = []
    for i in range(num_ops):
        kind = KINDS[int(rng.integers(len(KINDS)))]
        elems = int(rng.integers(1, 1 << 10))
        if kind == "collective-permute":
            perm = rng.permutation(d)
            pairs = [(int(perm[j]), int(perm[(j + 1) % d]))
                     for j in range(d)]
            protos.append(CollectiveOp(
                kind=kind, name=f"p{i}",
                result_shapes=[Shape("f32", (elems,))],
                replica_groups=[], source_target_pairs=pairs))
            continue
        gsize = int(rng.choice([s for s in (2, 4, 8, d) if s <= d]))
        devs = rng.permutation(d)
        groups = [sorted(int(x) for x in devs[k:k + gsize])
                  for k in range(0, d, gsize)]
        extra = {}
        if skewed and kind == "all-to-all":
            vec = rng.random(gsize) + 0.1
            vec[int(rng.integers(gsize))] *= 7.0
            vec = vec / vec.sum() * float(rng.integers(1 << 8, 1 << 16))
            extra["bytes_per_rank_vec"] = [float(x) for x in vec]
        protos.append(CollectiveOp(
            kind=kind, name=f"p{i}",
            result_shapes=[Shape("f32", (elems,))],
            replica_groups=groups, **extra))
    ops = []
    for rep in range(2):
        for i, p in enumerate(protos):
            ops.append(dataclasses.replace(
                p, name=f"op{rep}_{i}",
                weight=float(rng.integers(1, 17))))
    return ops


def per_op_matrix(ops, d, alg, topo):
    """The pre-engine oracle: decompose and place every op individually,
    per-op ``np.add.at`` in op order (the replaced accumulation exactly)."""
    mat = np.zeros((d + 1, d + 1), dtype=np.float64)
    for op in ops:
        sched = decompose(op, alg, topo, warn=False)
        src, dst, val = comm_matrix.schedule_edge_arrays(sched)
        if src.size:
            keep = (src < d) & (dst < d)
            w = max(1.0, op.weight)
            np.add.at(mat, (src[keep] + 1, dst[keep] + 1), val[keep] * w)
    return mat


GRID = [(mk, alg, skewed) for mk in MESHES for alg in ALGS
        for skewed in (False, True)]


@pytest.mark.parametrize("mesh_key,alg,skewed", GRID)
class TestBitwiseParity:
    """batched == per-op, bit for bit, across the full grid."""

    def _setup(self, mesh_key, alg, skewed):
        clear_schedule_cache()
        clear_billing_caches()
        topo = MESHES[mesh_key]
        d = int(np.prod(topo.axis_sizes))
        ops = make_stream(mesh_key, seed=hash((mesh_key, alg)) % 997,
                          skewed=skewed)
        return topo, d, ops

    def test_dense_matrix(self, mesh_key, alg, skewed):
        topo, d, ops = self._setup(mesh_key, alg, skewed)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", HierarchicalFallbackWarning)
            got = comm_matrix.matrix_for_ops(ops, d, alg, topo=topo)
        assert np.array_equal(got, per_op_matrix(ops, d, alg, topo))

    def test_sparse_matrix(self, mesh_key, alg, skewed):
        topo, d, ops = self._setup(mesh_key, alg, skewed)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", HierarchicalFallbackWarning)
            sp = comm_matrix.matrix_for_ops(ops, d, alg, topo=topo,
                                            sparse=True)
        assert np.array_equal(sp.to_dense(),
                              per_op_matrix(ops, d, alg, topo))

    def test_time_split_per_op(self, mesh_key, alg, skewed):
        topo, d, ops = self._setup(mesh_key, alg, skewed)
        batch = ScheduleBatch.from_ops(ops, alg, topo, warn=False)
        ici, dcn = batch.time_split_per_op(topo)
        for k, op in enumerate(ops):
            ri, rd = decompose(op, alg, topo, warn=False).time_split(topo)
            assert (float(ici[k]), float(dcn[k])) == (ri, rd)

    def test_total_time_split(self, mesh_key, alg, skewed):
        topo, d, ops = self._setup(mesh_key, alg, skewed)
        got = cost_models.total_time_split(ops, topo, alg)
        ici = dcn = 0.0
        for op in ops:
            i, dd = decompose(op, alg, topo, warn=False).time_split(topo)
            w = max(1.0, op.weight)
            ici += i * w
            dcn += dd * w
        assert got == (ici, dcn)

    def test_project_links(self, mesh_key, alg, skewed):
        topo, d, ops = self._setup(mesh_key, alg, skewed)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", HierarchicalFallbackWarning)
            got = comm_matrix.project_links(
                comm_matrix.matrix_for_ops(ops, d, alg, topo=topo), topo)
        ref = comm_matrix.project_links(
            per_op_matrix(ops, d, alg, topo), topo)
        assert got.bytes_by_link == ref.bytes_by_link


class TestBillingCaches:
    """The bounded signature-keyed caches behind ``wire_bytes_*``."""

    @pytest.mark.parametrize("kind", KINDS)
    def test_cached_equals_fresh(self, kind):
        for n in (2, 4, 8):
            clear_billing_caches()
            cold_pr = cost_models.wire_bytes_per_rank(kind, 4096.0, n,
                                                      "ring")
            cold_gt = cost_models.wire_bytes_group_total(kind, 4096.0, n,
                                                         "ring")
            warm_pr = cost_models.wire_bytes_per_rank(kind, 4096.0, n,
                                                      "ring")
            warm_gt = cost_models.wire_bytes_group_total(kind, 4096.0, n,
                                                         "ring")
            assert cold_pr == warm_pr and cold_gt == warm_gt

    def test_vector_ops_do_not_contaminate_the_scalar_cache(self):
        """Interleaving vector and scalar calls with identical (kind,
        payload, n, algorithm) must each keep returning their own fresh
        value -- a vec call can never be served a scalar cache entry or
        poison one."""
        vec = np.asarray([1000.0, 10.0, 10.0, 10.0])
        clear_billing_caches()
        v1 = cost_models.wire_bytes_group_total("all-to-all",
                                                float(vec.sum()), 4,
                                                "ring", vec=vec)
        s1 = cost_models.wire_bytes_group_total("all-to-all",
                                                float(vec.sum()), 4, "ring")
        v2 = cost_models.wire_bytes_group_total("all-to-all",
                                                float(vec.sum()), 4,
                                                "ring", vec=vec)
        clear_billing_caches()
        assert v1 == v2 == cost_models.wire_bytes_group_total(
            "all-to-all", float(vec.sum()), 4, "ring", vec=vec)
        assert s1 == cost_models.wire_bytes_group_total(
            "all-to-all", float(vec.sum()), 4, "ring")


class TestBoundedCache:
    def test_eviction_order_is_lru(self):
        c = BoundedCache(maxsize=2)
        c.put("a", 1)
        c.put("b", 2)
        assert c.get("a") == 1            # refreshes "a"
        c.put("c", 3)                     # evicts "b", the stalest
        assert "b" not in c and "a" in c and "c" in c
        assert len(c) == 2

    def test_hit_miss_counters_and_clear(self):
        c = BoundedCache(maxsize=4)
        assert c.get("x") is None and c.misses == 1
        c.put("x", 7)
        assert c.get("x") == 7 and c.hits == 1
        c.clear()
        assert len(c) == 0 and c.hits == 0 and c.misses == 0


class TestSignature:
    def test_equal_device_count_topologies_do_not_collide(self):
        """(4,2) and (2,4) meshes have 8 devices each but different ring
        neighbourhoods -- their signatures must differ."""
        t42 = MeshTopology(axis_names=("data", "model"), axis_sizes=(4, 2))
        t24 = MeshTopology(axis_names=("data", "model"), axis_sizes=(2, 4))
        assert topo_signature(t42) != topo_signature(t24)
        op = CollectiveOp(kind="all-reduce", name="ar",
                          result_shapes=[Shape("f32", (64,))],
                          replica_groups=[list(range(8))])
        assert op_signature(op, "ring", t42) != op_signature(op, "ring", t24)

    def test_weight_and_name_not_in_signature(self):
        op = CollectiveOp(kind="all-reduce", name="a", weight=1.0,
                          result_shapes=[Shape("f32", (64,))],
                          replica_groups=[list(range(8))])
        twin = dataclasses.replace(op, name="b", weight=64.0)
        assert op_signature(op) == op_signature(twin)

    def test_algorithm_in_signature(self):
        op = CollectiveOp(kind="all-reduce", name="a",
                          result_shapes=[Shape("f32", (64,))],
                          replica_groups=[list(range(8))])
        assert op_signature(op, "ring") != op_signature(op, "tree")

    def test_byte_vector_in_signature(self):
        base = dict(kind="all-to-all", name="a",
                    result_shapes=[Shape("f32", (1,))],
                    replica_groups=[[0, 1, 2, 3]])
        flat = CollectiveOp(bytes_per_rank_vec=[4.0] * 4, **base)
        skew = CollectiveOp(bytes_per_rank_vec=[13.0, 1.0, 1.0, 1.0],
                            **base)
        assert op_signature(flat) != op_signature(skew)

    def test_cached_decompose_shares_schedule_objects(self):
        clear_schedule_cache()
        topo = MESHES["1pod"]
        op = CollectiveOp(kind="all-gather", name="a",
                          result_shapes=[Shape("f32", (64,))],
                          replica_groups=[list(range(8))])
        twin = dataclasses.replace(op, name="b", weight=3.0)
        s1 = cached_decompose(op, "ring", topo, warn=False)
        s2 = cached_decompose(twin, "ring", topo, warn=False)
        assert s1 is s2
        scheds = schedules_for_ops([op, twin, op], "ring", topo)
        assert scheds[0] is scheds[1] is scheds[2]
        assert schedule_cache().hits >= 1

    def test_fallback_warning_replays_through_cache_hits(self):
        """A hierarchical refusal recorded at miss time must re-warn on a
        later cache hit (after the once-per-session dedup is reset)."""
        clear_schedule_cache()
        topo = MESHES["2pod"]
        # a cross-pod group that is NOT pod-aligned: 3 devices spanning
        # pods -> the hierarchical predicate refuses and falls back
        op = CollectiveOp(kind="all-reduce", name="odd",
                          result_shapes=[Shape("f32", (64,))],
                          replica_groups=[[0, 1, 8]])
        reset_fallback_warnings()
        with pytest.warns(HierarchicalFallbackWarning):
            cached_decompose(op, "hierarchical", topo)     # miss: records
        reset_fallback_warnings()
        with pytest.warns(HierarchicalFallbackWarning):
            cached_decompose(op, "hierarchical", topo)     # hit: replays
        reset_fallback_warnings()


class TestScheduleBatchLayout:
    def test_columns_align_with_schedules(self):
        topo = MESHES["2pod"]
        ops = make_stream("2pod", seed=5)
        batch = ScheduleBatch.from_ops(ops, "ring", topo, warn=False)
        assert len(batch) == len(ops)
        assert batch.op_phase_ptr[0] == 0
        assert batch.op_phase_ptr[-1] == batch.num_phases
        for i, sched in enumerate(batch.schedules):
            sl = batch.phase_slice(i)
            assert sl.stop - sl.start == len(sched.phases)
            for j, ph in enumerate(sched.phases):
                k = sl.start + j
                assert batch.is_dcn[k] == (ph.tier == "dcn")
                assert batch.max_bytes[k] == ph.max_bytes_per_rank()
                assert batch.hops[k] == ph.latency_hops
        assert batch.num_distinct <= len(ops)

    def test_phase_seconds_match_scalar_path(self):
        topo = MESHES["4pod"]
        ops = make_stream("4pod", seed=9, skewed=True)
        batch = ScheduleBatch.from_ops(ops, "ring", topo, warn=False)
        sec = batch.phase_seconds(topo)
        k = 0
        for sched in batch.schedules:
            for ph in sched.phases:
                assert float(sec[k]) == ph.seconds(topo)
                k += 1


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(mesh_key=st.sampled_from(sorted(MESHES)),
           alg=st.sampled_from(ALGS),
           seed=st.integers(0, 2**16),
           skewed=st.booleans())
    def test_hypothesis_bitwise_matrix_and_timing(mesh_key, alg, seed,
                                                  skewed):
        clear_schedule_cache()
        topo = MESHES[mesh_key]
        d = int(np.prod(topo.axis_sizes))
        ops = make_stream(mesh_key, seed=seed, skewed=skewed)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", HierarchicalFallbackWarning)
            got = comm_matrix.matrix_for_ops(ops, d, alg, topo=topo)
            sp = comm_matrix.matrix_for_ops(ops, d, alg, topo=topo,
                                            sparse=True)
        ref = per_op_matrix(ops, d, alg, topo)
        assert np.array_equal(got, ref)
        assert np.array_equal(sp.to_dense(), ref)
        batch = ScheduleBatch.from_ops(ops, alg, topo, warn=False)
        ici, dcn = batch.time_split_per_op(topo)
        for k, op in enumerate(ops):
            ri, rd = decompose(op, alg, topo, warn=False).time_split(topo)
            assert (float(ici[k]), float(dcn[k])) == (ri, rd)
