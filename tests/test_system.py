"""End-to-end system behaviour: drivers, serving, monitor integration."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.compile   # whole module drives XLA compiles


class TestTrainDriver:
    def test_train_resume_identical(self, tmp_path):
        """Fault tolerance: crash at step 10 + resume == uninterrupted run."""
        from repro.launch.train import main
        base = ["--arch", "granite_3_2b", "--global-batch", "4",
                "--seq-len", "16", "--mesh", "4x2", "--ckpt-every", "10"]
        full = main(base + ["--steps", "20",
                            "--ckpt-dir", str(tmp_path / "a")])
        # run that "crashes" after step 10, then restarts from its checkpoint
        main(base + ["--steps", "10", "--ckpt-dir", str(tmp_path / "b")])
        resumed = main(base + ["--steps", "20", "--resume",
                               "--ckpt-dir", str(tmp_path / "b")])
        assert resumed[-1] == pytest.approx(full[-1], rel=1e-4)

    def test_serve_driver_generates(self):
        from repro.launch.serve import main
        out = main(["--arch", "granite_3_2b", "--batch", "2",
                    "--prompt-len", "8", "--tokens", "4", "--mesh", "4x2"])
        assert out.shape == (2, 4)


class TestServing:
    def test_greedy_generation_deterministic(self, mesh8):
        from repro import configs
        from repro.models import build_model
        from repro.parallel import Sharder
        from repro.serve import generate
        shd = Sharder(mesh8)
        cfg = configs.config("qwen3_8b", reduced=True)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                     cfg.vocab_size)
        a = generate(model, params, prompts, shd, steps=6, max_len=32)
        b = generate(model, params, prompts, shd, steps=6, max_len=32)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestConfigs:
    def test_registry_complete(self):
        from repro import configs
        assert len(configs.ARCH_IDS) == 10
        for arch in configs.ARCH_IDS:
            cfg = configs.config(arch)
            assert cfg.n_layers > 0 and cfg.vocab_size > 0
            red = configs.config(arch, reduced=True)
            assert red.d_model <= 128

    def test_cells_skip_long_for_full_attention(self):
        from repro import configs
        cells = configs.cells()
        long_archs = {a for a, s in cells if s == "long_500k"}
        assert long_archs == {"xlstm_1_3b", "recurrentgemma_2b"}
        # 10 archs x 3 shapes + 2 long = 32 runnable cells
        assert len(cells) == 32

    def test_input_specs_match_shapes(self):
        from repro import configs
        from repro.models.common import SHAPES_BY_NAME
        cfg = configs.config("chameleon_34b")
        spec = configs.input_specs(cfg, SHAPES_BY_NAME["train_4k"])
        assert spec["embeds"].shape == (256, 4096, 8192)
        spec = configs.input_specs(cfg, SHAPES_BY_NAME["decode_32k"])
        assert spec["tokens"].shape == (128, 1)
