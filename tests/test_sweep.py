"""Sweep engine: mesh parsing, registry, cache-backed no-recompile re-runs."""
import pytest

from repro import sweep
from repro.core import ReportCache


class TestMeshSpecs:
    def test_parse(self):
        assert sweep.parse_mesh("8") == ((8,), ("data",))
        assert sweep.parse_mesh("4x2") == ((4, 2), ("data", "model"))
        assert sweep.parse_mesh("2x2x2") == ((2, 2, 2),
                                             ("pod", "data", "model"))

    def test_mesh_id_canonical(self):
        assert sweep.mesh_id("4x2") == "4x2:data,model"

    def test_bad_spec(self):
        with pytest.raises(ValueError):
            sweep.parse_mesh("2x2x2x2")


class TestRegistry:
    def test_paper_apps_and_archs_present(self):
        from repro import configs
        names = set(sweep.available_configs())
        assert {"paper", "gnmt", "resnet"} <= names
        assert set(configs.ARCH_IDS) <= names

    def test_unknown_config_rejected(self):
        with pytest.raises(KeyError):
            sweep.run_sweep(["nope"], ["4x2"], ["ring"])

    def test_unknown_algorithm_rejected(self):
        with pytest.raises(ValueError):
            sweep.run_sweep(["paper"], ["4x2"], ["nccl"])


class TestSweepRuns:
    pytestmark = pytest.mark.compile

    def test_cold_then_cached(self, tmp_path):
        cache = ReportCache(root=str(tmp_path / "cache"))
        logs: list[str] = []
        res = sweep.run_sweep(["paper"], ["4x2"], ["ring", "tree"],
                              cache=cache, log=logs.append)
        assert not res.failures
        assert res.compiles == 1              # tree derived, not recompiled
        assert [r.algorithm for r in res.reports] == ["ring", "tree"]
        assert any("derive" in l for l in logs)
        assert "paper" in res.summary_table()

        logs.clear()
        cache2 = ReportCache(root=str(tmp_path / "cache"))
        res2 = sweep.run_sweep(["paper"], ["4x2"], ["ring", "tree"],
                               cache=cache2, log=logs.append)
        assert res2.compiles == 0 and res2.cache_hits == 2
        assert all("[cache] hit" in l for l in logs)
        for a, b in zip(res.reports, res2.reports):
            assert a.matrix.sum() == pytest.approx(b.matrix.sum())

    def test_new_algorithm_derives_from_cached_sibling(self, tmp_path):
        cache = ReportCache(root=str(tmp_path / "cache"))
        sweep.run_sweep(["paper"], ["4x2"], ["ring"], cache=cache)
        logs: list[str] = []
        res = sweep.run_sweep(["paper"], ["4x2"], ["ring", "hierarchical"],
                              cache=ReportCache(root=str(tmp_path / "cache")),
                              log=logs.append)
        # the sibling ring entry satisfies hierarchical without compiling
        assert res.compiles == 0
        assert any("derive" in l and "hierarchical" in l for l in logs)

    def test_unrequested_sibling_spares_compile(self, tmp_path):
        cache = ReportCache(root=str(tmp_path / "cache"))
        sweep.run_sweep(["paper"], ["4x2"], ["ring"], cache=cache)
        logs: list[str] = []
        res = sweep.run_sweep(["paper"], ["4x2"], ["tree"],
                              cache=ReportCache(root=str(tmp_path / "cache")),
                              log=logs.append)
        # ring wasn't requested this time, but its cache entry still spares
        # the compile: tree derives from it
        assert res.compiles == 0
        assert any("sibling hit" in l for l in logs)
        assert res.reports[0].algorithm == "tree"
