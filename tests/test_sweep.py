"""Sweep engine: mesh parsing, registry, cache-backed no-recompile re-runs."""
import pytest

from repro import sweep
from repro.core import ReportCache


class TestMeshSpecs:
    def test_parse(self):
        assert sweep.parse_mesh("8") == ((8,), ("data",))
        assert sweep.parse_mesh("4x2") == ((4, 2), ("data", "model"))
        assert sweep.parse_mesh("2x2x2") == ((2, 2, 2),
                                             ("pod", "data", "model"))

    def test_mesh_id_canonical(self):
        assert sweep.mesh_id("4x2") == "4x2:data,model"

    def test_bad_spec(self):
        with pytest.raises(ValueError):
            sweep.parse_mesh("2x2x2x2")


class TestRegistry:
    def test_paper_apps_and_archs_present(self):
        from repro import configs
        names = set(sweep.available_configs())
        assert {"paper", "gnmt", "resnet"} <= names
        assert set(configs.ARCH_IDS) <= names

    def test_serve_config_is_multi_phase(self):
        """The serve config builds prefill/decode captures (a multi-phase
        session cell) instead of a single monitored function."""
        assert "serve" in sweep.available_configs()
        spec = sweep.available_configs()["serve"]
        assert "prefill/decode" in spec.description

    def test_moe_skew_config_present(self):
        assert "moe-skew" in sweep.available_configs()
        spec = sweep.available_configs()["moe-skew"]
        assert "irregular" in spec.description

    def test_unknown_config_rejected(self):
        with pytest.raises(KeyError):
            sweep.run_sweep(["nope"], ["4x2"], ["ring"])

    def test_unknown_algorithm_rejected(self):
        with pytest.raises(ValueError):
            sweep.run_sweep(["paper"], ["4x2"], ["nccl"])


class TestResolveJobs:
    def test_int_and_strings(self):
        assert sweep.resolve_jobs(1) == 1
        assert sweep.resolve_jobs(4) == 4
        assert sweep.resolve_jobs("2") == 2
        assert sweep.resolve_jobs(0) == 1          # floor at one worker

    def test_auto_is_cpu_count(self):
        import os
        assert sweep.resolve_jobs("auto") == max(1, os.cpu_count() or 1)
        assert sweep.resolve_jobs(" AUTO ") >= 1

    def test_garbage_rejected(self):
        with pytest.raises(ValueError):
            sweep.resolve_jobs("many")


class TestSweepRuns:
    pytestmark = pytest.mark.compile

    def test_cold_then_cached(self, tmp_path):
        cache = ReportCache(root=str(tmp_path / "cache"))
        logs: list[str] = []
        res = sweep.run_sweep(["paper"], ["4x2"], ["ring", "tree"],
                              cache=cache, log=logs.append)
        assert not res.failures
        assert res.compiles == 1              # tree derived, not recompiled
        assert [r.algorithm for r in res.reports] == ["ring", "tree"]
        assert any("derive" in l for l in logs)
        assert "paper" in res.summary_table()

        logs.clear()
        cache2 = ReportCache(root=str(tmp_path / "cache"))
        res2 = sweep.run_sweep(["paper"], ["4x2"], ["ring", "tree"],
                               cache=cache2, log=logs.append)
        assert res2.compiles == 0 and res2.cache_hits == 2
        assert all("[cache] hit" in l for l in logs)
        for a, b in zip(res.reports, res2.reports):
            assert a.matrix.sum() == pytest.approx(b.matrix.sum())

    def test_new_algorithm_derives_from_cached_sibling(self, tmp_path):
        cache = ReportCache(root=str(tmp_path / "cache"))
        sweep.run_sweep(["paper"], ["4x2"], ["ring"], cache=cache)
        logs: list[str] = []
        res = sweep.run_sweep(["paper"], ["4x2"], ["ring", "hierarchical"],
                              cache=ReportCache(root=str(tmp_path / "cache")),
                              log=logs.append)
        # the sibling ring entry satisfies hierarchical without compiling
        assert res.compiles == 0
        assert any("derive" in l and "hierarchical" in l for l in logs)

    def test_captures_build_monitors_one_session(self, mesh8):
        """A builder returning {"captures": ...} is monitored as ONE
        multi-phase session: phase-tagged ops, per-phase views, one
        snapshot per cell."""
        import jax
        import jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P

        ws = NamedSharding(mesh8, P(None, "model"))
        xs = NamedSharding(mesh8, P("data", None))
        w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        x = jax.ShapeDtypeStruct((32, 64), jnp.float32)

        def fwd(w, x):
            return ((x @ w) ** 2).mean()

        built = {"captures": [
            {"phase": "prefill", "name": "fwd", "fn": fwd, "args": (w, x),
             "kwargs": {"in_shardings": (ws, xs)}},
            {"phase": "decode", "name": "bwd",
             "fn": jax.value_and_grad(fwd), "args": (w, x),
             "kwargs": {"in_shardings": (ws, xs)}},
        ]}
        rep = sweep._monitor_cell(built, mesh8, "serve@4x2", "ring")
        assert rep.phase_names() == ["prefill", "decode"]
        assert {op.phase for op in rep.compiled_ops} <= \
            {"prefill", "decode"}
        res = sweep.SweepResult(reports=[rep], failures=[], cache_hits=0,
                                compiles=1)
        table = res.summary_table(by_phase=True)
        assert "prefill" in table and "decode" in table

    def test_phase_keyed_cell_reuses_session_snapshot(self, tmp_path,
                                                      mesh8):
        """Satellite: a sweep cell keyed with phase= hits the cached
        whole-session snapshot instead of recapturing."""
        from repro.core import ReportCache, cache_key
        import jax
        import jax.numpy as jnp

        built = {"captures": [
            {"phase": "prefill", "fn": lambda x: x.sum(),
             "args": (jax.ShapeDtypeStruct((8, 8), jnp.float32),)},
        ]}
        rep = sweep._monitor_cell(built, mesh8, "serve@4x2", "ring")
        cache = ReportCache(root=str(tmp_path / "cache"))
        key = cache_key("serve/v1", "4x2:data,model", "ring")
        cache.put(key, rep)
        hit = cache.get(cache_key("serve/v1", "4x2:data,model", "ring",
                                  phase="prefill"), phase="prefill")
        assert hit is not None and hit.phase_names() == ["prefill"]
        assert cache.get(key, phase="decode") is None   # never captured

    def test_moe_skew_cell_carries_irregular_vectors(self, mesh8):
        """The moe-skew builder's ``op_transform`` hook threads through
        ``_monitor_cell``: every captured a2a carries a per-rank byte
        vector with the hot expert above the skewed-a2a threshold, the
        summary grows the ``max_skew`` column, and the lint pass fires."""
        built = sweep.available_configs()["moe-skew"].build(mesh8)
        assert callable(built.get("op_transform"))
        rep = sweep._monitor_cell(built, mesh8, "moe-skew@4x2", "ring")
        a2as = [op for op in rep.compiled_ops
                if op.kind in ("all-to-all", "ragged-all-to-all")]
        assert a2as
        for op in a2as:
            vec = op.byte_vector()
            assert vec is not None
            assert vec.sum() == pytest.approx(op.payload_bytes)
            assert op.skew() > 2.0
        assert any(row.get("max_skew", 1.0) > 2.0
                   for row in rep.compiled_summary.values())
        assert any(f.rule_id == "skewed-a2a" for f in rep.lint())

    def test_parallel_and_serial_sweeps_are_identical(self, tmp_path):
        """``--jobs N`` must be invisible in the output: same report
        order, same counters, byte-identical summary CSV and table."""
        from repro.core.export import csv_exporter

        serial = sweep.run_sweep(
            ["paper"], ["4x2", "8"], ["ring", "tree"],
            cache=ReportCache(root=str(tmp_path / "c1")),
            log=lambda _: None)
        par = sweep.run_sweep(
            ["paper"], ["4x2", "8"], ["ring", "tree"],
            cache=ReportCache(root=str(tmp_path / "c2")), jobs=3,
            log=lambda _: None)
        assert not serial.failures and not par.failures
        assert serial.compiles == par.compiles == 2
        assert [(r.meta["config"], r.meta["mesh"], r.algorithm)
                for r in serial.reports] == \
               [(r.meta["config"], r.meta["mesh"], r.algorithm)
                for r in par.reports]
        p1 = csv_exporter.export_summary_csv(
            serial.reports, str(tmp_path / "serial.csv"))
        p2 = csv_exporter.export_summary_csv(
            par.reports, str(tmp_path / "parallel.csv"))
        with open(p1) as f1, open(p2) as f2:
            assert f1.read() == f2.read()
        assert serial.summary_table() == par.summary_table()

    def test_unrequested_sibling_spares_compile(self, tmp_path):
        cache = ReportCache(root=str(tmp_path / "cache"))
        sweep.run_sweep(["paper"], ["4x2"], ["ring"], cache=cache)
        logs: list[str] = []
        res = sweep.run_sweep(["paper"], ["4x2"], ["tree"],
                              cache=ReportCache(root=str(tmp_path / "cache")),
                              log=logs.append)
        # ring wasn't requested this time, but its cache entry still spares
        # the compile: tree derives from it
        assert res.compiles == 0
        assert any("sibling hit" in l for l in logs)
        assert res.reports[0].algorithm == "tree"
