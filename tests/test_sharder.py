"""Logical-axis sharding rules: divisibility fallbacks that carry 10 archs."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.parallel import Sharder
from repro.compat import make_mesh

pytestmark = pytest.mark.compile   # whole module drives XLA compiles


class TestSpec:
    def test_basic_tp(self, sharder):
        # mesh (data=4, model=2)
        assert sharder.spec((128, 64), ("embed", "mlp")) == P("data", "model")

    def test_indivisible_drops_axis(self, sharder):
        # 49155-style vocab not divisible by model axis (2): replicate
        assert sharder.spec((49155, 128), ("vocab", "embed")) == \
            P(None, "data")

    def test_no_axis_reuse_within_tensor(self, sharder):
        # both dims map to model; first claims it, second replicates
        assert sharder.spec((64, 64), ("mlp", "vocab")) == P("model", None)

    def test_heads_then_head_dim_fallback(self, sharder):
        # heads=5 not divisible by model=2 -> heads drops; head_dim takes it
        spec = sharder.spec((8, 16, 5, 64),
                            ("batch", "seq", "heads", "head_dim"))
        assert spec == P("data", None, None, "model")

    def test_multi_axis_batch(self):
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        shd = Sharder(mesh)
        assert shd.spec((8, 128), ("batch", None)) == P(("pod", "data"), None)

    def test_multi_axis_prefix_fallback(self):
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        shd = Sharder(mesh)
        # batch=2 divisible by pod(2) but not pod*data(4) -> prefix ("pod",)
        assert shd.spec((2, 16), ("batch", None)) == P("pod", None)

    def test_batch_one_replicates(self, sharder):
        # long_500k: global_batch=1
        assert sharder.spec((1, 64), ("batch", None)) == P(None, None)

    def test_sp_toggle(self, mesh8):
        off = Sharder(mesh8)
        on = Sharder(mesh8, enable_sp=True)
        assert off.spec((8, 64, 32), ("batch", "seq", None)) == \
            P("data", None, None)
        assert on.spec((8, 64, 32), ("batch", "seq", None)) == \
            P("data", "model", None)

    def test_expert_fallback_grok_vs_llama4(self, mesh8):
        shd = Sharder(mesh8)  # model=2
        # grok: 8 experts % 2 == 0 -> sharded on this mesh; mlp falls back
        assert shd.spec((8, 64, 128), ("expert", "embed", "mlp")) == \
            P("model", "data", None)
        # odd expert count -> replicate experts, shard mlp
        assert shd.spec((7, 64, 128), ("expert", "embed", "mlp")) == \
            P(None, "data", "model")


class TestTreeShardings:
    def test_tuple_axes_leaves(self, sharder):
        shapes = {"w": jax.ShapeDtypeStruct((64, 32), jnp.float32),
                  "b": jax.ShapeDtypeStruct((32,), jnp.float32)}
        axes = {"w": ("embed", "mlp"), "b": (None,)}
        sh = sharder.tree_shardings(shapes, axes)
        assert sh["w"].spec == P("data", "model")
        assert sh["b"].spec == P(None)

    def test_constraint_applies(self, sharder):
        @jax.jit
        def f(x):
            return sharder.constraint(x, ("batch", None))

        out = f(jnp.ones((8, 16)))
        # trailing Nones may be normalized away
        assert out.sharding.spec in (P("data", None), P("data"))
