"""Regenerate the committed lint-CI fixtures.

Two saved multi-phase session reports mirroring the examples --
``examples/translation.py`` (GNMT fwd/bwd/optim on an 8-way data mesh) and
``examples/serve_lm.py`` (qwen3 reduced prefill/decode on a 4x2 mesh) --
written with ``include_hlo=True`` (so the def-use lint rules can re-run
offline) and ``include_lint=True`` (so ``python -m repro lint <file>``
serves the v7 findings as saved).  The CI fast job gates on
``--fail-on error`` over both files.

Run:  PYTHONPATH=src python tests/fixtures/make_fixtures.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..",
                                "src"))

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import make_mesh, shard_map
from repro.core import MonitorSession

HERE = os.path.dirname(os.path.abspath(__file__))


def translation_report():
    from repro.data import SyntheticSeq2Seq
    from repro.models.gnmt import GNMT
    from repro.optim import OptConfig, init_opt_state, apply_updates
    from repro.train import ddp

    mesh = make_mesh((8,), ("data",))
    model = GNMT(vocab=64, d=128, layers=2)
    params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    data = SyntheticSeq2Seq(vocab_size=64, src_len=12, tgt_len=12,
                            global_batch=32)
    ocfg = OptConfig(peak_lr=3e-3, warmup_steps=10, decay_steps=500)
    opt = jax.eval_shape(lambda: init_opt_state(
        jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), params), ocfg))

    def fwd(params, batch):
        loss, _ = model.loss_fn(params, batch)
        return jax.lax.pmean(loss, "data")

    def bwd(params, batch):
        (_, _), grads = jax.value_and_grad(
            model.loss_fn, has_aux=True)(params, batch)
        grads, _ = ddp.allreduce_bucketed(grads, "data", bucket_mb=1.0)
        return grads

    def optim(params, grads, opt, i):
        params, opt, _ = apply_updates(params, grads, opt, ocfg, i)
        return params, opt

    def dp(fn, in_specs, out_specs):
        return shard_map(fn, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_vma=False)

    batch = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
                         data.batch_at(0))
    session = MonitorSession(mesh=mesh, name="GNMT-MT")
    with session:
        with session.phase("fwd"):
            session.capture(dp(fwd, (P(), P("data")), P()), params, batch)
        with session.phase("bwd"):
            session.capture(dp(bwd, (P(), P("data")), P()), params, batch)
        with session.phase("optim"):
            session.capture(
                dp(optim, (P(), P(), P(), P()), (P(), P())),
                params, params, opt,
                jax.ShapeDtypeStruct((), jnp.int32))
    return session.report()


def serve_report():
    from repro import configs
    from repro.models import build_model
    from repro.parallel import Sharder
    from repro.serve import ServeConfig, cache_shardings

    mesh = make_mesh((4, 2), ("data", "model"))
    shd = Sharder(mesh)
    cfg = configs.config("qwen3_8b", reduced=True)
    model = build_model(cfg)
    batch, prompt_len, max_len = 8, 32, 56
    scfg = ServeConfig(max_len=max_len, batch=batch)
    cache_sh = cache_shardings(model, scfg, shd)
    sess = MonitorSession(mesh=mesh, name=f"serve[{cfg.name}]")
    with sess:
        with sess.phase("prefill"):
            sess.capture(
                lambda p, b: model.prefill(p, b, shd, max_len=max_len),
                model.shapes(),
                {"tokens": jax.ShapeDtypeStruct((batch, prompt_len),
                                                jnp.int32)},
                name="prefill", out_shardings=(None, cache_sh))
        with sess.phase("decode"):
            sess.capture(
                lambda p, c, b: model.decode_step(p, c, b, shd),
                model.shapes(), model.cache_shapes(batch, max_len),
                {"tokens": jax.ShapeDtypeStruct((batch, 1), jnp.int32)},
                name="decode", in_shardings=(None, cache_sh, None),
                out_shardings=(None, cache_sh))
    return sess.report()


def main():
    for stem, build in (("translation_report", translation_report),
                        ("serve_report", serve_report)):
        rep = build()
        path = os.path.join(HERE, f"{stem}.json")
        rep.save(path, include_hlo=True, include_lint=True)
        findings = rep.lint()
        print(f"{stem}: {len(rep.compiled_ops)} collectives, "
              f"{len(findings)} lint findings -> {path}")
        for f in findings:
            print(f"  [{f.severity}] {f.rule_id}: {f.op_names}")


if __name__ == "__main__":
    main()
