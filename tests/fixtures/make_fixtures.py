"""Regenerate the committed lint- and compare-CI fixtures.

Two saved multi-phase session reports mirroring the examples --
``examples/translation.py`` (GNMT fwd/bwd/optim on an 8-way data mesh) and
``examples/serve_lm.py`` (qwen3 reduced prefill/decode on a 4x2 mesh) --
written with ``include_hlo=True`` (so the def-use lint rules can re-run
offline) and ``include_lint=True`` (so ``python -m repro lint <file>``
serves the v7 findings as saved).  The CI fast job gates on
``--fail-on error`` over both files.

On top of the reports, two trace fixtures for the ingestion subsystem
(:mod:`repro.core.trace`), derived from the COMMITTED report JSONs so
regenerating them never needs XLA:

* ``translation_trace.json`` -- our own Perfetto export of
  ``translation_report.json``; importing it must reproduce the report's
  comm matrix bitwise (the round-trip CI gate);
* ``serve_trace.csv`` -- a synthesized ComScribe-style nvprof GPU-trace
  CSV of ``serve_report.json``'s collectives, one kernel row per
  participating device, with deterministic measured durations
  ``modeled * (1 + delta_i)`` (|delta| <= 0.08) so ``repro compare``
  sees finite errors below the pinned CI bound (0.15).

Run:  PYTHONPATH=src python tests/fixtures/make_fixtures.py
      PYTHONPATH=src python tests/fixtures/make_fixtures.py --traces-only
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..",
                                "src"))

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import make_mesh, shard_map
from repro.core import MonitorSession

HERE = os.path.dirname(os.path.abspath(__file__))


def translation_report():
    from repro.data import SyntheticSeq2Seq
    from repro.models.gnmt import GNMT
    from repro.optim import OptConfig, init_opt_state, apply_updates
    from repro.train import ddp

    mesh = make_mesh((8,), ("data",))
    model = GNMT(vocab=64, d=128, layers=2)
    params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    data = SyntheticSeq2Seq(vocab_size=64, src_len=12, tgt_len=12,
                            global_batch=32)
    ocfg = OptConfig(peak_lr=3e-3, warmup_steps=10, decay_steps=500)
    opt = jax.eval_shape(lambda: init_opt_state(
        jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), params), ocfg))

    def fwd(params, batch):
        loss, _ = model.loss_fn(params, batch)
        return jax.lax.pmean(loss, "data")

    def bwd(params, batch):
        (_, _), grads = jax.value_and_grad(
            model.loss_fn, has_aux=True)(params, batch)
        grads, _ = ddp.allreduce_bucketed(grads, "data", bucket_mb=1.0)
        return grads

    def optim(params, grads, opt, i):
        params, opt, _ = apply_updates(params, grads, opt, ocfg, i)
        return params, opt

    def dp(fn, in_specs, out_specs):
        return shard_map(fn, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_vma=False)

    batch = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
                         data.batch_at(0))
    session = MonitorSession(mesh=mesh, name="GNMT-MT")
    with session:
        with session.phase("fwd"):
            session.capture(dp(fwd, (P(), P("data")), P()), params, batch)
        with session.phase("bwd"):
            session.capture(dp(bwd, (P(), P("data")), P()), params, batch)
        with session.phase("optim"):
            session.capture(
                dp(optim, (P(), P(), P(), P()), (P(), P())),
                params, params, opt,
                jax.ShapeDtypeStruct((), jnp.int32))
    return session.report()


def serve_report():
    from repro import configs
    from repro.models import build_model
    from repro.parallel import Sharder
    from repro.serve import ServeConfig, cache_shardings

    mesh = make_mesh((4, 2), ("data", "model"))
    shd = Sharder(mesh)
    cfg = configs.config("qwen3_8b", reduced=True)
    model = build_model(cfg)
    batch, prompt_len, max_len = 8, 32, 56
    scfg = ServeConfig(max_len=max_len, batch=batch)
    cache_sh = cache_shardings(model, scfg, shd)
    sess = MonitorSession(mesh=mesh, name=f"serve[{cfg.name}]")
    with sess:
        with sess.phase("prefill"):
            sess.capture(
                lambda p, b: model.prefill(p, b, shd, max_len=max_len),
                model.shapes(),
                {"tokens": jax.ShapeDtypeStruct((batch, prompt_len),
                                                jnp.int32)},
                name="prefill", out_shardings=(None, cache_sh))
        with sess.phase("decode"):
            sess.capture(
                lambda p, c, b: model.decode_step(p, c, b, shd),
                model.shapes(), model.cache_shapes(batch, max_len),
                {"tokens": jax.ShapeDtypeStruct((batch, 1), jnp.int32)},
                name="decode", in_shardings=(None, cache_sh, None),
                out_shardings=(None, cache_sh))
    return sess.report()


# deterministic measured-vs-modeled skew per op index (|delta| <= 0.08,
# cycling): keeps every fixture rel err finite and below the CI bound
_DELTAS = (0.05, -0.03, 0.07, -0.06, 0.02, -0.08, 0.04, -0.01)

_NCCL_NAMES = {
    "all-reduce": "ncclAllReduceRingLLKernel_sum_f32",
    "all-gather": "ncclAllGatherRingLLKernel_f32",
    "reduce-scatter": "ncclReduceScatterRingLLKernel_sum_f32",
    "all-to-all": "ncclAllToAllRingKernel_f32",
    "collective-broadcast": "ncclBroadcastRingLLKernel_f32",
}


def make_translation_trace():
    """Perfetto export of the committed translation report (the bitwise
    round-trip fixture)."""
    from repro.core import CommReport
    from repro.core.export.perfetto import export_perfetto

    rep = CommReport.load(os.path.join(HERE, "translation_report.json"))
    path = os.path.join(HERE, "translation_trace.json")
    export_perfetto(rep, path)
    print(f"translation_trace: {len(rep.compiled_ops)} collectives "
          f"-> {path}")
    return path


def make_serve_trace():
    """Synthesized nvprof GPU-trace CSV of the committed serve report:
    one kernel row per device per collective (PtoP memcpy rows for the
    permutes), durations = modeled * (1 + delta_i)."""
    from repro.core import CommReport

    rep = CommReport.load(os.path.join(HERE, "serve_report.json"))
    view = rep.view()
    secs = view.op_seconds()
    mb = 1024.0 ** 2
    dev = "Tesla V100-SXM2-16GB ({})"
    lines = [
        "==12345== NVPROF is profiling process 12345, "
        "command: serve_lm",
        "==12345== Profiling result:",
        '"Start","Duration","Size","SrcDev","DstDev","Device","Name",'
        '"Correlation_ID"',
        "s,ms,MB,,,,,",
    ]
    start = 0.0
    for i, (op, modeled) in enumerate(zip(rep.compiled_ops, secs)):
        measured_ms = modeled * (1.0 + _DELTAS[i % len(_DELTAS)]) * 1e3
        corr = 100 + i
        if op.kind == "collective-permute":
            size_mb = op.result_bytes / mb
            for src, dst in op.source_target_pairs:
                lines.append(
                    f"{start:.6f},{measured_ms:.9f},{size_mb:.9f},"
                    f'"{dev.format(src)}","{dev.format(dst)}",,'
                    f'"[CUDA memcpy PtoP]",{corr}')
        else:
            kname = _NCCL_NAMES[op.kind]
            size_mb = op.payload_bytes / mb
            group = (op.replica_groups[0] if op.replica_groups
                     else range(rep.num_devices))
            for d in group:
                lines.append(
                    f"{start:.6f},{measured_ms:.9f},{size_mb:.9f},,,"
                    f'"{dev.format(d)}","{kname}(...)",{corr}')
        start += measured_ms * 1e-3
    # one host transfer each way so row/col 0 of the matrix is exercised
    lines.append(f'{start:.6f},0.100000000,1.000000000,,,'
                 f'"{dev.format(0)}","[CUDA memcpy HtoD]",900')
    lines.append(f'{start + 0.001:.6f},0.100000000,1.000000000,,,'
                 f'"{dev.format(0)}","[CUDA memcpy DtoH]",901')
    path = os.path.join(HERE, "serve_trace.csv")
    with open(path, "w") as f:
        f.write("\n".join(lines) + "\n")
    print(f"serve_trace: {len(rep.compiled_ops)} collectives -> {path}")
    return path


def make_traces():
    make_translation_trace()
    make_serve_trace()


def main():
    if "--traces-only" not in sys.argv:
        for stem, build in (("translation_report", translation_report),
                            ("serve_report", serve_report)):
            rep = build()
            path = os.path.join(HERE, f"{stem}.json")
            rep.save(path, include_hlo=True, include_lint=True)
            findings = rep.lint()
            print(f"{stem}: {len(rep.compiled_ops)} collectives, "
                  f"{len(findings)} lint findings -> {path}")
            for f in findings:
                print(f"  [{f.severity}] {f.rule_id}: {f.op_names}")
    make_traces()


if __name__ == "__main__":
    main()
