"""Paper Table 1 values + algorithm-model properties (hypothesis).

``hypothesis`` is an optional [test] extra: without it this module degrades
to a skip instead of a collection error.
"""
import pytest

pytest.importorskip("hypothesis")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core import cost_models
from repro.core.cost_models import (table1_allreduce_bytes,
                                    wire_bytes_per_rank)


class TestTable1:
    """The published entries, verbatim (paper §3, Table 1)."""

    def test_ring_allreduce(self):
        # Ring: 2 x (N-1) x S/N
        assert table1_allreduce_bytes(4, 100.0, "ring") == 2 * 3 * 100.0 / 4
        assert table1_allreduce_bytes(16, 1.0, "ring") == 2 * 15 / 16

    def test_tree_allreduce(self):
        # Tree: root S, others 2S
        assert table1_allreduce_bytes(8, 5.0, "tree", role="root") == 5.0
        assert table1_allreduce_bytes(8, 5.0, "tree", role="other") == 10.0

    def test_collnet_allreduce(self):
        # Collnet: intranode 2S, internode S
        assert table1_allreduce_bytes(8, 3.0, "collnet", "intranode") == 6.0
        assert table1_allreduce_bytes(8, 3.0, "collnet", "internode") == 3.0

    def test_generalized_matches_table1_ring(self):
        for n in (2, 4, 8, 16):
            for s in (1.0, 1e6):
                assert wire_bytes_per_rank("all-reduce", s, n, "ring") == \
                    pytest.approx(table1_allreduce_bytes(n, s, "ring"))

    def test_generalized_matches_table1_tree(self):
        assert wire_bytes_per_rank("all-reduce", 7.0, 8, "tree") == 14.0


class TestProperties:
    @given(s=st.floats(1, 1e12), n=st.integers(2, 1024))
    @settings(max_examples=200, deadline=None)
    def test_ring_allreduce_below_2s(self, s, n):
        # ring AllReduce never exceeds 2S per rank and approaches it as N grows
        w = wire_bytes_per_rank("all-reduce", s, n, "ring")
        assert 0 < w < 2 * s
        assert w >= s  # and is at least S for N>=2

    @given(s=st.floats(1, 1e12), n=st.integers(2, 1024))
    @settings(max_examples=100, deadline=None)
    def test_allreduce_equals_rs_plus_ag(self, s, n):
        # AllReduce(ring) == ReduceScatter + AllGather exactly
        ar = wire_bytes_per_rank("all-reduce", s, n, "ring")
        rs = wire_bytes_per_rank("reduce-scatter", s, n, "ring")
        ag = wire_bytes_per_rank("all-gather", s, n, "ring")
        assert ar == pytest.approx(rs + ag)

    @given(s=st.floats(1, 1e9), n=st.integers(2, 256))
    @settings(max_examples=100, deadline=None)
    def test_monotone_in_payload(self, s, n):
        for kind in ("all-reduce", "all-gather", "reduce-scatter",
                     "all-to-all"):
            assert wire_bytes_per_rank(kind, 2 * s, n) == \
                pytest.approx(2 * wire_bytes_per_rank(kind, s, n))

    @given(n=st.integers(2, 64))
    @settings(max_examples=50, deadline=None)
    def test_all_to_all_less_than_gather(self, n):
        # a2a moves each rank's (n-1)/n blocks of S/n -> less than AllGather
        s = 1e6
        assert wire_bytes_per_rank("all-to-all", s, n) < \
            wire_bytes_per_rank("all-gather", s, n) + 1e-9

    def test_single_rank_is_free(self):
        for kind in ("all-reduce", "all-gather", "all-to-all"):
            assert wire_bytes_per_rank(kind, 1e9, 1) == 0.0

    def test_unknown_algorithm_raises(self):
        with pytest.raises(ValueError):
            wire_bytes_per_rank("all-reduce", 1.0, 2, "warp-shuffle")


class TestLatencyModel:
    def test_tree_is_logarithmic(self):
        assert cost_models.latency_model("all-reduce", 256, "tree") == \
            2 * 8  # 2*log2(256)

    def test_ring_is_linear(self):
        assert cost_models.latency_model("all-reduce", 8, "ring") == 14
