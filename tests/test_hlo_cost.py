"""Loop-aware HLO cost extraction (trip-count multipliers)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core.hlo_cost import (HloAnalyzer, analyze_hlo,
                                 computation_multipliers, split_computations,
                                 top_ops)


def _compile(f, *shapes):
    args = [jax.ShapeDtypeStruct(s, jnp.float32) for s in shapes]
    return jax.jit(f).lower(*args).compile().as_text()


class TestMultipliers:
    def test_nested_scan_flops_exact(self):
        def f(x):
            def outer(c, _):
                def body(c, _):
                    return c @ x + 1.0, None
                c, _ = jax.lax.scan(body, c, None, length=8)
                return c, None
            out, _ = jax.lax.scan(outer, x, None, length=4)
            return out.sum()

        hc = analyze_hlo(_compile(f, (64, 64)))
        expected = 2 * 64**3 * 32
        assert hc.flops == pytest.approx(expected, rel=0.05)

    def test_no_loop_flops_exact(self):
        hc = analyze_hlo(_compile(lambda a, b: a @ b, (32, 48), (48, 16)))
        assert hc.flops == pytest.approx(2 * 32 * 48 * 16, rel=0.01)

    def test_collectives_weighted_by_trip_count(self, mesh_dp):
        def g(x):
            def body(c, _):
                return jax.lax.psum(c, "data") * 0.1, None
            c, _ = jax.lax.scan(body, x, None, length=16)
            return c

        gg = jax.jit(shard_map(g, mesh=mesh_dp, in_specs=P("data"),
                                   out_specs=P("data"), check_vma=False))
        hlo = gg.lower(jax.ShapeDtypeStruct((8, 64), jnp.float32)) \
            .compile().as_text()
        s = analyze_hlo(hlo).collective_summary()
        assert s["all-reduce"]["calls"] == 16

    def test_synthetic_multiplier_graph(self):
        hlo = """
ENTRY %main (p: f32[4]) -> f32[4] {
  %p = f32[4]{0} parameter(0)
  %tuple = (s32[], f32[4]) tuple(%c, %p)
  %while.1 = (s32[], f32[4]) while(%tuple), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"}}
  ROOT %gte = f32[4]{0} get-tuple-element(%while.1), index=1
}
%body (t: (s32[], f32[4])) -> (s32[], f32[4]) {
  %t = (s32[], f32[4]) parameter(0)
  %x = f32[4]{0} get-tuple-element(%t), index=1
  ROOT %r = (s32[], f32[4]) tuple(%i, %x)
}
%cond (t2: (s32[], f32[4])) -> pred[] {
  %t2 = (s32[], f32[4]) parameter(0)
  ROOT %lt = pred[] compare(%i2, %n), direction=LT
}
"""
        comps, entry = split_computations(hlo)
        mult = computation_multipliers(comps, entry)
        assert mult["main"] == 1.0
        assert mult["body"] == 10.0
        assert mult["cond"] == 11.0


class TestBytesModel:
    def test_dus_fusion_counts_slice_not_buffer(self):
        """A scan writing 1-slice into a big stacked carry must charge the
        slice (the DUS buffer operand is aliased)."""
        def f(x):
            def body(c, _):
                return c * 1.5, c
            _, ys = jax.lax.scan(body, x, None, length=32)
            return ys.sum()

        hlo = _compile(f, (128, 128))
        hc = analyze_hlo(hlo)
        # if the full (32,128,128) buffer were charged per step, bytes would
        # exceed 32 steps * 32*128*128*4 * 2 = 128 MiB; slice-aware ~ a few MiB
        assert hc.bytes_hbm < 60e6, hc.bytes_hbm / 1e6

    def test_top_ops_returns_sorted(self):
        hlo = _compile(lambda a, b: jax.nn.relu(a @ b), (64, 64), (64, 64))
        rows = top_ops(hlo, 5, by="flops")
        assert rows and rows[0][0] >= rows[-1][0]

    def test_analyzer_handles_empty(self):
        hc = analyze_hlo("")
        assert hc.flops == 0 and hc.collectives == []
