"""Loop-aware HLO cost extraction (trip-count multipliers)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core.hlo_cost import (HloAnalyzer, analyze_hlo,
                                 computation_multipliers, split_computations,
                                 top_ops)


def _compile(f, *shapes):
    args = [jax.ShapeDtypeStruct(s, jnp.float32) for s in shapes]
    return jax.jit(f).lower(*args).compile().as_text()


class TestMultipliers:
    @pytest.mark.compile
    def test_nested_scan_flops_exact(self):
        def f(x):
            def outer(c, _):
                def body(c, _):
                    return c @ x + 1.0, None
                c, _ = jax.lax.scan(body, c, None, length=8)
                return c, None
            out, _ = jax.lax.scan(outer, x, None, length=4)
            return out.sum()

        hc = analyze_hlo(_compile(f, (64, 64)))
        expected = 2 * 64**3 * 32
        assert hc.flops == pytest.approx(expected, rel=0.05)

    @pytest.mark.compile
    def test_no_loop_flops_exact(self):
        hc = analyze_hlo(_compile(lambda a, b: a @ b, (32, 48), (48, 16)))
        assert hc.flops == pytest.approx(2 * 32 * 48 * 16, rel=0.01)

    @pytest.mark.compile
    def test_collectives_weighted_by_trip_count(self, mesh_dp):
        def g(x):
            def body(c, _):
                return jax.lax.psum(c, "data") * 0.1, None
            c, _ = jax.lax.scan(body, x, None, length=16)
            return c

        gg = jax.jit(shard_map(g, mesh=mesh_dp, in_specs=P("data"),
                                   out_specs=P("data"), check_vma=False))
        hlo = gg.lower(jax.ShapeDtypeStruct((8, 64), jnp.float32)) \
            .compile().as_text()
        s = analyze_hlo(hlo).collective_summary()
        assert s["all-reduce"]["calls"] == 16

    def test_synthetic_multiplier_graph(self):
        hlo = """
ENTRY %main (p: f32[4]) -> f32[4] {
  %p = f32[4]{0} parameter(0)
  %tuple = (s32[], f32[4]) tuple(%c, %p)
  %while.1 = (s32[], f32[4]) while(%tuple), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"}}
  ROOT %gte = f32[4]{0} get-tuple-element(%while.1), index=1
}
%body (t: (s32[], f32[4])) -> (s32[], f32[4]) {
  %t = (s32[], f32[4]) parameter(0)
  %x = f32[4]{0} get-tuple-element(%t), index=1
  ROOT %r = (s32[], f32[4]) tuple(%i, %x)
}
%cond (t2: (s32[], f32[4])) -> pred[] {
  %t2 = (s32[], f32[4]) parameter(0)
  ROOT %lt = pred[] compare(%i2, %n), direction=LT
}
"""
        comps, entry = split_computations(hlo)
        mult = computation_multipliers(comps, entry)
        assert mult["main"] == 1.0
        assert mult["body"] == 10.0
        assert mult["cond"] == 11.0

    def test_trip_count_inferred_without_annotation(self):
        """No known_trip_count backend_config (older jaxlibs / other
        pipelines): the trip count is statically inferred from the
        compare(iter, constant) condition + body increment + initializer,
        including the typed-operand spelling jax 0.4.x prints."""
        hlo = """
ENTRY %main (p: f32[4]) -> f32[4] {
  %p = f32[4]{0} parameter(0)
  %c0 = s32[] constant(2)
  %copy.1 = s32[] copy(s32[] %c0)
  %tuple = (s32[], f32[4]) tuple(s32[] %copy.1, f32[4]{0} %p)
  %while.1 = (s32[], f32[4]) while((s32[], f32[4]) %tuple), condition=%cond, body=%body
  ROOT %gte = f32[4]{0} get-tuple-element((s32[], f32[4]) %while.1), index=1
}
%body (t: (s32[], f32[4])) -> (s32[], f32[4]) {
  %t = (s32[], f32[4]) parameter(0)
  %i = s32[] get-tuple-element((s32[], f32[4]) %t), index=0
  %one = s32[] constant(1)
  %next = s32[] add(s32[] %i, s32[] %one)
  %x = f32[4]{0} get-tuple-element((s32[], f32[4]) %t), index=1
  ROOT %r = (s32[], f32[4]) tuple(s32[] %next, f32[4]{0} %x)
}
%cond (t2: (s32[], f32[4])) -> pred[] {
  %t2 = (s32[], f32[4]) parameter(0)
  %i2 = s32[] get-tuple-element((s32[], f32[4]) %t2), index=0
  %n = s32[] constant(9)
  ROOT %lt = pred[] compare(s32[] %i2, s32[] %n), direction=LT
}
"""
        comps, entry = split_computations(hlo)
        mult = computation_multipliers(comps, entry)
        # iter runs 2,3,...,8 -> 7 trips, inferred with no annotation
        assert mult["body"] == 7.0
        assert mult["cond"] == 8.0

    def test_trip_count_inference_flipped_compare(self):
        """constant-on-the-left compare still infers (direction flipped)."""
        hlo = """
ENTRY %main (p: f32[4]) -> (s32[], f32[4]) {
  %p = f32[4]{0} parameter(0)
  %c0 = s32[] constant(0)
  %tuple = (s32[], f32[4]) tuple(s32[] %c0, f32[4]{0} %p)
  ROOT %while.1 = (s32[], f32[4]) while((s32[], f32[4]) %tuple), condition=%cond, body=%body
}
%body (t: (s32[], f32[4])) -> (s32[], f32[4]) {
  %t = (s32[], f32[4]) parameter(0)
  %i = s32[] get-tuple-element((s32[], f32[4]) %t), index=0
  %one = s32[] constant(1)
  %next = s32[] add(s32[] %one, s32[] %i)
  %x = f32[4]{0} get-tuple-element((s32[], f32[4]) %t), index=1
  ROOT %r = (s32[], f32[4]) tuple(s32[] %next, f32[4]{0} %x)
}
%cond (t2: (s32[], f32[4])) -> pred[] {
  %t2 = (s32[], f32[4]) parameter(0)
  %i2 = s32[] get-tuple-element((s32[], f32[4]) %t2), index=0
  %n = s32[] constant(5)
  ROOT %gt = pred[] compare(s32[] %n, s32[] %i2), direction=GT
}
"""
        comps, entry = split_computations(hlo)
        mult = computation_multipliers(comps, entry)
        assert mult["body"] == 5.0

    def test_unbounded_loop_defaults_to_one(self):
        """A data-dependent bound must not be guessed: body counts once."""
        hlo = """
ENTRY %main (p: s32[]) -> (s32[], s32[]) {
  %p = s32[] parameter(0)
  %c0 = s32[] constant(0)
  %tuple = (s32[], s32[]) tuple(s32[] %c0, s32[] %p)
  ROOT %while.1 = (s32[], s32[]) while((s32[], s32[]) %tuple), condition=%cond, body=%body
}
%body (t: (s32[], s32[])) -> (s32[], s32[]) {
  %t = (s32[], s32[]) parameter(0)
  %i = s32[] get-tuple-element((s32[], s32[]) %t), index=0
  %one = s32[] constant(1)
  %next = s32[] add(s32[] %i, s32[] %one)
  %lim = s32[] get-tuple-element((s32[], s32[]) %t), index=1
  ROOT %r = (s32[], s32[]) tuple(s32[] %next, s32[] %lim)
}
%cond (t2: (s32[], s32[])) -> pred[] {
  %t2 = (s32[], s32[]) parameter(0)
  %i2 = s32[] get-tuple-element((s32[], s32[]) %t2), index=0
  %lim2 = s32[] get-tuple-element((s32[], s32[]) %t2), index=1
  ROOT %lt = pred[] compare(s32[] %i2, s32[] %lim2), direction=LT
}
"""
        comps, entry = split_computations(hlo)
        mult = computation_multipliers(comps, entry)
        assert mult["body"] == 1.0

    def test_early_exit_condition_not_guessed(self):
        """compare feeding an and() root = extra exit conditions; the
        compare bound is an upper limit, not the trip count."""
        hlo = """
ENTRY %main (p: pred[]) -> (s32[], pred[]) {
  %p = pred[] parameter(0)
  %c0 = s32[] constant(0)
  %tuple = (s32[], pred[]) tuple(s32[] %c0, pred[] %p)
  ROOT %while.1 = (s32[], pred[]) while((s32[], pred[]) %tuple), condition=%cond, body=%body
}
%body (t: (s32[], pred[])) -> (s32[], pred[]) {
  %t = (s32[], pred[]) parameter(0)
  %i = s32[] get-tuple-element((s32[], pred[]) %t), index=0
  %one = s32[] constant(1)
  %next = s32[] add(s32[] %i, s32[] %one)
  %f = pred[] get-tuple-element((s32[], pred[]) %t), index=1
  ROOT %r = (s32[], pred[]) tuple(s32[] %next, pred[] %f)
}
%cond (t2: (s32[], pred[])) -> pred[] {
  %t2 = (s32[], pred[]) parameter(0)
  %i2 = s32[] get-tuple-element((s32[], pred[]) %t2), index=0
  %n = s32[] constant(100)
  %lt = pred[] compare(s32[] %i2, s32[] %n), direction=LT
  %flag = pred[] get-tuple-element((s32[], pred[]) %t2), index=1
  ROOT %and = pred[] and(pred[] %lt, pred[] %flag)
}
"""
        comps, entry = split_computations(hlo)
        mult = computation_multipliers(comps, entry)
        assert mult["body"] == 1.0

    def test_hidden_increment_not_guessed(self):
        """No top-level constant increment of the induction variable (e.g.
        folded into a fusion): refuse to assume step=1."""
        hlo = """
ENTRY %main (p: f32[4]) -> (s32[], f32[4]) {
  %p = f32[4]{0} parameter(0)
  %c0 = s32[] constant(0)
  %tuple = (s32[], f32[4]) tuple(s32[] %c0, f32[4]{0} %p)
  ROOT %while.1 = (s32[], f32[4]) while((s32[], f32[4]) %tuple), condition=%cond, body=%body
}
%body (t: (s32[], f32[4])) -> (s32[], f32[4]) {
  %t = (s32[], f32[4]) parameter(0)
  %i = s32[] get-tuple-element((s32[], f32[4]) %t), index=0
  %next = s32[] fusion(s32[] %i), kind=kLoop, calls=%inc_fusion
  %x = f32[4]{0} get-tuple-element((s32[], f32[4]) %t), index=1
  ROOT %r = (s32[], f32[4]) tuple(s32[] %next, f32[4]{0} %x)
}
%inc_fusion (q: s32[]) -> s32[] {
  %q = s32[] parameter(0)
  %two = s32[] constant(2)
  ROOT %a = s32[] add(s32[] %q, s32[] %two)
}
%cond (t2: (s32[], f32[4])) -> pred[] {
  %t2 = (s32[], f32[4]) parameter(0)
  %i2 = s32[] get-tuple-element((s32[], f32[4]) %t2), index=0
  %n = s32[] constant(100)
  ROOT %lt = pred[] compare(s32[] %i2, s32[] %n), direction=LT
}
"""
        comps, entry = split_computations(hlo)
        mult = computation_multipliers(comps, entry)
        assert mult["body"] == 1.0


class TestBytesModel:
    @pytest.mark.compile
    def test_dus_fusion_counts_slice_not_buffer(self):
        """A scan writing 1-slice into a big stacked carry must charge the
        slice (the DUS buffer operand is aliased)."""
        def f(x):
            def body(c, _):
                return c * 1.5, c
            _, ys = jax.lax.scan(body, x, None, length=32)
            return ys.sum()

        hlo = _compile(f, (128, 128))
        hc = analyze_hlo(hlo)
        # if the full (32,128,128) buffer were charged per step, bytes would
        # exceed 32 steps * 32*128*128*4 * 2 = 128 MiB; slice-aware ~ a few MiB
        assert hc.bytes_hbm < 60e6, hc.bytes_hbm / 1e6

    @pytest.mark.compile
    def test_top_ops_returns_sorted(self):
        hlo = _compile(lambda a, b: jax.nn.relu(a @ b), (64, 64), (64, 64))
        rows = top_ops(hlo, 5, by="flops")
        assert rows and rows[0][0] >= rows[-1][0]

    def test_analyzer_handles_empty(self):
        hc = analyze_hlo("")
        assert hc.flops == 0 and hc.collectives == []
