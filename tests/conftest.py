"""Test fixtures.  8 host devices (NOT the dry-run's 512 — that flag stays
inside launch/dryrun.py) so distribution tests exercise real mesh sharding."""
import os
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def mesh8():
    from repro.compat import make_mesh
    return make_mesh((4, 2), ("data", "model"))


@pytest.fixture(scope="session")
def mesh_dp():
    from repro.compat import make_mesh
    return make_mesh((8,), ("data",))


@pytest.fixture(scope="session")
def sharder(mesh8):
    from repro.parallel import Sharder
    return Sharder(mesh8)
