"""Checkpointing: atomicity, retention, async, elastic restore."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (AsyncCheckpointer, latest_step,
                              restore_checkpoint, save_checkpoint)

pytestmark = pytest.mark.compile   # whole module drives XLA compiles


def make_state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (16, 8)),
                       "b": jnp.zeros((8,))},
            "opt": {"m": jnp.ones((16, 8))},
            "step": jnp.asarray(7, jnp.int32)}


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        state = make_state()
        save_checkpoint(str(tmp_path), 7, state)
        r = restore_checkpoint(str(tmp_path), 7, state)
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(r)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_latest_step(self, tmp_path):
        assert latest_step(str(tmp_path)) is None
        state = make_state()
        for s in (5, 10, 15):
            save_checkpoint(str(tmp_path), s, state, keep=10)
        assert latest_step(str(tmp_path)) == 15

    def test_retention_gc(self, tmp_path):
        state = make_state()
        for s in range(6):
            save_checkpoint(str(tmp_path), s, state, keep=2)
        kept = sorted(d for d in os.listdir(tmp_path)
                      if d.startswith("step_"))
        assert kept == ["step_4", "step_5"]

    def test_no_tmp_dirs_left(self, tmp_path):
        save_checkpoint(str(tmp_path), 1, make_state())
        assert not [d for d in os.listdir(tmp_path) if d.endswith(".tmp")]

    def test_structure_mismatch_raises(self, tmp_path):
        save_checkpoint(str(tmp_path), 1, make_state())
        bad = {"params": {"w": jnp.zeros((4, 4))}}
        with pytest.raises((KeyError, ValueError)):
            restore_checkpoint(str(tmp_path), 1, bad)

    def test_elastic_restore_new_mesh(self, tmp_path, mesh8):
        """Save unsharded, restore sharded into a mesh (elastic restart)."""
        from jax.sharding import NamedSharding, PartitionSpec as P
        state = make_state()
        save_checkpoint(str(tmp_path), 3, state)
        sh = jax.tree.map(lambda _: NamedSharding(mesh8, P()), state)
        sh["params"]["w"] = NamedSharding(mesh8, P("data", "model"))
        r = restore_checkpoint(str(tmp_path), 3, state, shardings=sh)
        assert r["params"]["w"].sharding.spec == P("data", "model")
        np.testing.assert_array_equal(np.asarray(r["params"]["w"]),
                                      np.asarray(state["params"]["w"]))

    def test_async_checkpointer(self, tmp_path):
        ck = AsyncCheckpointer(str(tmp_path), keep=2)
        state = make_state()
        ck.save(1, state)
        ck.save(2, state)     # waits for 1 internally
        ck.wait()
        assert latest_step(str(tmp_path)) == 2

    def test_crash_mid_save_preserves_previous(self, tmp_path):
        """A stale .tmp dir never shadows a completed checkpoint."""
        state = make_state()
        save_checkpoint(str(tmp_path), 1, state)
        os.makedirs(os.path.join(str(tmp_path), "step_2.tmp"))
        # interrupted save of step 2 -> latest complete is still 1
        assert latest_step(str(tmp_path)) == 1
        save_checkpoint(str(tmp_path), 2, state)  # retry succeeds
        assert latest_step(str(tmp_path)) == 2


class TestData:
    def test_deterministic_replay(self):
        from repro.data import SyntheticLMData
        d1 = SyntheticLMData(vocab_size=100, seq_len=16, global_batch=4,
                             seed=3)
        d2 = SyntheticLMData(vocab_size=100, seq_len=16, global_batch=4,
                             seed=3)
        b1, b2 = d1.batch_at(17), d2.batch_at(17)
        np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                      np.asarray(b2["tokens"]))
        b3 = d1.batch_at(18)
        assert not np.array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b3["tokens"]))

    def test_labels_shifted(self):
        from repro.data import SyntheticLMData
        d = SyntheticLMData(vocab_size=100, seq_len=16, global_batch=2)
        b = d.batch_at(0)
        np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                      np.asarray(b["labels"][:, :-1]))

    def test_host_transfers_logged(self):
        from repro.data import SyntheticLMData, host_transfer_log
        before = len(host_transfer_log())
        SyntheticLMData(vocab_size=100, seq_len=16,
                        global_batch=2).batch_at(0)
        logged = host_transfer_log()[before:]
        assert len(logged) == 2  # tokens + labels
        assert all(t.direction == "h2d" for t in logged)
        assert logged[0].nbytes == 2 * 16 * 4
