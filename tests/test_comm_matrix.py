"""Communication-matrix invariants (paper Figs. 2-3), property-based.

``hypothesis`` is an optional [test] extra: without it this module degrades
to a skip instead of a collection error (the tier-1 suite must stay green on
a bare interpreter).
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core import comm_matrix
from repro.core.events import CollectiveOp, HostTransfer, Shape


def mk_op(kind, dims, groups, dtype="f32", pairs=None):
    return CollectiveOp(kind=kind, name="t", result_shapes=[Shape(dtype, dims)],
                        replica_groups=groups,
                        source_target_pairs=pairs or [])


class TestMatrixInvariants:
    @given(n=st.sampled_from([2, 4, 8]), elems=st.integers(1, 4096))
    @settings(max_examples=60, deadline=None)
    def test_matrix_sum_equals_wire_total_ring(self, n, elems):
        op = mk_op("all-reduce", (elems,), [list(range(n))])
        mat = comm_matrix.matrix_for_ops([op], n)
        assert mat.sum() == pytest.approx(op.wire_bytes_total("ring"))

    @given(n=st.sampled_from([2, 4, 8]), elems=st.integers(1, 1024))
    @settings(max_examples=40, deadline=None)
    def test_ring_traffic_only_on_ring_edges(self, n, elems):
        """Bidirectional ring: both neighbours get half, nothing else."""
        op = mk_op("all-gather", (elems * n,), [list(range(n))])
        mat = comm_matrix.matrix_for_ops([op], n)[1:, 1:]
        for i in range(n):
            for j in range(n):
                if j in ((i + 1) % n, (i - 1) % n):
                    assert mat[i, j] > 0
                    assert mat[i, j] == pytest.approx(mat[i, (i + 1) % n])
                else:
                    assert mat[i, j] == 0

    def test_host_row_and_column(self):
        mat = np.zeros((5, 5))
        comm_matrix.add_host_transfers(mat, [
            HostTransfer("h2d", 0, 100), HostTransfer("h2d", 3, 50),
            HostTransfer("d2h", 1, 25)])
        assert mat[0, 1] == 100 and mat[0, 4] == 50 and mat[2, 0] == 25
        assert mat[1:, 1:].sum() == 0

    def test_permute_matrix_matches_pairs(self):
        op = mk_op("collective-permute", (8,), [],
                   pairs=[(0, 1), (1, 2), (2, 0)])
        mat = comm_matrix.matrix_for_ops([op], 4)
        nb = 8 * 4
        assert mat[1, 2] == nb and mat[2, 3] == nb and mat[3, 1] == nb
        assert mat.sum() == 3 * nb

    def test_all_to_all_uniform(self):
        n, elems = 4, 64
        op = mk_op("all-to-all", (elems,), [list(range(n))])
        mat = comm_matrix.matrix_for_ops([op], n)[1:, 1:]
        off_diag = mat[~np.eye(n, dtype=bool)]
        assert np.all(off_diag == off_diag[0]) and off_diag[0] > 0
        assert np.all(np.diag(mat) == 0)

    def test_multiple_groups_disjoint(self):
        op = mk_op("all-reduce", (16,), [[0, 1], [2, 3]])
        mat = comm_matrix.matrix_for_ops([op], 4)[1:, 1:]
        # no traffic between groups
        assert mat[0, 2] == mat[0, 3] == mat[1, 2] == mat[1, 3] == 0
        assert mat[2, 0] == mat[3, 0] == mat[2, 1] == mat[3, 1] == 0

    def test_per_primitive_split_sums_to_total(self):
        ops = [mk_op("all-reduce", (64,), [[0, 1, 2, 3]]),
               mk_op("all-gather", (64,), [[0, 1, 2, 3]])]
        total = comm_matrix.matrix_for_ops(ops, 4)
        per = comm_matrix.per_primitive_matrices(ops, 4)
        assert set(per) == {"all-reduce", "all-gather"}
        np.testing.assert_allclose(sum(per.values()), total)

    def test_tree_algorithm_uses_tree_edges(self):
        op = mk_op("all-reduce", (64,), [[0, 1, 2, 3, 4, 5, 6, 7]])
        ring = comm_matrix.matrix_for_ops([op], 8, algorithm="ring")
        tree = comm_matrix.matrix_for_ops([op], 8, algorithm="tree")
        assert not np.allclose(ring, tree)
        # tree root (rank 0) exchanges with children 1,2 only
        assert tree[1, 2] > 0 and tree[1, 3] > 0 and tree[1, 4] == 0


KINDS = ("all-reduce", "all-gather", "reduce-scatter",
         "collective-broadcast", "all-to-all", "collective-permute")


@st.composite
def op_streams(draw):
    """Randomized op streams over 8 devices: mixed kinds, group partitions
    of every dividing size, permute pair schedules, loop-trip weights."""
    num_devices = 8
    ops = []
    for _ in range(draw(st.integers(1, 8))):
        kind = draw(st.sampled_from(KINDS))
        elems = draw(st.integers(1, 2048))
        weight = float(draw(st.integers(1, 64)))
        if kind == "collective-permute":
            perm = draw(st.permutations(range(num_devices)))
            k = draw(st.integers(1, num_devices))
            pairs = [(perm[i], perm[(i + 1) % num_devices])
                     for i in range(k)]
            op = mk_op(kind, (elems,), [], pairs=pairs)
        else:
            gsize = draw(st.sampled_from([2, 4, 8]))
            devs = draw(st.permutations(range(num_devices)))
            groups = [sorted(devs[i:i + gsize])
                      for i in range(0, num_devices, gsize)]
            op = mk_op(kind, (elems,), groups)
        op.weight = weight
        ops.append(op)
    return ops


class TestVectorizedBuilder:
    """The COO-batched ``matrix_for_ops`` (rendered from decomposition
    schedules) must match the legacy per-op/per-edge reference loop on
    randomized op streams wherever the legacy placement is still the
    contract: no topology, or single-axis replica groups.  (Multi-axis
    single-pod groups intentionally diverge -- per-axis ring phases --
    pinned in tests/test_decompose.py.)"""

    @given(ops=op_streams(),
           algorithm=st.sampled_from(["ring", "tree", "hierarchical"]))
    @settings(max_examples=80, deadline=None)
    def test_coo_matches_loop(self, ops, algorithm):
        import warnings
        from repro.core.topology import MeshTopology
        # single-axis pods: every intra-pod group lies along ONE torus
        # axis, so per-axis decomposition never applies and the schedule
        # path must reproduce the legacy loop byte-for-byte
        topo = MeshTopology(axis_names=("pod", "data"), axis_sizes=(2, 4))
        for t in (None, topo):
            check_ops = ops
            if t is not None and algorithm == "hierarchical":
                # hierarchical a2a / cross-pod permute on a multi-pod
                # topology now genuinely decompose (intra-pod a2a +
                # pod-leader DCN exchange; leader relay); the legacy loop
                # keeps the flat placement -- the new paths' conservation
                # laws are pinned in test_decompose /
                # test_link_consistency instead
                check_ops = [op for op in ops if op.kind not in
                             ("all-to-all", "ragged-all-to-all",
                              "collective-permute")]
            with warnings.catch_warnings():
                warnings.simplefilter("ignore")
                vec = comm_matrix.matrix_for_ops(check_ops, 8, algorithm,
                                                 topo=t)
                ref = comm_matrix.matrix_for_ops_reference(
                    check_ops, 8, algorithm, topo=t)
            np.testing.assert_allclose(vec, ref, rtol=1e-12)

    @given(ops=op_streams(),
           algorithm=st.sampled_from(["ring", "tree", "hierarchical"]))
    @settings(max_examples=30, deadline=None)
    def test_edge_arrays_match_edge_tuples(self, ops, algorithm):
        """op_edge_arrays and op_edges render the same schedules: equal
        aggregate traffic per (src, dst) pair (edge order and splitting
        may differ) -- including multi-axis per-axis placements."""
        import warnings
        from repro.core.topology import MeshTopology
        topo = MeshTopology(axis_names=("pod", "data", "model"),
                            axis_sizes=(2, 2, 2))
        for t in (None, topo):
            for op in ops:
                with warnings.catch_warnings():
                    warnings.simplefilter("ignore")
                    agg_t: dict = {}
                    for s, d, b in comm_matrix.op_edges(op, algorithm, t):
                        agg_t[(s, d)] = agg_t.get((s, d), 0.0) + b
                    src, dst, val = comm_matrix.op_edge_arrays(
                        op, algorithm, t)
                agg_a: dict = {}
                for s, d, b in zip(src.tolist(), dst.tolist(),
                                   val.tolist()):
                    agg_a[(s, d)] = agg_a.get((s, d), 0.0) + b
                assert set(agg_t) == set(agg_a)
                for key in agg_t:
                    assert agg_t[key] == pytest.approx(agg_a[key])

    def test_flush_batching_boundary(self):
        """Streams larger than one flush batch accumulate identically
        (exercises the buffered-flush and the oversized-single-op paths:
        a 192-wide all-to-all alone exceeds ``_FLUSH_EDGES``)."""
        d = 192
        big = mk_op("all-to-all", (4096,), [list(range(d))])
        assert d * (d - 1) > comm_matrix._FLUSH_EDGES
        ops = [mk_op("all-reduce", (256,), [[0, 1, 2, 3]])] * 5000 + [big]
        vec = comm_matrix.matrix_for_ops(ops, d)
        ref = comm_matrix.matrix_for_ops_reference(ops, d)
        np.testing.assert_allclose(vec, ref)


class TestReporter:
    def test_heatmap_renders(self):
        from repro.core import reporter
        mat = np.random.default_rng(0).random((9, 9)) * 1e9
        txt = reporter.ascii_heatmap(mat, title="test")
        assert "test" in txt and len(txt.splitlines()) >= 10

    def test_heatmap_coarsens_large(self):
        from repro.core import reporter
        mat = np.ones((257, 257))
        txt = reporter.ascii_heatmap(mat, max_devices=32)
        assert "blocks of" in txt

    def test_csv(self):
        from repro.core import reporter
        mat = np.arange(9).reshape(3, 3).astype(float)
        csv = reporter.matrix_to_csv(mat)
        assert csv.splitlines()[0] == ",host,gpu0,gpu1"
        assert csv.splitlines()[1] == "host,0,1,2"

    def test_human_bytes(self):
        from repro.core.reporter import human_bytes
        assert human_bytes(0) == "0 B"
        assert human_bytes(1024) == "1.00 KiB"
        assert human_bytes(3.5 * 2**30) == "3.50 GiB"
