"""Export subsystem: save/load round-trip, golden CSV/JSON, Perfetto schema,
HTML dashboard structure, report cache, and the terminal reporter helpers
(re-homed from test_comm_matrix so they run without hypothesis)."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import CommReport, ReportCache, cache_key, export, monitor_fn
from repro.core.events import CollectiveOp, HostTransfer, Shape


@pytest.fixture(scope="module")
def report(mesh8):
    def step(w, x):
        return ((x @ w) ** 2).mean()

    return monitor_fn(
        jax.value_and_grad(step),
        jax.ShapeDtypeStruct((256, 256), jnp.float32),
        jax.ShapeDtypeStruct((128, 256), jnp.float32),
        mesh=mesh8, name="toy",
        in_shardings=(NamedSharding(mesh8, P(None, "model")),
                      NamedSharding(mesh8, P("data", None))))


def hand_report() -> CommReport:
    """A fully hand-built report with known numbers (golden-file basis)."""
    op = CollectiveOp(kind="all-reduce", name="%ar.1",
                      result_shapes=[Shape("f32", (256,))],
                      replica_groups=[[0, 1, 2, 3]], op_name="psum")
    from repro.core import comm_matrix, hlo_parser
    mat = comm_matrix.matrix_for_ops([op], 4)
    return CommReport(
        name="golden", num_devices=4, traced=[], compiled_ops=[op],
        traced_summary={}, compiled_summary=hlo_parser.summarize([op]),
        matrix=mat,
        per_primitive=comm_matrix.per_primitive_matrices([op], 4),
        cost={"flops": 1.0}, memory_stats=None,
        trace_seconds=0.01, compile_seconds=0.02,
        host_transfers=[HostTransfer("h2d", 0, 64)])


class TestRoundTrip:
    pytestmark = pytest.mark.compile  # module fixture compiles

    def test_save_load_lossless(self, report, tmp_path):
        p = str(tmp_path / "r.json")
        report.save(p)
        back = CommReport.load(p)
        assert back.name == report.name
        assert back.num_devices == report.num_devices
        assert back.algorithm == report.algorithm
        np.testing.assert_allclose(back.matrix, report.matrix)
        assert set(back.per_primitive) == set(report.per_primitive)
        for k in back.per_primitive:
            np.testing.assert_allclose(back.per_primitive[k],
                                       report.per_primitive[k])
        assert back.compiled_summary == json.loads(
            json.dumps(report.compiled_summary))
        assert len(back.compiled_ops) == len(report.compiled_ops)
        for a, b in zip(back.compiled_ops, report.compiled_ops):
            assert (a.kind, a.payload_bytes, a.group_size, a.weight) == \
                (b.kind, b.payload_bytes, b.group_size, b.weight)
        assert len(back.traced) == len(report.traced)
        assert back.topo.axis_names == report.topo.axis_names
        # a loaded report renders and re-exports like a fresh one
        assert "comm matrix" in back.render()

    def test_legacy_keys_preserved(self, report, tmp_path):
        """save() output stays a superset of the old dump_report layout."""
        p = str(tmp_path / "r.json")
        report.save(p)
        d = json.loads(open(p).read())
        assert {"name", "summary", "ops", "matrix",
                "traced_summary", "num_devices"} <= set(d)
        assert d["schema"] == export.serialize.SCHEMA
        assert len(d["matrix"]) == report.num_devices + 1
        # old-style op entries keep their repr'd shapes
        assert all("shapes" in op for op in d["ops"])

    def test_view_rebinding_no_recompile(self, report):
        """Algorithm comparison is a lazy view binding; ``rebound`` (the
        sweep derive path) snapshots it into a sibling report."""
        tv = report.view("tree")
        assert not np.allclose(tv.matrix, report.matrix)
        tree = report.rebound("tree")
        assert tree.algorithm == "tree"
        assert tree.compiled_ops is report.compiled_ops or \
            len(tree.compiled_ops) == len(report.compiled_ops)
        np.testing.assert_allclose(tree.matrix, tv.matrix)
        # same payloads, different wire model
        assert sum(r["payload_bytes"]
                   for r in tree.compiled_summary.values()) == \
            sum(r["payload_bytes"] for r in report.compiled_summary.values())
        # the deprecated eager spelling is gone
        assert not hasattr(report, "with_algorithm")


class TestSchemaSections:
    """Physical-link + overlap sections (since schema v3), the v4 phase
    section, and v1/v2/v3 backward-compat loads."""

    pytestmark = pytest.mark.compile  # module fixture compiles

    def test_v6_writes_link_sections(self, report, tmp_path):
        p = str(tmp_path / "v6.json")
        report.save(p)
        d = json.loads(open(p).read())
        assert d["schema"] == "repro.comm_report.v9"
        assert len(d["link_matrix"]) == report.num_devices + 1
        assert d["links"], "per-link rows missing"
        for row in d["links"]:
            assert {"kind", "src", "dst", "axis", "bytes", "bandwidth",
                    "seconds"} <= set(row)
            assert row["kind"] in ("ici", "dcn")
        assert "ici" in d["link_summary"]

    def test_v6_writes_phase_section(self, report, tmp_path):
        """monitor_fn is a single-phase session: its snapshot carries one
        'main' phase record and phase tags on every op."""
        p = str(tmp_path / "v6.json")
        report.save(p)
        d = json.loads(open(p).read())
        assert [ph["name"] for ph in d["phases"]] == ["main"]
        assert d["phases"][0]["num_captures"] == 1
        assert all(op["phase"] == "main" for op in d["ops"])

    def test_v6_writes_overlap_sections(self, report, tmp_path):
        p = str(tmp_path / "v6.json")
        report.save(p)
        d = json.loads(open(p).read())
        assert "ici" in d["link_tiers"]
        assert {"bytes", "busy_seconds"} <= set(d["link_tiers"]["ici"])
        ov = d["overlap"]
        assert {"collective_ici_s", "collective_dcn_s",
                "collective_overlap_s", "collective_serial_s"} <= set(ov)
        assert ov["collective_overlap_s"] <= \
            ov["collective_serial_s"] + 1e-15
        assert ov["collective_serial_s"] == pytest.approx(
            ov["collective_ici_s"] + ov["collective_dcn_s"])

    @pytest.mark.parametrize("old_schema", ["repro.comm_report.v1",
                                            "repro.comm_report.v2",
                                            "repro.comm_report.v3",
                                            "repro.comm_report.v4",
                                            "repro.comm_report.v5",
                                            "repro.comm_report.v6",
                                            "repro.comm_report.v7"])
    def test_old_file_loads_and_rederives_links(self, report, tmp_path,
                                                old_schema):
        """Files written by previous schemas (no link/overlap/phase/
        schedule sections) load fine; the derived views recompute from
        ops+topo."""
        p = str(tmp_path / "old.json")
        report.save(p)
        d = json.loads(open(p).read())
        for key in ("links", "link_matrix", "link_summary", "link_tiers",
                    "overlap", "phases", "hlo_gz", "schedules"):
            d.pop(key, None)
        for op in d["ops"]:
            op.pop("phase", None)
        d["schema"] = old_schema
        with open(p, "w") as f:
            json.dump(d, f)
        back = CommReport.load(p)
        lu = back.link_utilization()
        assert lu is not None and lu.total_bytes() > 0
        np.testing.assert_allclose(back.link_matrix(), report.link_matrix())
        assert back.collective_seconds_split() == \
            report.collective_seconds_split()

    def test_unknown_schema_rejected(self, report, tmp_path):
        p = str(tmp_path / "bad.json")
        report.save(p)
        d = json.loads(open(p).read())
        d["schema"] = "repro.comm_report.v99"
        with open(p, "w") as f:
            json.dump(d, f)
        with pytest.raises(ValueError):
            CommReport.load(p)

    def test_topoless_report_has_no_link_view(self, tmp_path):
        rep = hand_report()          # built without a topology
        p = str(tmp_path / "t.json")
        rep.save(p)
        d = json.loads(open(p).read())
        assert "links" not in d
        assert CommReport.load(p).link_utilization() is None

    def test_html_link_panel(self, report, tmp_path):
        p = str(tmp_path / "links.html")
        export.export_html(report, p)
        text = open(p).read()
        assert "physical links" in text
        assert "link kind" in text


def sparse_hand_report() -> CommReport:
    """The hand-built golden report in sparse (COO) form, with a topology
    so the link section is exercised too."""
    from repro.core import comm_matrix, hlo_parser
    from repro.core.topology import MeshTopology
    op = CollectiveOp(kind="all-reduce", name="%ar.1",
                      result_shapes=[Shape("f32", (256,))],
                      replica_groups=[[0, 1, 2, 3]], op_name="psum")
    return CommReport(
        name="golden_sparse", num_devices=4, traced=[], compiled_ops=[op],
        traced_summary={}, compiled_summary=hlo_parser.summarize([op]),
        matrix=comm_matrix.add_host_transfers(
            comm_matrix.matrix_for_ops([op], 4, sparse=True),
            [HostTransfer("h2d", 0, 64)]),
        per_primitive=comm_matrix.per_primitive_matrices([op], 4,
                                                         sparse=True),
        cost={"flops": 1.0}, memory_stats=None,
        trace_seconds=0.01, compile_seconds=0.02,
        topo=MeshTopology(axis_names=("data",), axis_sizes=(4,)),
        host_transfers=[HostTransfer("h2d", 0, 64)])


class TestSparseSerialization:
    """Schema v6: sparse matrices round-trip as COO dicts, never dense."""

    def test_sparse_round_trip(self, tmp_path):
        from repro.core.sparse import is_sparse
        rep = sparse_hand_report()
        p = str(tmp_path / "s.json")
        rep.save(p)
        back = CommReport.load(p)
        assert is_sparse(back.matrix)
        np.testing.assert_array_equal(back.matrix.to_dense(),
                                      rep.matrix.to_dense())
        assert set(back.per_primitive) == set(rep.per_primitive)
        for k in back.per_primitive:
            assert is_sparse(back.per_primitive[k])
            np.testing.assert_array_equal(
                back.per_primitive[k].to_dense(),
                rep.per_primitive[k].to_dense())
        assert back.compiled_summary == json.loads(
            json.dumps(rep.compiled_summary))

    def test_sparse_file_layout(self, tmp_path):
        """The on-disk form is the COO dict -- O(nnz), not a nested list --
        and the derived link section drops its dense matrix."""
        rep = sparse_hand_report()
        p = str(tmp_path / "s.json")
        rep.save(p)
        d = json.loads(open(p).read())
        assert d["schema"] == "repro.comm_report.v9"
        assert d["matrix"]["format"] == "coo"
        assert len(d["matrix"]["src"]) == rep.matrix.nnz
        assert all(m["format"] == "coo"
                   for m in d["per_primitive"].values())
        assert "link_matrix" not in d
        assert d["links"] and all(r["bytes"] > 0 for r in d["links"])

    def test_dense_report_stays_dense(self, tmp_path):
        """A dense report's file keeps the v1...v5 nested-list spelling."""
        rep = hand_report()
        p = str(tmp_path / "d.json")
        rep.save(p)
        d = json.loads(open(p).read())
        assert isinstance(d["matrix"], list)
        back = CommReport.load(p)
        assert isinstance(back.matrix, np.ndarray)

    def test_unknown_matrix_format_rejected(self, tmp_path):
        rep = sparse_hand_report()
        p = str(tmp_path / "s.json")
        rep.save(p)
        d = json.loads(open(p).read())
        d["matrix"]["format"] = "csr"
        with open(p, "w") as f:
            json.dump(d, f)
        with pytest.raises(ValueError, match="unknown matrix format"):
            CommReport.load(p)

    def test_loaded_sparse_view_stays_sparse(self, tmp_path):
        """CommReport.view on a loaded sparse snapshot keeps derived
        bindings sparse (no dense rebuild on algorithm rebind)."""
        from repro.core.sparse import is_sparse
        rep = sparse_hand_report()
        p = str(tmp_path / "s.json")
        rep.save(p)
        back = CommReport.load(p)
        assert back.view().use_sparse
        assert is_sparse(back.view("tree").matrix)

    def test_sparse_matrix_csv_long_form(self, tmp_path):
        rep = sparse_hand_report()
        p = str(tmp_path / "m.csv")
        export.export_matrix_csv(rep, p)
        lines = open(p).read().strip().splitlines()
        assert lines[0] == "src,dst,bytes"
        assert len(lines) == 1 + rep.matrix.nnz
        assert any(line.startswith("host,gpu0,") for line in lines)

    def test_sparse_html_renders(self, tmp_path):
        rep = sparse_hand_report()
        p = str(tmp_path / "s.html")
        export.export_html(rep, p)
        text = open(p).read()
        assert "golden_sparse" in text and "physical links" in text

    def test_sparse_heatmap_renders(self):
        out = sparse_hand_report().heatmap()
        assert "max cell" in out


class TestGolden:
    """Exact expected artifacts for a hand-built 4-device all-reduce."""

    def test_golden_csv(self, tmp_path):
        p = str(tmp_path / "g.csv")
        export.export_summary_csv(hand_report(), p)
        # ring all-reduce of S=1024B over 4 ranks: 2*(4-1)/4*1024 = 1536 B
        # per rank -> 6144 B on the wire
        assert open(p).read() == (
            "config,mesh,algorithm,num_devices,primitive,calls,"
            "payload_bytes,wire_bytes\n"
            "golden,4dev,ring,4,all-reduce,1,1024,6144.0\n")

    def test_golden_matrix_csv(self, tmp_path):
        p = str(tmp_path / "m.csv")
        export.export_matrix_csv(hand_report(), p)
        lines = open(p).read().splitlines()
        assert lines[0] == ",host,gpu0,gpu1,gpu2,gpu3"
        # bidirectional ring: edge 0->1 carries half the 1536 B per-rank
        # wire bytes, the other half flows 0->3 (col order: name, host,
        # gpu0..gpu3 -> gpu1 is index 3, gpu3 is index 5)
        assert lines[1] == "host,0,0,0,0,0"
        assert lines[2].split(",")[3] == "768"
        assert lines[2].split(",")[5] == "768"

    def test_sweep_document_loads_as_list(self, tmp_path):
        p = str(tmp_path / "sweep.json")
        export.export_comparison_json([hand_report(), hand_report()], p)
        reports = export.load_json_reports(p)
        assert len(reports) == 2 and reports[0].name == "golden"
        with pytest.raises(ValueError):
            export.load_json(p)   # single-report loader refuses multi-docs

    def test_golden_json_roundtrip(self, tmp_path):
        p = str(tmp_path / "g.json")
        rep = hand_report()
        rep.save(p)
        back = CommReport.load(p)
        assert back.compiled_summary["all-reduce"]["calls"] == 1
        assert back.matrix.sum() == rep.matrix.sum() == pytest.approx(6144)
        assert back.host_transfers[0].nbytes == 64


class TestPerfetto:
    pytestmark = pytest.mark.compile  # module fixture compiles

    def test_chrome_trace_schema(self, report):
        doc = export.chrome_trace([report, report.rebound("tree")])
        assert set(doc) >= {"traceEvents", "displayTimeUnit"}
        events = doc["traceEvents"]
        assert events, "no events emitted"
        for e in events:
            assert {"name", "ph", "pid", "tid"} <= set(e)
            assert e["ph"] in ("X", "M")
            if e["ph"] == "X":
                assert e["ts"] >= 0 and e["dur"] > 0
                assert e["cat"] in ("collective", "tier", "phase")
                if e["cat"] == "collective":
                    assert e["args"]["payload_bytes"] >= 0
            else:
                assert "name" in e["args"]
        # each track's spans are laid out in non-decreasing start order
        # (tracks themselves may overlap: that is the per-tier pipelining)
        for pid in {e["pid"] for e in events}:
            for tid in {e["tid"] for e in events if e["pid"] == pid}:
                ts = [e["ts"] for e in events
                      if e["pid"] == pid and e["tid"] == tid
                      and e["ph"] == "X"]
                assert ts == sorted(ts)
        json.dumps(doc)  # must be JSON-serializable as-is

    def test_tier_lanes_from_schedules(self, report):
        """Overlap-aware per-tier lanes: phase spans render straight from
        the decomposition schedules on dedicated ICI / DCN tracks."""
        events = export.trace_events(report)
        lane_meta = {e["args"]["name"]: e["tid"] for e in events
                     if e["ph"] == "M" and e["tid"] > 0}
        assert "ici lane" in lane_meta and "dcn lane" in lane_meta
        tiers = [e for e in events if e.get("cat") == "tier"]
        assert tiers, "no tier-lane spans emitted"
        for e in tiers:
            assert e["args"]["tier"] in ("ici", "dcn")
            assert e["args"]["structure"] in ("ring", "tree", "a2a",
                                              "pairs")
            assert e["args"]["bytes_per_rank"] >= 0
        # mesh8 is single-pod: every phase must ride the ICI lane
        assert {e["tid"] for e in tiers} == {lane_meta["ici lane"]}
        # an op's span covers its phases
        ops = [e for e in events if e.get("cat") == "collective"]
        assert ops and all(e["dur"] > 0 for e in ops)

    def test_one_process_per_report(self, report):
        doc = export.chrome_trace([report, report])
        assert len({e["pid"] for e in doc["traceEvents"]}) == 2


class TestHtml:
    pytestmark = pytest.mark.compile  # module fixture compiles

    def test_dashboard_structure(self, report, tmp_path):
        p = str(tmp_path / "d.html")
        export.export_html([report, report.rebound("tree")], p)
        html_text = open(p).read()
        assert html_text.count("<h2>") == 2
        assert "td class='q" in html_text          # ramp-bucketed cells
        assert "prefers-color-scheme: dark" in html_text
        assert "raw values" in html_text           # table view fallback
        assert "legend" in html_text

    def test_large_matrix_coarsens(self):
        rep = hand_report()
        rep.matrix = np.ones((257, 257))
        rep.per_primitive = {}
        html_text = export.render_dashboard(rep)
        assert "device blocks of" in html_text


class TestCache:
    def test_key_sensitivity(self):
        base = cache_key("a/v1", "4x2:data,model", "ring", jax_version="1")
        assert cache_key("a/v1", "4x2:data,model", "ring",
                         jax_version="1") == base
        assert cache_key("a/v2", "4x2:data,model", "ring",
                         jax_version="1") != base
        assert cache_key("a/v1", "8:data", "ring", jax_version="1") != base
        assert cache_key("a/v1", "4x2:data,model", "tree",
                         jax_version="1") != base
        assert cache_key("a/v1", "4x2:data,model", "ring",
                         jax_version="2") != base

    def test_put_get_roundtrip(self, tmp_path):
        cache = ReportCache(root=str(tmp_path / "cache"))
        key = cache_key("golden/v1", "4:data", "ring")
        assert cache.get(key) is None
        cache.put(key, hand_report(), meta={"config": "golden"})
        back = cache.get(key)
        assert back is not None and back.name == "golden"
        assert back.meta["config"] == "golden"
        assert cache.hits == 1 and cache.misses == 1
        assert len(cache.entries()) == 1
        assert cache.clear() == 1 and cache.entries() == []

    def test_corrupt_entry_is_miss(self, tmp_path):
        cache = ReportCache(root=str(tmp_path / "cache"))
        key = cache_key("golden/v1", "4:data", "ring")
        cache.put(key, hand_report())
        with open(cache.path_for(key), "w") as f:
            f.write("{not json")
        assert cache.get(key) is None

    def test_phase_is_key_neutral(self):
        """Satellite: a sweep cell keyed with phase= addresses the SAME
        entry as the whole session -- phases are views, not compiles."""
        base = cache_key("a/v1", "4x2:data,model", "ring", jax_version="1")
        assert cache_key("a/v1", "4x2:data,model", "ring", jax_version="1",
                         phase="decode") == base
        assert cache_key("a/v1", "4x2:data,model", "ring", jax_version="1",
                         phase="prefill") == base

    def test_phase_aware_get_reuses_session_snapshot(self, tmp_path):
        """A phase-keyed lookup hands back the cached whole-session
        snapshot (per-phase artifacts derive lazily); a phase the snapshot
        never captured is a miss."""
        from repro.core.events import PhaseRecord
        rep = hand_report()
        rep.phases = [PhaseRecord(name="prefill", num_captures=1),
                      PhaseRecord(name="decode", num_captures=1)]
        rep.compiled_ops[0].phase = "decode"
        cache = ReportCache(root=str(tmp_path / "cache"))
        key = cache_key("serve/v1", "4:data", "ring",
                        phase="decode")      # == the session's key
        cache.put(key, rep)
        back = cache.get(key, phase="decode")
        assert back is not None
        assert back.phase_names() == ["prefill", "decode"]
        # the decode view derives from the snapshot, nothing recaptured
        assert back.view(phase="decode").summary != {}
        assert back.view(phase="prefill").summary == {}
        # a phase the session never captured must miss
        assert cache.get(key, phase="bwd") is None


class TestReporter:
    """Terminal-reporter coverage (moved from test_comm_matrix, which now
    skips entirely when hypothesis is absent)."""

    def test_heatmap_renders(self):
        from repro.core import reporter
        mat = np.random.default_rng(0).random((9, 9)) * 1e9
        txt = reporter.ascii_heatmap(mat, title="test")
        assert "test" in txt and len(txt.splitlines()) >= 10

    def test_heatmap_coarsens_large(self):
        from repro.core import reporter
        mat = np.ones((257, 257))
        txt = reporter.ascii_heatmap(mat, max_devices=32)
        assert "blocks of" in txt

    def test_coarsen_preserves_total(self):
        from repro.core import reporter
        mat = np.random.default_rng(1).random((101, 101))
        small, block = reporter.coarsen_matrix(mat, max_devices=16)
        assert block > 1 and small.shape[0] <= 17 + 1
        assert small.sum() == pytest.approx(mat.sum())

    def test_csv(self):
        from repro.core import reporter
        mat = np.arange(9).reshape(3, 3).astype(float)
        csv = reporter.matrix_to_csv(mat)
        assert csv.splitlines()[0] == ",host,gpu0,gpu1"
        assert csv.splitlines()[1] == "host,0,1,2"

    def test_human_bytes(self):
        from repro.core.reporter import human_bytes
        assert human_bytes(0) == "0 B"
        assert human_bytes(1024) == "1.00 KiB"
        assert human_bytes(3.5 * 2**30) == "3.50 GiB"
