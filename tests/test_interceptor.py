"""Trace-time interception (the LD_PRELOAD analogue)."""
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import CollectiveInterceptor, intercept
from repro.compat import shard_map

import pytest

pytestmark = pytest.mark.compile   # whole module drives XLA compiles


def _traced_program(mesh):
    def f(x):
        y = jax.lax.psum(x, "data")
        z = jax.lax.all_gather(y, "model")
        w = jax.lax.ppermute(x, "data", [(i, (i + 1) % 4) for i in range(4)])
        return y.sum() + z.sum() + w.sum()

    return jax.jit(shard_map(f, mesh=mesh, in_specs=P("data"),
                                 out_specs=P(), check_vma=False))


class TestInterceptor:
    def test_captures_collectives(self, mesh8):
        with CollectiveInterceptor(mesh=mesh8) as icpt:
            _traced_program(mesh8).lower(jnp.ones((8, 16)))
        prims = [e.primitive for e in icpt.events]
        assert "psum" in prims and "all_gather" in prims \
            and "ppermute" in prims

    def test_axis_sizes_resolved(self, mesh8):
        with CollectiveInterceptor(mesh=mesh8) as icpt:
            _traced_program(mesh8).lower(jnp.ones((8, 16)))
        psum = [e for e in icpt.events if e.primitive == "psum"][0]
        assert psum.axis_size == 4      # data axis
        ag = [e for e in icpt.events if e.primitive == "all_gather"][0]
        assert ag.axis_size == 2        # model axis

    def test_payload_bytes(self, mesh8):
        with CollectiveInterceptor(mesh=mesh8) as icpt:
            _traced_program(mesh8).lower(jnp.ones((8, 16)))
        psum = [e for e in icpt.events if e.primitive == "psum"][0]
        # per-shard (2,16) f32
        assert psum.payload_bytes == 2 * 16 * 4

    def test_no_capture_outside_context(self, mesh8):
        prog = _traced_program(mesh8)
        with CollectiveInterceptor(mesh=mesh8) as icpt:
            pass
        prog.lower(jnp.ones((8, 16)))  # traced after exit
        assert icpt.events == []

    def test_nested_interceptors_both_see(self, mesh8):
        with CollectiveInterceptor(mesh=mesh8) as outer:
            with CollectiveInterceptor(mesh=mesh8) as inner:
                _traced_program(mesh8).lower(jnp.ones((8, 16)))
        assert len(outer.events) == len(inner.events) > 0

    def test_numerics_unchanged(self, mesh8):
        x = jnp.arange(128.0).reshape(8, 16)
        prog = _traced_program(mesh8)
        expected = prog(x)
        with intercept(mesh8):
            got = jax.jit(shard_map(
                lambda v: jax.lax.psum(v, "data").sum(), mesh=mesh8,
                in_specs=P("data"), out_specs=P(), check_vma=False))(x)
        assert jnp.isfinite(got)
        assert jnp.allclose(prog(x), expected)

    def test_summary_uses_nccl_names(self, mesh8):
        with CollectiveInterceptor(mesh=mesh8) as icpt:
            _traced_program(mesh8).lower(jnp.ones((8, 16)))
        s = icpt.summary()
        assert "AllReduce" in s and "AllGather" in s and "SendRecv" in s
        assert s["AllReduce"]["calls"] >= 1
