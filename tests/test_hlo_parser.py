"""HLO collective parsing: synthetic lines + a real compiled module."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import hlo_parser
from repro.core.hlo_parser import (HLOParseError, parse_hlo_collectives,
                                   parse_replica_groups)
from repro.compat import shard_map


class TestSyntheticLines:
    def test_explicit_groups(self):
        line = ("%psum.7 = f32[2,8]{1,0} all-reduce(%param.1), channel_id=1, "
                "replica_groups={{0,2,4,6},{1,3,5,7}}, "
                "use_global_device_ids=true, to_apply=%region_0.0")
        (op,) = parse_hlo_collectives(line)
        assert op.kind == "all-reduce"
        assert op.replica_groups == [[0, 2, 4, 6], [1, 3, 5, 7]]
        assert op.group_size == 4 and op.num_groups == 2
        assert op.result_shapes[0].bytes == 2 * 8 * 4

    def test_iota_groups(self):
        assert parse_replica_groups("replica_groups=[4,2]<=[8]") == \
            [[0, 1], [2, 3], [4, 5], [6, 7]]

    def test_iota_groups_transposed(self):
        got = parse_replica_groups("replica_groups=[2,4]<=[4,2]T(1,0)")
        assert got == [[0, 2, 4, 6], [1, 3, 5, 7]]

    def test_collective_permute_pairs(self):
        line = ("%cp = f32[4]{0} collective-permute(%p), channel_id=2, "
                "source_target_pairs={{0,1},{1,2},{2,3},{3,0}}")
        (op,) = parse_hlo_collectives(line)
        assert op.source_target_pairs == [(0, 1), (1, 2), (2, 3), (3, 0)]
        assert op.wire_bytes_total() == 4 * 16

    def test_variadic_all_reduce(self):
        line = ("%ar = (f32[10]{0}, f32[512,10]{1,0}) all-reduce(%a, %b), "
                "replica_groups={{0,1,2,3}}, to_apply=%sum")
        (op,) = parse_hlo_collectives(line)
        assert op.result_bytes == (10 + 512 * 10) * 4

    def test_reduce_scatter_payload(self):
        line = ("%rs = f32[16]{0} reduce-scatter(%x), "
                "replica_groups={{0,1,2,3}}, dimensions={0}, to_apply=%sum")
        (op,) = parse_hlo_collectives(line)
        # local result is S/N -> payload is full S
        assert op.payload_bytes == 16 * 4 * 4

    def test_non_collective_lines_ignored(self):
        hlo = """
        %dot.1 = f32[8,8]{1,0} dot(%a, %b), lhs_contracting_dims={1}
        %add.2 = f32[8]{0} add(%c, %d)
        """
        assert parse_hlo_collectives(hlo) == []

    def test_async_start_counted_once(self):
        hlo = ("%ag-start = (f32[4]{0}, f32[16]{0}) all-gather-start(%x), "
               "replica_groups={{0,1,2,3}}, dimensions={0}\n"
               "%ag-done = f32[16]{0} all-gather-done(%ag-start)")
        ops = parse_hlo_collectives(hlo)
        assert len(ops) == 1


class TestHardening:
    """Malformed attributes raise (with the op text); the channel /
    global-ids / operand attributes round-trip."""

    def test_ragged_explicit_groups_raise_with_op_text(self):
        line = ("%ar.9 = f32[8]{0} all-reduce(%p), "
                "replica_groups={{0,1,2},{3,4}}, to_apply=%sum")
        with pytest.raises(HLOParseError, match=r"ragged.*%ar\.9"):
            parse_replica_groups(line)
        with pytest.raises(HLOParseError):
            parse_hlo_collectives(line)

    def test_non_tiling_iota_raises(self):
        with pytest.raises(HLOParseError, match="do not tile"):
            parse_replica_groups("replica_groups=[4,3]<=[8]")

    def test_bad_iota_transpose_raises(self):
        with pytest.raises(HLOParseError, match="not a permutation"):
            parse_replica_groups("replica_groups=[2,4]<=[4,2]T(0,2)")

    def test_channel_and_global_ids_parsed(self):
        line = ("%ar = f32[8]{0} all-reduce(%p), channel_id=5, "
                "replica_groups={{0,1,2,3}}, use_global_device_ids=true, "
                "to_apply=%sum")
        (op,) = parse_hlo_collectives(line)
        assert op.channel_id == 5
        assert op.use_global_device_ids is True
        (plain,) = parse_hlo_collectives(
            "%ar = f32[8]{0} all-reduce(%p), replica_groups={{0,1}}, "
            "to_apply=%sum")
        assert plain.channel_id is None
        assert plain.use_global_device_ids is False

    def test_operand_names_plain(self):
        line = ("%ar = (f32[10]{0}, f32[4]{0}) all-reduce(%a, %b), "
                "replica_groups={{0,1,2,3}}, to_apply=%sum")
        (op,) = parse_hlo_collectives(line)
        assert op.operand_names == ["a", "b"]

    def test_operand_names_typed_and_tuple_shaped(self):
        """jax 0.4.x prints typed operands whose tuple shapes and layouts
        contain commas/parens -- naive splitting would yield garbage."""
        line = ("%ar = (f32[10]{0}, (s32[], f32[4])) all-reduce("
                "f32[10]{1,0} %a, (s32[], f32[4]) %b.2), "
                "replica_groups={{0,1,2,3}}, to_apply=%sum")
        (op,) = parse_hlo_collectives(line)
        assert op.operand_names == ["a", "b.2"]

    def test_async_start_operands_parsed(self):
        hlo = ("%ag-start = (f32[4]{0}, f32[16]{0}) all-gather-start(%x), "
               "replica_groups={{0,1,2,3}}, dimensions={0}\n"
               "%ag-done = f32[16]{0} all-gather-done(%ag-start)")
        (op,) = parse_hlo_collectives(hlo)
        assert op.operand_names == ["x"]


class TestRealModule:
    pytestmark = pytest.mark.compile

    def test_shard_map_collectives_roundtrip(self, mesh8):
        def f(x):
            y = jax.lax.psum(x, "data")
            z = jax.lax.all_gather(y, "model")
            return z.sum()

        g = jax.jit(shard_map(f, mesh=mesh8, in_specs=P("data"),
                                  out_specs=P(), check_vma=False))
        hlo = g.lower(jnp.ones((8, 16))).compile().as_text()
        ops = parse_hlo_collectives(hlo)
        kinds = {op.kind for op in ops}
        assert "all-reduce" in kinds and "all-gather" in kinds
        ar = [op for op in ops if op.kind == "all-reduce"][0]
        assert ar.group_size == 4  # data axis
        summary = hlo_parser.summarize(ops)
        assert summary["all-reduce"]["calls"] >= 1
        assert summary["all-reduce"]["payload_bytes"] > 0

    def test_compiler_inserted_collectives_visible(self, mesh8):
        """jit-auto-sharding emits collectives the app never wrote."""
        from jax.sharding import NamedSharding

        def step(w, x):
            return ((x @ w) ** 2).mean()

        ws = NamedSharding(mesh8, P(None, "model"))
        xs = NamedSharding(mesh8, P("data", None))
        lowered = jax.jit(jax.grad(step), in_shardings=(ws, xs)).lower(
            jax.ShapeDtypeStruct((64, 64), jnp.float32),
            jax.ShapeDtypeStruct((32, 64), jnp.float32))
        ops = parse_hlo_collectives(lowered.compile().as_text())
        assert ops, "expected compiler-inserted collectives"
