"""Pallas kernels vs pure-jnp oracles (interpret mode, shape/dtype sweeps)."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.rglru.ops import rglru_scan
from repro.kernels.rglru.ref import rglru_ref
from repro.kernels.rmsnorm.ops import rmsnorm
from repro.kernels.rmsnorm.ref import rmsnorm_ref

pytestmark = pytest.mark.compile   # whole module drives XLA compiles

RNG = jax.random.PRNGKey(0)


def rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape).astype(dtype)


class TestFlashAttention:
    @pytest.mark.parametrize("b,sq,h,kvh,dh,causal,window", [
        (2, 256, 4, 2, 64, True, 0),      # GQA causal
        (1, 128, 4, 4, 32, True, 0),      # MHA
        (2, 256, 4, 1, 64, True, 64),     # MQA + sliding window
        (1, 512, 2, 2, 128, False, 0),    # bidirectional
        (1, 256, 8, 2, 128, True, 128),   # GQA + window
    ])
    def test_matches_ref(self, b, sq, h, kvh, dh, causal, window):
        ks = jax.random.split(jax.random.PRNGKey(hash((b, sq, h)) % 2**31), 3)
        q = rand(ks[0], (b, sq, h, dh))
        k = rand(ks[1], (b, sq, kvh, dh))
        v = rand(ks[2], (b, sq, kvh, dh))
        out = flash_attention(q, k, v, causal=causal, window=window,
                              block_q=128, block_k=128, interpret=True)
        ref = attention_ref(q, k, v, causal=causal, window=window)
        assert jnp.max(jnp.abs(out - ref)) < 2e-5

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        ks = jax.random.split(RNG, 3)
        q = rand(ks[0], (1, 128, 2, 64), dtype)
        k = rand(ks[1], (1, 128, 2, 64), dtype)
        v = rand(ks[2], (1, 128, 2, 64), dtype)
        out = flash_attention(q, k, v, interpret=True, block_q=128,
                              block_k=128)
        ref = attention_ref(q, k, v)
        tol = 2e-5 if dtype == jnp.float32 else 2e-2
        assert out.dtype == dtype
        assert jnp.max(jnp.abs(out.astype(jnp.float32)
                               - ref.astype(jnp.float32))) < tol

    def test_block_size_independence(self):
        ks = jax.random.split(RNG, 3)
        q = rand(ks[0], (1, 256, 2, 32))
        k = rand(ks[1], (1, 256, 2, 32))
        v = rand(ks[2], (1, 256, 2, 32))
        o1 = flash_attention(q, k, v, block_q=64, block_k=64, interpret=True)
        o2 = flash_attention(q, k, v, block_q=128, block_k=256,
                             interpret=True)
        assert jnp.max(jnp.abs(o1 - o2)) < 2e-5

    def test_xla_chunked_path_matches(self):
        from repro.models.attention import chunked_attention
        ks = jax.random.split(RNG, 3)
        q = rand(ks[0], (2, 256, 4, 32))
        k = rand(ks[1], (2, 256, 2, 32))
        v = rand(ks[2], (2, 256, 2, 32))
        out = chunked_attention(q, k, v, q_chunk=64)
        ref = attention_ref(q, k, v)
        assert jnp.max(jnp.abs(out - ref)) < 2e-5


class TestRGLRU:
    @pytest.mark.parametrize("b,s,d", [(2, 64, 128), (1, 256, 256),
                                       (3, 128, 384)])
    def test_pallas_matches_ref(self, b, s, d):
        ks = jax.random.split(jax.random.PRNGKey(d), 3)
        x = rand(ks[0], (b, s, d))
        la = -jax.nn.softplus(rand(ks[1], (b, s, d)))
        h0 = rand(ks[2], (b, d))
        out = rglru_scan(x, la, h0, force="pallas_interpret", seq_chunk=64)
        ref = rglru_ref(x, la, h0)
        assert jnp.max(jnp.abs(out - ref)) < 1e-4

    def test_xla_associative_matches_ref(self):
        ks = jax.random.split(RNG, 3)
        x = rand(ks[0], (2, 128, 64))
        la = -jax.nn.softplus(rand(ks[1], (2, 128, 64)))
        h0 = rand(ks[2], (2, 64))
        out = rglru_scan(x, la, h0, force="xla")
        ref = rglru_ref(x, la, h0)
        assert jnp.max(jnp.abs(out - ref)) < 1e-4

    def test_chunked_state_carry(self):
        """Sequence chunking through h0 must be exact."""
        ks = jax.random.split(RNG, 2)
        x = rand(ks[0], (1, 128, 128))
        la = -jnp.abs(rand(ks[1], (1, 128, 128))) * 0.2
        full = rglru_scan(x, la, force="pallas_interpret", seq_chunk=128)
        chunked = rglru_scan(x, la, force="pallas_interpret", seq_chunk=32)
        assert jnp.max(jnp.abs(full - chunked)) < 1e-5


class TestRMSNorm:
    @pytest.mark.parametrize("shape,dtype", [
        ((4, 64, 128), jnp.float32),
        ((2, 32, 256), jnp.bfloat16),
        ((8, 512), jnp.bfloat16),
        ((16, 8, 384), jnp.float32),
    ])
    def test_matches_ref_exactly(self, shape, dtype):
        ks = jax.random.split(jax.random.PRNGKey(shape[-1]), 2)
        x = rand(ks[0], shape, dtype)
        w = rand(ks[1], shape[-1:], dtype) + 1
        out = rmsnorm(x, w, force="pallas_interpret")
        ref = rmsnorm_ref(x, w)
        assert out.dtype == ref.dtype
        # identical math; <= 1 ulp of fp32 reassociation in the reduce
        assert jnp.max(jnp.abs(out.astype(jnp.float32)
                               - ref.astype(jnp.float32))) < 4e-6


class TestFlashDecode:
    """Single-token decode over a KV cache (the decode_32k hot path)."""

    @pytest.mark.parametrize("b,h,kvh,dh,L,clen,win", [
        (2, 4, 2, 64, 256, 100, 0),     # GQA, partial cache
        (1, 8, 1, 32, 128, 128, 0),     # MQA, full cache
        (2, 4, 4, 64, 256, 200, 64),    # MHA + sliding window
        (1, 2, 2, 128, 512, 37, 0),     # short cache in a long buffer
    ])
    def test_matches_ref(self, b, h, kvh, dh, L, clen, win):
        from repro.kernels.flash_decode.kernel import flash_decode
        from repro.kernels.flash_decode.ref import decode_ref
        ks = jax.random.split(jax.random.PRNGKey(L + clen), 3)
        q = rand(ks[0], (b, h, dh))
        k = rand(ks[1], (b, L, kvh, dh))
        v = rand(ks[2], (b, L, kvh, dh))
        out = flash_decode(q, k, v, jnp.int32(clen), window=win,
                           block_k=min(128, L), interpret=True)
        ref = decode_ref(q, k, v, jnp.int32(clen), window=win)
        assert jnp.max(jnp.abs(out - ref)) < 2e-5

    def test_matches_model_decode_attention(self):
        """Kernel semantics == the model substrate's decode path."""
        from repro.kernels.flash_decode.ref import decode_ref
        from repro.models.attention import decode_attention
        ks = jax.random.split(jax.random.PRNGKey(7), 3)
        q = rand(ks[0], (2, 4, 32))
        k = rand(ks[1], (2, 64, 2, 32))
        v = rand(ks[2], (2, 64, 2, 32))
        a = decode_ref(q, k, v, jnp.int32(40))
        bq = decode_attention(q[:, None], k, v, jnp.int32(40))[:, 0]
        assert jnp.max(jnp.abs(a - bq)) < 2e-5

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        from repro.kernels.flash_decode.kernel import flash_decode
        from repro.kernels.flash_decode.ref import decode_ref
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = rand(ks[0], (1, 4, 64), dtype)
        k = rand(ks[1], (1, 128, 2, 64), dtype)
        v = rand(ks[2], (1, 128, 2, 64), dtype)
        out = flash_decode(q, k, v, jnp.int32(90), block_k=128,
                           interpret=True)
        ref = decode_ref(q, k, v, jnp.int32(90))
        tol = 2e-5 if dtype == jnp.float32 else 3e-2
        assert out.dtype == dtype
        assert jnp.max(jnp.abs(out.astype(jnp.float32)
                               - ref.astype(jnp.float32))) < tol
