"""The decomposition-schedule IR: one engine feeding placement, billing,
timing, links and timelines.

Property-based half (hypothesis, optional [test] extra): on single-axis
replica groups the schedule-derived matrices AND billing must equal the
legacy per-kind results for every kind x ring/tree/hierarchical.  Grid
half: multi-axis per-axis decomposition (the tentpole's new behavior) --
zero cross-axis transit inflation inside a pod, strictly reduced transit
bytes vs the flattened legacy ring, preserved Table-1 per-rank totals --
plus the IR's own invariants (tiers, streams, latency hops, schema-v5
summaries).
"""
import warnings

import numpy as np
import pytest

from repro.core import comm_matrix, cost_models
from repro.core.decompose import (CollectiveSchedule, CommPhase, decompose,
                                  group_phases)
from repro.core.events import CollectiveOp, Shape
from repro.core.topology import MeshTopology

KINDS = ("all-reduce", "all-gather", "reduce-scatter",
         "collective-broadcast", "all-to-all")
ALGORITHMS = ("ring", "tree", "hierarchical")

ONE_AXIS = MeshTopology(axis_names=("data",), axis_sizes=(8,))
PODS_1AXIS = MeshTopology(axis_names=("pod", "data"), axis_sizes=(2, 4))
MESH_2X2X2 = MeshTopology(axis_names=("pod", "data", "model"),
                          axis_sizes=(2, 2, 2))
MESH_4X4 = MeshTopology(axis_names=("data", "model"), axis_sizes=(4, 4))
MESH_2X2X2X2 = MeshTopology(axis_names=("pod", "x", "y", "z"),
                            axis_sizes=(2, 2, 2, 2))


def mk_op(kind, elems=256, groups=None, weight=1.0):
    op = CollectiveOp(kind=kind, name="t",
                      result_shapes=[Shape("f32", (elems,))],
                      replica_groups=groups or [list(range(8))])
    op.weight = weight
    return op


def _transit_inflation(mat, topo):
    """Extra ICI bytes the link projection charges beyond the logical
    matrix's intra-pod entries: zero iff every intra-pod edge is a single
    physical neighbour hop.  (DCN edges always charge uplink+downlink, so
    they are excluded from the comparison.)"""
    lu = comm_matrix.project_links(mat, topo)
    intra = sum(mat[i + 1, j + 1]
                for i in range(topo.num_devices)
                for j in range(topo.num_devices)
                if topo.pod_index(i) == topo.pod_index(j))
    return lu.total_bytes("ici") - intra


class TestScheduleEqualsLegacyOnSingleAxis:
    """Schedule-derived placement/billing == the legacy loop wherever
    per-axis decomposition cannot apply (the retirement contract)."""

    @pytest.mark.parametrize("kind", KINDS)
    @pytest.mark.parametrize("algorithm", ALGORITHMS)
    @pytest.mark.parametrize("topo", [None, ONE_AXIS, PODS_1AXIS],
                             ids=["none", "one_axis", "pods_1axis"])
    def test_matrix_matches_legacy(self, kind, algorithm, topo):
        op = mk_op(kind, weight=3.0)
        nd = 8
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            new = comm_matrix.matrix_for_ops([op], nd, algorithm, topo=topo)
            ref = comm_matrix.matrix_for_ops_reference([op], nd, algorithm,
                                                       topo=topo)
        if kind == "all-to-all" and algorithm == "hierarchical" \
                and topo is PODS_1AXIS:
            # hierarchical a2a now decomposes (the oracle keeps the flat
            # placement): same DCN share as flat, plus the two intra-pod
            # exchange stages; totals match the hierarchical billing.
            s, p = float(op.payload_bytes), 2
            dcn = sum(new[i + 1, j + 1] for i in range(nd)
                      for j in range(nd)
                      if topo.pod_index(i) != topo.pod_index(j))
            ref_dcn = sum(ref[i + 1, j + 1] for i in range(nd)
                          for j in range(nd)
                          if topo.pod_index(i) != topo.pod_index(j))
            assert dcn == pytest.approx(ref_dcn)
            assert dcn == pytest.approx((p - 1) / p * s * op.weight)
            assert new[1:, 1:].sum() == pytest.approx(
                cost_models.wire_bytes_group_total(
                    kind, s, nd, algorithm, pods=p) * op.weight)
            return
        np.testing.assert_allclose(new, ref, rtol=1e-12)

    @pytest.mark.parametrize("kind", KINDS + ("collective-permute",
                                              "mystery-kind"))
    @pytest.mark.parametrize("algorithm", ALGORITHMS)
    def test_per_rank_bytes_match_closed_forms(self, kind, algorithm):
        """wire_bytes_per_rank (schedule-summed) reproduces the Table-1
        closed forms for every kind x algorithm x pods."""
        s, n = 1000.0, 8
        for pods in (1, 2, 4):
            w = cost_models.wire_bytes_per_rank(kind, s, n, algorithm,
                                                pods=pods)
            p, m = (pods, n // pods) if n % pods == 0 else (1, n)
            if kind == "all-to-all":
                if algorithm == "hierarchical" and p > 1:
                    # two intra-pod exchange stages + the pod-slot DCN
                    # exchange of the S/m pod shard
                    exp = 2.0 * (m - 1) * s / (p * m * m) \
                        + (p - 1) * s / (p * p * m)
                else:
                    exp = (n - 1) * s / (n * n)
            elif kind in ("collective-permute", "mystery-kind"):
                exp = s
            elif kind == "all-reduce":
                if algorithm == "tree":
                    exp = 2.0 * s
                elif algorithm == "hierarchical" and p > 1:
                    exp = 2.0 * (m - 1) * s / m + 2.0 * (p - 1) * s / n
                else:
                    exp = 2.0 * (n - 1) * s / n
            else:   # one-phase kinds
                if algorithm == "hierarchical" and p > 1:
                    exp = (m - 1) * s / m + (p - 1) * s / n
                else:
                    exp = (n - 1) * s / n
            assert w == pytest.approx(exp), (kind, algorithm, pods)

    @pytest.mark.parametrize("algorithm", ALGORITHMS)
    def test_time_split_reads_the_same_schedule(self, algorithm):
        """collective_time_split == the schedule's own time_split."""
        op = mk_op("all-reduce")
        sched = decompose(op, algorithm, MESH_2X2X2, warn=False)
        assert cost_models.collective_time_split(
            op, MESH_2X2X2, algorithm) == sched.time_split(MESH_2X2X2)
        assert cost_models.collective_time_split(
            op, MESH_2X2X2, algorithm, include_latency=False) == \
            sched.time_split(MESH_2X2X2, include_latency=False)


try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
    _HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    _HAVE_HYPOTHESIS = False


if _HAVE_HYPOTHESIS:
    @st.composite
    def single_axis_ops(draw):
        """Randomized op streams whose groups partition a single-axis
        8-ring -- the domain where schedule == legacy is exact."""
        ops = []
        for _ in range(draw(st.integers(1, 6))):
            kind = draw(st.sampled_from(KINDS))
            elems = draw(st.integers(1, 2048))
            gsize = draw(st.sampled_from([2, 4, 8]))
            devs = draw(st.permutations(range(8)))
            groups = [sorted(devs[i:i + gsize])
                      for i in range(0, 8, gsize)]
            op = mk_op(kind, elems=elems, groups=groups,
                       weight=float(draw(st.integers(1, 64))))
            ops.append(op)
        return ops

    class TestScheduleLegacyProperty:
        """Satellite: hypothesis property pinning schedule-derived
        matrices AND billing equal to the legacy single-axis results for
        all kinds x ring/tree/hierarchical."""

        @given(ops=single_axis_ops(), algorithm=st.sampled_from(ALGORITHMS))
        @settings(max_examples=60, deadline=None)
        def test_matrices_and_billing_match_legacy(self, ops, algorithm):
            for topo in (None, ONE_AXIS, PODS_1AXIS):
                with warnings.catch_warnings():
                    warnings.simplefilter("ignore")
                    new = comm_matrix.matrix_for_ops(ops, 8, algorithm,
                                                     topo=topo)
                    ref = comm_matrix.matrix_for_ops_reference(
                        ops, 8, algorithm, topo=topo)
                    np.testing.assert_allclose(new, ref, rtol=1e-12)
                    # billing: row sums == device model x weight, per op
                    for op in ops:
                        mat = comm_matrix.matrix_for_ops([op], 8,
                                                         algorithm,
                                                         topo=topo)
                        rows = mat[1:, 1:].sum(axis=1)
                        for g in op.replica_groups:
                            exp = cost_models.device_send_bytes(
                                op.kind, op.payload_bytes, g, algorithm,
                                topo=topo)
                            for d in g:
                                assert rows[d] == pytest.approx(
                                    exp[d] * op.weight)


class TestPerAxisDecomposition:
    """The tentpole's new placement: ring per torus axis instead of the
    flattened ring."""

    @pytest.mark.parametrize("kind", ("all-reduce", "all-gather",
                                      "reduce-scatter",
                                      "collective-broadcast"))
    def test_zero_transit_inflation_inside_pod(self, kind):
        """Acceptance criterion: a multi-axis group's link matrix shows
        zero cross-axis transit inflation inside a pod -- every placed
        edge is a physical neighbour hop."""
        op = mk_op(kind, groups=[list(range(16))])
        mat = comm_matrix.matrix_for_ops([op], 16, "ring", topo=MESH_4X4)
        assert _transit_inflation(mat, MESH_4X4) == pytest.approx(0.0)

    @pytest.mark.parametrize("kind", ("all-reduce", "all-gather"))
    def test_strictly_reduces_intra_pod_transit_bytes(self, kind):
        """Satellite: per-axis decomposition strictly reduces intra-pod
        transit bytes vs the legacy flattened ring."""
        op = mk_op(kind, groups=[list(range(16))])
        new = comm_matrix.matrix_for_ops([op], 16, "ring", topo=MESH_4X4)
        ref = comm_matrix.matrix_for_ops_reference([op], 16, "ring",
                                                   topo=MESH_4X4)
        assert _transit_inflation(ref, MESH_4X4) > 0, \
            "legacy flattened ring must show transit inflation on 4x4"
        assert _transit_inflation(new, MESH_4X4) < \
            _transit_inflation(ref, MESH_4X4)
        assert _transit_inflation(new, MESH_4X4) == pytest.approx(0.0)

    @pytest.mark.parametrize("kind", ("all-reduce", "all-gather",
                                      "reduce-scatter",
                                      "collective-broadcast"))
    def test_per_rank_totals_preserved(self, kind):
        """Per-axis phases move the same Table-1 per-rank bytes as the
        flattened ring -- only *where* they travel changes."""
        op = mk_op(kind, groups=[list(range(16))])
        mat = comm_matrix.matrix_for_ops([op], 16, "ring", topo=MESH_4X4)
        per_rank = cost_models.wire_bytes_per_rank(
            kind, op.payload_bytes, 16, "ring")
        for d in range(16):
            assert mat[d + 1, 1:].sum() == pytest.approx(per_rank)

    def test_hierarchical_intra_pod_goes_per_axis(self):
        """Acceptance criterion: the hierarchical intra-pod phases decompose
        per axis too -- zero ICI transit inflation, same DCN share."""
        op = mk_op("all-reduce", groups=[list(range(8))])
        new = comm_matrix.matrix_for_ops([op], 8, "hierarchical",
                                         topo=MESH_2X2X2)
        ref = comm_matrix.matrix_for_ops_reference(
            [op], 8, "hierarchical", topo=MESH_2X2X2)
        assert _transit_inflation(new, MESH_2X2X2) == pytest.approx(0.0)
        # DCN bytes (the shard exchange) are identical to the legacy split
        def cross(m):
            return sum(m[i + 1, j + 1] for i in range(8) for j in range(8)
                       if MESH_2X2X2.pod_index(i)
                       != MESH_2X2X2.pod_index(j))
        assert cross(new) == pytest.approx(cross(ref))
        # and per-rank totals survive
        per_rank = cost_models.wire_bytes_per_rank(
            "all-reduce", op.payload_bytes, 8, "hierarchical", pods=2)
        for d in range(8):
            assert new[d + 1, 1:].sum() == pytest.approx(per_rank)

    def test_three_axis_group_decomposes_fully(self):
        op = mk_op("all-reduce", groups=[list(range(8))])
        sched = decompose(op, "ring", MESH_2X2X2X2)
        axes = [ph.axis for ph in sched.phases]
        assert axes == ["z", "y", "x", "x", "y", "z"]   # RS down, AG up
        assert all(ph.tier == "ici" for ph in sched.phases)
        mat = comm_matrix.matrix_for_ops([op], 16, "ring",
                                         topo=MESH_2X2X2X2)
        assert _transit_inflation(mat, MESH_2X2X2X2) == pytest.approx(0.0)

    def test_partial_axis_group_stays_flattened(self):
        """A group that is NOT a full-axis product (a strided subset) keeps
        the flattened ring -- no invented per-axis structure."""
        op = mk_op("all-reduce", groups=[[0, 1, 4, 5]])   # x fixed? no: 2
        sched = decompose(op, "ring", MESH_4X4)
        assert [ph.axis for ph in sched.phases] == ["", ""]

    def test_single_axis_group_keeps_flattened_ring(self):
        """Single-axis groups keep the (identical) flattened ring so the
        legacy oracle stays byte-exact on them."""
        op = mk_op("all-reduce", groups=[[0, 4, 8, 12]])  # one model column
        sched = decompose(op, "ring", MESH_4X4)
        assert [ph.axis for ph in sched.phases] == ["", ""]

    def test_crossing_groups_never_decompose_per_axis(self):
        """A ring group spanning pods stays a flat DCN-billed ring: the
        paper-faithful distinction from hierarchical is preserved."""
        op = mk_op("all-reduce", groups=[list(range(8))])
        sched = decompose(op, "ring", MESH_2X2X2)
        assert {ph.tier for ph in sched.phases} == {"dcn"}
        assert [ph.axis for ph in sched.phases] == ["", ""]


class TestScheduleIR:
    """The IR's own contracts: structure, streams, summaries."""

    def test_permute_pairs_split_by_tier(self):
        """A collective-permute's pairs are billed where they travel:
        cross-pod pairs on DCN, intra-pod pairs on ICI, as concurrent
        streams -- timing and link projection agree."""
        op = CollectiveOp(kind="collective-permute", name="p",
                          result_shapes=[Shape("f32", (1024,))],
                          replica_groups=[],
                          source_target_pairs=[(0, 4), (4, 0), (1, 2)])
        sched = decompose(op, "ring", PODS_1AXIS)
        tiers = {ph.tier: ph for ph in sched.phases}
        assert set(tiers) == {"ici", "dcn"}
        assert len(tiers["dcn"].pairs) == 2 and len(tiers["ici"].pairs) == 1
        assert tiers["ici"].stream != tiers["dcn"].stream
        s = float(op.result_bytes)
        ici, dcn = cost_models.collective_time_split(
            op, PODS_1AXIS, "ring", include_latency=False)
        assert dcn == pytest.approx(s / PODS_1AXIS.ring_bw_per_chip(True))
        assert ici == pytest.approx(s / PODS_1AXIS.ring_bw_per_chip(False))
        lu = comm_matrix.link_utilization_for_ops([op], PODS_1AXIS)
        assert lu.total_bytes("dcn") > 0 and lu.total_bytes("ici") > 0
        # single-pod (or no topo): everything stays one ICI phase
        flat = decompose(op, "ring", None)
        assert [ph.tier for ph in flat.phases] == ["ici"]

    def test_hierarchical_schedule_shape(self):
        op = mk_op("all-reduce", groups=[list(range(8))])
        sched = decompose(op, "hierarchical", MESH_2X2X2)
        kinds = [(ph.kind, ph.tier) for ph in sched.phases]
        # per-axis RS inside the pod, DCN shard all-reduce, per-axis AG
        assert kinds == [("reduce-scatter", "ici"), ("reduce-scatter", "ici"),
                        ("all-reduce", "dcn"),
                        ("all-gather", "ici"), ("all-gather", "ici")]
        dcn = [ph for ph in sched.phases if ph.tier == "dcn"]
        assert dcn[0].bytes_per_rank == pytest.approx(
            2 * (2 - 1) * op.payload_bytes / 8)

    def test_streams_are_concurrent_groups(self):
        """Disjoint replica groups land on distinct streams; time is the
        max over streams, not the sum."""
        op = mk_op("all-reduce", groups=[[0, 1], [2, 3, 4, 5]])
        sched = decompose(op, "ring", ONE_AXIS)
        streams = {ph.stream for ph in sched.phases}
        assert len(streams) == 2
        ici, dcn = sched.time_split(ONE_AXIS, include_latency=False)
        s = float(op.payload_bytes)
        slowest = max(2 * (2 - 1) * s / 2, 2 * (4 - 1) * s / 4) \
            / ONE_AXIS.ring_bw_per_chip(False)
        assert ici == pytest.approx(slowest) and dcn == 0.0

    def test_batched_groups_share_phases(self):
        """Same-size groups batch into shared phases (the vectorized
        builder's fast path) without changing the placed traffic."""
        op = mk_op("all-gather", groups=[[0, 1, 2, 3], [4, 5, 6, 7]])
        sched = decompose(op, "ring", None)
        assert len(sched.phases) == 1
        assert sched.phases[0].groups.shape == (2, 4)

    def test_summary_is_serializable(self):
        import json
        op = mk_op("all-reduce", groups=[list(range(8))])
        sched = decompose(op, "hierarchical", MESH_2X2X2)
        doc = sched.summary()
        json.dumps(doc)
        assert doc["kind"] == "all-reduce"
        assert {ph["tier"] for ph in doc["phases"]} == {"ici", "dcn"}
        assert all({"kind", "tier", "structure", "axis", "num_groups",
                    "group_size", "bytes_per_rank", "latency_hops"}
                   <= set(ph) for ph in doc["phases"])

    def test_total_bytes_matches_wire_total(self):
        for kind in KINDS:
            for alg in ALGORITHMS:
                op = mk_op(kind)
                sched = decompose(op, alg, None)
                assert sched.total_bytes() * op.weight == pytest.approx(
                    op.wire_bytes_total(alg)), (kind, alg)

    def test_group_phases_is_abstract_decompose(self):
        """group_phases with pods= reproduces the concrete decomposition's
        byte amounts without a mesh (the Table-1 entry point)."""
        abstract = group_phases("all-reduce", 1024.0, range(8),
                                "hierarchical", pods=2, warn=False)
        concrete = decompose(mk_op("all-reduce", elems=256,
                                   groups=[list(range(8))]),
                             "hierarchical", PODS_1AXIS).phases
        assert [round(p.bytes_per_rank, 9) for p in abstract] == \
            [round(p.bytes_per_rank, 9) for p in concrete]
        assert [p.tier for p in abstract] == [p.tier for p in concrete]


class TestScheduleSerialization:
    """Schema v5: optional per-op schedule summaries ride with reports."""

    def _report(self):
        from repro.core import CommReport, hlo_parser
        op = mk_op("all-reduce", groups=[list(range(8))])
        return CommReport(
            name="sched", num_devices=8, traced=[], compiled_ops=[op],
            traced_summary={},
            compiled_summary=hlo_parser.summarize([op], "hierarchical",
                                                  topo=MESH_2X2X2),
            matrix=comm_matrix.matrix_for_ops([op], 8, "hierarchical",
                                              topo=MESH_2X2X2),
            per_primitive={}, cost={}, memory_stats=None,
            trace_seconds=0.0, compile_seconds=0.0, topo=MESH_2X2X2,
            algorithm="hierarchical")

    def test_schedules_written_on_request(self, tmp_path):
        import json
        rep = self._report()
        p = str(tmp_path / "s.json")
        rep.save(p, include_schedules=True)
        d = json.loads(open(p).read())
        assert d["schema"] == "repro.comm_report.v9"
        assert len(d["schedules"]) == 1
        assert {ph["tier"] for ph in d["schedules"][0]["phases"]} == \
            {"ici", "dcn"}

    def test_schedules_absent_by_default_and_rederivable(self, tmp_path):
        import json
        rep = self._report()
        p = str(tmp_path / "s.json")
        rep.save(p)
        d = json.loads(open(p).read())
        assert "schedules" not in d
        from repro.core import CommReport
        back = CommReport.load(p)
        assert back.schedule_summaries() == rep.schedule_summaries()

    def test_v4_files_still_load(self, tmp_path):
        import json
        from repro.core import CommReport
        rep = self._report()
        p = str(tmp_path / "old.json")
        rep.save(p)
        d = json.loads(open(p).read())
        d["schema"] = "repro.comm_report.v4"
        with open(p, "w") as f:
            json.dump(d, f)
        back = CommReport.load(p)
        np.testing.assert_allclose(back.matrix, rep.matrix)
