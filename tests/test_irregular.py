"""Irregular collectives: per-rank byte vectors through the schedule IR.

The contract this module pins, end to end:

* **uniform == scalar, bitwise** -- an op whose byte vector is uniform
  collapses onto the scalar path at every entry point (decompose,
  placement dense + sparse, billing, timing), so every regular capture is
  unchanged by the vector plumbing (``==``, not ``allclose``);
* **skewed vectors conserve bytes** -- matrix row sums equal the
  schedule's per-device send totals, matrix total equals the billing
  model's group total, and the straggler (max-billed) time is never below
  the balanced time for the same total payload;
* **schema v8 round-trips** the optional ``bytes_per_rank_vec`` key and
  regular ops keep the v7 spelling (no key at all);
* **malformed vectors degrade to scalar** -- wrong length, negative or
  non-finite entries, or a non-vector kind never corrupt the accounting;
* **fleet projection carries the vector** -- ``scale.scale_op`` tiles +
  renormalizes instead of flattening to the mean, and irregular a2a pod
  chunks each carry their own slice.

A hypothesis-randomized sweep rides along when the optional [test] extra
is installed; the deterministic seed grid below is the tier-1 guarantee.
"""
import dataclasses
import json

import numpy as np
import pytest

from repro.core import comm_matrix, cost_models, decompose as dec
from repro.core.events import CollectiveOp, Shape
from repro.core.export import serialize
from repro.core.topology import MeshTopology

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
    _HAVE_HYPOTHESIS = True
except ImportError:            # tier-1 runs on a bare interpreter
    _HAVE_HYPOTHESIS = False

VEC_KINDS = ("all-gather", "reduce-scatter", "all-to-all")
ALGORITHMS = ("ring", "tree", "hierarchical")

ONE_AXIS = MeshTopology(axis_names=("data",), axis_sizes=(8,))
PODS_1AXIS = MeshTopology(axis_names=("pod", "data"), axis_sizes=(2, 4))
TOPOS = (None, ONE_AXIS, PODS_1AXIS)


def mk_op(kind, elems, groups, vec=None, pairs=None, weight=1.0):
    return CollectiveOp(
        kind=kind, name="t", result_shapes=[Shape("f32", (elems,))],
        replica_groups=groups, source_target_pairs=pairs or [],
        weight=weight,
        bytes_per_rank_vec=None if vec is None else [float(x) for x in vec])


def skewed_vec(n, total, hot=0, frac=0.6):
    v = np.full(n, total * (1.0 - frac) / (n - 1))
    v[hot] = total * frac
    return v


def device_send_totals(op, algorithm, topo, num_devices):
    """Per-device send bytes summed over the op's schedule phases."""
    sched = dec.decompose(op, algorithm, topo, warn=False)
    out = np.zeros(num_devices)
    for ph in sched.phases:
        if ph.pairs is not None:
            amts = (ph.pair_bytes if ph.pair_bytes is not None
                    else np.full(len(ph.pairs), ph.max_bytes_per_rank()))
            for (s, _d), b in zip(ph.pairs.tolist(), amts.tolist()):
                out[int(s)] += float(b)
            continue
        if ph.groups is None:
            continue
        bm = ph.byte_matrix()
        for gi, g in enumerate(np.asarray(ph.groups).tolist()):
            for pos, d in enumerate(g):
                out[int(d)] += float(bm[gi, pos])
    return out * op.weight


# ---------------------------------------------------------------------------
# uniform vector == scalar, bitwise
# ---------------------------------------------------------------------------
class TestUniformCollapsesToScalar:
    """A uniform vector must take the scalar path *exactly*: same
    schedules, same matrices (dense and sparse), same billed bytes, same
    times -- compared with ``==``, never ``allclose``."""

    @pytest.mark.parametrize("kind", VEC_KINDS)
    @pytest.mark.parametrize("algorithm", ALGORITHMS)
    @pytest.mark.parametrize("topo", TOPOS,
                             ids=["none", "one_axis", "pods"])
    @pytest.mark.parametrize("seed", range(3))
    def test_matrix_bitwise(self, kind, algorithm, topo, seed):
        rng = np.random.default_rng(seed)
        n = int(rng.choice([2, 4, 8]))
        elems = int(rng.integers(1, 4096))
        groups = [sorted(int(d) for d in g)
                  for g in rng.permutation(8).reshape(-1, n)]
        scalar = mk_op(kind, elems, groups,
                       weight=float(rng.integers(1, 16)))
        per = scalar.payload_bytes / n
        uniform = dataclasses.replace(
            scalar, bytes_per_rank_vec=[per] * n)
        assert uniform.byte_vector() is not None
        assert uniform.payload_bytes == scalar.payload_bytes
        for sparse in (False, True):
            ms = comm_matrix.matrix_for_ops([scalar], 8, algorithm,
                                            topo=topo, sparse=sparse)
            mu = comm_matrix.matrix_for_ops([uniform], 8, algorithm,
                                            topo=topo, sparse=sparse)
            if sparse:
                ms, mu = ms.to_dense(), mu.to_dense()
            assert (np.asarray(ms) == np.asarray(mu)).all()

    @pytest.mark.parametrize("kind", VEC_KINDS)
    @pytest.mark.parametrize("algorithm", ALGORITHMS)
    def test_billing_and_timing_bitwise(self, kind, algorithm):
        n, elems = 8, 1000
        scalar = mk_op(kind, elems, [list(range(n))], weight=3.0)
        uniform = dataclasses.replace(
            scalar, bytes_per_rank_vec=[scalar.payload_bytes / n] * n)
        assert uniform.wire_bytes_per_rank(algorithm) \
            == scalar.wire_bytes_per_rank(algorithm)
        assert uniform.wire_bytes_total(algorithm) \
            == scalar.wire_bytes_total(algorithm)
        for topo in (ONE_AXIS, PODS_1AXIS):
            ss = dec.decompose(scalar, algorithm, topo, warn=False)
            su = dec.decompose(uniform, algorithm, topo, warn=False)
            assert ss.time_split(topo) == su.time_split(topo)
            assert ss.total_bytes() == su.total_bytes()

    if _HAVE_HYPOTHESIS:
        @given(kind=st.sampled_from(VEC_KINDS),
               algorithm=st.sampled_from(ALGORITHMS),
               n=st.sampled_from([2, 4, 8]),
               elems=st.integers(1, 1 << 14),
               weight=st.integers(1, 64))
        @settings(max_examples=60, deadline=None)
        def test_matrix_bitwise_randomized(self, kind, algorithm, n,
                                           elems, weight):
            scalar = mk_op(kind, elems, [list(range(n))],
                           weight=float(weight))
            uniform = dataclasses.replace(
                scalar,
                bytes_per_rank_vec=[scalar.payload_bytes / n] * n)
            for topo in TOPOS:
                ms = comm_matrix.matrix_for_ops([scalar], 8, algorithm,
                                                topo=topo)
                mu = comm_matrix.matrix_for_ops([uniform], 8, algorithm,
                                                topo=topo)
                assert (ms == mu).all()


# ---------------------------------------------------------------------------
# skewed vectors: conservation + straggler laws
# ---------------------------------------------------------------------------
class TestSkewedVectors:
    @pytest.mark.parametrize("kind", VEC_KINDS)
    @pytest.mark.parametrize("topo", (None, ONE_AXIS),
                             ids=["none", "one_axis"])
    def test_row_sums_match_schedule(self, kind, topo):
        n = 8
        vec = skewed_vec(n, 81920.0, hot=2)
        op = mk_op(kind, 100, [list(range(n))], vec=vec, weight=2.0)
        mat = comm_matrix.matrix_for_ops([op], n, "ring", topo=topo)
        np.testing.assert_allclose(
            mat[1:, 1:].sum(axis=1),
            device_send_totals(op, "ring", topo, n), rtol=1e-12)

    @pytest.mark.parametrize("kind", VEC_KINDS)
    def test_matrix_total_matches_billing(self, kind):
        n = 4
        vec = skewed_vec(n, 40960.0)
        op = mk_op(kind, 100, [[0, 1, 2, 3], [4, 5, 6, 7]], vec=vec,
                   weight=3.0)
        mat = comm_matrix.matrix_for_ops([op], 8, "ring")
        assert mat.sum() == pytest.approx(op.wire_bytes_total("ring"))
        total = cost_models.wire_bytes_group_total(
            kind, op.payload_bytes, n, "ring", vec=op.byte_vector())
        assert mat.sum() == pytest.approx(total * op.num_groups * op.weight)

    def test_sparse_matches_dense_skewed(self):
        n = 8
        ops = [mk_op(k, 500, [list(range(n))],
                     vec=skewed_vec(n, 16000.0, hot=i % n), weight=2.0)
               for i, k in enumerate(VEC_KINDS)]
        dense = comm_matrix.matrix_for_ops(ops, n, "ring")
        sp = comm_matrix.matrix_for_ops(ops, n, "ring", sparse=True)
        np.testing.assert_allclose(sp.to_dense(), dense, rtol=1e-12)

    def test_hot_rank_dominates_matrix_row(self):
        n = 8
        op = mk_op("all-to-all", 100, [list(range(n))],
                   vec=skewed_vec(n, 81920.0, hot=3))
        mat = comm_matrix.matrix_for_ops([op], n)[1:, 1:]
        rows = mat.sum(axis=1)
        assert rows[3] == rows.max()
        assert rows[3] > 2.0 * np.delete(rows, 3).max()

    @pytest.mark.parametrize("algorithm", ("ring", "hierarchical"))
    def test_straggler_time_at_least_balanced(self, algorithm):
        n = 8
        total = 1 << 20
        skewed = mk_op("all-to-all", 100, [list(range(n))],
                       vec=skewed_vec(n, total))
        balanced = dataclasses.replace(
            skewed, bytes_per_rank_vec=[total / n] * n)
        for topo in (ONE_AXIS, PODS_1AXIS):
            ts = sum(dec.decompose(skewed, algorithm, topo,
                                   warn=False).time_split(topo))
            tb = sum(dec.decompose(balanced, algorithm, topo,
                                   warn=False).time_split(topo))
            assert ts >= tb > 0.0

    def test_skew_property(self):
        n = 8
        op = mk_op("all-to-all", 100, [list(range(n))],
                   vec=skewed_vec(n, 8000.0, frac=0.6))
        assert op.skew() == pytest.approx(0.6 * n)
        assert mk_op("all-to-all", 100, [list(range(n))]).skew() == 1.0

    def test_hierarchical_kinds_fall_back_to_flat_vector(self):
        """AG/RS vectors on a multi-pod group warn once and take the flat
        vector path (bytes conserved), never the scalar hierarchical
        schedule."""
        n = 8
        vec = skewed_vec(n, 81920.0)
        op = mk_op("all-gather", 100, [list(range(n))], vec=vec)
        sched = dec.decompose(op, "hierarchical", PODS_1AXIS, warn=False)
        assert all(ph.structure == "ring" for ph in sched.phases)
        dec.reset_fallback_warnings()
        with pytest.warns(dec.HierarchicalFallbackWarning):
            mat = comm_matrix.matrix_for_ops([op], n, "hierarchical",
                                             topo=PODS_1AXIS)
        np.testing.assert_allclose(
            mat[1:, 1:].sum(axis=1),
            device_send_totals(op, "hierarchical", PODS_1AXIS, n),
            rtol=1e-12)


# ---------------------------------------------------------------------------
# malformed vectors degrade to scalar
# ---------------------------------------------------------------------------
class TestVectorValidation:
    BASE = dict(kind="all-to-all", elems=100, groups=[[0, 1, 2, 3]])

    def _scalar(self):
        return mk_op(self.BASE["kind"], self.BASE["elems"],
                     self.BASE["groups"])

    @pytest.mark.parametrize("bad", [
        [1.0, 2.0, 3.0],                    # wrong length
        [1.0, 2.0, 3.0, -4.0],              # negative entry
        [1.0, 2.0, 3.0, float("nan")],      # non-finite
        [0.0, 0.0, 0.0, 0.0],               # zero sum
    ], ids=["short", "negative", "nan", "zero-sum"])
    def test_bad_vector_ignored(self, bad):
        op = mk_op(**{k: v for k, v in self.BASE.items()}, vec=bad)
        assert op.byte_vector() is None
        assert op.payload_bytes == self._scalar().payload_bytes
        ms = comm_matrix.matrix_for_ops([self._scalar()], 4)
        mb = comm_matrix.matrix_for_ops([op], 4)
        assert (ms == mb).all()

    def test_non_vector_kind_ignored(self):
        op = mk_op("all-reduce", 100, [[0, 1, 2, 3]],
                   vec=[1.0, 2.0, 3.0, 4.0])
        assert op.byte_vector() is None
        assert op.skew() == 1.0


# ---------------------------------------------------------------------------
# schema v8
# ---------------------------------------------------------------------------
class TestSchemaV8:
    def test_schema_string(self):
        assert serialize.SCHEMA == "repro.comm_report.v9"
        assert serialize.SCHEMA_V7 in serialize.ACCEPTED_SCHEMAS

    def test_op_round_trip_with_vector(self):
        vec = [100.0, 200.0, 300.0, 400.0]
        op = mk_op("all-to-all", 100, [[0, 1, 2, 3]], vec=vec, weight=7.0)
        d = serialize.op_to_dict(op)
        assert d["bytes_per_rank_vec"] == vec
        back = serialize.op_from_dict(json.loads(json.dumps(d)))
        assert back.bytes_per_rank_vec == vec
        np.testing.assert_array_equal(back.byte_vector(), op.byte_vector())
        assert back.skew() == op.skew()

    def test_regular_op_keeps_v7_spelling(self):
        op = mk_op("all-reduce", 100, [[0, 1]])
        d = serialize.op_to_dict(op)
        assert "bytes_per_rank_vec" not in d
        assert serialize.op_from_dict(d).bytes_per_rank_vec is None

    def test_v7_file_without_vectors_loads(self, tmp_path):
        """A v7-tagged file (no vec keys anywhere) loads as scalar ops."""
        op = mk_op("all-to-all", 64, [[0, 1, 2, 3]])
        mat = comm_matrix.matrix_for_ops([op], 4)
        d = {
            "schema": "repro.comm_report.v7",
            "name": "old", "num_devices": 4,
            "summary": {}, "traced_summary": {},
            "ops": [serialize.op_to_dict(op)],
            "matrix": mat.tolist(), "per_primitive": {},
        }
        back = serialize.report_from_dict(d)
        assert back.compiled_ops[0].bytes_per_rank_vec is None
        np.testing.assert_allclose(np.asarray(back.matrix), mat)

    def test_report_round_trip_preserves_vector(self, tmp_path):
        from repro.core.monitor import CommReport
        vec = skewed_vec(4, 4096.0)
        op = mk_op("all-to-all", 100, [[0, 1, 2, 3]], vec=vec)
        rep = CommReport(
            name="irr", num_devices=4, traced=[], compiled_ops=[op],
            traced_summary={}, compiled_summary={},
            matrix=comm_matrix.matrix_for_ops([op], 4), per_primitive={},
            cost={}, memory_stats=None, trace_seconds=0.0,
            compile_seconds=0.0, topo=None, host_transfers=[])
        p = str(tmp_path / "r.json")
        rep.save(p)
        d = json.loads(open(p).read())
        assert d["schema"] == "repro.comm_report.v9"
        back = CommReport.load(p)
        got = back.compiled_ops[0]
        np.testing.assert_array_equal(got.byte_vector(), vec)
        np.testing.assert_allclose(np.asarray(back.matrix),
                                   np.asarray(rep.matrix))


# ---------------------------------------------------------------------------
# fleet projection carries the vector
# ---------------------------------------------------------------------------
class TestScaleProjection:
    def test_vector_expansion_preserves_total_and_uniformity(self):
        from repro import scale
        n, total = 4, 4096.0
        op = mk_op("all-gather", 100, [list(range(n))],
                   vec=skewed_vec(n, total))
        out = scale.scale_op(op, 4)
        v = out.byte_vector()
        assert v is not None and v.size == n * 4
        assert v.sum() == pytest.approx(total)
        # each base rank's share tiles over its clone block
        np.testing.assert_allclose(v.reshape(n, 4).sum(axis=1),
                                   op.byte_vector())
        # a uniform vector stays uniform (the scalar path after collapse)
        uni = scale.scale_op(dataclasses.replace(
            op, bytes_per_rank_vec=[total / n] * n), 4)
        vu = uni.byte_vector()
        assert vu is not None and float(vu.max()) == float(vu.min())

    def test_irregular_a2a_chunks_carry_slices(self):
        from repro import scale
        n = 8
        total = float(n * scale.POD_DEVICES)
        vec = skewed_vec(n, total, hot=0)
        op = mk_op("all-to-all", 100, [list(range(n))], vec=vec)
        factor = 2 * scale.POD_DEVICES // n          # -> 2 pod chunks
        out = scale.scale_op(op, factor)
        assert isinstance(out, list) and len(out) == 2
        for chunk in out:
            assert chunk.group_size == scale.POD_DEVICES
            assert chunk.byte_vector() is not None
        # slices partition the expanded vector (x chunk-count renorm):
        # the hot rank's clones land in chunk 0, so chunk 0 stays hot
        s0 = out[0].byte_vector().sum()
        s1 = out[1].byte_vector().sum()
        assert s0 > s1
        # totals follow the scalar chunking convention: each chunk op
        # would carry the full base payload if balanced, so the two sum
        # to 2x the base total with the skew split across chunks
        assert s0 + s1 == pytest.approx(2.0 * total)
        # scale_ops flattens the chunk list
        flat = scale.scale_ops([op], n, n * factor)
        assert len(flat) == 2

    def test_scalar_path_unchanged(self):
        from repro import scale
        op = mk_op("all-to-all", 100, [list(range(8))])
        assert scale.scale_op(op, 1) is op
        out = scale.scale_op(op, 2 * scale.POD_DEVICES // 8)
        assert not isinstance(out, list)
        assert len(out.replica_groups) == 2
