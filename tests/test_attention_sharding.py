"""Adaptive attention sharding: repeat-KV, head padding, context-parallel.

These paths carry the §Perf wins; each must be numerically identical to the
unsharded reference.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ModelConfig, build_model
from repro.models.attention import _expand_kv, chunked_attention, pad_heads
from repro.kernels.flash_attention.ref import attention_ref
from repro.parallel import Sharder

pytestmark = pytest.mark.compile   # whole module drives XLA compiles


class TestExpandKV:
    def test_expand_matches_grouped(self):
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (2, 64, 8, 16))
        k = jax.random.normal(ks[1], (2, 64, 2, 16))
        v = jax.random.normal(ks[2], (2, 64, 2, 16))
        # expanded-MHA evaluation == grouped-GQA reference
        out = chunked_attention(q, k, v, q_chunk=32)
        ref = attention_ref(q, k, v)
        assert jnp.max(jnp.abs(out - ref)) < 2e-5

    def test_expand_is_identity_for_mha(self):
        k = jax.random.normal(jax.random.PRNGKey(0), (1, 8, 4, 16))
        assert _expand_kv(k, 4) is k


class TestHeadPadding:
    def test_padded_attention_matches_unpadded(self):
        """Zero-padded heads must not change the real heads' outputs."""
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        q = jax.random.normal(ks[0], (2, 64, 3, 16))
        k = jax.random.normal(ks[1], (2, 64, 3, 16))
        v = jax.random.normal(ks[2], (2, 64, 3, 16))
        ref = attention_ref(q, k, v)
        qp, kp, vp = (pad_heads(x, 4) for x in (q, k, v))
        out = chunked_attention(qp, kp, vp, q_chunk=32)[:, :, :3]
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)

    def test_pad_heads_shape(self):
        x = jnp.ones((1, 4, 5, 8))
        assert pad_heads(x, 8).shape == (1, 4, 8, 8)
        assert pad_heads(x, 5) is x


class TestIndivisibleHeadsEndToEnd:
    """heads % tp != 0 (the llama4/musicgen/recurrentgemma situation) on a
    real mesh: train step descends, prefill == stepwise decode."""

    @pytest.fixture(scope="class")
    def cfg(self):
        return ModelConfig(name="odd-heads", family="dense", n_layers=2,
                           d_model=48, n_heads=3, n_kv_heads=1, d_ff=96,
                           vocab_size=128, compute_dtype="float32")

    def test_train_descends(self, cfg, mesh8):
        shd = Sharder(mesh8)  # model axis = 2; 3 heads % 2 != 0
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, 128)
        batch = {"tokens": toks, "labels": toks}

        def loss(p):
            return model.loss_fn(p, batch, shd)[0]

        val, grads = jax.jit(jax.value_and_grad(loss))(params)
        p2 = jax.tree.map(lambda p, g: p - 0.3 * g.astype(p.dtype),
                          params, grads)
        assert float(jax.jit(loss)(p2)) < float(val)

    def test_prefill_matches_decode(self, cfg, mesh8):
        shd = Sharder(mesh8)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(2))
        toks = jax.random.randint(jax.random.PRNGKey(3), (2, 6), 0, 128)
        pf, _ = jax.jit(lambda p, b: model.prefill(p, b, shd))(
            params, {"tokens": toks})
        cache = model.init_cache(2, 6)
        step = jax.jit(lambda p, c, b: model.decode_step(p, c, b, shd))
        for t in range(6):
            logits, cache = step(params, cache, {"tokens": toks[:, t:t + 1]})
        np.testing.assert_allclose(np.asarray(pf, np.float32),
                                   np.asarray(logits[:, 0], np.float32),
                                   rtol=2e-2, atol=2e-2)
