"""Static lint pass: one positive + one negative golden case per rule,
the savings invariant, the clean-config assertion, schema-v7 lint
round-trip, and the fallback-warning dedup."""
import json
import warnings

import pytest

from repro.core import hlo_cost
from repro.core.decompose import (HierarchicalFallbackWarning, decompose,
                                  reset_fallback_warnings)
from repro.core.events import CollectiveOp, Shape
from repro.core.lint import (RULES, LintFinding, lint_ops, max_severity,
                             severity_rank)
from repro.core.topology import MeshTopology

TOPO_FLAT = MeshTopology(axis_names=("data",), axis_sizes=(8,))
TOPO_PODS = MeshTopology(axis_names=("pod", "data"), axis_sizes=(2, 4))


def _ar(name, dims=(1024, 1024), dtype="f32", groups=None, **kw):
    return CollectiveOp(
        kind="all-reduce", name=name,
        result_shapes=[Shape(dtype, dims)],
        replica_groups=groups or [[0, 1, 2, 3, 4, 5, 6, 7]], **kw)


def _findings(rule_id, findings):
    return [f for f in findings if f.rule_id == rule_id]


# ---------------------------------------------------------------------------
# hand-written HLO for the def-use rules
# ---------------------------------------------------------------------------
HLO_AG_SLICE = """\
HloModule m

ENTRY %main (p0: f32[128,64]) -> f32[16,64] {
  %p0 = f32[128,64] parameter(0)
  %ag = f32[1024,64] all-gather(%p0), replica_groups={{0,1,2,3,4,5,6,7}}, dimensions={0}
  ROOT %sl = f32[16,64] slice(%ag), slice={[0:16], [0:64]}
}
"""

# negative: the gathered tensor feeds real compute, not just a slice
HLO_AG_USED = """\
HloModule m

ENTRY %main (p0: f32[128,64]) -> f32[1024,64] {
  %p0 = f32[128,64] parameter(0)
  %ag = f32[1024,64] all-gather(%p0), replica_groups={{0,1,2,3,4,5,6,7}}, dimensions={0}
  ROOT %neg = f32[1024,64] negate(%ag)
}
"""

HLO_DUP = """\
HloModule m

%sum (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %add = f32[] add(%a, %b)
}

ENTRY %main (p0: f32[64]) -> (f32[64], f32[64]) {
  %p0 = f32[64] parameter(0)
  %ar1 = f32[64] all-reduce(%p0), replica_groups={{0,1,2,3,4,5,6,7}}, to_apply=%sum
  %ar2 = f32[64] all-reduce(%p0), replica_groups={{0,1,2,3,4,5,6,7}}, to_apply=%sum
  ROOT %t = (f32[64], f32[64]) tuple(%ar1, %ar2)
}
"""

# negative: same shape/groups but distinct operands -- two real transfers
HLO_NO_DUP = """\
HloModule m

%sum (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %add = f32[] add(%a, %b)
}

ENTRY %main (p0: f32[64], p1: f32[64]) -> (f32[64], f32[64]) {
  %p0 = f32[64] parameter(0)
  %p1 = f32[64] parameter(1)
  %ar1 = f32[64] all-reduce(%p0), replica_groups={{0,1,2,3,4,5,6,7}}, to_apply=%sum
  %ar2 = f32[64] all-reduce(%p1), replica_groups={{0,1,2,3,4,5,6,7}}, to_apply=%sum
  ROOT %t = (f32[64], f32[64]) tuple(%ar1, %ar2)
}
"""

HLO_DTYPE = """\
HloModule m

%sum (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %add = f32[] add(%a, %b)
}

ENTRY %main (p0: bf16[4096]) -> bf16[4096] {
  %p0 = bf16[4096] parameter(0)
  %cv = f32[4096] convert(%p0)
  %ar = f32[4096] all-reduce(%cv), replica_groups={{0,1,2,3,4,5,6,7}}, to_apply=%sum
  ROOT %back = bf16[4096] convert(%ar)
}
"""

# negative: genuinely f32 on both sides -- the wire width is needed
HLO_DTYPE_OK = """\
HloModule m

%sum (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %add = f32[] add(%a, %b)
}

ENTRY %main (p0: f32[4096]) -> f32[4096] {
  %p0 = f32[4096] parameter(0)
  %ar = f32[4096] all-reduce(%p0), replica_groups={{0,1,2,3,4,5,6,7}}, to_apply=%sum
  ROOT %neg = f32[4096] negate(%ar)
}
"""


def _hlo_case(text):
    """(ops, hlo_texts) pair for one hand-written module."""
    return hlo_cost.analyze_hlo(text).collectives, [text]


# ---------------------------------------------------------------------------
# rule 1: small-ar-bucketing
# ---------------------------------------------------------------------------
class TestSmallArBucketing:
    def test_latency_bound_run_flags(self):
        ops = [_ar(f"%ar.{i}", dims=(8,)) for i in range(4)]
        got = _findings("small-ar-bucketing",
                        lint_ops(ops, topo=TOPO_FLAT))
        assert len(got) == 1
        f = got[0]
        assert f.op_names == [op.name for op in ops]
        assert f.severity == "warn"
        assert f.est_savings_s > 0.0

    def test_bandwidth_bound_run_clean(self):
        ops = [_ar(f"%ar.{i}") for i in range(4)]      # 4 MiB each
        assert not _findings("small-ar-bucketing",
                             lint_ops(ops, topo=TOPO_FLAT))

    def test_different_groups_break_the_run(self):
        ops = [_ar("%ar.0", dims=(8,), groups=[[0, 1, 2, 3]]),
               _ar("%ar.1", dims=(8,), groups=[[4, 5, 6, 7]])]
        assert not _findings("small-ar-bucketing",
                             lint_ops(ops, topo=TOPO_FLAT))


# ---------------------------------------------------------------------------
# rule 2: flat-ring-multipod
# ---------------------------------------------------------------------------
class TestFlatRingMultipod:
    def test_pod_spanning_ring_flags_error(self):
        got = _findings("flat-ring-multipod",
                        lint_ops([_ar("%ar.0")], topo=TOPO_PODS,
                                 algorithm="ring"))
        assert len(got) == 1
        f = got[0]
        assert f.severity == "error"
        assert f.est_savings_s > 0.0
        assert f.est_dcn_bytes_saved > 0.0
        assert "hierarchical" in f.suggested_fix

    def test_hierarchical_binding_clean(self):
        assert not lint_ops([_ar("%ar.0")], topo=TOPO_PODS,
                            algorithm="hierarchical")

    def test_single_pod_clean(self):
        assert not _findings("flat-ring-multipod",
                             lint_ops([_ar("%ar.0")], topo=TOPO_FLAT))


# ---------------------------------------------------------------------------
# rule 3: allgather-then-slice
# ---------------------------------------------------------------------------
class TestAllgatherThenSlice:
    def test_slice_only_consumer_flags(self):
        ops, texts = _hlo_case(HLO_AG_SLICE)
        got = _findings("allgather-then-slice",
                        lint_ops(ops, topo=TOPO_FLAT, hlo_texts=texts))
        assert len(got) == 1
        f = got[0]
        assert f.op_names == ["ag"]
        assert f.est_savings_s > 0.0

    def test_real_consumer_clean(self):
        ops, texts = _hlo_case(HLO_AG_USED)
        assert not _findings("allgather-then-slice",
                             lint_ops(ops, topo=TOPO_FLAT,
                                      hlo_texts=texts))


# ---------------------------------------------------------------------------
# rule 4: redundant-collective
# ---------------------------------------------------------------------------
class TestRedundantCollective:
    def test_identical_pair_flags_error(self):
        ops, texts = _hlo_case(HLO_DUP)
        got = _findings("redundant-collective",
                        lint_ops(ops, topo=TOPO_FLAT, hlo_texts=texts))
        assert len(got) == 1
        f = got[0]
        assert f.severity == "error"
        assert sorted(f.op_names) == ["ar1", "ar2"]
        assert f.est_savings_s > 0.0
        # savings = (k-1)/k of current for k=2 duplicates
        assert f.est_savings_s == pytest.approx(f.est_current_s / 2)

    def test_distinct_operands_clean(self):
        ops, texts = _hlo_case(HLO_NO_DUP)
        assert not _findings("redundant-collective",
                             lint_ops(ops, topo=TOPO_FLAT,
                                      hlo_texts=texts))


# ---------------------------------------------------------------------------
# rule 5: dcn-permute
# ---------------------------------------------------------------------------
def _permute(pairs, name="%cp.0"):
    return CollectiveOp(kind="collective-permute", name=name,
                        result_shapes=[Shape("f32", (65536,))],
                        replica_groups=[],
                        source_target_pairs=list(pairs))


class TestDcnPermute:
    def test_packable_cross_pod_pairs_flag(self):
        # {0,4} and {1,5} each fit in a 4-device pod; the default device
        # order routes both exchanges over DCN
        op = _permute([(0, 4), (4, 0), (1, 5), (5, 1)])
        got = _findings("dcn-permute", lint_ops([op], topo=TOPO_PODS))
        assert len(got) == 1
        assert got[0].est_savings_s > 0.0

    def test_unpackable_component_clean(self):
        # one 8-cycle: the component needs all 8 devices > pod capacity 4
        op = _permute([(i, (i + 1) % 8) for i in range(8)])
        assert not _findings("dcn-permute",
                             lint_ops([op], topo=TOPO_PODS))

    def test_intra_pod_pairs_clean(self):
        op = _permute([(0, 1), (1, 0), (4, 5), (5, 4)])
        assert not _findings("dcn-permute",
                             lint_ops([op], topo=TOPO_PODS))


# ---------------------------------------------------------------------------
# rule 6: wire-dtype-waste
# ---------------------------------------------------------------------------
class TestWireDtypeWaste:
    def test_bf16_sandwich_flags(self):
        ops, texts = _hlo_case(HLO_DTYPE)
        got = _findings("wire-dtype-waste",
                        lint_ops(ops, topo=TOPO_FLAT, hlo_texts=texts))
        assert len(got) == 1
        assert got[0].op_names == ["ar"]
        assert got[0].est_savings_s >= 0.0

    def test_true_f32_clean(self):
        ops, texts = _hlo_case(HLO_DTYPE_OK)
        assert not _findings("wire-dtype-waste",
                             lint_ops(ops, topo=TOPO_FLAT,
                                      hlo_texts=texts))


# ---------------------------------------------------------------------------
# rule 7: skewed-a2a
# ---------------------------------------------------------------------------
def _a2a(name, vec=None, weight=1.0):
    return CollectiveOp(
        kind="all-to-all", name=name,
        result_shapes=[Shape("f32", (4096,))],
        replica_groups=[[0, 1, 2, 3, 4, 5, 6, 7]], weight=weight,
        bytes_per_rank_vec=vec)


def _skewed_vec(total, n=8, frac=0.6):
    return [total * frac] + [total * (1.0 - frac) / (n - 1)] * (n - 1)


class TestSkewedA2a:
    def test_hot_rank_flags_warn(self):
        op = _a2a("%a2a.0", vec=_skewed_vec(16384.0))   # skew 4.8x
        got = _findings("skewed-a2a", lint_ops([op], topo=TOPO_FLAT))
        assert len(got) == 1
        f = got[0]
        assert f.severity == "warn"
        assert f.op_names == ["%a2a.0"]
        assert 0.0 < f.est_savings_s <= f.est_current_s
        # the straggler gap is the whole story: rebalancing the same
        # bytes evenly is exactly the alternative the rule prices
        assert "rank 0" in f.message
        assert f.suggested_fix

    def test_balanced_vector_clean(self):
        op = _a2a("%a2a.0", vec=[2048.0] * 8)           # skew 1.0
        assert not _findings("skewed-a2a", lint_ops([op], topo=TOPO_FLAT))

    def test_scalar_a2a_clean(self):
        assert not _findings("skewed-a2a",
                             lint_ops([_a2a("%a2a.0")], topo=TOPO_FLAT))

    def test_mild_skew_below_threshold_clean(self):
        # 1.5x hot rank: below the 2x threshold
        vec = [1.5 * 2048.0] + [(16384.0 - 1.5 * 2048.0) / 7] * 7
        assert not _findings(
            "skewed-a2a",
            lint_ops([_a2a("%a2a.0", vec=vec)], topo=TOPO_FLAT))

    def test_no_topo_no_finding(self):
        op = _a2a("%a2a.0", vec=_skewed_vec(16384.0))
        assert not _findings("skewed-a2a", lint_ops([op], topo=None))

    def test_weight_scales_savings(self):
        one = _findings("skewed-a2a", lint_ops(
            [_a2a("%a2a.0", vec=_skewed_vec(16384.0))], topo=TOPO_FLAT))[0]
        sixteen = _findings("skewed-a2a", lint_ops(
            [_a2a("%a2a.0", vec=_skewed_vec(16384.0), weight=16.0)],
            topo=TOPO_FLAT))[0]
        assert sixteen.est_savings_s == pytest.approx(
            16.0 * one.est_savings_s)


# ---------------------------------------------------------------------------
# cross-rule properties
# ---------------------------------------------------------------------------
def _all_scenario_findings():
    out = []
    out += lint_ops([_ar(f"%ar.{i}", dims=(8,)) for i in range(4)],
                    topo=TOPO_FLAT)
    out += lint_ops([_ar("%ar.0")], topo=TOPO_PODS, algorithm="ring")
    out += lint_ops([_ar("%ar.0")], topo=TOPO_PODS, algorithm="tree")
    out += lint_ops([_permute([(0, 4), (4, 0)])], topo=TOPO_PODS)
    out += lint_ops([_a2a("%a2a.0", vec=_skewed_vec(16384.0))],
                    topo=TOPO_FLAT)
    out += lint_ops([_a2a("%a2a.0", vec=_skewed_vec(16384.0), weight=8.0)],
                    topo=TOPO_PODS)
    for text in (HLO_AG_SLICE, HLO_DUP, HLO_DTYPE):
        ops, texts = _hlo_case(text)
        out += lint_ops(ops, topo=TOPO_FLAT, hlo_texts=texts)
        out += lint_ops(ops, topo=TOPO_PODS, hlo_texts=texts)
        out += lint_ops(ops, topo=None, hlo_texts=texts)   # topo-free
    return out


class TestInvariants:
    def test_savings_bounded_by_current(self):
        """The finding invariant: 0 <= est_savings_s <= est_current_s (a
        fix can at best eliminate the op's whole modeled time), and DCN
        bytes saved are never negative."""
        findings = _all_scenario_findings()
        assert findings
        for f in findings:
            assert 0.0 <= f.est_savings_s <= f.est_current_s + 1e-15, f
            assert f.est_dcn_bytes_saved >= 0.0, f

    def test_sorted_errors_first_then_savings(self):
        findings = _all_scenario_findings()
        ranks = [(-severity_rank(f.severity), -f.est_savings_s)
                 for f in findings]
        # within one lint_ops call the order holds; across concatenated
        # scenario lists only the per-finding fields are checked here
        for f in findings:
            assert f.severity in ("info", "warn", "error")

    def test_rule_registry_matches_emitted_ids(self):
        ids = {r.rule_id for r in RULES}
        assert {f.rule_id for f in _all_scenario_findings()} <= ids

    def test_max_severity(self):
        assert max_severity([]) is None
        fs = [LintFinding("r", "warn", [], "", ""),
              LintFinding("r", "error", [], "", "")]
        assert max_severity(fs) == "error"

    def test_finding_dict_round_trip(self):
        for f in _all_scenario_findings():
            assert LintFinding.from_dict(
                json.loads(json.dumps(f.to_dict()))) == f


# ---------------------------------------------------------------------------
# whole-report integration: pod mesh DDP step end to end
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def pod_report():
    import jax
    import jax.numpy as jnp
    from repro.compat import make_mesh
    from repro.core import monitor_fn
    from repro.train import ddp

    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))

    def loss_fn(params, batch):
        pred = batch["x"] @ params["w"]
        return ((pred - batch["y"]) ** 2).mean(), {}

    step = ddp.make_ddp_train_step(loss_fn, mesh,
                                   axis_name=("pod", "data"),
                                   mode="bucketed", bucket_mb=1.0)
    f32 = jnp.float32
    params = {"w": jax.ShapeDtypeStruct((256, 256), f32)}
    mom = {"w": jax.ShapeDtypeStruct((256, 256), f32)}
    batch = {"x": jax.ShapeDtypeStruct((16, 256), f32),
             "y": jax.ShapeDtypeStruct((16, 256), f32)}
    return monitor_fn(step, params, mom, batch, mesh=mesh, name="podtoy")


class TestReportLint:
    pytestmark = pytest.mark.compile

    def test_flat_ring_flags_hierarchical_clean(self, pod_report):
        findings = pod_report.lint()
        assert "flat-ring-multipod" in {f.rule_id for f in findings}
        assert max_severity(findings) == "error"
        hier = pod_report.rebound("hierarchical").lint()
        assert max_severity(hier) not in ("error",)

    def test_lint_memoized_per_view(self, pod_report):
        v = pod_report.view()
        assert v.lint() is v.lint()

    def test_lint_table_renders(self, pod_report):
        out = pod_report.lint_table()
        assert "flat-ring-multipod" in out and "error" in out

    def test_schema_v7_round_trip(self, pod_report, tmp_path):
        p = str(tmp_path / "r.json")
        pod_report.save(p, include_lint=True)
        d = json.loads(open(p).read())
        assert d["schema"] == "repro.comm_report.v9"
        assert d["lint"], "lint section missing"
        from repro.core import CommReport
        back = CommReport.load(p)
        assert [f.to_dict() for f in back.lint()] == \
            [f.to_dict() for f in pod_report.lint()]

    def test_save_without_lint_has_no_section(self, pod_report, tmp_path):
        p = str(tmp_path / "r.json")
        pod_report.save(p)
        assert "lint" not in json.loads(open(p).read())

    def test_html_export_has_findings_panel(self, pod_report, tmp_path):
        from repro.core.export import html_exporter
        html = html_exporter.export_html(
            pod_report, str(tmp_path / "r.html"))
        text = open(html).read()
        assert "flat-ring-multipod" in text


class TestCleanConfig:
    pytestmark = pytest.mark.compile

    def test_serve_config_is_clean(self, tmp_path):
        """The serve workload (prefill/decode on a single-pod 4x2 mesh)
        triggers no rule -- the zero-findings baseline the CI gate relies
        on."""
        from repro import sweep as sweep_mod
        from repro.core.report_cache import ReportCache
        res = sweep_mod.run_sweep(
            ["serve"], ["4x2"], ["ring"],
            cache=ReportCache(root=str(tmp_path)), log=lambda m: None)
        assert not res.failures
        assert res.reports[0].lint() == []


# ---------------------------------------------------------------------------
# hierarchical-fallback warning dedup (decompose.warn_fallback_once)
# ---------------------------------------------------------------------------
def _decompose_warns(op, topo) -> bool:
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        decompose(op, "hierarchical", topo)
    return any(issubclass(x.category, HierarchicalFallbackWarning)
               for x in w)


class TestFallbackWarningDedup:
    def test_warns_once_per_kind_and_size(self):
        reset_fallback_warnings()
        op5 = _ar("%ar.0", groups=[[0, 1, 2, 3, 4]])
        assert _decompose_warns(op5, TOPO_PODS)
        assert not _decompose_warns(op5, TOPO_PODS)       # deduped
        # a different (kind, size) key warns afresh
        ag5 = CollectiveOp(kind="all-gather", name="%ag.0",
                           result_shapes=[Shape("f32", (40,))],
                           replica_groups=[[0, 1, 2, 3, 4]])
        assert _decompose_warns(ag5, TOPO_PODS)

    def test_reset_rearms(self):
        reset_fallback_warnings()
        op5 = _ar("%ar.0", groups=[[0, 1, 2, 3, 4]])
        assert _decompose_warns(op5, TOPO_PODS)
        reset_fallback_warnings()
        assert _decompose_warns(op5, TOPO_PODS)
