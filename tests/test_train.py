"""Training loop: learning, microbatch equivalence, DDP modes, compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CollectiveInterceptor
from repro.data import SyntheticImageData, SyntheticLMData
from repro.models import ModelConfig, build_model
from repro.models.resnet import ResNet18
from repro.optim import OptConfig
from repro.parallel import Sharder
from repro.train import TrainConfig, ddp, init_train_state
from repro.train.train import (batch_shardings, jit_train_step,
                               make_train_step, train_state_shardings)

pytestmark = pytest.mark.compile   # whole module drives XLA compiles

CFG = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128)


@pytest.fixture(scope="module")
def setup(mesh8):
    shd = Sharder(mesh8)
    model = build_model(CFG)
    ocfg = OptConfig(peak_lr=1e-2, warmup_steps=5, decay_steps=200)
    return shd, model, ocfg


class TestTrainStep:
    def test_loss_decreases(self, setup):
        shd, model, ocfg = setup
        step_fn, state_sh = jit_train_step(model, ocfg, TrainConfig(), shd,
                                           donate=False)
        state = jax.device_put(
            init_train_state(model, ocfg, jax.random.PRNGKey(0)), state_sh)
        data = SyntheticLMData(vocab_size=128, seq_len=32, global_batch=8)
        losses = []
        for i in range(25):
            state, m = step_fn(state, data.batch_at(i))
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0] - 0.2
        assert int(state["step"]) == 25

    def test_microbatch_equivalence(self, setup):
        """4 microbatches must produce (nearly) the same update as 1."""
        shd, model, ocfg = setup
        data = SyntheticLMData(vocab_size=128, seq_len=32, global_batch=8)
        batch = data.batch_at(0)
        out = {}
        for a in (1, 4):
            step_fn = jax.jit(make_train_step(
                model, ocfg, TrainConfig(microbatches=a), shd))
            state = init_train_state(model, ocfg, jax.random.PRNGKey(0))
            new_state, m = step_fn(state, batch)
            out[a] = (jax.tree.leaves(new_state["params"]),
                      float(m["loss"]))
        # microbatched grads reduce-scatter per microbatch (sharded
        # accumulator) -> different fp32 summation order; Adam amplifies the
        # roundoff on near-zero grads (untouched embedding rows), so a loose
        # elementwise tolerance + tight loss check is the right contract
        for l1, l4 in zip(out[1][0], out[4][0]):
            np.testing.assert_allclose(np.asarray(l1), np.asarray(l4),
                                       rtol=5e-2, atol=5e-3)
        # microbatches of 2 rows can't shard over data=4 -> different
        # reduction groupings; loss agrees to bf16-accumulation tolerance
        assert out[1][1] == pytest.approx(out[4][1], rel=1e-3)

    def test_bf16_grad_comm_mode_learns(self, setup):
        shd, model, ocfg = setup
        tcfg = TrainConfig(grad_dtype="bfloat16")
        step_fn, state_sh = jit_train_step(model, ocfg, tcfg, shd,
                                           donate=False)
        state = jax.device_put(
            init_train_state(model, ocfg, jax.random.PRNGKey(0)), state_sh)
        data = SyntheticLMData(vocab_size=128, seq_len=32, global_batch=8)
        l0 = None
        for i in range(15):
            state, m = step_fn(state, data.batch_at(i))
            l0 = l0 or float(m["loss"])
        assert float(m["loss"]) < l0


class TestDDP:
    """The paper's PyTorch-DDP scenario (Table 3): explicit collectives."""

    def _setup(self, mesh_dp):
        model = ResNet18(num_classes=10)
        params = model.init(jax.random.PRNGKey(0))
        data = SyntheticImageData(num_classes=10, global_batch=16,
                                  image_size=32)
        return model, params, data.batch_at(0)

    def test_bucketing_reduces_traced_calls(self, mesh_dp):
        model, params, batch = self._setup(mesh_dp)
        ef = ddp.init_error_feedback(params)
        counts = {}
        for mode in ("per_param", "bucketed"):
            step = ddp.make_ddp_train_step(model.loss_fn, mesh_dp, mode=mode,
                                           bucket_mb=1.0)
            with CollectiveInterceptor(mesh=mesh_dp) as icpt:
                step.lower(params, ef, batch)
            counts[mode] = sum(1 for e in icpt.events
                               if e.primitive == "psum")
        n_leaves = len(jax.tree.leaves(params))
        assert counts["per_param"] == n_leaves + 1     # +1 loss pmean
        assert counts["bucketed"] < counts["per_param"] / 2

    def test_modes_agree_numerically(self, mesh_dp):
        model, params, batch = self._setup(mesh_dp)
        ef = ddp.init_error_feedback(params)
        results = {}
        for mode in ("per_param", "bucketed"):
            step = ddp.make_ddp_train_step(model.loss_fn, mesh_dp, mode=mode)
            p2, _, loss = step(params, ef, batch)
            results[mode] = (jax.tree.leaves(p2), float(loss))
        assert results["per_param"][1] == pytest.approx(
            results["bucketed"][1], rel=1e-6)
        for a, b in zip(results["per_param"][0], results["bucketed"][0]):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-6)

    def test_compression_close_and_ef_nonzero(self, mesh_dp):
        model, params, batch = self._setup(mesh_dp)
        ef = ddp.init_error_feedback(params)
        exact = ddp.make_ddp_train_step(model.loss_fn, mesh_dp,
                                        mode="bucketed")
        comp = ddp.make_ddp_train_step(model.loss_fn, mesh_dp,
                                       mode="bucketed", compress=True)
        p_exact, _, _ = exact(params, ef, batch)
        p_comp, ef2, _ = comp(params, ef, batch)
        # bf16 wire compression stays close to exact
        for a, b in zip(jax.tree.leaves(p_exact), jax.tree.leaves(p_comp)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-2, atol=1e-4)
        # error feedback captured the quantization residual
        assert any(float(jnp.abs(e).max()) > 0
                   for e in jax.tree.leaves(ef2))

    def test_compiler_combines_allreduces(self, mesh_dp):
        """Beyond-paper: XLA's combiner does DDP bucketing automatically.

        Old jaxlibs never run the all-reduce combiner on CPU
        (``repro.compat.has_allreduce_combiner`` probes the actual
        behavior); there the same guarantee -- far fewer all-reduces than
        parameters -- must come from our explicit bucketed mode instead, so
        that is the path asserted.
        """
        from repro.compat import has_allreduce_combiner
        from repro.core import parse_hlo_collectives
        model, params, batch = self._setup(mesh_dp)
        ef = ddp.init_error_feedback(params)
        mode = "per_param" if has_allreduce_combiner() else "bucketed"
        step = ddp.make_ddp_train_step(model.loss_fn, mesh_dp, mode=mode,
                                       bucket_mb=4.0)
        hlo = step.lower(params, ef, batch).compile().as_text()
        ops = [o for o in parse_hlo_collectives(hlo)
               if o.kind == "all-reduce"]
        n_leaves = len(jax.tree.leaves(params))
        assert len(ops) < n_leaves / 4  # combined far below 1-per-tensor


class TestOptim:
    def test_adamw_matches_reference_quadratic(self):
        from repro.optim import apply_updates, init_opt_state
        ocfg = OptConfig(peak_lr=0.1, warmup_steps=0, decay_steps=10**9,
                         weight_decay=0.0, grad_clip=0.0, b1=0.9, b2=0.999)
        params = {"x": jnp.array([4.0])}
        state = init_opt_state(params, ocfg)
        # reference adam on f(x)=x^2/2
        m = v = 0.0
        x_ref = 4.0
        x = params
        for t in range(20):
            g = x_ref
            m = 0.9 * m + 0.1 * g
            v = 0.999 * v + 0.001 * g * g
            x_ref -= 0.1 * (m / (1 - 0.9**(t + 1))) / (
                np.sqrt(v / (1 - 0.999**(t + 1))) + 1e-8)
            x, state, _ = apply_updates(
                x, {"x": x["x"]}, state, ocfg, jnp.asarray(t))
        assert float(x["x"][0]) == pytest.approx(x_ref, rel=1e-4)

    def test_lr_schedule(self):
        from repro.optim import lr_at_step
        ocfg = OptConfig(peak_lr=1e-3, warmup_steps=100, decay_steps=1000,
                         min_lr_ratio=0.1)
        assert float(lr_at_step(ocfg, jnp.asarray(0))) < 1e-4
        assert float(lr_at_step(ocfg, jnp.asarray(99))) == pytest.approx(
            1e-3, rel=0.02)
        assert float(lr_at_step(ocfg, jnp.asarray(5000))) == pytest.approx(
            1e-4, rel=0.02)

    def test_grad_clip_bounds_update(self):
        from repro.optim import apply_updates, init_opt_state
        ocfg = OptConfig(peak_lr=1.0, warmup_steps=0, grad_clip=1.0,
                         weight_decay=0.0)
        params = {"x": jnp.zeros((4,))}
        state = init_opt_state(params, ocfg)
        huge = {"x": jnp.full((4,), 1e9)}
        _, _, stats = apply_updates(params, huge, state, ocfg,
                                    jnp.asarray(0))
        assert float(stats["grad_norm"]) == pytest.approx(2e9, rel=1e-3)

    def test_adafactor_state_is_factored(self):
        from repro.optim import init_opt_state
        ocfg = OptConfig(name="adafactor", factored_min_dim=8)
        params = {"w": jnp.zeros((16, 32)), "b": jnp.zeros((32,))}
        state = init_opt_state(params, ocfg)
        assert "vr" in state["w"] and state["w"]["vr"].shape == (16,)
        assert state["w"]["vc"].shape == (32,)
        assert "v" in state["b"]  # too small to factor
