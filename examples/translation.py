"""Paper §4.1: machine-translation model (GNMT-style), data-parallel +
monitored **per phase**, with per-primitive communication matrices (paper
Fig. 3) and the Table-2 breakdown split fwd / bwd / optim.

Trains the seq2seq model on a synthetic copy-reverse task (AdamW + bucketed
DDP AllReduce inside shard_map) until it learns, then monitors the step as a
three-phase :class:`~repro.core.session.MonitorSession`:

* ``fwd``   -- loss forward pass (+ the ``pmean`` loss all-reduce),
* ``bwd``   -- backward pass with the paper's bucketed gradient AllReduce,
* ``optim`` -- the AdamW update (local math: zero collectives -- visible as
  an empty row in the per-phase table, the point the paper's Table 2 cannot
  make because NCCL interception sees the whole step as one blob).

Run:  PYTHONPATH=src python examples/translation.py [--steps 150]
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
import argparse
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import MonitorSession
from repro.data import SyntheticSeq2Seq
from repro.models.gnmt import GNMT
from repro.optim import OptConfig, apply_updates, init_opt_state
from repro.train import ddp
from repro.compat import make_mesh, shard_map


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    args = ap.parse_args()

    mesh = make_mesh((8,), ("data",))
    model = GNMT(vocab=64, d=128, layers=2)
    params = model.init(jax.random.PRNGKey(0))
    data = SyntheticSeq2Seq(vocab_size=64, src_len=12, tgt_len=12,
                            global_batch=32)
    ocfg = OptConfig(peak_lr=3e-3, warmup_steps=10,
                     decay_steps=max(500, args.steps))
    opt = init_opt_state(params, ocfg)

    def step(params, opt, i, batch):
        (loss, _), grads = jax.value_and_grad(
            model.loss_fn, has_aux=True)(params, batch)
        # the paper's DDP pattern: bucketed AllReduce of every gradient
        grads, _ = ddp.allreduce_bucketed(grads, "data", bucket_mb=1.0)
        loss = jax.lax.pmean(loss, "data")
        params, opt, _ = apply_updates(params, grads, opt, ocfg, i)
        return params, opt, loss

    sharded_step = jax.jit(shard_map(
        step, mesh=mesh, in_specs=(P(), P(), P(), P("data")),
        out_specs=(P(), P(), P()), check_vma=False))

    l0 = None
    for i in range(args.steps):
        params, opt, loss = sharded_step(params, opt, jnp.asarray(i),
                                         data.batch_at(i))
        l0 = l0 if l0 is not None else float(loss)
        if i % 25 == 0 or i == args.steps - 1:
            print(f"step {i:4d} loss {float(loss):.4f}", flush=True)
    assert float(loss) < l0 * 0.7, "translation model failed to learn"

    # ------------------------------------------------------------------
    # one monitored step, split into its phases: fwd / bwd / optim
    # ------------------------------------------------------------------
    def fwd(params, batch):
        loss, _ = model.loss_fn(params, batch)
        return jax.lax.pmean(loss, "data")

    def bwd(params, batch):
        (_, _), grads = jax.value_and_grad(
            model.loss_fn, has_aux=True)(params, batch)
        grads, _ = ddp.allreduce_bucketed(grads, "data", bucket_mb=1.0)
        return grads

    def optim(params, grads, opt, i):
        params, opt, _ = apply_updates(params, grads, opt, ocfg, i)
        return params, opt

    def dp(fn, in_specs, out_specs):
        return shard_map(fn, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_vma=False)

    batch = data.batch_at(0)
    grads_like = params                    # same pytree shapes as the grads
    session = MonitorSession(mesh=mesh, name="GNMT-MT")
    with session:
        with session.phase("fwd"):
            session.capture(dp(fwd, (P(), P("data")), P()), params, batch)
        with session.phase("bwd"):
            session.capture(dp(bwd, (P(), P("data")), P()), params, batch)
        with session.phase("optim"):
            session.capture(
                dp(optim, (P(), P(), P(), P()), (P(), P())),
                params, grads_like, opt, jnp.asarray(0))

    rep = session.report()
    print()
    print(rep.phase_table())               # Table 2, per phase
    print()
    print(rep.phase_diff("fwd", "bwd"))    # where the bytes come from
    for phase in rep.phase_names():
        view = rep.view(phase=phase)
        if view.total_wire_bytes() == 0:
            print(f"\nphase {phase}: no collective communication "
                  "(local math only)")
            continue
        print()
        print(rep.heatmap(phase=phase))
    rep.save("artifacts/translation_report.json")
    print(f"\ntranslation example OK (loss {l0:.3f} -> {float(loss):.3f})")


if __name__ == "__main__":
    main()
