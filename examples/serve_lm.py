"""Serve a small LM with batched requests and a monitored serve session.

Uses the qwen3-family reduced config on a (data=4, model=2) mesh: prefill
the prompt batch, decode N tokens, then monitor prefill AND decode as the
two named phases of one :class:`MonitorSession` -- the per-phase tables
show the prefill all-gather-heavy profile next to the decode TP-psum
profile (the same cells ``python -m repro sweep --configs serve
--by-phase`` sweeps).

Run:  PYTHONPATH=src python examples/serve_lm.py [--tokens 24]
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
import argparse
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro import configs
from repro.core import MonitorSession
from repro.models import build_model
from repro.parallel import Sharder
from repro.serve import ServeConfig, cache_shardings, generate
from repro.compat import make_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_8b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=24)
    args = ap.parse_args()

    mesh = make_mesh((4, 2), ("data", "model"))
    shd = Sharder(mesh)
    cfg = configs.config(args.arch, reduced=True)
    model = build_model(cfg)
    params_sh = shd.tree_shardings(model.shapes(), model.axes())
    params = jax.device_put(model.init(jax.random.PRNGKey(0)), params_sh)

    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    t0 = time.perf_counter()
    out = generate(model, params, prompts, shd, steps=args.tokens,
                   max_len=args.prompt_len + args.tokens)
    dt = time.perf_counter() - t0
    print(f"served {args.batch} requests x {args.tokens} tokens in {dt:.1f}s "
          f"({args.batch*args.tokens/dt:.1f} tok/s incl. compile)")
    print("sample completion ids:", out[0, :12].tolist())

    # prefill/decode communication profile: one two-phase session over
    # ShapeDtypeStruct stand-ins (no allocation, nothing executes)
    max_len = args.prompt_len + args.tokens
    scfg = ServeConfig(max_len=max_len, batch=args.batch)
    cache_sh = cache_shardings(model, scfg, shd)
    cache_shapes = model.cache_shapes(args.batch, max_len)
    sess = MonitorSession(mesh=mesh, name=f"serve[{cfg.name}]")
    with sess:
        with sess.phase("prefill"):
            sess.capture(
                lambda p, b: model.prefill(p, b, shd, max_len=max_len),
                model.shapes(),
                {"tokens": jax.ShapeDtypeStruct(
                    (args.batch, args.prompt_len), jnp.int32)},
                name="prefill", out_shardings=(None, cache_sh))
        with sess.phase("decode"):
            sess.capture(
                lambda p, c, b: model.decode_step(p, c, b, shd),
                model.shapes(), cache_shapes,
                {"tokens": jax.ShapeDtypeStruct((args.batch, 1),
                                                jnp.int32)},
                name="decode", in_shardings=(None, cache_sh, None),
                out_shardings=(None, cache_sh))
    rep = sess.report()
    print()
    print(rep.phase_table())
    print(rep.phase_diff("prefill", "decode"))
    print(rep.heatmap(phase="decode"))
    print("serving example OK")


if __name__ == "__main__":
    main()
