"""Quickstart: monitor the collective communication of ANY jitted function.

The one-call workflow (paper Fig. 1, TPU edition):

    report = monitor_fn(step, *args, mesh=mesh, in_shardings=...)
    print(report.render())

Run:  python -m repro monitor examples/quickstart.py
(or directly: PYTHONPATH=src python examples/quickstart.py)
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import monitor_fn, roofline_of
from repro.compat import make_mesh


def main():
    # an 8-device (data=4, model=2) mesh on forced host devices
    mesh = make_mesh((4, 2), ("data", "model"))

    # a model-parallel train step the user wants to understand
    def train_step(w1, w2, x):
        h = jax.nn.relu(x @ w1)          # w1 column-sharded (TP)
        y = h @ w2                       # w2 row-sharded -> psum
        loss = (y ** 2).mean()
        return loss

    grad = jax.value_and_grad(train_step, argnums=(0, 1))
    shard = lambda *spec: NamedSharding(mesh, P(*spec))

    # ShapeDtypeStructs: nothing is allocated — works at any model size
    report = monitor_fn(
        grad,
        jax.ShapeDtypeStruct((1024, 4096), jnp.float32),   # w1
        jax.ShapeDtypeStruct((4096, 1024), jnp.float32),   # w2
        jax.ShapeDtypeStruct((512, 1024), jnp.float32),    # x
        mesh=mesh, name="quickstart",
        in_shardings=(shard(None, "model"), shard("model", None),
                      shard("data", None)),
    )

    print(report.render())

    # the three-term roofline for a hypothetical TPU v5e deployment
    rl = roofline_of(report, arch="2-layer-mlp", mesh_name="4x2",
                     model_flops=6 * (1024 * 4096 * 2) * 512)
    print()
    print(f"roofline: compute {rl.compute_s:.3e}s | memory "
          f"{rl.memory_s:.3e}s | collective {rl.collective_s:.3e}s")
    print(rl.one_liner())

    # persist + browser/Perfetto renderings via the export subsystem;
    # re-export later without recompiling:
    #   python -m repro report artifacts/quickstart_report.json --formats csv
    from repro.core import export
    report.save("artifacts/quickstart_report.json")
    export.export_html(report, "artifacts/quickstart_report.html")
    export.export_perfetto(report, "artifacts/quickstart_report.trace.json")
    print("\nreport written to artifacts/quickstart_report.{json,html,"
          "trace.json}")


if __name__ == "__main__":
    main()
