"""Irregular collectives: a skewed MoE all-to-all, monitored per phase.

Expert-parallel MoE routes token buffers between ranks with an all-to-all;
when the router runs hot (one expert drawing most of the tokens), the
per-rank byte counts become *irregular* -- and a scalar per-op byte model
flattens the hot expert into the group mean.  This walkthrough monitors a
small expert-parallel dispatch/combine program, injects the measured
routing skew through the capture's ``op_transform`` hook, and shows every
artifact that consumes the per-rank byte vector:

* the comm-matrix heatmap (the hot expert's row glows),
* the Table-2 summary (new skew column),
* the timed schedule (the collective finishes at the hot rank's pace),
* the ``skewed-a2a`` lint finding (priced vs a load-balanced routing).

Run:  PYTHONPATH=src python examples/moe_skew.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
import dataclasses
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import make_mesh, shard_map
from repro.core import MonitorSession
from repro.core.reporter import (ascii_heatmap, lint_table,
                                 primitive_usage_table)

N_EXPERTS = 8          # one expert per rank
CAP = 64               # tokens per (source, expert) capacity slot
D = 128                # token width
HOT_FRAC = 0.6         # expert 0 handles 60% of all tokens


def build_program(mesh):
    """Dispatch + expert MLP + combine, one expert per data-axis rank."""
    n = N_EXPERTS

    def step(tokens, wi, wo):
        # tokens local: (n, CAP, D) -- row e holds this rank's tokens
        # bound for expert e (capacity-padded dense dispatch buffers)
        recv = jax.lax.all_to_all(tokens, "data", 0, 0)        # dispatch
        h = jax.nn.silu(recv.reshape(n * CAP, D) @ wi) @ wo    # expert MLP
        return jax.lax.all_to_all(h.reshape(n, CAP, D),
                                  "data", 0, 0)                # combine

    return shard_map(step, mesh=mesh,
                     in_specs=(P("data"), P(), P()),
                     out_specs=P("data"), check_vma=False)


def hot_expert_transform(op):
    """Attach the measured routing: 60% of the bytes live on rank 0.

    The compiled HLO sizes the a2a for the *capacity* -- the worst case --
    because XLA cannot know the routing.  At runtime the router decides,
    and this hook is where that knowledge enters the model: a per-rank
    byte vector whose sum is the op's payload, with ``HOT_FRAC`` of it on
    the hot expert's rank.
    """
    if op.kind not in ("all-to-all", "ragged-all-to-all"):
        return op
    m = op.group_size
    total = float(op.payload_bytes)
    vec = [total * (1.0 - HOT_FRAC) / (m - 1)] * m
    vec[0] = total * HOT_FRAC
    return dataclasses.replace(op, bytes_per_rank_vec=vec)


def main():
    mesh = make_mesh((N_EXPERTS,), ("data",))
    prog = build_program(mesh)
    f32 = jnp.float32
    tokens = jax.ShapeDtypeStruct((N_EXPERTS * N_EXPERTS, CAP, D), f32)
    wi = jax.ShapeDtypeStruct((D, 2 * D), f32)
    wo = jax.ShapeDtypeStruct((2 * D, D), f32)

    # --- phase 1: the balanced baseline (no transform: scalar bytes) ----
    with MonitorSession(mesh=mesh, name="moe") as sess:
        with sess.phase("balanced"):
            sess.capture(prog, tokens, wi, wo, name="moe_balanced")
        # --- phase 2: the same program with the measured hot routing ----
        with sess.phase("skewed"):
            sess.capture(prog, tokens, wi, wo, name="moe_skewed",
                         op_transform=hot_expert_transform)

    for phase in ("balanced", "skewed"):
        view = sess.view(phase=phase)
        print()
        print(primitive_usage_table(view.summary, title=f"{phase} dispatch"))
        print()
        print(ascii_heatmap(view.matrix, title=f"{phase} comm matrix"))

    # the skewed phase's a2a finishes when rank 0 does; the balanced one
    # spreads the same bytes evenly
    bal = sess.view(phase="balanced").collective_seconds()
    skw = sess.view(phase="skewed").collective_seconds()
    print(f"\nmodeled collective time: balanced {bal * 1e6:.2f} us, "
          f"skewed {skw * 1e6:.2f} us "
          f"({skw / bal:.2f}x -- the hot rank is the straggler)")

    # the lint pass prices exactly that gap as the rebalancing savings
    findings = [f for f in sess.view().lint() if f.rule_id == "skewed-a2a"]
    print()
    print(lint_table(findings, title="skewed-a2a findings"))


if __name__ == "__main__":
    main()
