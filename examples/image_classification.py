"""Paper §4.2: data-parallel ResNet-18 image classification, monitored.

End-to-end driver: REALLY trains ResNet-18 on synthetic 64x64 images across
8 data-parallel devices with explicit DDP gradient sync, then uses the
monitor to explain the communication — including the paper's gradient
bucketing experiment.

Run:  PYTHONPATH=src python examples/image_classification.py [--steps 100]
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
import argparse
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.core import CollectiveInterceptor
from repro.data import SyntheticImageData
from repro.models.resnet import ResNet18
from repro.train import ddp
from repro.compat import make_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--classes", type=int, default=8)
    # paper uses 64x64; default 32 keeps the XLA:CPU collective rendezvous
    # comfortable on oversubscribed host devices (use --image-size 64 on
    # real hardware)
    ap.add_argument("--image-size", type=int, default=32)
    args = ap.parse_args()

    mesh = make_mesh((8,), ("data",))
    model = ResNet18(num_classes=args.classes)
    params = model.init(jax.random.PRNGKey(0))
    data = SyntheticImageData(num_classes=args.classes,
                              global_batch=args.batch,
                              image_size=args.image_size)
    ef = ddp.init_error_feedback(params)

    step = ddp.make_ddp_train_step(model.loss_fn, mesh, mode="bucketed",
                                   bucket_mb=25.0, lr=5e-2)

    # count application-issued collectives exactly as the paper does
    with CollectiveInterceptor(mesh=mesh) as icpt:
        step.lower(params, ef, data.batch_at(0))
    ar_per_step = sum(1 for e in icpt.events if e.primitive == "psum")

    eval_acc = jax.jit(lambda p, b: model.loss_fn(p, b)[1]["acc"])
    t0 = time.perf_counter()
    acc = None
    for i in range(args.steps):
        batch = data.batch_at(i)
        params, ef, loss = step(params, ef, batch)
        loss = float(loss)  # sync before anything else touches the devices
        if i % 10 == 0 or i == args.steps - 1:
            acc = float(eval_acc(params, batch))
            print(f"step {i:4d} loss {loss:.4f} acc {acc:.2f} "
                  f"({time.perf_counter()-t0:.1f}s)", flush=True)
    print(f"\nAllReduce calls per step (bucketed, 25 MiB): {ar_per_step}")
    print(f"-> one epoch of {args.steps} steps issues "
          f"{ar_per_step * args.steps} AllReduce calls "
          "(paper Table 3 accounting)")
    assert acc is not None and acc > 0.5, "model failed to learn"
    print("image classification example OK")


if __name__ == "__main__":
    main()
