"""Per-link utilization: where the bytes of one collective actually travel.

Beyond-paper benchmark for the physical-link subsystem: runs the same
data-parallel all-reduce program on a single-pod mesh and on a two-pod
(DCN-joined) mesh, then projects each algorithm's communication matrix onto
the physical ICI / DCN links.  The table shows what the logical ``(d+1)^2``
matrix hides:

* ring edges between non-neighbour torus coordinates become multi-hop ICI
  transit traffic (link bytes > matrix bytes),
* a hierarchical all-reduce puts only the ``S/m`` shard exchange on DCN
  uplinks, while ring/tree across pods push full per-rank payloads through
  the slow tier -- visible directly in the bottleneck-link milliseconds,
* the tier-overlap bound (ici ∥ dcn) never exceeds the serialized
  collective time, and only the hierarchical algorithm keeps both tiers
  busy at once.

The run doubles as the CI perf smoke: every emitted metric lands in
``artifacts/BENCH_link.json`` so the perf trajectory is machine-readable.
"""
import json
import os

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from benchmarks.common import ARTIFACTS, emit
from repro.compat import make_mesh, shard_map
from repro.core import monitor_fn
from repro.core.reporter import format_table, human_bytes


def _program(mesh):
    def step(x):
        g = jax.lax.psum(x, tuple(mesh.axis_names))
        return (x * g).sum()

    return shard_map(step, mesh=mesh,
                     in_specs=P(mesh.axis_names[0]),
                     out_specs=P(), check_vma=False)


def main():
    meshes = {
        "8 (one pod)": make_mesh((8,), ("data",)),
        "2x2x2 (two pods)": make_mesh((2, 2, 2), ("pod", "data", "model")),
    }
    rows = []
    raw: dict[tuple, dict] = {}          # (mesh, alg) -> unrounded seconds
    metrics: dict[str, float] = {}

    def record(name, value, derived=""):
        metrics[name] = float(value)
        emit(name, value, derived)

    for mesh_name, mesh in meshes.items():
        rep = monitor_fn(_program(mesh),
                         jax.ShapeDtypeStruct((8, 4096), jnp.float32),
                         mesh=mesh, name=f"links@{mesh_name}")
        for alg in ("ring", "tree", "hierarchical"):
            lu = rep.link_utilization(alg)
            bn = lu.bottleneck()
            matrix_bytes = rep.view(alg).matrix[1:, 1:].sum()
            ici_s, dcn_s = rep.collective_seconds_split(alg)
            overlap_ms = max(ici_s, dcn_s) * 1e3
            serial_ms = (ici_s + dcn_s) * 1e3
            raw[(mesh_name, alg)] = {
                "ici_s": ici_s, "dcn_s": dcn_s,
                "bottleneck_s": bn[1] if bn else 0.0}
            rows.append([
                mesh_name, alg,
                human_bytes(matrix_bytes),
                human_bytes(lu.total_bytes("ici")),
                human_bytes(lu.total_bytes("dcn")),
                bn[0].name if bn else "-",
                f"{bn[1] * 1e3:.4f}" if bn else "-",
                f"{overlap_ms:.4f}",
                f"{serial_ms:.4f}",
            ])
            record(f"links/{mesh_name}/{alg}/ici_bytes",
                   lu.total_bytes("ici"), "physical_link_bytes")
            record(f"links/{mesh_name}/{alg}/dcn_bytes",
                   lu.total_bytes("dcn"), "physical_link_bytes")
            record(f"links/{mesh_name}/{alg}/bottleneck_ms",
                   (bn[1] * 1e3) if bn else 0.0, "contention_bound")
            record(f"links/{mesh_name}/{alg}/overlap_ms",
                   overlap_ms, "tier_overlap_bound")
            record(f"links/{mesh_name}/{alg}/serialized_ms",
                   serial_ms, "serialized_collective_time")
    print(format_table(rows, [
        "mesh", "algorithm", "matrix bytes", "ICI link bytes",
        "DCN link bytes", "bottleneck link", "bottleneck ms",
        "overlap ms", "serialized ms"]))

    # invariants the table is meant to exhibit (asserted on the raw
    # seconds, not the 4-decimal table strings)
    by_key = {(r[0], r[1]): r for r in rows}
    hier = by_key[("2x2x2 (two pods)", "hierarchical")]
    assert hier[4] != "0 B", "hierarchical must use DCN on a two-pod mesh"
    assert raw[("2x2x2 (two pods)", "hierarchical")]["bottleneck_s"] <= \
        raw[("2x2x2 (two pods)", "ring")]["bottleneck_s"], \
        "hierarchical must not be slower than ring across DCN"
    one_pod = [r for r in rows if r[0] == "8 (one pod)"]
    assert all(r[4] == "0 B" for r in one_pod), "no DCN traffic inside a pod"
    for v in raw.values():
        assert max(v["ici_s"], v["dcn_s"]) <= v["ici_s"] + v["dcn_s"] + 1e-15, \
            "tier-overlap bound must not exceed the serialized time"
    h = raw[("2x2x2 (two pods)", "hierarchical")]
    assert h["ici_s"] > 0 and h["dcn_s"] > 0, \
        "hierarchical must keep both tiers busy (strict overlap win)"
    print("[links] per-link utilization + overlap invariants hold")

    out = os.path.join(ARTIFACTS, "BENCH_link.json")
    os.makedirs(ARTIFACTS, exist_ok=True)
    with open(out, "w") as f:
        json.dump({"benchmark": "link_utilization", "metrics": metrics}, f,
                  indent=2, sort_keys=True)
    print(f"[links] wrote {out}")


if __name__ == "__main__":
    main()
