"""§Roofline: render the full (arch x shape x mesh) table from dry-run
artifacts (artifacts/dryrun/*.json).  Emits markdown for EXPERIMENTS.md."""
import glob
import json
import os

from benchmarks.common import ARTIFACTS, emit
from repro.core.reporter import format_table, human_bytes

HINTS = {
    "compute": "less remat recompute / larger fused matmuls",
    "memory": "cut HBM traffic: fuse, bf16, better remat, weight-stationary",
    "collective": "cut wire bytes: resharding, bf16 comms, overlap",
}


def load_rows(mesh="single", tag=""):
    # prefer the optimized sweep; fall back to the baseline artifacts
    for d in ("dryrun_final", "dryrun"):
        rows = []
        for f in sorted(glob.glob(os.path.join(ARTIFACTS, d,
                                               f"*_{mesh}{tag}.json"))):
            if tag == "" and not f.endswith(f"_{mesh}.json"):
                continue
            rows.append(json.load(open(f)))
        if rows:
            return rows
    return []


def main():
    rows = load_rows("single")
    if not rows:
        print("[roofline] no dry-run artifacts found — run "
              "`python -m repro.launch.dryrun --all` first")
        return
    table = []
    md = ["| arch | shape | mem/dev | compute_s | memory_s | collective_s | "
          "dominant | MODEL/HLO flops | bound |",
          "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        rl = r["roofline"]
        bound = max(rl["compute_s"], rl["memory_s"], rl["collective_s"])
        frac = rl["compute_s"] / bound if bound else 0
        table.append([
            r["arch"], r["shape"],
            human_bytes(r["memory"]["total_bytes"]),
            f"{rl['compute_s']:.3e}", f"{rl['memory_s']:.3e}",
            f"{rl['collective_s']:.3e}", rl["dominant"],
            f"{rl['useful_flops_ratio']:.2f}", f"{frac:.3f}"])
        md.append("| " + " | ".join(table[-1]) + " |")
        emit(f"roofline/{r['arch']}/{r['shape']}", bound,
             f"dominant={rl['dominant']},compute_frac={frac:.4f}")
    print("== §Roofline: single-pod (16x16 = 256 chips), per-cell "
          "3-term analysis ==")
    print(format_table(table, ["arch", "shape", "mem/dev", "compute_s",
                               "memory_s", "collective_s", "dominant",
                               "useful", "roofline frac"]))
    multi = load_rows("multi")
    print(f"\nmulti-pod (2x16x16 = 512 chips): {len(multi)}/{len(rows)} "
          "cells compiled OK "
          + ("(all)" if len(multi) == len(rows) else "(INCOMPLETE)"))
    out = os.path.join(ARTIFACTS, "roofline_table.md")
    with open(out, "w") as f:
        f.write("\n".join(md) + "\n")
    print(f"[roofline] wrote {out}")


if __name__ == "__main__":
    main()
