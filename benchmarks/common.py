"""Shared benchmark scaffolding: every benchmark prints a paper-style table
and emits ``name,value,derived`` CSV rows for machine consumption."""
import os
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "artifacts")
CSV_ROWS: list[str] = []


def emit(name: str, value, derived: str = ""):
    row = f"{name},{value},{derived}"
    CSV_ROWS.append(row)
    return row


def mesh_dp(n=8):
    from repro.compat import make_mesh
    return make_mesh((n,), ("data",))


def mesh_2d(shape=(4, 2)):
    from repro.compat import make_mesh
    return make_mesh(shape, ("data", "model"))


def flush_csv(path: str):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        f.write("name,value,derived\n")
        for row in CSV_ROWS:
            f.write(row + "\n")
