"""Paper Table 3 + §4.2: ResNet-18 DDP gradient bucketing.

The paper shows PyTorch's gradient bucketing reduces ncclAllReduce calls from
the naive D x N (one per parameter per iteration).  We sweep:

* naive per-parameter AllReduce,
* bucketed (PyTorch-style, 1 MiB and 25 MiB buckets),
* bf16-compressed buckets (beyond paper: halves wire bytes),

counting *traced* (application) calls — the paper's measurement — and
*compiled* ops, where XLA's all-reduce combiner performs automatic bucketing
(beyond-paper finding: the compiler gives you Table 3's optimization for
free on TPU).
"""
import jax

from benchmarks.common import emit, mesh_dp
from repro.core import CollectiveInterceptor, parse_hlo_collectives
from repro.core.reporter import format_table, human_bytes
from repro.data import SyntheticImageData
from repro.models.resnet import ResNet18
from repro.train import ddp


def main():
    mesh = mesh_dp(8)
    model = ResNet18(num_classes=200)
    params = model.init(jax.random.PRNGKey(0))
    n_params = sum(p.size for p in jax.tree.leaves(params))
    data = SyntheticImageData(num_classes=200, global_batch=32,
                              image_size=64)
    batch = data.batch_at(0)
    ef = ddp.init_error_feedback(params)

    rows = []
    for label, mode, bucket_mb, compress in (
            ("naive per-param", "per_param", 0, False),
            ("bucketed 1 MiB", "bucketed", 1.0, False),
            ("bucketed 25 MiB (PyTorch)", "bucketed", 25.0, False),
            ("bucketed 25 MiB + bf16+EF", "bucketed", 25.0, True)):
        step = ddp.make_ddp_train_step(model.loss_fn, mesh, mode=mode,
                                       bucket_mb=bucket_mb,
                                       compress=compress)
        with CollectiveInterceptor(mesh=mesh) as icpt:
            lowered = step.lower(params, ef, batch)
        traced = sum(1 for e in icpt.events if e.primitive == "psum")
        traced_bytes = sum(e.payload_bytes for e in icpt.events
                           if e.primitive == "psum")
        ops = [o for o in parse_hlo_collectives(lowered.compile().as_text())
               if o.kind == "all-reduce"]
        compiled_bytes = sum(o.payload_bytes for o in ops)
        rows.append([label, f"{traced:,}", human_bytes(traced_bytes * 8),
                     f"{len(ops):,}", human_bytes(compiled_bytes * 8)])
        emit(f"table3/{mode}_{bucket_mb}_{compress}", traced,
             f"compiled={len(ops)},wire_bytes={compiled_bytes*8}")

    print(f"== Table 3: ResNet-18 ({n_params/1e6:.1f}M params) DDP gradient "
          "sync on 8 devices, one step ==")
    print(format_table(rows, ["gradient sync", "traced AllReduce",
                              "traced bytes (x8 ranks)",
                              "compiled all-reduce", "compiled bytes"]))
    naive, b25 = int(rows[0][1].replace(",", "")), \
        int(rows[2][1].replace(",", ""))
    assert b25 < naive / 4, "bucketing must reduce call count >=4x"
    print(f"[table3] bucketing reduces application AllReduce calls "
          f"{naive} -> {b25} (paper's claim); the XLA combiner further "
          f"merges to {rows[0][3]} compiled op(s) even for naive code "
          "(beyond paper)")


if __name__ == "__main__":
    main()
