"""Paper Table 2 + Fig 2: communication profile of data-parallel GNMT.

Trains the machine-translation model data-parallel with explicit DDP
collectives (+ an initial parameter Broadcast and a metrics AllGather, as in
the paper's app), monitors it, and prints:

* the Table-2 style primitive usage table (calls, total size),
* the Fig-2 combined (d+1)^2 communication matrix (log-scale ASCII),
* the traced-vs-compiled diff (beyond paper: what XLA actually schedules).
"""
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from benchmarks.common import emit, mesh_dp
from repro.core import CollectiveInterceptor, monitor_fn
from repro.core.events import HostTransfer
from repro.data import SyntheticSeq2Seq, host_transfer_log
from repro.models.gnmt import GNMT
from repro.train import ddp
from repro.compat import shard_map


def build(mesh):
    model = GNMT(vocab=2048, d=128, layers=2)
    params = model.init(jax.random.PRNGKey(0))
    data = SyntheticSeq2Seq(vocab_size=2048, src_len=24, tgt_len=24,
                            global_batch=16)
    return model, params, data


def training_program(model, mesh):
    """One 'epoch': Broadcast params, N DDP steps, AllGather metrics."""
    def epoch(params, batches):
        # initial parameter broadcast (root -> all), as DDP does at startup;
        # NCCL Broadcast has no jax primitive — modeled as AllGather + take
        # rank-0's copy (recorded under AllGather; DESIGN.md §8)
        params = jax.tree.map(
            lambda p: jax.lax.all_gather(p, "data")[0], params)

        def one(params, batch):
            (loss, _), grads = jax.value_and_grad(
                model.loss_fn, has_aux=True)(params, batch)
            grads, _ = ddp.allreduce_bucketed(grads, "data", bucket_mb=1.0)
            params = jax.tree.map(
                lambda p, g: p - 1e-2 * g.astype(p.dtype), params, grads)
            return params, loss

        params, losses = jax.lax.scan(one, params, batches)
        metrics = jax.lax.all_gather(losses, "data")
        return params, metrics

    return shard_map(epoch, mesh=mesh,
                         in_specs=(P(), P(None, "data")),
                         out_specs=(P(), P()), check_vma=False)


def main():
    mesh = mesh_dp(8)
    model, params, data = build(mesh)
    steps = 16
    batches = jax.tree.map(
        lambda *xs: jnp.stack(xs),
        *[data.batch_at(i) for i in range(steps)])

    transfers = [HostTransfer("h2d", d % 8, int(t.nbytes / 8), t.label)
                 for d in range(8) for t in host_transfer_log()]
    rep = monitor_fn(training_program(model, mesh), params, batches,
                     mesh=mesh, name="GNMT-DP(8)",
                     host_transfers=transfers)
    print(rep.logical_table())
    print()
    print(rep.usage_table())
    print()
    print(rep.heatmap())
    print()
    print("-- traced vs compiled --")
    print(rep.diff())
    rep.save("artifacts/gnmt_report.json")

    for name, row in rep.traced_summary.items():
        emit(f"table2/traced/{name}", row["calls"],
             f"payload={row['payload_bytes']}")
    for kind, row in rep.compiled_summary.items():
        emit(f"table2/compiled/{kind}", row["calls"],
             f"payload={row['payload_bytes']}")

    # paper's qualitative claim: AllReduce dominates collective traffic
    # (execution-weighted — per-step gradient sync vs one-time broadcast)
    ar = rep.compiled_summary.get("all-reduce", {"wire_bytes": 0})
    others = sum(v["wire_bytes"] for k, v in rep.compiled_summary.items()
                 if k != "all-reduce")
    assert ar["wire_bytes"] > others, \
        f"expected AllReduce to dominate (paper §4.1): {rep.compiled_summary}"
    print(f"[table2] AllReduce dominates wire traffic: "
          f"{ar['wire_bytes']:,.0f} B vs {others:,.0f} B for all other "
          "primitives over a 16-step epoch (paper Fig. 3 claim)")


if __name__ == "__main__":
    main()
