"""Paper Fig. 3: one communication matrix per collective primitive.

Runs a program that uses AllReduce, AllGather (the paper's Broadcast role)
and AllToAll, then renders each primitive's (d+1)^2 matrix separately —
showing, as the paper does, that different primitives induce different
pair-wise traffic even on the same devices.
"""
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from benchmarks.common import emit, mesh_dp
from repro.core import monitor_fn
from repro.compat import shard_map


def main():
    mesh = mesh_dp(8)

    def program(x):
        a = jax.lax.psum(x, "data")                       # AllReduce
        b = jax.lax.all_gather(x, "data")                 # AllGather
        c = jax.lax.all_to_all(x, "data", split_axis=0,
                               concat_axis=0, tiled=True)  # AllToAll
        d = jax.lax.ppermute(x, "data",
                             [(i, (i + 1) % 8) for i in range(8)])
        return a.sum() + b.sum() + c.sum() + d.sum()

    prog = shard_map(program, mesh=mesh, in_specs=P("data"),
                         out_specs=P(), check_vma=False)
    rep = monitor_fn(prog, jax.ShapeDtypeStruct((64, 256), jnp.float32),
                     mesh=mesh, name="Fig3")
    for kind, mat in sorted(rep.per_primitive.items()):
        print(rep.heatmap(kind))
        print()
        emit(f"fig3/{kind}", float(mat.sum()), "matrix_total_bytes")
    assert set(rep.per_primitive) >= {"all-reduce", "all-gather",
                                      "all-to-all", "collective-permute"}
    print("[fig3] per-primitive matrices rendered")


if __name__ == "__main__":
    main()
