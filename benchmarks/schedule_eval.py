"""Batched schedule evaluation vs the per-op decompose loop.

A monitored training step replays the same few collective *shapes*
thousands of times (every layer's all-reduce is byte-identical; an MoE
layer repeats one skewed all-to-all per step).  The batched engine --
signature-memoized :func:`~repro.core.decompose.cached_decompose`,
deduping :func:`~repro.core.decompose.schedules_for_ops`, columnar
:class:`~repro.core.decompose.ScheduleBatch` -- runs decompose -> place ->
bill -> time once per *distinct* shape instead of once per op.

This benchmark times the full derived-artifact build (dense comm matrix +
execution-weighted per-tier time split) both ways on repeated-shape
streams (regular kinds + irregular hot-expert all-to-all) at 256 / 1024
devices x 2k / 10k ops, asserts **bitwise** agreement, and requires the
acceptance bar: **>= 3x end-to-end on the 10k-op cells**.  Every batched
run starts from cleared caches, so the speedup measures within-stream
dedup + columnar math, not leftover warm state.

Metrics land in ``artifacts/BENCH_schedule.json``; the fast CI job runs
this module and the guard asserts the batched path stays within **1.5x**
of the recorded per-op-normalized baseline on the 1024dev/10k-op cell.
"""
import dataclasses
import json
import os
import time

import numpy as np

from benchmarks.common import ARTIFACTS, emit
from repro.core import comm_matrix
from repro.core.decompose import (ScheduleBatch, clear_schedule_cache,
                                  decompose, schedule_cache)
from repro.core.cost_models import clear_billing_caches
from repro.core.events import CollectiveOp, Shape
from repro.core.reporter import format_table
from repro.core.topology import MeshTopology

REGULAR_KINDS = ("all-reduce", "all-gather", "reduce-scatter",
                 "collective-broadcast", "all-to-all",
                 "collective-permute")


def _prototypes(num_devices: int, seed: int, pool: int = 32):
    """``pool`` distinct op shapes: 3/4 regular kinds over partition
    groups, 1/4 irregular all-to-all with a hot-expert byte vector."""
    rng = np.random.default_rng(seed)
    protos = []
    n_irregular = pool // 4
    for i in range(pool - n_irregular):
        kind = REGULAR_KINDS[int(rng.integers(len(REGULAR_KINDS)))]
        elems = int(rng.integers(1, 1 << 14))
        if kind == "collective-permute":
            perm = rng.permutation(num_devices)
            pairs = [(int(perm[j]), int(perm[(j + 1) % len(perm)]))
                     for j in range(len(perm))]
            protos.append(CollectiveOp(
                kind=kind, name=f"proto{i}",
                result_shapes=[Shape("f32", (elems,))],
                replica_groups=[], source_target_pairs=pairs))
            continue
        sizes = ((4, 8, 16) if kind == "all-to-all"
                 else (8, 16, 64, num_devices))
        gsize = int(rng.choice([s for s in sizes if s <= num_devices]))
        devs = rng.permutation(num_devices)
        groups = [sorted(int(d) for d in devs[k:k + gsize])
                  for k in range(0, num_devices, gsize)]
        protos.append(CollectiveOp(
            kind=kind, name=f"proto{i}",
            result_shapes=[Shape("f32", (elems,))],
            replica_groups=groups))
    for i in range(n_irregular):
        gsize = int(rng.choice((4, 8, 16)))
        devs = rng.permutation(num_devices)
        groups = [sorted(int(d) for d in devs[k:k + gsize])
                  for k in range(0, num_devices, gsize)]
        total = float(rng.integers(1 << 10, 1 << 20))
        vec = rng.random(gsize) + 0.1
        vec[int(rng.integers(gsize))] *= 8.0          # the hot expert
        vec = vec / vec.sum() * total
        protos.append(CollectiveOp(
            kind="all-to-all", name=f"iproto{i}",
            result_shapes=[Shape("f32", (1,))],
            replica_groups=groups,
            bytes_per_rank_vec=[float(x) for x in vec]))
    return protos


def repeated_ops(num_ops: int, num_devices: int,
                 seed: int = 0) -> list[CollectiveOp]:
    """A repeated-shape stream: ``num_ops`` draws from a 32-prototype
    pool, each with a fresh name and loop-trip weight (neither enters the
    memoization signature, so a training loop's layer-repeated collectives
    dedupe to the pool)."""
    rng = np.random.default_rng(seed + 1)
    protos = _prototypes(num_devices, seed)
    return [dataclasses.replace(
        protos[int(rng.integers(len(protos)))], name=f"op{i}",
        weight=float(rng.integers(1, 65))) for i in range(num_ops)]


def per_op_eval(ops, num_devices: int, topo):
    """The pre-batching oracle: decompose EVERY op, place and time it
    individually.  Mirrors the replaced code paths exactly -- per-op
    ``np.add.at`` flushes in op order, sequential weighted time sums."""
    mat = np.zeros((num_devices + 1, num_devices + 1), dtype=np.float64)
    ici = dcn = 0.0
    for op in ops:
        sched = decompose(op, "ring", topo, warn=False)
        src, dst, val = comm_matrix.schedule_edge_arrays(sched)
        w = max(1.0, getattr(op, "weight", 1.0))
        if src.size:
            keep = (src < num_devices) & (dst < num_devices)
            np.add.at(mat, (src[keep] + 1, dst[keep] + 1), val[keep] * w)
        i, d = sched.time_split(topo)
        ici += i * w
        dcn += d * w
    return mat, (ici, dcn)


def batched_eval(ops, num_devices: int, topo):
    """The engine under test, cold: cleared schedule/billing caches, then
    the production view path -- ONE :class:`ScheduleBatch` feeding both
    the matrix build and the columnar time split (exactly how
    ``CommView.schedule_batch`` shares the IR across its artifacts)."""
    clear_schedule_cache()
    clear_billing_caches()
    batch = ScheduleBatch.from_ops(ops, "ring", topo, warn=False)
    mat = comm_matrix.matrix_for_schedules(ops, batch, num_devices)
    split = batch.total_time_split(topo)
    return mat, split


def _time(fn, repeats: int = 3) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _baseline_guard(metrics: dict[str, float]) -> None:
    """Fast-CI perf guard, per-op-loop-normalized (the loop's time on the
    same machine is the yardstick, so the guard compares code, not runner
    hardware): the batched path's speedup on the 1024dev/10k-op cell must
    stay within 1.5x of the recorded ``BENCH_schedule.json`` baseline."""
    path = os.path.join(ARTIFACTS, "BENCH_schedule.json")
    if not os.path.exists(path):
        print("[schedule] no recorded baseline; skipping the 1.5x guard")
        return
    try:
        with open(path) as f:
            base = json.load(f)["metrics"]
        base_speedup = base["schedule_eval/1024dev/10000ops/speedup"]
    except (KeyError, ValueError, OSError):
        print("[schedule] unreadable baseline; skipping the 1.5x guard")
        return
    cur_speedup = metrics["schedule_eval/1024dev/10000ops/speedup"]
    ratio = base_speedup / cur_speedup
    assert ratio <= 1.5, (
        f"batched engine regressed to {ratio:.2f}x the recorded baseline "
        f"on the 1024dev/10k-op cell (speedup {cur_speedup:.1f}x now vs "
        f"{base_speedup:.1f}x recorded; allowed: 1.5x)")
    print(f"[schedule] baseline guard OK: {ratio:.2f}x the recorded "
          f"per-op-normalized batched time (limit 1.5x)")


def main():
    cases = [  # (devices, ops); the 10k cells are the acceptance bar
        (256, 2000),
        (256, 10000),
        (1024, 2000),
        (1024, 10000),
    ]
    rows = []
    metrics: dict[str, float] = {}

    def record(name, value, derived=""):
        metrics[name] = float(value)
        emit(name, value, derived)

    accept = {}
    for num_devices, num_ops in cases:
        side = int(round(num_devices ** 0.5))
        topo = MeshTopology(axis_names=("data", "model"),
                            axis_sizes=(side, num_devices // side))
        ops = repeated_ops(num_ops, num_devices)

        ref_mat, ref_split = per_op_eval(ops, num_devices, topo)
        bat_mat, bat_split = batched_eval(ops, num_devices, topo)
        assert np.array_equal(ref_mat, bat_mat), \
            f"matrix mismatch at {num_devices}dev/{num_ops}ops"
        assert ref_split == bat_split, \
            f"time-split mismatch at {num_devices}dev/{num_ops}ops: " \
            f"{ref_split} vs {bat_split}"
        distinct = schedule_cache().misses or len(schedule_cache())

        t_ref = _time(lambda: per_op_eval(ops, num_devices, topo),
                      repeats=1)
        t_bat = _time(lambda: batched_eval(ops, num_devices, topo))
        speedup = t_ref / t_bat
        if num_ops == 10000:
            accept[num_devices] = speedup
        rows.append([f"{num_devices}", f"{num_ops:,}", f"{distinct}",
                     f"{t_ref * 1e3:.1f}", f"{t_bat * 1e3:.1f}",
                     f"{speedup:.1f}x"])
        tag = f"schedule_eval/{num_devices}dev/{num_ops}ops"
        record(f"{tag}/per_op_ms", t_ref * 1e3, "per_op_decompose_loop")
        record(f"{tag}/batched_ms", t_bat * 1e3,
               "memoized_columnar_engine")
        record(f"{tag}/speedup", speedup, "per_op_ms/batched_ms")

    print(format_table(rows, ["devices", "ops", "distinct shapes",
                              "per-op ms", "batched ms", "speedup"]))
    for dev, sp in accept.items():
        assert sp >= 3.0, (
            f"batched engine must be >= 3x the per-op loop on the "
            f"{dev}dev/10k-op repeated-shape stream (got {sp:.1f}x)")
    print(f"[schedule] batched engine bitwise-matches the per-op loop and "
          f"is {min(accept.values()):.1f}x+ faster on the 10k-op cells")
    _baseline_guard(metrics)      # vs the recorded artifact, pre-overwrite

    out = os.path.join(ARTIFACTS, "BENCH_schedule.json")
    os.makedirs(ARTIFACTS, exist_ok=True)
    with open(out, "w") as f:
        json.dump({"benchmark": "schedule_eval", "metrics": metrics}, f,
                  indent=2, sort_keys=True)
    print(f"[schedule] wrote {out}")


if __name__ == "__main__":
    main()
