"""Fleet-scale sparse-matrix benchmark: the ``sweep --scale-curve`` engine.

The dense ``(d+1)^2`` matrix is ~2 GiB of float64 at 16384 devices; the
sparse COO path exists so fleet-scale points never allocate it.  This
benchmark pins that claim with numbers:

* **equivalence** at 1024 devices: the sparse build's ``to_dense()`` must
  equal the dense builder element-exact on the shared synthetic op stream;
* **build timings**: sparse build time at 1024 / 4096 / 16384 devices
  (dense only at 1024 -- the normalization anchor, see the guard);
* **peak memory** at 16384 devices: ``tracemalloc`` peak of the sparse
  build + link projection must stay far below the 2.1 GiB dense matrix
  (asserted < 400 MiB);
* **scale curve**: a DDP-shaped base op stream projected over
  256 -> 16384 devices must show monotonically non-decreasing bottleneck-
  link time (more devices, never a faster bottleneck at fixed payload).

Every metric lands in ``artifacts/BENCH_scale.json``; the fast CI job
asserts ``scale_curve/1024dev/sparse_over_dense`` stays within **1.5x of
the recorded baseline** -- sparse time normalized by dense time on the
same machine, so the guard compares code, not runner hardware.
"""
import json
import os
import time
import tracemalloc
import types

import numpy as np

from benchmarks.common import ARTIFACTS, emit
from benchmarks.matrix_build import _time, synthetic_ops
from repro import scale
from repro.core import comm_matrix
from repro.core.events import CollectiveOp, Shape
from repro.core.reporter import format_table

# tracemalloc bound for the 16k-device sparse build + projection: far under
# the ~2.1 GiB the dense (16385)^2 float64 matrix alone would need
PEAK_LIMIT_MB = 400.0


def ddp_base_ops(num_ops: int = 24, base_devices: int = 8,
                 seed: int = 2) -> list[CollectiveOp]:
    """A DDP-shaped base stream: bucketed AllReduce over the whole base
    mesh plus a metrics AllGather -- the op mix ``sweep --scale-curve``
    projects for the paper configs."""
    rng = np.random.default_rng(seed)
    group = [list(range(base_devices))]
    ops = []
    for i in range(num_ops):
        kind = "all-reduce" if i % 4 else "all-gather"
        ops.append(CollectiveOp(
            kind=kind, name=f"ddp{i}",
            result_shapes=[Shape("f32", (int(rng.integers(1 << 10,
                                                          1 << 16)),))],
            replica_groups=group, weight=float(rng.integers(1, 9))))
    return ops


def _fleet_sparse_build(ops, num_devices):
    topo = scale.fleet_topology(num_devices)
    mat = comm_matrix.matrix_for_ops(ops, num_devices, topo=topo,
                                     sparse=True)
    return comm_matrix.project_links(mat, topo), mat


def main():
    rows = []
    metrics: dict[str, float] = {}

    def record(name, value, derived=""):
        metrics[name] = float(value)
        emit(name, value, derived)

    # -- equivalence + the normalization anchor at 1024 devices ------------
    ops1k = synthetic_ops(500, 1024)
    dense = comm_matrix.matrix_for_ops(ops1k, 1024)
    sparse = comm_matrix.matrix_for_ops(ops1k, 1024, sparse=True)
    np.testing.assert_array_equal(sparse.to_dense(), dense)
    t_dense = _time(lambda: comm_matrix.matrix_for_ops(ops1k, 1024))
    t_sparse = _time(lambda: comm_matrix.matrix_for_ops(ops1k, 1024,
                                                        sparse=True))
    ratio = t_sparse / t_dense
    assert ratio <= 1.5, (
        f"sparse build is {ratio:.2f}x the dense build at 1024 devices "
        f"(acceptance bar: 1.5x -- the counting-sort coalesce should keep "
        f"COO accumulation within range of np.add.at)")
    rows.append(["1024", "500", f"{t_dense * 1e3:.1f}",
                 f"{t_sparse * 1e3:.1f}", f"{sparse.nnz:,}"])
    record("scale_curve/1024dev/dense_ms", t_dense * 1e3, "dense_np_add_at")
    record("scale_curve/1024dev/sparse_ms", t_sparse * 1e3, "coo_coalesce")
    record("scale_curve/1024dev/sparse_over_dense", ratio,
           "sparse_ms/dense_ms")
    print(f"[scale] sparse == dense element-exact at 1024 devices "
          f"({sparse.nnz:,} nnz); sparse/dense build ratio {ratio:.2f}x")

    # -- sparse-only build timings at fleet sizes --------------------------
    base = ddp_base_ops()
    for d in (1024, 4096, 16384):
        ops = scale.scale_ops(base, 8, d)
        t = _time(lambda: _fleet_sparse_build(ops, d), repeats=1)
        _, mat = _fleet_sparse_build(ops, d)
        rows.append([f"{d}", f"{len(ops)}", "-", f"{t * 1e3:.1f}",
                     f"{mat.nnz:,}"])
        record(f"scale_curve/{d}dev/sparse_build_ms", t * 1e3,
               "build_plus_link_projection")

    # -- peak memory at 16k: no dense (d+1)^2 anywhere ---------------------
    ops16k = scale.scale_ops(base, 8, 16384)
    tracemalloc.start()
    _fleet_sparse_build(ops16k, 16384)
    _, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    peak_mb = peak / 2**20
    record("scale_curve/16384dev/peak_mb", peak_mb, "tracemalloc_peak")
    assert peak_mb < PEAK_LIMIT_MB, (
        f"16384-device sparse build peaked at {peak_mb:.0f} MiB "
        f"(limit {PEAK_LIMIT_MB:.0f} MiB -- the dense matrix alone is "
        "~2100 MiB, so something materialized it)")
    print(f"[scale] 16384-device peak memory {peak_mb:.0f} MiB "
          f"(limit {PEAK_LIMIT_MB:.0f}; dense would be ~2100)")

    # -- the curve itself: bottleneck must never shrink with scale ---------
    rep = types.SimpleNamespace(compiled_ops=base, num_devices=8,
                                algorithm="ring", name="ddp_bench",
                                meta={"config": "ddp_bench"})
    points = scale.scale_curve([rep], (256, 1024, 4096, 16384))
    bns = [p.bottleneck_ms for p in points]
    assert all(b1 >= b0 * (1 - 1e-9) for b0, b1 in zip(bns, bns[1:])), (
        f"bottleneck-link ms must grow monotonically with fleet size, "
        f"got {bns}")
    for p in points:
        record(f"scale_curve/curve/{p.devices}dev/bottleneck_ms",
               p.bottleneck_ms, p.bottleneck_link)
        record(f"scale_curve/curve/{p.devices}dev/overlap_ms", p.overlap_ms,
               "max(ici,dcn)")
    print("[scale] curve bottleneck-link ms monotone over "
          + " -> ".join(f"{p.devices}" for p in points))
    print(scale.scale_table(points))

    print(format_table(rows, ["devices", "ops", "dense ms", "sparse ms",
                              "nnz"]))
    _baseline_guard(metrics)      # vs the recorded artifact, pre-overwrite

    out = os.path.join(ARTIFACTS, "BENCH_scale.json")
    os.makedirs(ARTIFACTS, exist_ok=True)
    with open(out, "w") as f:
        json.dump({"benchmark": "scale_curve", "metrics": metrics}, f,
                  indent=2, sort_keys=True)
    print(f"[scale] wrote {out}")


def _baseline_guard(metrics: dict[str, float]) -> None:
    """Fast-CI perf guard: the sparse build must stay within 1.5x of the
    recorded ``artifacts/BENCH_scale.json`` baseline on the 1024-device
    cell, normalized by the dense build's time on the SAME machine."""
    path = os.path.join(ARTIFACTS, "BENCH_scale.json")
    if not os.path.exists(path):
        print("[scale] no recorded baseline; skipping the 1.5x guard")
        return
    try:
        with open(path) as f:
            base = json.load(f)["metrics"]
        base_ratio = base["scale_curve/1024dev/sparse_over_dense"]
    except (KeyError, ValueError, OSError):
        print("[scale] unreadable baseline; skipping the 1.5x guard")
        return
    cur_ratio = metrics["scale_curve/1024dev/sparse_over_dense"]
    rel = cur_ratio / base_ratio
    assert rel <= 1.5, (
        f"sparse build regressed to {rel:.2f}x the recorded baseline on "
        f"the 1024-device cell (sparse/dense {cur_ratio:.2f} now vs "
        f"{base_ratio:.2f} recorded; allowed: 1.5x)")
    print(f"[scale] baseline guard OK: {rel:.2f}x the recorded "
          f"dense-normalized sparse time (limit 1.5x)")


if __name__ == "__main__":
    main()
