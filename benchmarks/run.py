"""Benchmark harness: one entry per paper table/figure + the roofline report.

The CLI front door (preferred):

  python -m repro bench                              # all
  python -m repro bench table3                       # one

Direct invocation still works:

  PYTHONPATH=src python -m benchmarks.run [names...]

Benchmarks that record a committed ``artifacts/BENCH_*.json`` baseline get
a **baseline-vs-current** comparison table at the end of the run: the
recorded metrics are snapshotted before any benchmark overwrites its
artifact, and each shared metric prints baseline / current / ratio.
"""
import glob
import json
import os
import sys
import time
import traceback

from benchmarks import common

BENCHES = ("table1", "table2", "table3", "fig3", "links", "matrix",
           "schedule", "overhead", "roofline", "scale", "trace")

_MODS = {
    "table1": "benchmarks.table1_collective_bytes",
    "table2": "benchmarks.table2_gnmt",
    "table3": "benchmarks.table3_resnet_bucketing",
    "fig3": "benchmarks.fig3_per_primitive",
    "links": "benchmarks.link_utilization",
    "matrix": "benchmarks.matrix_build",
    "schedule": "benchmarks.schedule_eval",
    "overhead": "benchmarks.overhead",
    "roofline": "benchmarks.roofline_table",
    "scale": "benchmarks.scale_curve",
    "trace": "benchmarks.trace_ingest",
}


def _read_bench_metrics() -> dict[str, float]:
    """Every metric in the committed ``artifacts/BENCH_*.json`` files."""
    merged: dict[str, float] = {}
    for path in sorted(glob.glob(os.path.join(common.ARTIFACTS,
                                              "BENCH_*.json"))):
        try:
            with open(path) as f:
                merged.update(json.load(f).get("metrics", {}))
        except (ValueError, OSError):
            continue
    return merged


def _comparison_table(baseline: dict[str, float],
                      current: dict[str, float]) -> None:
    """Print metric / baseline / current / ratio for every metric present
    both before and after the run (new metrics are listed as such)."""
    from repro.core.reporter import format_table

    shared = sorted(set(baseline) & set(current))
    fresh = sorted(set(current) - set(baseline))
    if not shared and not fresh:
        return
    rows = []
    for m in shared:
        b, c = baseline[m], current[m]
        ratio = c / b if b else float("inf")
        rows.append([m, f"{b:.3f}", f"{c:.3f}", f"{ratio:.2f}x"])
    for m in fresh:
        rows.append([m, "-", f"{current[m]:.3f}", "new"])
    print("\n== baseline vs current (BENCH_*.json) ==")
    print(format_table(rows, ["metric", "baseline", "current", "ratio"]))


def run_one(name: str) -> bool:
    import importlib
    mod = _MODS[name]
    print(f"\n{'='*72}\n## {name} ({mod})\n{'='*72}")
    t0 = time.perf_counter()
    try:
        importlib.import_module(mod).main()
        print(f"[{name}] PASS in {time.perf_counter()-t0:.1f}s")
        return True
    except Exception:
        traceback.print_exc()
        print(f"[{name}] FAIL")
        return False


def main(names=None) -> int:
    if names is None:               # direct invocation: read our own argv
        names = sys.argv[1:]
    todo = list(names) or list(BENCHES)
    unknown = [n for n in todo if n not in BENCHES]
    if unknown:
        print(f"unknown benchmark(s) {unknown}; known: {list(BENCHES)}",
              file=sys.stderr)
        return 2
    baseline = _read_bench_metrics()      # before any artifact overwrite
    results = {name: run_one(name) for name in todo}
    common.flush_csv("artifacts/benchmarks.csv")
    _comparison_table(baseline, _read_bench_metrics())
    print("\n== benchmark summary ==")
    for name, ok in results.items():
        print(f"  {name:10s} {'PASS' if ok else 'FAIL'}")
    return 0 if all(results.values()) else 1


if __name__ == "__main__":
    raise SystemExit(main())
