"""Benchmark harness: one entry per paper table/figure + the roofline report.

The CLI front door (preferred):

  python -m repro bench                              # all
  python -m repro bench table3                       # one

Direct invocation still works:

  PYTHONPATH=src python -m benchmarks.run [names...]
"""
import sys
import time
import traceback

from benchmarks import common

BENCHES = ("table1", "table2", "table3", "fig3", "links", "matrix",
           "overhead", "roofline", "trace")


def run_one(name: str) -> bool:
    import importlib
    mod = {
        "table1": "benchmarks.table1_collective_bytes",
        "table2": "benchmarks.table2_gnmt",
        "table3": "benchmarks.table3_resnet_bucketing",
        "fig3": "benchmarks.fig3_per_primitive",
        "links": "benchmarks.link_utilization",
        "matrix": "benchmarks.matrix_build",
        "overhead": "benchmarks.overhead",
        "roofline": "benchmarks.roofline_table",
        "trace": "benchmarks.trace_ingest",
    }[name]
    print(f"\n{'='*72}\n## {name} ({mod})\n{'='*72}")
    t0 = time.perf_counter()
    try:
        importlib.import_module(mod).main()
        print(f"[{name}] PASS in {time.perf_counter()-t0:.1f}s")
        return True
    except Exception:
        traceback.print_exc()
        print(f"[{name}] FAIL")
        return False


def main(names=None) -> int:
    if names is None:               # direct invocation: read our own argv
        names = sys.argv[1:]
    todo = list(names) or list(BENCHES)
    unknown = [n for n in todo if n not in BENCHES]
    if unknown:
        print(f"unknown benchmark(s) {unknown}; known: {list(BENCHES)}",
              file=sys.stderr)
        return 2
    results = {name: run_one(name) for name in todo}
    common.flush_csv("artifacts/benchmarks.csv")
    print("\n== benchmark summary ==")
    for name, ok in results.items():
        print(f"  {name:10s} {'PASS' if ok else 'FAIL'}")
    return 0 if all(results.values()) else 1


if __name__ == "__main__":
    raise SystemExit(main())
