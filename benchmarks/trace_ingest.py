"""Trace-ingestion throughput: events/sec through ``load_trace``.

A monitored fleet job emits six-figure event counts per trace; the
importer has to chew through them at parser-bound speed, not op-builder
speed.  This benchmark generates a synthetic 100k-event JSONL trace
(mixed collective kinds across 64 devices, per-rank observations merged
by correlation id, h2d/d2h rows in the stream), runs it through the full
:func:`repro.core.trace.load_trace` pipeline -- sniff, parse, validate,
cluster, build ops -- and reports events/sec.

Raw events/sec is not comparable across runner hardware, so the guard is
normalized by a bare ``json.loads``-per-line pass over the same file on
the same machine (the floor any JSONL parser pays): the importer must
stay within **1.5x of the recorded overhead ratio** in
``artifacts/BENCH_trace.json``, which this run rewrites.
"""
import json
import os
import time

from benchmarks.common import ARTIFACTS, emit
from repro.core.reporter import format_table
from repro.core.trace import load_trace

NUM_EVENTS = 100_000
NUM_DEVICES = 64
RANKS_PER_COLLECTIVE = 4       # observations sharing one corr id

KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
         "collective-broadcast")


def synthetic_trace(path: str, num_events: int = NUM_EVENTS) -> int:
    """A deterministic JSONL trace shaped like a long fleet profile:
    every collective is observed from RANKS_PER_COLLECTIVE ranks (rows
    sharing a corr id), with a sprinkle of host transfers."""
    lines = [json.dumps({"trace": {
        "name": "bench", "num_devices": NUM_DEVICES, "time_unit": "us"}})]
    i = 0
    corr = 0
    while i < num_events:
        if corr % 13 == 12:                   # ~2% host-transfer rows
            lines.append(json.dumps({
                "kind": "h2d" if corr % 2 else "d2h",
                "device": corr % NUM_DEVICES, "bytes": 4096}))
            i += 1
            if i >= num_events:
                break
        kind = KINDS[corr % len(KINDS)]
        base = (corr * RANKS_PER_COLLECTIVE) % NUM_DEVICES
        group = [(base + r) % NUM_DEVICES
                 for r in range(RANKS_PER_COLLECTIVE)]
        nbytes = 1024 << (corr % 12)
        for r in sorted(group):
            lines.append(json.dumps({
                "kind": kind, "name": f"{kind}.{corr}", "device": r,
                "dur": 100.0 + (corr % 7), "bytes": nbytes,
                "corr": corr, "group": sorted(group),
                "phase": "fwd" if corr % 3 else "bwd"}))
            i += 1
            if i >= num_events:
                break
        corr += 1
    with open(path, "w") as f:
        f.write("\n".join(lines) + "\n")
    return len(lines) - 1                      # events, sans header


def _time(fn, repeats: int = 5) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _json_floor(path: str) -> float:
    """The bare per-line ``json.loads`` pass -- the parser floor that
    normalizes the guard across runner hardware."""
    def run():
        with open(path) as f:
            for line in f:
                json.loads(line)
    return _time(run)


def _baseline_guard(metrics: dict) -> None:
    """Fast-CI perf guard: the importer's overhead over the raw
    ``json.loads`` floor must stay within 1.5x of the recorded
    ``artifacts/BENCH_trace.json`` baseline."""
    path = os.path.join(ARTIFACTS, "BENCH_trace.json")
    if not os.path.exists(path):
        print("[trace] no recorded baseline; skipping the 1.5x guard")
        return
    try:
        with open(path) as f:
            base = json.load(f)["metrics"]
        base_overhead = base["trace_ingest/100000ev/overhead_vs_json"]
    except (KeyError, ValueError, OSError):
        print("[trace] unreadable baseline; skipping the 1.5x guard")
        return
    cur = metrics["trace_ingest/100000ev/overhead_vs_json"]
    ratio = cur / base_overhead
    assert ratio <= 1.5, (
        f"trace importer regressed to {ratio:.2f}x the recorded baseline "
        f"(overhead {cur:.1f}x the raw json.loads floor now vs "
        f"{base_overhead:.1f}x recorded; allowed: 1.5x)")
    print(f"[trace] baseline guard OK: {ratio:.2f}x the recorded "
          f"json-normalized ingest time (limit 1.5x)")


def main():
    os.makedirs(ARTIFACTS, exist_ok=True)
    trace_path = os.path.join(ARTIFACTS, "bench_trace.jsonl")
    n = synthetic_trace(trace_path)

    imp = load_trace(trace_path)
    assert imp.num_devices == NUM_DEVICES
    assert imp.ops, "importer produced no ops from the synthetic trace"
    assert all(op.measured_s is not None for op in imp.ops)
    # clustering contract: RANKS_PER_COLLECTIVE rows -> one op (the
    # final cluster may be truncated by the event budget)
    n_transfer = len(imp.host_transfers)
    n_coll = n - n_transfer
    assert n_transfer > 0
    assert len(imp.ops) == -(-n_coll // RANKS_PER_COLLECTIVE)

    t_ingest = _time(lambda: load_trace(trace_path))
    t_json = _json_floor(trace_path)
    ev_per_s = n / t_ingest
    overhead = t_ingest / t_json

    metrics = {}

    def record(name, value, derived=""):
        metrics[name] = float(value)
        emit(name, value, derived)

    tag = f"trace_ingest/{NUM_EVENTS}ev"
    record(f"{tag}/ingest_ms", t_ingest * 1e3, "full_load_trace")
    record(f"{tag}/json_floor_ms", t_json * 1e3, "raw_json_loads_pass")
    record(f"{tag}/events_per_sec", ev_per_s, "events/ingest_seconds")
    record(f"{tag}/overhead_vs_json", overhead, "ingest_ms/json_floor_ms")
    record(f"{tag}/ops_built", len(imp.ops), "clustered_collectives")

    print(format_table(
        [[f"{n:,}", f"{t_json * 1e3:.1f}", f"{t_ingest * 1e3:.1f}",
          f"{ev_per_s / 1e3:.0f}k", f"{overhead:.1f}x",
          f"{len(imp.ops):,}"]],
        ["events", "json ms", "ingest ms", "ev/s", "overhead", "ops"]))
    _baseline_guard(metrics)      # vs the recorded artifact, pre-overwrite

    out = os.path.join(ARTIFACTS, "BENCH_trace.json")
    with open(out, "w") as f:
        json.dump({"benchmark": "trace_ingest", "metrics": metrics}, f,
                  indent=2, sort_keys=True)
    print(f"[trace] wrote {out}")
    os.remove(trace_path)


if __name__ == "__main__":
    main()
