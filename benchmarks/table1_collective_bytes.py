"""Paper Table 1: per-rank bytes by collective algorithm.

Validates our algorithm cost models against ground truth measured from
compiled HLO: for each primitive and communicator size N we lower an
explicit collective of payload S, parse the compiled module, and compare
the analytic per-rank wire bytes against the published ring formulas
(2(N-1)S/N for AllReduce, (N-1)S/N for AG/RS) plus the tree/hierarchical
entries the paper tabulates.
"""
import time

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from benchmarks.common import emit, mesh_dp
from repro.core import (hlo_parser, parse_hlo_collectives,
                        table1_allreduce_bytes, wire_bytes_per_rank)
from repro.core.reporter import format_table, human_bytes
from repro.compat import shard_map


def measured_payload(kind: str, n: int, elems: int) -> float:
    """Lower one explicit collective; return parsed payload bytes S."""
    mesh = mesh_dp(n)

    def f(x):
        if kind == "all-reduce":
            return jax.lax.psum(x, "data")
        if kind == "all-gather":
            return jax.lax.all_gather(x, "data")
        if kind == "reduce-scatter":
            return jax.lax.psum_scatter(x, "data", scatter_dimension=0,
                                        tiled=True)
        return jax.lax.all_to_all(x, "data", split_axis=0, concat_axis=0,
                                  tiled=True)

    g = jax.jit(shard_map(f, mesh=mesh, in_specs=P("data"),
                              out_specs=P("data"), check_vma=False))
    # global shape chosen so the collective's logical payload S is exactly
    # elems*4 bytes per group in every case
    shape = (n * elems,) if kind in ("all-reduce", "reduce-scatter") \
        else (elems,)
    hlo = g.lower(jax.ShapeDtypeStruct(shape, jnp.float32)) \
        .compile().as_text()
    ops = [o for o in parse_hlo_collectives(hlo) if o.kind == kind]
    assert ops, f"no {kind} found"
    return float(ops[0].payload_bytes)


def main():
    t0 = time.perf_counter()
    print("== Table 1: per-rank wire bytes by algorithm "
          "(model vs published formula vs HLO payload) ==")
    rows = []
    elems = 1 << 16
    s_bytes = elems * 4
    for n in (2, 4, 8):
        for kind in ("all-reduce", "all-gather", "reduce-scatter",
                     "all-to-all"):
            model = wire_bytes_per_rank(kind, s_bytes, n, "ring")
            if kind == "all-reduce":
                published = table1_allreduce_bytes(n, s_bytes, "ring")
            elif kind in ("all-gather", "reduce-scatter"):
                published = (n - 1) * s_bytes / n
            else:
                published = (n - 1) * s_bytes / (n * n)
            meas_payload = measured_payload(kind, n, elems)
            ok = abs(model - published) < 1e-6
            # HLO payload should equal S (the logical collective size)
            ok_s = abs(meas_payload - s_bytes) / s_bytes < 0.01
            rows.append([kind, n, human_bytes(s_bytes), human_bytes(model),
                         human_bytes(published),
                         human_bytes(meas_payload),
                         "OK" if (ok and ok_s) else "MISMATCH"])
            emit(f"table1/{kind}/n{n}", model,
                 f"published={published},hlo_payload={meas_payload}")
    # tree + hierarchical entries (analytic, paper-published)
    for n in (8, 16):
        for alg, role in (("tree", "other"), ("tree", "root"),
                          ("collnet", "intranode"), ("collnet", "internode")):
            v = table1_allreduce_bytes(n, s_bytes, alg, role)
            rows.append([f"all-reduce[{alg}/{role}]", n,
                         human_bytes(s_bytes), human_bytes(v), "=", "-",
                         "paper"])
            emit(f"table1/allreduce_{alg}_{role}/n{n}", v, "")
    print(format_table(rows, ["primitive", "N", "S", "model/rank",
                              "published", "HLO payload", "check"]))
    us = (time.perf_counter() - t0) * 1e6
    emit("table1/total", us, "us_total")
    assert all(r[-1] in ("OK", "paper") for r in rows), "Table 1 mismatch"
    print(f"[table1] all entries match ({us/1e6:.1f}s)")


if __name__ == "__main__":
    main()
