"""Paper §4: monitoring overhead (ComScribe: 1.4x at runtime).

Ours splits into:
* trace-time overhead — the interceptor's bind hooks run once per trace;
* steady-state overhead — ZERO by construction: the compiled binary is
  unchanged; we verify by timing the same compiled function before/after
  monitoring and by checking executable fingerprints.
"""
import time

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from benchmarks.common import emit, mesh_dp
from repro.core import CollectiveInterceptor
from repro.models.resnet import ResNet18
from repro.data import SyntheticImageData
from repro.train import ddp


def main():
    mesh = mesh_dp(8)
    model = ResNet18(num_classes=64)
    params = model.init(jax.random.PRNGKey(0))
    batch = SyntheticImageData(num_classes=64, global_batch=16,
                               image_size=32).batch_at(0)
    ef = ddp.init_error_feedback(params)
    step = ddp.make_ddp_train_step(model.loss_fn, mesh, mode="bucketed")

    # --- trace-time overhead -------------------------------------------
    def trace_once():
        t0 = time.perf_counter()
        step.lower(params, ef, batch)
        return time.perf_counter() - t0

    trace_once()  # warm caches
    base = min(trace_once() for _ in range(3))
    with CollectiveInterceptor(mesh=mesh):
        hooked = min(trace_once() for _ in range(3))
    trace_ovh = hooked / base
    emit("overhead/trace", trace_ovh, f"base={base:.3f}s hooked={hooked:.3f}s")

    # --- steady-state overhead ------------------------------------------
    compiled = step.lower(params, ef, batch).compile()
    with CollectiveInterceptor(mesh=mesh):
        compiled_mon = step.lower(params, ef, batch).compile()
    same_binary = compiled.as_text() == compiled_mon.as_text()

    def run(c):
        t0 = time.perf_counter()
        for _ in range(3):
            jax.block_until_ready(c(params, ef, batch))
        return (time.perf_counter() - t0) / 3

    run(compiled)
    t_plain = min(run(compiled) for _ in range(3))
    t_mon = min(run(compiled_mon) for _ in range(3))
    steady = t_mon / t_plain
    emit("overhead/steady_state", steady,
         f"identical_binary={same_binary}")

    print("== Monitoring overhead (paper: 1.4x at runtime) ==")
    print(f"trace-time   : {trace_ovh:.3f}x  "
          f"({base*1e3:.0f} ms -> {hooked*1e3:.0f} ms, once per jit)")
    print(f"steady-state : {steady:.3f}x  (compiled binary identical: "
          f"{same_binary})")
    assert same_binary, "monitoring must not change the compiled program"
    assert trace_ovh < 2.0, f"trace overhead too high: {trace_ovh}"
    print("[overhead] steady-state monitoring cost is structurally 0x — "
          "interception happens at trace, the binary is unchanged "
          "(improves on the paper's 1.4x)")


if __name__ == "__main__":
    main()
