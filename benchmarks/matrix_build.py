"""Matrix-accumulation throughput: COO-batched vs per-edge Python loop.

The session API accumulates thousands of weighted collective ops across a
whole run; building the ``(d+1)^2`` matrix from them used to walk a Python
tuple per edge.  ``comm_matrix.matrix_for_ops`` now generates per-op COO
edge arrays and flushes batched buffers with a single ``np.add.at`` per
flush; ``matrix_for_ops_reference`` keeps the old loop as the oracle.

This benchmark times both on synthetic op streams (mixed primitive kinds,
randomized groups/payloads/weights -- the same generator the property test
uses) at 64 / 256 / 1024 devices, asserts exact agreement, and requires the
acceptance bar: **>= 2.5x speedup on a 10k-op stream at 256 devices**
(every op here carries freshly-permuted groups, so this doubles as the
worst case for the memoizing schedule front-end -- see the bar's comment
in ``main``; repeated-shape streams are ``benchmarks/schedule_eval.py``).

A **multi-axis schedule case** rides along: the same 256 devices as a
16x16 torus with full-mesh replica groups, built through the per-axis
decomposition schedules (one ring phase per torus axis -- the placement
with zero intra-pod transit inflation), timing the topology-aware path and
asserting its row sums still reproduce the Table-1 per-rank entries.

The run doubles as a CI perf smoke: every metric lands in
``artifacts/BENCH_matrix.json`` (next to ``BENCH_link.json``) so the perf
trajectory is machine-readable, and the fast CI job asserts the COO path
stays within **1.5x of the recorded baseline** on the acceptance cell --
normalized by the per-edge loop's time on the same machine, so the guard
compares code, not runner hardware.
"""
import json
import os
import time

import numpy as np

from benchmarks.common import ARTIFACTS, emit
from repro.core import comm_matrix, cost_models
from repro.core.events import CollectiveOp, Shape
from repro.core.reporter import format_table
from repro.core.topology import MeshTopology

KINDS = ("all-reduce", "all-gather", "reduce-scatter",
         "collective-broadcast", "all-to-all", "collective-permute")


def synthetic_ops(num_ops: int, num_devices: int,
                  seed: int = 0) -> list[CollectiveOp]:
    """A randomized op stream shaped like a long monitored session: mixed
    kinds, groups spanning large slices of the mesh, loop-trip weights."""
    rng = np.random.default_rng(seed)
    ops = []
    for i in range(num_ops):
        kind = KINDS[int(rng.integers(len(KINDS)))]
        elems = int(rng.integers(1, 1 << 14))
        weight = float(rng.integers(1, 65))
        if kind == "collective-permute":
            perm = rng.permutation(num_devices)
            pairs = [(int(perm[j]), int(perm[(j + 1) % len(perm)]))
                     for j in range(len(perm))]
            ops.append(CollectiveOp(
                kind=kind, name=f"op{i}",
                result_shapes=[Shape("f32", (elems,))],
                replica_groups=[], source_target_pairs=pairs,
                weight=weight))
            continue
        # partition the mesh into equal groups of a random power-of-two
        # size; all-to-all is quadratic in group size (n*(n-1) edges per
        # group), so it sweeps small groups while the ring/tree kinds span
        # up to the whole mesh
        sizes = ((4, 8, 16) if kind == "all-to-all"
                 else (8, 16, 64, num_devices))
        gsize = int(rng.choice([s for s in sizes if s <= num_devices]))
        devs = rng.permutation(num_devices)
        groups = [sorted(int(d) for d in devs[k:k + gsize])
                  for k in range(0, num_devices, gsize)]
        ops.append(CollectiveOp(
            kind=kind, name=f"op{i}",
            result_shapes=[Shape("f32", (elems,))],
            replica_groups=groups, weight=weight))
    return ops


def _time(fn, repeats: int = 3) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def multiaxis_ops(num_ops: int, seed: int = 1) -> list[CollectiveOp]:
    """Full-mesh ring collectives on a 16x16 torus: every group is the
    whole mesh, so each op decomposes into one ring phase per torus axis."""
    rng = np.random.default_rng(seed)
    kinds = ("all-reduce", "all-gather", "reduce-scatter")
    return [CollectiveOp(
        kind=kinds[int(rng.integers(len(kinds)))], name=f"ma{i}",
        result_shapes=[Shape("f32", (int(rng.integers(1, 1 << 14)),))],
        replica_groups=[list(range(256))],
        weight=float(rng.integers(1, 65))) for i in range(num_ops)]


def irregular_a2a_ops(num_ops: int, num_devices: int,
                      seed: int = 2) -> list[CollectiveOp]:
    """Skewed all-to-all stream: every op carries a per-rank byte vector
    with one hot rank (the MoE hot-expert shape), exercising the
    irregular placement path (per-source edge weights instead of one
    uniform block per group)."""
    rng = np.random.default_rng(seed)
    ops = []
    for i in range(num_ops):
        gsize = int(rng.choice((4, 8, 16)))
        devs = rng.permutation(num_devices)
        groups = [sorted(int(d) for d in devs[k:k + gsize])
                  for k in range(0, num_devices, gsize)]
        total = float(rng.integers(1 << 10, 1 << 20))
        vec = rng.random(gsize) + 0.1
        vec[int(rng.integers(gsize))] *= 8.0          # the hot expert
        vec = vec / vec.sum() * total
        ops.append(CollectiveOp(
            kind="all-to-all", name=f"ia{i}",
            result_shapes=[Shape("f32", (1,))],
            replica_groups=groups,
            weight=float(rng.integers(1, 65)),
            bytes_per_rank_vec=[float(x) for x in vec]))
    return ops


def _baseline_guard(metrics: dict[str, float]) -> None:
    """Fast-CI perf guard: on the acceptance cell the COO path must stay
    within 1.5x of the recorded ``artifacts/BENCH_matrix.json`` baseline.

    Raw milliseconds are not comparable across runner hardware, so the
    per-edge loop's time on the SAME machine is the yardstick: the guard
    compares loop-normalized COO time (equivalently, requires the current
    speedup to stay within 1.5x of the recorded speedup).
    """
    path = os.path.join(ARTIFACTS, "BENCH_matrix.json")
    if not os.path.exists(path):
        print("[matrix] no recorded baseline; skipping the 1.5x guard")
        return
    try:
        with open(path) as f:
            base = json.load(f)["metrics"]
        base_speedup = base["matrix_build/256dev/10000ops/speedup"]
    except (KeyError, ValueError, OSError):
        print("[matrix] unreadable baseline; skipping the 1.5x guard")
        return
    cur_speedup = metrics["matrix_build/256dev/10000ops/speedup"]
    ratio = base_speedup / cur_speedup
    assert ratio <= 1.5, (
        f"COO path regressed to {ratio:.2f}x the recorded baseline on the "
        f"256dev/10k-op acceptance cell (speedup {cur_speedup:.1f}x now "
        f"vs {base_speedup:.1f}x recorded; allowed: 1.5x)")
    print(f"[matrix] baseline guard OK: {ratio:.2f}x the recorded "
          f"loop-normalized COO time (limit 1.5x)")


def main():
    cases = [  # (devices, ops); the 256/10k cell is the acceptance bar
        (64, 2000),
        (256, 10000),
        (1024, 2000),
    ]
    rows = []
    metrics: dict[str, float] = {}

    def record(name, value, derived=""):
        metrics[name] = float(value)
        emit(name, value, derived)

    accept_speedup = None
    for num_devices, num_ops in cases:
        ops = synthetic_ops(num_ops, num_devices)
        vec = comm_matrix.matrix_for_ops(ops, num_devices)
        ref = comm_matrix.matrix_for_ops_reference(ops, num_devices)
        np.testing.assert_allclose(vec, ref, rtol=1e-12)
        t_vec = _time(lambda: comm_matrix.matrix_for_ops(ops, num_devices))
        t_ref = _time(
            lambda: comm_matrix.matrix_for_ops_reference(ops, num_devices),
            repeats=1)
        speedup = t_ref / t_vec
        if (num_devices, num_ops) == (256, 10000):
            accept_speedup = speedup
        rows.append([f"{num_devices}", f"{num_ops:,}",
                     f"{t_ref * 1e3:.1f}", f"{t_vec * 1e3:.1f}",
                     f"{speedup:.1f}x"])
        tag = f"matrix_build/{num_devices}dev/{num_ops}ops"
        record(f"{tag}/loop_ms", t_ref * 1e3, "per_edge_python_loop")
        record(f"{tag}/coo_ms", t_vec * 1e3, "batched_np_add_at")
        record(f"{tag}/speedup", speedup, "loop_ms/coo_ms")

    # multi-axis schedule case: 16x16 torus, full-mesh groups -> one ring
    # phase per torus axis (the zero-transit placement), timed end to end
    topo = MeshTopology(axis_names=("data", "model"), axis_sizes=(16, 16))
    ma_ops = multiaxis_ops(2000)
    ma_mat = comm_matrix.matrix_for_ops(ma_ops, 256, topo=topo)
    total_w = {}
    for op in ma_ops:
        pr = cost_models.wire_bytes_per_rank(
            op.kind, op.payload_bytes, 256, "ring")
        for d in range(256):
            total_w[d] = total_w.get(d, 0.0) + pr * op.weight
    np.testing.assert_allclose(ma_mat[1:, 1:].sum(axis=1),
                               [total_w[d] for d in range(256)],
                               rtol=1e-9)
    t_ma = _time(lambda: comm_matrix.matrix_for_ops(ma_ops, 256,
                                                    topo=topo))
    rows.append(["256 (16x16)", "2,000", "-", f"{t_ma * 1e3:.1f}",
                 "per-axis"])
    record("matrix_build/256dev_16x16/2000ops/coo_ms", t_ma * 1e3,
           "per_axis_schedule_build")

    # irregular-a2a case: skewed per-rank byte vectors through the COO
    # path; the legacy loop cannot price vectors, so correctness is pinned
    # against the billing model's group totals instead
    ia_ops = irregular_a2a_ops(2000, 256)
    ia_mat = comm_matrix.matrix_for_ops(ia_ops, 256)
    expect_total = sum(
        cost_models.wire_bytes_group_total(
            op.kind, op.payload_bytes, op.group_size, "ring",
            vec=op.byte_vector()) * op.num_groups * op.weight
        for op in ia_ops)
    np.testing.assert_allclose(ia_mat.sum(), expect_total, rtol=1e-9)
    t_ia = _time(lambda: comm_matrix.matrix_for_ops(ia_ops, 256))
    rows.append(["256 (skewed)", "2,000", "-", f"{t_ia * 1e3:.1f}",
                 "irregular"])
    record("matrix_build/256dev/2000ops_irregular/coo_ms", t_ia * 1e3,
           "per_rank_vector_build")

    print(format_table(rows, ["devices", "ops", "loop ms", "COO ms",
                              "speedup"]))
    # Acceptance bar.  This stream is the ADVERSARIAL case for the
    # memoizing schedule front-end: every op has freshly-permuted groups,
    # so signature dedupe can never hit and its bounded per-op cost
    # (~12us: one tuple-canonicalized signature + capped cache traffic)
    # is pure overhead -- repaid on realistic repeated-shape sessions,
    # where benchmarks/schedule_eval.py requires >= 3x END-TO-END.  The
    # raw loop-vs-COO ratio also proved machine-sensitive (4.2x-5.9x on
    # the pre-memoization builder across runners: the pure-Python loop
    # and the numpy builder scale differently with interpreter speed),
    # so the bar sits with margin under the observed floor; the
    # baseline-normalized guard below tracks drift much tighter.
    assert accept_speedup is not None and accept_speedup >= 2.5, \
        f"COO builder must be >= 2.5x the per-op loop at 256dev/10k ops " \
        f"(got {accept_speedup:.1f}x)"
    print(f"[matrix] vectorized builder matches the loop exactly and is "
          f"{accept_speedup:.1f}x faster on the 256-device 10k-op stream")
    _baseline_guard(metrics)      # vs the recorded artifact, pre-overwrite

    out = os.path.join(ARTIFACTS, "BENCH_matrix.json")
    os.makedirs(ARTIFACTS, exist_ok=True)
    with open(out, "w") as f:
        json.dump({"benchmark": "matrix_build", "metrics": metrics}, f,
                  indent=2, sort_keys=True)
    print(f"[matrix] wrote {out}")


if __name__ == "__main__":
    main()
