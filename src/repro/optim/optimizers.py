"""Optimizers: AdamW and Adafactor on raw pytrees, dtype-configurable states.

Production notes baked in:

* moment dtype is configurable (`state_dtype`) — 314B/400B-class models use
  bf16 moments (AdamW) or factored second moments (Adafactor) to fit v5e HBM
  (EXPERIMENTS.md §Dry-run memory table);
* optimizer state inherits the parameter's logical sharding axes
  (`opt_state_axes`), so ZeRO-3 falls out of the same rules table;
* global-norm gradient clipping, decoupled weight decay, linear-warmup +
  cosine-decay schedule.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"                  # adamw | adafactor
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str = "float32"         # bf16 moments for XXL models
    # adafactor
    factored_min_dim: int = 128          # factor 2nd moment if both dims >=


def lr_at_step(cfg: OptConfig, step):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(1, cfg.decay_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    scale = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.peak_lr * warm * scale


def _is_factored(cfg: OptConfig, shape) -> bool:
    return (cfg.name == "adafactor" and len(shape) >= 2
            and shape[-1] >= cfg.factored_min_dim
            and shape[-2] >= cfg.factored_min_dim)


# ---------------------------------------------------------------------------
# state init
# ---------------------------------------------------------------------------
def init_opt_state(params, cfg: OptConfig):
    sdt = jnp.dtype(cfg.state_dtype)

    def leaf(p):
        if cfg.name == "adamw":
            out = {"m": jnp.zeros(p.shape, sdt),
                   "v": jnp.zeros(p.shape, sdt)}
        elif _is_factored(cfg, p.shape):
            out = {
                "m": jnp.zeros(p.shape, sdt),
                "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
            }
        else:
            out = {"m": jnp.zeros(p.shape, sdt),
                   "v": jnp.zeros(p.shape, jnp.float32)}
        if p.dtype == jnp.bfloat16:
            # Megatron-style mixed precision: bf16 model params (grads sync
            # natively in bf16 — half the wire bytes) + fp32 master here
            out["w32"] = p.astype(jnp.float32)
        return out

    return jax.tree.map(leaf, params)


def opt_state_axes(params_axes_tree, param_shapes_tree, cfg: OptConfig):
    """Logical axes tree matching init_opt_state's structure."""
    shape_leaves, treedef = jax.tree.flatten(param_shapes_tree)
    axes_leaves = treedef.flatten_up_to(params_axes_tree)

    out = []
    for shp, ax in zip(shape_leaves, axes_leaves):
        if cfg.name == "adamw" or not _is_factored(cfg, shp.shape):
            entry = {"m": ax, "v": ax}
        else:
            entry = {"m": ax, "vr": ax[:-1], "vc": ax[:-2] + ax[-1:]}
        if hasattr(shp, "dtype") and shp.dtype == jnp.bfloat16:
            entry["w32"] = ax
        out.append(entry)
    return jax.tree.unflatten(treedef, out)


# ---------------------------------------------------------------------------
# update
# ---------------------------------------------------------------------------
def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def apply_updates(params, grads, state, cfg: OptConfig, step):
    """Returns (new_params, new_state, stats)."""
    lr = lr_at_step(cfg, step)
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip > 0 else 1.0
    t = (step + 1).astype(jnp.float32)
    bc1 = 1 - cfg.b1 ** t
    bc2 = 1 - cfg.b2 ** t
    sdt = jnp.dtype(cfg.state_dtype)

    def leaf(p, g, s):
        g = g.astype(jnp.float32) * clip
        m = cfg.b1 * s["m"].astype(jnp.float32) + (1 - cfg.b1) * g
        if "v" in s:
            v = cfg.b2 * s["v"].astype(jnp.float32) + (1 - cfg.b2) * g * g
            upd = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
            new_s = {"m": m.astype(sdt), "v": v.astype(s["v"].dtype)}
        else:  # factored adafactor second moment
            g2 = g * g + 1e-30
            vr = cfg.b2 * s["vr"] + (1 - cfg.b2) * g2.mean(axis=-1)
            vc = cfg.b2 * s["vc"] + (1 - cfg.b2) * g2.mean(axis=-2)
            vhat_r = vr / bc2
            vhat_c = vc / bc2
            denom = (vhat_r[..., None] * vhat_c[..., None, :]
                     / jnp.maximum(vhat_r.mean(-1)[..., None, None], 1e-30))
            upd = (m / bc1) / (jnp.sqrt(denom) + cfg.eps)
            new_s = {"m": m.astype(sdt), "vr": vr, "vc": vc}
        master = s.get("w32", None)
        w = master if master is not None else p.astype(jnp.float32)
        new_w = w - lr * (upd + cfg.weight_decay * w)
        if master is not None:
            new_s["w32"] = new_w
        return new_w.astype(p.dtype), new_s

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_s = treedef.flatten_up_to(state)
    new = [leaf(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
    new_params = jax.tree.unflatten(treedef, [a for a, _ in new])
    new_state = jax.tree.unflatten(treedef, [b for _, b in new])
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}
