from .optimizers import (OptConfig, init_opt_state, apply_updates,
                         opt_state_axes, lr_at_step)

__all__ = ["OptConfig", "init_opt_state", "apply_updates", "opt_state_axes",
           "lr_at_step"]
