"""Config-sweep engine: monitor many (config x mesh x algorithm) cells.

The paper renders one program's communication; comparing behavior *across*
algorithms, topologies and workloads is where monitoring earns its keep
("Demystifying NCCL", "The Landscape of GPU-Centric Communication").  This
module runs :func:`repro.core.monitor.monitor_fn` over a registry of
sweepable configs -- the paper's own applications (GNMT, ResNet-18, the DDP
microbenchmark) plus every architecture in :mod:`repro.configs` at reduced
scale -- crossed with mesh shapes and collective algorithms, and emits the
comparative artifact set (JSON / CSV / HTML dashboard / Perfetto timeline)
through :mod:`repro.core.export`.

Three properties keep iteration fast:

* **dry-run**: every cell lowers against ``jax.ShapeDtypeStruct`` stand-ins
  (model ``.shapes()`` trees), so no device memory is ever allocated;
* **cache**: finished reports land in the on-disk
  :class:`~repro.core.report_cache.ReportCache` keyed by ``(config, mesh,
  algorithm, jax version)`` -- a second sweep run recompiles nothing, and a
  cell keyed with ``phase=`` reuses the cached whole-session snapshot
  instead of recapturing (per-phase rows are lazy ``view(phase=...)``
  bindings over it);
* **algorithm derivation**: compilation is algorithm-independent, so extra
  algorithms for an already-compiled cell are derived in milliseconds from
  a sibling report's lazy ``view(algorithm)`` binding
  (``CommReport.rebound``).

Multi-phase workloads sweep natively: a config's builder may return
``{"captures": [{"phase", "fn", "args", ...}, ...]}`` instead of a single
``{"fn", "args"}``, and the cell is monitored as one
:class:`~repro.core.session.MonitorSession` (one compile per capture, one
snapshot per cell) -- see the ``serve`` config's prefill/decode cells and
``sweep --by-phase``.
"""
from __future__ import annotations

import concurrent.futures
import dataclasses
import os
import time
from typing import Callable, Optional

from repro.core import monitor_fn
from repro.core.cost_models import ALGORITHMS, validate_algorithm
from repro.core.report_cache import ReportCache, cache_key
from repro.core.reporter import format_table, human_bytes
DEFAULT_MESHES = ("4x2",)


# ---------------------------------------------------------------------------
# mesh specs
# ---------------------------------------------------------------------------
_MESH_AXES = {1: ("data",), 2: ("data", "model"), 3: ("pod", "data", "model")}


def parse_mesh(spec: str):
    """``"8"`` -> (8,) data  |  ``"4x2"`` -> (4,2) data,model  |
    ``"2x2x2"`` -> (2,2,2) pod,data,model."""
    shape = tuple(int(p) for p in spec.lower().split("x"))
    if len(shape) not in _MESH_AXES:
        raise ValueError(f"mesh spec {spec!r}: want 1-3 'x'-separated ints")
    return shape, _MESH_AXES[len(shape)]


def mesh_id(spec: str) -> str:
    shape, axes = parse_mesh(spec)
    return "x".join(map(str, shape)) + ":" + ",".join(axes)


def build_mesh(spec: str):
    from repro.compat import make_mesh
    shape, axes = parse_mesh(spec)
    return make_mesh(shape, axes)


# ---------------------------------------------------------------------------
# sweepable-config registry
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class SweepSpec:
    """One sweepable workload: a builder from mesh -> monitorable program.

    ``build(mesh)`` returns either ``dict(fn=, args=, kwargs=)`` (a single
    captured function) or ``dict(captures=[dict(phase=, fn=, args=,
    kwargs=, name=), ...])`` -- a multi-phase session monitored as one
    cell.
    """

    name: str
    description: str
    version: str                 # part of the cache key: bump to invalidate
    build: Callable              # (mesh) -> dict(fn=...) | dict(captures=...)

    @property
    def config_id(self) -> str:
        return f"{self.name}/{self.version}"


def _sds_like(tree):
    import jax
    import jax.numpy as jnp
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), tree)


def _data_axis_size(mesh) -> int:
    if "data" not in mesh.axis_names:
        raise ValueError(
            f"config needs a 'data' mesh axis; got {tuple(mesh.axis_names)}")
    return dict(zip(mesh.axis_names, mesh.devices.shape))["data"]


def _build_paper(mesh):
    """Paper §4 microbenchmark: DDP 2-layer MLP, bucketed AllReduce.

    On a 3-axis (pod,data,model) mesh the replica axis spans ``("pod",
    "data")`` so the gradient AllReduce crosses the DCN boundary -- the
    multi-pod shape the lint pass's flat-ring rule prices.
    """
    import jax
    import jax.numpy as jnp
    from repro.train import ddp

    d = 256
    n_data = _data_axis_size(mesh)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    axis = ("pod", "data") if "pod" in mesh.axis_names else "data"
    n_repl = n_data * sizes.get("pod", 1)
    b = 4 * n_repl

    def loss_fn(params, batch):
        h = jnp.tanh(batch["x"] @ params["w1"] + params["b1"])
        pred = h @ params["w2"]
        return ((pred - batch["y"]) ** 2).mean(), {}

    step = ddp.make_ddp_train_step(loss_fn, mesh, axis_name=axis,
                                   mode="bucketed", bucket_mb=1.0)
    f32 = jnp.float32
    params = {"w1": jax.ShapeDtypeStruct((d, 4 * d), f32),
              "b1": jax.ShapeDtypeStruct((4 * d,), f32),
              "w2": jax.ShapeDtypeStruct((4 * d, d), f32)}
    batch = {"x": jax.ShapeDtypeStruct((b, d), f32),
             "y": jax.ShapeDtypeStruct((b, d), f32)}
    return {"fn": step, "args": (params, _sds_like(params), batch)}


def _build_gnmt(mesh):
    """Paper §4.1 app: data-parallel GNMT epoch (broadcast + DDP steps +
    metrics AllGather), lowered against ShapeDtypeStructs."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.compat import shard_map
    from repro.models.gnmt import GNMT
    from repro.train import ddp

    n_data = _data_axis_size(mesh)
    steps, seq = 4, 16
    b = 2 * n_data
    model = GNMT(vocab=1024, d=64, layers=2)

    def epoch(params, batches):
        # startup Broadcast modeled as AllGather + take rank-0 (DESIGN.md §8)
        params = jax.tree.map(
            lambda p: jax.lax.all_gather(p, "data")[0], params)

        def one(params, batch):
            (loss, _), grads = jax.value_and_grad(
                model.loss_fn, has_aux=True)(params, batch)
            grads, _ = ddp.allreduce_bucketed(grads, "data", bucket_mb=1.0)
            params = jax.tree.map(
                lambda p, g: p - 1e-2 * g.astype(p.dtype), params, grads)
            return params, loss

        params, losses = jax.lax.scan(one, params, batches)
        metrics = jax.lax.all_gather(losses, "data")
        return params, metrics

    prog = shard_map(epoch, mesh=mesh,
                     in_specs=(P(), P(None, "data")),
                     out_specs=(P(), P()), check_vma=False)
    i32 = jnp.int32
    batches = {k: jax.ShapeDtypeStruct((steps, b, seq), i32)
               for k in ("src", "tgt", "labels")}
    return {"fn": prog, "args": (model.shapes(), batches)}


def _build_resnet(mesh):
    """Paper §4.2 app: ResNet-18 DDP step with PyTorch-style bucketing."""
    import jax
    import jax.numpy as jnp
    from repro.models.resnet import ResNet18
    from repro.train import ddp

    n_data = _data_axis_size(mesh)
    b = 2 * n_data
    model = ResNet18(num_classes=100)
    step = ddp.make_ddp_train_step(model.loss_fn, mesh, mode="bucketed",
                                   bucket_mb=1.0)
    params = model.shapes()
    batch = {"images": jax.ShapeDtypeStruct((b, 32, 32, 3), jnp.float32),
             "labels": jax.ShapeDtypeStruct((b,), jnp.int32)}
    return {"fn": step, "args": (params, _sds_like(params), batch)}


def _build_serve(mesh):
    """Prefill/decode serve cells: one multi-phase session per sweep cell.

    Monitors the qwen3-family reduced config's prefill (full prompt, fills
    the KV cache) and decode (one token against the cache) as TWO named
    phases of one :class:`~repro.core.session.MonitorSession`, so
    ``sweep --by-phase`` shows the prefill all-gather-heavy profile next
    to the decode TP-psum profile without a separate compile per row.
    """
    import jax
    import jax.numpy as jnp
    from repro import configs
    from repro.models import build_model
    from repro.parallel import Sharder
    from repro.serve import ServeConfig, cache_shardings

    n_data = _data_axis_size(mesh)
    batch = 2 * n_data
    prompt_len, max_len = 32, 48
    cfg = configs.config("qwen3_8b", reduced=True)
    model = build_model(cfg)
    shd = Sharder(mesh)
    scfg = ServeConfig(max_len=max_len, batch=batch)
    cache_sh = cache_shardings(model, scfg, shd)
    params = model.shapes()
    i32 = jnp.int32

    def prefill(params, batch_):
        return model.prefill(params, batch_, shd, max_len=max_len)

    def decode(params, cache, batch_):
        return model.decode_step(params, cache, batch_, shd)

    return {"captures": [
        {"phase": "prefill", "name": "prefill", "fn": prefill,
         "args": (params,
                  {"tokens": jax.ShapeDtypeStruct((batch, prompt_len),
                                                  i32)}),
         "kwargs": {"out_shardings": (None, cache_sh)}},
        {"phase": "decode", "name": "decode", "fn": decode,
         "args": (params, model.cache_shapes(batch, max_len),
                  {"tokens": jax.ShapeDtypeStruct((batch, 1), i32)}),
         "kwargs": {"in_shardings": (None, cache_sh, None),
                    "out_shardings": (None, cache_sh)}},
    ]}


def _build_moe_skew(mesh):
    """Skewed MoE dispatch/combine: expert-parallel ``all_to_all`` with an
    irregular per-rank byte vector.

    The einsum MoE block (:mod:`repro.models.moe`) dispatches via matmuls
    and emits no all-to-all, so this cell uses the NCCL-style formulation
    instead: ``shard_map`` over the data axis, one expert per rank, one
    ``jax.lax.all_to_all`` to dispatch token buffers to their experts and
    one to combine the results back.  Expert capacity comes from the MoE
    block's own :func:`~repro.models.moe.group_capacity`.

    Static HLO cannot know the routing, so the cell injects the measured
    skew through the capture's ``op_transform`` hook: expert 0 is hot,
    handling 60% of all tokens, and every a2a gets a per-rank byte vector
    (``bytes_per_rank_vec``) with 60% of the bytes on rank 0 -- the hot
    row in the comm-matrix heatmap, the straggler in the timed schedule,
    and the ``skewed-a2a`` lint finding.
    """
    import dataclasses as dc

    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.compat import shard_map
    from repro.models.common import ModelConfig
    from repro.models.moe import group_capacity

    n = _data_axis_size(mesh)
    d, f = 128, 256
    cfg = ModelConfig(name="moe_skew", family="moe", n_layers=1, d_model=d,
                      n_heads=4, n_kv_heads=4, d_ff=f, vocab_size=256,
                      n_experts=n, top_k=1)
    cap = group_capacity(cfg, group=n * 32)   # tokens per (src, expert) slot

    def step(tokens, wi, wo):
        # tokens local: (n, cap, d) -- row e holds the tokens this rank
        # routes to expert e (capacity-padded dense dispatch buffers)
        recv = jax.lax.all_to_all(tokens, "data", 0, 0)           # dispatch
        h = jax.nn.silu(recv.reshape(n * cap, d) @ wi) @ wo       # expert MLP
        back = jax.lax.all_to_all(h.reshape(n, cap, d), "data", 0, 0)
        return back                                               # combine

    prog = shard_map(step, mesh=mesh,
                     in_specs=(P("data"), P(), P()),
                     out_specs=P("data"), check_vma=False)
    f32 = jnp.float32
    args = (jax.ShapeDtypeStruct((n * n, cap, d), f32),
            jax.ShapeDtypeStruct((d, f), f32),
            jax.ShapeDtypeStruct((f, d), f32))

    hot_frac = 0.6

    def hot_expert(op):
        if op.kind not in ("all-to-all", "ragged-all-to-all"):
            return op
        m = op.group_size
        if m < 2:
            return op
        total = float(op.payload_bytes)
        vec = [total * (1.0 - hot_frac) / (m - 1)] * m
        vec[0] = total * hot_frac
        return dc.replace(op, bytes_per_rank_vec=vec)

    return {"fn": prog, "args": args, "op_transform": hot_expert}


def _arch_builder(arch: str):
    """Reduced-scale train step for one :mod:`repro.configs` architecture,
    sharded by the production Sharder over the given mesh (needs data+model
    axes)."""

    def build(mesh):
        import dataclasses as dc

        import jax
        from repro import configs
        from repro.models import build_model
        from repro.models.common import ShapeConfig
        from repro.optim import OptConfig
        from repro.parallel import Sharder
        from repro.train import TrainConfig
        from repro.train.train import (batch_shardings, make_train_step,
                                       train_state_shapes,
                                       train_state_shardings)

        n_data = _data_axis_size(mesh)
        cfg = configs.config(arch, reduced=True)
        shape = ShapeConfig("sweep_small", seq_len=64,
                            global_batch=2 * n_data, kind="train")
        model = build_model(cfg)
        shd = Sharder(mesh)
        ocfg = OptConfig(name=cfg.optimizer, state_dtype=cfg.opt_state_dtype)
        tcfg = TrainConfig()
        step = make_train_step(model, ocfg, tcfg, shd)
        state_sh = train_state_shardings(model, ocfg, shd)
        state_shapes = train_state_shapes(model, ocfg)
        batch = configs.input_specs(cfg, shape)
        b_sh = batch_shardings(batch, shd)
        return {"fn": step, "args": (state_shapes, batch),
                "kwargs": {"in_shardings": (state_sh, b_sh)}}

    return build


def _registry() -> dict[str, SweepSpec]:
    from repro import configs as _configs

    specs = [
        SweepSpec("paper", "paper §4 DDP microbenchmark (2-layer MLP, "
                  "bucketed AllReduce)", "v2:d=256,bucket=1,pod-dp",
                  _build_paper),
        SweepSpec("gnmt", "paper §4.1 GNMT machine translation, DDP epoch "
                  "(broadcast + AllReduce + AllGather)",
                  "v1:d=64,layers=2,steps=4", _build_gnmt),
        SweepSpec("resnet", "paper §4.2 ResNet-18 image classification, DDP "
                  "step (PyTorch-style bucketing)",
                  "v1:classes=100,bucket=1", _build_resnet),
        SweepSpec("serve", "prefill/decode serve cells: one multi-phase "
                  "session per cell (qwen3_8b reduced; use --by-phase)",
                  "v1:qwen3,prompt=32,max=48", _build_serve),
        SweepSpec("moe-skew", "skewed MoE expert dispatch: expert-parallel "
                  "all-to-all with a 60%-hot expert 0 (irregular per-rank "
                  "byte vectors via op_transform)",
                  "v1:d=128,hot=0.6,topk=1", _build_moe_skew),
    ]
    for arch in _configs.ARCH_IDS:
        specs.append(SweepSpec(
            arch, f"reduced-scale {arch} train step (Sharder-sharded)",
            "v1:reduced,seq=64", _arch_builder(arch)))
    return {s.name: s for s in specs}


def available_configs() -> dict[str, SweepSpec]:
    """Name -> spec for every sweepable config (paper apps + architectures)."""
    return _registry()


# ---------------------------------------------------------------------------
# the sweep itself
# ---------------------------------------------------------------------------
def _monitor_cell(built: dict, mesh, name: str, algorithm: str):
    """Monitor one built cell: a single function via ``monitor_fn``, or a
    ``captures`` list as one multi-phase :class:`MonitorSession`."""
    if "captures" not in built:
        return monitor_fn(
            built["fn"], *built.get("args", ()),
            mesh=mesh, name=name, algorithm=algorithm,
            op_transform=built.get("op_transform"),
            **built.get("kwargs", {}))
    from repro.core import MonitorSession

    with MonitorSession(mesh=mesh, name=name, algorithm=algorithm) as sess:
        for cap in built["captures"]:
            with sess.phase(cap["phase"]):
                sess.capture(cap["fn"], *cap.get("args", ()),
                             name=cap.get("name"),
                             op_transform=cap.get("op_transform",
                                                  built.get("op_transform")),
                             **cap.get("kwargs", {}))
    return sess.report()


@dataclasses.dataclass
class SweepResult:
    reports: list                        # CommReport, one per finished cell
    failures: list[dict]                 # {config, mesh, error}
    cache_hits: int
    compiles: int
    artifacts: dict[str, str] = dataclasses.field(default_factory=dict)

    def summary_table(self, by_link: bool = False,
                      by_phase: bool = False,
                      lint: bool = False) -> str:
        """One row per cell; ``by_link=True`` adds the physical-link view
        (busiest link, its contention-aware bottleneck ms, and the
        tier-overlapped communication time ici ∥ dcn -- the ``--by-link``
        CLI columns).  ``by_phase=True`` expands each cell into one row per
        session phase (single-phase reports keep one row, labelled with
        their phase), with all statistics computed from that phase's
        :class:`~repro.core.views.CommView`.  ``lint=True`` appends the
        static-analysis columns: finding count (worst severity) and the
        total modeled savings across findings (the ``--lint`` CLI
        columns)."""
        from repro.core.lint import max_severity
        rows = []
        for rep in self.reports:
            targets = [(None, rep.view())]
            if by_phase and rep.phase_names():
                targets = [(ph, rep.view(phase=ph))
                           for ph in rep.phase_names()]
            for ph, view in targets:
                summary = view.summary
                total_wire = sum(r.get("wire_bytes", 0.0)
                                 for r in summary.values())
                calls = sum(r.get("calls", 0) for r in summary.values())
                dominant = max(
                    summary,
                    key=lambda k: summary[k].get("wire_bytes", 0.0),
                ) if summary else "-"
                row = [
                    rep.meta.get("config", rep.name),
                    rep.meta.get("mesh", f"{rep.num_devices}dev"),
                    rep.algorithm,
                ]
                if by_phase:
                    row.append(ph or "-")
                row += [
                    f"{rep.num_devices}",
                    f"{calls:,}",
                    human_bytes(total_wire),
                    f"{view.collective_seconds() * 1e3:.3f}",
                    dominant,
                    rep.meta.get("source", "?"),
                ]
                if by_link:
                    lu = view.link_utilization()
                    bn = lu.bottleneck() if lu is not None else None
                    overlap = view.collective_overlap_seconds()
                    row[-1:-1] = ([bn[0].name, f"{bn[1] * 1e3:.3f}",
                                   f"{overlap * 1e3:.3f}"]
                                  if bn else ["-", "-", "-"])
                if lint:
                    findings = rep.lint(phase=ph)
                    sev = max_severity(findings)
                    row[-1:-1] = [
                        f"{len(findings)}" + (f" ({sev})" if sev else ""),
                        f"{sum(f.est_savings_s for f in findings) * 1e3:.3f}",
                    ]
                rows.append(row)
        header = ["config", "mesh", "algorithm"] \
            + (["phase"] if by_phase else []) \
            + ["devices", "collective calls", "wire bytes", "collective ms",
               "dominant primitive", "source"]
        if by_link:
            header[-1:-1] = ["busiest link", "link ms", "overlap ms"]
        if lint:
            header[-1:-1] = ["lint findings", "lint savings ms"]
        return format_table(rows, header)


def run_scale_curve(
    config_names: list[str],
    mesh_specs: list[str] = DEFAULT_MESHES,
    algorithms: list[str] = ("ring",),
    *,
    device_counts: Optional[list[int]] = None,
    cache: Optional[ReportCache] = None,
    use_cache: bool = True,
    jobs: int = 1,
    log: Callable[[str], None] = print,
):
    """``sweep --scale-curve``: monitor each cell once at its (small) base
    mesh -- cache rules identical to :func:`run_sweep` (including the
    ``jobs`` thread pool) -- then project the compiled ops onto synthetic
    fleet topologies per device count (:mod:`repro.scale`), all sparse, no
    recompilation.

    Returns ``(SweepResult, list[ScalePoint])``.
    """
    from repro import scale

    result = run_sweep(config_names, mesh_specs, algorithms,
                       cache=cache, use_cache=use_cache, jobs=jobs, log=log)
    points = scale.scale_curve(
        result.reports,
        device_counts if device_counts else scale.DEFAULT_SCALE_POINTS,
        log=log)
    return result, points


def resolve_jobs(jobs) -> int:
    """Normalize a ``--jobs`` value: int-like, or ``"auto"`` -> cpu count."""
    if isinstance(jobs, str):
        if jobs.strip().lower() == "auto":
            return max(1, os.cpu_count() or 1)
        jobs = int(jobs)
    return max(1, int(jobs))


def run_sweep(
    config_names: list[str],
    mesh_specs: list[str] = DEFAULT_MESHES,
    algorithms: list[str] = ("ring",),
    *,
    cache: Optional[ReportCache] = None,
    use_cache: bool = True,
    jobs: int = 1,
    log: Callable[[str], None] = print,
) -> SweepResult:
    """Monitor every (config, mesh) cell, derive every algorithm, cache all.

    Per cell: try the cache for each requested algorithm; if at least one
    entry exists, derive the missing algorithms from it (compile-free); only
    a fully-cold cell compiles, once, regardless of algorithm count.

    ``jobs > 1`` evaluates independent cells on a thread pool (cells are
    jax compiles -- most of the wall clock releases the GIL).  Workers only
    *read* the shared :class:`ReportCache`; all writes (``cache.put``,
    report/failure assembly, counters) happen afterwards on the calling
    thread in the serial iteration order, so the result -- reports order,
    failures, CSV output -- is identical to ``jobs=1``.
    """
    registry = _registry()
    unknown = [c for c in config_names if c not in registry]
    if unknown:
        raise KeyError(
            f"unknown config(s) {unknown}; known: {sorted(registry)}")
    for alg in algorithms:
        validate_algorithm(alg)
    cache = cache or ReportCache()
    result = SweepResult(reports=[], failures=[], cache_hits=0, compiles=0)
    jobs = resolve_jobs(jobs)

    def eval_cell(cname: str, mspec: str):
        """One (config, mesh) cell: probe cache, compile if cold, derive
        missing algorithms.  Pure w.r.t. shared state -- returns
        ``(cell, keys, failure, cache_hits, compiles)`` for the caller to
        merge deterministically."""
        spec = registry[cname]
        mid = mesh_id(mspec)
        keys = {alg: cache_key(spec.config_id, mid, alg)
                for alg in algorithms}
        cell: dict[str, object] = {}
        hits = 0
        compiles = 0
        if use_cache:
            for alg, key in keys.items():
                rep = cache.get(key)
                if rep is not None:
                    log(f"[cache] hit config={cname} mesh={mspec} "
                        f"algorithm={alg} key={key}")
                    rep.meta["source"] = "cache"
                    cell[alg] = rep
                    hits += 1
        missing = [a for a in algorithms if a not in cell]
        sibling = None
        if missing and not cell and use_cache:
            # an entry for an UNrequested algorithm still spares the
            # compile: everything derives from the same compiled ops
            for alg in ALGORITHMS:
                if alg in keys:
                    continue            # already probed above
                rep = cache.get(cache_key(spec.config_id, mid, alg))
                if rep is not None:
                    log(f"[cache] sibling hit config={cname} "
                        f"mesh={mspec} algorithm={alg} -- deriving "
                        "requested algorithms without recompiling")
                    rep.meta["source"] = "cache"
                    sibling = rep
                    break
        if missing and not cell and sibling is None:
            # fully cold: compile once for the first missing algorithm
            alg0 = missing[0]
            log(f"[sweep] compile config={cname} mesh={mspec} "
                f"algorithm={alg0} ...")
            t0 = time.perf_counter()
            try:
                mesh = build_mesh(mspec)
                built = spec.build(mesh)
                rep = _monitor_cell(built, mesh, f"{cname}@{mspec}",
                                    alg0)
            except Exception as e:  # noqa: BLE001 -- keep sweeping
                log(f"[sweep] FAIL config={cname} mesh={mspec}: {e!r}")
                failure = {"config": cname, "mesh": mspec,
                           "error": repr(e)}
                return cell, keys, failure, hits, compiles
            compiles += 1
            log(f"[sweep] compiled config={cname} mesh={mspec} in "
                f"{time.perf_counter() - t0:.1f}s "
                f"({len(rep.compiled_ops)} collectives)")
            rep.meta.update(config=cname, mesh=mspec, source="compiled")
            cell[alg0] = rep
            missing = [a for a in algorithms if a not in cell]
        if missing and (cell or sibling):
            # warm: derive remaining algorithms without recompiling --
            # a lazy view(alg) binding over the sibling's compiled ops,
            # snapshotted so the cache gets one report per algorithm
            base = next(iter(cell.values())) if cell else sibling
            for alg in missing:
                rep = base.rebound(alg)
                rep.meta = dict(base.meta, source="derived",
                                algorithm=alg)
                log(f"[sweep] derive config={cname} mesh={mspec} "
                    f"algorithm={alg} (no recompile)")
                cell[alg] = rep
        return cell, keys, None, hits, compiles

    cells = [(cname, mspec) for cname in config_names
             for mspec in mesh_specs]
    if jobs > 1 and len(cells) > 1:
        with concurrent.futures.ThreadPoolExecutor(
                max_workers=min(jobs, len(cells))) as pool:
            futures = [pool.submit(eval_cell, cn, ms) for cn, ms in cells]
            outcomes = [f.result() for f in futures]
    else:
        outcomes = [eval_cell(cn, ms) for cn, ms in cells]

    for (cname, mspec), (cell, keys, failure, hits, compiles) in zip(
            cells, outcomes):
        result.cache_hits += hits
        result.compiles += compiles
        if failure is not None:
            result.failures.append(failure)
            continue
        for alg in algorithms:
            if alg not in cell:
                continue
            rep = cell[alg]
            rep.meta.update(config=cname, mesh=mspec, algorithm=alg)
            result.reports.append(rep)
            if use_cache and rep.meta.get("source") != "cache":
                cache.put(keys[alg], rep, meta=rep.meta)
    return result
