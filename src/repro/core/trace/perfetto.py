"""Perfetto / Chrome trace-event JSON frontend.

Two dialects of one format:

* **Our own exports** (:mod:`repro.core.export.perfetto`).  Each process
  carries a ``repro_report`` metadata event (devices, algorithm,
  topology, phases, host transfers) and every collective event embeds
  its full serialized op (``args.repro_op``), so the import rebuilds the
  originating report *exactly* -- the comm matrix round-trips bitwise.
  The event's rendered duration becomes ``measured_s`` when the op
  carries none of its own.

* **Generic profiler traces** (the jax profiler's trace-viewer JSON and
  friends): ``X`` duration events whose names alias a collective kind,
  one process or thread lane per device.  Events are normalized through
  :mod:`.normalize` -- device ids parsed from process labels
  (``/device:TPU:3``), per-device observations of one collective
  clustered by name occurrence (measured duration = worst rank), byte
  counts read from ``args`` (``payload_bytes`` / ``bytes`` / ``size``).
  A collective event with no byte annotation raises
  :class:`~.base.TraceParseError` -- bytes cannot be invented, and a
  silent skip would fake a zero-row matrix.

Timestamps/durations follow the Chrome convention (microseconds).
"""
from __future__ import annotations

import json
from typing import Optional

from ..export import serialize
from ..export.perfetto import REPORT_META_EVENT
from .base import TraceImport, TraceParseError, TraceSource
from .normalize import DeviceMap, collective_kind, measured_op

_BYTE_KEYS = ("payload_bytes", "bytes", "size", "bytes_accessed",
              "tensor_bytes")

# cats our own exporter writes for non-collective lanes
_SKIP_CATS = ("tier", "phase")


class PerfettoSource(TraceSource):
    """Chrome trace-event JSON (Perfetto UI, jax profiler, our exports)."""

    format = "perfetto"
    extensions = (".json",)

    @classmethod
    def sniff(cls, path: str, head: str) -> bool:
        s = head.lstrip()
        return "traceEvents" in head or s.startswith("[")

    @classmethod
    def parse(cls, path: str, *, num_devices: Optional[int] = None,
              device_map: Optional[dict] = None,
              name: Optional[str] = None, pid: Optional[int] = None,
              **_opts) -> TraceImport:
        with open(path) as f:
            try:
                doc = json.load(f)
            except json.JSONDecodeError as e:
                raise TraceParseError(
                    f"truncated or invalid JSON ({e.msg}, line {e.lineno})",
                    path=path) from e
        if isinstance(doc, dict):
            events = doc.get("traceEvents")
            if not isinstance(events, list):
                raise TraceParseError(
                    "no traceEvents array in trace document", path=path)
        elif isinstance(doc, list):
            events = doc
        else:
            raise TraceParseError(
                f"expected a trace object or event array,"
                f" got {type(doc).__name__}", path=path)

        # partition by process; our exports hold one report per pid
        pids = []
        for e in events:
            p = e.get("pid", 0) if isinstance(e, dict) else 0
            if p not in pids:
                pids.append(p)
        use_pid = pid if pid is not None else (pids[0] if pids else 0)
        if pid is not None and pid not in pids:
            raise TraceParseError(
                f"pid {pid} not in trace (processes: {pids})", path=path)
        evs = [e for e in events
               if isinstance(e, dict) and e.get("pid", 0) == use_pid]

        meta_ev = next((e for e in evs if e.get("ph") == "M"
                        and e.get("name") == REPORT_META_EVENT), None)
        if meta_ev is not None:
            imp = _parse_own_export(evs, meta_ev, path)
        else:
            imp = _parse_generic(evs, path, num_devices=num_devices,
                                 device_map=device_map)
        imp.meta.update({"source": "perfetto", "path": path,
                         "pid": use_pid, "num_processes": len(pids)})
        if name:
            imp.name = name
        return imp


def _parse_own_export(evs: list, meta_ev: dict, path: str) -> TraceImport:
    """Exact re-import of our own exporter's output (bitwise matrix)."""
    meta = meta_ev.get("args") or {}
    ops = []
    for e in evs:
        if e.get("ph") != "X" or e.get("cat") in _SKIP_CATS:
            continue
        args = e.get("args") or {}
        if "repro_op" not in args:
            continue
        try:
            op = serialize.op_from_dict(args["repro_op"])
        except (KeyError, TypeError, ValueError) as exc:
            raise TraceParseError(
                f"bad repro_op record ({exc})", path=path,
                record=f"event {e.get('name')!r}") from exc
        if op.measured_s is None and e.get("dur") is not None:
            op.measured_s = float(e["dur"]) * 1e-6
        ops.append(op)
    try:
        topo = serialize.topo_from_dict(meta.get("topo"))
        phases = [serialize.phase_from_dict(p)
                  for p in meta.get("phases", [])]
        transfers = [serialize.transfer_from_dict(t)
                     for t in meta.get("host_transfers", [])]
    except (KeyError, TypeError, ValueError) as exc:
        raise TraceParseError(
            f"bad {REPORT_META_EVENT} metadata ({exc})", path=path,
            record=REPORT_META_EVENT) from exc
    return TraceImport(
        name=str(meta.get("name", "perfetto-trace")),
        num_devices=int(meta.get("num_devices", 1)),
        ops=ops, host_transfers=transfers, topo=topo,
        algorithm=str(meta.get("algorithm", "ring")),
        phases=phases, sparse=bool(meta.get("sparse")) or None,
        meta={"exact_reimport": True})


def _device_of_label(label: str) -> Optional[int]:
    """Device id from a process/thread label when it names one
    (``/device:TPU:3``, ``GPU 2 stream``, ``Tesla ... (5)``); None for
    non-device lanes (``python``, ``Steps``)."""
    import re

    for pat in (r"/?device:[a-z_]+:(\d+)", r"\bgpu[ :]?(\d+)\b",
                r"\btpu[ :]?(\d+)\b", r"\((\d+)\)\s*$"):
        m = re.search(pat, label, re.I)
        if m:
            return int(m.group(1))
    return None


def _parse_generic(evs: list, path: str, *,
                   num_devices: Optional[int],
                   device_map: Optional[dict]) -> TraceImport:
    proc_label: dict = {}
    for e in evs:
        if e.get("ph") == "M" and e.get("name") in ("process_name",
                                                    "thread_name"):
            label = (e.get("args") or {}).get("name", "")
            proc_label[(e.get("pid", 0), e.get("tid", 0),
                        e.get("name"))] = label

    devmap = DeviceMap(num_devices, device_map, path=path)
    clusters: dict = {}
    order: list = []
    occ: dict = {}
    trace_name = "perfetto-trace"
    for i, e in enumerate(evs):
        if e.get("ph") != "X" or e.get("cat") in _SKIP_CATS:
            continue
        kind = collective_kind(e.get("name", ""))
        if kind is None:
            continue
        where = f"event {i} ({e.get('name')!r})"
        args = e.get("args") or {}
        ts, dur = e.get("ts", 0), e.get("dur", 0)
        if (isinstance(ts, (int, float)) and ts < 0) or \
                (isinstance(dur, (int, float)) and dur < 0):
            raise TraceParseError(
                f"negative timestamp/duration (ts={ts}, dur={dur})",
                path=path, record=where)
        nbytes = next((args[k] for k in _BYTE_KEYS
                       if isinstance(args.get(k), (int, float))), None)
        if nbytes is None or nbytes < 0:
            raise TraceParseError(
                "collective event carries no byte annotation"
                f" (looked for {list(_BYTE_KEYS)} in args)",
                path=path, record=where)
        dev = None
        if args.get("device") is not None:
            dev = devmap.resolve(args["device"], record=where)
        else:
            for mkey in ((e.get("pid", 0), e.get("tid", 0),
                          "thread_name"),
                         (e.get("pid", 0), 0, "process_name")):
                d = _device_of_label(proc_label.get(mkey, ""))
                if d is not None:
                    dev = devmap.resolve(d, record=where)
                    break
        group = args.get("group") or args.get("replica_group")
        groups = args.get("replica_groups") or \
            ([group] if group else None)
        ename = str(e.get("name", kind))
        k = occ.get((ename, dev), 0)
        occ[(ename, dev)] = k + 1
        key = (ename, k)
        c = clusters.get(key)
        if c is None:
            c = {"kind": kind, "name": ename, "dur": float(dur) * 1e-6,
                 "bytes": float(nbytes), "devices": set(),
                 "groups": groups,
                 "phase": str(args.get("phase", ""))}
            clusters[key] = c
            order.append(key)
        else:
            c["dur"] = max(c["dur"], float(dur) * 1e-6)
            c["bytes"] = max(c["bytes"], float(nbytes))
            c["groups"] = c["groups"] or groups
        if dev is not None:
            c["devices"].add(dev)

    ndev = num_devices
    if ndev is None:
        hi = max(devmap.seen, default=-1)
        for c in clusters.values():
            for g in c["groups"] or []:
                hi = max(hi, max(g))
        ndev = hi + 1 if hi >= 0 else 1
    devmap.num_devices = ndev

    ops = []
    for key in order:
        c = clusters[key]
        if c["groups"]:
            groups = [list(g) for g in c["groups"]]
        elif len(c["devices"]) > 1:
            groups = [sorted(c["devices"])]
        else:
            groups = [list(range(ndev))]
        pairs = None
        if c["kind"] == "collective-permute":
            g = groups[0]
            pairs = [(g[j], g[(j + 1) % len(g)])
                     for j in range(len(g))] if len(g) > 1 else []
        ops.append(measured_op(
            c["kind"], payload_bytes=c["bytes"], groups=groups,
            name=c["name"], measured_s=c["dur"], phase=c["phase"],
            pairs=pairs))
    label = proc_label.get((evs[0].get("pid", 0), 0, "process_name"),
                           "") if evs else ""
    return TraceImport(name=label or trace_name, num_devices=int(ndev),
                       ops=ops, meta={"exact_reimport": False})
