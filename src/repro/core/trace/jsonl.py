"""Generic JSONL trace frontend: one JSON event object per line.

The house schema for tools that are neither Perfetto nor nvprof -- small
enough to emit from a shell one-liner, strict enough to catch malformed
records.  One object per line:

* **Header** (optional, first line)::

      {"trace": {"name": "run1", "num_devices": 8, "time_unit": "us",
                 "clock_align": "global"}}

* **Collective event** -- ``kind`` (any alias
  :func:`~.normalize.collective_kind` understands) plus ``bytes`` and
  ``dur`` are required::

      {"kind": "all-reduce", "name": "ar.3", "device": 0, "ts": 10.0,
       "dur": 250.0, "bytes": 4194304, "group": [0,1,2,3], "corr": 7,
       "phase": "fwd", "weight": 1}

  Rows sharing a ``corr`` id are one collective observed from several
  ranks: they merge into a single op whose measured duration is the
  *worst rank's* (max) and whose replica group defaults to the sorted
  participating devices.

* **Host transfer** -- ``kind`` of ``h2d`` / ``d2h`` with ``device`` and
  ``bytes``.

``ts``/``dur`` are in ``time_unit`` (default seconds).  Timestamps are
validated per device: negative times and overlapping events on one
device's stream raise :class:`~.base.TraceParseError` naming the line --
this frontend's schema defines a device's events as sequential.
"""
from __future__ import annotations

import json
from typing import Optional

from ..events import HostTransfer
from .base import TraceImport, TraceParseError, TraceSource
from .normalize import DeviceMap, align_clocks, collective_kind, measured_op

_TIME_UNITS = {"s": 1.0, "ms": 1e-3, "us": 1e-6, "ns": 1e-9}


def _num(rec: dict, key: str, line: int, path: str, *,
         required: bool = False, minimum: Optional[float] = None):
    if key not in rec or rec[key] is None:
        if required:
            raise TraceParseError(f"missing required field {key!r}",
                                  path=path, record=f"line {line}")
        return None
    v = rec[key]
    if isinstance(v, bool) or not isinstance(v, (int, float)):
        raise TraceParseError(f"field {key!r} is not a number: {v!r}",
                              path=path, record=f"line {line}")
    if minimum is not None and v < minimum:
        raise TraceParseError(f"field {key!r} is negative: {v!r}",
                              path=path, record=f"line {line}")
    return float(v)


class JsonlSource(TraceSource):
    """The generic JSONL event schema (see module docstring)."""

    format = "jsonl"
    extensions = (".jsonl", ".ndjson")

    @classmethod
    def sniff(cls, path: str, head: str) -> bool:
        first = head.lstrip().splitlines()[0] if head.strip() else ""
        if not first.startswith("{"):
            return False
        try:
            rec = json.loads(first)
        except Exception:
            # a single-line object truncated by the head window still
            # counts; multi-line JSON documents (perfetto exports, saved
            # reports) have a newline inside the head and do not
            return "\n" not in head.strip("\n") and \
                "traceEvents" not in head
        return isinstance(rec, dict) and "traceEvents" not in rec

    @classmethod
    def parse(cls, path: str, *, num_devices: Optional[int] = None,
              device_map: Optional[dict] = None,
              name: Optional[str] = None, **_opts) -> TraceImport:
        with open(path) as f:
            lines = f.read().splitlines()

        header: dict = {}
        events: list[tuple[int, dict]] = []
        for i, line in enumerate(lines, start=1):
            if not line.strip():
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError as e:
                raise TraceParseError(
                    f"truncated or invalid JSON ({e.msg})",
                    path=path, record=f"line {i}") from e
            if not isinstance(rec, dict):
                raise TraceParseError(
                    f"expected a JSON object, got {type(rec).__name__}",
                    path=path, record=f"line {i}")
            if "trace" in rec and not events and not header:
                header = dict(rec["trace"] or {})
                continue
            events.append((i, rec))

        unit = header.get("time_unit", "s")
        if unit not in _TIME_UNITS:
            raise TraceParseError(
                f"unknown time_unit {unit!r}; expected one of"
                f" {sorted(_TIME_UNITS)}", path=path, record="header")
        scale = _TIME_UNITS[unit]
        ndev = num_devices or header.get("num_devices")
        devmap = DeviceMap(ndev, device_map, path=path)

        transfers: list[HostTransfer] = []
        coll: list[dict] = []
        spans: dict[int, list[tuple[float, float, int]]] = {}
        for i, rec in events:
            kind_raw = rec.get("kind") or rec.get("name") or ""
            where = f"line {i}"
            if str(kind_raw).lower() in ("h2d", "d2h"):
                dev = devmap.resolve(rec.get("device", 0), record=where)
                nbytes = _num(rec, "bytes", i, path, required=True,
                              minimum=0)
                transfers.append(HostTransfer(
                    direction=str(kind_raw).lower(), device=dev,
                    nbytes=int(nbytes), label=str(rec.get("name", "")),
                    phase=str(rec.get("phase", ""))))
                continue
            kind = collective_kind(kind_raw)
            if kind is None:
                raise TraceParseError(
                    f"unknown collective kind {kind_raw!r}",
                    path=path, record=where)
            nbytes = _num(rec, "bytes", i, path, required=True, minimum=0)
            dur = _num(rec, "dur", i, path, required=True, minimum=0)
            ts = _num(rec, "ts", i, path, minimum=0)
            dev = None
            if rec.get("device") is not None:
                dev = devmap.resolve(rec["device"], record=where)
                if ts is not None:
                    spans.setdefault(dev, []).append(
                        (ts * scale, (ts + dur) * scale, i))
            coll.append({
                "line": i, "kind": kind, "bytes": nbytes,
                "dur": dur * scale, "ts": None if ts is None else ts * scale,
                "device": dev, "corr": rec.get("corr"),
                "name": str(rec.get("name", "")),
                "phase": str(rec.get("phase", "")),
                "weight": _num(rec, "weight", i, path, minimum=0) or 1.0,
                "group": rec.get("group"), "groups": rec.get("groups"),
                "pairs": rec.get("pairs"),
            })

        # per-device streams are sequential by schema: overlap is malformed
        for dev, sp in spans.items():
            sp.sort()
            for (s0, e0, l0), (s1, _e1, l1) in zip(sp, sp[1:]):
                if s1 < e0 - 1e-12:
                    raise TraceParseError(
                        f"overlapping events on device {dev}"
                        f" (lines {l0} and {l1})",
                        path=path, record=f"line {l1}")

        if ndev is None:
            ndev = _infer_devices(coll, devmap)
        devmap.num_devices = ndev

        ops = [_build_op(c, ndev) for c in _cluster(coll)]
        shifts = align_clocks(
            {d: [s for s, _e, _l in sp] for d, sp in spans.items()},
            header.get("clock_align", "global"))
        meta = {
            "source": "jsonl", "path": path,
            "time_unit": unit, "num_events": len(events),
            "clock_align": header.get("clock_align", "global"),
            "clock_shifts_s": {str(d): s for d, s in shifts.items()},
        }
        return TraceImport(
            name=name or header.get("name") or "jsonl-trace",
            num_devices=int(ndev), ops=ops, host_transfers=transfers,
            meta=meta)


def _infer_devices(coll: list[dict], devmap: DeviceMap) -> int:
    hi = max(devmap.seen, default=-1)
    for c in coll:
        for g in (c.get("groups") or
                  ([c["group"]] if c.get("group") else [])):
            hi = max(hi, max(g))
    return hi + 1 if hi >= 0 else 1


def _cluster(coll: list[dict]) -> list[dict]:
    """Merge per-rank observations of one collective (shared ``corr``)
    into one record carrying the worst rank's duration."""
    out: list[dict] = []
    by_corr: dict = {}
    for c in coll:
        if c["corr"] is None:
            out.append(c)
            continue
        key = (c["kind"], c["corr"])
        base = by_corr.get(key)
        if base is None:
            c = dict(c, devices={c["device"]} - {None})
            by_corr[key] = c
            out.append(c)
        else:
            base["dur"] = max(base["dur"], c["dur"])
            base["bytes"] = max(base["bytes"], c["bytes"])
            if c["device"] is not None:
                base["devices"].add(c["device"])
            base["name"] = base["name"] or c["name"]
            base["phase"] = base["phase"] or c["phase"]
    return out


def _build_op(c: dict, num_devices: int):
    if c.get("groups"):
        groups = [list(g) for g in c["groups"]]
    elif c.get("group"):
        groups = [list(c["group"])]
    elif c.get("devices"):
        groups = [sorted(c["devices"])]
    else:
        groups = [list(range(num_devices))]
    pairs = c.get("pairs")
    if c["kind"] == "collective-permute" and not pairs:
        g = groups[0]
        pairs = [(g[i], g[(i + 1) % len(g)]) for i in range(len(g))] \
            if len(g) > 1 else []
    return measured_op(
        c["kind"], payload_bytes=c["bytes"], groups=groups,
        name=c["name"] or f"{c['kind']}.l{c['line']}",
        measured_s=c["dur"] * max(1.0, c["weight"]),
        weight=c["weight"], phase=c["phase"], pairs=pairs)
