"""Trace ingestion: import real device traces onto the event model.

The subsystem that closes the model-vs-measured loop (ROADMAP: "ingest
real traces").  Three frontends behind one
:class:`~.base.TraceSource` interface:

* :class:`~.perfetto.PerfettoSource` -- Perfetto / Chrome trace-event
  JSON, both the jax profiler's output and our own exporter's (the
  latter re-imports *exactly*: bitwise comm-matrix round-trip);
* :class:`~.nvprof.NvprofCsvSource` -- ComScribe-style nvprof GPU-trace
  CSV (NCCL kernels, PtoP/HtoD/DtoH memcpys);
* :class:`~.jsonl.JsonlSource` -- the generic one-JSON-object-per-line
  schema.

:func:`load_trace` sniffs the format and returns a
:class:`~.base.TraceImport`; ``.report()`` turns it into a regular
:class:`~repro.core.monitor.CommReport` whose ops carry *measured*
seconds (``measured_s``, schema v9) next to the modeled ones, and
:func:`~.compare.compare` pins the two against each other.

    from repro.core.trace import load_trace
    rep = load_trace("artifacts/run_trace.json").report()
    print(rep.compare().table())          # modeled vs measured

Malformed input raises :class:`~.base.TraceParseError` naming the
offending record; silent zero-row matrices are a bug by contract.
"""
from __future__ import annotations

import os
from typing import Optional

from .base import TraceImport, TraceParseError, TraceSource
from .compare import CompareResult, CompareRow, compare

# alias for package-level re-export: ``repro.core.trace_compare`` cannot be
# spelled ``compare`` there without shadowing this subpackage's submodule
trace_compare = compare
from .jsonl import JsonlSource
from .normalize import DeviceMap, align_clocks, collective_kind, measured_op
from .nvprof import NvprofCsvSource
from .perfetto import PerfettoSource

#: sniff order matters: the CSV test is the cheapest and most specific,
#: the JSONL test would also accept some single-line JSON documents
SOURCES: tuple = (NvprofCsvSource, PerfettoSource, JsonlSource)

FORMATS = tuple(s.format for s in SOURCES)

_SNIFF_BYTES = 4096


def source_for(fmt: str) -> type:
    """The :class:`TraceSource` registered under ``fmt``."""
    for src in SOURCES:
        if src.format == fmt:
            return src
    raise ValueError(
        f"unknown trace format {fmt!r}; valid formats: {list(FORMATS)}")


def sniff_format(path: str) -> Optional[str]:
    """Best-guess format name for ``path`` (content first, extension as
    tie-break); None when nothing matches."""
    try:
        with open(path, errors="replace") as f:
            head = f.read(_SNIFF_BYTES)
    except OSError:
        return None
    for src in SOURCES:
        try:
            if src.sniff(path, head):
                return src.format
        except Exception:
            continue
    ext = os.path.splitext(path)[1].lower()
    for src in SOURCES:
        if ext in src.extensions:
            return src.format
    return None


def load_trace(path: str, fmt: Optional[str] = None, **opts) -> TraceImport:
    """Parse a device trace into a :class:`TraceImport`.

    ``fmt`` forces a frontend (one of :data:`FORMATS`); by default the
    file's head is sniffed.  Keyword options are passed to the frontend:
    every frontend takes ``num_devices`` (validates device ids against
    it), ``device_map`` (explicit label -> id pins) and ``name``;
    :class:`PerfettoSource` additionally takes ``pid`` (process to
    import from a multi-report export).
    """
    if not os.path.exists(path):
        raise FileNotFoundError(f"trace file not found: {path}")
    if fmt is None:
        fmt = sniff_format(path)
        if fmt is None:
            raise TraceParseError(
                f"cannot determine trace format; pass fmt= one of"
                f" {list(FORMATS)}", path=path)
    return source_for(fmt).parse(path, **opts)


__all__ = [
    "TraceImport", "TraceParseError", "TraceSource",
    "CompareResult", "CompareRow", "compare", "trace_compare",
    "JsonlSource", "NvprofCsvSource", "PerfettoSource",
    "DeviceMap", "align_clocks", "collective_kind", "measured_op",
    "SOURCES", "FORMATS", "source_for", "sniff_format", "load_trace",
]
