"""Normalization shared by every trace frontend.

Real traces spell the same collective a dozen ways
(``ncclAllReduceRingLLKernel_sum_f32``, ``all_reduce``, ``psum``,
``AllReduce``) and name devices a dozen more (``GPU 3``,
``/device:TPU:3``, ``Tesla V100-SXM2-16GB (3)``).  This module maps both
onto the repo's canonical vocabulary -- :data:`~repro.core.events.
COLLECTIVE_KINDS` and dense logical device ids -- plus clock alignment
across ranks and the synthetic-op builder that inverts the payload
relations of :attr:`CollectiveOp.payload_bytes` so a measured byte count
round-trips exactly.
"""
from __future__ import annotations

import re
from typing import Optional

from ..events import CollectiveOp, Shape
from .base import TraceParseError

# ---------------------------------------------------------------------------
# collective-kind aliasing
# ---------------------------------------------------------------------------
# Matched against the event name lowercased with every non-letter removed,
# first hit wins -- so order matters: ``ragged-all-to-all`` before
# ``all-to-all``, ``reduce-scatter`` before the bare ``reduce`` aliases.
_KIND_ALIASES: tuple[tuple[str, tuple[str, ...]], ...] = (
    ("ragged-all-to-all", ("raggedalltoall",)),
    ("all-to-all", ("alltoall",)),
    ("reduce-scatter", ("reducescatter",)),
    ("all-gather", ("allgather",)),
    ("all-reduce", ("allreduce", "crossreplicasum", "psum")),
    ("collective-broadcast", ("collectivebroadcast", "broadcast", "bcast")),
    ("collective-permute", ("collectivepermute", "ppermute", "permute",
                            "sendrecv", "neighborexchange")),
)


def collective_kind(raw_name: str) -> Optional[str]:
    """Canonical collective kind for a raw trace-event name, or ``None``
    for non-collective events (gemm kernels, memsets, ...).

    Understands HLO spellings (``all-reduce.17``), jax primitive names
    (``psum``), and NCCL kernel names as nvprof records them
    (``ncclAllReduceRingLLKernel_sum_f32(...)``).
    """
    s = re.sub(r"[^a-z]", "", str(raw_name).lower())
    for kind, keys in _KIND_ALIASES:
        if any(k in s for k in keys):
            return kind
    return None


# ---------------------------------------------------------------------------
# device-id mapping
# ---------------------------------------------------------------------------
_DEVICE_PATTERNS = (
    re.compile(r"\((\d+)\)\s*$"),                  # "Tesla V100-SXM2 (3)"
    re.compile(r"^/?device:[a-z_]+:(\d+)$", re.I),  # "/device:TPU:3"
    re.compile(r"^[a-z_ ]*?(\d+)\s*$", re.I),      # "GPU 3", "gpu3", "3"
)


class DeviceMap:
    """Raw trace device labels -> dense logical device ids.

    ``mapping`` pins explicit label -> id pairs (the device-mapping rule
    for traces whose labels carry no number); otherwise the id is parsed
    out of the label.  With ``num_devices`` set, any id outside
    ``[0, num_devices)`` raises :class:`TraceParseError` naming the label
    -- an unknown device is a mapping bug, never a silent drop.
    """

    def __init__(self, num_devices: Optional[int] = None,
                 mapping: Optional[dict] = None, *,
                 path: Optional[str] = None):
        self.num_devices = num_devices
        self.mapping = dict(mapping or {})
        self.path = path
        self.seen: set[int] = set()

    def resolve(self, raw, *, record: Optional[str] = None) -> int:
        if isinstance(raw, bool):
            raise TraceParseError(f"bad device id {raw!r}",
                                  path=self.path, record=record)
        if isinstance(raw, (int, float)) and int(raw) == raw:
            dev = int(raw)
        else:
            label = str(raw).strip()
            if label in self.mapping:
                dev = int(self.mapping[label])
            else:
                for pat in _DEVICE_PATTERNS:
                    m = pat.search(label)
                    if m:
                        dev = int(m.group(1))
                        break
                else:
                    raise TraceParseError(
                        f"cannot map device label {label!r} to a device id"
                        " (no trailing index; pass an explicit device"
                        " mapping)", path=self.path, record=record)
        if dev < 0 or (self.num_devices is not None
                       and dev >= self.num_devices):
            raise TraceParseError(
                f"device id {dev} out of range for {self.num_devices}"
                f" devices (label {raw!r})", path=self.path, record=record)
        self.seen.add(dev)
        return dev


# ---------------------------------------------------------------------------
# clock alignment
# ---------------------------------------------------------------------------
def align_clocks(ts_by_device: dict, mode: str = "global") -> dict:
    """Per-device clock shift (seconds to subtract from every timestamp).

    ``"global"`` anchors all devices to the earliest timestamp anywhere
    (ranks share a clock -- the jax profiler, single-process nvprof);
    ``"per-device"`` zeroes each device independently (per-rank files
    whose epochs never agreed).  Returns ``{device: shift}``.
    """
    if mode not in ("global", "per-device"):
        raise ValueError(f"unknown clock-align mode {mode!r};"
                         " expected 'global' or 'per-device'")
    firsts = {dev: min(ts) for dev, ts in ts_by_device.items() if ts}
    if not firsts:
        return {}
    if mode == "global":
        t0 = min(firsts.values())
        return {dev: t0 for dev in firsts}
    return firsts


# ---------------------------------------------------------------------------
# synthetic measured ops
# ---------------------------------------------------------------------------
def measured_op(kind: str, *, payload_bytes: float,
                groups: list[list[int]], name: str = "",
                measured_s: Optional[float] = None, weight: float = 1.0,
                phase: str = "",
                pairs: Optional[list[tuple[int, int]]] = None,
                op_name: str = "") -> CollectiveOp:
    """A :class:`CollectiveOp` whose :attr:`payload_bytes` equals the
    measured ``payload_bytes`` exactly.

    Inverts the payload relations of the byte accounting: kinds whose
    result *is* S get a ``u8[S]`` result shape; divide-by-N kinds
    (reduce-scatter, all-to-all) additionally carry an equal per-rank
    byte vector summing to S exactly, so integer division can never leak
    bytes.  ``measured_s`` is the op's TOTAL measured wall seconds across
    all its executions (already including ``weight``).
    """
    payload = int(round(float(payload_bytes)))
    if payload < 0:
        raise ValueError(f"negative payload {payload_bytes!r}")
    groups = [list(g) for g in groups] if groups else []
    n = len(groups[0]) if groups else (
        len({d for p in (pairs or []) for d in p}) or 1)
    vec = None
    if kind in ("reduce-scatter", "all-to-all", "ragged-all-to-all"):
        local = max(1, payload // max(1, n))
        if n >= 2 and payload > 0:
            vec = [payload / n] * n
    else:
        local = payload
    return CollectiveOp(
        kind=kind,
        name=name or kind,
        result_shapes=[Shape(dtype="u8", dims=(local,))],
        replica_groups=groups,
        source_target_pairs=[tuple(p) for p in (pairs or [])],
        op_name=op_name or name or kind,
        weight=float(weight),
        phase=phase,
        bytes_per_rank_vec=vec,
        measured_s=(float(measured_s)
                    if measured_s is not None else None),
    )
