"""Shared datatypes of the trace-ingestion subsystem.

Every frontend (:mod:`.perfetto`, :mod:`.nvprof`, :mod:`.jsonl`) is a
:class:`TraceSource`: it sniffs whether a file is in its format and parses
it into one :class:`TraceImport` -- a normalized bundle of
:class:`~repro.core.events.CollectiveOp` records carrying *measured*
wall-clock seconds (``op.measured_s``, schema v9) plus host transfers,
optional topology, and import provenance.  ``TraceImport.report()`` then
snapshots the bundle as an ordinary
:class:`~repro.core.monitor.CommReport`, so every downstream consumer --
matrix, links, phases, HTML, Perfetto, compare -- works on measured data
unchanged.

Malformed input never degrades silently: each frontend raises
:class:`TraceParseError` naming the offending record (line / row / event),
so a truncated file or an unknown device id can never produce a quiet
zero-row matrix.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from ..events import CollectiveOp, HostTransfer, PhaseRecord
from ..topology import MeshTopology


class TraceParseError(ValueError):
    """A trace file could not be parsed.

    Carries the file path and a short description of the offending record
    (``record``, e.g. ``"line 17"`` or ``"row 4 (ncclAllReduce...)"``) so
    the message pinpoints *which* record broke, not just that one did.
    """

    def __init__(self, message: str, *, path: Optional[str] = None,
                 record: Optional[str] = None):
        self.path = path
        self.record = record
        loc = ""
        if path:
            loc += f"{path}: "
        if record:
            loc += f"{record}: "
        super().__init__(f"{loc}{message}")


@dataclasses.dataclass
class TraceImport:
    """One parsed device trace, normalized onto the repo's event model.

    ``ops`` carry ``measured_s`` (total measured wall seconds per op,
    worst rank for multi-rank records); ``meta`` records import
    provenance (frontend, source path, device mapping, clock alignment)
    and is persisted as the report's schema-v9 ``trace_meta`` section.
    """

    name: str
    num_devices: int
    ops: list[CollectiveOp] = dataclasses.field(default_factory=list)
    host_transfers: list[HostTransfer] = dataclasses.field(
        default_factory=list)
    topo: Optional[MeshTopology] = None
    algorithm: str = "ring"
    phases: list[PhaseRecord] = dataclasses.field(default_factory=list)
    sparse: Optional[bool] = None
    meta: dict = dataclasses.field(default_factory=dict)

    def view(self, algorithm: Optional[str] = None):
        """A :class:`~repro.core.views.CommView` over the imported ops."""
        from ..views import build_view

        return build_view(
            self.ops, self.num_devices, algorithm or self.algorithm,
            self.topo, self.host_transfers, phase=None, known_phases=(),
            label=self.name, sparse=self.sparse)

    def report(self):
        """Snapshot the import as a :class:`~repro.core.monitor.CommReport`.

        The eager artifacts (matrix / per-primitive / summary) are built
        through the same :class:`~repro.core.views.CommView` pipeline a
        live session uses, so an import of our own Perfetto export
        reproduces the original comm matrix bitwise.
        """
        from ..monitor import CommReport

        v = self.view()
        return CommReport(
            name=self.name,
            num_devices=self.num_devices,
            traced=[],
            compiled_ops=list(self.ops),
            traced_summary={},
            compiled_summary=v.summary,
            matrix=v.matrix,
            per_primitive=v.per_primitive,
            cost={},
            memory_stats=None,
            trace_seconds=0.0,
            compile_seconds=0.0,
            topo=self.topo,
            host_transfers=list(self.host_transfers),
            algorithm=self.algorithm,
            meta={},
            phases=list(self.phases),
            trace_meta=dict(self.meta) if self.meta else None,
        )


class TraceSource:
    """Interface of one trace-format frontend.

    Subclasses set :attr:`format` / :attr:`extensions` and implement
    :meth:`sniff` (cheap content test on the file's head) and
    :meth:`parse` (full file -> :class:`TraceImport`).  The registry in
    :mod:`repro.core.trace` routes ``load_trace`` through these.
    """

    #: short format name (the CLI's ``--fmt`` value)
    format: str = ""
    #: lowercase filename extensions this frontend claims by default
    extensions: tuple = ()

    @classmethod
    def sniff(cls, path: str, head: str) -> bool:
        """Whether ``head`` (the file's first few KiB) looks like this
        format.  Must not raise."""
        raise NotImplementedError

    @classmethod
    def parse(cls, path: str, **opts) -> TraceImport:
        """Parse the full file; raise :class:`TraceParseError` on any
        malformed record."""
        raise NotImplementedError
