"""Modeled-vs-measured comparison: the model-vs-measured loop, closed.

Takes a *measured* report (ops carrying ``measured_s`` from a trace
import) and a *model* (the same report's own cost model, or a second
purely-modeled report, e.g. a sweep result for the same config) and pins
one against the other per collective:

* rows are matched by exact ``(phase, name)`` first, then per-kind FIFO
  (k-th measured all-reduce <-> k-th modeled all-reduce) -- trace tools
  rarely preserve HLO names, program order within a kind is the stable
  signal;
* each matched row gets ``rel_err = |measured - modeled| / measured``;
* aggregates (mean/max relative error, second totals) are bucketed
  per collective kind and per payload size class
  (<64KiB, 64KiB-1MiB, 1-16MiB, >=16MiB -- latency-bound through
  bandwidth-bound).

The result renders as a terminal table
(:meth:`CompareResult.table`), JSON (:meth:`CompareResult.to_dict`,
the CLI's ``compare --json``), CSV and HTML (``repro.core.export``).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

from ..reporter import format_table, human_bytes

#: payload size-class buckets (upper bound in bytes, label), ordered
SIZE_CLASSES = (
    (64 * 1024, "<64KiB"),
    (1 << 20, "64KiB-1MiB"),
    (16 << 20, "1-16MiB"),
    (None, ">=16MiB"),
)


def size_class(nbytes: float) -> str:
    for bound, label in SIZE_CLASSES:
        if bound is None or nbytes < bound:
            return label
    return SIZE_CLASSES[-1][1]


@dataclasses.dataclass
class CompareRow:
    """One matched collective: the model's seconds vs the trace's."""

    name: str
    kind: str
    phase: str
    payload_bytes: float
    modeled_s: Optional[float]
    measured_s: float

    @property
    def rel_err(self) -> Optional[float]:
        """``|measured - modeled| / measured``; None when either side is
        missing or the measurement is non-positive."""
        if self.modeled_s is None or self.measured_s <= 0:
            return None
        return abs(self.measured_s - self.modeled_s) / self.measured_s

    @property
    def size_class(self) -> str:
        return size_class(self.payload_bytes)

    def to_dict(self) -> dict:
        return {
            "name": self.name, "kind": self.kind, "phase": self.phase,
            "payload_bytes": float(self.payload_bytes),
            "modeled_s": (None if self.modeled_s is None
                          else float(self.modeled_s)),
            "measured_s": float(self.measured_s),
            "rel_err": self.rel_err,
            "size_class": self.size_class,
        }


def _bucket_stats(rows: list) -> dict:
    errs = [r.rel_err for r in rows if r.rel_err is not None]
    return {
        "count": len(rows),
        "measured_s": float(sum(r.measured_s for r in rows)),
        "modeled_s": float(sum(r.modeled_s or 0.0 for r in rows)),
        "mean_rel_err": (sum(errs) / len(errs)) if errs else None,
        "max_rel_err": max(errs) if errs else None,
    }


@dataclasses.dataclass
class CompareResult:
    """All matched rows plus the unmatched leftovers on both sides."""

    rows: list
    unmatched_measured: int = 0
    unmatched_modeled: int = 0
    measured_label: str = ""
    modeled_label: str = ""
    algorithm: str = "ring"

    def stats(self) -> dict:
        s = _bucket_stats(self.rows)
        s["unmatched_measured"] = self.unmatched_measured
        s["unmatched_modeled"] = self.unmatched_modeled
        return s

    def by_kind(self) -> dict:
        out: dict = {}
        for r in self.rows:
            out.setdefault(r.kind, []).append(r)
        return {k: _bucket_stats(v) for k, v in sorted(out.items())}

    def by_size_class(self) -> dict:
        out = {label: [] for _b, label in SIZE_CLASSES}
        for r in self.rows:
            out[r.size_class].append(r)
        return {label: _bucket_stats(v)
                for label, v in out.items() if v}

    def max_rel_err(self) -> Optional[float]:
        return self.stats()["max_rel_err"]

    def to_dict(self) -> dict:
        return {
            "measured": self.measured_label,
            "modeled": self.modeled_label,
            "algorithm": self.algorithm,
            "stats": self.stats(),
            "by_kind": self.by_kind(),
            "by_size_class": self.by_size_class(),
            "rows": [r.to_dict() for r in self.rows],
        }

    # -- terminal rendering -------------------------------------------------
    def table(self, title: str = "") -> str:
        """Per-collective modeled-vs-measured table plus the per-kind and
        per-size-class aggregate blocks."""
        def fmt_err(e):
            return "-" if e is None else f"{e * 100:.1f}%"

        def fmt_s(s):
            return "-" if s is None else f"{s * 1e3:.3f} ms"

        lines = []
        if title:
            lines.append(title)
        body = [[r.name, r.kind, r.phase or "-",
                 human_bytes(r.payload_bytes), fmt_s(r.modeled_s),
                 fmt_s(r.measured_s), fmt_err(r.rel_err)]
                for r in self.rows]
        lines.append(format_table(
            body, header=["Op", "Kind", "Phase", "Payload", "Modeled",
                          "Measured", "RelErr"]))
        for label, buckets in (("by kind", self.by_kind()),
                               ("by size class", self.by_size_class())):
            if not buckets:
                continue
            rows = [[k, str(b["count"]), fmt_s(b["modeled_s"]),
                     fmt_s(b["measured_s"]), fmt_err(b["mean_rel_err"]),
                     fmt_err(b["max_rel_err"])]
                    for k, b in buckets.items()]
            lines.append("")
            lines.append(format_table(
                rows, header=[label, "Ops", "Modeled", "Measured",
                              "MeanErr", "MaxErr"]))
        s = self.stats()
        lines.append("")
        tail = (f"{s['count']} matched"
                f" ({s['unmatched_measured']} measured /"
                f" {s['unmatched_modeled']} modeled unmatched);"
                f" mean rel err {fmt_err(s['mean_rel_err'])},"
                f" max {fmt_err(s['max_rel_err'])}")
        lines.append(tail)
        return "\n".join(lines)


def _measured_ops(report) -> list:
    return [op for op in report.compiled_ops if op.measured_s is not None]


def compare(measured, model=None, *, algorithm: Optional[str] = None
            ) -> CompareResult:
    """Build the :class:`CompareResult` for a measured report.

    ``measured`` is a :class:`~repro.core.monitor.CommReport` whose ops
    carry ``measured_s`` (a trace import or a loaded v9 file).  ``model``
    picks the modeled side:

    * ``None`` -- the measured report's *own* cost model: each measured
      op's decomposition-schedule seconds under the report's topology
      (requires one);
    * another ``CommReport`` -- its ops' modeled seconds, matched to the
      measured ops by ``(phase, name)`` then per-kind FIFO.

    Raises :class:`ValueError` when there is nothing to compare (no
    measured ops, or no modeled seconds on the chosen side).
    """
    mops = _measured_ops(measured)
    if not mops:
        raise ValueError(
            f"report {measured.name!r} carries no measured ops"
            " (measured_s is unset on every op); import a trace first")

    if model is None:
        view = measured.view(algorithm)
        if view.topo is None:
            raise ValueError(
                f"report {measured.name!r} has no topology: its own ops"
                " cannot be modeled -- pass a modeled report or config")
        secs = view.op_seconds()
        rows = [CompareRow(name=op.name, kind=op.kind, phase=op.phase,
                           payload_bytes=op.payload_bytes,
                           modeled_s=s, measured_s=op.measured_s)
                for op, s in zip(view.ops, secs)
                if op.measured_s is not None]
        return CompareResult(
            rows=rows, measured_label=measured.name,
            modeled_label=f"{measured.name} (own model)",
            algorithm=view.algorithm)

    mview = model.view(algorithm)
    if mview.topo is None:
        raise ValueError(
            f"model report {model.name!r} has no topology --"
            " no modeled seconds to compare against")
    model_secs = mview.op_seconds()
    model_ops = list(mview.ops)

    used = [False] * len(model_ops)
    by_name = {}
    for i, op in enumerate(model_ops):
        by_name.setdefault((op.phase, op.name), []).append(i)
    rows: list[CompareRow] = []
    unmatched = 0

    def claim(i, mop):
        used[i] = True
        op = model_ops[i]
        rows.append(CompareRow(
            name=op.name, kind=op.kind, phase=op.phase,
            payload_bytes=op.payload_bytes, modeled_s=model_secs[i],
            measured_s=mop.measured_s))

    fifo: list = []
    for mop in mops:
        cands = by_name.get((mop.phase, mop.name), [])
        i = next((j for j in cands if not used[j]), None)
        if i is not None:
            claim(i, mop)
        else:
            fifo.append(mop)
    for mop in fifo:
        i = next((j for j, op in enumerate(model_ops)
                  if not used[j] and op.kind == mop.kind), None)
        if i is not None:
            claim(i, mop)
        else:
            unmatched += 1

    result = CompareResult(
        rows=rows, unmatched_measured=unmatched,
        unmatched_modeled=used.count(False),
        measured_label=measured.name, modeled_label=model.name,
        algorithm=mview.algorithm)
    if not rows:
        raise ValueError(
            f"no measured op of {measured.name!r} matched any modeled op"
            f" of {model.name!r} (kinds measured:"
            f" {sorted({o.kind for o in mops})}, modeled:"
            f" {sorted({o.kind for o in model_ops})})")
    if all(r.rel_err is None or not math.isfinite(r.rel_err)
           for r in result.rows):
        raise ValueError(
            "no finite relative error in any matched row -- measured"
            " durations are zero or modeled seconds missing")
    return result
