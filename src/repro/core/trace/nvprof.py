"""ComScribe-style nvprof GPU-trace CSV frontend.

Parses the ``nvprof --print-gpu-trace --csv`` shape the paper's tool
consumes: ``==``-prefixed banner lines, a quoted header row, an optional
units row (``ms`` / ``us`` / ``MB`` / ``B`` ...), then one row per kernel
or memcpy.  The rows that matter here:

* ``[CUDA memcpy HtoD]`` / ``[CUDA memcpy DtoH]`` -> host transfers
  (the comm matrix's row/col 0).
* ``[CUDA memcpy PtoP]`` -> device-to-device copies; rows sharing a
  correlation id merge into one ``collective-permute`` carrying all the
  observed (src, dst) pairs.
* ``nccl*Kernel`` rows (``ncclAllReduceRingLLKernel_sum_f32(...)``) ->
  collectives.  NCCL launches one kernel per participating device, so
  rows are clustered into one logical collective by ``(kind,
  correlation id)`` when the file has a correlation column, else by
  ``(kind, per-device occurrence index)``; the measured duration is the
  **worst rank's** (max over the cluster) and the payload is the
  cluster's max ``Size``.

A CSV without a byte column (``Size``/``Bytes``) cannot produce a comm
matrix and raises :class:`~.base.TraceParseError` up front, as do
negative durations and unmappable device labels -- never a silent
zero-row matrix.
"""
from __future__ import annotations

import csv
import io
from typing import Optional

from ..events import HostTransfer
from .base import TraceImport, TraceParseError, TraceSource
from .normalize import DeviceMap, collective_kind, measured_op

_DUR_UNITS = {"s": 1.0, "ms": 1e-3, "us": 1e-6, "ns": 1e-9}
_SIZE_UNITS = {"b": 1.0, "kb": 1024.0, "mb": 1024.0 ** 2,
               "gb": 1024.0 ** 3}

# nvprof's own defaults when the units row is absent
_DEFAULT_DUR_UNIT = "ms"
_DEFAULT_SIZE_UNIT = "mb"


def _norm(h: str) -> str:
    return "".join(ch for ch in h.lower() if ch.isalnum())


_COLS = {
    "start": ("start",),
    "duration": ("duration", "dur"),
    "size": ("size", "bytes"),
    "device": ("device", "dev"),
    "srcdev": ("srcdev", "srcdevice", "sourcedevice"),
    "dstdev": ("dstdev", "dstdevice", "destinationdevice"),
    "name": ("name", "kernel"),
    "corr": ("correlationid", "correlation", "corrid"),
}


def _find_cols(header: list[str], path: str) -> dict:
    normed = [_norm(h) for h in header]
    cols = {}
    for key, aliases in _COLS.items():
        for a in aliases:
            if a in normed:
                cols[key] = normed.index(a)
                break
    if "name" not in cols or "duration" not in cols:
        raise TraceParseError(
            f"header row lacks Name/Duration columns (got {header!r})",
            path=path, record="header")
    return cols


def _cell(row: list[str], idx: Optional[int]) -> str:
    if idx is None or idx >= len(row):
        return ""
    return row[idx].strip()


def _float(s: str, what: str, where: str, path: str, *,
           minimum: Optional[float] = None) -> float:
    try:
        v = float(s)
    except ValueError:
        raise TraceParseError(f"bad {what} value {s!r}",
                              path=path, record=where) from None
    if minimum is not None and v < minimum:
        raise TraceParseError(f"negative {what}: {s!r}",
                              path=path, record=where)
    return v


class NvprofCsvSource(TraceSource):
    """The nvprof/ComScribe GPU-trace CSV format (see module docstring)."""

    format = "nvprof"
    extensions = (".csv",)

    @classmethod
    def sniff(cls, path: str, head: str) -> bool:
        for line in head.splitlines():
            if not line.strip() or line.startswith("=="):
                continue
            n = _norm(line)
            return "duration" in n and ("name" in n or "kernel" in n)
        return False

    @classmethod
    def parse(cls, path: str, *, num_devices: Optional[int] = None,
              device_map: Optional[dict] = None,
              name: Optional[str] = None, **_opts) -> TraceImport:
        with open(path) as f:
            text = f.read()
        data_lines = [ln for ln in text.splitlines()
                      if ln.strip() and not ln.startswith("==")]
        if not data_lines:
            raise TraceParseError("no CSV rows (banner only?)", path=path)
        rows = list(csv.reader(io.StringIO("\n".join(data_lines))))
        cols = _find_cols(rows[0], path)
        body = rows[1:]

        dur_scale = _DUR_UNITS[_DEFAULT_DUR_UNIT]
        size_scale = _SIZE_UNITS[_DEFAULT_SIZE_UNIT]
        if body and _is_units_row(body[0], cols):
            units = body.pop(0)
            du = _cell(units, cols["duration"]).lower()
            dur_scale = _DUR_UNITS.get(du, dur_scale)
            if "size" in cols:
                su = _cell(units, cols.get("size")).lower()
                size_scale = _SIZE_UNITS.get(su, size_scale)

        devmap = DeviceMap(num_devices, device_map, path=path)
        transfers: list[HostTransfer] = []
        clusters: dict = {}
        order: list = []
        occ: dict = {}   # (kind, device) -> occurrence count
        for rnum, row in enumerate(body, start=2):
            rname = _cell(row, cols["name"])
            where = f"row {rnum} ({rname or 'unnamed'})"
            if not rname:
                continue
            low = rname.lower()
            if "memcpy" in low:
                _parse_memcpy(low, row, cols, rnum, rname, devmap,
                              dur_scale, size_scale, path, transfers,
                              clusters, order, occ)
                continue
            kind = collective_kind(rname)
            if kind is None:
                continue           # compute kernel, memset, ... -- not comm
            if "size" not in cols:
                raise TraceParseError(
                    "collective rows but no byte column (Size/Bytes) in"
                    " the header -- cannot build a comm matrix",
                    path=path, record=where)
            dur = _float(_cell(row, cols["duration"]), "duration", where,
                         path, minimum=0) * dur_scale
            size = _float(_cell(row, cols["size"]), "size", where, path,
                          minimum=0) * size_scale
            dev = None
            if _cell(row, cols.get("device")):
                dev = devmap.resolve(_cell(row, cols["device"]),
                                     record=where)
            corr = _cell(row, cols.get("corr"))
            if corr:
                key = (kind, "corr", corr)
            else:
                k = occ.get((kind, dev), 0)
                occ[(kind, dev)] = k + 1
                key = (kind, "occ", k)
            c = clusters.get(key)
            if c is None:
                c = {"kind": kind, "name": rname.split("(")[0],
                     "dur": dur, "bytes": size, "devices": set(),
                     "pairs": [], "row": rnum}
                clusters[key] = c
                order.append(key)
            else:
                c["dur"] = max(c["dur"], dur)
                c["bytes"] = max(c["bytes"], size)
            if dev is not None:
                c["devices"].add(dev)

        ndev = num_devices
        if ndev is None:
            ndev = max(devmap.seen, default=0) + 1
        devmap.num_devices = ndev

        ops = []
        for key in order:
            c = clusters[key]
            devs = sorted(c["devices"])
            # a single-process profile often sees one device; the logical
            # group is then the whole job
            group = devs if len(devs) > 1 else list(range(ndev))
            pairs = c["pairs"] or None
            if c["kind"] == "collective-permute" and pairs:
                group = sorted({d for p in pairs for d in p})
            ops.append(measured_op(
                c["kind"], payload_bytes=c["bytes"], groups=[group],
                name=f"{c['name']}.r{c['row']}", measured_s=c["dur"],
                pairs=pairs, op_name=c["name"]))

        return TraceImport(
            name=name or "nvprof-trace", num_devices=int(ndev), ops=ops,
            host_transfers=transfers,
            meta={"source": "nvprof", "path": path,
                  "num_rows": len(body),
                  "duration_scale_s": dur_scale,
                  "size_scale_bytes": size_scale})


def _is_units_row(row: list[str], cols: dict) -> bool:
    du = _cell(row, cols["duration"]).lower()
    return du in _DUR_UNITS


def _parse_memcpy(low: str, row: list[str], cols: dict, rnum: int,
                  rname: str, devmap: DeviceMap, dur_scale: float,
                  size_scale: float, path: str, transfers: list,
                  clusters: dict, order: list, occ: dict) -> None:
    where = f"row {rnum} ({rname})"
    if "size" not in cols:
        raise TraceParseError(
            "memcpy rows but no byte column (Size/Bytes) in the header",
            path=path, record=where)
    size = _float(_cell(row, cols["size"]), "size", where, path,
                  minimum=0) * size_scale
    dur = _float(_cell(row, cols["duration"]), "duration", where, path,
                 minimum=0) * dur_scale
    if "htod" in low or "dtoh" in low:
        direction = "h2d" if "htod" in low else "d2h"
        dev = 0
        if _cell(row, cols.get("device")):
            dev = devmap.resolve(_cell(row, cols["device"]), record=where)
        transfers.append(HostTransfer(direction=direction, device=dev,
                                      nbytes=int(round(size)),
                                      label="cuda-memcpy"))
        return
    if "ptop" not in low:
        return                       # DtoD on one device moves no wire bytes
    src_s = _cell(row, cols.get("srcdev")) or _cell(row, cols.get("device"))
    dst_s = _cell(row, cols.get("dstdev"))
    if not src_s or not dst_s:
        raise TraceParseError(
            "PtoP memcpy without src/dst device columns",
            path=path, record=where)
    src = devmap.resolve(src_s, record=where)
    dst = devmap.resolve(dst_s, record=where)
    corr = _cell(row, cols.get("corr"))
    if corr:
        key = ("collective-permute", "corr", corr)
    else:
        k = occ.get(("ptop", None), 0)
        occ[("ptop", None)] = k + 1
        key = ("collective-permute", "occ-p2p", k)
    c = clusters.get(key)
    if c is None:
        c = {"kind": "collective-permute", "name": "cuda-memcpy-ptop",
             "dur": dur, "bytes": size, "devices": set(),
             "pairs": [], "row": rnum}
        clusters[key] = c
        order.append(key)
    else:
        c["dur"] = max(c["dur"], dur)
        c["bytes"] = max(c["bytes"], size)
    c["pairs"].append((src, dst))
    c["devices"].update((src, dst))
