"""On-disk report cache: skip recompilation on repeated monitoring runs.

Compiling a model config is the hot path of iterative use -- seconds to
minutes per (config, mesh) cell -- while everything downstream (matrices,
tables, exports) derives from the parsed collective schedule in milliseconds.
So the sweep engine caches whole :class:`~repro.core.monitor.CommReport`
objects on disk, serialized through :mod:`repro.core.export.serialize`.

**Cache-key semantics.**  A key is the SHA-256 (first 20 hex chars) of the
JSON tuple ``(schema, config, mesh, algorithm, jax_version)``:

* ``config``  -- the sweep config identity *including its builder version
  string* (e.g. ``"gnmt/v1:d=64,layers=2,steps=4"``), so editing a builder
  invalidates its entries;
* ``mesh``    -- canonical mesh id, shape x axes (e.g. ``"4x2:data,model"``);
* ``algorithm`` -- collective algorithm used for byte/edge accounting
  (``ring`` / ``tree`` / ``hierarchical``); compilation does not depend on
  it, but the derived matrices and summaries do, so each algorithm gets its
  own entry (derivation from a sibling entry is still compile-free: a lazy
  ``CommReport.view(algorithm)`` binding, snapshotted by
  ``CommReport.rebound``);
* ``jax_version`` -- XLA's collective emission changes across releases, so
  reports never survive a jax upgrade.

**Phase-aware entries.**  Sessions capture under named phases, but a phase
is a *view* of the session snapshot, not a separate compilation -- so a
sweep cell keyed with ``phase=`` resolves to the SAME cache entry as the
whole session (:func:`cache_key` deliberately folds ``phase`` out of the
hash) and :meth:`ReportCache.get` hands back the cached whole-session
snapshot, from which ``report.view(phase=...)`` derives the per-phase
artifacts in milliseconds.  A phase the cached snapshot never captured is
a miss (the caller re-monitors the session, which then contains it).

The cache directory defaults to ``artifacts/report_cache`` (override with
``REPRO_CACHE_DIR`` or ``ReportCache(root=...)``).  Entries are one JSON file
per key, written atomically (tmp file + rename); a corrupt or unreadable
entry behaves as a miss.  Inspect or clear from the CLI::

    python -m repro cache            # list entries, total size
    python -m repro cache --clear
"""
from __future__ import annotations

import hashlib
import json
import os
import tempfile
from typing import Optional

_SCHEMA = "repro.report_cache.v1"
DEFAULT_ROOT = os.path.join("artifacts", "report_cache")


def cache_key(config: str, mesh: str, algorithm: str,
              jax_version: Optional[str] = None, *,
              phase: Optional[str] = None) -> str:
    """Deterministic key for one (config, mesh, algorithm, jax) cell.

    ``phase`` is accepted -- and deliberately **not hashed** -- so a
    per-phase sweep cell addresses the whole-session snapshot it derives
    from: ``cache_key(..., phase="decode") == cache_key(...)``.  Pass the
    phase to :meth:`ReportCache.get` instead to assert the cached snapshot
    actually captured it.
    """
    del phase  # key-neutral by design: phases are views of one snapshot
    if jax_version is None:
        import jax
        jax_version = jax.__version__
    blob = json.dumps([_SCHEMA, config, mesh, algorithm, jax_version])
    return hashlib.sha256(blob.encode()).hexdigest()[:20]


class ReportCache:
    """Directory of serialized CommReports, addressed by :func:`cache_key`."""

    def __init__(self, root: Optional[str] = None):
        self.root = root or os.environ.get("REPRO_CACHE_DIR") or DEFAULT_ROOT
        self.hits = 0
        self.misses = 0

    def path_for(self, key: str) -> str:
        return os.path.join(self.root, key + ".json")

    def get(self, key: str, phase: Optional[str] = None):
        """Cached CommReport for ``key``, or None (corrupt entry == miss).

        ``phase`` makes the lookup phase-aware: the WHOLE-session snapshot
        is returned (phases are lazy views over it -- derive with
        ``report.view(phase=...)``; nothing is recaptured), but a phase
        the snapshot never captured counts as a miss so the caller
        re-monitors a session that contains it.
        """
        path = self.path_for(key)
        try:
            with open(path) as f:
                payload = json.load(f)
            from .export import serialize
            report = serialize.report_from_dict(payload["report"])
        except (OSError, KeyError, ValueError, TypeError):
            self.misses += 1
            return None
        if phase is not None and phase not in report.phase_names():
            self.misses += 1
            return None
        report.meta = dict(payload.get("meta", {}))
        self.hits += 1
        return report

    def put(self, key: str, report, meta: Optional[dict] = None) -> str:
        """Store ``report`` under ``key`` atomically; returns the entry path."""
        from .export import serialize
        os.makedirs(self.root, exist_ok=True)
        payload = {
            "schema": _SCHEMA,
            "key": key,
            "meta": dict(meta or getattr(report, "meta", {}) or {}),
            "report": serialize.report_to_dict(report),
        }
        fd, tmp = tempfile.mkstemp(dir=self.root, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(payload, f)
            os.replace(tmp, self.path_for(key))
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)
        return self.path_for(key)

    # ------------------------------------------------------------------
    # maintenance
    # ------------------------------------------------------------------
    def entries(self) -> list[dict]:
        out = []
        if not os.path.isdir(self.root):
            return out
        for fn in sorted(os.listdir(self.root)):
            if not fn.endswith(".json"):
                continue
            path = os.path.join(self.root, fn)
            entry = {"key": fn[:-5], "path": path,
                     "size": os.path.getsize(path)}
            try:
                with open(path) as f:
                    payload = json.load(f)
                entry["meta"] = payload.get("meta", {})
                entry["name"] = payload.get("report", {}).get("name", "?")
            except (OSError, ValueError, TypeError, AttributeError):
                entry["corrupt"] = True
            out.append(entry)
        return out

    def clear(self) -> int:
        """Delete every entry; returns the number removed."""
        n = 0
        for e in self.entries():
            os.unlink(e["path"])
            n += 1
        return n
