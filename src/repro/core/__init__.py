# CommScribe-JAX core: the paper's contribution (collective-communication
# monitoring) as a composable library. See DESIGN.md §3.
from .events import (CollectiveOp, HostTransfer, PhaseRecord, Shape,
                     TraceEvent, jax_shape)
from .interceptor import CollectiveInterceptor, intercept, traced_summary
from .hlo_parser import parse_hlo_collectives, summarize, total_wire_bytes
from .comm_matrix import (LinkUtilization, add_host_transfers,
                          link_utilization_for_ops, matrix_for_ops,
                          matrix_for_ops_reference, op_edge_arrays, op_edges,
                          per_primitive_matrices, project_links)
# NOTE: the decompose() function itself is NOT re-exported at package
# level -- binding the name here would shadow the repro.core.decompose
# submodule attribute (import it via `from repro.core.decompose import
# decompose`); only the IR types and the warning are lifted.
from .decompose import (CollectiveSchedule, CommPhase,
                        HierarchicalFallbackWarning)
from .cost_models import (ALGORITHMS, collective_time, contention_time,
                          device_send_bytes, table1_allreduce_bytes,
                          validate_algorithm, wire_bytes_per_rank)
from .sparse import (SPARSE_DEVICE_THRESHOLD, SparseCommMatrix, from_dense,
                     is_sparse)
from .topology import HardwareSpec, Link, MeshTopology, V5E
from .views import CommView
from .monitor import CommReport, monitor_fn, roofline_of
from .session import Capture, MonitorSession
from .roofline import RooflineReport, analyze as roofline_analyze
from .report_cache import ReportCache, cache_key
from . import reporter
from . import export
from . import trace
from .trace import (CompareResult, TraceImport, TraceParseError, load_trace,
                    trace_compare)

__all__ = [
    "trace", "TraceImport", "TraceParseError", "load_trace",
    "CompareResult", "trace_compare",
    "CollectiveOp", "HostTransfer", "PhaseRecord", "Shape", "TraceEvent",
    "jax_shape",
    "CollectiveInterceptor", "intercept", "traced_summary",
    "parse_hlo_collectives", "summarize", "total_wire_bytes",
    "matrix_for_ops", "matrix_for_ops_reference", "op_edges",
    "op_edge_arrays", "per_primitive_matrices", "add_host_transfers",
    "LinkUtilization", "project_links", "link_utilization_for_ops",
    "CollectiveSchedule", "CommPhase", "HierarchicalFallbackWarning",
    "ALGORITHMS", "validate_algorithm",
    "wire_bytes_per_rank", "collective_time", "table1_allreduce_bytes",
    "contention_time", "device_send_bytes",
    "SPARSE_DEVICE_THRESHOLD", "SparseCommMatrix", "from_dense", "is_sparse",
    "HardwareSpec", "Link", "MeshTopology", "V5E",
    "CommView", "CommReport", "monitor_fn", "roofline_of",
    "Capture", "MonitorSession",
    "RooflineReport", "roofline_analyze",
    "ReportCache", "cache_key",
    "reporter", "export",
]
