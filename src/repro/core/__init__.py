# CommScribe-JAX core: the paper's contribution (collective-communication
# monitoring) as a composable library. See DESIGN.md §3.
from .events import CollectiveOp, HostTransfer, Shape, TraceEvent, jax_shape
from .interceptor import CollectiveInterceptor, intercept
from .hlo_parser import parse_hlo_collectives, summarize, total_wire_bytes
from .comm_matrix import matrix_for_ops, per_primitive_matrices, add_host_transfers
from .cost_models import wire_bytes_per_rank, collective_time, table1_allreduce_bytes
from .topology import HardwareSpec, MeshTopology, V5E
from .monitor import CommReport, monitor_fn, roofline_of
from .roofline import RooflineReport, analyze as roofline_analyze
from .report_cache import ReportCache, cache_key
from . import reporter
from . import export

__all__ = [
    "CollectiveOp", "HostTransfer", "Shape", "TraceEvent", "jax_shape",
    "CollectiveInterceptor", "intercept",
    "parse_hlo_collectives", "summarize", "total_wire_bytes",
    "matrix_for_ops", "per_primitive_matrices", "add_host_transfers",
    "wire_bytes_per_rank", "collective_time", "table1_allreduce_bytes",
    "HardwareSpec", "MeshTopology", "V5E",
    "CommReport", "monitor_fn", "roofline_of",
    "RooflineReport", "roofline_analyze",
    "ReportCache", "cache_key",
    "reporter", "export",
]
