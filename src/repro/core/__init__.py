# CommScribe-JAX core: the paper's contribution (collective-communication
# monitoring) as a composable library. See DESIGN.md §3.
from .events import CollectiveOp, HostTransfer, Shape, TraceEvent, jax_shape
from .interceptor import CollectiveInterceptor, intercept
from .hlo_parser import parse_hlo_collectives, summarize, total_wire_bytes
from .comm_matrix import (LinkUtilization, add_host_transfers,
                          link_utilization_for_ops, matrix_for_ops,
                          per_primitive_matrices, project_links)
from .cost_models import (collective_time, contention_time, device_send_bytes,
                          table1_allreduce_bytes, wire_bytes_per_rank)
from .topology import HardwareSpec, Link, MeshTopology, V5E
from .monitor import CommReport, monitor_fn, roofline_of
from .roofline import RooflineReport, analyze as roofline_analyze
from .report_cache import ReportCache, cache_key
from . import reporter
from . import export

__all__ = [
    "CollectiveOp", "HostTransfer", "Shape", "TraceEvent", "jax_shape",
    "CollectiveInterceptor", "intercept",
    "parse_hlo_collectives", "summarize", "total_wire_bytes",
    "matrix_for_ops", "per_primitive_matrices", "add_host_transfers",
    "LinkUtilization", "project_links", "link_utilization_for_ops",
    "wire_bytes_per_rank", "collective_time", "table1_allreduce_bytes",
    "contention_time", "device_send_bytes",
    "HardwareSpec", "Link", "MeshTopology", "V5E",
    "CommReport", "monitor_fn", "roofline_of",
    "RooflineReport", "roofline_analyze",
    "ReportCache", "cache_key",
    "reporter", "export",
]
