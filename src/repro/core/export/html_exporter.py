"""Self-contained HTML dashboard of communication matrices (paper Figs. 2/3).

One static file, no JavaScript libraries: every ``(d+1) x (d+1)`` matrix is an
HTML table whose cells are bucketed onto a 13-step single-hue sequential ramp
(log scale, light -> dark = near-zero -> max).  Dark mode re-steps the same
ramp against the dark surface (reversed, so "near zero" recedes toward the
surface in both modes) via ``prefers-color-scheme`` -- the cells themselves
only carry a bucket class.

Each cell exposes its exact value as a hover tooltip (``title``), every
matrix ships a color legend with min/max labels, and a collapsible raw-value
table preserves a text-readable view of the same data.

Session reports with two or more named phases additionally render a pure-CSS
tab strip per report (radio inputs + sibling selectors, still zero
JavaScript): an "all phases" tab with the full artifact set, and one tab per
phase holding that phase's summary table and matrix heatmap.
"""
from __future__ import annotations

import html
import math
import os

import numpy as np

from .. import reporter
from ..sparse import is_sparse

# 13-step sequential blue ramp (steps 100..700 of the reference palette);
# validated single-hue light->dark -- index 0 = near zero, 12 = max.
_RAMP = (
    "#cde2fb", "#b7d3f6", "#9ec5f4", "#86b6ef", "#6da7ec", "#5598e7",
    "#3987e5", "#2a78d6", "#256abf", "#1c5cab", "#184f95", "#104281",
    "#0d366b",
)
_NBUCKETS = len(_RAMP)

_CSS = """
:root {
  color-scheme: light dark;
  --surface: #fcfcfb; --surface-2: #f0efec;
  --text-1: #0b0b0b; --text-2: #52514e; --border: #d9d8d3;
}
@media (prefers-color-scheme: dark) {
  :root { --surface: #1a1a19; --surface-2: #262624;
          --text-1: #ffffff; --text-2: #c3c2b7; --border: #3a3a37; }
}
body { background: var(--surface); color: var(--text-1);
       font: 14px/1.5 system-ui, sans-serif; margin: 2rem auto;
       max-width: 1100px; padding: 0 1rem; }
h1, h2, h3 { font-weight: 600; }
h1 { font-size: 1.4rem; } h2 { font-size: 1.15rem; margin-top: 2.5rem; }
h3 { font-size: 0.95rem; color: var(--text-2); margin-bottom: 0.3rem; }
.meta { color: var(--text-2); font-size: 0.85rem; }
.grid { display: flex; flex-wrap: wrap; gap: 1.5rem; align-items: flex-start; }
table.hm { border-collapse: separate; border-spacing: 2px; }
table.hm td { width: 16px; height: 16px; padding: 0; border-radius: 2px; }
table.hm th { font-weight: 400; font-size: 0.65rem; color: var(--text-2);
              padding: 0 2px; text-align: center; }
table.sum { border-collapse: collapse; margin: 0.5rem 0; }
table.sum th, table.sum td { text-align: left; padding: 2px 12px 2px 0;
  border-bottom: 1px solid var(--border); font-size: 0.85rem; }
table.sum th { color: var(--text-2); font-weight: 500; }
td.z { background: var(--surface-2); }
.legend { display: flex; align-items: center; gap: 6px; margin: 0.4rem 0;
          font-size: 0.75rem; color: var(--text-2); }
.legend .bar { display: flex; }
.legend .bar i { width: 12px; height: 10px; display: inline-block; }
details { margin: 0.5rem 0 1rem; }
details summary { cursor: pointer; color: var(--text-2); font-size: 0.8rem; }
details pre { font-size: 0.7rem; overflow-x: auto; background: var(--surface-2);
              padding: 0.5rem; border-radius: 4px; }
.tabs { margin: 1rem 0; }
.tabs > input { display: none; }
.tabs > label { display: inline-block; padding: 4px 14px; cursor: pointer;
                border: 1px solid var(--border); border-bottom: none;
                border-radius: 6px 6px 0 0; color: var(--text-2);
                font-size: 0.85rem; margin-right: 2px; }
.tabs > input:checked + label { background: var(--surface-2);
                                color: var(--text-1); font-weight: 600; }
.tabs > .panel { display: none; border-top: 1px solid var(--border);
                 padding-top: 0.8rem; }
""" + "\n".join(
    # pure-CSS tab switching: the checked radio reveals the same-index panel
    f".tabs > input:nth-of-type({i}):checked ~ .panel:nth-of-type({i})"
    " { display: block; }"
    for i in range(1, 17)
) + "\n" + "\n".join(
    f"td.q{i} {{ background: {c}; }}" for i, c in enumerate(_RAMP)
) + "\n@media (prefers-color-scheme: dark) {\n" + "\n".join(
    # dark mode: reversed ramp so near-zero recedes toward the dark surface
    f"  td.q{i} {{ background: {c}; }}"
    for i, c in enumerate(reversed(_RAMP))
) + "\n}\n"


def _bucket(value: float, vmax_log: float) -> int:
    if value <= 0 or vmax_log <= 0:
        return -1                      # zero cell: surface, not on the ramp
    t = max(0.0, math.log10(value)) / vmax_log
    return min(_NBUCKETS - 1, int(t * _NBUCKETS))


def _labels(d: int, block: int) -> list[str]:
    if block > 1:
        return ["host"] + [f"d{i * block}" for i in range(d - 1)]
    return ["host"] + [f"d{i}" for i in range(d - 1)]


def matrix_table(mat, *, max_devices: int = 32) -> str:
    """One matrix as an HTML heatmap table (+ legend + raw-value fallback).

    ``mat`` may be dense or a :class:`~repro.core.sparse.SparseCommMatrix`
    -- ``coarsen_matrix`` dispatches, so the rendered table is identical
    either way and the sparse path never builds the ``(d+1)^2`` array.
    """
    if not is_sparse(mat):
        mat = np.asarray(mat, dtype=np.float64)
    m, block = reporter.coarsen_matrix(mat, max_devices=max_devices)
    d = m.shape[0]
    labels = _labels(d, block)
    vmax = float(m.max())
    vmax_log = math.log10(vmax) if vmax > 1 else 1.0
    rows = ["<table class='hm'>",
            "<tr><th></th>" + "".join(f"<th>{l}</th>" for l in labels)
            + "</tr>"]
    for i in range(d):
        cells = [f"<th>{labels[i]}</th>"]
        for j in range(d):
            b = _bucket(m[i, j], vmax_log)
            cls = "z" if b < 0 else f"q{b}"
            tip = (f"{labels[i]} → {labels[j]}: "
                   f"{reporter.human_bytes(m[i, j])}")
            cells.append(f"<td class='{cls}' title='{html.escape(tip)}'></td>")
        rows.append("<tr>" + "".join(cells) + "</tr>")
    rows.append("</table>")
    swatches = "".join(f"<i style='background:{c}'></i>" for c in _RAMP)
    rows.append(
        "<div class='legend'><span>0</span><span class='bar'>"
        f"{swatches}</span><span>{reporter.human_bytes(vmax)}</span>"
        "<span>(log scale)</span></div>")
    if block > 1:
        rows.append(f"<div class='meta'>device blocks of {block}</div>")
    rows.append("<details><summary>raw values (CSV)</summary><pre>"
                + html.escape(reporter.matrix_to_csv(m)) + "</pre></details>")
    return "\n".join(rows)


def _summary_table(summary: dict) -> str:
    has_skew = any("max_skew" in summary[k] for k in summary)
    skew_th = "<th>skew (max/mean)</th>" if has_skew else ""
    rows = ["<table class='sum'><tr><th>primitive</th><th>calls</th>"
            f"<th>payload</th><th>wire bytes</th>{skew_th}</tr>"]
    for kind in sorted(summary, key=lambda k: -summary[k].get("wire_bytes", 0)):
        r = summary[kind]
        skew_td = (f"<td>{r.get('max_skew', 1.0):.2f}x</td>"
                   if has_skew else "")
        rows.append(
            f"<tr><td>{html.escape(kind)}</td><td>{r.get('calls', 0):,}</td>"
            f"<td>{reporter.human_bytes(r.get('payload_bytes', 0))}</td>"
            f"<td>{reporter.human_bytes(r.get('wire_bytes', 0))}</td>"
            f"{skew_td}</tr>")
    rows.append("</table>")
    return "\n".join(rows)


def _link_summary_table(lu) -> str:
    """Per link-kind aggregates (ICI vs DCN) under the link heatmap."""
    rows = ["<table class='sum'><tr><th>link kind</th><th>links</th>"
            "<th>total bytes</th><th>busiest link</th>"
            "<th>bottleneck ms</th></tr>"]
    summary = lu.summary()
    for kind in sorted(summary):
        r = summary[kind]
        rows.append(
            f"<tr><td>{html.escape(kind)}</td><td>{r['links']}</td>"
            f"<td>{reporter.human_bytes(r['bytes'])}</td>"
            f"<td>{html.escape(r['busiest_link'])}</td>"
            f"<td>{r['bottleneck_seconds'] * 1e3:.3f}</td></tr>")
    rows.append("</table>")
    return "\n".join(rows)


def _overlap_table(report, lu) -> str:
    """Tier-overlap view: serialized per-tier seconds next to each tier's
    busiest-link time, plus the overlapped vs serialized bound."""
    if not hasattr(report, "collective_seconds_split"):
        return ""
    ici_s, dcn_s = report.collective_seconds_split()
    rows = ["<table class='sum'><tr><th>tier</th><th>serialized ms</th>"
            "<th>busiest-link ms</th></tr>"]
    for tier, serial in (("ici", ici_s), ("dcn", dcn_s)):
        rows.append(
            f"<tr><td>{tier}</td><td>{serial * 1e3:.3f}</td>"
            f"<td>{lu.busy_seconds(tier) * 1e3:.3f}</td></tr>")
    rows.append("</table>")
    rows.append(
        f"<div class='meta'>overlapped (ici ∥ dcn): "
        f"{max(ici_s, dcn_s) * 1e3:.3f} ms &middot; serialized: "
        f"{(ici_s + dcn_s) * 1e3:.3f} ms</div>")
    return "\n".join(rows)


def link_section(report) -> str:
    """The physical-link panel: per-link byte heatmap + per-kind summary +
    the tier-overlap table.

    Entry ``(i+1, j+1)`` of the heatmap is the physical ICI link ``i -> j``
    (only torus neighbours light up); row/col 0 is the DCN tier (uplinks /
    downlinks).  Empty string for reports without a topology.
    """
    lu = report.link_utilization() \
        if hasattr(report, "link_utilization") else None
    if lu is None:
        return ""
    # sparse reports keep the link view sparse too: the COO link matrix is
    # O(links), the dense one O(d^2)
    link_mat = (lu.sparse_matrix() if is_sparse(report.matrix)
                else lu.matrix())
    return ("<div><h3>physical links</h3>"
            "<div class='meta'>row/col 0 = DCN uplink/downlink; "
            "other cells = ICI neighbour links</div>"
            + matrix_table(link_mat) + _link_summary_table(lu)
            + _overlap_table(report, lu)
            + "</div>")


_SEV_BADGE = {"error": "#c0392b", "warn": "#b9770e", "info": "#2874a6"}


def lint_panel(report) -> str:
    """The static-lint findings panel: one row per finding with severity,
    flagged ops, modeled savings and the suggested fix.  Empty string when
    the report carries no findings (clean capture, or no lint surface)."""
    findings = report.lint() if hasattr(report, "lint") else []
    if not findings:
        return ""
    rows = ["<div><h3>lint findings</h3>",
            "<div class='meta'>static anti-patterns with savings modeled "
            "by the decomposition engine (current vs suggested "
            "schedule)</div>",
            "<table class='sum'><tr><th>rule</th><th>severity</th>"
            "<th>phase</th><th>ops</th><th>est. savings</th>"
            "<th>DCN bytes saved</th><th>suggested fix</th></tr>"]
    for f in findings:
        ops = ",".join(f.op_names)
        if len(ops) > 60:
            ops = ops[:57] + f"...({len(f.op_names)} ops)"
        color = _SEV_BADGE.get(f.severity, "inherit")
        rows.append(
            f"<tr><td>{html.escape(f.rule_id)}</td>"
            f"<td style='color:{color}'>{html.escape(f.severity)}</td>"
            f"<td>{html.escape(f.phase or '-')}</td>"
            f"<td title='{html.escape(f.message)}'>{html.escape(ops)}</td>"
            f"<td>{f.est_savings_s * 1e3:.3f} ms</td>"
            f"<td>{reporter.human_bytes(f.est_dcn_bytes_saved)}</td>"
            f"<td>{html.escape(f.suggested_fix)}</td></tr>")
    rows.append("</table></div>")
    return "\n".join(rows)


def _compare_rows_table(result) -> str:
    """The shared per-collective modeled-vs-measured table body."""
    rows = ["<table class='sum'><tr><th>op</th><th>kind</th><th>phase</th>"
            "<th>payload</th><th>modeled</th><th>measured</th>"
            "<th>rel err</th></tr>"]
    for r in result.rows:
        mod = "-" if r.modeled_s is None else f"{r.modeled_s * 1e3:.3f} ms"
        err = "-" if r.rel_err is None else f"{r.rel_err * 100:.1f}%"
        rows.append(
            f"<tr><td>{html.escape(r.name)}</td>"
            f"<td>{html.escape(r.kind)}</td>"
            f"<td>{html.escape(r.phase or '-')}</td>"
            f"<td>{reporter.human_bytes(r.payload_bytes)}</td>"
            f"<td>{mod}</td><td>{r.measured_s * 1e3:.3f} ms</td>"
            f"<td>{err}</td></tr>")
    rows.append("</table>")
    return "\n".join(rows)


def _compare_buckets_table(label: str, buckets: dict) -> str:
    rows = [f"<table class='sum'><tr><th>{html.escape(label)}</th>"
            "<th>ops</th><th>modeled</th><th>measured</th>"
            "<th>mean err</th><th>max err</th></tr>"]
    for key, b in buckets.items():
        mean = ("-" if b["mean_rel_err"] is None
                else f"{b['mean_rel_err'] * 100:.1f}%")
        mx = ("-" if b["max_rel_err"] is None
              else f"{b['max_rel_err'] * 100:.1f}%")
        rows.append(
            f"<tr><td>{html.escape(str(key))}</td><td>{b['count']}</td>"
            f"<td>{b['modeled_s'] * 1e3:.3f} ms</td>"
            f"<td>{b['measured_s'] * 1e3:.3f} ms</td>"
            f"<td>{mean}</td><td>{mx}</td></tr>")
    rows.append("</table>")
    return "\n".join(rows)


def compare_panel(result) -> str:
    """The modeled-vs-measured panel for one
    :class:`repro.core.trace.compare.CompareResult`: per-collective rows
    plus per-kind and per-size-class aggregates."""
    s = result.stats()
    mean = ("-" if s["mean_rel_err"] is None
            else f"{s['mean_rel_err'] * 100:.1f}%")
    mx = ("-" if s["max_rel_err"] is None
          else f"{s['max_rel_err'] * 100:.1f}%")
    parts = [
        "<div><h3>modeled vs measured</h3>",
        f"<div class='meta'>measured: {html.escape(result.measured_label)}"
        f" &middot; model: {html.escape(result.modeled_label)}"
        f" [{html.escape(result.algorithm)}] &middot; {s['count']} matched"
        f" ({s['unmatched_measured']} measured /"
        f" {s['unmatched_modeled']} modeled unmatched) &middot;"
        f" mean rel err {mean}, max {mx}</div>",
        _compare_rows_table(result),
        _compare_buckets_table("kind", result.by_kind()),
        _compare_buckets_table("size class", result.by_size_class()),
        "</div>",
    ]
    return "\n".join(parts)


def _measured_panel(report) -> str:
    """The compare panel for a measured (trace-imported) report, against
    its own model when one exists.  Empty string for purely modeled
    reports or when no comparison is possible (no topology, nothing
    matched) -- the dashboard never fails over an absent model."""
    if not hasattr(report, "compare") or \
            not any(getattr(op, "measured_s", None) is not None
                    for op in report.compiled_ops):
        return ""
    try:
        return compare_panel(report.compare())
    except ValueError:
        return ""


def _matrices_section(report) -> str:
    """The whole-report artifact set: summary + lint findings +
    modeled-vs-measured panel (trace imports) +
    combined/per-primitive/link heatmaps (the body of the "all phases"
    view)."""
    parts = [
        _summary_table(report.compiled_summary),
        lint_panel(report),
        _measured_panel(report),
        "<div class='grid'>",
        "<div><h3>all primitives</h3>" + matrix_table(report.matrix)
        + "</div>",
    ]
    for kind, mat in sorted(report.per_primitive.items()):
        parts.append(f"<div><h3>{html.escape(kind)}</h3>"
                     + matrix_table(mat) + "</div>")
    parts.append(link_section(report))
    parts.append("</div>")
    return "\n".join(parts)


def _phase_panel(report, phase: str) -> str:
    """One phase's view: its summary table + combined matrix heatmap."""
    view = report.view(phase=phase)
    parts = [_summary_table(view.summary),
             "<div class='grid'>",
             f"<div><h3>phase {html.escape(phase)}: all primitives</h3>"
             + matrix_table(view.matrix) + "</div>"]
    for kind, mat in sorted(view.per_primitive.items()):
        parts.append(f"<div><h3>{html.escape(kind)}</h3>"
                     + matrix_table(mat) + "</div>")
    parts.append("</div>")
    return "\n".join(parts)


def _phase_tabs(report, uid: str) -> str:
    """Pure-CSS tab strip: "all phases" + one tab per session phase."""
    names = report.phase_names()
    panels = [("all phases", _matrices_section(report))]
    panels += [(p, _phase_panel(report, p)) for p in names]
    if len(panels) > 16:        # CSS switch rules cover 16 tabs; stack past it
        return "\n".join(f"<h3>{html.escape(label)}</h3>\n{content}"
                         for label, content in panels)
    parts = ["<div class='tabs'>"]
    for i, (label, _) in enumerate(panels):
        checked = " checked" if i == 0 else ""
        parts.append(f"<input type='radio' name='{uid}' id='{uid}-{i}'"
                     f"{checked}><label for='{uid}-{i}'>"
                     f"{html.escape(label)}</label>")
    for _, content in panels:
        parts.append(f"<div class='panel'>\n{content}\n</div>")
    parts.append("</div>")
    return "\n".join(parts)


def report_section(report, idx: int = 0) -> str:
    """One report: header, primitive summary, combined + per-primitive +
    physical-link maps; multi-phase session reports get a per-phase tab
    strip ("all phases" first, then one tab per phase)."""
    algorithm = getattr(report, "algorithm", "ring")
    total_wire = sum(r.get("wire_bytes", 0.0)
                     for r in report.compiled_summary.values())
    phase_names = (report.phase_names()
                   if hasattr(report, "phase_names") else [])
    phase_note = (f" &middot; phases: "
                  f"{html.escape(' → '.join(phase_names))}"
                  if len(phase_names) >= 2 else "")
    parts = [
        f"<h2>{html.escape(report.name)}</h2>",
        f"<div class='meta'>{report.num_devices} devices &middot; "
        f"algorithm: {html.escape(algorithm)} &middot; wire bytes "
        f"{reporter.human_bytes(total_wire)} &middot; compile "
        f"{report.compile_seconds * 1e3:.0f} ms{phase_note}</div>",
    ]
    if len(phase_names) >= 2:
        parts.append(_phase_tabs(report, uid=f"phases{idx}"))
    else:
        parts.append(_matrices_section(report))
    return "\n".join(parts)


def render_dashboard(reports, title: str = "Communication matrices") -> str:
    if not isinstance(reports, (list, tuple)):
        reports = [reports]
    body = "\n".join(report_section(r, idx=i) for i, r in enumerate(reports))
    return (
        "<!doctype html>\n<html lang='en'>\n<head>\n<meta charset='utf-8'>\n"
        f"<title>{html.escape(title)}</title>\n"
        "<meta name='viewport' content='width=device-width, initial-scale=1'>"
        f"\n<style>{_CSS}</style>\n</head>\n<body>\n"
        f"<h1>{html.escape(title)}</h1>\n"
        "<div class='meta'>(d+1)&sup2; byte matrices, row/col 0 = host "
        "(paper Figs. 2/3); hover a cell for the exact value.</div>\n"
        f"{body}\n</body>\n</html>\n")


def export_html(reports, path: str, title: str = "Communication matrices") -> str:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "w") as f:
        f.write(render_dashboard(reports, title))
    return path


# ---------------------------------------------------------------------------
# scale-curve panel (``sweep --scale-curve``): per-config device sweeps
# ---------------------------------------------------------------------------
def _scale_svg(rows: list[dict]) -> str:
    """Inline SVG: overlapped communication time vs device count, both axes
    log scale (straight lines = power-law scaling)."""
    pts = [(r["devices"], r["overlap_ms"]) for r in rows
           if r["overlap_ms"] > 0]
    if len(pts) < 2:
        return ""
    w, h, pad = 260, 120, 24
    xs = [math.log2(p[0]) for p in pts]
    ys = [math.log10(p[1]) for p in pts]
    x0, x1 = min(xs), max(xs)
    y0, y1 = min(ys), max(ys)
    xspan = (x1 - x0) or 1.0
    yspan = (y1 - y0) or 1.0
    coords = " ".join(
        f"{pad + (x - x0) / xspan * (w - 2 * pad):.1f},"
        f"{h - pad - (y - y0) / yspan * (h - 2 * pad):.1f}"
        for x, y in zip(xs, ys))
    labels = "".join(
        f"<text x='{pad + (x - x0) / xspan * (w - 2 * pad):.1f}' "
        f"y='{h - 6}' font-size='9' fill='currentColor' "
        f"text-anchor='middle'>{d}</text>"
        for (d, _), x in zip(pts, xs))
    return (f"<svg width='{w}' height='{h}' role='img' "
            "style='color: var(--text-2)'>"
            f"<polyline points='{coords}' fill='none' "
            "stroke='#3987e5' stroke-width='2'/>"
            + "".join(f"<circle cx='{c.split(',')[0]}' "
                      f"cy='{c.split(',')[1]}' r='2.5' fill='#3987e5'/>"
                      for c in coords.split())
            + labels
            + f"<text x='{pad}' y='12' font-size='9' "
              "fill='currentColor'>overlap ms vs devices "
              "(log-log)</text></svg>")


def render_scale_curve(points: list[dict],
                       title: str = "Fleet scale curves") -> str:
    """Standalone dashboard for ``sweep --scale-curve`` output: one panel
    per (config, algorithm) with the per-device-count scaling table and a
    log-log time-to-solution sparkline.  ``points`` are
    :meth:`repro.scale.ScalePoint.row` dicts."""
    groups: dict[tuple, list[dict]] = {}
    for p in points:
        groups.setdefault((p["config"], p["algorithm"]), []).append(p)
    sections = []
    for (config, algorithm), rows in sorted(groups.items()):
        rows = sorted(rows, key=lambda r: r["devices"])
        body = ["<table class='sum'><tr><th>devices</th><th>pods</th>"
                "<th>wire bytes</th><th>ici ms</th><th>dcn ms</th>"
                "<th>overlap ms</th><th>bottleneck link</th>"
                "<th>bottleneck ms</th><th>nnz</th></tr>"]
        for r in rows:
            body.append(
                f"<tr><td>{r['devices']:,}</td><td>{r['pods']}</td>"
                f"<td>{reporter.human_bytes(r['wire_bytes'])}</td>"
                f"<td>{r['ici_ms']:.3f}</td><td>{r['dcn_ms']:.3f}</td>"
                f"<td>{r['overlap_ms']:.3f}</td>"
                f"<td>{html.escape(r['bottleneck_link'])}</td>"
                f"<td>{r['bottleneck_ms']:.3f}</td>"
                f"<td>{r['nnz']:,}</td></tr>")
        body.append("</table>")
        sections.append(
            f"<h2>{html.escape(config)} &middot; "
            f"{html.escape(algorithm)}</h2>\n"
            + _scale_svg(rows) + "\n" + "\n".join(body))
    return (
        "<!doctype html>\n<html lang='en'>\n<head>\n<meta charset='utf-8'>\n"
        f"<title>{html.escape(title)}</title>\n"
        "<meta name='viewport' content='width=device-width, initial-scale=1'>"
        f"\n<style>{_CSS}</style>\n</head>\n<body>\n"
        f"<h1>{html.escape(title)}</h1>\n"
        "<div class='meta'>sparse COO matrices per device count; "
        "time-to-solution = tier-overlapped collective ms; bottleneck = "
        "busiest physical link's contention-aware ms.</div>\n"
        + "\n".join(sections) + "\n</body>\n</html>\n")


def export_scale_html(points: list[dict], path: str,
                      title: str = "Fleet scale curves") -> str:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "w") as f:
        f.write(render_scale_curve(points, title))
    return path


# ---------------------------------------------------------------------------
# compare page (``repro compare``): modeled vs measured
# ---------------------------------------------------------------------------
def render_compare(results, title: str = "Modeled vs measured") -> str:
    """Standalone page for one or many
    :class:`repro.core.trace.compare.CompareResult` (one per algorithm
    binding)."""
    if not isinstance(results, (list, tuple)):
        results = [results]
    sections = []
    for res in results:
        sections.append(
            f"<h2>{html.escape(res.measured_label)} vs "
            f"{html.escape(res.modeled_label)} "
            f"[{html.escape(res.algorithm)}]</h2>\n" + compare_panel(res))
    return (
        "<!doctype html>\n<html lang='en'>\n<head>\n<meta charset='utf-8'>\n"
        f"<title>{html.escape(title)}</title>\n"
        "<meta name='viewport' content='width=device-width, initial-scale=1'>"
        f"\n<style>{_CSS}</style>\n</head>\n<body>\n"
        f"<h1>{html.escape(title)}</h1>\n"
        "<div class='meta'>per-collective cost-model seconds vs the wall "
        "time a real device trace measured; rel err = |measured &minus; "
        "modeled| / measured.</div>\n"
        + "\n".join(sections) + "\n</body>\n</html>\n")


def export_compare_html(results, path: str,
                        title: str = "Modeled vs measured") -> str:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "w") as f:
        f.write(render_compare(results, title))
    return path
