"""Perfetto / Chrome trace-event timeline of the collective schedule.

Renders each report's compiled collectives as a timeline loadable in
https://ui.perfetto.dev or ``chrome://tracing``: one *process* per report,
one *thread* (track) per collective primitive, one complete (``ph="X"``)
event per collective op.  Events are laid out serially in session/HLO
program order -- the same no-overlap assumption as
:func:`repro.core.cost_models.total_time` -- with durations from the
algorithm-aware bandwidth model, so the timeline *is* the roofline's
collective term, made visible.

Session reports with named phases additionally get a **phase lane**: a
dedicated track whose ``X`` events span each phase's extent on the same
clock, so the fwd/bwd/optimizer structure reads directly off the timeline
(every op event also carries its ``phase`` in ``args``).

Only the documented subset of the Chrome trace-event format is emitted
(https://docs.google.com/document/d/1CvAClvFfyA5R-PhYUmn5OOQtYMH4h6I0nSsKchNAySU):
``X`` duration events and ``M`` metadata events, each with ``name``, ``ph``,
``ts``/``dur`` in microseconds, ``pid``, ``tid``, ``cat`` and ``args``.
"""
from __future__ import annotations

import json
import os

from .. import cost_models

# floor so zero-cost ops (group size 1, no topology) stay visible in the UI
_MIN_DUR_US = 0.05


def _op_duration_us(op, topo, algorithm: str) -> float:
    if topo is not None:
        sec = cost_models.collective_time(op, topo, algorithm)
    else:
        # no topology: assume a generic 50 GB/s per-rank link
        sec = op.wire_bytes_per_rank(algorithm) / 50e9
    return max(_MIN_DUR_US, sec * 1e6)


def trace_events(report, *, pid: int = 1) -> list[dict]:
    """Trace events for one report (one process, one track per primitive)."""
    algorithm = getattr(report, "algorithm", "ring")
    label = f"{report.name} [{report.num_devices} devices, {algorithm}]"
    events: list[dict] = [{
        "name": "process_name", "ph": "M", "pid": pid, "tid": 0,
        "args": {"name": label},
    }]
    kinds = sorted({op.kind for op in report.compiled_ops})
    tid_of = {kind: i + 1 for i, kind in enumerate(kinds)}
    for kind, tid in tid_of.items():
        events.append({
            "name": "thread_name", "ph": "M", "pid": pid, "tid": tid,
            "args": {"name": kind},
        })
    phase_names = (report.phase_names()
                   if hasattr(report, "phase_names") else [])
    ops = report.compiled_ops
    if phase_names:
        # lay phases out contiguously in session order (stable within phase)
        order = {p: i for i, p in enumerate(phase_names)}
        ops = sorted(ops, key=lambda op: order.get(op.phase, len(order)))
    ts = 0.0
    phase_spans: dict[str, list[float]] = {}
    for op in ops:
        # a weighted op (while-loop body) executes `weight` times; show the
        # aggregate as one span so trip-count-64 loops don't emit 64 events
        dur = _op_duration_us(op, report.topo, algorithm) * max(1.0, op.weight)
        args = {
            "kind": op.kind,
            "hlo_name": op.name,
            "payload_bytes": int(op.payload_bytes),
            "wire_bytes_total": float(op.wire_bytes_total(algorithm)),
            "group_size": op.group_size,
            "num_groups": op.num_groups,
            "weight": op.weight,
        }
        if op.phase:
            args["phase"] = op.phase
            span = phase_spans.setdefault(op.phase, [ts, ts])
            span[1] = ts + dur
        events.append({
            "name": op.op_name or op.kind,
            "cat": "collective",
            "ph": "X",
            "ts": round(ts, 3),
            "dur": round(dur, 3),
            "pid": pid,
            "tid": tid_of[op.kind],
            "args": args,
        })
        ts += dur
    if len(phase_names) >= 2:
        # the phase lane: one span per phase on a dedicated track (phases
        # with no collectives occupy no wall-clock on this model, so they
        # have no span to draw)
        lane_tid = len(kinds) + 1
        events.append({
            "name": "thread_name", "ph": "M", "pid": pid, "tid": lane_tid,
            "args": {"name": "phases"},
        })
        for name in phase_names:
            span = phase_spans.get(name)
            if span is None:
                continue
            events.append({
                "name": name,
                "cat": "phase",
                "ph": "X",
                "ts": round(span[0], 3),
                "dur": round(max(_MIN_DUR_US, span[1] - span[0]), 3),
                "pid": pid,
                "tid": lane_tid,
                "args": {"phase": name},
            })
    return events


def chrome_trace(reports) -> dict:
    """Combined trace document for one or many reports (one process each)."""
    if not isinstance(reports, (list, tuple)):
        reports = [reports]
    events: list[dict] = []
    for i, rep in enumerate(reports):
        events.extend(trace_events(rep, pid=i + 1))
    return {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "otherData": {"generator": "repro.core.export.perfetto",
                      "schema": "chrome-trace-event/json"},
    }


def export_perfetto(reports, path: str) -> str:
    """Write the Chrome-trace JSON for one or many reports."""
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "w") as f:
        json.dump(chrome_trace(reports), f, indent=1)
    return path
