"""Perfetto / Chrome trace-event timeline of the collective schedule.

Renders each report's compiled collectives as a timeline loadable in
https://ui.perfetto.dev or ``chrome://tracing``: one *process* per report,
one *thread* (track) per collective primitive, one complete (``ph="X"``)
event per collective op.  Durations come straight from the op's
decomposition schedule (:func:`repro.core.decompose.decompose`) -- the same
phase IR the cost models bill -- so the timeline *is* the roofline's
collective term, made visible.

**Overlap-aware per-tier lanes.**  Reports with a topology additionally get
one **ICI lane** and one **DCN lane**: every schedule phase is drawn as a
span on its tier's lane, laid out with a software-pipelined clock -- a
phase starts when both its predecessor phase (within its op *stream*;
disjoint replica groups are concurrent streams and overlap) and the op's
tier base are free.  Ops therefore overlap across tiers exactly the way the
link-overlap roofline bound (``max(ici_s, dcn_s)``) assumes: op ``k+1``'s
intra-pod ICI phases run while op ``k``'s DCN shard exchange is still in
flight, and the timeline's end approaches the overlapped bound instead of
the serialized sum.

Session reports with named phases additionally get a **phase lane**: a
dedicated track whose ``X`` events span each phase's extent on the same
clock, so the fwd/bwd/optimizer structure reads directly off the timeline
(every op event also carries its ``phase`` in ``args``).

Only the documented subset of the Chrome trace-event format is emitted
(https://docs.google.com/document/d/1CvAClvFfyA5R-PhYUmn5OOQtYMH4h6I0nSsKchNAySU):
``X`` duration events and ``M`` metadata events, each with ``name``, ``ph``,
``ts``/``dur`` in microseconds, ``pid``, ``tid``, ``cat`` and ``args``.

**Lossless re-import.**  Every ``collective`` event embeds the op's full
serialized record (``args.repro_op``, the schema-v9 op dict) and each
process carries one ``repro_report`` metadata event (devices, algorithm,
topology, phases, host transfers), so the Perfetto frontend of
:mod:`repro.core.trace` can rebuild the originating report exactly --
importing our own export reproduces the comm matrix bitwise.
"""
from __future__ import annotations

import json
import os

from ..decompose import cached_decompose as _decompose
from ..sparse import is_sparse
from . import serialize

# floor so zero-cost ops (group size 1, no topology) stay visible in the UI
_MIN_DUR_US = 0.05

# metadata-event name carrying the report-level round-trip record
REPORT_META_EVENT = "repro_report"


def _op_args(op, algorithm: str) -> dict:
    args = {
        "kind": op.kind,
        "hlo_name": op.name,
        "payload_bytes": int(op.payload_bytes),
        "wire_bytes_total": float(op.wire_bytes_total(algorithm)),
        "group_size": op.group_size,
        "num_groups": op.num_groups,
        "weight": op.weight,
        # the full serialized op -- replica groups, shapes, pairs, byte
        # vectors -- so a re-import loses nothing the matrix needs
        "repro_op": serialize.op_to_dict(op),
    }
    if op.phase:
        args["phase"] = op.phase
    if op.skew() > 1.0:
        args["skew"] = round(op.skew(), 4)
    if op.measured_s is not None:
        args["measured_s"] = float(op.measured_s)
    return args


def _report_meta(report) -> dict:
    """Report-level round-trip record for the ``repro_report`` metadata
    event: everything the comm matrix needs beyond the op list (device
    count, algorithm binding, topology, phase order, host transfers --
    the matrix's row/col 0)."""
    meta = {
        "name": report.name,
        "num_devices": report.num_devices,
        "algorithm": getattr(report, "algorithm", "ring"),
        "topo": serialize.topo_to_dict(getattr(report, "topo", None)),
        "sparse": bool(is_sparse(getattr(report, "matrix", None))),
        "phases": [serialize.phase_to_dict(p)
                   for p in getattr(report, "phases", []) or []],
        "host_transfers": [serialize.transfer_to_dict(t)
                           for t in getattr(report, "host_transfers", [])],
    }
    return meta


def _memoized_schedules(report, algorithm: str) -> tuple[dict, dict]:
    """``({id(op): CollectiveSchedule}, {id(op): phase seconds})`` from
    the report view's memoized :class:`~repro.core.decompose.
    ScheduleBatch` when the report offers one (a ``CommReport``), so the
    exporter shares the IR other artifacts already computed -- including
    the batch's columnar per-phase seconds, sliced per op -- instead of
    re-running ``decompose`` and per-phase timing per op.  Empty dicts
    for plain objects."""
    view = getattr(report, "view", None)
    if view is None:
        return {}, {}
    try:
        v = view(algorithm)
        batch = v.schedule_batch()
        sched_of = {id(op): sched
                    for op, sched in zip(batch.ops, batch.schedules)}
        secs_of = {}
        if batch.topo is not None:
            sec = batch.phase_seconds()
            secs_of = {id(op): sec[batch.phase_slice(i)]
                       for i, op in enumerate(batch.ops)}
        return sched_of, secs_of
    except Exception:
        return {}, {}


def _ordered_ops(report, phase_names):
    ops = report.compiled_ops
    if phase_names:
        # lay phases out contiguously in session order (stable within phase)
        order = {p: i for i, p in enumerate(phase_names)}
        ops = sorted(ops, key=lambda op: order.get(op.phase, len(order)))
    return ops


def trace_events(report, *, pid: int = 1) -> list[dict]:
    """Trace events for one report (one process, one track per primitive,
    plus the per-tier lanes when the report carries a topology)."""
    algorithm = getattr(report, "algorithm", "ring")
    topo = getattr(report, "topo", None)
    label = f"{report.name} [{report.num_devices} devices, {algorithm}]"
    events: list[dict] = [{
        "name": "process_name", "ph": "M", "pid": pid, "tid": 0,
        "args": {"name": label},
    }, {
        "name": REPORT_META_EVENT, "ph": "M", "pid": pid, "tid": 0,
        "args": _report_meta(report),
    }]
    kinds = sorted({op.kind for op in report.compiled_ops})
    tid_of = {kind: i + 1 for i, kind in enumerate(kinds)}
    for kind, tid in tid_of.items():
        events.append({
            "name": "thread_name", "ph": "M", "pid": pid, "tid": tid,
            "args": {"name": kind},
        })
    phase_names = (report.phase_names()
                   if hasattr(report, "phase_names") else [])
    ops = _ordered_ops(report, phase_names)
    next_tid = len(kinds) + 1
    tier_tid: dict[str, int] = {}
    if topo is not None and ops:
        for tier in ("ici", "dcn"):
            tier_tid[tier] = next_tid
            events.append({
                "name": "thread_name", "ph": "M", "pid": pid,
                "tid": next_tid, "args": {"name": f"{tier} lane"}})
            next_tid += 1

    phase_spans: dict[str, list[float]] = {}

    def note_span(op, start: float, end: float):
        if op.phase:
            span = phase_spans.setdefault(op.phase, [start, end])
            span[0] = min(span[0], start)
            span[1] = max(span[1], end)

    if topo is None:
        # no topology: the legacy serial layout (generic 50 GB/s link);
        # imported ops carry measured wall time -- already execution-total
        # -- so their spans show the trace's truth, not the generic link
        ts = 0.0
        for op in ops:
            if op.measured_s is not None:
                dur = max(_MIN_DUR_US, op.measured_s * 1e6)
            else:
                sec = op.wire_bytes_per_rank(algorithm) / 50e9
                dur = max(_MIN_DUR_US, sec * 1e6) * max(1.0, op.weight)
            events.append({
                "name": op.op_name or op.kind, "cat": "collective",
                "ph": "X", "ts": round(ts, 3), "dur": round(dur, 3),
                "pid": pid, "tid": tid_of[op.kind],
                "args": _op_args(op, algorithm)})
            note_span(op, ts, ts + dur)
            ts += dur
    else:
        # software-pipelined layout: a phase starts when its predecessor
        # (within its op *stream*) and its tier's lane are both free --
        # ICI and DCN overlap across ops exactly as the roofline's overlap
        # bound assumes, and concurrent streams (disjoint replica groups)
        # overlap within the op like ``time_split``'s max-over-streams.
        # A weighted op (while-loop body) executes ``weight`` times; its
        # phases show the aggregate as one span each.
        sched_of, secs_of = _memoized_schedules(report, algorithm)
        cursor = {"ici": 0.0, "dcn": 0.0}
        issue = 0.0   # monotone issue clock: ops are issued in program
        for op in ops:  # order, so op k+1 never *starts* before op k does
            sched = sched_of.get(id(op)) \
                or _decompose(op, algorithm, topo, warn=False)
            secs = secs_of.get(id(op))
            w = max(1.0, op.weight)
            # a schedule-less op (size-1 groups) moves nothing: marker at
            # the issue clock, gating nothing (no pipeline barrier)
            t_prev = issue if not sched.phases else 0.0
            # streams start from the op's base (not behind each other's
            # phases); the base honours both lane availability and issue
            # order
            base = {t: max(c, issue) for t, c in cursor.items()}
            op_start = None
            op_end = 0.0
            stream_end: dict[int, float] = {}
            tier_events: list[dict] = []
            for j, ph in enumerate(sched.phases):
                sec = float(secs[j]) if secs is not None \
                    else ph.seconds(topo)
                dur = max(_MIN_DUR_US, sec * 1e6 * w)
                start = max(stream_end.get(ph.stream, 0.0), base[ph.tier])
                end = start + dur
                cursor[ph.tier] = max(cursor[ph.tier], end)
                stream_end[ph.stream] = end
                op_start = start if op_start is None else min(op_start,
                                                              start)
                op_end = max(op_end, end)
                tier_events.append({
                    "name": f"{ph.kind}"
                            + (f"@{ph.axis}" if ph.axis else ""),
                    "cat": "tier", "ph": "X",
                    "ts": round(start, 3), "dur": round(dur, 3),
                    "pid": pid, "tid": tier_tid[ph.tier],
                    "args": {
                        "tier": ph.tier, "structure": ph.structure,
                        "axis": ph.axis, "hlo_name": op.name,
                        "bytes_per_rank": float(ph.max_bytes_per_rank()),
                        "latency_hops": float(ph.latency_hops),
                    }})
            # concurrent streams restart from the op's base, so sort the
            # op's lane spans by start time to keep each track ordered
            events.extend(sorted(tier_events, key=lambda e: e["ts"]))
            if op_start is None:            # scheduleless op (size-1 group)
                op_start, op_end = t_prev, t_prev + _MIN_DUR_US
            issue = op_start
            events.append({
                "name": op.op_name or op.kind, "cat": "collective",
                "ph": "X", "ts": round(op_start, 3),
                "dur": round(max(_MIN_DUR_US, op_end - op_start), 3),
                "pid": pid, "tid": tid_of[op.kind],
                "args": _op_args(op, algorithm)})
            note_span(op, op_start, op_end)

    if len(phase_names) >= 2:
        # the phase lane: one span per phase on a dedicated track (phases
        # with no collectives occupy no wall-clock on this model, so they
        # have no span to draw)
        lane_tid = next_tid
        events.append({
            "name": "thread_name", "ph": "M", "pid": pid, "tid": lane_tid,
            "args": {"name": "phases"},
        })
        for name in phase_names:
            span = phase_spans.get(name)
            if span is None:
                continue
            events.append({
                "name": name,
                "cat": "phase",
                "ph": "X",
                "ts": round(span[0], 3),
                "dur": round(max(_MIN_DUR_US, span[1] - span[0]), 3),
                "pid": pid,
                "tid": lane_tid,
                "args": {"phase": name},
            })
    return events


def chrome_trace(reports) -> dict:
    """Combined trace document for one or many reports (one process each)."""
    if not isinstance(reports, (list, tuple)):
        reports = [reports]
    events: list[dict] = []
    for i, rep in enumerate(reports):
        events.extend(trace_events(rep, pid=i + 1))
    return {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "otherData": {"generator": "repro.core.export.perfetto",
                      "schema": "chrome-trace-event/json"},
    }


def export_perfetto(reports, path: str) -> str:
    """Write the Chrome-trace JSON for one or many reports."""
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "w") as f:
        json.dump(chrome_trace(reports), f, indent=1)
    return path
