"""Report export subsystem: one serializer, many renderings.

``repro.core.reporter`` renders for terminals; this package renders for
machines and browsers.  Formats:

* ``json``     -- lossless schema-v1 report (``CommReport.save``/``load``);
* ``csv``      -- long-form per-primitive comparison rows (+ matrix CSV);
* ``html``     -- self-contained heatmap dashboard of the ``(d+1)^2``
                  communication matrices (paper Figs. 2/3);
* ``perfetto`` -- Chrome trace-event timeline of the collective schedule
                  (open in https://ui.perfetto.dev).

``export_report`` writes one report in one format; ``export_comparison``
writes a whole sweep's artifact set.
"""
from __future__ import annotations

import os

from . import serialize
from .csv_exporter import export_matrix_csv, export_summary_csv, summary_rows
from .html_exporter import export_html, render_dashboard
from .json_exporter import (export_comparison_json, export_json, load_json,
                            load_json_reports)
from .perfetto import chrome_trace, export_perfetto, trace_events

FORMATS = ("json", "csv", "html", "perfetto")

SUFFIXES = {"json": ".json", "csv": ".csv", "html": ".html",
            "perfetto": ".trace.json"}


def _check_formats(formats):
    unknown = [f for f in formats if f not in FORMATS]
    if unknown:
        raise ValueError(f"unknown format(s) {unknown}; known: {FORMATS}")


def export_report(report, fmt: str, path: str) -> str:
    """Write one report in ``fmt`` (one of :data:`FORMATS`) to ``path``."""
    _check_formats([fmt])
    if fmt == "json":
        return export_json(report, path)
    if fmt == "csv":
        return export_summary_csv(report, path)
    if fmt == "html":
        return export_html(report, path, title=report.name)
    return export_perfetto(report, path)


def export_comparison(reports: list, out_dir: str, formats=FORMATS,
                      stem: str = "sweep") -> dict[str, str]:
    """Write the comparative artifact set for many reports.

    Returns ``{format: path}``.  ``json``/``csv`` hold one row/document per
    report; ``html`` is a single dashboard; ``perfetto`` a single timeline
    with one process per report.
    """
    _check_formats(formats)
    os.makedirs(out_dir, exist_ok=True)
    paths: dict[str, str] = {}
    for fmt in formats:
        path = os.path.join(out_dir, stem + SUFFIXES[fmt])
        if fmt == "json":
            export_comparison_json(reports, path)
        elif fmt == "csv":
            export_summary_csv(reports, path)
        elif fmt == "html":
            export_html(reports, path, title=f"{stem}: communication matrices")
        else:
            export_perfetto(reports, path)
        paths[fmt] = path
    return paths


__all__ = [
    "FORMATS", "SUFFIXES", "export_report", "export_comparison",
    "export_json", "export_comparison_json", "load_json",
    "load_json_reports",
    "export_matrix_csv", "export_summary_csv", "summary_rows",
    "export_html", "render_dashboard",
    "export_perfetto", "chrome_trace", "trace_events",
    "serialize",
]
