"""CSV export: communication matrices + per-primitive summary rows.

Four products:

* ``export_matrix_csv`` -- one ``(d+1) x (d+1)`` matrix as CSV.  Dense
  matrices keep the square layout (paper Fig. 2/3 data, host row/column
  first, identical to ``reporter.matrix_to_csv``); sparse COO matrices
  write long-form ``src,dst,bytes`` rows instead -- the square form is
  exactly the O(d^2) materialization the sparse path exists to avoid;
* ``export_summary_csv`` -- long-form rows
  ``config,mesh,algorithm,primitive,calls,payload_bytes,wire_bytes`` across
  one or many reports -- the sweep's machine-readable comparison table;
* ``export_compare_csv`` -- one modeled-vs-measured row per matched
  collective of a trace-import comparison (``repro compare``);
* ``export_scale_csv`` -- one row per (config, algorithm, device count)
  from a ``sweep --scale-curve`` run.
"""
from __future__ import annotations

import os

from .. import reporter
from ..sparse import is_sparse


def export_matrix_csv(report, path: str) -> str:
    mat = report.matrix
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    if is_sparse(mat):
        body = "\n".join(["src,dst,bytes"] + mat.to_csv_rows())
    else:
        body = reporter.matrix_to_csv(mat)
    with open(path, "w") as f:
        f.write(body + "\n")
    return path


def summary_rows(report) -> list[dict]:
    """Long-form per-primitive rows for one report."""
    meta = getattr(report, "meta", {}) or {}
    mesh = meta.get("mesh", f"{report.num_devices}dev")
    config = meta.get("config", report.name)
    rows = []
    for kind in sorted(report.compiled_summary):
        row = report.compiled_summary[kind]
        rows.append({
            "config": config,
            "mesh": mesh,
            "algorithm": getattr(report, "algorithm", "ring"),
            "num_devices": report.num_devices,
            "primitive": kind,
            "calls": row.get("calls", 0),
            "payload_bytes": row.get("payload_bytes", 0),
            "wire_bytes": round(float(row.get("wire_bytes", 0.0)), 3),
        })
    return rows


_COLUMNS = ("config", "mesh", "algorithm", "num_devices", "primitive",
            "calls", "payload_bytes", "wire_bytes")


def export_summary_csv(reports, path: str) -> str:
    """Write the long-form comparison CSV for one or many reports."""
    if not isinstance(reports, (list, tuple)):
        reports = [reports]
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    lines = [",".join(_COLUMNS)]
    for rep in reports:
        for row in summary_rows(rep):
            lines.append(",".join(str(row[c]) for c in _COLUMNS))
    with open(path, "w") as f:
        f.write("\n".join(lines) + "\n")
    return path


# stable schema for ``repro compare`` output; tests pin the header
COMPARE_COLUMNS = ("op", "kind", "phase", "payload_bytes", "size_class",
                   "modeled_s", "measured_s", "rel_err")


def export_compare_csv(result, path: str) -> str:
    """Write one modeled-vs-measured row per matched collective (a
    :class:`repro.core.trace.compare.CompareResult`), in match order."""
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    lines = [",".join(COMPARE_COLUMNS)]
    for r in result.rows:
        d = r.to_dict()
        d["op"] = d.pop("name")
        lines.append(",".join(
            "" if d[c] is None else str(d[c]) for c in COMPARE_COLUMNS))
    with open(path, "w") as f:
        f.write("\n".join(lines) + "\n")
    return path


# stable schema for ``sweep --scale-curve`` output; tests pin the header
SCALE_COLUMNS = ("config", "algorithm", "devices", "pods", "ops",
                 "wire_bytes", "ici_ms", "dcn_ms", "overlap_ms",
                 "bottleneck_link", "bottleneck_ms", "nnz", "build_ms")


def export_scale_csv(points, path: str) -> str:
    """Write scale-curve rows (``repro.scale.ScalePoint.row`` dicts), one
    per (config, algorithm, device count), sorted for diff-stable goldens."""
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    lines = [",".join(SCALE_COLUMNS)]
    for p in sorted(points, key=lambda r: (r["config"], r["algorithm"],
                                           r["devices"])):
        lines.append(",".join(str(p[c]) for c in SCALE_COLUMNS))
    with open(path, "w") as f:
        f.write("\n".join(lines) + "\n")
    return path
