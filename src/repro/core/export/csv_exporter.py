"""CSV export: communication matrices + per-primitive summary rows.

Two products:

* ``export_matrix_csv`` -- one ``(d+1) x (d+1)`` matrix as CSV (paper Fig. 2/3
  data), host row/column first, identical to ``reporter.matrix_to_csv``;
* ``export_summary_csv`` -- long-form rows
  ``config,mesh,algorithm,primitive,calls,payload_bytes,wire_bytes`` across
  one or many reports -- the sweep's machine-readable comparison table.
"""
from __future__ import annotations

import os

from .. import reporter


def export_matrix_csv(report, path: str) -> str:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "w") as f:
        f.write(reporter.matrix_to_csv(report.matrix) + "\n")
    return path


def summary_rows(report) -> list[dict]:
    """Long-form per-primitive rows for one report."""
    meta = getattr(report, "meta", {}) or {}
    mesh = meta.get("mesh", f"{report.num_devices}dev")
    config = meta.get("config", report.name)
    rows = []
    for kind in sorted(report.compiled_summary):
        row = report.compiled_summary[kind]
        rows.append({
            "config": config,
            "mesh": mesh,
            "algorithm": getattr(report, "algorithm", "ring"),
            "num_devices": report.num_devices,
            "primitive": kind,
            "calls": row.get("calls", 0),
            "payload_bytes": row.get("payload_bytes", 0),
            "wire_bytes": round(float(row.get("wire_bytes", 0.0)), 3),
        })
    return rows


_COLUMNS = ("config", "mesh", "algorithm", "num_devices", "primitive",
            "calls", "payload_bytes", "wire_bytes")


def export_summary_csv(reports, path: str) -> str:
    """Write the long-form comparison CSV for one or many reports."""
    if not isinstance(reports, (list, tuple)):
        reports = [reports]
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    lines = [",".join(_COLUMNS)]
    for rep in reports:
        for row in summary_rows(rep):
            lines.append(",".join(str(row[c]) for c in _COLUMNS))
    with open(path, "w") as f:
        f.write("\n".join(lines) + "\n")
    return path
