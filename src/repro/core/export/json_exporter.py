"""JSON export: the schema-``v7`` report dict, verbatim, on disk."""
from __future__ import annotations

import json
import os

from . import serialize


def export_json(report, path: str, *, include_hlo: bool = False,
                include_schedules: bool = False,
                include_lint: bool = False) -> str:
    """Write one report as schema-v7 JSON.  Returns ``path``.

    ``include_hlo=True`` persists the compiled HLO text (gzip+base64) so
    ``roofline_of`` works on the loaded report.  ``include_schedules=True``
    adds the optional per-op decomposition-schedule summaries.
    ``include_lint=True`` adds (and loaders restore) the default binding's
    lint findings.
    """
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "w") as f:
        json.dump(serialize.report_to_dict(
            report, include_hlo=include_hlo,
            include_schedules=include_schedules,
            include_lint=include_lint), f, indent=1)
    return path


def export_comparison_json(reports: list, path: str) -> str:
    """Write a list of reports as one JSON document (sweep output)."""
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "w") as f:
        json.dump({"schema": serialize.SCHEMA + ".sweep",
                   "reports": [serialize.report_to_dict(r) for r in reports]},
                  f, indent=1)
    return path


def load_json_reports(path: str) -> list:
    """Read any JSON this package writes: a single report, a report-cache
    entry, or a sweep comparison document.  Always returns a list."""
    with open(path) as f:
        d = json.load(f)
    if isinstance(d.get("reports"), list):
        # a sweep comparison document (export_comparison_json)
        return [serialize.report_from_dict(r) for r in d["reports"]]
    if "name" not in d and isinstance(d.get("report"), dict):
        # a report-cache entry: the report dict is wrapped with its meta
        report = serialize.report_from_dict(d["report"])
        report.meta = dict(d.get("meta", {}))
        return [report]
    return [serialize.report_from_dict(d)]


def load_json(path: str):
    """Read exactly one report (see :func:`load_json_reports`)."""
    reports = load_json_reports(path)
    if len(reports) != 1:
        raise ValueError(
            f"{path} holds {len(reports)} reports (a sweep document); "
            "use load_json_reports")
    return reports[0]
