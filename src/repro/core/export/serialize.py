"""Lossless CommReport <-> plain-dict serialization (schema ``v9``).

This is the substrate for everything under :mod:`repro.core.export`: the JSON
exporter writes the dict verbatim, the on-disk report cache
(:mod:`repro.core.report_cache`) round-trips reports through it, and
``CommReport.save``/``CommReport.load`` are thin wrappers around it.

The schema is a strict superset of the legacy ``reporter.dump_report`` layout,
so files written by older code remain readable by external consumers:
``name``, ``num_devices``, ``summary`` (compiled), ``traced_summary``, ``ops``
and ``matrix`` keep their old spelling and meaning; the v1 additions
(``per_primitive``, ``traced``, ``topo``, ``algorithm``, timings, ...) ride
alongside under new keys.

Schema **v2** added the physical-link view for reports that carry a topology:
``link_matrix`` (the ``(d+1)^2`` per-link byte matrix, row/col 0 = DCN tier)
and ``links`` (one row per physical link: kind/src/dst/axis/bytes/bandwidth/
seconds).  Schema **v3** added the link-overlap view on top: ``link_tiers``
(per-tier bytes + busy seconds from ``LinkUtilization.tier_summary``) and
``overlap`` (per-tier serialized collective seconds, their overlapped max
and serialized sum).  All link/overlap sections are *derived* from ``ops``
+ ``topo``, so older files load unchanged (:func:`report_from_dict`
accepts any accepted schema; loaded reports recompute the views on demand
via ``CommReport.link_utilization`` / ``collective_seconds_split``).

Schema **v4** is the session snapshot: ``phases`` (one record per named
capture phase -- name, capture count, per-phase trace/compile seconds) and
a ``phase`` tag on every op / traced event / host transfer, so per-phase
views (``CommReport.view(phase=...)``) rebuild from any loaded file.  It
also adds the *optional* ``hlo_gz`` key (a list of gzip + base64 compiled
HLO modules, one per capture, written only by
``save(..., include_hlo=True)``), which lets
``roofline_of`` run on loaded/cached reports without a live compilation.

Schema **v5** adds the *optional* ``schedules`` section: one decomposition-
schedule summary per compiled op (aligned with ``ops``), each a list of
phase records -- kind / tier / structure / axis / group shape / per-rank
bytes / latency hops -- straight from
:func:`repro.core.decompose.decompose`.  Written only on request
(``save(..., include_schedules=True)``): schedules are pure derived data,
so loaders recompute them from ``ops`` + ``topo`` + ``algorithm`` on
demand (``CommReport.schedule_summaries()``), and every older file loads
unchanged: missing phase tags default to ``""`` (a single anonymous
phase), missing ``hlo_gz`` just means no offline roofline, missing
``schedules`` just means re-derive.

Schema **v6** adds the sparse (COO) matrix encoding for fleet-scale
reports: ``matrix`` / ``per_primitive`` values may now be either the
legacy dense nested list or a ``{"format": "coo", "side", "src", "dst",
"val"}`` dict (:func:`matrix_to_jsonable`), whichever the in-memory
report held -- a sparse :class:`~repro.core.sparse.SparseCommMatrix`
round-trips as sparse, a dense ndarray as dense, and loading restores
the same representation (:func:`matrix_from_jsonable`).  Sparse reports
also drop the derived dense ``link_matrix`` from the link section (it is
O(d^2) too) and keep only the nonzero per-link ``links`` rows; v1...v5
files, always dense lists, load unchanged.

Schema **v7** adds the static-lint surface: two new per-op keys
(``operand_names`` and ``use_global_device_ids``, both defaulted on load
so v1...v6 files read back unchanged) and the *optional* ``lint`` section
-- the default binding's :class:`~repro.core.lint.LintFinding` records,
written by ``save(..., include_lint=True)``.  Unlike the purely derived
sections, persisted findings ARE restored on load
(``report._lint_findings``): the HLO def-use rules need the module text,
so a file saved without ``hlo_gz`` could not reproduce them from the op
list alone.

Schema **v8** adds irregular collectives: the *optional* per-op
``bytes_per_rank_vec`` key (a list of floats, one entry per group
position, for allgatherv-style / skewed-MoE ops whose ranks contribute
unequal bytes).  Ops without the key load with ``bytes_per_rank_vec=None``
-- the scalar path -- so every v1...v7 file reads back unchanged, and a
v8 file whose ops are all regular is byte-identical to v7 apart from the
schema string.

Schema **v9** closes the model-vs-measured loop: the *optional* per-op
``measured_s`` key (total measured wall seconds for the op, set by the
trace importers in :mod:`repro.core.trace`) and the *optional* top-level
``trace_meta`` section (import provenance: source frontend, trace path,
record counts, clock-alignment rule, device mapping), both restored on
load.  Purely modeled reports carry neither key, so an all-modeled v9
file is byte-identical to v8 apart from the schema string, and every
v1...v8 file loads with ``measured_s=None`` / ``trace_meta=None``.
"""
from __future__ import annotations

import base64
import dataclasses
import gzip
from typing import Any, Optional

import numpy as np

from ..events import (CollectiveOp, HostTransfer, PhaseRecord, Shape,
                      TraceEvent)
from ..sparse import SparseCommMatrix, is_sparse
from ..topology import HardwareSpec, MeshTopology

SCHEMA = "repro.comm_report.v9"
SCHEMA_V8 = "repro.comm_report.v8"
SCHEMA_V7 = "repro.comm_report.v7"
SCHEMA_V6 = "repro.comm_report.v6"
SCHEMA_V5 = "repro.comm_report.v5"
SCHEMA_V4 = "repro.comm_report.v4"
SCHEMA_V3 = "repro.comm_report.v3"
SCHEMA_V2 = "repro.comm_report.v2"
SCHEMA_V1 = "repro.comm_report.v1"
ACCEPTED_SCHEMAS = (SCHEMA, SCHEMA_V8, SCHEMA_V7, SCHEMA_V6, SCHEMA_V5,
                    SCHEMA_V4, SCHEMA_V3, SCHEMA_V2, SCHEMA_V1)


# ---------------------------------------------------------------------------
# leaf types
# ---------------------------------------------------------------------------
def shape_to_dict(s: Shape) -> dict:
    return {"dtype": s.dtype, "dims": list(s.dims)}


def shape_from_dict(d: dict) -> Shape:
    return Shape(dtype=d["dtype"], dims=tuple(d["dims"]))


def op_to_dict(op: CollectiveOp) -> dict:
    d = {
        "kind": op.kind,
        "name": op.name,
        "result_shapes": [shape_to_dict(s) for s in op.result_shapes],
        # legacy spelling kept for external consumers of dump_report files
        "shapes": [repr(s) for s in op.result_shapes],
        "replica_groups": [list(g) for g in op.replica_groups],
        "channel_id": op.channel_id,
        "dimensions": list(op.dimensions),
        "source_target_pairs": [list(p) for p in op.source_target_pairs],
        "op_name": op.op_name,
        "weight": op.weight,
        "phase": op.phase,
        "operand_names": list(op.operand_names),
        "use_global_device_ids": op.use_global_device_ids,
        "payload_bytes": op.payload_bytes,
        "group_size": op.group_size,
        "num_groups": op.num_groups,
    }
    # schema v8: irregular ops only -- regular ops keep the v7 spelling
    if op.bytes_per_rank_vec is not None:
        d["bytes_per_rank_vec"] = [float(x) for x in op.bytes_per_rank_vec]
    # schema v9: measured (imported-trace) ops only -- modeled ops keep
    # the v8 spelling, so all-modeled files stay byte-identical
    if op.measured_s is not None:
        d["measured_s"] = float(op.measured_s)
    return d


def op_from_dict(d: dict) -> CollectiveOp:
    return CollectiveOp(
        kind=d["kind"],
        name=d["name"],
        result_shapes=[shape_from_dict(s) for s in d["result_shapes"]],
        replica_groups=[list(g) for g in d["replica_groups"]],
        channel_id=d.get("channel_id"),
        dimensions=tuple(d.get("dimensions", ())),
        source_target_pairs=[tuple(p) for p in d.get("source_target_pairs", [])],
        op_name=d.get("op_name", ""),
        weight=float(d.get("weight", 1.0)),
        phase=d.get("phase", ""),
        operand_names=list(d.get("operand_names", [])),
        use_global_device_ids=bool(d.get("use_global_device_ids", False)),
        bytes_per_rank_vec=(list(d["bytes_per_rank_vec"])
                            if d.get("bytes_per_rank_vec") is not None
                            else None),
        measured_s=(float(d["measured_s"])
                    if d.get("measured_s") is not None else None),
    )


def event_to_dict(e: TraceEvent) -> dict:
    return {
        "primitive": e.primitive,
        "axis_name": e.axis_name,
        "arg_shapes": [shape_to_dict(s) for s in e.arg_shapes],
        "axis_size": e.axis_size,
        "call_site": e.call_site,
        "phase": e.phase,
    }


def event_from_dict(d: dict) -> TraceEvent:
    return TraceEvent(
        primitive=d["primitive"],
        axis_name=d["axis_name"],
        arg_shapes=[shape_from_dict(s) for s in d["arg_shapes"]],
        axis_size=d.get("axis_size"),
        call_site=d.get("call_site", ""),
        phase=d.get("phase", ""),
    )


def transfer_to_dict(t: HostTransfer) -> dict:
    return {"direction": t.direction, "device": t.device,
            "nbytes": t.nbytes, "label": t.label, "phase": t.phase}


def transfer_from_dict(d: dict) -> HostTransfer:
    return HostTransfer(direction=d["direction"], device=d["device"],
                        nbytes=d["nbytes"], label=d.get("label", ""),
                        phase=d.get("phase", ""))


def phase_to_dict(p: PhaseRecord) -> dict:
    return {"name": p.name, "num_captures": p.num_captures,
            "trace_seconds": p.trace_seconds,
            "compile_seconds": p.compile_seconds}


def phase_from_dict(d: dict) -> PhaseRecord:
    return PhaseRecord(name=d["name"],
                       num_captures=int(d.get("num_captures", 0)),
                       trace_seconds=float(d.get("trace_seconds", 0.0)),
                       compile_seconds=float(d.get("compile_seconds", 0.0)))


def topo_to_dict(t: Optional[MeshTopology]) -> Optional[dict]:
    if t is None:
        return None
    return {
        "axis_names": list(t.axis_names),
        "axis_sizes": list(t.axis_sizes),
        "dcn_axes": list(t.dcn_axes),
        "hw": dataclasses.asdict(t.hw),
    }


def topo_from_dict(d: Optional[dict]) -> Optional[MeshTopology]:
    if d is None:
        return None
    return MeshTopology(
        axis_names=tuple(d["axis_names"]),
        axis_sizes=tuple(d["axis_sizes"]),
        hw=HardwareSpec(**d["hw"]),
        dcn_axes=tuple(d["dcn_axes"]),
    )


# ---------------------------------------------------------------------------
# matrices: dense nested-list vs sparse COO dict (schema v6)
# ---------------------------------------------------------------------------
def matrix_to_jsonable(mat):
    """Dense ndarray -> nested list (the v1...v5 spelling); sparse
    :class:`SparseCommMatrix` -> ``{"format": "coo", ...}`` dict whose
    size is O(nnz), never O(d^2)."""
    if is_sparse(mat):
        return {
            "format": "coo",
            "side": mat.side,
            "src": mat.src.tolist(),
            "dst": mat.dst.tolist(),
            "val": mat.val.tolist(),
        }
    return np.asarray(mat).tolist()


def matrix_from_jsonable(j):
    """The inverse: the COO dict form restores a ``SparseCommMatrix``
    (already coalesced on write), anything else the dense float64 array."""
    if isinstance(j, dict):
        fmt = j.get("format")
        if fmt != "coo":
            raise ValueError(f"unknown matrix format {fmt!r}; expected 'coo'")
        return SparseCommMatrix(
            int(j["side"]) - 1,
            np.asarray(j["src"], dtype=np.int64),
            np.asarray(j["dst"], dtype=np.int64),
            np.asarray(j["val"], dtype=np.float64),
            coalesced=True,
        )
    return np.asarray(j, dtype=np.float64)


# ---------------------------------------------------------------------------
# whole-report round-trip
# ---------------------------------------------------------------------------
def _jsonable_cost(cost: dict) -> dict:
    return {k: float(v) for k, v in (cost or {}).items()
            if isinstance(v, (int, float))}


def _link_section(report) -> dict:
    """Schema v2+v3 physical-link view (empty when the report has no topo).

    For sparse (fleet-scale) reports the dense ``link_matrix`` is omitted
    -- it is the same O(d^2) array the sparse path avoids -- and ``links``
    keeps only the rows that actually carried bytes; both are derived
    data, recomputed from ``ops`` + ``topo`` on load either way.
    """
    lu = None
    if getattr(report, "topo", None) is not None \
            and hasattr(report, "link_utilization"):
        lu = report.link_utilization()
    if lu is None:
        return {}
    if is_sparse(getattr(report, "matrix", None)):
        out = {
            "links": [r for r in lu.rows() if r.get("bytes", 0) > 0],
            "link_summary": lu.summary(),
            "link_tiers": lu.tier_summary(),
        }
    else:
        out = {
            "link_matrix": lu.matrix().tolist(),
            "links": lu.rows(),
            "link_summary": lu.summary(),
            "link_tiers": lu.tier_summary(),
        }
    if hasattr(report, "collective_seconds_split"):
        ici_s, dcn_s = report.collective_seconds_split()
        out["overlap"] = {
            "collective_ici_s": ici_s,
            "collective_dcn_s": dcn_s,
            "collective_overlap_s": max(ici_s, dcn_s),
            "collective_serial_s": ici_s + dcn_s,
        }
    return out


def _hlo_section(report, include_hlo: bool) -> dict:
    """Optional gzip+base64 of the compiled HLO modules (schema-v4 key).

    ``hlo_gz`` is a list -- one compressed module per session capture;
    modules must stay separate because computation names are only unique
    within a module.  Persisted only on request
    (``save(..., include_hlo=True)``): the text is large even compressed,
    and most consumers never run a roofline on a loaded report.
    """
    if not include_hlo:
        return {}
    texts = getattr(report, "_hlo_texts", None)
    if not texts:
        single = getattr(report, "_hlo_text", None)
        texts = [single] if single else None
    if not texts:
        return {}
    return {"hlo_gz": [base64.b64encode(gzip.compress(t.encode()))
                       .decode("ascii") for t in texts]}


def _schedule_section(report, include_schedules: bool) -> dict:
    """Optional schema-v5 per-op decomposition-schedule summaries.

    One entry per compiled op (aligned with the ``ops`` list), derived
    from the report's ``(algorithm, topo)`` binding -- purely derived
    data, so it is written only on request and never restored on load
    (``CommReport.schedule_summaries()`` recomputes it).
    """
    if not include_schedules or not hasattr(report, "schedule_summaries"):
        return {}
    return {"schedules": report.schedule_summaries()}


def _lint_section(report, include_lint: bool) -> dict:
    """Optional schema-v7 findings of the report's default binding.

    Written on request (``save(..., include_lint=True)``) and RESTORED on
    load -- the def-use rules read the module text, which most saved files
    do not carry, so persisted findings are the only way a plain file can
    serve ``lint()`` without re-capture.
    """
    if not include_lint or not hasattr(report, "lint"):
        return {}
    return {"lint": [f.to_dict() for f in report.lint()]}


def _trace_meta_section(report) -> dict:
    """Optional schema-v9 import provenance for measured (trace-imported)
    reports: which frontend parsed the trace, how device ids were mapped
    and clocks aligned.  Restored verbatim on load -- it cannot be
    re-derived from the op list."""
    tm = getattr(report, "trace_meta", None)
    return {"trace_meta": dict(tm)} if tm else {}


def report_to_dict(report, *, include_hlo: bool = False,
                   include_schedules: bool = False,
                   include_lint: bool = False) -> dict:
    """``CommReport`` -> JSON-serializable dict (schema ``v9``)."""
    return {
        "schema": SCHEMA,
        **_link_section(report),
        **_trace_meta_section(report),
        **_hlo_section(report, include_hlo),
        **_schedule_section(report, include_schedules),
        **_lint_section(report, include_lint),
        "phases": [phase_to_dict(p)
                   for p in getattr(report, "phases", []) or []],
        "name": report.name,
        "num_devices": report.num_devices,
        "algorithm": getattr(report, "algorithm", "ring"),
        "summary": report.compiled_summary,
        "traced_summary": report.traced_summary,
        "ops": [op_to_dict(op) for op in report.compiled_ops],
        "traced": [event_to_dict(e) for e in report.traced],
        "matrix": matrix_to_jsonable(report.matrix),
        "per_primitive": {k: matrix_to_jsonable(m)
                          for k, m in report.per_primitive.items()},
        "cost": _jsonable_cost(report.cost),
        "memory_stats": report.memory_stats,
        "trace_seconds": report.trace_seconds,
        "compile_seconds": report.compile_seconds,
        "topo": topo_to_dict(report.topo),
        "host_transfers": [transfer_to_dict(t) for t in report.host_transfers],
        "meta": dict(getattr(report, "meta", {}) or {}),
    }


def report_from_dict(d: dict):
    """Dict (schema ``v1`` ... ``v9``) -> ``CommReport``.

    The reverse of :func:`report_to_dict`.  Loaded reports carry everything
    needed for matrices, tables, exports and cost models; the live
    compilation artifacts (``_compiled`` / ``_lowered``) never persist, and
    the HLO text only does when the file was saved with
    ``include_hlo=True`` (``hlo_gz``), in which case
    :func:`repro.core.monitor.roofline_of` works on the loaded report too.
    The v2/v3 ``links``/``link_matrix``/``link_tiers``/``overlap`` sections
    and the v5 ``schedules`` section are derived data and are not restored
    -- ``CommReport.link_utilization`` / ``collective_seconds_split`` /
    ``schedule_summaries`` recompute them from ``ops`` + ``topo``, which is
    how older files stay fully usable.
    """
    from ..monitor import CommReport  # deferred: monitor imports this module

    schema = d.get("schema")
    if schema is not None and schema not in ACCEPTED_SCHEMAS:
        raise ValueError(
            f"unknown report schema {schema!r}; accepted: {ACCEPTED_SCHEMAS}")

    report = CommReport(
        name=d["name"],
        num_devices=int(d["num_devices"]),
        traced=[event_from_dict(e) for e in d.get("traced", [])],
        compiled_ops=[op_from_dict(o) for o in d.get("ops", [])],
        traced_summary=d.get("traced_summary", {}),
        compiled_summary=d.get("summary", {}),
        matrix=matrix_from_jsonable(d["matrix"]),
        per_primitive={k: matrix_from_jsonable(m)
                       for k, m in d.get("per_primitive", {}).items()},
        cost=d.get("cost", {}),
        memory_stats=d.get("memory_stats"),
        trace_seconds=float(d.get("trace_seconds", 0.0)),
        compile_seconds=float(d.get("compile_seconds", 0.0)),
        topo=topo_from_dict(d.get("topo")),
        host_transfers=[transfer_from_dict(t)
                        for t in d.get("host_transfers", [])],
        algorithm=d.get("algorithm", "ring"),
        meta=dict(d.get("meta", {})),
        phases=[phase_from_dict(p) for p in d.get("phases", [])],
        trace_meta=(dict(d["trace_meta"])
                    if d.get("trace_meta") else None),
    )
    if d.get("hlo_gz"):
        blobs = d["hlo_gz"]
        if isinstance(blobs, str):     # tolerate a single-blob spelling
            blobs = [blobs]
        texts = [gzip.decompress(base64.b64decode(b)).decode()
                 for b in blobs]
        report._hlo_texts = texts
        if len(texts) == 1:
            report._hlo_text = texts[0]
    if "lint" in d:
        from ..lint import LintFinding   # deferred: keep leaf import light
        report._lint_findings = [LintFinding.from_dict(x)
                                 for x in d["lint"]]
    return report
