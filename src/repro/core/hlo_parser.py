"""Extract collective-communication ops from compiled HLO text.

This is the TPU/XLA analogue of the paper's NCCL interception: on TPU the
*compiler* decides the communication schedule, so the compiled (SPMD
partitioned, per-device) module is the ground truth.  We parse
``compiled.as_text()`` for every collective op, its result shape(s),
replica groups (explicit or iota form) and metadata.

The parser is line-oriented and regex-based; HLO prints one instruction per
line.  Async pairs (``all-gather-start``/``-done``) are counted once at the
``-start``.

A malformed replica-group list (ragged explicit groups, an iota form whose
group shape does not tile its source) raises :class:`HLOParseError` carrying
the offending instruction text -- silently dropping groups would make every
downstream byte count quietly wrong.
"""
from __future__ import annotations

import re
from typing import Iterable

import numpy as np

from .events import COLLECTIVE_KINDS, CollectiveOp, Shape


class HLOParseError(ValueError):
    """An HLO instruction the parser recognizes but cannot interpret
    (malformed replica groups, ...).  Carries the op text in the message."""

# ----------------------------------------------------------------------------
# Shape parsing
# ----------------------------------------------------------------------------
_SHAPE_RE = re.compile(
    r"(pred|bf16|f16|f32|f64|s4|s8|s16|s32|s64|u4|u8|u16|u32|u64|c64|c128"
    r"|f8e4m3fn|f8e4m3b11fnuz|f8e4m3fnuz|f8e5m2fnuz|f8e5m2|f8e3m4|f8e4m3)"
    r"\[([0-9,]*)\]"
)


def _parse_shapes(text: str) -> list[Shape]:
    out = []
    for m in _SHAPE_RE.finditer(text):
        dims = tuple(int(d) for d in m.group(2).split(",") if d != "")
        out.append(Shape(dtype=m.group(1), dims=dims))
    return out


# ----------------------------------------------------------------------------
# Replica-group parsing: explicit {{0,1},{2,3}} and iota [4,2]<=[8] or
# [2,4]<=[4,2]T(1,0) forms.
# ----------------------------------------------------------------------------
_GROUPS_EXPLICIT_RE = re.compile(r"replica_groups=\{(\{[0-9,{}\s]*\})\}")
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[([0-9,]+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?"
)


def parse_replica_groups(line: str) -> list[list[int]]:
    """Replica groups of one instruction line ([] when the attribute is
    absent).  Raises :class:`HLOParseError` (with the op text) on malformed
    lists: ragged explicit groups, or an iota form whose group shape does
    not hold exactly the source's elements / whose permutation does not
    match the source rank."""
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        group_shape = [int(x) for x in m.group(1).split(",")]
        src_dims = [int(x) for x in m.group(2).split(",")]
        if int(np.prod(group_shape)) != int(np.prod(src_dims)):
            raise HLOParseError(
                f"iota replica_groups [{m.group(1)}]<=[{m.group(2)}] do not "
                f"tile: {np.prod(group_shape)} != {np.prod(src_dims)} "
                f"elements in op: {line.strip()}")
        v = np.arange(int(np.prod(src_dims))).reshape(src_dims)
        if m.group(3):
            perm = [int(x) for x in m.group(3).split(",")]
            if sorted(perm) != list(range(len(src_dims))):
                raise HLOParseError(
                    f"iota replica_groups transpose T({m.group(3)}) is not "
                    f"a permutation of the {len(src_dims)}-d source in op: "
                    f"{line.strip()}")
            v = v.transpose(perm)
        v = v.reshape(group_shape)
        return [list(map(int, row)) for row in v]
    m = _GROUPS_EXPLICIT_RE.search(line)
    if m:
        inner = m.group(1)
        groups = [
            [int(x) for x in g.replace(" ", "").split(",") if x != ""]
            for g in re.findall(r"\{([0-9,\s]*)\}", inner)
        ]
        sizes = {len(g) for g in groups}
        if len(sizes) > 1:
            raise HLOParseError(
                f"ragged replica_groups (sizes {sorted(sizes)}) in op: "
                f"{line.strip()}")
        return groups
    return []


_PAIRS_RE = re.compile(r"source_target_pairs=\{((?:\{\d+,\d+\},?)+)\}")
_CHANNEL_RE = re.compile(r"channel_id=(\d+)")
_GLOBAL_IDS_RE = re.compile(r"use_global_device_ids=true")
_DIMS_RE = re.compile(r"dimensions=\{([0-9,]*)\}")
_OPNAME_RE = re.compile(r'op_name="([^"]*)"')
# Per-rank byte vector riding in frontend_attributes (irregular
# collectives: allgatherv / skewed MoE all-to-all).  Runtimes that know the
# true per-rank sizes stamp them as a comma-separated list, e.g.
# ``frontend_attributes={repro.bytes_per_rank_vec="4096,1024,1024,1024"}``.
_VEC_RE = re.compile(r'repro\.bytes_per_rank_vec="([0-9eE+\-.,\s]+)"')


def _parse_byte_vector(line: str):
    """``bytes_per_rank_vec`` list from a frontend attribute, or ``None``
    (malformed vectors are dropped here; length/kind validation happens in
    :meth:`~repro.core.events.CollectiveOp.byte_vector`)."""
    m = _VEC_RE.search(line)
    if not m:
        return None
    try:
        vec = [float(x) for x in m.group(1).split(",") if x.strip()]
    except ValueError:
        return None
    return vec or None


# ----------------------------------------------------------------------------
# Operand parsing that survives both HLO spellings.  New jax prints
# ``all-reduce(%a, %b)``; jax 0.4.x prints typed operands
# ``all-reduce(f32[8,8]{1,0} %a, (s32[], f32[4]) %b)`` whose layouts and
# tuple-shaped types contain commas and parens, so naive ``split(",")``
# parsing silently yields garbage names.  These helpers are shared with
# :mod:`repro.core.hlo_cost` (which re-imports them).
# ----------------------------------------------------------------------------
def _split_top_level(text: str) -> list[str]:
    """Split on commas at bracket depth 0 (wrt ``()[]{}``)."""
    parts: list[str] = []
    cur: list[str] = []
    depth = 0
    for ch in text:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        parts.append("".join(cur))
    return [p.strip() for p in parts if p.strip()]


def _operand_names(args_text: str) -> list[str]:
    """Operand names from a call's argument text (last token per operand,
    ``%`` stripped -- drops any inline type annotation)."""
    return [p.split()[-1].lstrip("%") for p in _split_top_level(args_text)]


def _call_args(line: str, opcode: str) -> str:
    """Balanced-paren argument text of ``opcode(...)`` in ``line``
    ('' when absent)."""
    idx = line.find(opcode + "(")
    if idx < 0:
        return ""
    start = idx + len(opcode) + 1
    depth = 1
    for i in range(start, len(line)):
        if line[i] == "(":
            depth += 1
        elif line[i] == ")":
            depth -= 1
            if depth == 0:
                return line[start:i]
    return line[start:]

# instruction: [ROOT] %name = <result-type> opcode(
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute"
    r"|collective-broadcast|ragged-all-to-all)"
    r"(-start)?\s*\("
)


_PROMOTED_RE = re.compile(r"to_apply=%?\S*promoted")


def parse_hlo_collectives(hlo_text: str) -> list[CollectiveOp]:
    """Parse all collective ops from HLO text (one per async pair).

    XLA:CPU *promotes* bf16 all-reduces to f32 (convert -> AR(f32) ->
    convert, reduction computation named ``*_promoted``); TPU reduces bf16
    natively.  Promoted ops are accounted at their pre-promotion width.
    """
    ops: list[CollectiveOp] = []
    for raw in hlo_text.splitlines():
        line = raw.strip()
        if not line or "=" not in line:
            continue
        m = _INSTR_RE.match(line)
        if m is None:
            continue
        name, result_text, kind, _start = m.group(1), m.group(2), m.group(3), m.group(4)
        # skip fusions that merely *consume* a collective: opcode must follow '='
        result_shapes = _parse_shapes(result_text)
        if _PROMOTED_RE.search(line):
            result_shapes = [
                Shape("bf16", s.dims) if s.dtype == "f32" else s
                for s in result_shapes]
        # async-start results repeat operand + result; dedupe: the final shape
        # tuple of a start op is ((operands), results, ...) -- keep the result
        # entries only for the common (operand, result, u32[]) layout.
        if _start and len(result_shapes) >= 2:
            # all-gather-start: (op, result); all-reduce-start: same shape
            half = len(result_shapes) // 2
            result_shapes = result_shapes[half:] or result_shapes
        groups = parse_replica_groups(line)
        pairs = []
        pm = _PAIRS_RE.search(line)
        if pm:
            pairs = [
                tuple(int(x) for x in p.split(","))
                for p in re.findall(r"\{(\d+,\d+)\}", pm.group(1))
            ]
        cm = _CHANNEL_RE.search(line)
        dm = _DIMS_RE.search(line)
        om = _OPNAME_RE.search(line)
        # operand names via the balanced-paren walk: tuple-shaped operands
        # (async starts, variadic all-reduces) contain depth-1 commas that
        # a naive split would shred
        args = _call_args(line, kind + ("-start" if _start else ""))
        operands = _operand_names(args) if args.strip() else []
        ops.append(
            CollectiveOp(
                kind=kind,
                name=name,
                result_shapes=result_shapes,
                replica_groups=groups,
                channel_id=int(cm.group(1)) if cm else None,
                dimensions=tuple(int(x) for x in dm.group(1).split(",") if x)
                if dm
                else (),
                source_target_pairs=pairs,
                op_name=om.group(1) if om else "",
                operand_names=operands,
                use_global_device_ids=bool(_GLOBAL_IDS_RE.search(line)),
                bytes_per_rank_vec=_parse_byte_vector(line),
            )
        )
    return ops


# ----------------------------------------------------------------------------
# Summaries
# ----------------------------------------------------------------------------
def _op_wire_bytes(op: CollectiveOp, algorithm: str, topo) -> float:
    """Execution-weighted wire bytes for one op, decided **per replica
    group** with the shared hierarchical predicate -- so summaries
    degenerate to ring exactly where the placement and the cost model do
    (one predicate, no divergence), even when groups differ in how they
    straddle pods."""
    from . import cost_models

    if op.kind == "collective-permute":
        if algorithm == "hierarchical" and topo is not None \
                and topo.num_pods > 1 and op.source_target_pairs:
            # the pod-leader relay adds ICI hops the flat pair count
            # misses; read the total off the same schedule the matrix
            # places so summary == matrix
            from . import decompose as _dec
            return _dec.decompose(op, algorithm, topo,
                                  warn=False).total_bytes() * op.weight
        return op.wire_bytes_total(algorithm)
    if topo is None or not op.replica_groups:
        return op.wire_bytes_total(algorithm)
    total = 0.0
    for g in op.replica_groups:
        total += cost_models.wire_bytes_group_total(
            op.kind, op.payload_bytes, len(g), algorithm,
            pods=cost_models.effective_pods(op.kind, g, topo),
            vec=op.byte_vector())
    return total * op.weight


def summarize(ops: Iterable[CollectiveOp], algorithm: str = "ring",
              topo=None) -> dict:
    """Paper Table-2/3-style summary: per-kind call counts and byte totals.

    Counts are execution-weighted: an op inside a while body with trip count
    64 contributes 64 calls (loop-aware, see hlo_cost.py).  ``topo`` (a
    :class:`~repro.core.topology.MeshTopology`) makes the hierarchical
    algorithm's byte totals pod-aware.
    """
    table: dict[str, dict] = {}
    for op in ops:
        row = table.setdefault(
            op.kind,
            {"calls": 0, "payload_bytes": 0, "wire_bytes": 0.0},
        )
        row["calls"] += int(op.weight)
        row["payload_bytes"] += int(op.payload_bytes * op.num_groups * op.weight)
        row["wire_bytes"] += _op_wire_bytes(op, algorithm, topo)
        skew = op.skew()
        if skew > 1.0:
            # irregular ops surface their worst max/mean per-rank skew
            # (absent for regular kinds, so fixed-column consumers keep
            # their layout)
            row["max_skew"] = max(row.get("max_skew", 1.0), skew)
        if op.measured_s is not None:
            # trace-imported ops carry measured wall time (schema v9);
            # absent for purely modeled captures, so fixed-column
            # consumers keep their layout
            row["measured_s"] = (row.get("measured_s", 0.0)
                                 + float(op.measured_s))
    return table


def total_wire_bytes(ops: Iterable[CollectiveOp], algorithm: str = "ring",
                     topo=None) -> float:
    """Global bytes-on-the-wire across all devices (roofline numerator)."""
    return float(sum(_op_wire_bytes(op, algorithm, topo) for op in ops))


def count_by_opname(ops: Iterable[CollectiveOp]) -> dict[str, int]:
    out: dict[str, int] = {}
    for op in ops:
        key = op.op_name or "<unattributed>"
        out[key] = out.get(key, 0) + 1
    return out
