"""Sparse communication matrices: the fleet-scale representation.

The paper's ``(d+1) x (d+1)`` dense matrix (row/col 0 = host) is O(d^2)
memory -- 2 GiB of float64 at 16k devices -- while the matrices this repo
builds are *schedule-derived*: ring phases touch torus neighbours, trees
touch heap edges, DCN exchanges touch pod representatives.  The number of
distinct (src, dst) pairs grows like O(d), not O(d^2), so fleet-scale
capacity planning (``sweep --scale-curve``, 256 -> 16k devices) keeps the
same byte accounting in a COO triplet form and never materializes the
dense array.

:class:`SparseCommMatrix` is that form: coalesced, deduplicated
``(src, dst, val)`` arrays over the same (d+1)-indexed space as the dense
matrix (index 0 = host).  It answers everything downstream consumers ask
of a matrix -- totals, row sums, the coarsened heatmap block
(:meth:`coarsen`, bit-for-bit equal to ``reporter.coarsen_matrix`` of the
dense equivalent), link projection via :meth:`device_entries` -- and
converts exactly via :meth:`to_dense` for small meshes and tests.

:class:`SparseAccumulator` is the bounded-memory builder behind
``comm_matrix.matrix_for_ops(..., sparse=True)``: it buffers raw COO
chunks and coalesces (sort + reduce on encoded keys) whenever the pending
entry count crosses a threshold, so a long op stream costs
O(nnz + threshold) transient memory regardless of device count.

``SPARSE_DEVICE_THRESHOLD`` is the auto-cutover used by
:class:`~repro.core.views.CommView`: at or below it views build dense
(cheap, fully general); above it they build sparse.  2048 devices puts the
dense matrix at ~32 MiB -- the last point where allocating it per view is
still reasonable.
"""
from __future__ import annotations

from typing import Iterable, Optional

import numpy as np

# CommView's auto mode builds dense matrices up to this many devices and
# sparse ones above it (see docs/architecture.md, "sparse representation").
SPARSE_DEVICE_THRESHOLD = 2048

# raw (uncoalesced) entries buffered before an intermediate coalesce
_COALESCE_AT = 1 << 20

# counting-sort coalesce is used while side^2 float64 scratch stays modest
# (side = SPARSE_DEVICE_THRESHOLD + 1 -> ~34 MB); the argsort path takes
# over beyond that, preserving the O(nnz)-memory fleet guarantee
_COUNTING_MAX_SIDE = SPARSE_DEVICE_THRESHOLD + 1


def _coalesce(side: int, src: np.ndarray, dst: np.ndarray,
              val: np.ndarray) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Group by (src, dst) and sum duplicates.  Encoded int64 keys: safe up
    to side ~ 3e9, far beyond any fleet.

    Two strategies, identical results: a counting sort via ``np.bincount``
    over the dense key space when ``side`` is modest (it dominated the
    sparse-vs-dense gap: a stable ``argsort`` over millions of edges is
    ~3x the cost of summing them), and the stable argsort + ``reduceat``
    beyond, where ``side^2`` scratch would defeat the point of sparse.
    Both accumulate each cell's contributions sequentially in array order,
    so dense/sparse bitwise equality holds on either path.  The counting
    path drops cells that sum to exactly 0.0 -- values here are
    non-negative bytes, so such a cell only ever held zero-byte edges,
    which no derived quantity reads.
    """
    if src.size == 0:
        return (np.empty(0, dtype=np.int64), np.empty(0, dtype=np.int64),
                np.empty(0, dtype=np.float64))
    key = src.astype(np.int64) * np.int64(side) + dst.astype(np.int64)
    if side <= _COUNTING_MAX_SIDE and key.size >= side:
        flat = np.bincount(key, weights=val, minlength=side * side)
        uk = np.flatnonzero(flat)
        return uk // side, uk % side, flat[uk]
    order = np.argsort(key, kind="stable")
    key = key[order]
    val = val[order]
    boundary = np.empty(key.size, dtype=bool)
    boundary[0] = True
    np.not_equal(key[1:], key[:-1], out=boundary[1:])
    starts = np.flatnonzero(boundary)
    uk = key[starts]
    sums = np.add.reduceat(val, starts)
    return uk // side, uk % side, sums.astype(np.float64, copy=False)


class SparseCommMatrix:
    """COO form of one ``(d+1) x (d+1)`` bytes-sent matrix.

    Indices live in the dense matrix's coordinate space: 0 is the host
    row/column, device ``i`` is index ``i + 1``.  Entries are kept
    coalesced (unique, sorted (src, dst), summed values); zero-valued
    entries may exist after accumulating zero-byte edges but never change
    any derived quantity.
    """

    __slots__ = ("side", "src", "dst", "val")

    def __init__(self, num_devices: int,
                 src: Optional[np.ndarray] = None,
                 dst: Optional[np.ndarray] = None,
                 val: Optional[np.ndarray] = None, *,
                 coalesced: bool = False):
        self.side = int(num_devices) + 1
        src = np.asarray([] if src is None else src, dtype=np.int64).ravel()
        dst = np.asarray([] if dst is None else dst, dtype=np.int64).ravel()
        val = np.asarray([] if val is None else val,
                         dtype=np.float64).ravel()
        if not (src.size == dst.size == val.size):
            raise ValueError(
                f"COO arrays disagree: {src.size}/{dst.size}/{val.size}")
        if src.size and (src.min() < 0 or dst.min() < 0
                         or src.max() >= self.side
                         or dst.max() >= self.side):
            raise ValueError(
                f"COO indices out of range for side {self.side}")
        if not coalesced:
            src, dst, val = _coalesce(self.side, src, dst, val)
        self.src, self.dst, self.val = src, dst, val

    # -- identity ----------------------------------------------------------
    @property
    def shape(self) -> tuple[int, int]:
        return (self.side, self.side)

    @property
    def num_devices(self) -> int:
        return self.side - 1

    @property
    def nnz(self) -> int:
        return int(self.src.size)

    def __repr__(self) -> str:
        return (f"SparseCommMatrix({self.num_devices} devices, "
                f"nnz={self.nnz}, total={self.sum():.4g} B)")

    # -- aggregates (all O(nnz) or O(d), never O(d^2)) ---------------------
    def sum(self) -> float:
        return float(self.val.sum())

    def max(self) -> float:
        return float(self.val.max()) if self.nnz else 0.0

    def row_sums(self) -> np.ndarray:
        """Per-index sent bytes, length ``d + 1`` (index 0 = host)."""
        return np.bincount(self.src, weights=self.val, minlength=self.side)

    def col_sums(self) -> np.ndarray:
        """Per-index received bytes, length ``d + 1`` (index 0 = host)."""
        return np.bincount(self.dst, weights=self.val, minlength=self.side)

    def entries(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """The coalesced ``(src, dst, val)`` arrays (read-only by
        convention; indices include the host slot 0)."""
        return self.src, self.dst, self.val

    def device_entries(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Device-to-device entries only, with 0-based device ids -- the
        input :func:`~repro.core.comm_matrix.project_links` routes."""
        keep = (self.src > 0) & (self.dst > 0) & (self.val > 0)
        return self.src[keep] - 1, self.dst[keep] - 1, self.val[keep]

    # -- mutation (matrix building only) -----------------------------------
    def add_entries(self, src, dst, val) -> "SparseCommMatrix":
        """Accumulate more COO entries (re-coalesces); used by
        ``add_host_transfers``.  Returns self."""
        self.src, self.dst, self.val = _coalesce(
            self.side,
            np.concatenate([self.src, np.asarray(src, dtype=np.int64)]),
            np.concatenate([self.dst, np.asarray(dst, dtype=np.int64)]),
            np.concatenate([self.val, np.asarray(val, dtype=np.float64)]))
        return self

    # -- conversions -------------------------------------------------------
    def to_dense(self) -> np.ndarray:
        """The equivalent dense ``(d+1) x (d+1)`` array.  O(d^2) memory by
        definition -- for small meshes, tests and round-trip checks; the
        fleet-scale paths never call it."""
        mat = np.zeros((self.side, self.side), dtype=np.float64)
        mat[self.src, self.dst] = self.val
        return mat

    def coarsen(self, max_devices: int = 32) -> tuple[np.ndarray, int]:
        """Block-summed small dense matrix for heatmaps, identical to
        ``reporter.coarsen_matrix(self.to_dense(), max_devices)`` without
        the dense detour.  Returns ``(matrix, block)``."""
        d = self.side
        if d <= max_devices + 1:
            return self.to_dense(), 1
        k = -(-(d - 1) // max_devices)          # ceil((d-1)/max_devices)
        nb = -(-(d - 1) // k)
        hm = np.zeros((nb + 1, nb + 1), dtype=np.float64)
        # host slot stays exact; device indices collapse onto blocks
        bsrc = np.where(self.src == 0, 0, (self.src - 1) // k + 1)
        bdst = np.where(self.dst == 0, 0, (self.dst - 1) // k + 1)
        np.add.at(hm, (bsrc, bdst), self.val)
        return hm, k

    def to_csv_rows(self) -> list[str]:
        """Long-form ``src,dst,bytes`` rows (host slot labelled ``host``,
        device ``i`` labelled ``gpu{i}``), nonzero entries only -- the
        fleet-scale CSV export (a (16k)^2 grid CSV would be absurd)."""
        def label(i: int) -> str:
            return "host" if i == 0 else f"gpu{i - 1}"
        return [f"{label(int(s))},{label(int(t))},{v:.0f}"
                for s, t, v in zip(self.src, self.dst, self.val) if v > 0]


def is_sparse(mat) -> bool:
    return isinstance(mat, SparseCommMatrix)


class SparseAccumulator:
    """Bounded-memory COO accumulation for matrix building.

    ``add`` takes raw (possibly duplicated) entry chunks; whenever the
    pending raw count crosses ``coalesce_at`` everything is coalesced down
    to unique entries, so peak memory is O(unique nnz + coalesce_at)
    however long the op stream runs.
    """

    def __init__(self, num_devices: int, coalesce_at: int = _COALESCE_AT):
        self.num_devices = int(num_devices)
        self.side = self.num_devices + 1
        self.coalesce_at = int(coalesce_at)
        # At modest device counts a flat side^2 float64 working array --
        # the dense builder's exact footprint and regime (the dense matrix
        # is affordable here by definition) -- accumulates via ``np.add.at``
        # on linearized keys: the same per-cell addition sequence as the
        # dense path, so bitwise equality is free, and no concatenate /
        # sort / bincount pass ever runs.  Beyond ``_COUNTING_MAX_SIDE``
        # the buffered-COO path below keeps memory O(nnz + coalesce_at).
        self._flat: Optional[np.ndarray] = (
            None if self.side > _COUNTING_MAX_SIDE else
            np.zeros(self.side * self.side, dtype=np.float64))
        self._src: list[np.ndarray] = []
        self._dst: list[np.ndarray] = []
        self._val: list[np.ndarray] = []
        self._pending = 0

    def add(self, src: np.ndarray, dst: np.ndarray, val: np.ndarray):
        if src.size == 0:
            return
        if self._flat is not None:
            key = (np.asarray(src, dtype=np.int64) * np.int64(self.side)
                   + np.asarray(dst, dtype=np.int64))
            np.add.at(self._flat, key, np.asarray(val, dtype=np.float64))
            return
        self._src.append(np.asarray(src, dtype=np.int64))
        self._dst.append(np.asarray(dst, dtype=np.int64))
        self._val.append(np.asarray(val, dtype=np.float64))
        self._pending += src.size
        if self._pending >= self.coalesce_at:
            self._squash()

    def _squash(self):
        src, dst, val = _coalesce(self.side,
                                  np.concatenate(self._src),
                                  np.concatenate(self._dst),
                                  np.concatenate(self._val))
        self._src, self._dst, self._val = [src], [dst], [val]
        self._pending = src.size

    def build(self) -> SparseCommMatrix:
        if self._flat is not None:
            # exact-0.0 cells drop here, same as the counting coalesce:
            # values are non-negative bytes, so such a cell only ever held
            # zero-byte edges, which no derived quantity reads
            uk = np.flatnonzero(self._flat)
            return SparseCommMatrix(self.num_devices, uk // self.side,
                                    uk % self.side, self._flat[uk],
                                    coalesced=True)
        if not self._src:
            return SparseCommMatrix(self.num_devices)
        self._squash()
        return SparseCommMatrix(self.num_devices, self._src[0],
                                self._dst[0], self._val[0], coalesced=True)


def from_dense(mat: np.ndarray) -> SparseCommMatrix:
    """Dense ``(d+1) x (d+1)`` array -> :class:`SparseCommMatrix` (exact)."""
    m = np.asarray(mat, dtype=np.float64)
    src, dst = np.nonzero(m)
    return SparseCommMatrix(m.shape[0] - 1, src, dst, m[src, dst],
                            coalesced=True)
