"""Three-term roofline analysis from a compiled (dry-run) artifact.

For a compiled SPMD program the module is per-device, so
``compiled.cost_analysis()`` reports *per-device* FLOPs and bytes; dividing
by per-chip peaks yields the same seconds as the global formulation
(``HLO_FLOPs_global / (chips x peak)``):

    compute_s    = flops_per_device        / peak_flops_per_chip
    memory_s     = bytes_accessed_per_dev  / hbm_bw_per_chip
    collective_s = wire_bytes_per_device   / link_bw  (spec formula), and a
                   topology-aware estimate (ring/DCN) as a refinement.

``wire_bytes_per_device`` is NOT in cost_analysis — it is summed from the
collective ops parsed out of the compiled HLO (the paper's contribution makes
exactly this visible).

**Link-level overlap model.**  ``collective_s_topo`` serializes every
collective; real schedules overlap compute with communication and the ICI
torus with the DCN fabric (independent wires).  The overlap-aware bound is

    bound_overlap_s = max(compute_s, memory_s,
                          collective_ici_s, collective_dcn_s)

where ``collective_ici_s`` / ``collective_dcn_s`` are the per-tier
serialized sums from ``cost_models.total_time_split`` -- bandwidth plus
the per-phase latency hops of each op's decomposition schedule
(:mod:`repro.core.decompose`), summed per phase per tier -- so
``collective_overlap_s = max(ici, dcn) <= collective_s_topo``, with
equality exactly when a single tier carries all the traffic.  The
per-link busy times from ``LinkUtilization.busy_seconds`` ride along as
the contention-aware refinement per tier (``ici_busy_s`` / ``dcn_busy_s``:
the busiest physical link of each fabric, including multi-hop transit --
pure bandwidth, since links carry bytes, not hop latencies).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from . import cost_models, hlo_parser
from .events import CollectiveOp
from .topology import HardwareSpec, MeshTopology, V5E


@dataclasses.dataclass
class RooflineReport:
    arch: str
    mesh: str
    num_devices: int
    # raw inputs
    flops_per_device: float
    bytes_per_device: float
    wire_bytes_per_device: float
    # three terms, in seconds
    compute_s: float
    memory_s: float
    collective_s: float
    collective_s_topo: float        # topology-aware (serialized, bw+latency)
    # link-level overlap terms (tiers are independent fabrics)
    collective_ici_s: float = 0.0   # serialized ICI share of collective_s_topo
    collective_dcn_s: float = 0.0   # serialized DCN share of collective_s_topo
    ici_busy_s: float = 0.0         # busiest physical ICI link (w/ transit)
    dcn_busy_s: float = 0.0         # busiest DCN up/downlink
    # analysis
    model_flops: float = 0.0        # 6*N*D (dense) / 6*N_active*D (MoE), global
    useful_flops_ratio: float = 0.0 # MODEL_FLOPS / (flops_per_device*chips)
    peak_fraction: float = 0.0      # compute_s / max(all terms)
    dominant: str = ""
    memory_bytes_per_device: Optional[dict] = None  # memory_analysis summary
    collective_breakdown: Optional[dict] = None

    @property
    def bound_time_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def collective_overlap_s(self) -> float:
        """Overlapped communication time: ICI and DCN are independent
        fabrics, so their serialized per-tier sums run concurrently.
        Always <= ``collective_s_topo`` (their sum); equal exactly when a
        single tier carries all the traffic."""
        return max(self.collective_ici_s, self.collective_dcn_s)

    @property
    def bound_overlap_s(self) -> float:
        """Overlap-aware roofline bound: compute ∥ ICI ∥ DCN (and the HBM
        stream), instead of summing serialized collective times."""
        return max(self.compute_s, self.memory_s,
                   self.collective_ici_s, self.collective_dcn_s)

    def one_liner(self) -> str:
        hints = {
            "compute": "increase arithmetic efficiency (less remat recompute, "
                       "larger fused matmuls, avoid redundant einsums)",
            "memory": "reduce HBM traffic (fuse elementwise chains, better remat "
                      "policy, bf16 activations, larger per-op tiles)",
            "collective": "cut wire bytes (overlapped/hierarchical collectives, "
                          "bf16/compressed gradients, resharding to remove "
                          "redundant all-gathers)",
        }
        val = getattr(self, "collective_s" if self.dominant == "collective"
                      else self.dominant + "_s")
        return (f"{self.arch}@{self.mesh}: dominant={self.dominant} "
                f"({val:.3e}s); {hints[self.dominant]}")


def _sum_wire_bytes_per_device(ops: list[CollectiveOp], num_devices: int,
                               algorithm: str = "ring") -> float:
    """Average per-device bytes *sent* over all collective ops in one step."""
    total = 0.0
    for op in ops:
        total += op.wire_bytes_total(algorithm)
    return total / max(1, num_devices)


def analyze(
    *,
    arch: str,
    mesh_name: str,
    cost: dict,
    hlo_text,
    topo: MeshTopology,
    hw: HardwareSpec = V5E,
    model_flops: float = 0.0,
    memory_stats: Optional[dict] = None,
    algorithm: str = "ring",
    link_utilization=None,
) -> RooflineReport:
    """Build the roofline report for one (arch x mesh) dry-run cell.

    FLOPs/bytes/collectives come from the loop-aware HLO walk
    (:mod:`repro.core.hlo_cost`) — ``cost_analysis`` counts while bodies once
    and is kept only as the ``cost_analysis_*`` reference fields.

    ``hlo_text`` is one compiled module, or a list of modules (a
    multi-capture session): each module is analyzed **separately** —
    computation names are only unique within a module, so concatenating
    them would clobber same-named computations and drop loop trip counts
    — and the per-module FLOPs / bytes / collectives are summed.

    ``link_utilization`` lets a caller that already projected the program
    onto physical links (e.g. ``CommReport.link_utilization()``) reuse it
    for the per-tier busy diagnostics instead of re-routing the placed
    edges here (cost is proportional to placed edges x route hops).
    """
    from . import hlo_cost as hc_mod
    texts = [hlo_text] if isinstance(hlo_text, str) else list(hlo_text)
    hcs = [hc_mod.analyze_hlo(t) for t in texts]
    ops = [op for hc in hcs for op in hc.collectives]
    flops = sum(hc.flops for hc in hcs)
    byts = sum(hc.bytes_hbm for hc in hcs)
    bytes_logical = sum(hc.bytes_logical for hc in hcs)
    wire = _sum_wire_bytes_per_device(ops, topo.num_devices, algorithm)

    compute_s = flops / hw.peak_flops_bf16
    memory_s = byts / hw.hbm_bw
    # spec formula: collective_bytes / (chips x link_bw); per-device wire bytes
    # over one link's bandwidth (conservative: a ring uses 2 links per axis,
    # captured in the topology-aware estimate below).
    collective_s = wire / hw.ici_bw
    ici_s, dcn_s = cost_models.total_time_split(ops, topo, algorithm)
    collective_s_topo = ici_s + dcn_s
    lu = link_utilization
    if lu is None and ops:
        from . import comm_matrix
        lu = comm_matrix.link_utilization_for_ops(ops, topo, algorithm)

    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)
    total_flops = flops * topo.num_devices
    mem = dict(memory_stats or {})
    mem["cost_analysis_flops"] = float(cost.get("flops", 0.0))
    mem["cost_analysis_bytes"] = float(cost.get("bytes accessed", 0.0))
    mem["hlo_bytes_logical"] = bytes_logical
    memory_stats = mem
    report = RooflineReport(
        arch=arch,
        mesh=mesh_name,
        num_devices=topo.num_devices,
        flops_per_device=flops,
        bytes_per_device=byts,
        wire_bytes_per_device=wire,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        collective_s_topo=collective_s_topo,
        collective_ici_s=ici_s,
        collective_dcn_s=dcn_s,
        ici_busy_s=lu.busy_seconds("ici") if lu is not None else 0.0,
        dcn_busy_s=lu.busy_seconds("dcn") if lu is not None else 0.0,
        model_flops=model_flops,
        useful_flops_ratio=(model_flops / total_flops) if total_flops else 0.0,
        peak_fraction=(compute_s / max(terms.values())) if max(terms.values()) else 0.0,
        dominant=dominant,
        memory_bytes_per_device=memory_stats,
        collective_breakdown=hlo_parser.summarize(ops, algorithm),
    )
    return report


# ---------------------------------------------------------------------------
# MODEL_FLOPS helpers — 6*N*D for training, 2*N*D for a forward/decode token
# ---------------------------------------------------------------------------
def train_model_flops(n_params_active: float, tokens: float) -> float:
    return 6.0 * n_params_active * tokens

def forward_model_flops(n_params_active: float, tokens: float) -> float:
    return 2.0 * n_params_active * tokens


def to_row(r: RooflineReport) -> dict:
    return {
        "arch": r.arch,
        "mesh": r.mesh,
        "devices": r.num_devices,
        "flops/dev": r.flops_per_device,
        "bytes/dev": r.bytes_per_device,
        "wire_bytes/dev": r.wire_bytes_per_device,
        "compute_s": r.compute_s,
        "memory_s": r.memory_s,
        "collective_s": r.collective_s,
        "collective_s_topo": r.collective_s_topo,
        "collective_ici_s": r.collective_ici_s,
        "collective_dcn_s": r.collective_dcn_s,
        "collective_overlap_s": r.collective_overlap_s,
        "bound_overlap_s": r.bound_overlap_s,
        "ici_busy_s": r.ici_busy_s,
        "dcn_busy_s": r.dcn_busy_s,
        "dominant": r.dominant,
        "model_flops": r.model_flops,
        "useful_flops_ratio": r.useful_flops_ratio,
    }
