"""Human/machine-readable reports: the paper's tables, matrices and heatmaps.

Everything ComScribe emits, we emit:

* per-primitive call-count / byte tables (paper Tables 2 & 3),
* the ``(d+1) x (d+1)`` communication matrix rendered as an ASCII heatmap in
  log scale (paper Figs. 2 & 3) plus CSV/JSON for machine consumption,
* the traced-vs-compiled diff table (beyond-paper: visible compiler-inserted
  communication).
"""
from __future__ import annotations

import json
import math
from typing import Iterable, Optional

import numpy as np

from .events import CollectiveOp

# ---------------------------------------------------------------------------
# formatting helpers
# ---------------------------------------------------------------------------
_UNITS = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"]


def human_bytes(n: float) -> str:
    n = float(n)
    if n <= 0:
        return "0 B"
    k = min(len(_UNITS) - 1, int(math.log(n, 1024)))
    return f"{n / 1024 ** k:,.2f} {_UNITS[k]}"


def format_table(rows: list[list[str]], header: list[str]) -> str:
    widths = [len(h) for h in header]
    for r in rows:
        for i, c in enumerate(r):
            widths[i] = max(widths[i], len(str(c)))
    def fmt(row):
        return " | ".join(str(c).ljust(w) for c, w in zip(row, widths))
    sep = "-+-".join("-" * w for w in widths)
    return "\n".join([fmt(header), sep] + [fmt(r) for r in rows])


# ---------------------------------------------------------------------------
# paper Table 2/3 — primitive usage analysis
# ---------------------------------------------------------------------------
def primitive_usage_table(summary: dict, title: str = "") -> str:
    """``summary`` maps primitive name -> {calls, payload_bytes[,
    wire_bytes][, max_skew][, measured_s]}.  ``max_skew`` (worst max/mean
    per-rank byte ratio of any irregular op of that kind) adds a Skew
    column only when some row carries it; ``measured_s`` (trace-imported
    wall time, schema v9) likewise adds a Measured column -- regular,
    purely modeled captures keep the classic layout."""
    has_skew = any("max_skew" in summary[k] for k in summary)
    has_meas = any("measured_s" in summary[k] for k in summary)
    rows = []
    for name in sorted(summary, key=lambda k: -summary[k].get("payload_bytes", 0)):
        row = summary[name]
        cells = [name, f"{row['calls']:,}", human_bytes(row.get("payload_bytes", 0))]
        if "wire_bytes" in row:
            cells.append(human_bytes(row["wire_bytes"]))
        if has_skew:
            cells.append(f"{row.get('max_skew', 1.0):.2f}x")
        if has_meas:
            cells.append(f"{row.get('measured_s', 0.0) * 1e3:.3f} ms")
        rows.append(cells)
    header = ["Communication Type", "Number of Calls", "Total Size"]
    if rows and len(rows[0]) >= 4 + has_skew + has_meas:
        header.append("Wire Bytes")
    if has_skew:
        header.append("Skew (max/mean)")
    if has_meas:
        header.append("Measured")
    out = format_table(rows, header)
    if title:
        out = f"== {title} ==\n{out}"
    return out


# ---------------------------------------------------------------------------
# session phases — per-phase Table 2 breakdown and phase-vs-phase diff
# ---------------------------------------------------------------------------
def phase_usage_table(phase_summaries: dict, title: str = "") -> str:
    """Per-phase primitive usage: one row per (phase, primitive).

    ``phase_summaries`` maps phase name (in session order) to a Table-2
    style summary dict.  A phase with no compiled collectives still gets a
    row -- an optimizer phase that moves no bytes is a finding, not an
    omission.
    """
    rows = []
    for phase, summary in phase_summaries.items():
        if not summary:
            rows.append([phase, "(none)", "0", "0 B", "0 B"])
            continue
        for name in sorted(summary,
                           key=lambda k: -summary[k].get("payload_bytes", 0)):
            r = summary[name]
            rows.append([phase, name, f"{r.get('calls', 0):,}",
                         human_bytes(r.get("payload_bytes", 0)),
                         human_bytes(r.get("wire_bytes", 0))])
    out = format_table(rows, ["Phase", "Communication Type",
                              "Number of Calls", "Total Size", "Wire Bytes"])
    if title:
        out = f"== {title} ==\n{out}"
    return out


def _signed_bytes(n: float) -> str:
    return ("-" if n < 0 else "+") + human_bytes(abs(n))


# ---------------------------------------------------------------------------
# static lint findings — the advisor's table
# ---------------------------------------------------------------------------
def lint_table(findings, title: str = "") -> str:
    """Findings table (:class:`~repro.core.lint.LintFinding` records):
    rule, severity, ops, modeled savings -- already sorted errors-first by
    the lint pass."""
    if not findings:
        out = "(no lint findings)"
        return f"== {title} ==\n{out}" if title else out
    rows = []
    for f in findings:
        ops = ",".join(f.op_names)
        if len(ops) > 40:
            ops = ops[:37] + f"...({len(f.op_names)} ops)"
        rows.append([
            f.rule_id, f.severity, f.phase or "-", ops,
            f"{f.est_savings_s * 1e3:.3f} ms",
            human_bytes(f.est_dcn_bytes_saved),
            f.suggested_fix,
        ])
    out = format_table(rows, ["Rule", "Severity", "Phase", "Ops",
                              "Est. Savings", "DCN Bytes Saved",
                              "Suggested Fix"])
    if title:
        out = f"== {title} ==\n{out}"
    return out


def compare_table(result, title: str = "") -> str:
    """Modeled-vs-measured table for a
    :class:`~repro.core.trace.compare.CompareResult` (per-collective rows
    plus per-kind / per-size-class aggregates -- the ``repro compare``
    terminal rendering)."""
    return result.table(title)


def phase_diff_table(a_name: str, a_summary: dict,
                     b_name: str, b_summary: dict) -> str:
    """Primitive-by-primitive comparison of two phases' compiled
    communication (calls + wire bytes, with the wire-byte delta b - a)."""
    names = sorted(set(a_summary) | set(b_summary))
    rows = []
    for n in names:
        a = a_summary.get(n, {})
        b = b_summary.get(n, {})
        rows.append([
            n,
            f"{a.get('calls', 0):,}", human_bytes(a.get("wire_bytes", 0.0)),
            f"{b.get('calls', 0):,}", human_bytes(b.get("wire_bytes", 0.0)),
            _signed_bytes(b.get("wire_bytes", 0.0)
                          - a.get("wire_bytes", 0.0)),
        ])
    return format_table(rows, [
        "Primitive", f"{a_name} calls", f"{a_name} wire",
        f"{b_name} calls", f"{b_name} wire", "Δ wire"])


# ---------------------------------------------------------------------------
# paper Fig. 2/3 — communication-matrix heatmap (log scale), ASCII rendering
# ---------------------------------------------------------------------------
_SHADES = " .:-=+*#%@"


def coarsen_matrix(mat, max_devices: int = 32) -> tuple[np.ndarray, int]:
    """Block-sum the device block of a (d+1)x(d+1) matrix down to at most
    ``max_devices`` rows/cols (host row/col 0 stays exact).

    Returns ``(matrix, block)`` where ``block`` is the number of devices per
    aggregated row (1 when no coarsening happened).  Shared by the ASCII and
    HTML heatmap renderers so both stay screen-sized at production scale.
    Accepts the dense array or a :class:`~repro.core.sparse.
    SparseCommMatrix` (coarsened directly from its COO entries -- the
    fleet-scale path never round-trips through the dense form).
    """
    from .sparse import SparseCommMatrix
    if isinstance(mat, SparseCommMatrix):
        return mat.coarsen(max_devices)
    m = np.asarray(mat, dtype=np.float64)
    d = m.shape[0]
    if d <= max_devices + 1:
        return m, 1
    dev = m[1:, 1:]
    k = math.ceil(dev.shape[0] / max_devices)
    nb = math.ceil(dev.shape[0] / k)
    pad = nb * k - dev.shape[0]
    dev = np.pad(dev, ((0, pad), (0, pad)))
    dev = dev.reshape(nb, k, nb, k).sum(axis=(1, 3))
    hm = np.zeros((nb + 1, nb + 1))
    hm[0, 0] = m[0, 0]
    hm[1:, 1:] = dev
    hm[0, 1:] = np.pad(m[0, 1:], (0, pad)).reshape(nb, k).sum(1)
    hm[1:, 0] = np.pad(m[1:, 0], (0, pad)).reshape(nb, k).sum(1)
    return hm, k


def ascii_heatmap(mat: np.ndarray, title: str = "", log: bool = True,
                  max_devices: int = 32) -> str:
    """Render a (d+1)x(d+1) byte matrix as an ASCII heatmap.

    Row/col 0 is the host (paper convention).  For d > max_devices the matrix
    is coarsened by block-summing so the rendering stays terminal-sized.
    """
    m, block = coarsen_matrix(mat, max_devices=max_devices)
    blk = f" (device blocks of {block})" if block > 1 else ""
    v = m.copy()
    if log:
        with np.errstate(divide="ignore"):
            v = np.where(v > 0, np.log10(v), 0.0)
    vmax = v.max() if v.max() > 0 else 1.0
    lines = []
    if title or blk:
        lines.append(f"== {title}{blk} ==")
    lines.append("    " + "".join(f"{j:>2d}" for j in range(m.shape[1])))
    for i in range(m.shape[0]):
        row = "".join(
            " " + _SHADES[min(len(_SHADES) - 1, int(v[i, j] / vmax * (len(_SHADES) - 1)))]
            for j in range(m.shape[1])
        )
        lines.append(f"{i:>3d} {row}")
    lines.append(f"max cell = {human_bytes(m.max())}"
                 + (" (log scale)" if log else ""))
    return "\n".join(lines)


def matrix_to_csv(mat: np.ndarray) -> str:
    d = mat.shape[0]
    header = "," + ",".join(["host"] + [f"gpu{i}" for i in range(d - 1)])
    lines = [header]
    for i in range(d):
        name = "host" if i == 0 else f"gpu{i-1}"
        lines.append(name + "," + ",".join(f"{mat[i, j]:.0f}" for j in range(d)))
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# traced-vs-compiled diff (beyond paper)
# ---------------------------------------------------------------------------
def diff_table(traced_summary: dict, compiled_summary: dict) -> str:
    """Logical (application) vs physical (compiler) collective comparison."""
    # map HLO kinds to NCCL-ish names for alignment
    kind_to_name = {
        "all-reduce": "AllReduce",
        "all-gather": "AllGather",
        "reduce-scatter": "ReduceScatter",
        "all-to-all": "AllToAll",
        "ragged-all-to-all": "AllToAll",
        "collective-permute": "SendRecv",
        "collective-broadcast": "Broadcast",
    }
    phys: dict[str, dict] = {}
    for kind, row in compiled_summary.items():
        name = kind_to_name.get(kind, kind)
        agg = phys.setdefault(name, {"calls": 0, "payload_bytes": 0})
        agg["calls"] += row["calls"]
        agg["payload_bytes"] += row["payload_bytes"]
    names = sorted(set(traced_summary) | set(phys))
    rows = []
    for n in names:
        t = traced_summary.get(n, {"calls": 0, "payload_bytes": 0})
        p = phys.get(n, {"calls": 0, "payload_bytes": 0})
        rows.append([
            n, f"{t['calls']:,}", human_bytes(t["payload_bytes"]),
            f"{p['calls']:,}", human_bytes(p["payload_bytes"]),
        ])
    return format_table(
        rows,
        ["Primitive", "Traced Calls", "Traced Bytes",
         "Compiled Ops", "Compiled Bytes"],
    )


# ---------------------------------------------------------------------------
# JSON dump of a full report (legacy layout)
#
# Kept for external consumers of the old flat files; new code should use the
# lossless schema-v1 round-trip in repro.core.export (CommReport.save/load),
# whose output is a strict superset of this layout.
# ---------------------------------------------------------------------------
def ops_to_json(ops: Iterable[CollectiveOp]) -> list[dict]:
    return [
        {
            "kind": op.kind,
            "name": op.name,
            "shapes": [repr(s) for s in op.result_shapes],
            "payload_bytes": op.payload_bytes,
            "group_size": op.group_size,
            "num_groups": op.num_groups,
            "op_name": op.op_name,
        }
        for op in ops
    ]


def dump_report(path: str, *, summary: dict, ops: list[CollectiveOp],
                matrix: Optional[np.ndarray] = None, extra: Optional[dict] = None):
    payload = {
        "summary": summary,
        "ops": ops_to_json(ops),
    }
    if matrix is not None:
        payload["matrix"] = matrix.tolist()
    if extra:
        payload.update(extra)
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
