"""Lazy, memoized algorithm-bound views of a collective-op stream.

A :class:`CommView` owns ONE ``(algorithm, topology)`` binding of a set of
compiled ops and every artifact derived from it -- the ``(d+1)^2`` matrix,
per-primitive matrices, the Table-2/3 summary, link utilization, per-tier
collective seconds, overlap bounds, roofline inputs.  Each artifact is
computed on first access and memoized, so consumers stop threading
``algorithm=None, topo=...`` through every call: bind once, read many.

Re-binding is free until read: ``view.rebind("tree")`` shares the same op
list and recomputes nothing until an artifact is touched -- the cheap way
to compare ring vs tree vs hierarchical for one program (no recompilation,
no eager ``dataclasses.replace`` churn).

Views are produced by :meth:`repro.core.session.MonitorSession.view`
(whole-session or per-phase) and :meth:`repro.core.monitor.CommReport.view`
(including loaded/cached reports); building one directly from a plain op
list works too.
"""
from __future__ import annotations

from typing import Iterable, Optional

import numpy as np

from . import comm_matrix, cost_models, hlo_parser
from .decompose import ScheduleBatch
from .events import CollectiveOp, HostTransfer
from .sparse import SPARSE_DEVICE_THRESHOLD
from .topology import MeshTopology


def build_view(ops, num_devices: int, algorithm: str,
               topo: Optional[MeshTopology], host_transfers,
               *, phase: Optional[str], known_phases, label: str,
               sparse: Optional[bool] = None, hlo_texts=()):
    """Construct the :class:`CommView` for one ``(algorithm, phase)``
    binding -- the shared filter/validation behind both
    ``MonitorSession.view`` and ``CommReport.view`` (one implementation,
    so session and snapshot views cannot diverge).

    ``phase=None`` binds everything; a named phase filters ops and host
    transfers by their tag and must be one of ``known_phases``.
    ``sparse`` is the matrix-representation mode (None = auto by device
    count, see :class:`CommView`).  ``hlo_texts`` are the captures'
    compiled modules (one string each) -- the def-use ground truth the
    :meth:`CommView.lint` rules read; views without them still lint, on
    the schedule-only rules.
    """
    if phase is not None:
        known = list(known_phases)
        if phase not in known:
            raise KeyError(
                f"unknown phase {phase!r}; known phases: {known}")
        ops = [op for op in ops if op.phase == phase]
        host_transfers = [t for t in host_transfers if t.phase == phase]
    return CommView(ops, num_devices, algorithm=algorithm, topo=topo,
                    host_transfers=host_transfers,
                    label=f"{label}:{phase or 'all'}", sparse=sparse,
                    hlo_texts=hlo_texts)


class CommView:
    """One ``(ops, algorithm, topology)`` binding; every derived artifact
    lazy and memoized.

    The view never mutates its inputs: ``rebind`` shares the same op list
    under a different algorithm with a fresh memo, and the memoized arrays
    are handed out by reference (treat them as read-only).
    """

    def __init__(self, ops: Iterable[CollectiveOp], num_devices: int, *,
                 algorithm: str = "ring",
                 topo: Optional[MeshTopology] = None,
                 host_transfers: Iterable[HostTransfer] = (),
                 label: str = "", sparse: Optional[bool] = None,
                 hlo_texts: Iterable[str] = ()):
        cost_models.validate_algorithm(algorithm)
        self.ops = list(ops)
        self.num_devices = int(num_devices)
        self.algorithm = algorithm
        self.topo = topo
        self.host_transfers = list(host_transfers)
        self.label = label
        # compiled module text per capture -- def-use input for lint()
        self.hlo_texts = [t for t in hlo_texts if t]
        # matrix representation: True = COO SparseCommMatrix, False =
        # dense ndarray, None = auto (sparse above the device-count
        # cutover -- the dense array is O(d^2) memory)
        self.sparse = sparse
        self._memo: dict = {}

    @property
    def use_sparse(self) -> bool:
        """The resolved matrix representation for this view."""
        if self.sparse is None:
            return self.num_devices > SPARSE_DEVICE_THRESHOLD
        return bool(self.sparse)

    def __repr__(self) -> str:
        tag = f" {self.label!r}" if self.label else ""
        return (f"CommView({len(self.ops)} ops, {self.num_devices} devices, "
                f"algorithm={self.algorithm!r}{tag})")

    def _cached(self, key: str, build):
        if key not in self._memo:
            self._memo[key] = build()
        return self._memo[key]

    def rebind(self, algorithm: str) -> "CommView":
        """Same ops/topology under another algorithm (fresh memo, no
        recompilation -- compilation never depended on the algorithm)."""
        if algorithm == self.algorithm:
            return self
        return CommView(self.ops, self.num_devices, algorithm=algorithm,
                        topo=self.topo, host_transfers=self.host_transfers,
                        label=self.label, sparse=self.sparse,
                        hlo_texts=self.hlo_texts)

    # -- byte accounting ---------------------------------------------------
    @property
    def matrix(self):
        """``(d+1)^2`` bytes-sent matrix (host transfers in row/col 0).

        A dense ``np.ndarray`` or, when :attr:`use_sparse` resolves true,
        the byte-identical COO :class:`~repro.core.sparse.
        SparseCommMatrix` -- every downstream consumer (link projection,
        heatmaps, exporters) accepts both.
        """
        def build():
            mat = comm_matrix.matrix_for_schedules(
                self.ops, self.schedule_batch(), self.num_devices,
                sparse=self.use_sparse)
            if self.host_transfers:
                comm_matrix.add_host_transfers(mat, self.host_transfers)
            return mat
        return self._cached("matrix", build)

    @property
    def per_primitive(self) -> dict:
        """Paper Fig. 3: one matrix per collective primitive."""
        def build():
            return {k: comm_matrix.matrix_for_schedules(
                        self.ops, self.schedule_batch(), self.num_devices,
                        kinds={k}, sparse=self.use_sparse)
                    for k in sorted({op.kind for op in self.ops})}
        return self._cached("per_primitive", build)

    @property
    def summary(self) -> dict:
        """Paper Table-2/3 per-kind calls / payload / wire bytes."""
        return self._cached("summary", lambda: hlo_parser.summarize(
            self.ops, self.algorithm, topo=self.topo))

    def total_wire_bytes(self) -> float:
        """Global bytes-on-the-wire across all devices."""
        return self._cached("total_wire_bytes", lambda: (
            hlo_parser.total_wire_bytes(self.ops, self.algorithm,
                                        topo=self.topo)))

    # -- decomposition schedules -------------------------------------------
    def schedule_batch(self) -> ScheduleBatch:
        """The columnar :class:`~repro.core.decompose.ScheduleBatch` over
        this binding's ops -- deduped by op signature (``decompose`` runs
        once per *distinct shape*, not once per op), memoized, and shared
        by every derived artifact: :attr:`matrix` / :attr:`per_primitive`
        reuse its per-schedule edge cache, the time models read its flat
        phase columns, the Perfetto exporter slices its per-op phase
        seconds.  Built with fallback warnings on, like the placement
        always warned."""
        return self._cached("schedule_batch", lambda: (
            ScheduleBatch.from_ops(self.ops, self.algorithm, self.topo,
                                   warn=True)))

    def schedules(self) -> list:
        """One :class:`~repro.core.decompose.CollectiveSchedule` per op
        (aligned with ``self.ops``; ops sharing a signature share one
        schedule object) -- the phase IR every derived artifact reads."""
        return self.schedule_batch().schedules

    def schedule_summaries(self) -> list[dict]:
        """Serializable per-op schedule summaries (schema-v5 section)."""
        return [sched.summary() for sched in self.schedules()]

    # -- time models -------------------------------------------------------
    def collective_seconds(self) -> float:
        """Serialized collective time (0.0 without a topology)."""
        ici, dcn = self.collective_seconds_split()
        return ici + dcn

    def collective_seconds_split(self) -> tuple[float, float]:
        """Per-tier serialized collective time ``(ici_s, dcn_s)``,
        execution-weighted, summed over the memoized schedules."""
        def build():
            if self.topo is None:
                return 0.0, 0.0
            return self.schedule_batch().total_time_split(self.topo)
        return self._cached("seconds_split", build)

    def collective_overlap_seconds(self) -> float:
        """Tier-overlapped communication time: ``max(ici_s, dcn_s)``."""
        return max(self.collective_seconds_split())

    def op_seconds(self) -> list:
        """Modeled seconds per op (aligned with ``self.ops``): each entry
        is the op's serialized schedule time -- ``sum(time_split)`` --
        times its execution weight.  ``None`` entries without a topology
        (no time model); the compare layer matches these against the
        measured ``op.measured_s`` values a trace import carries."""
        def build():
            if self.topo is None:
                return [None] * len(self.ops)
            batch = self.schedule_batch()
            ici, dcn = batch.time_split_per_op(self.topo)
            return ((ici + dcn) * batch.weight).tolist()
        return self._cached("op_seconds", build)

    def measured_seconds(self):
        """Total measured wall seconds over ops carrying ``measured_s``
        (trace imports, schema v9); ``None`` when no op is measured."""
        vals = [op.measured_s for op in self.ops
                if op.measured_s is not None]
        return float(sum(vals)) if vals else None

    # -- physical-link view ------------------------------------------------
    def link_utilization(self):
        """Per-physical-link byte counts (None without a topology)."""
        def build():
            if self.topo is None:
                return None
            return comm_matrix.project_links(self.matrix, self.topo)
        return self._cached("link_utilization", build)

    def link_matrix(self):
        lu = self.link_utilization()
        return None if lu is None else lu.matrix()

    def link_seconds(self) -> float:
        """Contention-aware bound: the bottleneck link's bytes/bandwidth."""
        lu = self.link_utilization()
        return 0.0 if lu is None else lu.bottleneck_seconds()

    # -- static lint ---------------------------------------------------------
    def lint(self) -> list:
        """Static anti-pattern findings for this binding (lazy, memoized
        like every other artifact): a list of
        :class:`~repro.core.lint.LintFinding`, errors first, then by
        modeled savings.  HLO def-use rules run only when the view carries
        :attr:`hlo_texts`; schedule rules always run (savings are zero
        without a topology)."""
        from .lint import lint_ops   # deferred: lint imports decompose

        return self._cached("lint", lambda: lint_ops(
            self.ops, topo=self.topo, algorithm=self.algorithm,
            hlo_texts=self.hlo_texts))
