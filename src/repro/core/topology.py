"""TPU interconnect topology model, down to individual physical links.

The paper models NCCL traffic on NVSwitch/NVLink/PCIe; the TPU analogue is the
ICI torus inside a pod plus DCN between pods.  We model:

* a pod as a torus of chips (v5e: 16x16 = 256), each chip with 2 ICI links
  per torus axis (bidirectional ring per row/column),
* multi-pod meshes as torus pods joined by DCN (per-chip share of pod-level
  DCN bandwidth),
* the **physical links themselves**: every directed ICI neighbour link per
  torus axis and every per-chip DCN uplink/downlink is enumerable
  (:meth:`MeshTopology.links`) and routable (:meth:`MeshTopology.route`), so
  a logical communication matrix can be projected onto the links that
  actually carry the bytes (:func:`repro.core.comm_matrix.project_links`),
* hardware constants used by the roofline (given for TPU v5e-class chips).
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    name: str = "tpu-v5e"
    peak_flops_bf16: float = 197e12      # FLOP/s per chip
    hbm_bw: float = 819e9                # bytes/s per chip
    ici_bw: float = 50e9                 # bytes/s per link, per direction
    ici_links_per_axis: int = 2          # bidirectional ring: +1/-1 neighbours
    dcn_bw_per_chip: float = 6.25e9      # bytes/s per chip across pods
    hbm_per_chip: int = 16 * 1024**3     # bytes
    # per-hop latency terms (small-payload regime): one ICI neighbour hop
    # vs one DCN exchange -- charged per schedule-phase ``latency_hops`` by
    # ``cost_models.collective_time_split``
    ici_hop_latency_s: float = 1e-6      # seconds per ICI ring hop
    dcn_hop_latency_s: float = 25e-6     # seconds per cross-pod DCN hop


V5E = HardwareSpec()

# sentinel device id for the inter-pod DCN fabric endpoint of a link
DCN_FABRIC = -1


@dataclasses.dataclass(frozen=True)
class Link:
    """One directed physical link.

    * ``kind == "ici"``: a torus neighbour link ``src -> dst`` along mesh
      axis ``axis`` (each chip has one per direction per axis).
    * ``kind == "dcn"``: a chip's share of the pod DCN connectivity.  The
      uplink is ``src=device, dst=DCN_FABRIC``; the downlink is
      ``src=DCN_FABRIC, dst=device``.  Cross-pod traffic is charged to the
      sender's uplink and the receiver's downlink (the fabric core is
      assumed non-blocking, so the chip shares are the contended resource).
    """

    kind: str                    # "ici" | "dcn"
    src: int                     # sending device, or DCN_FABRIC
    dst: int                     # receiving device, or DCN_FABRIC
    axis: str                    # torus axis name for ici; "dcn" otherwise

    @property
    def name(self) -> str:
        if self.kind == "dcn":
            if self.dst == DCN_FABRIC:
                return f"dcn:d{self.src}^"      # uplink
            return f"dcn:vd{self.dst}"          # downlink
        return f"ici:{self.axis}:d{self.src}>d{self.dst}"


@dataclasses.dataclass
class MeshTopology:
    """Logical mesh axes mapped onto the physical torus.

    ``axis_names``/``axis_sizes`` follow the jax mesh.  Axes named "pod" (or
    listed in ``dcn_axes``) cross DCN; all other axes ride ICI.
    """

    axis_names: tuple[str, ...]
    axis_sizes: tuple[int, ...]
    hw: HardwareSpec = V5E
    dcn_axes: tuple[str, ...] = ("pod",)

    @classmethod
    def from_mesh(cls, mesh, hw: HardwareSpec = V5E, dcn_axes=("pod",)):
        return cls(
            axis_names=tuple(mesh.axis_names),
            axis_sizes=tuple(mesh.devices.shape),
            hw=hw,
            dcn_axes=tuple(dcn_axes),
        )

    @classmethod
    def fleet(cls, num_devices: int, pod_side: int = 16,
              hw: HardwareSpec = V5E) -> "MeshTopology":
        """Synthetic fleet topology for scale curves (``sweep
        --scale-curve``): up to ``pod_side**2`` devices is one 2D torus pod
        (squarest ``data x model`` factorization); beyond that, full
        ``pod_side x pod_side`` pods joined by a DCN ``pod`` axis --
        16384 devices is ``(64, 16, 16)`` over ``(pod, data, model)``.

        No jax mesh exists at these device counts; this is the pure
        topology model the sparse matrix/link path is projected onto.
        """
        if num_devices < 1:
            raise ValueError(f"num_devices must be >= 1, got {num_devices}")
        pod = pod_side * pod_side
        if num_devices <= pod:
            side = max(1, math.isqrt(num_devices))
            while num_devices % side:
                side -= 1
            return cls(axis_names=("data", "model"),
                       axis_sizes=(num_devices // side, side), hw=hw)
        if num_devices % pod:
            raise ValueError(
                f"multi-pod fleet sizes must be multiples of {pod} "
                f"({pod_side}x{pod_side} pods), got {num_devices}")
        return cls(axis_names=("pod", "data", "model"),
                   axis_sizes=(num_devices // pod, pod_side, pod_side),
                   hw=hw)

    @property
    def num_devices(self) -> int:
        return int(math.prod(self.axis_sizes))

    @property
    def devices_per_pod(self) -> int:
        n = self.num_devices
        for name, size in zip(self.axis_names, self.axis_sizes):
            if name in self.dcn_axes:
                n //= size
        return n

    @property
    def num_pods(self) -> int:
        return self.num_devices // self.devices_per_pod

    def axis_size(self, name: str) -> int:
        return self.axis_sizes[self.axis_names.index(name)]

    def is_dcn_axis(self, name: str) -> bool:
        return name in self.dcn_axes

    # ------------------------------------------------------------------
    # Bandwidth available to one chip for a collective along a set of devices.
    # A ring along an ICI mesh axis uses both directions of that axis' links.
    # ------------------------------------------------------------------
    def ring_bw_per_chip(self, crosses_dcn: bool) -> float:
        if crosses_dcn:
            return self.hw.dcn_bw_per_chip
        return self.hw.ici_bw * self.hw.ici_links_per_axis

    def group_crosses_dcn(self, group: list[int]) -> bool:
        """Does a replica group (global device ids) span multiple pods?

        Device ids enumerate the mesh in row-major order of ``axis_sizes``
        (jax ``make_mesh`` convention), so a group crosses DCN iff members
        differ in their coordinate on a DCN axis.
        """
        if self.num_pods == 1 or not group:
            return False
        pod_of = [self._pod_index(d) for d in group]
        return len(set(pod_of)) > 1

    def pod_partition(self, group: list[int]) -> list[list[int]]:
        """Split a replica group into per-pod subgroups (member order kept).

        The hierarchical all-reduce placement and cost model both decompose
        a cross-DCN group this way: ring phases inside each subgroup, a
        cross-pod exchange between same-index members of the subgroups.
        """
        by_pod: dict[int, list[int]] = {}
        for d in group:
            by_pod.setdefault(self._pod_index(d), []).append(d)
        return [by_pod[k] for k in sorted(by_pod)]

    def _pod_index(self, device: int) -> int:
        coords = []
        rem = device
        for size in reversed(self.axis_sizes):
            coords.append(rem % size)
            rem //= size
        coords.reverse()
        pod = 0
        for name, c in zip(self.axis_names, coords):
            if name in self.dcn_axes:
                pod = pod * self.axis_size(name) + c
        return pod

    def coords(self, device: int) -> tuple[int, ...]:
        coords = []
        rem = device
        for size in reversed(self.axis_sizes):
            coords.append(rem % size)
            rem //= size
        return tuple(reversed(coords))

    # ------------------------------------------------------------------
    # Physical links: enumeration and routing.
    # ------------------------------------------------------------------
    @property
    def ici_axes(self) -> tuple[str, ...]:
        """Torus axes (size > 1) that ride ICI, in mesh-axis order."""
        return tuple(n for n, s in zip(self.axis_names, self.axis_sizes)
                     if n not in self.dcn_axes and s > 1)

    def device_at(self, coords) -> int:
        device = 0
        for size, c in zip(self.axis_sizes, coords):
            device = device * size + (c % size)
        return device

    def neighbor(self, device: int, axis: str, step: int = 1) -> int:
        """Torus neighbour of ``device`` ``step`` hops along ``axis``."""
        i = self.axis_names.index(axis)
        coords = list(self.coords(device))
        coords[i] = (coords[i] + step) % self.axis_sizes[i]
        return self.device_at(coords)

    def pod_index(self, device: int) -> int:
        """Which pod (DCN tier) a device belongs to."""
        return self._pod_index(device)

    def links(self) -> list[Link]:
        """Every physical link: directed ICI neighbour links per torus axis
        plus, on multi-pod meshes, each chip's DCN uplink and downlink.

        A size-2 torus axis wraps both directions onto the same neighbour;
        the two physical cables collapse into one directed link per
        (src, dst) pair here, matching how traffic is charged in
        :meth:`route` (which emits exactly one hop for that neighbour).
        :meth:`link_multiplicity` records the 2 aggregated cables and
        :meth:`link_bandwidth` credits both, so the collapse never halves
        the pair's real capacity.
        """
        out: list[Link] = []
        seen: set[tuple] = set()
        for d in range(self.num_devices):
            for axis in self.ici_axes:
                for step in (1, -1):
                    nb = self.neighbor(d, axis, step)
                    key = ("ici", d, nb, axis)
                    if nb != d and key not in seen:
                        seen.add(key)
                        out.append(Link("ici", d, nb, axis))
        if self.num_pods > 1:
            for d in range(self.num_devices):
                out.append(Link("dcn", d, DCN_FABRIC, "dcn"))
                out.append(Link("dcn", DCN_FABRIC, d, "dcn"))
        return out

    def link_multiplicity(self, link: Link) -> int:
        """Physical cables aggregated into this directed :class:`Link`.

        1 for every link except an ICI link on a size-2 torus axis, where
        the +1 and -1 cables reach the *same* neighbour and collapse into
        one enumerated link carrying both cables' bandwidth.
        """
        if link.kind == "ici" and self.axis_size(link.axis) == 2:
            return self.hw.ici_links_per_axis
        return 1

    def link_bandwidth(self, link: Link) -> float:
        """Bytes/s one direction of this physical link sustains (both
        aggregated cables on a collapsed size-2 axis, see
        :meth:`link_multiplicity`)."""
        if link.kind == "dcn":
            return self.hw.dcn_bw_per_chip
        return self.hw.ici_bw * self.link_multiplicity(link)

    def torus_distance(self, src: int, dst: int) -> int:
        """Minimal ICI hop count between two same-pod devices: the sum over
        torus axes of the shorter way around each ring (wrap-aware)."""
        src_coords = self.coords(src)
        dst_coords = self.coords(dst)
        hops = 0
        for i, axis in enumerate(self.axis_names):
            size = self.axis_sizes[i]
            if axis in self.dcn_axes or size <= 1:
                continue
            delta = (dst_coords[i] - src_coords[i]) % size
            hops += min(delta, size - delta)
        return hops

    def route(self, src: int, dst: int) -> list[Link]:
        """Physical links a ``src -> dst`` transfer traverses.

        Within a pod: dimension-ordered torus routing, wrap-aware -- each
        axis takes the shorter way around its ring (ties at exactly half
        way go +1), so ``len(route(a, b)) == torus_distance(a, b)``.  On a
        size-2 axis both directions are the same single hop onto the
        collapsed neighbour link -- never two distinct hops.  Across pods:
        the sender's DCN uplink plus the receiver's DCN downlink (inter-pod
        traffic does not detour over ICI in this model).  Every emitted
        link is one of :meth:`links` -- :func:`repro.core.comm_matrix.
        project_links` enforces this.
        """
        if src == dst:
            return []
        if self._pod_index(src) != self._pod_index(dst):
            return [Link("dcn", src, DCN_FABRIC, "dcn"),
                    Link("dcn", DCN_FABRIC, dst, "dcn")]
        hops: list[Link] = []
        cur = src
        cur_coords = list(self.coords(src))
        dst_coords = self.coords(dst)
        for i, axis in enumerate(self.axis_names):
            size = self.axis_sizes[i]
            if axis in self.dcn_axes or size <= 1:
                continue
            delta = (dst_coords[i] - cur_coords[i]) % size
            step = 1 if delta <= size - delta else -1
            while cur_coords[i] != dst_coords[i]:
                nxt = self.neighbor(cur, axis, step)
                hops.append(Link("ici", cur, nxt, axis))
                cur = nxt
                cur_coords[i] = (cur_coords[i] + step) % size
        return hops
