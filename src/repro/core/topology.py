"""TPU interconnect topology model.

The paper models NCCL traffic on NVSwitch/NVLink/PCIe; the TPU analogue is the
ICI torus inside a pod plus DCN between pods.  We model:

* a pod as a 2-D torus of chips (v5e: 16x16 = 256), each chip with 2 ICI links
  per torus axis (bidirectional ring per row/column),
* multi-pod meshes as torus pods joined by DCN (per-chip share of pod-level
  DCN bandwidth),
* hardware constants used by the roofline (given for TPU v5e-class chips).
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    name: str = "tpu-v5e"
    peak_flops_bf16: float = 197e12      # FLOP/s per chip
    hbm_bw: float = 819e9                # bytes/s per chip
    ici_bw: float = 50e9                 # bytes/s per link, per direction
    ici_links_per_axis: int = 2          # bidirectional ring: +1/-1 neighbours
    dcn_bw_per_chip: float = 6.25e9      # bytes/s per chip across pods
    hbm_per_chip: int = 16 * 1024**3     # bytes


V5E = HardwareSpec()


@dataclasses.dataclass
class MeshTopology:
    """Logical mesh axes mapped onto the physical torus.

    ``axis_names``/``axis_sizes`` follow the jax mesh.  Axes named "pod" (or
    listed in ``dcn_axes``) cross DCN; all other axes ride ICI.
    """

    axis_names: tuple[str, ...]
    axis_sizes: tuple[int, ...]
    hw: HardwareSpec = V5E
    dcn_axes: tuple[str, ...] = ("pod",)

    @classmethod
    def from_mesh(cls, mesh, hw: HardwareSpec = V5E, dcn_axes=("pod",)):
        return cls(
            axis_names=tuple(mesh.axis_names),
            axis_sizes=tuple(mesh.devices.shape),
            hw=hw,
            dcn_axes=tuple(dcn_axes),
        )

    @property
    def num_devices(self) -> int:
        return int(math.prod(self.axis_sizes))

    @property
    def devices_per_pod(self) -> int:
        n = self.num_devices
        for name, size in zip(self.axis_names, self.axis_sizes):
            if name in self.dcn_axes:
                n //= size
        return n

    @property
    def num_pods(self) -> int:
        return self.num_devices // self.devices_per_pod

    def axis_size(self, name: str) -> int:
        return self.axis_sizes[self.axis_names.index(name)]

    def is_dcn_axis(self, name: str) -> bool:
        return name in self.dcn_axes

    # ------------------------------------------------------------------
    # Bandwidth available to one chip for a collective along a set of devices.
    # A ring along an ICI mesh axis uses both directions of that axis' links.
    # ------------------------------------------------------------------
    def ring_bw_per_chip(self, crosses_dcn: bool) -> float:
        if crosses_dcn:
            return self.hw.dcn_bw_per_chip
        return self.hw.ici_bw * self.hw.ici_links_per_axis

    def group_crosses_dcn(self, group: list[int]) -> bool:
        """Does a replica group (global device ids) span multiple pods?

        Device ids enumerate the mesh in row-major order of ``axis_sizes``
        (jax ``make_mesh`` convention), so a group crosses DCN iff members
        differ in their coordinate on a DCN axis.
        """
        if self.num_pods == 1 or not group:
            return False
        pod_of = [self._pod_index(d) for d in group]
        return len(set(pod_of)) > 1

    def _pod_index(self, device: int) -> int:
        coords = []
        rem = device
        for size in reversed(self.axis_sizes):
            coords.append(rem % size)
            rem //= size
        coords.reverse()
        pod = 0
        for name, c in zip(self.axis_names, coords):
            if name in self.dcn_axes:
                pod = pod * self.axis_size(name) + c
        return pod

    def coords(self, device: int) -> tuple[int, ...]:
        coords = []
        rem = device
        for size in reversed(self.axis_sizes):
            coords.append(rem % size)
            rem //= size
        return tuple(reversed(coords))
