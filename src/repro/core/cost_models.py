"""Algorithm-aware data-movement models (paper Table 1, adapted to TPU).

The paper's central quantitative artifact is Table 1: the bytes a rank sends/
receives for an AllReduce of payload ``S`` over ``N`` ranks depends on the
algorithm NCCL picked (ring / tree / collnet).  XLA's TPU collectives have the
same structure; the TPU-native algorithm menu is:

* ``ring``         -- bandwidth-optimal ring per torus axis (XLA default for
                      large payloads; NCCL-ring analogue).
* ``tree``         -- binary reduce/broadcast tree, logarithmic latency (small
                      payloads; NCCL-tree analogue).
* ``hierarchical`` -- phase decomposition across the pod boundary (the
                      collnet/SHARP analogue), per kind: all-reduce does
                      reduce-scatter + all-gather rings inside the pod over
                      ICI with a cross-pod ring all-reduce of the ``S/m``
                      shard over DCN; all-gather / reduce-scatter / broadcast
                      do their shard exchange across the ``p`` same-index
                      members over DCN and the full-payload ring phase inside
                      the pod over ICI (only ``(p-1)/n`` of S per rank ever
                      crosses the slow tier).  With ``pods=1`` (no DCN tier)
                      every entry degenerates exactly to ``ring``.

``wire_bytes_per_rank`` reproduces the Table-1 entries; ``collective_time``
(= the sum of ``collective_time_split``'s per-tier terms) turns them into
seconds on a :class:`~repro.core.topology.MeshTopology`, honouring the
*requested* algorithm even when the group spans DCN (a ring all-reduce
across pods pays its full per-rank payload at the per-chip DCN share -- it
is never silently rebilled as hierarchical).
:func:`hierarchical_decomposition` is the ONE predicate deciding whether a
(kind, group, topology) triple decomposes hierarchically -- matrix placement
and billing both go through it, so they cannot diverge.
``device_send_bytes`` resolves the per-rank entries down to each device's
role (tree roots/leaves send different amounts), and is the contract the
communication-matrix row sums are tested against.  ``contention_time``
projects the matrix onto physical links and takes the bottleneck link.
"""
from __future__ import annotations

import math
from typing import Iterable, Optional

from .events import CollectiveOp
from .topology import MeshTopology

ALGORITHMS = ("ring", "tree", "hierarchical")


def validate_algorithm(algorithm: str) -> str:
    """Reject unknown collective algorithms with a clear error.

    Every public entry point that accepts an ``algorithm`` string
    (``monitor_fn``, ``MonitorSession``, ``CommView``, ``matrix_for_ops``,
    the sweep engine / CLI) funnels through here, so a typo like
    ``"treee"`` raises immediately instead of silently falling through to
    ring edge placement.  Returns the validated name for call-through use.
    """
    if algorithm not in ALGORITHMS:
        raise ValueError(
            f"unknown algorithm {algorithm!r}; known: {ALGORITHMS}")
    return algorithm


# Kinds the hierarchical algorithm knows how to decompose across pods.
HIERARCHICAL_KINDS = ("all-reduce", "all-gather", "reduce-scatter",
                      "collective-broadcast")


def _hier_split(n: int, pods: int) -> tuple[int, int]:
    """(pods, in_pod) for a hierarchical decomposition of an ``n``-rank group.

    Degenerates to ``(1, n)`` when the group does not split evenly across
    pods (or there is no DCN tier), which makes hierarchical == ring.
    """
    p = max(1, int(pods))
    if p <= 1 or n % p != 0 or n // p < 1:
        return 1, n
    return p, n // p


def hierarchical_decomposition(
        kind: str, group: list[int],
        topo: Optional[MeshTopology]) -> Optional[
            tuple[int, int, list[list[int]]]]:
    """``(p, m, subgroups)`` when ``kind`` over ``group`` decomposes
    hierarchically.

    The single shared predicate between matrix placement
    (:func:`repro.core.comm_matrix.op_edges`) and billing
    (:func:`collective_time_split`): a group decomposes iff the kind is one
    of :data:`HIERARCHICAL_KINDS`, the group spans more than one pod, and
    the pods partition it into equal-size subgroups.  ``None`` otherwise --
    both callers then fall back to the flat ring model together.  The
    per-pod subgroups ride along so callers never recompute the partition.
    """
    if topo is None or kind not in HIERARCHICAL_KINDS or not group:
        return None
    if not topo.group_crosses_dcn(group):
        return None
    subs = topo.pod_partition(group)
    p, n = len(subs), len(group)
    if p <= 1 or n % p != 0 or any(len(sub) != n // p for sub in subs):
        return None
    return p, n // p, subs


def effective_pods(kind: str, group: list[int],
                   topo: Optional[MeshTopology]) -> int:
    """``pods`` argument for the Table-1 entries: the decomposition's ``p``
    when :func:`hierarchical_decomposition` accepts the triple, else 1 (so
    hierarchical degenerates to ring exactly where the placement does)."""
    dec = hierarchical_decomposition(kind, group, topo)
    return dec[0] if dec is not None else 1


def hier_phases(kind: str) -> float:
    """Ring phases per tier: all-reduce = RS + AG (2), the one-phase kinds
    (all-gather / reduce-scatter / scatter-allgather broadcast) = 1.
    Part of the shared placement/billing contract alongside
    :data:`HIERARCHICAL_KINDS` and :func:`hierarchical_decomposition`."""
    return 2.0 if kind == "all-reduce" else 1.0


def wire_bytes_per_rank(kind: str, payload: float, n: int,
                        algorithm: str = "ring", *, pods: int = 1) -> float:
    """Bytes *sent* by one rank for one collective (paper Table 1 analogue).

    ``payload`` is S (the full logical payload per group), ``n`` the group
    size.  ``pods`` is the number of DCN tiers the group spans -- every
    hierarchical entry in :data:`HIERARCHICAL_KINDS` depends on it.  Pass
    :func:`effective_pods` for ``pods`` so a group the placement cannot
    decompose degenerates here too.  Receives mirror sends for all entries
    below (symmetric algorithms), matching the paper's "sent and received"
    accounting.  Tree entries report the non-root (dominant) cost;
    ``device_send_bytes`` resolves per-role amounts.

    Hierarchical per-rank entries (``m = n/pods`` in-pod ranks, ``p = pods``):

    ========================  =====================  ====================
    kind                      intra-pod (ICI)        cross-pod (DCN)
    ========================  =====================  ====================
    all-reduce                ``2(m-1)/m * S``       ``2(p-1)/n * S``
    all-gather                ``(m-1)/m * S``        ``(p-1)/n * S``
    reduce-scatter            ``(m-1)/m * S``        ``(p-1)/n * S``
    collective-broadcast      ``(m-1)/m * S``        ``(p-1)/n * S``
    ========================  =====================  ====================

    All-reduce is RS+AG rings in pod plus a cross-pod ring all-reduce of
    the ``S/m`` shard; the one-phase kinds exchange their ``S/n`` shards
    across the ``p`` same-index members over DCN and run the full-payload
    ring phase inside the pod (broadcast is the scatter-allgather form, the
    same convention the ring entry already uses).  Each entry degenerates
    exactly to its ring value at ``p = 1``.
    """
    if n <= 1:
        return 0.0
    s = float(payload)
    validate_algorithm(algorithm)

    if kind == "all-reduce":
        if algorithm == "ring":
            # reduce-scatter ring + all-gather ring
            return 2.0 * (n - 1) * s / n
        if algorithm == "tree":
            # double binary tree: non-root sends S up + S down (pipelined);
            # paper: root S, others 2S.  Report the non-root (dominant) cost.
            return 2.0 * s
        # hierarchical: RS ring over the in-pod ranks (2*(m-1)/m * S total
        # for RS+AG) + cross-pod ring all-reduce of the S/m shard over pods
        p, m = _hier_split(n, pods)
        intra = 2.0 * (m - 1) * s / m if m > 1 else 0.0
        cross = 2.0 * (p - 1) * s / n if p > 1 else 0.0
        return intra + cross
    if kind in ("all-gather", "reduce-scatter", "collective-broadcast"):
        # ring: each rank forwards (n-1) shards of size S/n around the ring.
        # hierarchical: cross-pod shard exchange among the p same-index
        # members ((p-1)/n * S over DCN) + full-payload ring phase inside
        # the pod ((m-1)/m * S over ICI); total bytes stay minimal.
        if algorithm == "hierarchical":
            p, m = _hier_split(n, pods)
            intra = (m - 1) * s / m if m > 1 else 0.0
            cross = (p - 1) * s / n if p > 1 else 0.0
            return intra + cross
        return (n - 1) * s / n
    if kind in ("all-to-all", "ragged-all-to-all"):
        # each rank sends (n-1) of its n blocks; block = S/n^2 of global S
        return (n - 1) * s / (n * n)
    if kind == "collective-permute":
        return s
    return s


def wire_bytes_received_per_rank(kind: str, payload: float, n: int,
                                 algorithm: str = "ring", *,
                                 pods: int = 1) -> float:
    return wire_bytes_per_rank(kind, payload, n, algorithm, pods=pods)


def wire_bytes_group_total(kind: str, payload: float, n: int,
                           algorithm: str = "ring", *, pods: int = 1) -> float:
    """Bytes on the wire summed over every rank of ONE group.

    For the symmetric (ring, hierarchical) entries this is
    ``n * wire_bytes_per_rank``; tree entries sum the true per-role amounts
    (a binary tree all-reduce moves ``2*(n-1)*S`` total: S up and S down
    each of its ``n-1`` edges), so matrices, summaries and cost models all
    agree on the same totals.
    """
    if n <= 1:
        return 0.0
    s = float(payload)
    if algorithm == "tree":
        if kind == "all-reduce":
            return 2.0 * (n - 1) * s
        if kind in ("all-gather", "reduce-scatter", "collective-broadcast"):
            # up + down phases move (n-1)*S in aggregate, same as the ring
            return (n - 1) * s
    return n * wire_bytes_per_rank(kind, s, n, algorithm, pods=pods)


# ----------------------------------------------------------------------------
# Binary-tree structure (heap layout over group positions) -- shared contract
# between the per-device byte model below and the matrix edge placement in
# comm_matrix.py.
# ----------------------------------------------------------------------------
def tree_children(i: int, n: int) -> list[int]:
    """Children of position ``i`` in the implicit binary tree over ``n``."""
    return [c for c in (2 * i + 1, 2 * i + 2) if c < n]


def tree_subtree_sizes(n: int) -> list[int]:
    """Subtree size per position of the implicit binary tree over ``n``."""
    sizes = [1] * n
    for i in range(n - 1, 0, -1):
        sizes[(i - 1) // 2] += sizes[i]
    return sizes


def device_send_bytes(kind: str, payload: float, group: list[int],
                      algorithm: str = "ring",
                      topo: Optional[MeshTopology] = None) -> dict[int, float]:
    """Bytes each device of ``group`` sends for one collective execution.

    This is the per-role resolution of :func:`wire_bytes_per_rank` -- the
    matrix/model consistency contract: ``matrix_for_ops`` row sums must
    equal these values (times the op weight).  Ring and hierarchical
    placements are symmetric (every rank sends the Table-1 per-rank
    amount); tree placements depend on the device's position (root sends S
    per child, a leaf sends S up and nothing down).
    """
    n = len(group)
    if n <= 1:
        return {d: 0.0 for d in group}
    s = float(payload)
    if algorithm == "tree" and kind in ("all-reduce", "all-gather",
                                        "reduce-scatter",
                                        "collective-broadcast"):
        sizes = tree_subtree_sizes(n)
        out: dict[int, float] = {}
        for i, d in enumerate(group):
            kids = tree_children(i, n)
            up = s if i > 0 else 0.0                      # reduce phase
            down = s * len(kids)                          # broadcast phase
            if kind == "all-reduce":
                sent = up + down
            elif kind == "collective-broadcast":
                sent = down
            elif kind == "all-gather":
                # up: my subtree's shards; down: everything a child lacks
                sent = (sizes[i] * s / n if i > 0 else 0.0) \
                    + sum((n - sizes[c]) * s / n for c in kids)
            else:  # reduce-scatter == time-reversed all-gather
                sent = ((n - sizes[i]) * s / n if i > 0 else 0.0) \
                    + sum(sizes[c] * s / n for c in kids)
            out[d] = sent
        return out
    per_rank = wire_bytes_per_rank(kind, s, n, algorithm,
                                   pods=effective_pods(kind, group, topo))
    return {d: per_rank for d in group}


def _group_time_split(kind: str, s: float, group: list[int], n: int,
                      topo: MeshTopology,
                      algorithm: str) -> tuple[float, float]:
    """``(ici_seconds, dcn_seconds)`` for ONE replica group."""
    if n <= 1:
        return 0.0, 0.0
    crosses = topo.group_crosses_dcn(group)

    if not crosses:
        per_rank = wire_bytes_per_rank(kind, s, n, algorithm)
        return per_rank / topo.ring_bw_per_chip(False), 0.0

    if algorithm == "hierarchical":
        dec = hierarchical_decomposition(kind, group, topo)
        if dec is not None:
            p, m, _ = dec
            phases = hier_phases(kind)
            intra = (phases * (m - 1) * s / m) / topo.ring_bw_per_chip(False) \
                if m > 1 else 0.0
            cross = (phases * (p - 1) * s / n) / topo.ring_bw_per_chip(True) \
                if p > 1 else 0.0
            return intra, cross
        # refusal: bill the flat ring fallback the placement also uses
        # (pods=1 degenerates every hierarchical Table-1 entry to ring)
        per_rank = wire_bytes_per_rank(kind, s, n, algorithm, pods=1)
        return 0.0, per_rank / topo.ring_bw_per_chip(True)

    per_rank = wire_bytes_per_rank(kind, s, n, algorithm)
    return 0.0, per_rank / topo.ring_bw_per_chip(True)


def collective_time_split(op: CollectiveOp, topo: MeshTopology,
                          algorithm: str = "ring") -> tuple[float, float]:
    """``(ici_seconds, dcn_seconds)`` for one collective (bandwidth terms).

    The per-tier resolution of :func:`collective_time`, decided **per
    replica group** with the same shared predicate the matrix placement
    uses (groups occupy disjoint devices and run concurrently, so each
    tier's time is the max over groups).  The *requested* algorithm is
    honoured:

    * intra-pod groups stream the per-rank bytes at the per-chip ring
      bandwidth (both directions of the axis links) -- pure ICI time;
    * a **hierarchical** group across pods that
      :func:`hierarchical_decomposition` accepts pays its intra-pod ring
      phases over ICI and only the shard exchange over DCN (per-kind
      entries in the :func:`wire_bytes_per_rank` table);
    * a hierarchical request the predicate *refuses* (uneven pod split,
      or a kind outside :data:`HIERARCHICAL_KINDS`) is billed exactly like
      the placement's fallback -- flat ring edges crossing DCN at the
      per-chip DCN share -- never as a phantom decomposition;
    * a **ring or tree** group spanning pods has ring/tree edges crossing
      DCN, so its full per-rank payload streams at the per-chip DCN share
      -- it is NOT silently rebilled as hierarchical (that would
      contradict the matrix's edge placement).
    """
    s = float(op.payload_bytes)
    groups = [g for g in (op.replica_groups or []) if len(g) > 1]
    if not groups:
        # pair-form ops (collective-permute) carry no replica groups
        return _group_time_split(op.kind, s, [], op.group_size, topo,
                                 algorithm)
    ici = dcn = 0.0
    for g in groups:
        i, d = _group_time_split(op.kind, s, g, len(g), topo, algorithm)
        ici = max(ici, i)
        dcn = max(dcn, d)
    return ici, dcn


def collective_time(op: CollectiveOp, topo: MeshTopology,
                    algorithm: str = "ring") -> float:
    """Seconds for one collective on the torus: the serialized sum of the
    per-tier terms of :func:`collective_time_split`."""
    ici, dcn = collective_time_split(op, topo, algorithm)
    return ici + dcn


def total_time(ops: Iterable[CollectiveOp], topo: MeshTopology,
               algorithm: str = "ring") -> float:
    """Serialized collective time (no overlap) -- upper bound / roofline term.

    Execution-weighted: an op inside a while body contributes once per trip.
    """
    return float(sum(collective_time(op, topo, algorithm)
                     * max(1.0, getattr(op, "weight", 1.0)) for op in ops))


def total_time_split(ops: Iterable[CollectiveOp], topo: MeshTopology,
                     algorithm: str = "ring") -> tuple[float, float]:
    """Execution-weighted per-tier serialized sums ``(ici_s, dcn_s)``.

    ``total_time == sum(total_time_split)`` by construction; the overlap
    roofline bound takes ``max`` of these instead of their sum (ICI and DCN
    are independent fabrics, so their busy times can fully overlap).
    """
    ici = dcn = 0.0
    for op in ops:
        i, d = collective_time_split(op, topo, algorithm)
        w = max(1.0, getattr(op, "weight", 1.0))
        ici += i * w
        dcn += d * w
    return ici, dcn


def contention_time(ops: Iterable[CollectiveOp], topo: MeshTopology,
                    algorithm: str = "ring") -> float:
    """Bottleneck seconds: project every op onto physical links and take the
    busiest link (bytes / link bandwidth), instead of a flat per-chip
    bandwidth.  This is the contention-aware lower bound on communication
    time -- two logical edges sharing one ICI cable serialize on it.
    """
    from . import comm_matrix  # deferred: comm_matrix imports this module

    lu = comm_matrix.link_utilization_for_ops(list(ops), topo, algorithm)
    return lu.bottleneck_seconds()


# ----------------------------------------------------------------------------
# Paper Table 1 (verbatim) -- used by tests & table1 benchmark to check that
# our generalized formulas reduce to the published entries.
# ----------------------------------------------------------------------------
def table1_allreduce_bytes(n: int, s: float, algorithm: str, role: str = "other") -> float:
    if algorithm == "ring":
        return 2.0 * (n - 1) * s / n
    if algorithm == "tree":
        return s if role == "root" else 2.0 * s
    if algorithm == "collnet":
        # paper: intranode 2S, internode S (SHARP in-network reduction)
        return 2.0 * s if role == "intranode" else s
    raise ValueError(algorithm)


def latency_model(kind: str, n: int, algorithm: str = "ring") -> float:
    """Number of serial hops (latency term), for small-payload reasoning."""
    if n <= 1:
        return 0.0
    if algorithm == "tree":
        return 2.0 * math.ceil(math.log2(n))
    if kind == "all-reduce":
        return 2.0 * (n - 1)
    return float(n - 1)
