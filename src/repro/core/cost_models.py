"""Algorithm-aware data-movement models (paper Table 1, adapted to TPU).

The paper's central quantitative artifact is Table 1: the bytes a rank sends/
receives for an AllReduce of payload ``S`` over ``N`` ranks depends on the
algorithm NCCL picked (ring / tree / collnet).  XLA's TPU collectives have the
same structure; the TPU-native algorithm menu is:

* ``ring``         -- bandwidth-optimal ring per torus axis (XLA default for
                      large payloads; NCCL-ring analogue).
* ``tree``         -- recursive doubling/halving, logarithmic latency (small
                      payloads; NCCL-tree analogue).
* ``hierarchical`` -- reduce-scatter inside the pod over ICI, cross-pod
                      exchange over DCN, all-gather inside the pod (the
                      collnet/SHARP analogue: only S/N_pod crosses the slow
                      tier).

``wire_bytes_per_rank`` reproduces the Table-1 entries; ``collective_time``
turns them into seconds on a :class:`~repro.core.topology.MeshTopology`.
"""
from __future__ import annotations

import math
from typing import Iterable

from .events import CollectiveOp
from .topology import MeshTopology

ALGORITHMS = ("ring", "tree", "hierarchical")


def wire_bytes_per_rank(kind: str, payload: float, n: int, algorithm: str = "ring") -> float:
    """Bytes *sent* by one rank for one collective (paper Table 1 analogue).

    ``payload`` is S (the full logical payload per group), ``n`` the group
    size.  Receives mirror sends for all entries below (symmetric algorithms),
    matching the paper's "sent and received" accounting.
    """
    if n <= 1:
        return 0.0
    s = float(payload)
    if algorithm not in ALGORITHMS:
        raise ValueError(f"unknown algorithm {algorithm!r}")

    if kind == "all-reduce":
        if algorithm == "ring":
            # reduce-scatter ring + all-gather ring
            return 2.0 * (n - 1) * s / n
        if algorithm == "tree":
            # double binary tree: non-root sends S up + S down (pipelined);
            # paper: root S, others 2S.  Report the non-root (dominant) cost.
            return 2.0 * s
        # hierarchical: RS in pod (n-1)/n*S + DCN exchange S/n + AG in pod
        return 2.0 * (n - 1) * s / n + s / n
    if kind in ("all-gather", "collective-broadcast"):
        # each rank forwards (n-1) shards of size S/n around the ring
        return (n - 1) * s / n
    if kind == "reduce-scatter":
        return (n - 1) * s / n
    if kind in ("all-to-all", "ragged-all-to-all"):
        # each rank sends (n-1) of its n blocks; block = S/n^2 of global S
        return (n - 1) * s / (n * n)
    if kind == "collective-permute":
        return s
    return s


def wire_bytes_received_per_rank(kind: str, payload: float, n: int, algorithm: str = "ring") -> float:
    return wire_bytes_per_rank(kind, payload, n, algorithm)


def collective_time(op: CollectiveOp, topo: MeshTopology, algorithm: str = "ring") -> float:
    """Seconds for one collective on the torus (bandwidth term only).

    Ring collectives stream at the per-chip ring bandwidth (both directions of
    the axis links); hierarchical ops across DCN are bottlenecked by the
    per-chip DCN share for the cross-pod fraction.
    """
    n = op.group_size
    if n <= 1:
        return 0.0
    group = op.replica_groups[0] if op.replica_groups else []
    crosses = topo.group_crosses_dcn(group)
    per_rank = wire_bytes_per_rank(op.kind, op.payload_bytes, n, algorithm)

    if not crosses:
        return per_rank / topo.ring_bw_per_chip(False)

    # hierarchical decomposition: intra-pod part over ICI + cross-pod over DCN
    pods = topo.num_pods
    in_pod = max(1, n // pods)
    s = float(op.payload_bytes)
    intra = wire_bytes_per_rank(op.kind, s, in_pod, "ring") / topo.ring_bw_per_chip(False)
    cross = (s / max(1, in_pod)) * (pods - 1) / pods / topo.ring_bw_per_chip(True)
    return intra + cross


def total_time(ops: Iterable[CollectiveOp], topo: MeshTopology, algorithm: str = "ring") -> float:
    """Serialized collective time (no overlap) -- upper bound / roofline term."""
    return float(sum(collective_time(op, topo, algorithm) for op in ops))


# ----------------------------------------------------------------------------
# Paper Table 1 (verbatim) -- used by tests & table1 benchmark to check that
# our generalized formulas reduce to the published entries.
# ----------------------------------------------------------------------------
def table1_allreduce_bytes(n: int, s: float, algorithm: str, role: str = "other") -> float:
    if algorithm == "ring":
        return 2.0 * (n - 1) * s / n
    if algorithm == "tree":
        return s if role == "root" else 2.0 * s
    if algorithm == "collnet":
        # paper: intranode 2S, internode S (SHARP in-network reduction)
        return 2.0 * s if role == "intranode" else s
    raise ValueError(algorithm)


def latency_model(kind: str, n: int, algorithm: str = "ring") -> float:
    """Number of serial hops (latency term), for small-payload reasoning."""
    if n <= 1:
        return 0.0
    if algorithm == "tree":
        return 2.0 * math.ceil(math.log2(n))
    if kind == "all-reduce":
        return 2.0 * (n - 1)
    return float(n - 1)
