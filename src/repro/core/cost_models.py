"""Algorithm-aware data-movement models (paper Table 1, adapted to TPU).

The paper's central quantitative artifact is Table 1: the bytes a rank sends/
receives for an AllReduce of payload ``S`` over ``N`` ranks depends on the
algorithm NCCL picked (ring / tree / collnet).  XLA's TPU collectives have the
same structure; the TPU-native algorithm menu is:

* ``ring``         -- bandwidth-optimal ring per torus axis (XLA default for
                      large payloads; NCCL-ring analogue).
* ``tree``         -- binary reduce/broadcast tree, logarithmic latency (small
                      payloads; NCCL-tree analogue).
* ``hierarchical`` -- phase decomposition across the pod boundary (the
                      collnet/SHARP analogue): intra-pod ring phases over ICI
                      around a cross-pod DCN shard exchange, degenerating
                      exactly to ``ring`` at ``pods=1``.

Every entry below is **derived from the one schedule engine**
(:mod:`repro.core.decompose`): :func:`wire_bytes_per_rank` sums the per-rank
bytes of the phases :func:`repro.core.decompose.group_phases` emits,
:func:`device_send_bytes` resolves them per device role (tree roots/leaves
send different amounts), and :func:`collective_time_split` streams each
phase's bytes at its tier's bandwidth **plus the phase's serial
``latency_hops`` at the tier's per-hop latency** (the latency term
:func:`latency_model` describes, finally billed).  There is no per-kind
algorithm branching left here -- the schedule IR is the single source of
truth shared with matrix placement and link projection, so they cannot
diverge.  The algorithm menu, the shared hierarchical predicate and the
tree-structure helpers live in :mod:`repro.core.decompose` and are
re-exported here for compatibility.
"""
from __future__ import annotations

import math
from typing import Iterable, Optional

import numpy as np

from . import decompose as _dec
from .decompose import (A2A_KINDS, ALGORITHMS,  # noqa: F401
                        HIERARCHICAL_KINDS, BoundedCache,
                        HierarchicalFallbackWarning, a2a_decomposition,
                        effective_byte_vector, effective_pods, hier_phases,
                        hierarchical_decomposition, tree_children,
                        tree_subtree_sizes, validate_algorithm)
from .events import CollectiveOp
from .topology import MeshTopology

# Bounded signature-keyed caches for the Table-1 entry points.  These used
# to be ``functools.lru_cache`` on the helper functions -- unbounded in
# practice for long-running sessions (every distinct (kind, payload, n,
# algorithm, pods) tuple pinned forever) and invisible to invalidation.
# The explicit :class:`~repro.core.decompose.BoundedCache` keeps the same
# hit rate on real workloads (shape diversity is tiny) with a hard cap.
_PER_RANK_CACHE = BoundedCache(maxsize=8192)
_GROUP_TOTAL_CACHE = BoundedCache(maxsize=8192)


def clear_billing_caches() -> None:
    """Drop the memoized Table-1 entries (tests, post-spec mutation)."""
    _PER_RANK_CACHE.clear()
    _GROUP_TOTAL_CACHE.clear()


def wire_bytes_per_rank(kind: str, payload: float, n: int,
                        algorithm: str = "ring", *, pods: int = 1,
                        vec=None) -> float:
    """Bytes *sent* by one rank for one collective (paper Table 1 analogue).

    ``payload`` is S (the full logical payload per group), ``n`` the group
    size, ``pods`` the number of DCN tiers the group spans (pass
    :func:`effective_pods` so a group the schedule cannot decompose
    degenerates here too).  The value is the per-rank sum over the phases
    of :func:`repro.core.decompose.group_phases` -- the same schedule the
    matrix placement walks -- which reproduces the closed-form Table-1
    entries exactly:

    ========================  =====================  ====================
    kind (hierarchical)       intra-pod (ICI)        cross-pod (DCN)
    ========================  =====================  ====================
    all-reduce                ``2(m-1)/m * S``       ``2(p-1)/n * S``
    all-gather                ``(m-1)/m * S``        ``(p-1)/n * S``
    reduce-scatter            ``(m-1)/m * S``        ``(p-1)/n * S``
    collective-broadcast      ``(m-1)/m * S``        ``(p-1)/n * S``
    ========================  =====================  ====================

    (``m = n/pods``; ring entries are the ``pods=1`` degenerate case:
    ``2(n-1)/n*S`` for all-reduce, ``(n-1)/n*S`` for the one-phase kinds,
    ``(n-1)/n^2*S`` for all-to-all; hierarchical all-to-all pays
    ``2(m-1)S/(p m^2)`` intra-pod plus ``(p-1)S/(p^2 m)`` over DCN.)
    Receives mirror sends for the symmetric entries; tree entries report
    the non-root (dominant) cost, with :func:`device_send_bytes`
    resolving per-role amounts.

    ``vec`` is an optional per-rank byte vector (irregular collectives):
    a uniform vector collapses to the cached scalar path bitwise; a
    genuinely skewed one bills the **straggler** -- the max over the
    per-device send totals of the vector schedule.
    """
    if n <= 1:
        return 0.0
    validate_algorithm(algorithm)
    vec = effective_byte_vector(kind, vec, n)
    if vec is None:
        return _per_rank_cached(kind, float(payload), n, algorithm,
                                int(pods))
    phases = _dec.group_phases(kind, float(vec.sum()),
                               np.arange(n, dtype=np.intp), algorithm,
                               topo=None, pods=int(pods), warn=False,
                               vec=vec)
    totals: dict[int, float] = {}
    for ph in phases:
        for d, b in ph.send_bytes().items():
            totals[d] = totals.get(d, 0.0) + b
    return float(max(totals.values(), default=0.0))


def _per_rank_cached(kind: str, payload: float, n: int, algorithm: str,
                     pods: int) -> float:
    """Scalar-cached per-rank sum over the abstract phase plan (ops repeat
    the same (kind, payload, n) tuples across summaries, the Perfetto
    exporter's per-op args, and matrices, so the schedule is built once
    per distinct entry)."""
    key = (kind, payload, n, algorithm, pods)
    hit = _PER_RANK_CACHE.get(key)
    if hit is not None:
        return hit
    phases = _dec.group_phases(kind, payload, np.arange(n, dtype=np.intp),
                               algorithm, topo=None, pods=pods,
                               warn=False)
    out = float(sum(ph.bytes_per_rank for ph in phases))
    _PER_RANK_CACHE.put(key, out)
    return out


def wire_bytes_received_per_rank(kind: str, payload: float, n: int,
                                 algorithm: str = "ring", *,
                                 pods: int = 1, vec=None) -> float:
    return wire_bytes_per_rank(kind, payload, n, algorithm, pods=pods,
                               vec=vec)


def wire_bytes_group_total(kind: str, payload: float, n: int,
                           algorithm: str = "ring", *, pods: int = 1,
                           vec=None) -> float:
    """Bytes on the wire summed over every rank of ONE group.

    The per-device sum over the group's schedule: for the symmetric (ring,
    hierarchical) entries this is ``n * wire_bytes_per_rank``; tree phases
    resolve true per-role amounts (a binary tree all-reduce moves
    ``2*(n-1)*S`` total: S up and S down each of its ``n-1`` edges), so
    matrices, summaries and cost models all agree on the same totals.
    ``vec`` follows :func:`wire_bytes_per_rank`: irregular groups sum
    their true per-position amounts (cache bypassed; uniform vectors
    collapse to the cached scalar path).
    """
    if n <= 1:
        return 0.0
    validate_algorithm(algorithm)
    vec = effective_byte_vector(kind, vec, n)
    if vec is None:
        return _group_total_cached(kind, float(payload), n, algorithm,
                                   int(pods))
    phases = _dec.group_phases(kind, float(vec.sum()),
                               np.arange(n, dtype=np.intp), algorithm,
                               topo=None, pods=int(pods), warn=False,
                               vec=vec)
    return float(sum(ph.total_send_bytes() for ph in phases))


def _group_total_cached(kind: str, payload: float, n: int, algorithm: str,
                        pods: int) -> float:
    key = (kind, payload, n, algorithm, pods)
    hit = _GROUP_TOTAL_CACHE.get(key)
    if hit is not None:
        return hit
    phases = _dec.group_phases(kind, payload, np.arange(n, dtype=np.intp),
                               algorithm, topo=None, pods=pods,
                               warn=False)
    out = float(sum(ph.total_send_bytes() for ph in phases))
    _GROUP_TOTAL_CACHE.put(key, out)
    return out


def device_send_bytes(kind: str, payload: float, group: list[int],
                      algorithm: str = "ring",
                      topo: Optional[MeshTopology] = None, *,
                      vec=None) -> dict[int, float]:
    """Bytes each device of ``group`` sends for one collective execution.

    The per-role resolution of :func:`wire_bytes_per_rank` -- the
    matrix/model consistency contract: ``matrix_for_ops`` row sums must
    equal these values (times the op weight).  Both sides read the same
    schedule, so the contract holds by construction: ring and hierarchical
    phases are symmetric (every rank sends the per-phase amount); tree
    phases depend on the device's position (root sends S per child, a leaf
    sends S up and nothing down); vector phases resolve their per-position
    amounts (``vec`` is positional over ``group``'s order).
    """
    out = {d: 0.0 for d in group}
    if len(group) <= 1:
        return out
    phases = _dec.group_phases(kind, float(payload), group, algorithm,
                               topo, warn=False, vec=vec)
    for ph in phases:
        for d, b in ph.send_bytes().items():
            out[d] = out.get(d, 0.0) + b
    return out


def collective_time_split(op: CollectiveOp, topo: MeshTopology,
                          algorithm: str = "ring", *,
                          include_latency: bool = True) -> tuple[float, float]:
    """``(ici_seconds, dcn_seconds)`` for one collective.

    The per-tier resolution of :func:`collective_time`, read off the op's
    :func:`~repro.core.decompose.decompose` schedule: each phase streams
    its per-rank bytes at its tier's per-chip ring bandwidth and adds its
    serial ``latency_hops`` at the tier's per-hop latency
    (``HardwareSpec.ici_hop_latency_s`` / ``dcn_hop_latency_s``; set
    ``include_latency=False`` for the pure bandwidth term, e.g. to compare
    against byte-conservation invariants).  Phase streams of disjoint
    replica groups run concurrently, so each tier's time is the max over
    streams.  The *requested* algorithm is honoured:

    * intra-pod groups stream over ICI only (per-axis decomposed groups
      pay fewer serial hops than the flattened ring -- same bytes, less
      latency);
    * a **hierarchical** group across pods that the shared predicate
      accepts pays its intra-pod phases over ICI and only the shard
      exchange over DCN;
    * a hierarchical request the predicate *refuses* is billed exactly
      like the placement's fallback -- flat ring phases crossing DCN --
      never as a phantom decomposition;
    * a **ring or tree** group spanning pods streams its full per-rank
      payload at the per-chip DCN share -- it is NOT silently rebilled as
      hierarchical (that would contradict the matrix's edge placement).
    """
    return _dec.cached_decompose(op, algorithm, topo,
                                 warn=False).time_split(
        topo, include_latency=include_latency)


def collective_time(op: CollectiveOp, topo: MeshTopology,
                    algorithm: str = "ring", *,
                    include_latency: bool = True) -> float:
    """Seconds for one collective on the torus: the serialized sum of the
    per-tier terms of :func:`collective_time_split`."""
    ici, dcn = collective_time_split(op, topo, algorithm,
                                     include_latency=include_latency)
    return ici + dcn


def total_time(ops: Iterable[CollectiveOp], topo: MeshTopology,
               algorithm: str = "ring", *,
               include_latency: bool = True) -> float:
    """Serialized collective time (no overlap) -- upper bound / roofline term.

    Execution-weighted: an op inside a while body contributes once per trip.
    """
    return float(sum(
        collective_time(op, topo, algorithm,
                        include_latency=include_latency)
        * max(1.0, getattr(op, "weight", 1.0)) for op in ops))


def total_time_split(ops: Iterable[CollectiveOp], topo: MeshTopology,
                     algorithm: str = "ring", *,
                     include_latency: bool = True) -> tuple[float, float]:
    """Execution-weighted per-tier serialized sums ``(ici_s, dcn_s)``.

    ``total_time == sum(total_time_split)`` by construction; the overlap
    roofline bound takes ``max`` of these instead of their sum (ICI and DCN
    are independent fabrics, so their busy times can fully overlap).
    Evaluated through the columnar :class:`~repro.core.decompose.
    ScheduleBatch` (decompose once per distinct shape, per-tier sums as
    array expressions) -- bitwise identical to the per-op loop it
    replaced.
    """
    batch = _dec.ScheduleBatch.from_ops(list(ops), algorithm, topo,
                                        warn=False)
    return batch.total_time_split(topo, include_latency=include_latency)


def contention_time(ops: Iterable[CollectiveOp], topo: MeshTopology,
                    algorithm: str = "ring") -> float:
    """Bottleneck seconds: project every op onto physical links and take the
    busiest link (bytes / link bandwidth), instead of a flat per-chip
    bandwidth.  This is the contention-aware lower bound on communication
    time -- two logical edges sharing one ICI cable serialize on it.
    (Pure bandwidth: link projection carries bytes, not hop latencies.)
    """
    from . import comm_matrix  # deferred: comm_matrix imports this module

    lu = comm_matrix.link_utilization_for_ops(list(ops), topo, algorithm)
    return lu.bottleneck_seconds()


# ----------------------------------------------------------------------------
# Paper Table 1 (verbatim) -- used by tests & table1 benchmark to check that
# our generalized formulas reduce to the published entries.
# ----------------------------------------------------------------------------
def table1_allreduce_bytes(n: int, s: float, algorithm: str, role: str = "other") -> float:
    if algorithm == "ring":
        return 2.0 * (n - 1) * s / n
    if algorithm == "tree":
        return s if role == "root" else 2.0 * s
    if algorithm == "collnet":
        # paper: intranode 2S, internode S (SHARP in-network reduction)
        return 2.0 * s if role == "intranode" else s
    raise ValueError(algorithm)


def latency_model(kind: str, n: int, algorithm: str = "ring") -> float:
    """Number of serial hops (latency term), for small-payload reasoning.

    The closed-form reference the schedule reproduces on flattened rings:
    ``CollectiveSchedule.latency_hops()`` equals this for single-axis
    groups, and is strictly smaller for per-axis-decomposed multi-axis
    groups (``2 * sum(axis_size - 1)`` instead of ``2 * (n - 1)``).
    """
    if n <= 1:
        return 0.0
    if algorithm == "tree":
        return 2.0 * math.ceil(math.log2(n))
    if kind == "all-reduce":
        return 2.0 * (n - 1)
    return float(n - 1)
