"""Algorithm-aware data-movement models (paper Table 1, adapted to TPU).

The paper's central quantitative artifact is Table 1: the bytes a rank sends/
receives for an AllReduce of payload ``S`` over ``N`` ranks depends on the
algorithm NCCL picked (ring / tree / collnet).  XLA's TPU collectives have the
same structure; the TPU-native algorithm menu is:

* ``ring``         -- bandwidth-optimal ring per torus axis (XLA default for
                      large payloads; NCCL-ring analogue).
* ``tree``         -- binary reduce/broadcast tree, logarithmic latency (small
                      payloads; NCCL-tree analogue).
* ``hierarchical`` -- reduce-scatter inside the pod over ICI, cross-pod
                      ring exchange of the scattered shards over DCN,
                      all-gather inside the pod (the collnet/SHARP analogue:
                      only S/N_in_pod crosses the slow tier).  With ``pods=1``
                      (no DCN tier) it degenerates exactly to ``ring``.

``wire_bytes_per_rank`` reproduces the Table-1 entries; ``collective_time``
turns them into seconds on a :class:`~repro.core.topology.MeshTopology`,
honouring the *requested* algorithm even when the group spans DCN (a ring
all-reduce across pods pays its full per-rank payload at the per-chip DCN
share -- it is never silently rebilled as hierarchical).
``device_send_bytes`` resolves the per-rank entries down to each device's
role (tree roots/leaves send different amounts), and is the contract the
communication-matrix row sums are tested against.  ``contention_time``
projects the matrix onto physical links and takes the bottleneck link.
"""
from __future__ import annotations

import math
from typing import Iterable, Optional

from .events import CollectiveOp
from .topology import MeshTopology

ALGORITHMS = ("ring", "tree", "hierarchical")


def _hier_split(n: int, pods: int) -> tuple[int, int]:
    """(pods, in_pod) for a hierarchical decomposition of an ``n``-rank group.

    Degenerates to ``(1, n)`` when the group does not split evenly across
    pods (or there is no DCN tier), which makes hierarchical == ring.
    """
    p = max(1, int(pods))
    if p <= 1 or n % p != 0 or n // p < 1:
        return 1, n
    return p, n // p


def wire_bytes_per_rank(kind: str, payload: float, n: int,
                        algorithm: str = "ring", *, pods: int = 1) -> float:
    """Bytes *sent* by one rank for one collective (paper Table 1 analogue).

    ``payload`` is S (the full logical payload per group), ``n`` the group
    size.  ``pods`` is the number of DCN tiers the group spans -- only the
    hierarchical all-reduce entry depends on it (reduce-scatter over the
    ``n/pods`` in-pod ranks, cross-pod ring over ``pods``, all-gather in
    pod).  Receives mirror sends for all entries below (symmetric
    algorithms), matching the paper's "sent and received" accounting.  Tree
    entries report the non-root (dominant) cost; ``device_send_bytes``
    resolves per-role amounts.
    """
    if n <= 1:
        return 0.0
    s = float(payload)
    if algorithm not in ALGORITHMS:
        raise ValueError(f"unknown algorithm {algorithm!r}")

    if kind == "all-reduce":
        if algorithm == "ring":
            # reduce-scatter ring + all-gather ring
            return 2.0 * (n - 1) * s / n
        if algorithm == "tree":
            # double binary tree: non-root sends S up + S down (pipelined);
            # paper: root S, others 2S.  Report the non-root (dominant) cost.
            return 2.0 * s
        # hierarchical: RS ring over the in-pod ranks (2*(m-1)/m * S total
        # for RS+AG) + cross-pod ring all-reduce of the S/m shard over pods
        p, m = _hier_split(n, pods)
        intra = 2.0 * (m - 1) * s / m if m > 1 else 0.0
        cross = 2.0 * (p - 1) * (s / m) / p if p > 1 else 0.0
        return intra + cross
    if kind in ("all-gather", "collective-broadcast"):
        # each rank forwards (n-1) shards of size S/n around the ring
        return (n - 1) * s / n
    if kind == "reduce-scatter":
        return (n - 1) * s / n
    if kind in ("all-to-all", "ragged-all-to-all"):
        # each rank sends (n-1) of its n blocks; block = S/n^2 of global S
        return (n - 1) * s / (n * n)
    if kind == "collective-permute":
        return s
    return s


def wire_bytes_received_per_rank(kind: str, payload: float, n: int,
                                 algorithm: str = "ring", *,
                                 pods: int = 1) -> float:
    return wire_bytes_per_rank(kind, payload, n, algorithm, pods=pods)


def wire_bytes_group_total(kind: str, payload: float, n: int,
                           algorithm: str = "ring", *, pods: int = 1) -> float:
    """Bytes on the wire summed over every rank of ONE group.

    For the symmetric (ring, hierarchical) entries this is
    ``n * wire_bytes_per_rank``; tree entries sum the true per-role amounts
    (a binary tree all-reduce moves ``2*(n-1)*S`` total: S up and S down
    each of its ``n-1`` edges), so matrices, summaries and cost models all
    agree on the same totals.
    """
    if n <= 1:
        return 0.0
    s = float(payload)
    if algorithm == "tree":
        if kind == "all-reduce":
            return 2.0 * (n - 1) * s
        if kind in ("all-gather", "reduce-scatter", "collective-broadcast"):
            # up + down phases move (n-1)*S in aggregate, same as the ring
            return (n - 1) * s
    return n * wire_bytes_per_rank(kind, s, n, algorithm, pods=pods)


# ----------------------------------------------------------------------------
# Binary-tree structure (heap layout over group positions) -- shared contract
# between the per-device byte model below and the matrix edge placement in
# comm_matrix.py.
# ----------------------------------------------------------------------------
def tree_children(i: int, n: int) -> list[int]:
    """Children of position ``i`` in the implicit binary tree over ``n``."""
    return [c for c in (2 * i + 1, 2 * i + 2) if c < n]


def tree_subtree_sizes(n: int) -> list[int]:
    """Subtree size per position of the implicit binary tree over ``n``."""
    sizes = [1] * n
    for i in range(n - 1, 0, -1):
        sizes[(i - 1) // 2] += sizes[i]
    return sizes


def device_send_bytes(kind: str, payload: float, group: list[int],
                      algorithm: str = "ring",
                      topo: Optional[MeshTopology] = None) -> dict[int, float]:
    """Bytes each device of ``group`` sends for one collective execution.

    This is the per-role resolution of :func:`wire_bytes_per_rank` -- the
    matrix/model consistency contract: ``matrix_for_ops`` row sums must
    equal these values (times the op weight).  Ring and hierarchical
    placements are symmetric (every rank sends the Table-1 per-rank
    amount); tree placements depend on the device's position (root sends S
    per child, a leaf sends S up and nothing down).
    """
    n = len(group)
    if n <= 1:
        return {d: 0.0 for d in group}
    s = float(payload)
    if algorithm == "tree" and kind in ("all-reduce", "all-gather",
                                        "reduce-scatter",
                                        "collective-broadcast"):
        sizes = tree_subtree_sizes(n)
        out: dict[int, float] = {}
        for i, d in enumerate(group):
            kids = tree_children(i, n)
            up = s if i > 0 else 0.0                      # reduce phase
            down = s * len(kids)                          # broadcast phase
            if kind == "all-reduce":
                sent = up + down
            elif kind == "collective-broadcast":
                sent = down
            elif kind == "all-gather":
                # up: my subtree's shards; down: everything a child lacks
                sent = (sizes[i] * s / n if i > 0 else 0.0) \
                    + sum((n - sizes[c]) * s / n for c in kids)
            else:  # reduce-scatter == time-reversed all-gather
                sent = ((n - sizes[i]) * s / n if i > 0 else 0.0) \
                    + sum(sizes[c] * s / n for c in kids)
            out[d] = sent
        return out
    pods = len(topo.pod_partition(group)) if topo is not None else 1
    per_rank = wire_bytes_per_rank(kind, s, n, algorithm, pods=pods)
    return {d: per_rank for d in group}


def collective_time(op: CollectiveOp, topo: MeshTopology,
                    algorithm: str = "ring") -> float:
    """Seconds for one collective on the torus (bandwidth term only).

    The *requested* algorithm is honoured:

    * intra-pod groups stream the per-rank bytes at the per-chip ring
      bandwidth (both directions of the axis links);
    * a **hierarchical** all-reduce across pods pays its intra-pod phases
      over ICI and only the ``S/m`` shard exchange over DCN;
    * a **ring or tree** collective whose group spans pods has ring/tree
      edges crossing DCN, so its full per-rank payload streams at the
      per-chip DCN share -- it is NOT silently rebilled as hierarchical
      (that would contradict the matrix's edge placement).
    """
    n = op.group_size
    if n <= 1:
        return 0.0
    group = op.replica_groups[0] if op.replica_groups else []
    crosses = topo.group_crosses_dcn(group)
    s = float(op.payload_bytes)

    if not crosses:
        per_rank = wire_bytes_per_rank(op.kind, s, n, algorithm)
        return per_rank / topo.ring_bw_per_chip(False)

    if algorithm == "hierarchical" and op.kind == "all-reduce":
        p, m = _hier_split(n, len(topo.pod_partition(group)))
        intra = (2.0 * (m - 1) * s / m) / topo.ring_bw_per_chip(False) \
            if m > 1 else 0.0
        cross = (2.0 * (p - 1) * (s / m) / p) / topo.ring_bw_per_chip(True) \
            if p > 1 else 0.0
        return intra + cross

    per_rank = wire_bytes_per_rank(op.kind, s, n, algorithm)
    return per_rank / topo.ring_bw_per_chip(True)


def total_time(ops: Iterable[CollectiveOp], topo: MeshTopology,
               algorithm: str = "ring") -> float:
    """Serialized collective time (no overlap) -- upper bound / roofline term.

    Execution-weighted: an op inside a while body contributes once per trip.
    """
    return float(sum(collective_time(op, topo, algorithm)
                     * max(1.0, getattr(op, "weight", 1.0)) for op in ops))


def contention_time(ops: Iterable[CollectiveOp], topo: MeshTopology,
                    algorithm: str = "ring") -> float:
    """Bottleneck seconds: project every op onto physical links and take the
    busiest link (bytes / link bandwidth), instead of a flat per-chip
    bandwidth.  This is the contention-aware lower bound on communication
    time -- two logical edges sharing one ICI cable serialize on it.
    """
    from . import comm_matrix  # deferred: comm_matrix imports this module

    lu = comm_matrix.link_utilization_for_ops(list(ops), topo, algorithm)
    return lu.bottleneck_seconds()


# ----------------------------------------------------------------------------
# Paper Table 1 (verbatim) -- used by tests & table1 benchmark to check that
# our generalized formulas reduce to the published entries.
# ----------------------------------------------------------------------------
def table1_allreduce_bytes(n: int, s: float, algorithm: str, role: str = "other") -> float:
    if algorithm == "ring":
        return 2.0 * (n - 1) * s / n
    if algorithm == "tree":
        return s if role == "root" else 2.0 * s
    if algorithm == "collnet":
        # paper: intranode 2S, internode S (SHARP in-network reduction)
        return 2.0 * s if role == "intranode" else s
    raise ValueError(algorithm)


def latency_model(kind: str, n: int, algorithm: str = "ring") -> float:
    """Number of serial hops (latency term), for small-payload reasoning."""
    if n <= 1:
        return 0.0
    if algorithm == "tree":
        return 2.0 * math.ceil(math.log2(n))
    if kind == "all-reduce":
        return 2.0 * (n - 1)
    return float(n - 1)
