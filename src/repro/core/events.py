"""Event datatypes for communication monitoring.

Two sources of truth, mirroring the paper's design (ComScribe intercepts NCCL
calls; we additionally read the compiled program):

* ``TraceEvent``   -- a collective the *application* issued, captured at trace
  time by the interceptor (the LD_PRELOAD analogue).
* ``CollectiveOp`` -- a collective the *compiler* emitted, extracted from the
  compiled HLO module (the ground truth for wire traffic on TPU).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

# Bytes per element for HLO dtype names.
DTYPE_BYTES = {
    "pred": 1,
    "s4": 1, "u4": 1,
    "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "f8e4m3fnuz": 1,
    "f8e5m2fnuz": 1, "f8e3m4": 1, "f8e4m3": 1,
}

# Canonical collective kinds (HLO opcode spelling).
COLLECTIVE_KINDS = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
    "collective-broadcast",
    "ragged-all-to-all",
)

# Kinds whose payload may legitimately differ per rank (allgatherv-style
# irregular collectives).  ``bytes_per_rank_vec`` on other kinds is ignored:
# an all-reduce moves the full reduced tensor through every rank, so a
# per-rank contribution vector has no wire meaning.
VECTOR_KINDS = (
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "ragged-all-to-all",
)


@dataclasses.dataclass
class Shape:
    dtype: str
    dims: tuple[int, ...]

    @property
    def num_elements(self) -> int:
        n = 1
        for d in self.dims:
            n *= d
        return n

    @property
    def bytes(self) -> int:
        return self.num_elements * DTYPE_BYTES.get(self.dtype, 4)

    def __repr__(self) -> str:
        return f"{self.dtype}[{','.join(map(str, self.dims))}]"


@dataclasses.dataclass
class CollectiveOp:
    """One collective op from a compiled (SPMD-partitioned, per-device) module."""

    kind: str                            # one of COLLECTIVE_KINDS
    name: str                            # HLO instruction name, e.g. %all-reduce.2
    result_shapes: list[Shape]           # tuple results flattened
    replica_groups: list[list[int]]      # explicit groups (possibly from iota form)
    channel_id: Optional[int] = None
    dimensions: tuple[int, ...] = ()     # gather/scatter/a2a dimension(s)
    source_target_pairs: list[tuple[int, int]] = dataclasses.field(default_factory=list)
    op_name: str = ""                    # metadata op_name (jax source op)
    weight: float = 1.0                  # execution count (while trip counts)
    phase: str = ""                      # session phase ("" = unphased/legacy)
    operand_names: list[str] = dataclasses.field(default_factory=list)
    use_global_device_ids: bool = False  # replica_groups hold global ids
    # Optional per-rank byte vector (irregular collectives, schema v8).
    # ``bytes_per_rank_vec[i]`` is the logical payload contribution (bytes)
    # of group POSITION i, applied positionally to every replica group:
    # the shard rank i contributes to an allgatherv, the chunk destined to
    # rank i for a v-reduce-scatter, the total bytes rank i injects into a
    # skewed all-to-all.  ``sum(vec)`` replaces ``payload_bytes``.  Kept as
    # a plain float list (JSON-friendly, dataclasses.replace-friendly);
    # consumers read the validated ndarray via :meth:`byte_vector`.
    bytes_per_rank_vec: Optional[list] = None
    # Optional *measured* wall-clock seconds (schema v9): the total device
    # time a real trace recorded for this op across all its executions
    # (worst rank for multi-rank records), set by the trace importers
    # (:mod:`repro.core.trace`).  ``None`` for purely modeled ops -- the
    # cost models never read it, so modeled and measured time coexist and
    # the compare layer (:mod:`repro.core.trace.compare`) can pin one
    # against the other.
    measured_s: Optional[float] = None

    # ------------------------------------------------------------------
    # Byte accounting.  The compiled module is per-device: result shapes are
    # the *local* post-op shapes.  ``payload_bytes`` is the full logical
    # payload S of the collective (paper Table 1's S), per group.
    # ------------------------------------------------------------------
    @property
    def group_size(self) -> int:
        if self.replica_groups:
            return len(self.replica_groups[0])
        if self.source_target_pairs:
            return len({d for p in self.source_target_pairs for d in p})
        return 1

    @property
    def num_groups(self) -> int:
        return max(1, len(self.replica_groups))

    @property
    def result_bytes(self) -> int:
        return sum(s.bytes for s in self.result_shapes)

    def byte_vector(self) -> Optional[np.ndarray]:
        """Validated per-rank byte vector, or None.

        Returns the ``float64`` vector only when the op's kind is in
        :data:`VECTOR_KINDS`, the vector's length matches the group size,
        and every entry is finite and non-negative -- anything else is
        silently treated as the regular (scalar) op, so a stale or
        malformed vector can never corrupt downstream byte accounting.
        """
        if self.bytes_per_rank_vec is None or self.kind not in VECTOR_KINDS:
            return None
        v = np.asarray(self.bytes_per_rank_vec, dtype=np.float64)
        if v.ndim != 1 or v.size != self.group_size or v.size < 2:
            return None
        if not np.all(np.isfinite(v)) or np.any(v < 0) or v.sum() <= 0:
            return None
        return v

    def skew(self) -> float:
        """Max/mean of the per-rank byte vector (1.0 for regular ops)."""
        v = self.byte_vector()
        if v is None:
            return 1.0
        return float(v.max() / v.mean())

    @property
    def payload_bytes(self) -> float:
        """Full logical payload S per group (bytes)."""
        v = self.byte_vector()
        if v is not None:
            return float(v.sum())
        n = self.group_size
        if self.kind == "all-reduce":
            # result (local) == full reduced tensor
            return self.result_bytes
        if self.kind in ("all-gather", "collective-broadcast"):
            # result is the gathered tensor == S
            return self.result_bytes
        if self.kind == "reduce-scatter":
            # result is S/N
            return self.result_bytes * n
        if self.kind in ("all-to-all", "ragged-all-to-all"):
            # each rank holds S/N in and out; define S as the full exchanged set
            return self.result_bytes * n
        if self.kind == "collective-permute":
            return self.result_bytes
        return self.result_bytes

    def wire_bytes_per_rank(self, algorithm: str = "ring",
                            pods: int = 1) -> float:
        """Bytes *sent* by one participating rank (paper Table 1 analogue).

        ``pods`` is the number of DCN tiers the group spans (only the
        hierarchical entries depend on it; pass
        ``cost_models.effective_pods`` so non-decomposable groups
        degenerate to ring exactly like the placement).
        """
        from . import cost_models

        return cost_models.wire_bytes_per_rank(
            self.kind, self.payload_bytes, self.group_size, algorithm,
            pods=pods, vec=self.byte_vector(),
        )

    def wire_bytes_total(self, algorithm: str = "ring",
                         pods: int = 1) -> float:
        """Bytes on the wire summed over every rank in every group,
        weighted by execution count (while-loop trip counts).  Tree
        entries sum true per-role amounts (see
        ``cost_models.wire_bytes_group_total``)."""
        from . import cost_models

        if self.kind == "collective-permute":
            # every group executes the pair schedule (num_groups scales the
            # total exactly like it does for every other kind)
            return float(self.result_bytes
                         * max(1, len(self.source_target_pairs))) \
                * self.num_groups * self.weight
        return (cost_models.wire_bytes_group_total(
                    self.kind, self.payload_bytes, self.group_size,
                    algorithm, pods=pods, vec=self.byte_vector())
                * self.num_groups * self.weight)


@dataclasses.dataclass
class TraceEvent:
    """A collective issued by user code, captured by the interceptor."""

    primitive: str                       # e.g. "psum", "all_gather", "ppermute"
    axis_name: str                       # mesh axis (or tuple repr)
    arg_shapes: list[Shape]
    axis_size: Optional[int] = None      # resolved group size if known
    call_site: str = ""                  # abbreviated stack location
    phase: str = ""                      # session phase ("" = unphased/legacy)

    @property
    def payload_bytes(self) -> int:
        return sum(s.bytes for s in self.arg_shapes)


@dataclasses.dataclass
class HostTransfer:
    """Host<->device transfer (paper's row/col 0); recorded by the data layer."""

    direction: str                       # "h2d" | "d2h"
    device: int
    nbytes: int
    label: str = ""
    phase: str = ""                      # session phase ("" = unphased/legacy)


@dataclasses.dataclass
class PhaseRecord:
    """One named capture phase of a :class:`~repro.core.session.MonitorSession`.

    Serialized with the report (schema v4): ``name`` matches the ``phase``
    tag carried by every :class:`CollectiveOp` / :class:`TraceEvent` /
    :class:`HostTransfer` captured under it, so per-phase views can be
    rebuilt from any loaded report.
    """

    name: str
    num_captures: int = 0
    trace_seconds: float = 0.0
    compile_seconds: float = 0.0


def jax_shape(x) -> Shape:
    """Shape from a jax array / ShapeDtypeStruct / np array."""
    dt = str(x.dtype)
    dt = {"float32": "f32", "float64": "f64", "float16": "f16",
          "bfloat16": "bf16", "int32": "s32", "int64": "s64",
          "int16": "s16", "int8": "s8", "uint32": "u32", "uint64": "u64",
          "uint16": "u16", "uint8": "u8", "bool": "pred"}.get(dt, dt)
    return Shape(dtype=dt, dims=tuple(x.shape))
