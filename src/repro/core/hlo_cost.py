"""Loop-aware cost extraction from compiled HLO.

``compiled.cost_analysis()`` counts a ``while`` body ONCE regardless of trip
count — for scan-over-layers models that undercounts FLOPs/bytes/collectives
by orders of magnitude (a 64-layer x 16-microbatch train step executes its
body 1024x).  Monitoring infrastructure must be loop-aware: this module
walks the computation graph, propagates execution multipliers through
``while`` ops (XLA annotates ``known_trip_count``), and produces:

* ``flops``         — 2*prod(result)*contraction for every dot/convolution,
* ``bytes_hbm``     — fusion-boundary traffic with slice-aware operands
  (a fused dynamic-slice of a stacked loop carry reads one slice, not the
  stack; a root dynamic-update-slice writes the update, not the buffer) —
  this is the roofline memory term,
* ``bytes_logical`` — cost_analysis-style per-op operand+result bytes,
* ``collectives``   — :class:`CollectiveOp` list with per-op ``weight`` =
  execution count (fixes paper-Table-2 style tallies for scanned code).

This is the TPU answer to "NCCL computes channels before launch, ComScribe
reads the plan": we read XLA's plan, trip counts included.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

from .events import DTYPE_BYTES, CollectiveOp
from .hlo_parser import (_SHAPE_RE, _call_args, _operand_names,
                         _split_top_level, parse_hlo_collectives)

_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.*\{")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
# Tolerates both operand spellings: `while(%tuple)` (new jax) and the typed
# `while((s32[], f32[4]{0}) %tuple)` form older jaxlibs print.
_WHILE_RE = re.compile(
    r"\bwhile\(.*\),\s*condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply|branch_computations)="
                       r"\{?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)\}?")
_FUSION_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*")
_OPCODE_RE = re.compile(r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*?)\s+([\w\-]+)\(")
_DOT_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_DIM_LABELS_RE = re.compile(r"dim_labels=([\w?]+)_([\w?]+)->([\w?]+)")
_PARAM_RE = re.compile(r"^\s*%?([\w.\-]+)\s*=\s*(.*?)\s+parameter\((\d+)\)")
_OPNAME_RE = re.compile(r'op_name="([^"]*)"')

_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "get-dimension-size",
    "copy-start", "copy-done",
}


def _shapes_bytes(type_text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_text):
        dims = [int(d) for d in m.group(2).split(",") if d]
        n = 1
        for d in dims:
            n *= d
        total += n * DTYPE_BYTES.get(m.group(1), 4)
    return total


def _first_shape_dims(type_text: str) -> Optional[list[int]]:
    m = _SHAPE_RE.search(type_text)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(",") if d]


# The operand-parsing helpers (_split_top_level / _operand_names /
# _call_args) live in hlo_parser and are re-imported above: the collective
# parser needs them too, and hlo_cost already imports from hlo_parser.


def split_computations(hlo: str):
    """-> (dict comp_name -> list[str] instruction lines, entry_name)."""
    comps: dict[str, list[str]] = {}
    entry = None
    cur: Optional[str] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HDR_RE.match(line.strip())
            if m:
                cur = m.group(2)
                comps[cur] = []
                if m.group(1):
                    entry = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        if line.strip():
            comps[cur].append(line.strip())
    return comps, entry


# ----------------------------------------------------------------------------
# Static trip-count inference.  XLA usually annotates counted loops with
# ``backend_config={"known_trip_count":{"n":...}}``, but not every jaxlib /
# pass pipeline does.  The scan-lowered loops it may omit follow a rigid
# shape we can read directly: the condition computation compares a tuple
# element against a constant (``compare(iter, N), direction=LT``), the body
# increments that element by a constant, and the parent initializes it from
# a constant.
# ----------------------------------------------------------------------------
_DIRECTION_RE = re.compile(r"direction=(\w+)")
_CONST_INT_RE = re.compile(r"constant\((-?\d+)\)")
_GTE_INDEX_RE = re.compile(r"index=(\d+)")
_FLIP_DIRECTION = {"LT": "GT", "LE": "GE", "GT": "LT", "GE": "LE",
                   "EQ": "EQ", "NE": "NE"}


def _line_defs(lines) -> dict[str, tuple[str, str]]:
    """name -> (opcode, full line) for one computation's instructions."""
    out: dict[str, tuple[str, str]] = {}
    for line in lines:
        nm = _NAME_RE.match(line)
        om = _OPCODE_RE.match(line)
        if nm and om:
            out[nm.group(1)] = (om.group(2), line)
    return out


def _const_value(name: str, defs: dict) -> Optional[int]:
    """Integer constant behind ``name``, traced through copy/convert."""
    for _ in range(8):
        if name not in defs:
            return None
        opcode, line = defs[name]
        if opcode == "constant":
            m = _CONST_INT_RE.search(line)
            return int(m.group(1)) if m else None
        if opcode in ("copy", "convert", "bitcast"):
            args = _operand_names(_call_args(line, opcode))
            if not args:
                return None
            name = args[0]
            continue
        return None
    return None


def _gte_index(name: str, defs: dict) -> Optional[int]:
    """Tuple index if ``name`` is a get-tuple-element of the loop carry."""
    if name in defs and defs[name][0] == "get-tuple-element":
        m = _GTE_INDEX_RE.search(defs[name][1])
        return int(m.group(1)) if m else None
    return None


def infer_trip_count(while_line: str, cond: str, body: str,
                     parent_lines: list, comps: dict) -> Optional[float]:
    """Trip count of a while loop with no ``known_trip_count`` annotation.

    Reads the ``compare(iter, constant)`` condition, the body's constant
    increment of the same tuple element, and the constant initializer in
    the parent's operand tuple.  Returns None when the loop does not match
    the counted-loop shape (data-dependent bound, missing increment, ...)
    -- the caller then falls back to counting the body once.
    """
    cdefs = _line_defs(comps.get(cond, []))
    # the compare must BE the condition root: a compare feeding an and/or
    # root means extra exit conditions (early exit, data-dependent), and
    # its bound is an upper limit, not the trip count -- don't guess.
    root = None
    for line in comps.get(cond, []):
        if line.lstrip().startswith("ROOT"):
            root = line
            break
    if root is None or not _OPCODE_RE.match(root) \
            or _OPCODE_RE.match(root).group(2) != "compare":
        return None
    names = _operand_names(_call_args(root, "compare"))
    if len(names) != 2:
        return None
    dm = _DIRECTION_RE.search(root)
    direction = dm.group(1) if dm else "LT"
    lhs_idx, rhs_idx = (_gte_index(n, cdefs) for n in names)
    lhs_const, rhs_const = (_const_value(n, cdefs) for n in names)
    if lhs_idx is not None and rhs_const is not None:
        idx, bound = lhs_idx, rhs_const
    elif rhs_idx is not None and lhs_const is not None:
        idx, bound = rhs_idx, lhs_const
        direction = _FLIP_DIRECTION.get(direction, direction)
    else:
        return None

    # increment: add(gte(idx), constant) at the body's top level.  If the
    # increment is not visible (folded into a fusion, non-constant step),
    # refuse to guess -- a wrong step silently scales every weighted metric.
    bdefs = _line_defs(comps.get(body, []))
    step = None
    for opcode, line in bdefs.values():
        if opcode != "add":
            continue
        args = _operand_names(_call_args(line, "add"))
        if len(args) != 2:
            continue
        consts = [c for c in (_const_value(a, bdefs) for a in args)
                  if c is not None]
        if consts and any(_gte_index(a, bdefs) == idx for a in args):
            step = consts[0]
            break
    if step is None:
        return None

    # initializer: the while operand tuple's element ``idx`` in the parent
    init = 0
    pdefs = _line_defs(parent_lines)
    wargs = _operand_names(_call_args(while_line, "while"))
    if wargs and wargs[0] in pdefs and pdefs[wargs[0]][0] == "tuple":
        targs = _operand_names(_call_args(pdefs[wargs[0]][1], "tuple"))
        if idx < len(targs):
            v = _const_value(targs[idx], pdefs)
            if v is not None:
                init = v

    if direction in ("LT", "LE"):
        if step <= 0:
            return None
        span = bound - init + (1 if direction == "LE" else 0)
        return float(max(0, -(-span // step)))
    if direction in ("GT", "GE"):
        if step >= 0:
            return None
        span = init - bound + (1 if direction == "GE" else 0)
        return float(max(0, -(-span // -step)))
    return None


def computation_multipliers(comps: dict, entry: str) -> dict[str, float]:
    """Execution count per computation, propagated through while/call/fusion.

    Trip counts come from XLA's ``known_trip_count`` annotation when
    present, else from static inference over the condition/body/parent
    (:func:`infer_trip_count`), else default to 1.
    """
    mult = {name: 0.0 for name in comps}
    if entry not in comps:
        return {name: 1.0 for name in comps}
    mult[entry] = 1.0
    for _ in range(64):  # fixed point; call graphs are shallow
        changed = False
        for name, lines in comps.items():
            m = mult.get(name, 0.0)
            if m == 0.0:
                continue
            for line in lines:
                wm = _WHILE_RE.search(line)
                if wm:
                    cond, body = wm.group(1), wm.group(2)
                    tm = _TRIP_RE.search(line)
                    if tm:
                        trips = float(tm.group(1))
                    else:
                        trips = infer_trip_count(line, cond, body, lines,
                                                 comps)
                        trips = trips if trips is not None else 1.0
                    for target, k in ((body, trips), (cond, trips + 1)):
                        new = m * k
                        if target in mult and new > mult[target]:
                            mult[target] = new
                            changed = True
                    continue
                cm = _CALLS_RE.search(line)
                if cm:
                    for target in re.split(r",\s*", cm.group(1)):
                        target = target.lstrip("%")
                        if target in mult and m > mult[target]:
                            mult[target] = m
                            changed = True
        if not changed:
            break
    return {k: (v if v > 0 else 1.0) for k, v in mult.items()}


@dataclasses.dataclass
class HloCost:
    flops: float
    bytes_logical: float
    bytes_hbm: float
    collectives: list[CollectiveOp]

    def collective_summary(self, algorithm: str = "ring") -> dict:
        from .hlo_parser import summarize
        return summarize(self.collectives, algorithm)


def _operand_dims(piece: str, symtab: dict[str, str]) -> Optional[list[int]]:
    """Shape dims of one operand: from the symbol table, else from the
    inline type annotation old jaxlibs print next to the operand name."""
    name = piece.split()[-1].lstrip("%")
    return _first_shape_dims(symtab.get(name, "")) \
        or _first_shape_dims(piece.rsplit("%", 1)[0] if "%" in piece else "")


def _dot_flops(line: str, symtab: dict[str, str]) -> float:
    """2 * prod(result) * prod(contracting dims of lhs)."""
    res = _first_shape_dims(line.split(" dot(")[0])
    if res is None:
        return 0.0
    n = 1
    for d in res:
        n *= d
    operands = _split_top_level(_call_args(line, "dot"))
    contract = 1
    cm = _DOT_CONTRACT_RE.search(line)
    if operands and cm is not None:
        lhs_dims = _operand_dims(operands[0], symtab) or []
        for idx in (int(x) for x in cm.group(1).split(",") if x):
            if idx < len(lhs_dims):
                contract *= lhs_dims[idx]
    return 2.0 * n * contract


def _conv_flops(line: str, symtab: dict[str, str]) -> float:
    res = _first_shape_dims(line.split(" convolution(")[0])
    if res is None:
        return 0.0
    n = 1
    for d in res:
        n *= d
    operands = _split_top_level(_call_args(line, "convolution"))
    if len(operands) < 2:
        return 0.0
    k_dims = _operand_dims(operands[1], symtab) or []
    kn = 1
    for d in k_dims:
        kn *= d
    dm = _DIM_LABELS_RE.search(line)
    if dm and k_dims:
        o_pos = dm.group(2).find("o")
        if 0 <= o_pos < len(k_dims) and k_dims[o_pos]:
            kn //= k_dims[o_pos]
    return 2.0 * n * kn


class HloAnalyzer:
    """Parsed module with symbol tables, multipliers and byte accounting."""

    def __init__(self, hlo: str):
        self.comps, self.entry = split_computations(hlo)
        self.mult = computation_multipliers(self.comps, self.entry or "")
        self.symtab: dict[str, dict[str, str]] = {}
        for name, lines in self.comps.items():
            st = {}
            for line in lines:
                nm = _NAME_RE.match(line)
                if nm:
                    st[nm.group(1)] = line[line.index("=") + 1:].split("(")[0]
            self.symtab[name] = st
        self._fusion_cache: dict[str, tuple[dict[int, int], Optional[int]]] = {}

    # ------------------------------------------------------------------
    def _fusion_profile(self, comp: str):
        """(param_idx -> effective read bytes, write bytes or None=default).

        Slice-aware: a parameter consumed only by dynamic-slice/gather reads
        the slice; a ROOT dynamic-update-slice writes the update only.
        """
        if comp in self._fusion_cache:
            return self._fusion_cache[comp]
        lines = self.comps.get(comp, [])
        st = self.symtab.get(comp, {})
        params: dict[str, tuple[int, int]] = {}
        defs: dict[str, tuple[str, list[str], str]] = {}  # name->(op,operands,type)
        for line in lines:
            pm = _PARAM_RE.match(line)
            if pm:
                params[pm.group(1)] = (int(pm.group(3)),
                                       _shapes_bytes(pm.group(2)))
            om = _OPCODE_RE.match(line)
            if om:
                nm = _NAME_RE.match(line)
                args = _call_args(line, om.group(2))
                ops = _operand_names(args) if args.strip() else []
                defs[nm.group(1)] = (om.group(2), ops, om.group(1))

        def origin(name: str) -> str:
            """Trace back through convert/bitcast/copy to the source."""
            seen = 0
            while name in defs and defs[name][0] in ("convert", "bitcast",
                                                     "copy", "reshape") \
                    and defs[name][1] and seen < 16:
                name = defs[name][1][0]
                seen += 1
            return name

        consumers: dict[str, list[tuple[str, str]]] = {n: [] for n in params}
        root_write: Optional[int] = None
        aliased_params: set[str] = set()
        for name, (opcode, ops, type_text) in defs.items():
            if opcode == "dynamic-update-slice" and len(ops) >= 2:
                # write = the update; the updated buffer is aliased, not read
                root_write = _shapes_bytes(st.get(ops[1], ""))
                buf = origin(ops[0])
                if buf in params:
                    aliased_params.add(buf)
            for o in ops:
                o2 = origin(o)
                if o2 in consumers:
                    consumers[o2].append((opcode, type_text))
        eff: dict[int, int] = {}
        for name, (idx, full) in params.items():
            if name in aliased_params:
                eff[idx] = 0
                continue
            cons = [c for c in consumers.get(name, [])
                    if c[0] not in ("convert", "bitcast", "copy", "reshape")]
            if cons and all(c[0] in ("dynamic-slice", "gather")
                            for c in cons):
                eff[idx] = sum(_shapes_bytes(c[1]) for c in cons)
            else:
                eff[idx] = full
        self._fusion_cache[comp] = (eff, root_write)
        return eff, root_write

    # ------------------------------------------------------------------
    def instr_bytes(self, comp: str, line: str, opcode: str,
                    type_text: str) -> int:
        """Effective HBM bytes for one top-level instruction."""
        st = self.symtab[comp]
        args = _call_args(line, opcode)
        operands = _operand_names(args) if args.strip() else []

        if opcode == "fusion":
            fm = _FUSION_CALLS_RE.search(line)
            eff, root_write = self._fusion_profile(fm.group(1)) if fm else ({}, None)
            read = 0
            for i, o in enumerate(operands):
                read += min(eff.get(i, 1 << 62), _shapes_bytes(st.get(o, "")))
            write = root_write if root_write is not None \
                else _shapes_bytes(type_text)
            return read + write
        if opcode == "dynamic-slice":
            return 2 * _shapes_bytes(type_text)
        if opcode == "dynamic-update-slice":
            upd = _shapes_bytes(st.get(operands[1], "")) if len(operands) > 1 \
                else 0
            return 2 * upd
        read = sum(_shapes_bytes(st.get(o, "")) for o in operands)
        return read + _shapes_bytes(type_text)

    def in_fusion_comp(self, name: str) -> bool:
        return name.startswith("fused_") or name.startswith("wrapped_") \
            or ".fused" in name

    _PURE_CONVERT_OPS = {"parameter", "convert", "bitcast", "copy",
                         "constant", "tuple", "get-tuple-element", "reshape"}

    def is_pure_convert_fusion(self, comp: str, line: str) -> bool:
        """Fusions that only change dtype/layout — artifacts of XLA:CPU's
        bf16->f32 all-reduce promotion; they do not exist on the TPU
        pipeline and are excluded from the HBM roofline term."""
        fm = _FUSION_CALLS_RE.search(line)
        if not fm:
            return False
        for l in self.comps.get(fm.group(1), ()):
            om = _OPCODE_RE.match(l)
            if om and om.group(2) not in self._PURE_CONVERT_OPS:
                return False
        return True

    # ------------------------------------------------------------------
    def iter_instrs(self):
        for name, lines in self.comps.items():
            m = self.mult.get(name, 1.0)
            for line in lines:
                om = _OPCODE_RE.match(line)
                if om:
                    yield name, m, line, om.group(1), om.group(2)


def analyze_hlo(hlo: str) -> HloCost:
    az = HloAnalyzer(hlo)
    flops = 0.0
    bytes_logical = 0.0
    bytes_hbm = 0.0
    for comp, m, line, type_text, opcode in az.iter_instrs():
        if " dot(" in line:
            flops += m * _dot_flops(line, az.symtab[comp])
        elif " convolution(" in line:
            flops += m * _conv_flops(line, az.symtab[comp])
        if opcode in _SKIP_BYTES_OPS:
            continue
        b = az.instr_bytes(comp, line, opcode, type_text)
        bytes_logical += m * b
        if not az.in_fusion_comp(comp) and not (
                opcode == "fusion"
                and az.is_pure_convert_fusion(comp, line)):
            bytes_hbm += m * b

    collectives: list[CollectiveOp] = []
    for name, lines in az.comps.items():
        m = az.mult.get(name, 1.0)
        for op in parse_hlo_collectives("\n".join(lines)):
            op.weight = m
            collectives.append(op)
    return HloCost(flops=flops, bytes_logical=bytes_logical,
                   bytes_hbm=bytes_hbm, collectives=collectives)


def top_ops(hlo: str, n: int = 20, by: str = "bytes"):
    """Largest contributors to a roofline term — the 'profile' the perf loop
    reads (no wall-clock trace exists on a CPU dry-run).

    Returns rows: (weighted_total, weight, opcode, op_name_metadata, line).
    """
    az = HloAnalyzer(hlo)
    rows = []
    for comp, m, line, type_text, opcode in az.iter_instrs():
        if by == "flops":
            if " dot(" in line:
                val = _dot_flops(line, az.symtab[comp])
            elif " convolution(" in line:
                val = _conv_flops(line, az.symtab[comp])
            else:
                continue
        elif by == "collective":
            ops = parse_hlo_collectives(line)
            if not ops:
                continue
            val = ops[0].wire_bytes_per_rank() * ops[0].group_size \
                * ops[0].num_groups
        else:
            if opcode in _SKIP_BYTES_OPS or az.in_fusion_comp(comp):
                continue
            val = az.instr_bytes(comp, line, opcode, type_text)
        if val <= 0:
            continue
        onm = _OPNAME_RE.search(line)
        rows.append((val * m, m, opcode, onm.group(1) if onm else "",
                     line[:200]))
    rows.sort(key=lambda r: -r[0])
    return rows[:n]
