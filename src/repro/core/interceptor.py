"""Trace-time collective interception — the LD_PRELOAD analogue for JAX.

The paper's ComScribe preloads a shim over ``ncclAllReduce`` & friends so that
every collective an application issues is recorded without touching its
source.  A JAX application does not *call* a communication library at runtime;
it *traces* collective primitives (``psum``, ``all_gather``, ...) into a
program.  The faithful analogue is therefore a scoped hook on the ``bind`` of
every parallel primitive: while the :class:`CollectiveInterceptor` context is
active, any trace that executes — including inside ``jax.jit`` — logs a
:class:`~repro.core.events.TraceEvent` per collective, with primitive kind,
operand shapes/dtypes and mesh axes, then defers to the original bind.

This captures the *logical* (application-issued) communication.  The
*physical* schedule (what actually hits the wire, including compiler-inserted
resharding) comes from :mod:`repro.core.hlo_parser`; the monitor reports both
and their diff.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Callable, Optional

from jax._src.lax import parallel as _lax_parallel

from .events import TraceEvent, jax_shape

# primitive object name -> (logical primitive label, NCCL-style name)
_HOOKED_PRIMITIVES = {
    "psum_p": ("psum", "AllReduce"),
    "psum_invariant_p": ("psum", "AllReduce"),
    "unreduced_psum_p": ("psum", "AllReduce"),
    "pmax_p": ("pmax", "AllReduce"),
    "pmin_p": ("pmin", "AllReduce"),
    "all_gather_p": ("all_gather", "AllGather"),
    "all_gather_invariant_p": ("all_gather", "AllGather"),
    "reduce_scatter_p": ("psum_scatter", "ReduceScatter"),
    "unreduced_reduce_scatter_p": ("psum_scatter", "ReduceScatter"),
    "all_to_all_p": ("all_to_all", "AllToAll"),
    "ragged_all_to_all_p": ("ragged_all_to_all", "AllToAll"),
    "ppermute_p": ("ppermute", "SendRecv"),
    "pgather_p": ("pgather", "Gather"),
}

_lock = threading.Lock()


def traced_summary(events) -> dict:
    """Paper Table-2 style logical summary over trace events.

    Module-level so multi-capture sessions (which accumulate events across
    many interceptor scopes) summarize exactly like a single interceptor.
    """
    table: dict[str, dict] = {}
    for ev in events:
        name = getattr(ev, "nccl_name", ev.primitive)
        row = table.setdefault(name, {"calls": 0, "payload_bytes": 0})
        row["calls"] += 1
        row["payload_bytes"] += ev.payload_bytes
    return table


def _axis_names(params: dict) -> tuple[str, ...]:
    ax = params.get("axes", params.get("axis_name", ()))
    if ax is None:
        ax = ()
    if isinstance(ax, (str, int)):
        ax = (ax,)
    return tuple(str(a) for a in ax)


class CollectiveInterceptor:
    """Scoped trace-time logger for JAX collective primitives.

    Usage::

        with CollectiveInterceptor(mesh=mesh) as icpt:
            jitted = jax.jit(step).lower(*args)    # trace happens here
        icpt.events   # -> list[TraceEvent]

    ``mesh`` (optional) resolves axis names to sizes so each event carries its
    group size.  Nested interceptors each observe every event (innermost
    first); hooks are reference-counted so nesting is safe.
    """

    def __init__(self, mesh=None, callback: Optional[Callable] = None):
        self.events: list[TraceEvent] = []
        self._axis_sizes: dict[str, int] = {}
        self._callback = callback
        if mesh is not None:
            self._axis_sizes = dict(
                zip(map(str, mesh.axis_names), mesh.devices.shape)
            )

    # -- book-keeping shared across (possibly nested) interceptors ---------
    _active: list["CollectiveInterceptor"] = []
    _originals: dict[str, Callable] = {}

    def __enter__(self):
        with _lock:
            if not CollectiveInterceptor._active:
                self._install()
            CollectiveInterceptor._active.append(self)
        return self

    def __exit__(self, *exc):
        with _lock:
            CollectiveInterceptor._active.remove(self)
            if not CollectiveInterceptor._active:
                self._uninstall()
        return False

    # -- hook plumbing ------------------------------------------------------
    @classmethod
    def _install(cls):
        for prim_name, (label, nccl) in _HOOKED_PRIMITIVES.items():
            prim = getattr(_lax_parallel, prim_name, None)
            if prim is None:  # tolerate jax version drift
                continue
            orig = prim.bind
            cls._originals[prim_name] = orig

            def make_hook(label=label, nccl=nccl, orig=orig):
                def hooked_bind(*args, **params):
                    for icpt in reversed(CollectiveInterceptor._active):
                        icpt._record(label, nccl, args, params)
                    return orig(*args, **params)

                return hooked_bind

            prim.bind = make_hook()

    @classmethod
    def _uninstall(cls):
        for prim_name, orig in cls._originals.items():
            prim = getattr(_lax_parallel, prim_name, None)
            if prim is not None:
                try:
                    del prim.bind  # remove instance attr, reveal class method
                except AttributeError:
                    prim.bind = orig
        cls._originals.clear()

    # -- event recording ----------------------------------------------------
    def _record(self, label: str, nccl: str, args, params):
        axes = _axis_names(params)
        size = 1
        known = True
        for a in axes:
            if a in self._axis_sizes:
                size *= self._axis_sizes[a]
            else:
                known = False
        shapes = []
        for a in args:
            if hasattr(a, "shape") and hasattr(a, "dtype"):
                shapes.append(jax_shape(a))
        ev = TraceEvent(
            primitive=label,
            axis_name=",".join(axes),
            arg_shapes=shapes,
            axis_size=size if known and axes else None,
        )
        ev.nccl_name = nccl  # annotate with the paper's primitive taxonomy
        self.events.append(ev)
        if self._callback is not None:
            self._callback(ev)

    # -- summaries (paper Table 2 style, logical view) -----------------------
    def summary(self) -> dict:
        return traced_summary(self.events)


@contextlib.contextmanager
def intercept(mesh=None):
    """Functional alias: ``with intercept(mesh) as icpt: ...``."""
    with CollectiveInterceptor(mesh=mesh) as icpt:
        yield icpt
