"""Static communication lint: anti-pattern findings with modeled savings.

The comm matrix says *what* moved; this pass says *what to change*.  Each
rule walks the captured HLO module(s) (def-use ground truth) and/or the
per-op :class:`~repro.core.decompose.CollectiveSchedule`s, and prices its
suggested fix by re-running ``decompose``/``time_split`` under the
alternative -- modeled seconds and DCN bytes, never hand-waved constants.
Every finding keeps the invariant ``0 <= est_savings_s <= est_current_s``
(property-tested): a fix can at best eliminate the op's current modeled
time.

Rules (see :data:`RULES`):

====================  ========  ==================================================
rule id               severity  anti-pattern
====================  ========  ==================================================
small-ar-bucketing    warn      runs of latency-bound all-reduces that should fuse
flat-ring-multipod    error     ring/tree on a pod-spanning group that decomposes
allgather-then-slice  warn      all-gather consumed only through slices
redundant-collective  error     identical collective executed twice, same operands
dcn-permute           warn      DCN-crossing permute with a pod-local device order
wire-dtype-waste      warn      f32 on the wire inside a bf16 producer/consumer
skewed-a2a            warn      irregular all-to-all with a >2x hot rank (straggler)
====================  ========  ==================================================

Entry points: :func:`lint_ops` (module-level),
:meth:`~repro.core.views.CommView.lint` (lazy/memoized per binding),
``CommReport.lint_table()``, ``python -m repro lint`` (CI exit codes), and
``sweep --lint`` columns.  Findings serialize in the schema-v7 ``lint``
section.

HLO def-use rules need the captures' module text (``hlo_texts``); the
schedule rules run on the op stream alone.  Without a topology the
structural rules still fire, with zero modeled savings.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterable, Optional

import numpy as np

from . import hlo_cost, hlo_parser
from .decompose import (CommPhase, CollectiveSchedule, HIERARCHICAL_KINDS,
                        cached_decompose, decompose,  # noqa: F401
                        hierarchical_decomposition)
from .events import CollectiveOp, Shape
from .topology import MeshTopology

SEVERITIES = ("info", "warn", "error")
_SEV_RANK = {s: i for i, s in enumerate(SEVERITIES)}


def severity_rank(severity: str) -> int:
    """info < warn < error (for ``--fail-on`` thresholds and sorting)."""
    return _SEV_RANK[severity]


@dataclasses.dataclass
class LintFinding:
    """One priced anti-pattern instance.

    ``est_current_s`` is the modeled time of the flagged op(s) as
    captured; ``est_savings_s`` the modeled delta to the suggested
    alternative (both execution-weighted, clamped to the invariant
    ``0 <= est_savings_s <= est_current_s``).  ``est_dcn_bytes_saved``
    prices the DCN-traffic delta the same way.
    """

    rule_id: str
    severity: str                  # "info" | "warn" | "error"
    op_names: list[str]
    phase: str
    message: str
    est_savings_s: float = 0.0
    est_dcn_bytes_saved: float = 0.0
    suggested_fix: str = ""
    est_current_s: float = 0.0

    def to_dict(self) -> dict:
        return {
            "rule_id": self.rule_id,
            "severity": self.severity,
            "op_names": list(self.op_names),
            "phase": self.phase,
            "message": self.message,
            "est_savings_s": float(self.est_savings_s),
            "est_dcn_bytes_saved": float(self.est_dcn_bytes_saved),
            "suggested_fix": self.suggested_fix,
            "est_current_s": float(self.est_current_s),
        }

    @classmethod
    def from_dict(cls, d: dict) -> "LintFinding":
        return cls(
            rule_id=d["rule_id"],
            severity=d["severity"],
            op_names=list(d.get("op_names", [])),
            phase=d.get("phase", ""),
            message=d.get("message", ""),
            est_savings_s=float(d.get("est_savings_s", 0.0)),
            est_dcn_bytes_saved=float(d.get("est_dcn_bytes_saved", 0.0)),
            suggested_fix=d.get("suggested_fix", ""),
            est_current_s=float(d.get("est_current_s", 0.0)),
        )


def max_severity(findings: Iterable[LintFinding]) -> Optional[str]:
    """Highest severity present (None for an empty list)."""
    best = None
    for f in findings:
        if best is None or severity_rank(f.severity) > severity_rank(best):
            best = f.severity
    return best


# ---------------------------------------------------------------------------
# Module def-use index: one per captured HLO text.
# ---------------------------------------------------------------------------
# opcodes that forward their operand's value unchanged -- the def-use walk
# looks *through* them when resolving a collective's effective consumers
_PASSTHROUGH_OPS = {"get-tuple-element", "copy", "bitcast", "reshape"}


@dataclasses.dataclass
class _Def:
    opcode: str
    type_text: str                 # result-type text ('' when unparsed)
    operands: list[str]


class _ModuleIndex:
    """Per-computation def-use tables of one compiled module."""

    def __init__(self, hlo_text: str):
        comps, _entry = hlo_cost.split_computations(hlo_text)
        self.defs: dict[str, dict[str, _Def]] = {}
        self.users: dict[str, dict[str, list[str]]] = {}
        self.collectives: dict[str, list[CollectiveOp]] = {}
        for comp, lines in comps.items():
            defs: dict[str, _Def] = {}
            users: dict[str, list[str]] = {}
            for line in lines:
                nm = hlo_cost._NAME_RE.match(line)
                om = hlo_cost._OPCODE_RE.match(line)
                if not (nm and om):
                    continue
                name, opcode = nm.group(1), om.group(2)
                args = hlo_parser._call_args(line, opcode)
                operands = (hlo_parser._operand_names(args)
                            if args.strip() else [])
                defs[name] = _Def(opcode, om.group(1), operands)
                for operand in operands:
                    users.setdefault(operand, []).append(name)
            self.defs[comp] = defs
            self.users[comp] = users
            colls = hlo_parser.parse_hlo_collectives("\n".join(lines))
            if colls:
                self.collectives[comp] = colls

    def result_dtype(self, comp: str, name: str) -> Optional[str]:
        """dtype of ``name``'s (first) result shape, None when unknown."""
        d = self.defs[comp].get(name)
        if d is None:
            return None
        m = hlo_parser._SHAPE_RE.search(d.type_text)
        return m.group(1) if m else None

    def result_bytes(self, comp: str, name: str) -> int:
        d = self.defs[comp].get(name)
        if d is None:
            return 0
        shapes = []
        for m in hlo_parser._SHAPE_RE.finditer(d.type_text):
            dims = tuple(int(x) for x in m.group(2).split(",") if x != "")
            shapes.append(Shape(m.group(1), dims))
        return sum(s.bytes for s in shapes)

    def effective_users(self, comp: str,
                        name: str) -> Optional[list[tuple[str, str]]]:
        """Terminal ``(name, opcode)`` consumers of ``name``, looking
        through pass-through ops and async ``*-done`` halves.  ``None``
        when any consumer is opaque (tuple/ROOT/cross-computation) -- the
        conservative answer for rules that need the FULL consumer set."""
        defs, users = self.defs[comp], self.users[comp]
        out: list[tuple[str, str]] = []
        frontier = [name]
        seen = {name}
        while frontier:
            cur = frontier.pop()
            consumers = users.get(cur)
            if not consumers:
                return None            # ROOT or escaping value: opaque
            for u in consumers:
                if u in seen:
                    continue
                seen.add(u)
                d = defs.get(u)
                if d is None:
                    return None
                if d.opcode in _PASSTHROUGH_OPS or d.opcode.endswith("-done"):
                    frontier.append(u)
                elif d.opcode == "tuple":
                    return None        # re-packaged: consumers unknowable
                else:
                    out.append((u, d.opcode))
        return out


# ---------------------------------------------------------------------------
# Rule context: ops + topology + module indexes, with shared pricing.
# ---------------------------------------------------------------------------
class LintContext:
    """Everything a rule reads: the op stream of one view binding, its
    topology/algorithm, and lazily-built module def-use indexes."""

    def __init__(self, ops, topo: Optional[MeshTopology],
                 algorithm: str, hlo_texts: Iterable[str]):
        self.ops: list[CollectiveOp] = list(ops)
        self.topo = topo
        self.algorithm = algorithm
        self.hlo_texts = [t for t in hlo_texts if t]
        # module-parsed collectives are re-matched to the view's ops by
        # instruction name, so phase-filtered views lint only their ops
        # and findings inherit weight/phase from the analyzed stream
        self.by_name: dict[str, CollectiveOp] = {}
        for op in self.ops:
            self.by_name.setdefault(op.name, op)
        self._modules: Optional[list[_ModuleIndex]] = None

    @property
    def modules(self) -> list[_ModuleIndex]:
        if self._modules is None:
            self._modules = [_ModuleIndex(t) for t in self.hlo_texts]
        return self._modules

    # -- pricing (one execution; callers apply op.weight) -------------------
    def op_time(self, op: CollectiveOp, algorithm: Optional[str] = None, *,
                include_latency: bool = True) -> float:
        if self.topo is None:
            return 0.0
        # memoized: a rule pricing its suggested alternative re-decomposes
        # the same shapes the capture already decomposed
        sched = cached_decompose(op, algorithm or self.algorithm, self.topo,
                                 warn=False)
        ici, dcn = sched.time_split(self.topo,
                                    include_latency=include_latency)
        return ici + dcn

    def sched_time(self, sched: CollectiveSchedule) -> float:
        if self.topo is None:
            return 0.0
        ici, dcn = sched.time_split(self.topo)
        return ici + dcn

    def dcn_bytes(self, op: CollectiveOp,
                  algorithm: Optional[str] = None) -> float:
        if self.topo is None:
            return 0.0
        sched = cached_decompose(op, algorithm or self.algorithm, self.topo,
                                 warn=False)
        return sum(ph.total_send_bytes() for ph in sched.phases
                   if ph.tier == "dcn")


def _clamp(savings: float, current: float) -> tuple[float, float]:
    """Enforce the finding invariant 0 <= savings <= current."""
    current = max(0.0, float(current))
    return min(max(0.0, float(savings)), current), current


# ---------------------------------------------------------------------------
# Rule 1: small-collective bucketing.
# ---------------------------------------------------------------------------
def _rule_small_ar_bucketing(ctx: LintContext) -> list[LintFinding]:
    """Consecutive latency-bound all-reduces over the same groups should
    fuse into one bucket: each op below the bandwidth crossover pays the
    full per-hop latency chain for a few bytes, and one fused op pays it
    once.  Priced as sum-of-current minus the fused op's modeled time."""
    if ctx.topo is None:
        return []
    findings: list[LintFinding] = []
    run: list[CollectiveOp] = []

    def flush():
        if len(run) < 2:
            run.clear()
            return
        ops = list(run)
        run.clear()
        # latency-bound: the per-hop latency term dominates the bandwidth
        # term (full time at least twice the latency-free time)
        for op in ops:
            t_full = ctx.op_time(op)
            if t_full <= 0.0 or t_full < 2.0 * ctx.op_time(
                    op, include_latency=False):
                return
        w = max(1.0, ops[0].weight)
        current = sum(ctx.op_time(op) for op in ops) * w
        fused = dataclasses.replace(
            ops[0],
            name=f"fused({ops[0].name}..{ops[-1].name})",
            result_shapes=[s for op in ops for s in op.result_shapes])
        fused_t = ctx.op_time(fused) * w
        savings, current = _clamp(current - fused_t, current)
        dcn_cur = sum(ctx.dcn_bytes(op) for op in ops) * w
        dcn_saved = max(0.0, dcn_cur - ctx.dcn_bytes(fused) * w)
        total_bytes = sum(op.result_bytes for op in ops)
        findings.append(LintFinding(
            rule_id="small-ar-bucketing", severity="warn",
            op_names=[op.name for op in ops], phase=ops[0].phase,
            message=(f"{len(ops)} consecutive latency-bound all-reduces "
                     f"({total_bytes} B total) over the same replica "
                     "groups; each pays the full latency chain for a "
                     "sub-crossover payload"),
            est_savings_s=savings, est_dcn_bytes_saved=dcn_saved,
            est_current_s=current,
            suggested_fix=("fuse into one bucketed all-reduce (e.g. "
                           "ddp.allreduce_bucketed / larger bucket_mb) so "
                           "the latency chain is paid once per bucket"),
        ))

    prev_key = None
    for op in ctx.ops:
        key = (op.kind, op.phase, repr(op.replica_groups), op.weight)
        if op.kind != "all-reduce":
            flush()
            prev_key = None
            continue
        if key != prev_key:
            flush()
        run.append(op)
        prev_key = key
    flush()
    return findings


# ---------------------------------------------------------------------------
# Rule 2: flat ring/tree on a multi-pod group that decomposes.
# ---------------------------------------------------------------------------
def _rule_flat_ring_multipod(ctx: LintContext) -> list[LintFinding]:
    """A pod-spanning replica group bound to ring/tree where the shared
    hierarchical predicate holds sends the whole payload across DCN;
    priced current-vs-hierarchical via the schedule engine."""
    if ctx.topo is None or ctx.algorithm == "hierarchical":
        return []
    findings = []
    for op in ctx.ops:
        if op.kind not in HIERARCHICAL_KINDS:
            continue
        if not any(hierarchical_decomposition(op.kind, g, ctx.topo)
                   for g in op.replica_groups):
            continue
        w = max(1.0, op.weight)
        current = ctx.op_time(op) * w
        hier = ctx.op_time(op, "hierarchical") * w
        savings, current = _clamp(current - hier, current)
        if savings <= 0.0:
            continue
        dcn_saved = max(0.0, (ctx.dcn_bytes(op)
                              - ctx.dcn_bytes(op, "hierarchical")) * w)
        findings.append(LintFinding(
            rule_id="flat-ring-multipod", severity="error",
            op_names=[op.name], phase=op.phase,
            message=(f"{op.kind} over {op.group_size} ranks spans "
                     f"{ctx.topo.num_pods} pods under "
                     f"{ctx.algorithm!r}: the flat schedule streams the "
                     "full payload over DCN where a hierarchical "
                     "intra-pod + cross-pod decomposition exists"),
            est_savings_s=savings, est_dcn_bytes_saved=dcn_saved,
            est_current_s=current,
            suggested_fix=("bind algorithm='hierarchical' (pod-local "
                           "reduce/gather + cross-pod shard exchange)"),
        ))
    return findings


# ---------------------------------------------------------------------------
# Rule 3: all-gather consumed only through slices.
# ---------------------------------------------------------------------------
def _rule_allgather_then_slice(ctx: LintContext) -> list[LintFinding]:
    """An all-gather whose every effective consumer is slice/dynamic-slice
    materializes the full gathered tensor to keep a fraction: the slice
    could move before the collective (sharded compute, or reduce-scatter
    when the producer is a reduction).  Priced as the all-gather's current
    time minus an all-gather of only the consumed bytes."""
    findings = []
    for mod in ctx.modules:
        for comp, colls in mod.collectives.items():
            for parsed in colls:
                if parsed.kind != "all-gather":
                    continue
                op = ctx.by_name.get(parsed.name)
                if op is None:
                    continue
                users = mod.effective_users(comp, parsed.name)
                if not users:
                    continue
                if not all(opc in ("slice", "dynamic-slice")
                           for _, opc in users):
                    continue
                consumed = sum(mod.result_bytes(comp, u)
                               for u in {u for u, _ in users})
                if consumed <= 0 or consumed >= op.result_bytes:
                    continue
                w = max(1.0, op.weight)
                current = ctx.op_time(op) * w
                alt = dataclasses.replace(
                    op, result_shapes=[Shape("u8", (int(consumed),))])
                savings, current = _clamp(current - ctx.op_time(alt) * w,
                                          current)
                dcn_saved = max(0.0, (ctx.dcn_bytes(op)
                                      - ctx.dcn_bytes(alt)) * w)
                findings.append(LintFinding(
                    rule_id="allgather-then-slice", severity="warn",
                    op_names=[op.name], phase=op.phase,
                    message=(f"all-gather of {op.result_bytes} B is "
                             "consumed only through "
                             f"{sorted({o for _, o in users})} keeping "
                             f"{consumed} B; the full gather is wasted "
                             "wire traffic"),
                    est_savings_s=savings, est_dcn_bytes_saved=dcn_saved,
                    est_current_s=current,
                    suggested_fix=("shard the consumer (keep compute on "
                                   "the local shard) or use "
                                   "reduce-scatter / a smaller gather of "
                                   "just the consumed region"),
                ))
    return findings


# ---------------------------------------------------------------------------
# Rule 4: redundant collective (same kind, operands, groups).
# ---------------------------------------------------------------------------
def _rule_redundant_collective(ctx: LintContext) -> list[LintFinding]:
    """Two collectives with identical operands, replica groups and
    attributes inside one computation compute the same value twice: HLO is
    SSA, so the shared operand cannot have been rewritten in between.
    Priced as (k-1) executions of the duplicate."""
    findings = []
    for mod in ctx.modules:
        for comp, colls in mod.collectives.items():
            groups: dict[tuple, list[CollectiveOp]] = {}
            for parsed in colls:
                if not parsed.operand_names:
                    continue
                op = ctx.by_name.get(parsed.name)
                if op is None:
                    continue
                # channel_id deliberately excluded: two channels moving
                # the same operands over the same groups are still the
                # same transfer
                key = (parsed.kind, tuple(parsed.operand_names),
                       repr(parsed.replica_groups),
                       repr(parsed.dimensions),
                       repr(parsed.source_target_pairs),
                       parsed.use_global_device_ids)
                groups.setdefault(key, []).append(op)
            for key, dupes in groups.items():
                if len(dupes) < 2:
                    continue
                k = len(dupes)
                w = max(1.0, dupes[0].weight)
                per_exec = ctx.op_time(dupes[0]) * w
                current = per_exec * k
                savings, current = _clamp(per_exec * (k - 1), current)
                dcn_saved = max(
                    0.0, ctx.dcn_bytes(dupes[0]) * w * (k - 1))
                findings.append(LintFinding(
                    rule_id="redundant-collective", severity="error",
                    op_names=[op.name for op in dupes],
                    phase=dupes[0].phase,
                    message=(f"{k} identical {dupes[0].kind} ops over "
                             f"operands {list(key[1])} with the same "
                             "replica groups and no intervening writer "
                             "(SSA): the transfer runs "
                             f"{k}x for one value"),
                    est_savings_s=savings, est_dcn_bytes_saved=dcn_saved,
                    est_current_s=current,
                    suggested_fix=("deduplicate at the source (reuse the "
                                   "first result; check for repeated "
                                   "psum/all_gather calls on the same "
                                   "value across the step)"),
                ))
    return findings


# ---------------------------------------------------------------------------
# Rule 5: DCN-crossing permute with an intra-pod alternative.
# ---------------------------------------------------------------------------
def _components(pairs: list[tuple[int, int]]) -> list[list[int]]:
    """Connected components of the permute's communication graph: every
    device set that must share a pod for the permute to stay on ICI."""
    parent: dict[int, int] = {}

    def find(x: int) -> int:
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for a, b in pairs:
        parent.setdefault(a, a)
        parent.setdefault(b, b)
        ra, rb = find(a), find(b)
        if ra != rb:
            parent[ra] = rb
    comps: dict[int, list[int]] = {}
    for d in parent:
        comps.setdefault(find(d), []).append(d)
    return [sorted(c) for c in comps.values()]


def _rule_dcn_permute(ctx: LintContext) -> list[LintFinding]:
    """A collective-permute whose pairs cross pods is billed on DCN, but
    when the permutation's connected device sets each fit inside a pod
    (first-fit packed into the pod capacity), a different device order
    keeps every hop on ICI.  Priced current-vs-all-pairs-on-ICI."""
    topo = ctx.topo
    if topo is None or topo.num_pods <= 1:
        return []
    findings = []
    cap = topo.devices_per_pod
    for op in ctx.ops:
        if op.kind != "collective-permute" or not op.source_target_pairs:
            continue
        if not any(topo.pod_index(a) != topo.pod_index(b)
                   for a, b in op.source_target_pairs):
            continue
        comps = _components(op.source_target_pairs)
        # first-fit decreasing into num_pods bins of pod capacity: does a
        # device reordering exist that keeps each component pod-local?
        bins = [0] * topo.num_pods
        feasible = True
        for comp in sorted(comps, key=len, reverse=True):
            if len(comp) > cap:
                feasible = False
                break
            for i, used in enumerate(bins):
                if used + len(comp) <= cap:
                    bins[i] = used + len(comp)
                    break
            else:
                feasible = False
                break
        if not feasible:
            continue
        w = max(1.0, op.weight)
        current = ctx.op_time(op) * w
        alt = CollectiveSchedule(op.kind, ctx.algorithm, [CommPhase(
            kind=op.kind, tier="ici", groups=None,
            bytes_per_rank=float(op.result_bytes), latency_hops=1.0,
            structure="pairs",
            payload=float(op.result_bytes) * op.num_groups,
            pairs=np.asarray(op.source_target_pairs, dtype=np.intp))])
        savings, current = _clamp(current - ctx.sched_time(alt) * w,
                                  current)
        if savings <= 0.0:
            continue
        n_cross = sum(1 for a, b in op.source_target_pairs
                      if topo.pod_index(a) != topo.pod_index(b))
        findings.append(LintFinding(
            rule_id="dcn-permute", severity="warn",
            op_names=[op.name], phase=op.phase,
            message=(f"collective-permute routes {n_cross} of "
                     f"{len(op.source_target_pairs)} pairs across DCN, "
                     "but its communicating device sets each fit inside "
                     "one pod -- a pod-local device order keeps every "
                     "hop on ICI"),
            est_savings_s=savings,
            est_dcn_bytes_saved=max(0.0, ctx.dcn_bytes(op) * w),
            est_current_s=current,
            suggested_fix=("reorder the mesh's device assignment (or the "
                           "permute axis layout) so communicating ranks "
                           "share a pod"),
        ))
    return findings


# ---------------------------------------------------------------------------
# Rule 6: f32 on the wire inside a bf16 chain.
# ---------------------------------------------------------------------------
def _rule_wire_dtype_waste(ctx: LintContext) -> list[LintFinding]:
    """A collective moving f32 whose producers are bf16->f32 converts, or
    whose every effective consumer converts straight back to bf16, sends
    double the bytes the computation needs.  (XLA:CPU's own f32 promotion
    of bf16 all-reduces is already accounted at bf16 by the parser and is
    not flagged.)  Priced against the same op at bf16 width."""
    findings = []
    for mod in ctx.modules:
        for comp, colls in mod.collectives.items():
            for parsed in colls:
                if not any(s.dtype == "f32" for s in parsed.result_shapes):
                    continue
                op = ctx.by_name.get(parsed.name)
                if op is None or not any(
                        s.dtype == "f32" for s in op.result_shapes):
                    continue
                defs = mod.defs[comp]
                prod_bf16 = bool(parsed.operand_names) and all(
                    defs.get(o) is not None
                    and defs[o].opcode == "convert"
                    and defs[o].operands
                    and mod.result_dtype(comp, defs[o].operands[0])
                    == "bf16"
                    for o in parsed.operand_names)
                users = mod.effective_users(comp, parsed.name)
                cons_bf16 = bool(users) and all(
                    opc == "convert"
                    and mod.result_dtype(comp, u) == "bf16"
                    for u, opc in users)
                if not (prod_bf16 or cons_bf16):
                    continue
                w = max(1.0, op.weight)
                current = ctx.op_time(op) * w
                alt = dataclasses.replace(op, result_shapes=[
                    Shape("bf16", s.dims) if s.dtype == "f32" else s
                    for s in op.result_shapes])
                savings, current = _clamp(current - ctx.op_time(alt) * w,
                                          current)
                dcn_saved = max(0.0, (ctx.dcn_bytes(op)
                                      - ctx.dcn_bytes(alt)) * w)
                side = ("producers are bf16->f32 converts" if prod_bf16
                        else "every consumer converts back to bf16")
                findings.append(LintFinding(
                    rule_id="wire-dtype-waste", severity="warn",
                    op_names=[op.name], phase=op.phase,
                    message=(f"{op.kind} moves {op.result_bytes} B of "
                             f"f32 but {side}: the wire width is double "
                             "what the computation keeps"),
                    est_savings_s=savings, est_dcn_bytes_saved=dcn_saved,
                    est_current_s=current,
                    suggested_fix=("run the collective at bf16 (convert "
                                   "before, not after), halving wire "
                                   "bytes"),
                ))
    return findings


# ---------------------------------------------------------------------------
# Rule 7: skewed all-to-all (hot-rank straggler).
# ---------------------------------------------------------------------------
_SKEW_THRESHOLD = 2.0
_A2A_LINT_KINDS = ("all-to-all", "ragged-all-to-all")


def _rule_skewed_a2a(ctx: LintContext) -> list[LintFinding]:
    """An irregular all-to-all whose max per-rank bytes exceed twice the
    mean is straggler-bound: every phase completes when its hottest rank
    does, so the collective runs at the hot rank's time while the other
    ranks idle.  Priced as the op's current (max-billed) modeled time
    minus the same op with its bytes rebalanced to the mean -- i.e. the
    time a load-balanced routing (capacity-factor cap, expert replication,
    or re-sharding the hot expert) would achieve with the same total
    payload."""
    if ctx.topo is None:
        return []
    findings = []
    for op in ctx.ops:
        if op.kind not in _A2A_LINT_KINDS:
            continue
        skew = op.skew()
        if skew <= _SKEW_THRESHOLD:
            continue
        vec = op.byte_vector()
        if vec is None:
            continue
        n = int(vec.size)
        w = max(1.0, op.weight)
        current = ctx.op_time(op) * w
        balanced = dataclasses.replace(
            op, bytes_per_rank_vec=[float(vec.sum()) / n] * n)
        savings, current = _clamp(current - ctx.op_time(balanced) * w,
                                  current)
        if savings <= 0.0:
            continue
        dcn_saved = max(0.0, (ctx.dcn_bytes(op)
                              - ctx.dcn_bytes(balanced)) * w)
        hot = int(np.argmax(vec))
        findings.append(LintFinding(
            rule_id="skewed-a2a", severity="warn",
            op_names=[op.name], phase=op.phase,
            message=(f"{op.kind} over {op.group_size} ranks is "
                     f"{skew:.2f}x skewed (rank {hot} sends "
                     f"{float(vec[hot]):.0f} B vs {float(vec.mean()):.0f} B "
                     "mean): the schedule completes at the hot rank's "
                     "pace while the rest idle"),
            est_savings_s=savings, est_dcn_bytes_saved=dcn_saved,
            est_current_s=current,
            suggested_fix=("rebalance the routing (capacity-factor cap, "
                           "replicate the hot expert, or re-shard it "
                           "across ranks) so per-rank bytes approach the "
                           "mean"),
        ))
    return findings


# ---------------------------------------------------------------------------
# Registry and entry point.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class LintRule:
    """One registered rule (the registry is the docs' rule table)."""

    rule_id: str
    severity: str
    title: str
    fn: Callable[[LintContext], list[LintFinding]]


RULES: tuple[LintRule, ...] = (
    LintRule("small-ar-bucketing", "warn",
             "latency-bound all-reduce run should fuse into one bucket",
             _rule_small_ar_bucketing),
    LintRule("flat-ring-multipod", "error",
             "pod-spanning group on ring/tree where hierarchical holds",
             _rule_flat_ring_multipod),
    LintRule("allgather-then-slice", "warn",
             "all-gather consumed only through slice/dynamic-slice",
             _rule_allgather_then_slice),
    LintRule("redundant-collective", "error",
             "identical collective executed more than once per value",
             _rule_redundant_collective),
    LintRule("dcn-permute", "warn",
             "DCN-crossing permute with a pod-local device order",
             _rule_dcn_permute),
    LintRule("wire-dtype-waste", "warn",
             "f32 on the wire inside a bf16 producer/consumer chain",
             _rule_wire_dtype_waste),
    LintRule("skewed-a2a", "warn",
             "irregular all-to-all with a >2x hot rank (straggler-bound)",
             _rule_skewed_a2a),
)


def lint_ops(ops, topo: Optional[MeshTopology] = None,
             algorithm: str = "ring",
             hlo_texts: Iterable[str] = ()) -> list[LintFinding]:
    """Run every registered rule over one ``(ops, algorithm, topo)``
    binding; findings sorted errors-first, then by modeled savings.

    ``hlo_texts`` (compiled module text, one per capture) enables the
    def-use rules; without a topology the structural rules still run but
    every modeled figure is zero.
    """
    ctx = LintContext(ops, topo, algorithm, hlo_texts)
    findings: list[LintFinding] = []
    for rule in RULES:
        findings.extend(rule.fn(ctx))
    findings.sort(key=lambda f: (-severity_rank(f.severity),
                                 -f.est_savings_s, f.rule_id, f.op_names))
    return findings
