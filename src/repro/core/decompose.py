"""Unified collective decomposition engine: ONE phase-schedule IR.

The paper's promise is that the communication matrix faithfully reflects
what the collective algorithm actually moves over each link.  Before this
module existed that knowledge was re-derived three times -- edge placement
in :mod:`repro.core.comm_matrix`, wire-byte billing in
:mod:`repro.core.cost_models`, and per-tier timing in
``collective_time_split`` -- held consistent only by a shared predicate and
a wall of consistency tests.  Following "Demystifying NCCL" (which models
every collective as an explicit per-step schedule of (participants, bytes,
channel)), :func:`decompose` turns one :class:`~repro.core.events.
CollectiveOp` under one ``(algorithm, topology)`` binding into a
:class:`CollectiveSchedule`: an ordered list of :class:`CommPhase` records.
Every consumer derives from the schedule instead of re-implementing
algorithm knowledge:

* **placement** -- ``comm_matrix.op_edges`` / ``op_edge_arrays`` place each
  phase's edges (ring / tree / all-to-all / explicit pairs);
* **billing**  -- ``cost_models.wire_bytes_per_rank`` /
  ``device_send_bytes`` sum per-phase per-rank bytes;
* **timing**   -- ``cost_models.collective_time_split`` streams each
  phase's bytes at its tier's bandwidth and (new here) adds the phase's
  ``latency_hops`` at the tier's per-hop latency;
* **links**    -- ``project_links`` / the roofline's per-tier overlap sums
  see schedule-placed edges, and the Perfetto exporter renders per-tier
  lanes straight from schedules.

**Per-axis decomposition.**  A single-pod replica group that is exactly the
Cartesian product of two or more full torus axes no longer runs one
flattened ring over arbitrary device order (whose non-neighbour edges
dissolve into multi-hop transit traffic): it decomposes into one ring
phase per torus axis -- reduce-scatter down the axes and all-gather back
up -- moving the same per-rank total (``2*(n-1)/n*S`` for all-reduce)
entirely over physical neighbour links.  The hierarchical algorithm's
intra-pod phases get the same treatment, which removes the residual
intra-pod transit inflation of the flattened subgroup rings.

The engine is deliberately dependency-light (numpy + topology + events):
``cost_models`` and ``comm_matrix`` both build on it, so the algorithm
menu (:data:`ALGORITHMS`), the shared hierarchical predicate
(:func:`hierarchical_decomposition`) and the binary-tree structure helpers
live here and are re-exported from ``cost_models`` for compatibility.
"""
from __future__ import annotations

import dataclasses
import math
import warnings
from typing import Iterable, Optional

import numpy as np

from .events import CollectiveOp, VECTOR_KINDS
from .topology import MeshTopology

ALGORITHMS = ("ring", "tree", "hierarchical")

# Kinds the hierarchical algorithm knows how to decompose across pods, and
# the kinds the binary-tree placement covers.
HIERARCHICAL_KINDS = ("all-reduce", "all-gather", "reduce-scatter",
                      "collective-broadcast")
TREE_KINDS = HIERARCHICAL_KINDS
# Kinds whose ring form may decompose per torus axis (phase sequences
# below preserve the Table-1 per-rank totals exactly).
AXIS_DECOMPOSABLE_KINDS = HIERARCHICAL_KINDS
# Kinds the hierarchical algorithm decomposes as a two-tier exchange
# (intra-pod all-to-all, pod-slot DCN exchange, intra-pod distribution);
# kept separate from :data:`HIERARCHICAL_KINDS` because the ring-chain
# decomposition and its legacy oracle do not apply to all-to-all.
A2A_KINDS = ("all-to-all", "ragged-all-to-all")


class HierarchicalFallbackWarning(UserWarning):
    """``algorithm="hierarchical"`` was requested for a cross-pod group the
    shared predicate cannot decompose (uneven pod split, or a kind outside
    :data:`HIERARCHICAL_KINDS`); the schedule fell back to flat ring phases
    and billing/timing/placement all follow that same fallback."""


# One warning per (op kind, group size): a large capture decomposes the same
# shape hundreds of times across matrix / billing / timing / lint paths, and
# identical repeats would drown every other diagnostic.
# ``MonitorSession.__init__`` resets the set, so each session warns afresh.
_FALLBACK_SEEN: set[tuple[str, int]] = set()


def reset_fallback_warnings() -> None:
    """Forget which (kind, group size) hierarchical fallbacks already
    warned; the next occurrence of each warns again."""
    _FALLBACK_SEEN.clear()


def warn_fallback_once(kind: str, n: int, message: str,
                       stacklevel: int = 3) -> bool:
    """Emit a :class:`HierarchicalFallbackWarning` once per (kind, group
    size) since the last :func:`reset_fallback_warnings`.  Returns whether
    the warning fired (deduplicated repeats return False)."""
    key = (kind, int(n))
    if key in _FALLBACK_SEEN:
        return False
    _FALLBACK_SEEN.add(key)
    warnings.warn(HierarchicalFallbackWarning(message),
                  stacklevel=stacklevel + 1)
    return True


def _note_fallback(records: Optional[list], warn: bool, kind: str, n: int,
                   message: str) -> None:
    """Record a fallback for memoized replay and (optionally) warn now.

    :func:`decompose` routes its fallback sites through here so
    :func:`cached_decompose` can capture the ``(kind, n, message)``
    triples alongside the schedule and re-issue them on cache hits --
    a hit must warn exactly as loudly as a miss would have (still
    deduplicated by :func:`warn_fallback_once`).
    """
    if records is not None:
        records.append((kind, int(n), message))
    if warn:
        warn_fallback_once(kind, n, message, stacklevel=2)


def validate_algorithm(algorithm: str) -> str:
    """Reject unknown collective algorithms with a clear error.

    Every public entry point that accepts an ``algorithm`` string
    (``monitor_fn``, ``MonitorSession``, ``CommView``, ``matrix_for_ops``,
    the sweep engine / CLI) funnels through here, so a typo like
    ``"treee"`` raises immediately instead of silently falling through to
    ring edge placement.  Returns the validated name for call-through use.
    """
    if algorithm not in ALGORITHMS:
        raise ValueError(
            f"unknown algorithm {algorithm!r}; known: {ALGORITHMS}")
    return algorithm


def _hier_split(n: int, pods: int) -> tuple[int, int]:
    """(pods, in_pod) for a hierarchical decomposition of an ``n``-rank group.

    Degenerates to ``(1, n)`` when the group does not split evenly across
    pods (or there is no DCN tier), which makes hierarchical == ring.
    """
    p = max(1, int(pods))
    if p <= 1 or n % p != 0 or n // p < 1:
        return 1, n
    return p, n // p


def hierarchical_decomposition(
        kind: str, group: list[int],
        topo: Optional[MeshTopology]) -> Optional[
            tuple[int, int, list[list[int]]]]:
    """``(p, m, subgroups)`` when ``kind`` over ``group`` decomposes
    hierarchically.

    The single shared predicate behind the whole schedule engine: a group
    decomposes iff the kind is one of :data:`HIERARCHICAL_KINDS`, the group
    spans more than one pod, and the pods partition it into equal-size
    subgroups.  ``None`` otherwise -- placement, billing and timing all
    fall back to the flat ring model together because they all read the
    same schedule.  The per-pod subgroups ride along so callers never
    recompute the partition.
    """
    if topo is None or kind not in HIERARCHICAL_KINDS or not group:
        return None
    if not topo.group_crosses_dcn(group):
        return None
    subs = topo.pod_partition(group)
    p, n = len(subs), len(group)
    if p <= 1 or n % p != 0 or any(len(sub) != n // p for sub in subs):
        return None
    return p, n // p, subs


def a2a_decomposition(
        kind: str, group: list[int],
        topo: Optional[MeshTopology]) -> Optional[
            tuple[int, int, list[list[int]]]]:
    """``(p, m, subgroups)`` when an all-to-all over ``group`` decomposes
    into the two-tier exchange (the :data:`A2A_KINDS` twin of
    :func:`hierarchical_decomposition`, same acceptance rule: the group
    spans more than one pod and the pods partition it into equal-size
    subgroups).  ``None`` otherwise -- placement, billing and timing all
    fall back to the flat all-to-all phase together."""
    if topo is None or kind not in A2A_KINDS or not group:
        return None
    if not topo.group_crosses_dcn(group):
        return None
    subs = topo.pod_partition(group)
    p, n = len(subs), len(group)
    if p <= 1 or n % p != 0 or any(len(sub) != n // p for sub in subs):
        return None
    return p, n // p, subs


def effective_pods(kind: str, group: list[int],
                   topo: Optional[MeshTopology]) -> int:
    """``pods`` argument for the Table-1 entries: the decomposition's ``p``
    when :func:`hierarchical_decomposition` (or, for :data:`A2A_KINDS`,
    :func:`a2a_decomposition`) accepts the triple, else 1 (so hierarchical
    degenerates to ring exactly where the schedule does)."""
    dec = hierarchical_decomposition(kind, group, topo)
    if dec is None:
        dec = a2a_decomposition(kind, group, topo)
    return dec[0] if dec is not None else 1


def effective_byte_vector(kind: str, vec, n: int) -> Optional[np.ndarray]:
    """Validated, genuinely irregular per-rank byte vector, or ``None``.

    The single collapse point of the vector IR: a missing / malformed /
    wrong-kind / wrong-length vector -- and, crucially, a **uniform**
    one -- returns ``None``, routing the op down the scalar path with
    ``payload = sum(vec)``.  A uniform vector's sum is exactly the scalar
    payload, so uniform-vector ops reproduce scalar matrices, bills and
    times bitwise; only genuinely skewed vectors ever reach the vector
    phase constructors.  ``vec[i]`` is positional: the bytes the rank at
    group position ``i`` injects, applied identically to every replica
    group of the op.
    """
    if vec is None or kind not in VECTOR_KINDS:
        return None
    v = np.asarray(vec, dtype=np.float64)
    if v.ndim != 1 or int(v.size) != int(n) or v.size < 2:
        return None
    if not np.all(np.isfinite(v)) or np.any(v < 0) or v.sum() <= 0:
        return None
    if float(v.max()) == float(v.min()):
        return None
    return v


def hier_phases(kind: str) -> float:
    """Ring phases per tier: all-reduce = RS + AG (2), the one-phase kinds
    (all-gather / reduce-scatter / scatter-allgather broadcast) = 1."""
    return 2.0 if kind == "all-reduce" else 1.0


# ----------------------------------------------------------------------------
# Binary-tree structure (heap layout over group positions) -- the one
# definition every consumer of tree phases resolves per-role amounts from.
# ----------------------------------------------------------------------------
def tree_children(i: int, n: int) -> list[int]:
    """Children of position ``i`` in the implicit binary tree over ``n``."""
    return [c for c in (2 * i + 1, 2 * i + 2) if c < n]


def tree_subtree_sizes(n: int) -> list[int]:
    """Subtree size per position of the implicit binary tree over ``n``."""
    sizes = [1] * n
    for i in range(n - 1, 0, -1):
        sizes[(i - 1) // 2] += sizes[i]
    return sizes


def tree_latency_hops(n: int) -> float:
    """Serial hops of a double binary tree pass (up + down)."""
    return 2.0 * math.ceil(math.log2(n)) if n > 1 else 0.0


def tree_edge_profile(kind: str, s: float,
                      n: int) -> tuple[np.ndarray, np.ndarray]:
    """``(up, down)`` bytes per tree position ``1..n-1`` (child index).

    ``up[i-1]`` is what position ``i`` sends to its parent, ``down[i-1]``
    what the parent sends back down that edge:

    * all-reduce: S up (reduce) and S down (broadcast) every edge,
    * broadcast: S down only,
    * all-gather: a child sends its subtree's shards up, a parent sends
      everything the child's subtree lacks down,
    * reduce-scatter: the time-reversed all-gather.
    """
    sizes = np.asarray(tree_subtree_sizes(n), dtype=np.float64)[1:]
    if kind == "all-reduce":
        up = np.full(n - 1, float(s))
        return up, up
    if kind == "collective-broadcast":
        return np.zeros(n - 1), np.full(n - 1, float(s))
    if kind == "all-gather":
        return sizes * s / n, (n - sizes) * s / n
    # reduce-scatter
    return (n - sizes) * s / n, sizes * s / n


def tree_send_bytes(kind: str, s: float, n: int) -> np.ndarray:
    """Bytes each tree *position* sends (per-role resolution of the tree
    phase): root sends S per child, a leaf sends up only."""
    up, down = tree_edge_profile(kind, s, n)
    out = np.zeros(n, dtype=np.float64)
    out[1:] += up                                # child -> parent
    np.add.at(out, (np.arange(1, n) - 1) // 2, down)   # parent -> child
    return out


# ----------------------------------------------------------------------------
# The IR.
# ----------------------------------------------------------------------------
@dataclasses.dataclass
class CommPhase:
    """One step of a collective schedule.

    ``groups`` is a ``(k, m)`` array of ``k`` concurrent same-size groups
    (rings for ``structure="ring"``, heap-layout trees for ``"tree"``,
    full-exchange groups for ``"a2a"``); ``pairs`` replaces it for
    ``structure="pairs"`` (collective-permute).  ``bytes_per_rank`` is what
    each participating rank sends during the phase (the dominant per-role
    amount for tree phases; ``payload`` lets consumers resolve exact
    per-role bytes).  ``latency_hops`` is the phase's serial hop count --
    the latency term ``collective_time_split`` charges at the tier's
    per-hop latency.  ``axis`` names the torus axis the rings run along
    (``""`` for flattened rings, trees and the DCN exchange).  Phases
    sharing a ``stream`` are sequential; distinct streams (disjoint replica
    groups of one op) run concurrently.

    **Irregular phases.**  ``bytes_per_rank`` may be an ndarray instead of
    a float: 1-D of length ``m`` (positional -- entry ``i`` is what the
    rank at group position ``i`` sends, applied to every group row) or 2-D
    of shape ``(k, m)`` (per group row).  Consumers broadcast it to the
    ``groups`` shape (:meth:`byte_matrix`); timing charges the **max**
    entry (:meth:`max_bytes_per_rank` -- the straggler rank paces the
    phase), billing sums the true per-position amounts.  ``pair_bytes``
    likewise carries per-pair bytes for ``structure="pairs"`` phases whose
    pairs move different amounts (the hierarchical permute relay).
    """

    kind: str                       # semantic step, e.g. "reduce-scatter"
    tier: str                       # "ici" | "dcn"
    groups: Optional[np.ndarray]    # (k, m) device ids, or None for pairs
    bytes_per_rank: "float | np.ndarray"
    latency_hops: float
    axis: str = ""                  # torus axis for per-axis ring phases
    structure: str = "ring"         # "ring" | "tree" | "a2a" | "pairs"
    payload: float = 0.0            # logical payload S the phase operates on
    stream: int = 0                 # sequential within, concurrent across
    pairs: Optional[np.ndarray] = None   # (k, 2) for structure "pairs"
    pair_bytes: Optional[np.ndarray] = None  # per-pair bytes (num_groups-scaled)

    @property
    def group_size(self) -> int:
        return 0 if self.groups is None else int(self.groups.shape[-1])

    @property
    def num_groups(self) -> int:
        if self.groups is not None:
            return int(self.groups.shape[0]) if self.groups.ndim > 1 else 1
        return 0 if self.pairs is None else int(len(self.pairs))

    def max_bytes_per_rank(self) -> float:
        """Scalar per-rank bill of the phase: the value itself for scalar
        phases, the **max** entry for vector phases -- the straggler rank
        every other participant waits on, which is what timing charges."""
        if isinstance(self.bytes_per_rank, np.ndarray):
            return float(np.max(self.bytes_per_rank))
        return float(self.bytes_per_rank)

    def byte_matrix(self) -> Optional[np.ndarray]:
        """Per-position send bytes broadcast to the ``groups`` shape
        ``(k, m)``, or ``None`` for scalar phases (1-D vectors are
        positional: the same row applies to every group)."""
        if not isinstance(self.bytes_per_rank, np.ndarray) \
                or self.groups is None:
            return None
        G = np.atleast_2d(self.groups)
        return np.broadcast_to(
            np.asarray(self.bytes_per_rank, dtype=np.float64), G.shape)

    def seconds(self, topo: MeshTopology, *,
                include_latency: bool = True) -> float:
        """Streaming time of this phase on ``topo``: bytes at the tier's
        per-chip ring bandwidth, plus ``latency_hops`` at the tier's
        per-hop latency.  Vector phases stream their **max** per-rank
        bytes -- the straggler paces the phase."""
        dcn = self.tier == "dcn"
        t = self.max_bytes_per_rank() / topo.ring_bw_per_chip(dcn)
        if include_latency:
            t += self.latency_hops * (topo.hw.dcn_hop_latency_s if dcn
                                      else topo.hw.ici_hop_latency_s)
        return t

    def total_send_bytes(self) -> float:
        """Bytes sent by ALL participants of this phase (one execution) --
        the O(1)/vectorized aggregate of :meth:`send_bytes`, for billing
        paths that never need the per-device resolution.  Vector phases
        sum their true per-position amounts (not ``size * max``)."""
        if self.structure == "pairs" and self.pairs is not None:
            if self.pair_bytes is not None:
                return float(np.sum(self.pair_bytes))
            return float(len(self.pairs)) * self.payload
        if self.groups is None:
            return 0.0
        G = np.atleast_2d(self.groups)
        if self.structure == "tree":
            return float(G.shape[0]) * float(
                tree_send_bytes(self.kind, self.payload, G.shape[1]).sum())
        B = self.byte_matrix()
        if B is not None:
            return float(B.sum())
        return float(G.size) * self.bytes_per_rank

    def send_bytes(self) -> dict[int, float]:
        """Bytes each participating device sends during this phase."""
        out: dict[int, float] = {}
        if self.structure == "pairs" and self.pairs is not None:
            if self.pair_bytes is not None:
                for src, b in zip(self.pairs[:, 0].tolist(),
                                  self.pair_bytes.tolist()):
                    out[src] = out.get(src, 0.0) + b
                return out
            # payload is the per-edge byte amount (num_groups-scaled)
            for src in self.pairs[:, 0].tolist():
                out[src] = out.get(src, 0.0) + self.payload
            return out
        if self.groups is None:
            return out
        G = np.atleast_2d(self.groups)
        if self.structure == "tree":
            per_pos = tree_send_bytes(self.kind, self.payload, G.shape[1])
            for row in G:
                for d, b in zip(row.tolist(), per_pos.tolist()):
                    out[d] = out.get(d, 0.0) + b
            return out
        B = self.byte_matrix()
        if B is not None:
            for row, brow in zip(G, B):
                for d, b in zip(row.tolist(), brow.tolist()):
                    out[d] = out.get(d, 0.0) + b
            return out
        for d in G.ravel().tolist():
            out[d] = out.get(d, 0.0) + self.bytes_per_rank
        return out

    def to_summary(self) -> dict:
        """Serializable record (schema-v5 ``schedules`` section); vector
        phases report their max as ``bytes_per_rank`` plus mean and skew."""
        out = {
            "kind": self.kind,
            "tier": self.tier,
            "structure": self.structure,
            "axis": self.axis,
            "num_groups": self.num_groups,
            "group_size": self.group_size,
            "bytes_per_rank": self.max_bytes_per_rank(),
            "latency_hops": float(self.latency_hops),
            "stream": self.stream,
        }
        if isinstance(self.bytes_per_rank, np.ndarray):
            mean = float(np.mean(self.bytes_per_rank))
            out["bytes_per_rank_mean"] = mean
            out["skew"] = (out["bytes_per_rank"] / mean) if mean > 0 else 1.0
        return out


@dataclasses.dataclass
class CollectiveSchedule:
    """Ordered phase list for ONE execution of one collective op."""

    op_kind: str
    algorithm: str
    phases: list[CommPhase]

    def __iter__(self):
        return iter(self.phases)

    def time_split(self, topo: MeshTopology, *,
                   include_latency: bool = True) -> tuple[float, float]:
        """``(ici_seconds, dcn_seconds)`` for one execution.

        Phases of one stream serialize (sum); streams are disjoint replica
        groups running concurrently, so each tier's time is the max over
        streams -- the same semantics ``collective_time_split`` always had,
        now read off the schedule.
        """
        by_stream: dict[int, list[float]] = {}
        for ph in self.phases:
            acc = by_stream.setdefault(ph.stream, [0.0, 0.0])
            acc[ph.tier == "dcn"] += ph.seconds(
                topo, include_latency=include_latency)
        ici = max((v[0] for v in by_stream.values()), default=0.0)
        dcn = max((v[1] for v in by_stream.values()), default=0.0)
        return ici, dcn

    def send_bytes_by_device(self) -> dict[int, float]:
        """Per-device sent bytes over the whole schedule (one execution)."""
        out: dict[int, float] = {}
        for ph in self.phases:
            for d, b in ph.send_bytes().items():
                out[d] = out.get(d, 0.0) + b
        return out

    def total_bytes(self) -> float:
        """Wire bytes summed over every device (one execution)."""
        return float(sum(ph.total_send_bytes() for ph in self.phases))

    def latency_hops(self, tier: Optional[str] = None) -> float:
        """Serial hops on the slowest stream (per tier, or both summed)."""
        by_stream: dict[int, float] = {}
        for ph in self.phases:
            if tier is not None and ph.tier != tier:
                continue
            by_stream[ph.stream] = by_stream.get(ph.stream, 0.0) \
                + ph.latency_hops
        return max(by_stream.values(), default=0.0)

    def summary(self) -> dict:
        return {"kind": self.op_kind, "algorithm": self.algorithm,
                "phases": [ph.to_summary() for ph in self.phases]}


# ----------------------------------------------------------------------------
# Per-axis ring detection: is a group the Cartesian product of full torus
# axes (other coordinates fixed, single pod)?
# ----------------------------------------------------------------------------
def axis_rings(group, topo: Optional[MeshTopology]) -> Optional[
        list[tuple[str, np.ndarray]]]:
    """``[(axis_name, rings)]`` when ``group`` decomposes per torus axis.

    Accepts exactly the groups a mesh collective over named axes produces:
    every member in one pod, the member set equal to the Cartesian product
    of **two or more full ICI axes** (each participating axis spans its
    whole size, so every ring is a torus-neighbour ring with a one-hop
    wrap), all other coordinates fixed.  ``rings`` is a ``(k, size)`` array
    of the axis' neighbour rings in coordinate order.  ``None`` otherwise
    -- single-axis groups keep their (identical) flattened ring so the
    legacy oracle stays byte-exact on them.
    """
    n = len(group)
    if topo is None or n <= 1 or topo.group_crosses_dcn(list(group)):
        return None
    coords = np.asarray([topo.coords(d) for d in group])
    part: list[int] = []
    for i, name in enumerate(topo.axis_names):
        vals = np.unique(coords[:, i])
        if len(vals) == 1:
            continue
        if name in topo.dcn_axes or len(vals) != topo.axis_sizes[i] \
                or not np.array_equal(vals, np.arange(topo.axis_sizes[i])):
            return None
        part.append(i)
    if len(part) < 2:
        return None
    sizes = [topo.axis_sizes[i] for i in part]
    if n != math.prod(sizes):
        return None
    order = np.lexsort(tuple(coords[:, i] for i in reversed(part)))
    sorted_coords = coords[order][:, part]
    expect = np.stack(np.meshgrid(*[np.arange(s) for s in sizes],
                                  indexing="ij"), -1).reshape(n, len(part))
    if not np.array_equal(sorted_coords, expect):
        return None
    garr = np.asarray(group, dtype=np.intp)[order].reshape(sizes)
    out = []
    for j, i in enumerate(part):
        rings = np.moveaxis(garr, j, -1).reshape(-1, sizes[j])
        out.append((topo.axis_names[i], rings))
    return out


# ----------------------------------------------------------------------------
# Phase construction.
# ----------------------------------------------------------------------------
def _gather_chain(kind: str, chunk: float,
                  axes: list[tuple[str, np.ndarray]], tier: str,
                  stream: int) -> list[CommPhase]:
    """All-gather-direction ring phases along ``axes`` (growing chunks).

    Starting from a per-rank ``chunk``, each axis phase forwards
    ``(size-1) * chunk`` around its rings and multiplies the chunk by the
    axis size -- the shard-growth schedule whose per-rank total telescopes
    to ``(prod-1) * chunk``.  Reduce-scatter chains are the time-reverse:
    same per-axis amounts, reversed order (see :func:`_scatter_chain`).
    """
    out = []
    for axis_name, rings in axes:
        size = int(rings.shape[-1])
        out.append(CommPhase(
            kind=kind, tier=tier, groups=rings,
            bytes_per_rank=(size - 1) * chunk,
            latency_hops=float(size - 1), axis=axis_name, stream=stream))
        chunk *= size
    return out


def _scatter_chain(kind: str, chunk: float,
                   axes: list[tuple[str, np.ndarray]], tier: str,
                   stream: int) -> list[CommPhase]:
    """Reduce-scatter-direction chain: the reversed gather chain."""
    return list(reversed(_gather_chain(kind, chunk, axes, tier, stream)))


def _ring_phases(kind: str, s: float, axes: list[tuple[str, np.ndarray]],
                 n: int, tier: str, stream: int) -> list[CommPhase]:
    """Ring phase sequence for one (possibly per-axis) ring placement.

    ``axes`` is the ring set per torus axis (one flattened entry for a
    non-decomposable group); ``n`` the total member count.  All-reduce is
    the scatter chain followed by the mirrored gather chain (per-rank total
    ``2*(n-1)/n*S``); the one-phase kinds run a single gather- or
    scatter-direction chain (``(n-1)/n*S``); anything else streams its full
    payload once around the (flattened) rings, matching the generic ring
    entry.
    """
    if kind == "all-reduce":
        return (_scatter_chain("reduce-scatter", s / n, axes, tier, stream)
                + _gather_chain("all-gather", s / n, axes, tier, stream))
    if kind in ("all-gather", "collective-broadcast"):
        return _gather_chain(kind, s / n, axes, tier, stream)
    if kind == "reduce-scatter":
        return _scatter_chain(kind, s / n, axes, tier, stream)
    # generic/unknown kind: full payload once around the rings
    return [CommPhase(kind=kind, tier=tier, groups=rings,
                      bytes_per_rank=s,
                      latency_hops=float(rings.shape[-1] - 1),
                      axis=axis_name, stream=stream)
            for axis_name, rings in axes]


def _flat_phases(kind: str, s: float, arr: np.ndarray, algorithm: str,
                 crosses: bool, stream: int,
                 vec: Optional[np.ndarray] = None) -> list[CommPhase]:
    """Phases for a batch of same-size groups with no pod or per-axis
    structure (``arr`` is ``(k, n)``): the ONE place the flat a2a / tree /
    ring byte amounts are written -- both the group-level billing path
    (:func:`group_phases`) and :func:`decompose`'s batched fast path call
    it, so placement and billing cannot fork.

    ``vec`` (already validated / uniform-collapsed by
    :func:`effective_byte_vector`) switches the irregular forms: a skewed
    all-to-all where position ``i`` injects ``vec[i]`` sends
    ``vec[i] * (n-1)/n`` (``vec[i]/n`` to each peer); an allgatherv ring
    forwards every shard except the one it receives last
    (``S - vec[(i+1) % n]``); a v-reduce-scatter is its time reverse
    (``S - vec[i]``).  Irregular ops keep the single flat ring/a2a phase
    regardless of ``algorithm`` -- the tree and per-axis decompositions
    assume equal shards.
    """
    n = int(arr.shape[-1])
    tier = "dcn" if crosses else "ici"
    if vec is not None:
        if kind in A2A_KINDS:
            return [CommPhase(kind=kind, tier=tier, groups=arr,
                              bytes_per_rank=vec * (n - 1) / n,
                              latency_hops=float(n - 1), structure="a2a",
                              payload=s, stream=stream)]
        per = s - np.roll(vec, -1) if kind == "all-gather" else s - vec
        return [CommPhase(kind=kind, tier=tier, groups=arr,
                          bytes_per_rank=per, latency_hops=float(n - 1),
                          structure="ring", payload=s, stream=stream)]
    if kind in A2A_KINDS:
        return [CommPhase(kind=kind, tier=tier, groups=arr,
                          bytes_per_rank=(n - 1) * s / (n * n),
                          latency_hops=float(n - 1), structure="a2a",
                          payload=s, stream=stream)]
    if algorithm == "tree" and kind in TREE_KINDS:
        per = 2.0 * s if kind == "all-reduce" else (n - 1) * s / n
        return [CommPhase(kind=kind, tier=tier, groups=arr,
                          bytes_per_rank=per,
                          latency_hops=tree_latency_hops(n),
                          structure="tree", payload=s, stream=stream)]
    return _ring_phases(kind, s, [("", arr)], n, tier, stream)


def _subgroup_axes(subs: list[list[int]],
                   topo: Optional[MeshTopology]) -> list[
                       tuple[str, np.ndarray]]:
    """Ring set for the hierarchical intra-pod phases: per-axis rings when
    EVERY pod subgroup decomposes identically, else one flattened ring per
    subgroup."""
    per_pod = []
    for sub in subs:
        rings = axis_rings(sub, topo)
        if rings is None:
            break
        per_pod.append(rings)
    else:
        shapes = [[(a, r.shape) for a, r in rings] for rings in per_pod]
        if all(sh == shapes[0] for sh in shapes):
            return [(axis, np.concatenate([rings[j][1]
                                           for rings in per_pod]))
                    for j, (axis, _) in enumerate(per_pod[0])]
    return [("", np.asarray(subs, dtype=np.intp))]


def group_phases(kind: str, payload: float, group, algorithm: str,
                 topo: Optional[MeshTopology] = None, *,
                 pods: Optional[int] = None, stream: int = 0,
                 warn: bool = True,
                 vec: Optional[np.ndarray] = None) -> list[CommPhase]:
    """Phase sequence for ONE replica group of one collective.

    The group-level heart of :func:`decompose`, also usable abstractly:
    with ``topo=None`` and ``pods=p`` the group splits into ``p``
    consecutive chunks (how ``cost_models.wire_bytes_per_rank`` reproduces
    the Table-1 entries without a concrete mesh).  A hierarchical request
    the shared predicate refuses emits a
    :class:`HierarchicalFallbackWarning` (when ``warn``) and returns the
    flat-ring fallback every consumer then shares.

    ``vec`` is an optional per-rank byte vector (positional over the
    group); it is collapsed by :func:`effective_byte_vector` first, so a
    uniform vector takes the scalar path bitwise with
    ``payload = sum(vec)``.
    """
    members = np.asarray(group, dtype=np.intp)   # free if already ndarray
    n = int(members.size)
    if n <= 1:
        return []
    vec = effective_byte_vector(kind, vec, n)
    s = float(payload) if vec is None else float(vec.sum())
    arr = members[None, :]
    group = members.tolist() if topo is not None else members
    crosses = (topo.group_crosses_dcn(group) if topo is not None
               else (pods or 1) > 1)
    tier = "dcn" if crosses else "ici"

    if kind == "collective-permute":
        # pair schedules are op-level; the group-level entry only carries
        # the per-rank bill (S) for Table-1 reproduction
        return [CommPhase(kind=kind, tier=tier, groups=arr,
                          bytes_per_rank=s, latency_hops=1.0,
                          structure="pairs", payload=s, stream=stream)]

    if algorithm == "hierarchical" and crosses and kind in A2A_KINDS:
        if topo is not None:
            dec = a2a_decomposition(kind, group, topo)
        else:
            p0, m0 = _hier_split(n, pods or 1)
            dec = None if p0 <= 1 else (
                p0, m0, [list(group[i * m0:(i + 1) * m0])
                         for i in range(p0)])
        if dec is not None:
            return _hierarchical_a2a_phases(kind, s, dec, vec, group,
                                            stream)
        if warn:
            warn_fallback_once(
                kind, n,
                f"hierarchical {kind} over cross-pod group of {n} cannot "
                "decompose (uneven pod split); scheduling a flat "
                "all-to-all phase -- placement, billing and timing all "
                "share this fallback", stacklevel=2)
        return _flat_phases(kind, s, arr, algorithm, True, stream, vec=vec)

    if algorithm == "hierarchical" and crosses \
            and kind in HIERARCHICAL_KINDS:
        if vec is not None:
            # the ring-chain decomposition assumes equal shards; an
            # irregular gather/scatter stays a flat vector ring
            if warn:
                warn_fallback_once(
                    kind, n,
                    f"irregular (per-rank vector) {kind} over cross-pod "
                    f"group of {n} does not decompose hierarchically; "
                    "scheduling a flat vector ring phase -- placement, "
                    "billing and timing all share this fallback",
                    stacklevel=2)
            return _flat_phases(kind, s, arr, algorithm, True, stream,
                                vec=vec)
        if topo is not None:
            dec = hierarchical_decomposition(kind, group, topo)
        else:
            p0, m0 = _hier_split(n, pods or 1)
            dec = None if p0 <= 1 else (
                p0, m0, [group[i * m0:(i + 1) * m0] for i in range(p0)])
        if dec is not None:
            return _hierarchical_phases(kind, s, dec, topo, stream)
        if warn:
            warn_fallback_once(
                kind, n,
                f"hierarchical {kind} over cross-pod group of {n} cannot "
                "decompose (uneven pod split); scheduling flat ring phases "
                "-- placement, billing and timing all share this fallback",
                stacklevel=2)
        return _flat_phases(kind, s, arr, algorithm, True, stream)

    if vec is not None:
        # irregular ops skip the per-axis / tree decompositions (equal
        # shards assumed there); the flat vector phase carries the skew
        return _flat_phases(kind, s, arr, algorithm, crosses, stream,
                            vec=vec)
    if not crosses and kind in AXIS_DECOMPOSABLE_KINDS \
            and algorithm != "tree":
        axes = axis_rings(group, topo)
        if axes is not None:
            return _ring_phases(kind, s, axes, n, "ici", stream)
    return _flat_phases(kind, s, arr, algorithm, crosses, stream)


def _hierarchical_phases(kind: str, s: float, dec,
                         topo: Optional[MeshTopology],
                         stream: int) -> list[CommPhase]:
    """Hierarchical phase sequence: intra-pod ring chains (per-axis when
    the subgroups allow) around a cross-pod DCN shard exchange.

    All-reduce: reduce-scatter inside the pod, ring all-reduce of the
    ``S/m`` shard across the ``p`` same-index members over DCN, all-gather
    back inside the pod.  The one-phase kinds exchange their ``S/n`` shards
    across pods and run the single intra-pod chain.  Per-rank totals match
    the Table-1 hierarchical entries exactly.
    """
    p, m, subs = dec
    sub_arr = np.asarray(subs, dtype=np.intp)            # (p, m)
    cross_rings = sub_arr.T                              # (m, p) columns
    intra_axes = _subgroup_axes(subs, topo) if (topo is not None and m > 1) \
        else ([("", sub_arr)] if m > 1 else [])
    phases: list[CommPhase] = []
    if kind == "all-reduce":
        if intra_axes:
            phases += _scatter_chain("reduce-scatter", s / m, intra_axes,
                                     "ici", stream)
        phases.append(CommPhase(
            kind="all-reduce", tier="dcn", groups=cross_rings,
            bytes_per_rank=2.0 * (p - 1) * s / (p * m),
            latency_hops=2.0 * (p - 1), axis="dcn", stream=stream))
        if intra_axes:
            phases += _gather_chain("all-gather", s / m, intra_axes,
                                    "ici", stream)
        return phases
    cross = CommPhase(
        kind=kind, tier="dcn", groups=cross_rings,
        bytes_per_rank=(p - 1) * s / (p * m),
        latency_hops=float(p - 1), axis="dcn", stream=stream)
    if kind == "reduce-scatter":
        # scatter inside the pod first ((m-1)/m * S, chunk telescopes from
        # S down to the S/m shard), then scatter the shard across pods
        if intra_axes:
            phases.extend(_scatter_chain(kind, s / m, intra_axes, "ici",
                                         stream))
        phases.append(cross)
        return phases
    # all-gather / scatter-allgather broadcast: cross-pod exchange first
    # (each rank then holds the S/m pod shard), then gather inside the pod
    phases.append(cross)
    if intra_axes:
        phases.extend(_gather_chain(kind, s / m, intra_axes, "ici",
                                    stream))
    return phases


def _hierarchical_a2a_phases(kind: str, s: float, dec,
                             vec: Optional[np.ndarray], group,
                             stream: int) -> list[CommPhase]:
    """Two-tier all-to-all: intra-pod exchange, pod-slot DCN exchange,
    intra-pod distribution.

    Stage A is an all-to-all inside each pod that re-buckets every rank's
    payload by destination pod (each rank keeps ``1/p`` of what it holds,
    so it moves ``(m-1)/m`` of its ``S/p``-sized per-pod buckets); stage B
    exchanges the re-bucketed data between same-slot ranks across pods
    (``p``-way all-to-all of the ``S/m`` pod shard); stage C distributes
    the received shards to their final in-pod destinations (same form as
    stage A).  Per-rank total ``2(m-1)S/(p m^2) + (p-1)S/(p^2 m)``; DCN
    carries exactly the flat placement's cross-pod share ``(p-1)/p * S``.

    With a per-rank ``vec``, stages A/C move each rank's own injection
    (``vec_i * (m-1)/m``) while stage B carries the **pod mean** -- stage
    A load-balances the pod, so the DCN exchange of pod ``q`` is paced by
    ``mean(vec over pod q)``: the hierarchical decomposition smooths
    per-rank skew before it reaches the expensive tier.  Group totals
    depend only on per-pod sums, so billing and placement agree with the
    abstract (contiguous-chunk) split used by the Table-1 entries.
    """
    p, m, subs = dec
    sub_arr = np.asarray(subs, dtype=np.intp)            # (p, m)
    if vec is not None:
        pos = {int(d): i for i, d in enumerate(group)}
        vsub = np.asarray(
            [[vec[pos[int(d)]] for d in sub] for sub in subs],
            dtype=np.float64)                            # (p, m)
        total = float(vec.sum())
        bytes_a = vsub * (m - 1) / m
        bytes_b = vsub.mean(axis=1) * (p - 1) / p        # (p,) positional
        pay_a, pay_b = total / p, total / m
    else:
        bytes_a = (m - 1) * (s / p) / (m * m)
        bytes_b = (p - 1) * (s / m) / (p * p)
        pay_a, pay_b = s / p, s / m
    cross = CommPhase(
        kind=kind, tier="dcn", groups=sub_arr.T,         # (m, p) slots
        bytes_per_rank=bytes_b, latency_hops=float(p - 1),
        structure="a2a", payload=pay_b, axis="dcn", stream=stream)
    if m <= 1:
        return [cross]
    intra = CommPhase(
        kind=kind, tier="ici", groups=sub_arr,
        bytes_per_rank=bytes_a, latency_hops=float(m - 1),
        structure="a2a", payload=pay_a, stream=stream)
    return [intra, cross, dataclasses.replace(intra)]


def _pod_leaders(topo: MeshTopology) -> dict[int, int]:
    """Lowest device id per pod: the DCN egress rank of the hierarchical
    collective-permute relay."""
    leaders: dict[int, int] = {}
    for d in range(topo.num_devices):
        pod = topo.pod_index(d)
        if pod not in leaders:      # ids ascend, so first seen is the min
            leaders[pod] = d
    return leaders


def _permute_relay_phases(pairs: np.ndarray, pair_pods: np.ndarray,
                          per_edge: float, topo: MeshTopology,
                          stream: int) -> list[CommPhase]:
    """Pod-leader relay for cross-pod permute pairs under hierarchical.

    Instead of every cross-pod pair occupying its own DCN uplink, traffic
    funnels through pod leaders: source -> its pod leader (ICI), leader ->
    destination pod's leader (one aggregated DCN exchange per pod pair),
    leader -> destination (ICI).  The three hops serialize on one stream;
    ``pair_bytes`` carries the aggregated per-pair amounts and each
    phase's ``bytes_per_rank`` is the busiest source's total (the
    straggler timing charges).  Hops whose source already is the leader
    (or whose destination is) are elided rather than billed at zero.
    """
    leaders = _pod_leaders(topo)
    hops: list[dict[tuple[int, int], float]] = [{}, {}, {}]
    for (a, b), (pa, pb) in zip(pairs.tolist(), pair_pods.tolist()):
        la, lb = leaders[pa], leaders[pb]
        if a != la:
            hops[0][(a, la)] = hops[0].get((a, la), 0.0) + per_edge
        hops[1][(la, lb)] = hops[1].get((la, lb), 0.0) + per_edge
        if b != lb:
            hops[2][(lb, b)] = hops[2].get((lb, b), 0.0) + per_edge
    out: list[CommPhase] = []
    for tier, hop in zip(("ici", "dcn", "ici"), hops):
        if not hop:
            continue
        p_arr = np.asarray(list(hop.keys()), dtype=np.intp)
        b_arr = np.asarray(list(hop.values()), dtype=np.float64)
        by_src: dict[int, float] = {}
        for (src, _), b in hop.items():
            by_src[src] = by_src.get(src, 0.0) + b
        out.append(CommPhase(
            kind="collective-permute", tier=tier, groups=None,
            bytes_per_rank=float(max(by_src.values())),
            latency_hops=1.0, structure="pairs", payload=per_edge,
            axis="dcn" if tier == "dcn" else "",
            pairs=p_arr, pair_bytes=b_arr, stream=stream))
    return out


def decompose(op: CollectiveOp, algorithm: str = "ring",
              topo: Optional[MeshTopology] = None, *,
              warn: bool = True,
              _fallbacks: Optional[list] = None) -> CollectiveSchedule:
    """The engine's front door: one op -> its :class:`CollectiveSchedule`.

    The schedule covers ONE execution (consumers apply ``op.weight``).
    Same-class replica groups (same size, same tier, no pod or per-axis
    decomposition) are batched into shared phases whose ``groups`` arrays
    stack the rings, so a 32-group op costs the same handful of phases as
    one group would -- the batching ``matrix_for_ops``' vectorized
    accumulation relies on.  Groups that decompose (across pods, or per
    torus axis) get their own phase streams.
    """
    validate_algorithm(algorithm)
    phases: list[CommPhase] = []
    if op.kind == "collective-permute":
        if op.source_target_pairs:
            # bytes_per_rank is the per-rank bill (one pair's payload);
            # ``payload`` carries the per-edge bytes, scaled by num_groups
            # because every replica group executes the pair schedule.
            # Pairs split by tier: a cross-pod pair streams (and is
            # billed) on DCN, an intra-pod one on ICI -- concurrent
            # streams, since pairs occupy disjoint wires.
            pairs = np.asarray(op.source_target_pairs, dtype=np.intp)
            if topo is not None and topo.num_pods > 1:
                pods = np.asarray([[topo.pod_index(int(a)),
                                    topo.pod_index(int(b))]
                                   for a, b in pairs])
                cross = pods[:, 0] != pods[:, 1]
            else:
                cross = np.zeros(len(pairs), dtype=bool)
            if algorithm == "hierarchical" and cross.any():
                # pod-leader relay for the cross-pod pairs; intra-pod
                # pairs keep their own concurrent stream as before
                if (~cross).any():
                    phases.append(CommPhase(
                        kind=op.kind, tier="ici", groups=None,
                        bytes_per_rank=float(op.result_bytes),
                        latency_hops=1.0, structure="pairs",
                        payload=float(op.result_bytes) * op.num_groups,
                        pairs=pairs[~cross], stream=0))
                phases += _permute_relay_phases(
                    pairs[cross], pods[cross],
                    float(op.result_bytes) * op.num_groups, topo,
                    stream=1)
                return CollectiveSchedule(op.kind, algorithm, phases)
            for tier, mask, strm in (("ici", ~cross, 0),
                                     ("dcn", cross, 1)):
                if mask.any():
                    phases.append(CommPhase(
                        kind=op.kind, tier=tier, groups=None,
                        bytes_per_rank=float(op.result_bytes),
                        latency_hops=1.0, structure="pairs",
                        payload=float(op.result_bytes) * op.num_groups,
                        pairs=pairs[mask], stream=strm))
        return CollectiveSchedule(op.kind, algorithm, phases)

    s = float(op.payload_bytes)
    vec = effective_byte_vector(op.kind, op.byte_vector(), op.group_size)
    stream = 0
    flat: dict[tuple[int, bool], list] = {}
    for group in op.replica_groups or []:
        n = len(group)
        if n <= 1:
            continue
        gvec = vec if (vec is not None and vec.size == n) else None
        if topo is None:
            flat.setdefault((n, False), []).append(group)
            continue
        crosses = topo.group_crosses_dcn(group)
        if algorithm == "hierarchical" and crosses \
                and op.kind in A2A_KINDS:
            dec = a2a_decomposition(op.kind, group, topo)
            if dec is not None:
                phases += _hierarchical_a2a_phases(op.kind, s, dec, gvec,
                                                   group, stream)
                stream += 1
                continue
            _note_fallback(
                _fallbacks, warn, op.kind, n,
                f"hierarchical {op.kind} over cross-pod group of {n} "
                "cannot decompose (uneven pod split); scheduling a "
                "flat all-to-all phase -- placement, billing and "
                "timing all share this fallback")
            flat.setdefault((n, True), []).append(group)
            continue
        if algorithm == "hierarchical" and crosses \
                and op.kind in HIERARCHICAL_KINDS:
            if gvec is not None:
                _note_fallback(
                    _fallbacks, warn, op.kind, n,
                    f"irregular (per-rank vector) {op.kind} over "
                    f"cross-pod group of {n} does not decompose "
                    "hierarchically; scheduling a flat vector ring "
                    "phase -- placement, billing and timing all "
                    "share this fallback")
                flat.setdefault((n, True), []).append(group)
                continue
            dec = hierarchical_decomposition(op.kind, group, topo)
            if dec is not None:
                phases += _hierarchical_phases(op.kind, s, dec, topo,
                                               stream)
                stream += 1
                continue
            _note_fallback(
                _fallbacks, warn, op.kind, n,
                f"hierarchical {op.kind} over cross-pod group of {n} "
                "cannot decompose (uneven pod split); scheduling flat "
                "ring phases -- placement, billing and timing all "
                "share this fallback")
            flat.setdefault((n, True), []).append(group)
            continue
        if gvec is None and not crosses \
                and op.kind in AXIS_DECOMPOSABLE_KINDS \
                and algorithm != "tree":
            axes = axis_rings(group, topo)
            if axes is not None:
                phases += _ring_phases(op.kind, s, axes, n, "ici", stream)
                stream += 1
                continue
        flat.setdefault((n, crosses), []).append(group)
    for (n, crosses), gs in flat.items():
        phases += _flat_phases(op.kind, s, np.asarray(gs, dtype=np.intp),
                               algorithm, crosses, stream,
                               vec=vec if (vec is not None
                                           and vec.size == n) else None)
        stream += 1
    return CollectiveSchedule(op.kind, algorithm, phases)


# ----------------------------------------------------------------------------
# Batched schedule evaluation: memoized decompose + columnar phase columns.
#
# ``decompose`` is pure in ``(op shape, algorithm, topology)`` -- it never
# reads ``op.weight``, ``op.name`` or the hardware spec -- so a workload
# whose 10k ops repeat a few dozen shapes only needs a few dozen
# decompositions.  :func:`op_signature` canonicalizes exactly the inputs
# ``decompose`` consumes; :func:`cached_decompose` memoizes on it through
# an explicit :class:`BoundedCache` (no ``lru_cache``: that would pin op
# references for the life of the process); :func:`schedules_for_ops`
# dedupes an op stream before decomposing and fans the shared schedule
# objects back out, which downstream edge/phase caches key on ``id()``.
# ----------------------------------------------------------------------------
class BoundedCache:
    """Tiny explicit LRU: ``get`` refreshes recency, ``put`` evicts the
    stalest entry beyond ``maxsize``.  Replaces ``functools.lru_cache`` on
    the billing/schedule hot paths so long-running sessions cannot grow an
    unbounded key set, and so invalidation (:meth:`clear`) is a method on
    an object rather than an attribute of a decorated function.  A lock
    guards the recency reordering -- the module-level schedule and billing
    caches are shared by ``sweep --jobs N`` worker threads."""

    __slots__ = ("maxsize", "_data", "_lock", "hits", "misses")

    def __init__(self, maxsize: int = 4096):
        import threading
        from collections import OrderedDict
        self.maxsize = int(maxsize)
        self._data: "OrderedDict" = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def get(self, key, default=None):
        with self._lock:
            try:
                self._data.move_to_end(key)
            except KeyError:
                self.misses += 1
                return default
            self.hits += 1
            return self._data[key]

    def put(self, key, value) -> None:
        with self._lock:
            self._data[key] = value
            self._data.move_to_end(key)
            while len(self._data) > self.maxsize:
                self._data.popitem(last=False)

    def clear(self) -> None:
        with self._lock:
            self._data.clear()
            self.hits = 0
            self.misses = 0

    def __len__(self) -> int:
        return len(self._data)

    def __contains__(self, key) -> bool:
        return key in self._data


def topo_signature(topo: Optional[MeshTopology]):
    """Hashable token for everything :func:`decompose` reads off a
    topology: axis layout and the DCN axis set.  Deliberately EXCLUDES
    ``topo.hw`` -- schedules are hardware-independent (bandwidths and
    latencies only enter at :meth:`CommPhase.seconds` time), so two
    meshes differing only in hardware share cache entries, while two
    meshes with equal device counts but different axis shapes (say 8x4
    vs 4x8) get distinct tokens and can never collide."""
    if topo is None:
        return None
    return (tuple(topo.axis_names), tuple(topo.axis_sizes),
            tuple(topo.dcn_axes))


#: Identity-keyed memo for the list-valued signature tokens below.  Ops
#: emitted by a capture loop (``dataclasses.replace`` per repetition)
#: share their ``replica_groups`` / ``source_target_pairs`` /
#: ``bytes_per_rank_vec`` objects, so canonicalizing those lists -- the
#: dominant cost of :func:`op_signature` on wide meshes -- happens once
#: per distinct object instead of once per op.  Entries hold a strong
#: reference to the keyed object, so its ``id`` cannot be recycled while
#: the entry lives and the ``is`` check below is definitive.
_TOKEN_CACHE = BoundedCache(maxsize=4096)


def _identity_token(obj, build):
    """``build(obj)`` memoized by ``id(obj)`` (ops never mutate their
    group/pair/vector lists in place -- the repo's event records are
    replace-only by convention)."""
    ent = _TOKEN_CACHE.get(id(obj))
    if ent is not None and ent[0] is obj:
        return ent[1]
    tok = build(obj)
    _TOKEN_CACHE.put(id(obj), (obj, tok))
    return tok


def _groups_token_of(rg):
    """Canonical token for a replica-group list (device ids + grouping).

    Nested tuples, not array bytes: ``tuple()`` over each group runs at C
    speed on lists and ndarray rows alike, and numpy integer scalars hash
    equal to Python ints, so value-equal groups in either representation
    land on the same cache entry without ever materializing an array."""
    return tuple(map(tuple, rg))


def _groups_token(op: CollectiveOp):
    """Canonical token for ``op.replica_groups`` (device ids + grouping)."""
    rg = op.replica_groups or []
    if not rg:
        return ()
    return _identity_token(rg, _groups_token_of)


def _pairs_token_of(pairs):
    return tuple(map(tuple, pairs))


def _vec_token_of(raw):
    return tuple(raw)


def op_signature(op: CollectiveOp, algorithm: str = "ring",
                 topo: Optional[MeshTopology] = None):
    """Canonical, hashable key of ONE ``decompose`` call, or ``None`` when
    the op resists canonicalization (then callers just decompose it
    directly).  Covers every input the schedule depends on -- kind,
    algorithm, topology axis layout, payload bytes, the raw per-rank byte
    vector, and the exact replica groups / permute pairs -- and nothing
    it does not: ``op.weight``, names and phase tags are consumer-side.
    """
    base = (op.kind, algorithm, topo_signature(topo))
    try:
        if op.kind == "collective-permute":
            stp = op.source_target_pairs or []
            ptok = _identity_token(stp, _pairs_token_of) if stp else ()
            return base + (float(op.result_bytes), int(op.num_groups),
                           ptok)
        raw = getattr(op, "bytes_per_rank_vec", None)
        if raw is None:
            vtok = None
        else:
            op.byte_vector()          # keep the validation errors
            vtok = _identity_token(raw, _vec_token_of)
        return base + (float(op.payload_bytes), vtok, _groups_token(op))
    except (TypeError, ValueError, OverflowError):
        return None


#: Process-wide schedule cache.  2048 distinct (shape, algorithm, topo)
#: triples is far beyond any real capture's shape diversity; the bound
#: exists so adversarial streams degrade to plain decompose, not OOM.
_SCHEDULE_CACHE = BoundedCache(maxsize=2048)


def schedule_cache() -> BoundedCache:
    """The process-wide memoized-decompose cache (stats, tests)."""
    return _SCHEDULE_CACHE


def clear_schedule_cache() -> None:
    """Drop every memoized schedule (tests, post-topology-mutation)."""
    _SCHEDULE_CACHE.clear()


def cached_decompose(op: CollectiveOp, algorithm: str = "ring",
                     topo: Optional[MeshTopology] = None, *,
                     warn: bool = True,
                     cache: Optional[BoundedCache] = None
                     ) -> CollectiveSchedule:
    """Memoized :func:`decompose`: same signature -> the SAME schedule
    object.  Fallback warnings recorded at miss time are replayed through
    :func:`warn_fallback_once` on every warning hit, so the once-per-
    session diagnostics survive memoization."""
    cache = _SCHEDULE_CACHE if cache is None else cache
    key = op_signature(op, algorithm, topo)
    if key is None:
        return decompose(op, algorithm, topo, warn=warn)
    hit = cache.get(key)
    if hit is not None:
        sched, fallbacks = hit
        if warn:
            for kind, n, msg in fallbacks:
                warn_fallback_once(kind, n, msg, stacklevel=1)
        return sched
    records: list = []
    sched = decompose(op, algorithm, topo, warn=warn, _fallbacks=records)
    cache.put(key, (sched, tuple(records)))
    return sched


def schedules_for_ops(ops: Iterable[CollectiveOp], algorithm: str,
                      topo: Optional[MeshTopology] = None, *,
                      warn: bool = False,
                      cache: Optional[BoundedCache] = None
                      ) -> list[CollectiveSchedule]:
    """Schedules for an op stream, deduped by :func:`op_signature` before
    decomposing and fanned back out: ops sharing a signature share ONE
    schedule object, which edge/phase caches downstream key on ``id()``.
    A per-call dedupe map backs the bounded cache so even a thrashing
    cache cannot force duplicate work within one stream.  The cache
    lookup is inlined (rather than delegated to :func:`cached_decompose`)
    so each op pays for exactly ONE signature computation, and once the
    stream's distinct-shape count exceeds the cache bound the global
    get/put traffic stops: every further put would only evict an earlier
    key of the SAME stream (pure churn -- cross-call reuse for such a
    stream was already lost to eviction), so the local map carries the
    rest alone."""
    cache = _SCHEDULE_CACHE if cache is None else cache
    local: dict = {}
    out: list[CollectiveSchedule] = []
    spilled = False
    for op in ops:
        key = op_signature(op, algorithm, topo)
        if key is None:
            out.append(decompose(op, algorithm, topo, warn=warn))
            continue
        sched = local.get(key)
        if sched is None:
            hit = None if spilled else cache.get(key)
            if hit is not None:
                sched, fallbacks = hit
                if warn:
                    for kind, n, msg in fallbacks:
                        warn_fallback_once(kind, n, msg, stacklevel=1)
            else:
                records: list = []
                sched = decompose(op, algorithm, topo, warn=warn,
                                  _fallbacks=records)
                if not spilled:
                    cache.put(key, (sched, tuple(records)))
            local[key] = sched
            spilled = spilled or len(local) >= cache.maxsize
        out.append(sched)
    return out


class ScheduleBatch:
    """Columnar view over one op stream's schedules.

    Flat float64/bool/intp arrays across ALL phases of all ops --
    ``op_index`` / ``stream`` / ``is_dcn`` / ``max_bytes`` / ``hops``
    laid out op-major in schedule order, with ``op_phase_ptr`` (CSR-style,
    ``nops + 1``) delimiting each op's slice -- so timing and billing run
    as array expressions instead of per-phase Python.  ``schedules``
    holds the (deduped, shared) schedule objects aligned with ``ops``;
    ``edge_cache`` is the per-batch ``id(schedule) -> edge arrays`` memo
    ``comm_matrix`` fills, so the matrix build also pays per *distinct*
    schedule.  Every derived quantity is BITWISE identical to the per-op
    path: phase seconds use the same scalar expression elementwise,
    per-(op, stream, tier) sums run through unbuffered ``np.add.at`` in
    phase order (the exact float-addition sequence of the Python loop),
    and weighted totals reduce through a sequential Python sum.
    """

    __slots__ = ("ops", "algorithm", "topo", "schedules", "weight",
                 "op_index", "stream", "is_dcn", "max_bytes", "hops",
                 "op_phase_ptr", "edge_cache")

    def __init__(self, ops, schedules, algorithm: Optional[str] = None,
                 topo: Optional[MeshTopology] = None):
        self.ops = list(ops)
        self.schedules = list(schedules)
        if len(self.ops) != len(self.schedules):
            raise ValueError(
                f"{len(self.ops)} ops vs {len(self.schedules)} schedules")
        self.algorithm = algorithm
        self.topo = topo
        self.weight = np.asarray(
            [max(1.0, float(getattr(op, "weight", 1.0)))
             for op in self.ops], dtype=np.float64)
        self.edge_cache: dict = {}
        cols: dict = {}          # id(sched) -> per-phase column template
        op_idx, streams, dcn, mb, hops = [], [], [], [], []
        ptr = [0]
        total = 0
        for i, sched in enumerate(self.schedules):
            tmpl = cols.get(id(sched))
            if tmpl is None:
                k = len(sched.phases)
                tmpl = cols[id(sched)] = (
                    np.fromiter((ph.stream for ph in sched.phases),
                                dtype=np.intp, count=k),
                    np.fromiter((ph.tier == "dcn" for ph in sched.phases),
                                dtype=bool, count=k),
                    np.fromiter((ph.max_bytes_per_rank()
                                 for ph in sched.phases),
                                dtype=np.float64, count=k),
                    np.fromiter((ph.latency_hops for ph in sched.phases),
                                dtype=np.float64, count=k),
                )
            k = tmpl[0].size
            op_idx.append(np.full(k, i, dtype=np.intp))
            streams.append(tmpl[0])
            dcn.append(tmpl[1])
            mb.append(tmpl[2])
            hops.append(tmpl[3])
            total += k
            ptr.append(total)
        if total:
            self.op_index = np.concatenate(op_idx)
            self.stream = np.concatenate(streams)
            self.is_dcn = np.concatenate(dcn)
            self.max_bytes = np.concatenate(mb)
            self.hops = np.concatenate(hops)
        else:
            self.op_index = np.empty(0, dtype=np.intp)
            self.stream = np.empty(0, dtype=np.intp)
            self.is_dcn = np.empty(0, dtype=bool)
            self.max_bytes = np.empty(0, dtype=np.float64)
            self.hops = np.empty(0, dtype=np.float64)
        self.op_phase_ptr = np.asarray(ptr, dtype=np.intp)

    @classmethod
    def from_ops(cls, ops, algorithm: str,
                 topo: Optional[MeshTopology] = None, *,
                 warn: bool = False,
                 cache: Optional[BoundedCache] = None) -> "ScheduleBatch":
        ops = list(ops)
        scheds = schedules_for_ops(ops, algorithm, topo, warn=warn,
                                   cache=cache)
        return cls(ops, scheds, algorithm, topo)

    def __len__(self) -> int:
        return len(self.ops)

    @property
    def num_phases(self) -> int:
        return int(self.op_index.size)

    @property
    def num_distinct(self) -> int:
        """Distinct schedule objects (the work actually decomposed)."""
        return len({id(s) for s in self.schedules})

    def phase_slice(self, i: int) -> slice:
        """Column slice of op ``i``'s phases (aligned with
        ``self.schedules[i].phases``)."""
        return slice(int(self.op_phase_ptr[i]), int(self.op_phase_ptr[i + 1]))

    def phase_seconds(self, topo: Optional[MeshTopology] = None, *,
                      include_latency: bool = True) -> np.ndarray:
        """Per-phase streaming seconds, columnar: elementwise the exact
        scalar expression of :meth:`CommPhase.seconds`."""
        topo = self.topo if topo is None else topo
        if topo is None:
            raise ValueError("phase_seconds needs a topology")
        bw = np.where(self.is_dcn, topo.ring_bw_per_chip(True),
                      topo.ring_bw_per_chip(False))
        sec = self.max_bytes / bw
        if include_latency:
            lat = np.where(self.is_dcn, topo.hw.dcn_hop_latency_s,
                           topo.hw.ici_hop_latency_s)
            sec = sec + self.hops * lat
        return sec

    def time_split_per_op(self, topo: Optional[MeshTopology] = None, *,
                          include_latency: bool = True
                          ) -> tuple[np.ndarray, np.ndarray]:
        """``(ici, dcn)`` seconds per op for ONE execution -- the columnar
        :meth:`CollectiveSchedule.time_split`: phases of one stream sum
        (sequentially, in phase order), tiers take the max over streams."""
        nops = len(self.ops)
        ici = np.zeros(nops, dtype=np.float64)
        dcn = np.zeros(nops, dtype=np.float64)
        if self.op_index.size == 0:
            return ici, dcn
        sec = self.phase_seconds(topo, include_latency=include_latency)
        # compact (op, stream) ids; streams are per-op counters < 2**31
        pair = (self.op_index.astype(np.int64) << 31) \
            | self.stream.astype(np.int64)
        uniq, inv = np.unique(pair, return_inverse=True)
        acc = np.zeros((uniq.size, 2), dtype=np.float64)
        # np.add.at is unbuffered: within each (op, stream, tier) cell the
        # additions land in array order == phase order, reproducing the
        # per-op Python accumulation bitwise
        np.add.at(acc, (inv, self.is_dcn.astype(np.intp)), sec)
        op_of = (uniq >> 31).astype(np.intp)
        np.maximum.at(ici, op_of, acc[:, 0])
        np.maximum.at(dcn, op_of, acc[:, 1])
        return ici, dcn

    def total_time_split(self, topo: Optional[MeshTopology] = None, *,
                         include_latency: bool = True
                         ) -> tuple[float, float]:
        """Weighted ``(ici, dcn)`` totals over the stream.  The final
        reduction is a sequential Python sum in op order -- numpy's
        pairwise ``sum`` is faster but not bitwise-equal to the per-op
        accumulation loop this replaces."""
        ici_arr, dcn_arr = self.time_split_per_op(
            topo, include_latency=include_latency)
        iw = ici_arr * self.weight
        dw = dcn_arr * self.weight
        ici = 0.0
        dcn = 0.0
        for a, b in zip(iw.tolist(), dw.tolist()):
            ici += a
            dcn += b
        return ici, dcn
