"""High-level monitoring API — ComScribe's workflow, end to end.

The paper's workflow (Fig. 1): preload shim -> record transfers during
execution -> post-process into matrices + statistics.  Ours:

1. **intercept**: trace each captured function under a scoped primitive hook
   (:mod:`repro.core.interceptor`) -> logical, application-issued collectives;
2. **extract**: compile and parse the SPMD module
   (:mod:`repro.core.hlo_parser`) -> physical, compiler-scheduled collectives;
3. **post-process**: per-primitive statistics (Tables 2/3), ``(d+1)^2``
   communication matrices (Figs. 2/3), logical-vs-physical diff, and the
   roofline terms used by the perf loop.

The accumulating front door is :class:`~repro.core.session.MonitorSession`
(any number of captures under named phases); derived artifacts live on lazy
:class:`~repro.core.views.CommView` bindings (``session.view()`` /
``report.view()``), one per ``(algorithm, phase)``.  ``monitor_fn`` below is
the one-call compatibility wrapper -- a single capture in a single phase --
still used by examples, benchmarks, the dry-run launcher and the sweep CLI.
Reports round-trip losslessly through :meth:`CommReport.save` /
:meth:`CommReport.load` (schema v5, :mod:`repro.core.export.serialize`;
v1-v4 files still load), which is also how the on-disk report cache
(:mod:`repro.core.report_cache`) lets repeated sweeps skip recompilation.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from . import cost_models, hlo_parser, reporter, roofline
from .events import CollectiveOp, HostTransfer, PhaseRecord, TraceEvent
from .sparse import is_sparse
from .topology import MeshTopology, V5E
from .views import CommView, build_view


@dataclasses.dataclass
class CommReport:
    """Everything ComScribe produces for one session, plus the TPU extras.

    A report is the *serializable snapshot* of a monitoring session: it
    serializes losslessly to JSON via :meth:`save` and comes back via
    :meth:`load`, so sweeps can cache it on disk
    (:mod:`repro.core.report_cache`) keyed by ``(config, mesh, algorithm,
    jax version)`` and re-render any export format without recompiling.
    ``phases`` records the session's named capture phases (empty for
    legacy single-shot reports); every op / traced event / host transfer
    carries its phase tag, so per-phase views rebuild from loaded files.

    ``algorithm`` records which collective algorithm the eager byte
    accounting (``matrix``, ``per_primitive``, ``compiled_summary``) was
    derived with.  Every *derived* artifact beyond those snapshot fields is
    served by :meth:`view`: a lazy, memoized
    :class:`~repro.core.views.CommView` per ``(algorithm, phase)`` binding
    -- re-binding ring -> tree -> hierarchical recomputes nothing until an
    artifact is read, and never recompiles.

    Export beyond the terminal renderings below lives in
    :mod:`repro.core.export` (JSON / CSV / HTML heatmap dashboard / Perfetto
    timeline), or from the shell::

        python -m repro report artifacts/quickstart_report.json \\
            --formats html,perfetto --out artifacts/
    """

    name: str
    num_devices: int
    traced: list[TraceEvent]
    compiled_ops: list[CollectiveOp]
    traced_summary: dict
    compiled_summary: dict
    # (d+1)x(d+1) bytes, row/col 0 host: a dense ndarray, or the COO
    # SparseCommMatrix form at fleet scale (sparse sessions / loaded v6)
    matrix: np.ndarray
    per_primitive: dict[str, np.ndarray]
    cost: dict
    memory_stats: Optional[dict]
    trace_seconds: float
    compile_seconds: float
    topo: Optional[MeshTopology] = None
    host_transfers: list[HostTransfer] = dataclasses.field(default_factory=list)
    algorithm: str = "ring"                 # algorithm the matrices assume
    meta: dict = dataclasses.field(default_factory=dict)  # sweep provenance
    phases: list[PhaseRecord] = dataclasses.field(default_factory=list)
    # import provenance when the report was built from a real device trace
    # (:mod:`repro.core.trace`): source frontend, trace path, clock
    # alignment, device mapping.  None for purely modeled reports.
    trace_meta: Optional[dict] = None

    # -- lazy algorithm/phase-bound views ---------------------------------
    def view(self, algorithm: Optional[str] = None,
             phase: Optional[str] = None) -> CommView:
        """The :class:`CommView` for ``(algorithm, phase)`` (defaults: the
        report's own algorithm, the whole session).  Memoized per binding;
        the default binding is seeded with the snapshot's eager artifacts,
        so reading it recomputes nothing.
        """
        alg = algorithm or self.algorithm
        cost_models.validate_algorithm(alg)
        if not hasattr(self, "_views"):
            self._views: dict = {}
        key = (alg, phase)
        if key not in self._views:
            # a sparse snapshot keeps every derived binding sparse; dense
            # snapshots leave the per-binding auto cutover in charge
            v = build_view(
                self.compiled_ops, self.num_devices, alg, self.topo,
                self.host_transfers, phase=phase,
                known_phases=self.phase_names(), label=self.name,
                sparse=True if is_sparse(self.matrix) else None,
                hlo_texts=self._all_hlo_texts())
            if phase is None and alg == self.algorithm:
                v._memo.update(matrix=self.matrix,
                               per_primitive=self.per_primitive,
                               summary=self.compiled_summary)
            self._views[key] = v
        return self._views[key]

    def _all_hlo_texts(self) -> list[str]:
        """Compiled module texts (one per capture) when the report carries
        them -- live sessions always do; loaded files only when saved with
        ``include_hlo=True``.  Empty list otherwise."""
        texts = getattr(self, "_hlo_texts", None)
        if texts:
            return [t for t in texts if t]
        single = getattr(self, "_hlo_text", None)
        return [single] if single else []

    def phase_names(self) -> list[str]:
        """Phase order of the originating session (op-tag order for files
        predating the phase records; empty for single-shot legacy data)."""
        if self.phases:
            return [p.name for p in self.phases]
        seen: list[str] = []
        for op in self.compiled_ops:
            if op.phase and op.phase not in seen:
                seen.append(op.phase)
        return seen

    def phase_view(self, phase: str,
                   algorithm: Optional[str] = None) -> CommView:
        """Shorthand for :meth:`view` with a required phase."""
        return self.view(algorithm, phase=phase)

    def phase_summaries(self, algorithm: Optional[str] = None) -> dict:
        """``{phase: Table-2 summary}`` in phase order."""
        return {p: self.view(algorithm, phase=p).summary
                for p in self.phase_names()}

    # -- paper-style renderings -------------------------------------------
    def usage_table(self) -> str:
        return reporter.primitive_usage_table(
            self.compiled_summary, title=f"{self.name}: compiled collectives")

    def logical_table(self) -> str:
        return reporter.primitive_usage_table(
            self.traced_summary, title=f"{self.name}: traced (application) collectives")

    def phase_table(self, algorithm: Optional[str] = None) -> str:
        """Per-phase Table-2 breakdown (paper Table 2, one block per
        phase) -- the session analogue of :meth:`usage_table`."""
        return reporter.phase_usage_table(
            self.phase_summaries(algorithm),
            title=f"{self.name}: per-phase compiled collectives")

    def phase_diff(self, a: str, b: str,
                   algorithm: Optional[str] = None) -> str:
        """Primitive-by-primitive comparison of two phases' compiled
        communication (calls + wire bytes, with the wire-byte delta)."""
        return reporter.phase_diff_table(
            a, self.view(algorithm, phase=a).summary,
            b, self.view(algorithm, phase=b).summary)

    def heatmap(self, kind: Optional[str] = None,
                phase: Optional[str] = None) -> str:
        v = self.view(phase=phase)
        mat = v.per_primitive.get(kind, v.matrix) if kind else v.matrix
        t = (f"{self.name} comm matrix"
             + (f" [{kind}]" if kind else "")
             + (f" [phase {phase}]" if phase else ""))
        return reporter.ascii_heatmap(mat, title=t)

    def diff(self) -> str:
        return reporter.diff_table(self.traced_summary, self.compiled_summary)

    def total_wire_bytes(self, algorithm: Optional[str] = None) -> float:
        return self.view(algorithm).total_wire_bytes()

    def collective_seconds(self, algorithm: Optional[str] = None) -> float:
        return self.view(algorithm).collective_seconds()

    def collective_seconds_split(
            self, algorithm: Optional[str] = None) -> tuple[float, float]:
        """Per-tier serialized collective time ``(ici_s, dcn_s)``; sums to
        :meth:`collective_seconds`.  ``(0, 0)`` without a topology."""
        return self.view(algorithm).collective_seconds_split()

    def collective_overlap_seconds(
            self, algorithm: Optional[str] = None) -> float:
        """Overlap-aware communication time: ICI and DCN are independent
        fabrics, so the slower tier bounds the overlapped schedule --
        ``max`` of the per-tier serialized sums, always <=
        :meth:`collective_seconds` (equal when one tier has it all)."""
        return self.view(algorithm).collective_overlap_seconds()

    # -- physical-link view ------------------------------------------------
    def link_utilization(self, algorithm: Optional[str] = None):
        """Project the matrix onto physical links (ICI hops, DCN uplinks).

        Returns a :class:`~repro.core.comm_matrix.LinkUtilization` (bytes
        per link, bottleneck link, contention-aware seconds), or ``None``
        when the report carries no topology (monitoring without
        ``mesh=``).  Derived from the compiled ops, so it works on loaded
        and cached reports too.
        """
        return self.view(algorithm).link_utilization()

    def link_matrix(self, algorithm: Optional[str] = None):
        """The ``(d+1)^2`` per-link byte matrix: entry ``(i+1, j+1)`` is the
        physical ICI link ``i -> j``; row/col 0 is the DCN tier (uplinks/
        downlinks).  ``None`` without a topology."""
        return self.view(algorithm).link_matrix()

    def link_seconds(self, algorithm: Optional[str] = None) -> float:
        """Contention-aware communication time: the bottleneck link's
        bytes/bandwidth (max over links, not flat per-chip bandwidth)."""
        return self.view(algorithm).link_seconds()

    def link_table(self) -> str:
        lu = self.link_utilization()
        if lu is None:
            return "(no topology: pass mesh= to the monitor for link stats)"
        ici_s, dcn_s = self.collective_seconds_split()
        overlap = (f"tier overlap: ici {ici_s * 1e3:.3f} ms ∥ dcn "
                   f"{dcn_s * 1e3:.3f} ms -> overlapped "
                   f"{max(ici_s, dcn_s) * 1e3:.3f} ms "
                   f"(serialized {(ici_s + dcn_s) * 1e3:.3f} ms)")
        return lu.table() + "\n" + overlap

    def render(self) -> str:
        parts = [
            f"### CommReport: {self.name} ({self.num_devices} devices) ###",
            self.logical_table(),
            self.usage_table(),
        ]
        if len(self.phase_names()) >= 2:
            parts.append(self.phase_table())
        parts += [
            "-- traced vs compiled --",
            self.diff(),
            self.heatmap(),
        ]
        if self.topo is not None:
            parts.append("-- physical links --\n" + self.link_table())
        parts.append(
            f"trace {self.trace_seconds * 1e3:.1f} ms | "
            f"compile {self.compile_seconds * 1e3:.1f} ms | "
            f"wire bytes (all devices) {reporter.human_bytes(self.total_wire_bytes())}")
        return "\n\n".join(parts)

    def rebound(self, algorithm: str) -> "CommReport":
        """A sibling snapshot report with its eager artifacts re-derived
        from ``view(algorithm)``.

        This is NOT the way to compare algorithms -- use :meth:`view`,
        which binds lazily and memoizes.  It exists for the one consumer
        that genuinely needs a whole replacement *snapshot*: the sweep
        engine's derive path, whose on-disk cache stores one serialized
        report per ``(config, mesh, algorithm)`` cell.  (The old
        ``with_algorithm`` spelling is gone; compilation never depended on
        the algorithm, so no recompilation either way.)
        """
        if algorithm == self.algorithm:
            return self
        v = self.view(algorithm)
        rep = dataclasses.replace(
            self,
            algorithm=algorithm,
            compiled_summary=v.summary,
            matrix=v.matrix,
            per_primitive=v.per_primitive,
            meta=dict(self.meta, algorithm=algorithm),
        )
        for attr in ("_lowered", "_compiled", "_hlo_text", "_hlo_texts"):
            if hasattr(self, attr):
                setattr(rep, attr, getattr(self, attr))
        return rep

    def schedule_summaries(self, algorithm: Optional[str] = None) -> list[dict]:
        """Per-op decomposition-schedule summaries (one entry per compiled
        op, aligned with ``compiled_ops``): the phase IR's serializable
        face, also written by ``save(..., include_schedules=True)``."""
        return self.view(algorithm).schedule_summaries()

    # -- measured (trace-imported) time -------------------------------------
    def measured_seconds(self, phase: Optional[str] = None) -> Optional[float]:
        """Total *measured* wall seconds over ops that carry a trace
        measurement (``op.measured_s``, schema v9) -- ``None`` when no op
        does, i.e. for purely modeled reports."""
        return self.view(phase=phase).measured_seconds()

    def compare(self, model=None, algorithm: Optional[str] = None):
        """Modeled-vs-measured comparison
        (:class:`~repro.core.trace.compare.CompareResult`) of this report's
        measured ops against ``model`` (a CommReport / CommView; default:
        this report's own modeled times)."""
        from .trace.compare import compare as compare_fn

        return compare_fn(self, model, algorithm=algorithm)

    # -- static lint ---------------------------------------------------------
    def lint(self, algorithm: Optional[str] = None,
             phase: Optional[str] = None) -> list:
        """Static anti-pattern findings
        (:class:`~repro.core.lint.LintFinding`) for the ``(algorithm,
        phase)`` binding -- lazy and memoized via :meth:`view`.  A report
        loaded from a schema-v7 file saved with ``include_lint=True``
        serves its persisted default-binding findings without re-analysis
        (and without needing the HLO text back)."""
        alg = algorithm or self.algorithm
        if phase is None and alg == self.algorithm:
            cached = getattr(self, "_lint_findings", None)
            if cached is not None:
                return cached
        return self.view(alg, phase=phase).lint()

    def lint_table(self, algorithm: Optional[str] = None) -> str:
        """Terminal rendering of :meth:`lint` (reporter.lint_table)."""
        return reporter.lint_table(
            self.lint(algorithm), title=f"{self.name}: lint findings")

    def save(self, path: str, *, include_hlo: bool = False,
             include_schedules: bool = False,
             include_lint: bool = False):
        """Write the full report as schema-v7 JSON (see ``load``).

        The file is a lossless round-trip: ops, traced events, matrices,
        summaries, topology, phase records and timings all survive.  It is
        also a strict superset of the legacy ``reporter.dump_report``
        layout (``name``, ``summary``, ``ops``, ``matrix`` keep their old
        meaning), so existing consumers of those files keep working.

        ``include_hlo=True`` additionally persists the compiled HLO text
        (gzip + base64, ``hlo_gz`` key) so :func:`roofline_of` works on the
        loaded report without a live compilation.
        ``include_schedules=True`` adds the optional schema-v5
        ``schedules`` section: one decomposition-schedule summary per op
        (phase kind / tier / structure / axis / bytes / latency hops).
        ``include_lint=True`` adds the schema-v7 ``lint`` section: the
        default binding's :meth:`lint` findings, served back by loaded
        reports without re-analysis.
        """
        from .export import export_json
        export_json(self, path, include_hlo=include_hlo,
                    include_schedules=include_schedules,
                    include_lint=include_lint)

    @classmethod
    def load(cls, path: str) -> "CommReport":
        """Read a report written by :meth:`save` (or the report cache).

        Accepts schema v1-v7.  Loaded reports render, diff, export and
        feed the cost models exactly like fresh ones; ``roofline_of``
        additionally needs the compiled HLO, which is present when the
        file was saved with ``include_hlo=True`` (otherwise a live
        compilation is required).
        """
        from .export import load_json
        return load_json(path)


def monitor_fn(
    fn,
    *args,
    mesh=None,
    name: str = "fn",
    in_shardings=None,
    out_shardings=None,
    donate_argnums=(),
    static_argnums=(),
    algorithm: str = "ring",
    host_transfers: Optional[list[HostTransfer]] = None,
    sparse: Optional[bool] = None,
    op_transform=None,
    **kwargs,
) -> CommReport:
    """Monitor one function end-to-end: a single-capture, single-phase
    :class:`~repro.core.session.MonitorSession`, snapshotted.

    ``args``/``kwargs`` may be concrete arrays or ``jax.ShapeDtypeStruct``
    stand-ins (the dry-run path: no device memory is allocated).

    ``algorithm`` selects the collective algorithm assumed by the byte
    accounting (``ring`` / ``tree`` / ``hierarchical``, paper Table 1;
    anything else raises); use ``report.view(...)`` to re-bind another one
    lazily without recompiling.  Compilation dominates this call's cost --
    for iterative use, persist the result (``report.save``) or go through
    the sweep CLI, which caches reports on disk keyed by ``(config, mesh,
    algorithm, jax version)`` and logs ``[cache] hit`` instead of
    recompiling::

        python -m repro sweep --configs paper,gnmt,resnet \\
            --algorithms ring,tree          # first run compiles
        python -m repro sweep --configs paper,gnmt,resnet \\
            --algorithms ring,tree          # second run: all cache hits

    Multi-step workloads with distinguishable phases (fwd/bwd/optimizer,
    prefill/decode) should use :class:`MonitorSession` directly -- this
    wrapper exists so one-shot callers and pre-session code keep working,
    golden-tested equal to the session path.
    """
    from .session import MonitorSession

    session = MonitorSession(mesh=mesh, name=name, algorithm=algorithm,
                             sparse=sparse)
    with session:
        session.capture(
            fn, *args, name=name,
            in_shardings=in_shardings, out_shardings=out_shardings,
            donate_argnums=donate_argnums, static_argnums=static_argnums,
            host_transfers=host_transfers, op_transform=op_transform,
            **kwargs)
    return session.report()


def roofline_of(report: CommReport, *, arch: str = "", mesh_name: str = "",
                model_flops: float = 0.0,
                algorithm: str = "ring") -> roofline.RooflineReport:
    assert report.topo is not None, "monitoring needs mesh= for roofline"
    # one module per capture; analyzed per module (concatenating would
    # clobber same-named computations across independently compiled modules)
    hlo_texts = getattr(report, "_hlo_texts", None)
    if not hlo_texts:
        single = getattr(report, "_hlo_text", None)
        hlo_texts = [single] if single else None
    if not hlo_texts:
        raise ValueError(
            "report carries no compiled HLO (loaded from a file saved "
            "without include_hlo=True); re-monitor, or save with "
            "report.save(path, include_hlo=True) to make rooflines work "
            "on loaded reports")
    return roofline.analyze(
        arch=arch or report.name,
        mesh_name=mesh_name,
        cost=report.cost,
        hlo_text=hlo_texts,
        topo=report.topo,
        hw=report.topo.hw if report.topo else V5E,
        model_flops=model_flops,
        memory_stats=report.memory_stats,
        algorithm=algorithm,
        link_utilization=report.link_utilization(algorithm),
    )
