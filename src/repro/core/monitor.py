"""High-level monitoring API — ComScribe's workflow, end to end.

The paper's workflow (Fig. 1): preload shim -> record transfers during
execution -> post-process into matrices + statistics.  Ours:

1. **intercept**: trace the function under a scoped primitive hook
   (:mod:`repro.core.interceptor`) -> logical, application-issued collectives;
2. **extract**: compile and parse the SPMD module
   (:mod:`repro.core.hlo_parser`) -> physical, compiler-scheduled collectives;
3. **post-process**: per-primitive statistics (Tables 2/3), ``(d+1)^2``
   communication matrices (Figs. 2/3), logical-vs-physical diff, and the
   roofline terms used by the perf loop.

``monitor_fn`` is the one-call entry point used by examples, benchmarks, the
dry-run launcher and the sweep CLI (``python -m repro sweep``).  Reports
round-trip losslessly through :meth:`CommReport.save` / :meth:`CommReport.load`
(schema v1, :mod:`repro.core.export.serialize`), which is also how the on-disk
report cache (:mod:`repro.core.report_cache`) lets repeated sweeps skip
recompilation entirely.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Optional

import jax
import numpy as np

from . import comm_matrix, cost_models, hlo_parser, reporter, roofline
from .events import CollectiveOp, HostTransfer, TraceEvent
from .interceptor import CollectiveInterceptor
from .topology import MeshTopology, V5E


@dataclasses.dataclass
class CommReport:
    """Everything ComScribe produces for one program, plus the TPU extras.

    A report is a plain data object: it serializes losslessly to JSON via
    :meth:`save` and comes back via :meth:`load`, so sweeps can cache it on
    disk (:mod:`repro.core.report_cache`) keyed by ``(config, mesh,
    algorithm, jax version)`` and re-render any export format without
    recompiling.  ``algorithm`` records which collective algorithm the byte
    accounting (``matrix``, ``per_primitive``, ``compiled_summary``) was
    derived with; :meth:`with_algorithm` re-derives them for another
    algorithm from the same compiled ops -- no recompilation.

    Export beyond the terminal renderings below lives in
    :mod:`repro.core.export` (JSON / CSV / HTML heatmap dashboard / Perfetto
    timeline), or from the shell::

        python -m repro report artifacts/quickstart_report.json \\
            --formats html,perfetto --out artifacts/
    """

    name: str
    num_devices: int
    traced: list[TraceEvent]
    compiled_ops: list[CollectiveOp]
    traced_summary: dict
    compiled_summary: dict
    matrix: np.ndarray                      # (d+1)x(d+1) bytes, row/col 0 host
    per_primitive: dict[str, np.ndarray]
    cost: dict
    memory_stats: Optional[dict]
    trace_seconds: float
    compile_seconds: float
    topo: Optional[MeshTopology] = None
    host_transfers: list[HostTransfer] = dataclasses.field(default_factory=list)
    algorithm: str = "ring"                 # algorithm the matrices assume
    meta: dict = dataclasses.field(default_factory=dict)  # sweep provenance

    # -- paper-style renderings -------------------------------------------
    def usage_table(self) -> str:
        return reporter.primitive_usage_table(
            self.compiled_summary, title=f"{self.name}: compiled collectives")

    def logical_table(self) -> str:
        return reporter.primitive_usage_table(
            self.traced_summary, title=f"{self.name}: traced (application) collectives")

    def heatmap(self, kind: Optional[str] = None) -> str:
        mat = self.per_primitive.get(kind, self.matrix) if kind else self.matrix
        t = f"{self.name} comm matrix" + (f" [{kind}]" if kind else "")
        return reporter.ascii_heatmap(mat, title=t)

    def diff(self) -> str:
        return reporter.diff_table(self.traced_summary, self.compiled_summary)

    def total_wire_bytes(self, algorithm: Optional[str] = None) -> float:
        return hlo_parser.total_wire_bytes(
            self.compiled_ops, algorithm or self.algorithm, topo=self.topo)

    def collective_seconds(self, algorithm: Optional[str] = None) -> float:
        if self.topo is None:
            return 0.0
        return cost_models.total_time(
            self.compiled_ops, self.topo, algorithm or self.algorithm)

    def collective_seconds_split(
            self, algorithm: Optional[str] = None) -> tuple[float, float]:
        """Per-tier serialized collective time ``(ici_s, dcn_s)``; sums to
        :meth:`collective_seconds`.  ``(0, 0)`` without a topology."""
        if self.topo is None:
            return 0.0, 0.0
        return cost_models.total_time_split(
            self.compiled_ops, self.topo, algorithm or self.algorithm)

    def collective_overlap_seconds(
            self, algorithm: Optional[str] = None) -> float:
        """Overlap-aware communication time: ICI and DCN are independent
        fabrics, so the slower tier bounds the overlapped schedule --
        ``max`` of the per-tier serialized sums, always <=
        :meth:`collective_seconds` (equal when one tier has it all)."""
        return max(self.collective_seconds_split(algorithm))

    # -- physical-link view ------------------------------------------------
    def link_utilization(self, algorithm: Optional[str] = None):
        """Project the matrix onto physical links (ICI hops, DCN uplinks).

        Returns a :class:`~repro.core.comm_matrix.LinkUtilization` (bytes
        per link, bottleneck link, contention-aware seconds), or ``None``
        when the report carries no topology (``monitor_fn`` without
        ``mesh=``).  Derived from the compiled ops, so it works on loaded
        and cached reports too.
        """
        if self.topo is None:
            return None
        return comm_matrix.link_utilization_for_ops(
            self.compiled_ops, self.topo, algorithm or self.algorithm)

    def link_matrix(self, algorithm: Optional[str] = None):
        """The ``(d+1)^2`` per-link byte matrix: entry ``(i+1, j+1)`` is the
        physical ICI link ``i -> j``; row/col 0 is the DCN tier (uplinks/
        downlinks).  ``None`` without a topology."""
        lu = self.link_utilization(algorithm)
        return None if lu is None else lu.matrix()

    def link_seconds(self, algorithm: Optional[str] = None) -> float:
        """Contention-aware communication time: the bottleneck link's
        bytes/bandwidth (max over links, not flat per-chip bandwidth)."""
        lu = self.link_utilization(algorithm)
        return 0.0 if lu is None else lu.bottleneck_seconds()

    def link_table(self) -> str:
        lu = self.link_utilization()
        if lu is None:
            return "(no topology: pass mesh= to monitor_fn for link stats)"
        ici_s, dcn_s = self.collective_seconds_split()
        overlap = (f"tier overlap: ici {ici_s * 1e3:.3f} ms ∥ dcn "
                   f"{dcn_s * 1e3:.3f} ms -> overlapped "
                   f"{max(ici_s, dcn_s) * 1e3:.3f} ms "
                   f"(serialized {(ici_s + dcn_s) * 1e3:.3f} ms)")
        return lu.table() + "\n" + overlap

    def render(self) -> str:
        parts = [
            f"### CommReport: {self.name} ({self.num_devices} devices) ###",
            self.logical_table(),
            self.usage_table(),
            "-- traced vs compiled --",
            self.diff(),
            self.heatmap(),
        ]
        if self.topo is not None:
            parts.append("-- physical links --\n" + self.link_table())
        parts.append(
            f"trace {self.trace_seconds * 1e3:.1f} ms | "
            f"compile {self.compile_seconds * 1e3:.1f} ms | "
            f"wire bytes (all devices) {reporter.human_bytes(self.total_wire_bytes())}")
        return "\n\n".join(parts)

    def with_algorithm(self, algorithm: str) -> "CommReport":
        """Same compiled ops, byte accounting re-derived for ``algorithm``.

        Compilation does not depend on the collective algorithm -- only the
        wire-byte model and matrix edge placement do -- so this is the cheap
        way to compare ring vs tree for one program (the sweep engine uses it
        to fill cache entries for extra algorithms without recompiling).
        """
        if algorithm == self.algorithm:
            return self
        rep = dataclasses.replace(
            self,
            algorithm=algorithm,
            compiled_summary=hlo_parser.summarize(
                self.compiled_ops, algorithm, topo=self.topo),
            matrix=comm_matrix.matrix_for_ops(
                self.compiled_ops, self.num_devices, algorithm,
                topo=self.topo),
            per_primitive=comm_matrix.per_primitive_matrices(
                self.compiled_ops, self.num_devices, algorithm,
                topo=self.topo),
            meta=dict(self.meta, algorithm=algorithm),
        )
        if self.host_transfers:
            comm_matrix.add_host_transfers(rep.matrix, self.host_transfers)
        for attr in ("_lowered", "_compiled", "_hlo_text"):
            if hasattr(self, attr):
                setattr(rep, attr, getattr(self, attr))
        return rep

    def save(self, path: str):
        """Write the full report as schema-v1 JSON (see ``load``).

        The file is a lossless round-trip: ops, traced events, matrices,
        summaries, topology and timings all survive.  It is also a strict
        superset of the legacy ``reporter.dump_report`` layout (``name``,
        ``summary``, ``ops``, ``matrix`` keep their old meaning), so existing
        consumers of those files keep working.
        """
        from .export import export_json
        export_json(self, path)

    @classmethod
    def load(cls, path: str) -> "CommReport":
        """Read a report written by :meth:`save` (or the report cache).

        Loaded reports render, diff, export and feed the cost models exactly
        like fresh ones; only ``roofline_of`` needs a live compilation (the
        HLO text is not persisted).
        """
        from .export import load_json
        return load_json(path)


def _memory_stats(compiled) -> Optional[dict]:
    try:
        m = compiled.memory_analysis()
        return {
            "argument_bytes": m.argument_size_in_bytes,
            "output_bytes": m.output_size_in_bytes,
            "temp_bytes": m.temp_size_in_bytes,
            "alias_bytes": m.alias_size_in_bytes,
            "generated_code_bytes": m.generated_code_size_in_bytes,
            "total_bytes": (m.argument_size_in_bytes + m.output_size_in_bytes
                            + m.temp_size_in_bytes - m.alias_size_in_bytes),
        }
    except Exception:
        return None


def _cost_analysis(compiled) -> dict:
    try:
        c = compiled.cost_analysis()
        if isinstance(c, (list, tuple)):
            c = c[0] if c else {}
        return dict(c)
    except Exception:
        return {}


def monitor_fn(
    fn,
    *args,
    mesh=None,
    name: str = "fn",
    in_shardings=None,
    out_shardings=None,
    donate_argnums=(),
    static_argnums=(),
    algorithm: str = "ring",
    host_transfers: Optional[list[HostTransfer]] = None,
    **kwargs,
) -> CommReport:
    """Monitor a function end-to-end: trace (intercepted) + compile + parse.

    ``args``/``kwargs`` may be concrete arrays or ``jax.ShapeDtypeStruct``
    stand-ins (the dry-run path: no device memory is allocated).

    ``algorithm`` selects the collective algorithm assumed by the byte
    accounting (``ring`` / ``tree`` / ``hierarchical``, paper Table 1); use
    ``report.with_algorithm(...)`` to re-derive for another one without
    recompiling.  Compilation dominates this call's cost -- for iterative
    use, persist the result (``report.save``) or go through the sweep CLI,
    which caches reports on disk keyed by ``(config, mesh, algorithm, jax
    version)`` and logs ``[cache] hit`` instead of recompiling::

        python -m repro sweep --configs paper,gnmt,resnet \\
            --algorithms ring,tree          # first run compiles
        python -m repro sweep --configs paper,gnmt,resnet \\
            --algorithms ring,tree          # second run: all cache hits
    """
    jit_kw: dict[str, Any] = {}
    if in_shardings is not None:
        jit_kw["in_shardings"] = in_shardings
    if out_shardings is not None:
        jit_kw["out_shardings"] = out_shardings
    if donate_argnums:
        jit_kw["donate_argnums"] = donate_argnums
    if static_argnums:
        jit_kw["static_argnums"] = static_argnums

    jitted = jax.jit(fn, **jit_kw)

    t0 = time.perf_counter()
    with CollectiveInterceptor(mesh=mesh) as icpt:
        lowered = jitted.lower(*args, **kwargs)
    t1 = time.perf_counter()
    compiled = lowered.compile()
    t2 = time.perf_counter()

    hlo_text = compiled.as_text()
    # loop-aware extraction: ops inside while bodies carry execution weights
    from . import hlo_cost
    ops = hlo_cost.analyze_hlo(hlo_text).collectives
    num_devices = int(np.prod(mesh.devices.shape)) if mesh is not None else jax.device_count()
    topo = MeshTopology.from_mesh(mesh) if mesh is not None else None

    mat = comm_matrix.matrix_for_ops(ops, num_devices, algorithm, topo=topo)
    if host_transfers:
        comm_matrix.add_host_transfers(mat, host_transfers)
    report = CommReport(
        name=name,
        num_devices=num_devices,
        traced=list(icpt.events),
        compiled_ops=ops,
        traced_summary=icpt.summary(),
        compiled_summary=hlo_parser.summarize(ops, algorithm, topo=topo),
        matrix=mat,
        per_primitive=comm_matrix.per_primitive_matrices(ops, num_devices,
                                                         algorithm, topo=topo),
        cost=_cost_analysis(compiled),
        memory_stats=_memory_stats(compiled),
        trace_seconds=t1 - t0,
        compile_seconds=t2 - t1,
        topo=topo,
        host_transfers=list(host_transfers or []),
        algorithm=algorithm,
    )
    # stash the artifacts for roofline / debugging without re-compiling
    report._lowered = lowered
    report._compiled = compiled
    report._hlo_text = hlo_text
    return report


def roofline_of(report: CommReport, *, arch: str = "", mesh_name: str = "",
                model_flops: float = 0.0,
                algorithm: str = "ring") -> roofline.RooflineReport:
    assert report.topo is not None, "monitor_fn needs mesh= for roofline"
    return roofline.analyze(
        arch=arch or report.name,
        mesh_name=mesh_name,
        cost=report.cost,
        hlo_text=report._hlo_text,
        topo=report.topo,
        hw=report.topo.hw if report.topo else V5E,
        model_flops=model_flops,
        memory_stats=report.memory_stats,
        algorithm=algorithm,
        link_utilization=report.link_utilization(algorithm),
    )
