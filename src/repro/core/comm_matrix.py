"""Communication matrices -- the paper's central visualization.

A ``(d+1) x (d+1)`` matrix where entry ``(i+1, j+1)`` is the number of bytes
device ``i`` sends to device ``j``; row/column 0 is reserved for the host
(paper Fig. 2).  Matrices are built from compiled :class:`CollectiveOp` lists
by **placing the op's decomposition schedule**
(:func:`repro.core.decompose.decompose`) -- the same phase IR that drives
billing and timing, so placement cannot diverge from the cost models:

* ring phases stream **both directions** of their rings (half the phase's
  per-rank bytes to each neighbour -- the bidirectional torus ring whose
  bandwidth ``ring_bw_per_chip`` credits); multi-axis single-pod groups
  arrive as one ring phase per torus axis, so every edge lands on a
  physical neighbour link (no multi-hop transit inflation inside a pod),
* tree phases place per-role traffic on binary-tree edges (root sends S
  per child, leaves send up only),
* hierarchical schedules place intra-pod ring phases (per-axis when the
  subgroups allow) plus the cross-pod DCN shard exchange; a group the
  shared predicate refuses falls back to flat ring **with a**
  :class:`HierarchicalFallbackWarning` (billing follows the same fallback),
* collective-permute places its explicit source-target pairs,
* all-to-all places uniform pairwise traffic.

Every matrix row sum equals ``cost_models.device_send_bytes`` times the op
weight (the matrix/model consistency contract -- both read the same
schedule), and any matrix can be **projected onto physical links**
(:func:`project_links`): each logical edge is routed over the ICI torus /
DCN uplinks of a :class:`~repro.core.topology.MeshTopology`, yielding
per-link byte counts, the bottleneck link, and a contention-aware time
bound.

**Vectorized accumulation.**  :func:`matrix_for_ops` renders each op's
schedule as numpy COO arrays (:func:`op_edge_arrays`; the schedule batches
same-size replica groups into shared phases) and batches them into edge
buffers flushed with a single ``np.add.at`` per flush, so a session with
thousands of weighted ops on a large mesh builds its matrix without a
per-edge Python loop.  The retired pre-schedule placement survives only as
:func:`matrix_for_ops_reference` -- the legacy per-kind, per-edge oracle
that pins schedule-derived matrices equal to the old loop on single-axis
groups (``benchmarks/matrix_build.py`` also measures the COO path against
it).
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Optional

import numpy as np

from .events import CollectiveOp, HostTransfer
from . import cost_models, decompose as decompose_mod
from .decompose import HierarchicalFallbackWarning, decompose  # noqa: F401
from .sparse import SparseAccumulator, SparseCommMatrix, is_sparse
from .topology import DCN_FABRIC, Link, MeshTopology


# ---------------------------------------------------------------------------
# Scalar edge placement: the schedule rendered as (src, dst, bytes) tuples.
# ---------------------------------------------------------------------------
def _ring_edges(group, per_rank: float) -> list[tuple[int, int, float]]:
    """Bidirectional ring: each member streams half its per-rank bytes to
    each ring neighbour (the torus ring algorithm uses both directions of
    the axis links -- the bandwidth ``ring_bw_per_chip`` credits).  On a
    2-member ring both halves reach the same peer and accumulate."""
    group = list(group)
    n = len(group)
    half = 0.5 * per_rank
    out: list[tuple[int, int, float]] = []
    for i in range(n):
        out.append((group[i], group[(i + 1) % n], half))
        out.append((group[i], group[(i - 1) % n], half))
    return out


def _tree_placement(group, kind: str,
                    s: float) -> list[tuple[int, int, float]]:
    """Per-edge bytes on the implicit binary tree (heap layout), resolved
    from the shared :func:`repro.core.decompose.tree_edge_profile`."""
    group = list(group)
    n = len(group)
    up, down = decompose_mod.tree_edge_profile(kind, s, n)
    edges: list[tuple[int, int, float]] = []
    for i in range(1, n):
        parent, child = group[(i - 1) // 2], group[i]
        if up[i - 1]:
            edges.append((child, parent, float(up[i - 1])))
        if down[i - 1]:
            edges.append((parent, child, float(down[i - 1])))
    return edges


def _phase_edges(ph) -> list[tuple[int, int, float]]:
    """Scalar edges of ONE schedule phase.

    Vector phases (``bytes_per_rank`` is an ndarray, see
    :class:`~repro.core.decompose.CommPhase`) place per-position amounts:
    ring members stream half their own per-rank bytes to each neighbour,
    a2a members send ``per_rank / (n-1)`` to each peer, and ``pair_bytes``
    overrides the uniform per-pair payload of ``structure="pairs"``.
    """
    if ph.structure == "pairs":
        if ph.pairs is None:
            return []
        if ph.pair_bytes is not None:
            return [(int(a), int(b), float(v))
                    for (a, b), v in zip(ph.pairs.tolist(),
                                         ph.pair_bytes.tolist())]
        return [(int(a), int(b), ph.payload) for a, b in ph.pairs]
    if ph.groups is None:
        return []
    G = np.atleast_2d(ph.groups)
    B = ph.byte_matrix()
    out: list[tuple[int, int, float]] = []
    if ph.structure == "ring":
        if B is not None:
            for row, brow in zip(G, B):
                members = row.tolist()
                n = len(members)
                for i, per in enumerate(brow.tolist()):
                    out.append((members[i], members[(i + 1) % n],
                                0.5 * per))
                    out.append((members[i], members[(i - 1) % n],
                                0.5 * per))
        else:
            for row in G:
                out += _ring_edges(row.tolist(), ph.bytes_per_rank)
    elif ph.structure == "tree":
        for row in G:
            out += _tree_placement(row.tolist(), ph.kind, ph.payload)
    elif ph.structure == "a2a":
        n = G.shape[1]
        if B is not None:
            for row, brow in zip(G, B):
                members = row.tolist()
                per_peer = (brow / (n - 1)).tolist()
                out += [(a, b, per_peer[i])
                        for i, a in enumerate(members)
                        for b in members if a != b]
        else:
            block = ph.payload / (n * n)
            for row in G:
                members = row.tolist()
                out += [(a, b, block) for a in members for b in members
                        if a != b]
    return out


def op_edges(op: CollectiveOp, algorithm: str = "ring",
             topo: Optional[MeshTopology] = None) -> list[tuple[int, int, float]]:
    """``(src, dst, bytes)`` edges for ONE execution of ``op`` (weight not
    applied) -- the scalar rendering of the op's decomposition schedule.

    Production matrix building goes through the vectorized
    :func:`op_edge_arrays`; both walk the same
    :func:`~repro.core.decompose.decompose` output, and a property test
    pins their aggregate traffic equal.  A hierarchical request for a
    cross-pod group the shared predicate cannot decompose emits a
    :class:`HierarchicalFallbackWarning` and places flat ring edges
    instead (silently degenerating is exactly the matrix/model mismatch
    this module exists to expose).
    """
    sched = decompose_mod.cached_decompose(op, algorithm, topo)
    edges: list[tuple[int, int, float]] = []
    for ph in sched.phases:
        edges += _phase_edges(ph)
    return edges


# ---------------------------------------------------------------------------
# Vectorized edge generation: numpy COO arrays instead of per-edge tuples.
# ---------------------------------------------------------------------------
_EMPTY_EDGES = (np.empty(0, dtype=np.intp), np.empty(0, dtype=np.intp),
                np.empty(0, dtype=np.float64))


def _concat_edges(parts):
    if not parts:
        return _EMPTY_EDGES
    if len(parts) == 1:
        return parts[0]
    return (np.concatenate([p[0] for p in parts]),
            np.concatenate([p[1] for p in parts]),
            np.concatenate([p[2] for p in parts]))


# ring-size -> column indices of [next neighbour | previous neighbour],
# cached because every same-size ring shares them
_RING_IDX_CACHE: dict[int, np.ndarray] = {}


def _ring_neighbor_idx(n: int) -> np.ndarray:
    idx = _RING_IDX_CACHE.get(n)
    if idx is None:
        pos = np.arange(n)
        idx = _RING_IDX_CACHE.setdefault(
            n, np.concatenate([(pos + 1) % n, (pos - 1) % n]))
    return idx


def _ring_edges_arr(rings, per_rank):
    """Bidirectional ring edges for a batch of rings (one per row).

    The array form of :func:`_ring_edges`: each member streams half its
    per-rank bytes to each neighbour (cached neighbour-index gather along
    the row axis); on a 2-member ring both halves land on the same peer
    and accumulate.  ``per_rank`` may be an ndarray (1-D positional or
    ``(k, n)``): each member then streams half its *own* amount.
    """
    r = np.asarray(rings, dtype=np.intp)
    if r.ndim == 1:
        r = r[None, :]
    src = np.tile(r, (1, 2)).ravel()
    dst = r[:, _ring_neighbor_idx(r.shape[1])].ravel()
    if isinstance(per_rank, np.ndarray):
        B = np.broadcast_to(np.asarray(per_rank, dtype=np.float64),
                            r.shape)
        return src, dst, np.tile(0.5 * B, (1, 2)).ravel()
    return src, dst, np.full(src.size, 0.5 * per_rank)


def _tree_edges_arr(groups, kind: str, s: float):
    """Array form of :func:`_tree_placement` (same heap-layout tree) for a
    batch of same-size groups (one per row) -- the per-edge byte profile
    depends only on the tree *position*, so it is computed once per column
    and tiled over the batch."""
    G = np.asarray(groups, dtype=np.intp)
    if G.ndim == 1:
        G = G[None, :]
    k, n = G.shape
    pos = np.arange(1, n)
    parent = G[:, (pos - 1) // 2]                      # (k, n-1)
    child = G[:, 1:]
    up, down = decompose_mod.tree_edge_profile(kind, s, n)
    mu, md = up > 0, down > 0
    return (np.concatenate([child[:, mu].ravel(), parent[:, md].ravel()]),
            np.concatenate([parent[:, mu].ravel(), child[:, md].ravel()]),
            np.concatenate([np.tile(up[mu], k), np.tile(down[md], k)]))


def _a2a_edges_arr(groups, block: float, per_src=None):
    """Pairwise exchange for a batch of same-size groups: uniform
    ``block`` bytes per ordered pair, or -- when ``per_src`` (1-D
    positional or ``(k, n)``) is given -- each source's own
    ``per_src / (n-1)`` to every peer (skewed all-to-all)."""
    G = np.asarray(groups, dtype=np.intp)
    if G.ndim == 1:
        G = G[None, :]
    k, n = G.shape
    src = np.repeat(G, n, axis=1).ravel()
    dst = np.tile(G, (1, n)).ravel()
    keep = src != dst
    if per_src is not None:
        B = np.broadcast_to(np.asarray(per_src, dtype=np.float64),
                            G.shape)
        vals = np.repeat(B / (n - 1), n, axis=1).ravel()[keep]
        return src[keep], dst[keep], vals
    return src[keep], dst[keep], np.full(k * n * (n - 1), block)


def _phase_edge_arrays(ph):
    """COO arrays of ONE schedule phase (the vectorized
    :func:`_phase_edges`)."""
    if ph.structure == "pairs":
        if ph.pairs is None:
            return _EMPTY_EDGES
        if ph.pair_bytes is not None:
            return (ph.pairs[:, 0], ph.pairs[:, 1],
                    np.asarray(ph.pair_bytes, dtype=np.float64))
        return (ph.pairs[:, 0], ph.pairs[:, 1],
                np.full(len(ph.pairs), ph.payload))
    if ph.groups is None:
        return _EMPTY_EDGES
    if ph.structure == "ring":
        return _ring_edges_arr(ph.groups, ph.bytes_per_rank)
    if ph.structure == "tree":
        return _tree_edges_arr(ph.groups, ph.kind, ph.payload)
    if ph.structure == "a2a":
        n = int(np.atleast_2d(ph.groups).shape[1])
        if isinstance(ph.bytes_per_rank, np.ndarray):
            return _a2a_edges_arr(ph.groups, 0.0,
                                  per_src=ph.bytes_per_rank)
        return _a2a_edges_arr(ph.groups, ph.payload / (n * n))
    return _EMPTY_EDGES


def schedule_edge_arrays(sched):
    """``(src, dst, bytes)`` COO arrays of one whole schedule."""
    if not sched.phases:
        return _EMPTY_EDGES
    return _concat_edges([_phase_edge_arrays(ph) for ph in sched.phases])


def op_edge_arrays(op: CollectiveOp, algorithm: str = "ring",
                   topo: Optional[MeshTopology] = None):
    """``(src, dst, bytes)`` numpy arrays for ONE execution of ``op``.

    The vectorized twin of :func:`op_edges` -- identical aggregate traffic
    (property-tested), produced as COO arrays so :func:`matrix_for_ops`
    accumulates them without a per-edge Python loop.  The schedule already
    batches same-size replica groups into shared phases (an op with 32
    groups of 8 costs the same handful of numpy calls as one group would),
    and emits the same :class:`HierarchicalFallbackWarning` in the same
    refusal case.
    """
    return schedule_edge_arrays(
        decompose_mod.cached_decompose(op, algorithm, topo))


# flush threshold for the batched COO accumulation: large enough to amortize
# np.add.at, small enough to keep the edge buffers cache-resident
_FLUSH_EDGES = 32768


def matrix_for_ops(
    ops: Iterable[CollectiveOp],
    num_devices: int,
    algorithm: str = "ring",
    kinds: Optional[set[str]] = None,
    topo: Optional[MeshTopology] = None,
    sparse: bool = False,
):
    """Bytes-sent matrix, shape ``(d+1, d+1)``; row/col 0 = host.

    ``topo`` enables topology-faithful placement (per-axis ring phases for
    multi-axis groups, the hierarchical algorithm's pod decomposition);
    without it every schedule degenerates to flattened rings, matching
    ``wire_bytes_per_rank(..., pods=1)``.

    Accumulation is vectorized: per-op COO edge arrays
    (:func:`op_edge_arrays`, execution weights applied per op) are batched
    into buffers and flushed with one ``np.add.at`` per
    ``_FLUSH_EDGES``-sized batch -- see :func:`matrix_for_ops_reference`
    for the legacy oracle this is property-tested against.

    ``sparse=True`` returns a :class:`~repro.core.sparse.SparseCommMatrix`
    instead of the dense array -- element-exact (property-tested), built
    without ever allocating ``(d+1)^2`` floats, which is what makes
    fleet-scale device counts (``sweep --scale-curve``, 16k devices)
    tractable.
    """
    cost_models.validate_algorithm(algorithm)
    kept = [op for op in ops if kinds is None or op.kind in kinds]
    scheds = decompose_mod.schedules_for_ops(kept, algorithm, topo,
                                             warn=True)
    return _accumulate_edges(_edge_pairs(kept, scheds, None, {}),
                             num_devices, sparse=sparse)


def _edge_pairs(ops, schedules, kinds, edge_cache: dict):
    """``(op, (src, dst, val))`` pairs in op order, with edge arrays built
    once per *distinct* schedule object (``id``-keyed, which the deduped
    ``schedules_for_ops`` output makes meaningful).  Accumulation stays
    per-op so the float addition order -- and hence the matrix, bitwise --
    is identical to the uncached path."""
    for op, sched in zip(ops, schedules):
        if kinds is not None and op.kind not in kinds:
            continue
        e = edge_cache.get(id(sched))
        if e is None:
            e = edge_cache[id(sched)] = schedule_edge_arrays(sched)
        yield op, e


def matrix_for_schedules(
    ops, schedules, num_devices: int,
    kinds: Optional[set[str]] = None,
    sparse: bool = False,
):
    """Bytes-sent matrix from pre-built schedules (aligned with ``ops``).

    The entry point for callers that already hold the ops' decomposition
    schedules (e.g. a :class:`~repro.core.views.CommView`'s memoized IR):
    identical accumulation to :func:`matrix_for_ops` without re-running
    :func:`~repro.core.decompose.decompose` per op.  ``schedules`` may be
    the plain aligned list or a :class:`~repro.core.decompose.
    ScheduleBatch` -- the batch's persistent ``edge_cache`` then carries
    rendered COO edge arrays across calls (the whole-matrix build and
    every per-primitive slice of one view pay edge generation once per
    distinct schedule).  ``sparse=True`` builds the COO
    :class:`~repro.core.sparse.SparseCommMatrix` form.
    """
    if isinstance(schedules, decompose_mod.ScheduleBatch):
        edge_cache = schedules.edge_cache
        schedules = schedules.schedules
    else:
        edge_cache = {}
    return _accumulate_edges(
        _edge_pairs(ops, schedules, kinds, edge_cache),
        num_devices, sparse=sparse)


def _accumulate_edges_sparse(pairs, num_devices: int) -> SparseCommMatrix:
    """Sparse twin of :func:`_accumulate_edges`: same per-op COO edges,
    accumulated into a bounded-memory :class:`SparseAccumulator` -- no
    ``(d+1)^2`` allocation anywhere on this path."""
    acc = SparseAccumulator(num_devices)
    for op, (src, dst, val) in pairs:
        if src.size == 0:
            continue
        w = getattr(op, "weight", 1.0)
        keep = (src < num_devices) & (dst < num_devices)
        if not keep.all():
            src, dst, val = src[keep], dst[keep], val[keep]
        acc.add(src + 1, dst + 1, val * w if w != 1.0 else val)
    return acc.build()


def _accumulate_edges(pairs, num_devices: int,
                      sparse: bool = False):
    """Buffered COO accumulation over ``(op, (src, dst, val))`` pairs."""
    if sparse:
        return _accumulate_edges_sparse(pairs, num_devices)
    mat = np.zeros((num_devices + 1, num_devices + 1), dtype=np.float64)
    cap = _FLUSH_EDGES
    buf_src = np.empty(cap, dtype=np.intp)
    buf_dst = np.empty(cap, dtype=np.intp)
    buf_val = np.empty(cap, dtype=np.float64)
    pending = 0

    def apply(src, dst, val):
        keep = (src < num_devices) & (dst < num_devices)
        if not keep.all():
            src, dst, val = src[keep], dst[keep], val[keep]
        np.add.at(mat, (src + 1, dst + 1), val)

    def flush():
        nonlocal pending
        if pending:
            apply(buf_src[:pending], buf_dst[:pending], buf_val[:pending])
            pending = 0

    for op, (src, dst, val) in pairs:
        w = getattr(op, "weight", 1.0)   # execution count (loop trip counts)
        m = src.size
        if m == 0:
            continue
        if w != 1.0:
            val = val * w
        if m >= cap:                     # oversized op: apply directly
            flush()
            apply(src, dst, val)
            continue
        if pending + m > cap:
            flush()
        buf_src[pending:pending + m] = src
        buf_dst[pending:pending + m] = dst
        buf_val[pending:pending + m] = val
        pending += m
    flush()
    return mat


# ---------------------------------------------------------------------------
# Legacy oracle: the retired per-kind placement, kept ONLY to pin the
# schedule-derived path against the old behavior on single-axis groups.
# ---------------------------------------------------------------------------
_TREE_KINDS = ("all-reduce", "all-gather", "reduce-scatter",
               "collective-broadcast")


def _legacy_hierarchical_placement(group, kind: str, s: float,
                                   topo: MeshTopology):
    """Pre-schedule hierarchical placement: flattened intra-pod rings +
    cross-pod exchange (no per-axis decomposition)."""
    dec = cost_models.hierarchical_decomposition(kind, list(group), topo)
    if dec is None:
        return None
    p, m, subs = dec
    phases = cost_models.hier_phases(kind)
    edges: list[tuple[int, int, float]] = []
    if m > 1:
        intra_per_rank = phases * (m - 1) * s / m
        for sub in subs:
            edges.extend(_ring_edges(sub, intra_per_rank))
    cross_per_rank = phases * (p - 1) * s / len(group)
    for j in range(m):
        ring = [subs[k][j] for k in range(p)]
        edges.extend(_ring_edges(ring, cross_per_rank))
    return edges


def _legacy_op_edges(op: CollectiveOp, algorithm: str = "ring",
                     topo: Optional[MeshTopology] = None):
    """The pre-schedule scalar placement (flattened rings everywhere)."""
    edges: list[tuple[int, int, float]] = []
    if op.kind == "collective-permute":
        nbytes = float(op.result_bytes) * op.num_groups
        return [(src, dst, nbytes) for src, dst in op.source_target_pairs]
    for group in op.replica_groups or [[]]:
        n = len(group)
        if n <= 1:
            continue
        s = float(op.payload_bytes)
        if op.kind in ("all-to-all", "ragged-all-to-all"):
            block = s / (n * n)
            edges.extend((a, b, block)
                         for a in group for b in group if a != b)
            continue
        if algorithm == "tree" and op.kind in _TREE_KINDS:
            edges.extend(_tree_placement(group, op.kind, s))
            continue
        if algorithm == "hierarchical" and topo is not None:
            placed = _legacy_hierarchical_placement(group, op.kind, s, topo)
            if placed is not None:
                edges.extend(placed)
                continue
            if op.kind in cost_models.HIERARCHICAL_KINDS \
                    and topo.group_crosses_dcn(group):
                decompose_mod.warn_fallback_once(
                    op.kind, n,
                    f"hierarchical {op.kind} over cross-pod group of {n} "
                    "cannot decompose (uneven pod split); placing flat "
                    "ring edges and billing the same fallback",
                    stacklevel=1)
        per_rank = cost_models.wire_bytes_per_rank(
            op.kind, s, n, algorithm, pods=1)
        edges.extend(_ring_edges(group, per_rank))
    return edges


def matrix_for_ops_reference(
    ops: Iterable[CollectiveOp],
    num_devices: int,
    algorithm: str = "ring",
    kinds: Optional[set[str]] = None,
    topo: Optional[MeshTopology] = None,
) -> np.ndarray:
    """The pre-schedule builder: per-op, per-edge Python accumulation over
    the legacy per-kind placement.  Kept as the readable oracle: on
    single-axis replica groups (where per-axis decomposition does not
    apply) schedule-derived matrices must equal this loop exactly -- the
    property test pins that, and ``benchmarks/matrix_build.py`` measures
    the COO-batched :func:`matrix_for_ops` against it.
    """
    cost_models.validate_algorithm(algorithm)
    mat = np.zeros((num_devices + 1, num_devices + 1), dtype=np.float64)
    for op in ops:
        if kinds is not None and op.kind not in kinds:
            continue
        w = getattr(op, "weight", 1.0)
        for src, dst, nbytes in _legacy_op_edges(op, algorithm, topo):
            if src < num_devices and dst < num_devices:
                mat[src + 1, dst + 1] += nbytes * w
    return mat


def add_host_transfers(mat, transfers: Iterable[HostTransfer]):
    """Accumulate host row/col traffic into a dense or sparse matrix."""
    if is_sparse(mat):
        transfers = list(transfers)
        src = np.array([0 if t.direction == "h2d" else t.device + 1
                        for t in transfers], dtype=np.int64)
        dst = np.array([t.device + 1 if t.direction == "h2d" else 0
                        for t in transfers], dtype=np.int64)
        val = np.array([t.nbytes for t in transfers], dtype=np.float64)
        return mat.add_entries(src, dst, val)
    for t in transfers:
        if t.direction == "h2d":
            mat[0, t.device + 1] += t.nbytes
        else:
            mat[t.device + 1, 0] += t.nbytes
    return mat


def per_primitive_matrices(
    ops: list[CollectiveOp], num_devices: int, algorithm: str = "ring",
    topo: Optional[MeshTopology] = None, sparse: bool = False,
) -> dict:
    """Paper Fig. 3: one matrix per collective primitive (ops partitioned
    by kind once instead of re-filtering the whole stream per kind)."""
    by_kind: dict[str, list[CollectiveOp]] = {}
    for op in ops:
        by_kind.setdefault(op.kind, []).append(op)
    return {
        k: matrix_for_ops(by_kind[k], num_devices, algorithm, topo=topo,
                          sparse=sparse)
        for k in sorted(by_kind)
    }


# ---------------------------------------------------------------------------
# Physical-link projection: where the bytes actually travel.
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class LinkUtilization:
    """Per-physical-link byte counts for one communication matrix.

    ``bytes_by_link`` covers every link of the topology (zero-traffic links
    included, so utilization denominators are meaningful).  Multi-hop
    logical edges charge every link on their route, so the sum over links
    can exceed the matrix total -- that is the point: it exposes transit
    traffic a logical matrix hides.  (Schedules that decompose per torus
    axis place neighbour-only edges, so their projection carries zero
    transit inflation inside a pod.)
    """

    topo: MeshTopology
    bytes_by_link: dict[Link, float]

    def seconds(self, link: Link) -> float:
        return self.bytes_by_link.get(link, 0.0) / self.topo.link_bandwidth(link)

    def total_bytes(self, kind: Optional[str] = None) -> float:
        return float(sum(b for l, b in self.bytes_by_link.items()
                         if kind is None or l.kind == kind))

    def bottleneck(self) -> Optional[tuple[Link, float]]:
        """(busiest link, seconds on it), by time -- None when no link
        carries any traffic (every link is pre-seeded at 0 bytes, so an
        emptiness check alone would name an arbitrary idle link)."""
        if not self.bytes_by_link or not any(self.bytes_by_link.values()):
            return None
        link = max(self.bytes_by_link, key=self.seconds)
        return link, self.seconds(link)

    def bottleneck_seconds(self) -> float:
        """Contention-aware time bound: max over links of bytes/bandwidth."""
        bn = self.bottleneck()
        return bn[1] if bn else 0.0

    def busy_seconds(self, kind: Optional[str] = None) -> float:
        """Per-tier busy time: max over links (of ``kind``, or all) of
        bytes/bandwidth -- how long that fabric tier is occupied if every
        link streams its traffic back-to-back.  Feeds the link-overlap
        roofline (``compute ∥ ICI ∥ DCN``): tiers are independent fabrics,
        so ``max(busy_seconds("ici"), busy_seconds("dcn"))`` bounds the
        overlapped communication time from below."""
        return max((self.seconds(l) for l in self.bytes_by_link
                    if kind is None or l.kind == kind), default=0.0)

    def tier_summary(self) -> dict:
        """Per-tier ``{kind: {bytes, busy_seconds}}`` (schema-v3 section)."""
        return {kind: {"bytes": self.total_bytes(kind),
                       "busy_seconds": self.busy_seconds(kind)}
                for kind in sorted({l.kind for l in self.bytes_by_link})}

    def matrix(self) -> np.ndarray:
        """The per-link utilization matrix, shape ``(d+1, d+1)``.

        Entry ``(i+1, j+1)`` is the bytes carried by the *physical* ICI
        link ``i -> j`` (only torus-neighbour entries can be nonzero).
        Row/col 0 is the **DCN tier**: ``(i+1, 0)`` is device ``i``'s DCN
        uplink, ``(0, j+1)`` device ``j``'s downlink -- the slot the
        logical matrix uses for the host plays the off-fabric role here.
        """
        d = self.topo.num_devices
        mat = np.zeros((d + 1, d + 1), dtype=np.float64)
        for link, nbytes in self.bytes_by_link.items():
            if link.kind == "ici":
                mat[link.src + 1, link.dst + 1] += nbytes
            elif link.dst == DCN_FABRIC:
                mat[link.src + 1, 0] += nbytes
            else:
                mat[0, link.dst + 1] += nbytes
        return mat

    def sparse_matrix(self) -> SparseCommMatrix:
        """The per-link utilization matrix in COO form -- same layout as
        :meth:`matrix` (row/col 0 = DCN tier) with O(links) memory, which
        is what the exporters read at fleet scale."""
        src = np.empty(len(self.bytes_by_link), dtype=np.int64)
        dst = np.empty(len(self.bytes_by_link), dtype=np.int64)
        val = np.empty(len(self.bytes_by_link), dtype=np.float64)
        for n, (link, nbytes) in enumerate(self.bytes_by_link.items()):
            if link.kind == "ici":
                src[n], dst[n] = link.src + 1, link.dst + 1
            elif link.dst == DCN_FABRIC:
                src[n], dst[n] = link.src + 1, 0
            else:
                src[n], dst[n] = 0, link.dst + 1
            val[n] = nbytes
        return SparseCommMatrix(self.topo.num_devices, src, dst, val)

    def summary(self) -> dict:
        """Per link-kind aggregates for tables and serialization."""
        out: dict[str, dict] = {}
        for link, nbytes in self.bytes_by_link.items():
            row = out.setdefault(link.kind, {
                "links": 0, "bytes": 0.0, "busiest_link": "",
                "busiest_bytes": 0.0, "bottleneck_seconds": 0.0})
            row["links"] += 1
            row["bytes"] += nbytes
            secs = self.seconds(link)
            if secs > row["bottleneck_seconds"]:
                row.update(busiest_link=link.name, busiest_bytes=nbytes,
                           bottleneck_seconds=secs)
        return out

    def rows(self) -> list[dict]:
        """One serializable row per link (schema-v2 ``links`` section)."""
        return [{"kind": l.kind, "src": l.src, "dst": l.dst, "axis": l.axis,
                 "bytes": float(b),
                 "bandwidth": self.topo.link_bandwidth(l),
                 "seconds": self.seconds(l)}
                for l, b in sorted(self.bytes_by_link.items(),
                                   key=lambda kv: -kv[1])]

    def table(self) -> str:
        """Terminal rendering of the per-kind aggregates."""
        from . import reporter
        rows = []
        summary = self.summary()
        for kind in sorted(summary):
            r = summary[kind]
            rows.append([kind, f"{r['links']}",
                         reporter.human_bytes(r["bytes"]),
                         r["busiest_link"],
                         reporter.human_bytes(r["busiest_bytes"]),
                         f"{r['bottleneck_seconds'] * 1e3:.3f}"])
        return reporter.format_table(rows, [
            "link kind", "links", "total bytes", "busiest link",
            "busiest bytes", "bottleneck ms"])


def project_links(mat, topo: MeshTopology) -> LinkUtilization:
    """Route a logical ``(d+1)^2`` matrix onto physical links.

    ``mat`` may be the dense ``np.ndarray`` form or a
    :class:`~repro.core.sparse.SparseCommMatrix` -- both project to the
    identical link view (the sparse path iterates its coalesced COO
    entries instead of ``argwhere`` over a dense block, and never
    materializes the dense array).  Anything else raises ``TypeError``.

    The host row/col (index 0) is skipped -- host transfers ride PCIe, not
    the ICI/DCN fabric.  Each device-to-device entry is routed by
    :meth:`MeshTopology.route` (dimension-ordered wrap-aware torus routing,
    DCN uplink+downlink across pods) and its bytes charged to every hop.
    The matrices this module builds are schedule-derived
    (:func:`op_edge_arrays` renders :func:`~repro.core.decompose.
    decompose` output), so the projection IS the schedule's link view.

    Every routed hop must be one of the enumerated physical links -- in
    particular, both directions around a size-2 torus axis are the SAME
    single collapsed link (``MeshTopology.links`` docstring); a hop outside
    the enumeration would silently invent fabric, so it raises.
    """
    if is_sparse(mat):
        srcs, dsts, vals = mat.device_entries()
        entries = zip(srcs.tolist(), dsts.tolist(), vals.tolist())
    elif isinstance(mat, np.ndarray):
        dev = mat[1:, 1:]
        entries = ((int(i), int(j), float(dev[i, j]))
                   for i, j in np.argwhere(dev > 0))
    else:
        raise TypeError(
            "project_links expects a dense (d+1)x(d+1) np.ndarray or a "
            f"SparseCommMatrix, not {type(mat).__name__}")
    bytes_by_link: dict[Link, float] = {l: 0.0 for l in topo.links()}
    for i, j, nbytes in entries:
        for link in topo.route(i, j):
            if link not in bytes_by_link:
                raise ValueError(
                    f"route({i}, {j}) emitted {link.name}, which is not an "
                    "enumerated physical link of the topology")
            bytes_by_link[link] += nbytes
    return LinkUtilization(topo=topo, bytes_by_link=bytes_by_link)


def link_utilization_for_ops(
    ops: list[CollectiveOp], topo: MeshTopology, algorithm: str = "ring",
    kinds: Optional[set[str]] = None, sparse: bool = False,
) -> LinkUtilization:
    """Place ``ops``' schedules and project onto physical links
    (``sparse=True`` routes the COO form, never building the dense
    matrix)."""
    mat = matrix_for_ops(ops, topo.num_devices, algorithm, kinds, topo=topo,
                         sparse=sparse)
    return project_links(mat, topo)
