"""Communication matrices -- the paper's central visualization.

A ``(d+1) x (d+1)`` matrix where entry ``(i+1, j+1)`` is the number of bytes
device ``i`` sends to device ``j``; row/column 0 is reserved for the host
(paper Fig. 2).  Matrices are built from compiled :class:`CollectiveOp` lists
with an algorithm-aware edge model:

* ring collectives place traffic on consecutive group neighbours,
* tree collectives place traffic on binary-tree edges,
* collective-permute uses its explicit source-target pairs,
* all-to-all places uniform pairwise traffic.
"""
from __future__ import annotations

from typing import Iterable, Optional

import numpy as np

from .events import CollectiveOp, HostTransfer
from . import cost_models


def _ring_edges(group: list[int]) -> list[tuple[int, int]]:
    n = len(group)
    return [(group[i], group[(i + 1) % n]) for i in range(n)]


def _tree_edges(group: list[int]) -> list[tuple[int, int]]:
    """Binary-tree edges (both directions: reduce up, broadcast down)."""
    edges = []
    n = len(group)
    for i in range(1, n):
        parent = group[(i - 1) // 2]
        child = group[i]
        edges.append((child, parent))
        edges.append((parent, child))
    return edges


def matrix_for_ops(
    ops: Iterable[CollectiveOp],
    num_devices: int,
    algorithm: str = "ring",
    kinds: Optional[set[str]] = None,
) -> np.ndarray:
    """Bytes-sent matrix, shape ``(d+1, d+1)``; row/col 0 = host."""
    mat = np.zeros((num_devices + 1, num_devices + 1), dtype=np.float64)
    for op in ops:
        if kinds is not None and op.kind not in kinds:
            continue
        w = getattr(op, "weight", 1.0)   # execution count (loop trip counts)
        if op.kind == "collective-permute":
            nbytes = op.result_bytes * w
            for src, dst in op.source_target_pairs:
                if src < num_devices and dst < num_devices:
                    mat[src + 1, dst + 1] += nbytes
            continue
        for group in op.replica_groups or [[]]:
            if len(group) <= 1:
                continue
            n = len(group)
            s = op.payload_bytes
            if op.kind in ("all-to-all", "ragged-all-to-all"):
                block = s / (n * n) * w
                for a in group:
                    for b in group:
                        if a != b and a < num_devices and b < num_devices:
                            mat[a + 1, b + 1] += block
                continue
            per_rank = cost_models.wire_bytes_per_rank(op.kind, s, n, algorithm)
            if algorithm == "tree" and op.kind == "all-reduce":
                edges = _tree_edges(group)
                per_edge = per_rank * n / max(1, len(edges)) * w
            else:
                edges = _ring_edges(group)
                per_edge = per_rank * w  # per_rank to the next hop, per exec
            for src, dst in edges:
                if src < num_devices and dst < num_devices:
                    mat[src + 1, dst + 1] += per_edge
    return mat


def add_host_transfers(mat: np.ndarray, transfers: Iterable[HostTransfer]) -> np.ndarray:
    for t in transfers:
        if t.direction == "h2d":
            mat[0, t.device + 1] += t.nbytes
        else:
            mat[t.device + 1, 0] += t.nbytes
    return mat


def per_primitive_matrices(
    ops: list[CollectiveOp], num_devices: int, algorithm: str = "ring"
) -> dict[str, np.ndarray]:
    """Paper Fig. 3: one matrix per collective primitive."""
    kinds = sorted({op.kind for op in ops})
    return {
        k: matrix_for_ops(ops, num_devices, algorithm, kinds={k}) for k in kinds
    }
