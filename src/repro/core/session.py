"""Session-based monitoring: multi-phase capture over a whole run.

The paper's tool monitors a *running application*: it accumulates transfers
across the execution and post-processes them afterwards.  Real workloads
have *phases* -- fwd/bwd/optimizer in a train step, prefill/decode on the
serve path, per-iteration segments of an NCCL-style phase analysis -- and a
one-shot wrapper around a single jitted function cannot tell them apart.

:class:`MonitorSession` is the accumulating front door::

    with MonitorSession(mesh=mesh, name="train") as sess:
        with sess.phase("fwd"):
            sess.capture(fwd_step, params, batch)
        with sess.phase("bwd"):
            sess.capture(bwd_step, params, batch)
        with sess.phase("optim"):
            sess.capture(opt_step, params, grads, opt_state)

    sess.view()                    # whole-session CommView (lazy, memoized)
    sess.view(phase="bwd")         # one phase's matrices / summaries
    sess.view("tree")              # re-bound algorithm, no recompilation
    report = sess.report()         # serializable CommReport snapshot (v5)

Each :meth:`capture` traces one function under the interceptor, compiles
it, parses the collective schedule, and tags every op / traced event /
host transfer with the active phase.  Derived artifacts are never built
eagerly -- :meth:`view` hands out :class:`~repro.core.views.CommView`
bindings that memoize on first read -- and :meth:`report` snapshots the
session into a :class:`~repro.core.monitor.CommReport` whose schema-v5
serialization round-trips the phase structure.

``monitor_fn`` (:mod:`repro.core.monitor`) survives as a thin
compatibility wrapper: one capture in one phase, artifact-for-artifact
identical to the session path (golden-tested).
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Any, Iterable, Optional

import jax
import numpy as np

from . import cost_models, decompose, hlo_cost
from .events import (CollectiveOp, HostTransfer, PhaseRecord, TraceEvent)
from .interceptor import CollectiveInterceptor, traced_summary
from .topology import MeshTopology
from .views import CommView, build_view

DEFAULT_PHASE = "main"


def _memory_stats(compiled) -> Optional[dict]:
    try:
        m = compiled.memory_analysis()
        return {
            "argument_bytes": m.argument_size_in_bytes,
            "output_bytes": m.output_size_in_bytes,
            "temp_bytes": m.temp_size_in_bytes,
            "alias_bytes": m.alias_size_in_bytes,
            "generated_code_bytes": m.generated_code_size_in_bytes,
            "total_bytes": (m.argument_size_in_bytes + m.output_size_in_bytes
                            + m.temp_size_in_bytes - m.alias_size_in_bytes),
        }
    except Exception:
        return None


def _cost_analysis(compiled) -> dict:
    try:
        c = compiled.cost_analysis()
        if isinstance(c, (list, tuple)):
            c = c[0] if c else {}
        return dict(c)
    except Exception:
        return {}


@dataclasses.dataclass
class Capture:
    """One monitored function inside a session (trace + compile + parse).

    Carries parsed artifacts only; the live XLA executables of the most
    recent capture live on the session (``last_lowered``/``last_compiled``)
    so a long session does not pin one compiled executable per capture.
    """

    name: str
    phase: str
    ops: list[CollectiveOp]
    traced: list[TraceEvent]
    trace_seconds: float
    compile_seconds: float
    cost: dict
    memory_stats: Optional[dict]
    hlo_text: str = ""


class MonitorSession:
    """Accumulating, phase-aware monitoring context (see module docstring).

    ``mesh`` fixes the device topology for every capture; ``algorithm`` is
    the default binding of the views and the snapshot report (validated
    here, so a typo fails before anything compiles).  The session object is
    reusable as a plain accumulator -- the ``with`` block is bookkeeping
    sugar, not a resource: captures outside it work identically.
    """

    def __init__(self, mesh=None, name: str = "session",
                 algorithm: str = "ring",
                 sparse: Optional[bool] = None):
        cost_models.validate_algorithm(algorithm)
        # a fresh session warns afresh: hierarchical-fallback warnings are
        # deduplicated per (kind, group size) per session, not per process
        decompose.reset_fallback_warnings()
        self.mesh = mesh
        self.name = name
        self.algorithm = algorithm
        # matrix representation for every view/snapshot of this session:
        # True = COO SparseCommMatrix, False = dense, None = auto by
        # device count (views.SPARSE_DEVICE_THRESHOLD)
        self.sparse = sparse
        self.topo = MeshTopology.from_mesh(mesh) if mesh is not None else None
        self.num_devices = (int(np.prod(mesh.devices.shape))
                            if mesh is not None else jax.device_count())
        self.captures: list[Capture] = []
        self.host_transfers: list[HostTransfer] = []
        self.last_lowered: Any = None      # live artifacts of the most
        self.last_compiled: Any = None     # recent capture only
        self._phases: dict[str, PhaseRecord] = {}   # insertion == phase order
        self._phase_stack: list[str] = []
        self._views: dict = {}

    # -- context plumbing --------------------------------------------------
    def __enter__(self) -> "MonitorSession":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    @contextlib.contextmanager
    def phase(self, name: str):
        """Scope subsequent captures under phase ``name`` (re-enterable:
        capturing into an existing phase accumulates into its record)."""
        if not name:
            raise ValueError("phase name must be non-empty")
        self._phase_record(name)      # fix ordering at first entry
        self._phase_stack.append(name)
        try:
            yield self
        finally:
            self._phase_stack.pop()

    @property
    def current_phase(self) -> str:
        return self._phase_stack[-1] if self._phase_stack else DEFAULT_PHASE

    def _phase_record(self, name: str) -> PhaseRecord:
        if name not in self._phases:
            self._phases[name] = PhaseRecord(name=name)
        return self._phases[name]

    # -- capture -----------------------------------------------------------
    def capture(
        self,
        fn,
        *args,
        name: Optional[str] = None,
        phase: Optional[str] = None,
        in_shardings=None,
        out_shardings=None,
        donate_argnums=(),
        static_argnums=(),
        host_transfers: Optional[Iterable[HostTransfer]] = None,
        op_transform=None,
        **kwargs,
    ) -> Capture:
        """Monitor one function: trace (intercepted) + compile + parse.

        ``args``/``kwargs`` may be concrete arrays or
        ``jax.ShapeDtypeStruct`` stand-ins (nothing executes; no device
        memory is allocated).  The parsed ops and traced events are tagged
        with ``phase`` (default: the innermost active :meth:`phase`, else
        ``"main"``) and accumulated into the session.

        ``op_transform`` (``CollectiveOp -> CollectiveOp``, optional) is
        applied to every parsed op before it is accumulated -- the hook
        captured runtime knowledge the HLO cannot carry, e.g. injecting a
        measured per-rank byte vector (``bytes_per_rank_vec``) onto an
        all-to-all whose expert routing is skewed.  Returning the op
        unchanged is fine; returning ``None`` keeps the original.
        """
        phase_name = phase or self.current_phase
        rec = self._phase_record(phase_name)

        jit_kw: dict[str, Any] = {}
        if in_shardings is not None:
            jit_kw["in_shardings"] = in_shardings
        if out_shardings is not None:
            jit_kw["out_shardings"] = out_shardings
        if donate_argnums:
            jit_kw["donate_argnums"] = donate_argnums
        if static_argnums:
            jit_kw["static_argnums"] = static_argnums
        jitted = jax.jit(fn, **jit_kw)

        t0 = time.perf_counter()
        with CollectiveInterceptor(mesh=self.mesh) as icpt:
            lowered = jitted.lower(*args, **kwargs)
        t1 = time.perf_counter()
        compiled = lowered.compile()
        t2 = time.perf_counter()

        hlo_text = compiled.as_text()
        # loop-aware extraction: ops inside while bodies carry trip weights
        ops = hlo_cost.analyze_hlo(hlo_text).collectives
        if op_transform is not None:
            ops = [op_transform(op) or op for op in ops]
        for op in ops:
            op.phase = phase_name
        events = list(icpt.events)
        for ev in events:
            ev.phase = phase_name

        cap = Capture(
            name=name or getattr(fn, "__name__", "fn"),
            phase=phase_name,
            ops=ops,
            traced=events,
            trace_seconds=t1 - t0,
            compile_seconds=t2 - t1,
            cost=_cost_analysis(compiled),
            memory_stats=_memory_stats(compiled),
            hlo_text=hlo_text,
        )
        self.captures.append(cap)
        self.last_lowered = lowered
        self.last_compiled = compiled
        rec.num_captures += 1
        rec.trace_seconds += cap.trace_seconds
        rec.compile_seconds += cap.compile_seconds
        if host_transfers:
            self.add_host_transfers(host_transfers, phase=phase_name)
        self._views.clear()           # accumulated state changed
        return cap

    def add_host_transfers(self, transfers: Iterable[HostTransfer],
                           phase: Optional[str] = None):
        """Record host<->device transfers (paper row/col 0), phase-tagged.

        Untagged transfers are *copied* with the active phase (never
        mutating the caller's objects, so a list reused across phases
        records once per phase as expected); a transfer arriving with its
        own phase tag registers that phase so per-phase views see it.
        """
        phase_name = phase or self.current_phase
        self._phase_record(phase_name)
        for t in transfers:
            if not t.phase:
                t = dataclasses.replace(t, phase=phase_name)
            else:
                self._phase_record(t.phase)
            self.host_transfers.append(t)
        self._views.clear()

    # -- accumulated state -------------------------------------------------
    @property
    def compiled_ops(self) -> list[CollectiveOp]:
        return [op for cap in self.captures for op in cap.ops]

    @property
    def traced(self) -> list[TraceEvent]:
        return [ev for cap in self.captures for ev in cap.traced]

    @property
    def trace_seconds(self) -> float:
        return sum(c.trace_seconds for c in self.captures)

    @property
    def compile_seconds(self) -> float:
        return sum(c.compile_seconds for c in self.captures)

    def phase_names(self) -> list[str]:
        return list(self._phases)

    # -- views and snapshots -----------------------------------------------
    def view(self, algorithm: Optional[str] = None,
             phase: Optional[str] = None) -> CommView:
        """Lazy :class:`CommView` of the session (or one ``phase``) bound
        to ``algorithm`` (default: the session's).  Memoized per
        ``(algorithm, phase)``; invalidated by the next capture."""
        alg = algorithm or self.algorithm
        cost_models.validate_algorithm(alg)
        key = (alg, phase)
        if key not in self._views:
            self._views[key] = build_view(
                self.compiled_ops, self.num_devices, alg, self.topo,
                self.host_transfers, phase=phase,
                known_phases=self.phase_names(), label=self.name,
                sparse=self.sparse,
                hlo_texts=[c.hlo_text for c in self.captures])
        return self._views[key]

    def _merged_cost(self) -> dict:
        if len(self.captures) == 1:
            return dict(self.captures[0].cost)
        out: dict[str, float] = {}
        for cap in self.captures:
            for k, v in (cap.cost or {}).items():
                if isinstance(v, (int, float)):
                    out[k] = out.get(k, 0.0) + float(v)
        return out

    def _merged_memory_stats(self) -> Optional[dict]:
        if len(self.captures) == 1:
            return self.captures[0].memory_stats
        stats = [c.memory_stats for c in self.captures if c.memory_stats]
        if not stats:
            return None
        out: dict[str, float] = {}
        for st in stats:
            for k, v in st.items():
                if isinstance(v, (int, float)):
                    out[k] = out.get(k, 0) + v
        return out

    def report(self, name: Optional[str] = None):
        """Snapshot the session into a serializable
        :class:`~repro.core.monitor.CommReport` (schema v5: per-phase op
        lists and phase records ride along; ``save``/``load`` round-trips
        them).  The compiled HLO of every capture is attached as
        ``_hlo_texts`` (one module per capture -- analyzed per module, a
        concatenation would clobber same-named computations), and the most
        recent capture's live artifacts as ``_lowered``/``_compiled``, so
        ``roofline_of`` works in-process; persist the HLO with
        ``save(..., include_hlo=True)`` to keep rooflines working on
        loaded reports.
        """
        from .monitor import CommReport   # deferred: monitor imports us

        v = self.view()
        rep = CommReport(
            name=name or self.name,
            num_devices=self.num_devices,
            traced=list(self.traced),
            compiled_ops=list(self.compiled_ops),
            traced_summary=traced_summary(self.traced),
            compiled_summary=v.summary,
            matrix=v.matrix,
            per_primitive=v.per_primitive,
            cost=self._merged_cost(),
            memory_stats=self._merged_memory_stats(),
            trace_seconds=self.trace_seconds,
            compile_seconds=self.compile_seconds,
            topo=self.topo,
            host_transfers=list(self.host_transfers),
            algorithm=self.algorithm,
            phases=[dataclasses.replace(p) for p in self._phases.values()],
        )
        if self.captures:
            rep._lowered = self.last_lowered
            rep._compiled = self.last_compiled
            rep._hlo_texts = [c.hlo_text for c in self.captures]
            if len(self.captures) == 1:
                rep._hlo_text = self.captures[0].hlo_text
        return rep
