"""Fleet-scale projection: one monitored program, many device counts.

``sweep --scale-curve`` answers the question the paper's per-run matrices
cannot: *how does this workload's communication scale?*  A report is
monitored once at a small base mesh (compilation needs real jax devices),
then its compiled op stream is **projected** onto synthetic fleet
topologies -- 256 / 1k / 4k / 16k devices -- and every derived artifact
(sparse matrix, per-tier times, bottleneck link) is recomputed per point.
No recompilation, no jax mesh, and critically **no dense matrix**: every
point binds a :class:`~repro.core.views.CommView` with ``sparse=True``,
so the 16k-device point never allocates the ~2 GiB ``(d+1)^2`` array.

Projection rule (documented convention, pinned by tests):

* device ``d`` of the base mesh becomes the contiguous block
  ``[d*F, (d+1)*F)`` of the fleet, ``F = devices / base_devices`` -- so
  replica groups stay a partition, group *count* is preserved, and group
  *size* grows proportionally (``n' = n * F``);
* collective-permute pairs map ``(s, t) -> (s*F, t*F)`` (injective, so no
  self-pairs or duplicates appear);
* all-to-all groups additionally split into pod-sized chunks
  (:data:`POD_DEVICES`) -- fleet-scale a2a is pod-local in practice, and
  an unsplit 16k-wide a2a would place ``n^2`` edges;
* result shapes (and hence per-primitive payload semantics) are held
  constant: per-device tensor shards do not change as the job scales out;
* an *irregular* op (``bytes_per_rank_vec``) expands its vector with the
  group -- each base entry tiles over its clone block and renormalizes by
  the factor (``repeat(vec, F) / F``), so the group total is preserved
  and a uniform vector stays the scalar path's equal shares.  When an
  irregular a2a splits into pod chunks, each chunk op carries its own
  *slice* of the expanded vector scaled by the chunk count (the same
  convention that keeps every scalar chunk's payload at the base
  payload), so the hot-expert pod stays hot instead of being flattened
  to the group mean.

Topologies come from :meth:`repro.core.topology.MeshTopology.fleet`:
2D torus pods of ``16 x 16`` joined by a DCN ``pod`` axis.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Iterable, Optional

import numpy as np

from repro.core.events import CollectiveOp
from repro.core.reporter import format_table, human_bytes
from repro.core.topology import MeshTopology
from repro.core.views import CommView

POD_SIDE = 16
POD_DEVICES = POD_SIDE * POD_SIDE
DEFAULT_SCALE_POINTS = (256, 1024, 4096, 16384)

_A2A_KINDS = ("all-to-all", "ragged-all-to-all")


def fleet_topology(num_devices: int) -> MeshTopology:
    """The synthetic topology a scale point projects onto."""
    return MeshTopology.fleet(num_devices, pod_side=POD_SIDE)


def _scale_group(group: list[int], factor: int) -> list[int]:
    return [d * factor + i for d in group for i in range(factor)]


def _chunk(group: list[int], size: int) -> list[list[int]]:
    return [group[i:i + size] for i in range(0, len(group), size)]


def _scale_vec(op: CollectiveOp, factor: int):
    """Expanded per-rank byte vector (``repeat(vec, F) / F``), or ``None``
    for regular ops.  Tiling preserves each base rank's *share* across its
    clone block; dividing by the factor keeps the group total constant,
    so a uniform vector expands to the scalar path's equal shares."""
    vec = op.byte_vector()
    if vec is None:
        return None
    return np.repeat(vec, factor) / factor


def scale_op(op: CollectiveOp, factor: int) -> CollectiveOp:
    """Project ONE op onto a fleet ``factor`` times the base device count.

    Returns a *list* of ops in exactly one case: an irregular a2a whose
    scaled group splits into multiple pod chunks -- the chunks carry
    different slices of the expanded byte vector, so they cannot share
    one op record.  Every other op (including ``factor == 1``, which is
    the identity) comes back as a single op.
    """
    if factor == 1:
        return op
    if op.kind == "collective-permute":
        return dataclasses.replace(op, source_target_pairs=[
            (s * factor, t * factor) for s, t in op.source_target_pairs])
    groups = [_scale_group(list(g), factor) for g in op.replica_groups]
    vec = _scale_vec(op, factor)
    if op.kind in _A2A_KINDS:
        per_group = [_chunk(g, POD_DEVICES) for g in groups]
        n_chunks = len(per_group[0]) if per_group else 1
        if vec is not None and n_chunks > 1:
            # one op per chunk index: chunk j of every group spans the
            # same positional slice of the expanded vector.  Each slice is
            # scaled by the chunk count -- the irregular twin of scalar
            # chunking, where every chunk op keeps the full base payload.
            out = []
            for j in range(n_chunks):
                sl = vec[j * POD_DEVICES:(j + 1) * POD_DEVICES] * n_chunks
                out.append(dataclasses.replace(
                    op,
                    replica_groups=[ch[j] for ch in per_group],
                    bytes_per_rank_vec=[float(x) for x in sl]))
            return out
        groups = [c for chunks in per_group for c in chunks]
    rep = {"replica_groups": groups}
    if vec is not None:
        rep["bytes_per_rank_vec"] = [float(x) for x in vec]
    return dataclasses.replace(op, **rep)


def scale_ops(ops: Iterable[CollectiveOp], base_devices: int,
              num_devices: int) -> list[CollectiveOp]:
    """Project a compiled op stream from ``base_devices`` onto
    ``num_devices`` (which must be a positive multiple of the base)."""
    if num_devices % base_devices or num_devices < base_devices:
        raise ValueError(
            f"fleet size {num_devices} must be a multiple of the base "
            f"mesh's {base_devices} devices")
    factor = num_devices // base_devices
    out: list[CollectiveOp] = []
    for op in ops:
        scaled = scale_op(op, factor)
        if isinstance(scaled, list):
            out.extend(scaled)
        else:
            out.append(scaled)
    return out


@dataclasses.dataclass
class ScalePoint:
    """One (config, algorithm, device count) cell of a scale curve."""

    config: str
    algorithm: str
    devices: int
    pods: int
    ops: int
    wire_bytes: float
    ici_ms: float
    dcn_ms: float
    overlap_ms: float
    bottleneck_link: str
    bottleneck_ms: float
    nnz: int
    build_ms: float

    def row(self) -> dict:
        """CSV/HTML row (floats rounded for diff-stable goldens)."""
        d = dataclasses.asdict(self)
        for k in ("wire_bytes", "ici_ms", "dcn_ms", "overlap_ms",
                  "bottleneck_ms", "build_ms"):
            d[k] = round(d[k], 3)
        return d


def scale_point(report, num_devices: int) -> ScalePoint:
    """Evaluate one fleet size for one report: scale the ops, bind a
    sparse :class:`CommView` against the fleet topology, read the derived
    artifacts.  ``build_ms`` times the sparse matrix construction."""
    topo = fleet_topology(num_devices)
    ops = scale_ops(report.compiled_ops, report.num_devices, num_devices)
    view = CommView(ops, num_devices, algorithm=report.algorithm,
                    topo=topo, label=f"scale:{num_devices}", sparse=True)
    t0 = time.perf_counter()
    mat = view.matrix
    build_ms = (time.perf_counter() - t0) * 1e3
    ici_s, dcn_s = view.collective_seconds_split()
    lu = view.link_utilization()
    bn = lu.bottleneck() if lu is not None else None
    return ScalePoint(
        config=report.meta.get("config", report.name),
        algorithm=report.algorithm,
        devices=num_devices,
        pods=topo.num_pods,
        ops=len(ops),
        wire_bytes=view.total_wire_bytes(),
        ici_ms=ici_s * 1e3,
        dcn_ms=dcn_s * 1e3,
        overlap_ms=max(ici_s, dcn_s) * 1e3,
        bottleneck_link=bn[0].name if bn else "-",
        bottleneck_ms=bn[1] * 1e3 if bn else 0.0,
        nnz=mat.nnz,
        build_ms=build_ms,
    )


def scale_curve(
    reports,
    device_counts: Iterable[int] = DEFAULT_SCALE_POINTS,
    *,
    log: Optional[Callable[[str], None]] = None,
) -> list[ScalePoint]:
    """Every (report, device count) cell.  Fleet sizes that are not a
    multiple of a report's base mesh are skipped (and logged) rather than
    silently rounded."""
    points: list[ScalePoint] = []
    for rep in reports:
        for d in device_counts:
            if d % rep.num_devices or d < rep.num_devices:
                if log:
                    log(f"[scale] skip devices={d} for "
                        f"{rep.meta.get('config', rep.name)}: not a "
                        f"multiple of base mesh ({rep.num_devices})")
                continue
            if log:
                log(f"[scale] {rep.meta.get('config', rep.name)} "
                    f"algorithm={rep.algorithm} devices={d} ...")
            points.append(scale_point(rep, d))
    return points


def scale_table(points: list[ScalePoint]) -> str:
    """Terminal rendering of a scale curve (one row per cell)."""
    rows = [[p.config, p.algorithm, f"{p.devices:,}", f"{p.pods}",
             human_bytes(p.wire_bytes), f"{p.ici_ms:.3f}",
             f"{p.dcn_ms:.3f}", f"{p.overlap_ms:.3f}", p.bottleneck_link,
             f"{p.bottleneck_ms:.3f}", f"{p.nnz:,}"]
            for p in sorted(points, key=lambda p: (p.config, p.algorithm,
                                                   p.devices))]
    return format_table(rows, [
        "config", "algorithm", "devices", "pods", "wire bytes", "ici ms",
        "dcn ms", "overlap ms", "bottleneck link", "bottleneck ms", "nnz"])
