"""Architecture registry: one module per assigned architecture.

Each module exports:
  CONFIG   — the exact published configuration (full scale; dry-run only),
  REDUCED  — same family at smoke-test scale (instantiated on CPU in tests),
  TRAIN    — TrainConfig preset (microbatching / grad dtype tuned to fit HBM).

``input_specs(cfg, shape)`` builds ShapeDtypeStruct stand-ins for every input
of the step a shape exercises (train_step / prefill / decode) — the dry-run
lowers against these, so full configs never allocate memory.
"""
from __future__ import annotations

import importlib
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, ShapeConfig, SHAPES_BY_NAME

ARCH_IDS = (
    "grok_1_314b",
    "llama4_maverick_400b_a17b",
    "codeqwen15_7b",
    "granite_3_2b",
    "qwen3_8b",
    "granite_20b",
    "xlstm_1_3b",
    "chameleon_34b",
    "musicgen_medium",
    "recurrentgemma_2b",
)

# archs whose attention is strictly quadratic-full -> long_500k skipped
LONG_CONTEXT_ARCHS = ("xlstm_1_3b", "recurrentgemma_2b")


def get(arch: str):
    """Returns the config module for an arch id (dashes tolerated)."""
    name = arch.replace("-", "_").replace(".", "")
    if name not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    return importlib.import_module(f"repro.configs.{name}")


def config(arch: str, reduced: bool = False) -> ModelConfig:
    mod = get(arch)
    return mod.REDUCED if reduced else mod.CONFIG


def train_config(arch: str):
    return get(arch).TRAIN


def cells(include_long: bool = True):
    """All runnable (arch, shape) dry-run cells."""
    out = []
    for arch in ARCH_IDS:
        for shape in ("train_4k", "prefill_32k", "decode_32k", "long_500k"):
            if shape == "long_500k" and arch not in LONG_CONTEXT_ARCHS:
                continue  # DESIGN.md §4: full-attention archs skip long_500k
            if not include_long and shape == "long_500k":
                continue
            out.append((arch, shape))
    return out


# ---------------------------------------------------------------------------
# input stand-ins per (cfg, shape)
# ---------------------------------------------------------------------------
def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct batch for the step this shape lowers."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "train":
        batch = {"tokens": jax.ShapeDtypeStruct((b, s), i32),
                 "labels": jax.ShapeDtypeStruct((b, s), i32)}
        if cfg.input_mode == "embeddings":
            # modality frontend stub: precomputed frame/patch embeddings
            batch["embeds"] = jax.ShapeDtypeStruct(
                (b, s, cfg.d_model), jnp.bfloat16)
        return batch
    if shape.kind == "prefill":
        batch = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
        if cfg.input_mode == "embeddings":
            batch["embeds"] = jax.ShapeDtypeStruct(
                (b, s, cfg.d_model), jnp.bfloat16)
        return batch
    # decode: one new token against a seq_len-deep cache
    batch = {"tokens": jax.ShapeDtypeStruct((b, 1), i32)}
    if cfg.input_mode == "embeddings":
        batch["embeds"] = jax.ShapeDtypeStruct((b, 1, cfg.d_model),
                                               jnp.bfloat16)
    return batch


def reduce_config(cfg: ModelConfig, **over) -> ModelConfig:
    """Same family, smoke-test scale (runs a real step on CPU)."""
    import dataclasses
    nh = min(cfg.n_heads, 4)
    nkv = max(1, min(cfg.n_kv_heads, nh))
    if cfg.n_kv_heads == cfg.n_heads:
        nkv = nh
    d = 16 * nh
    repl = dict(
        name=cfg.name + "-reduced",
        n_layers=6 if cfg.family == "hybrid" else 4,
        d_model=d,
        n_heads=nh,
        n_kv_heads=nkv,
        head_dim=d // nh,
        d_ff=0 if cfg.d_ff == 0 else 4 * d,
        vocab_size=512,
        n_experts=min(cfg.n_experts, 4),
        top_k=min(cfg.top_k, 2),
        attn_window=32 if cfg.attn_window else 0,
        d_rnn=d if cfg.d_rnn else 0,
        mlstm_chunk=16,
    )
    repl.update(over)
    return dataclasses.replace(cfg, **repl)
