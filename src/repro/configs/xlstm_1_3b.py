"""xlstm-1.3b — 48L d2048 4H, sLSTM + mLSTM blocks (1:1 alternating here;
DESIGN.md §4), O(1) recurrent state -> runs long_500k.
[arXiv:2405.04517; unverified]"""
from repro.configs import reduce_config
from repro.models.common import ModelConfig
from repro.train import TrainConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4, d_ff=0,
    vocab_size=50304, subquadratic=True, mlstm_chunk=256,
    block_pattern=("mlstm", "slstm"),
)

REDUCED = reduce_config(CONFIG)

TRAIN = TrainConfig(microbatches=8, remat="full")
