"""codeqwen1.5-7b — 32L d4096 32H(kv32 = MHA) ff13440 v92416.
[hf:Qwen/CodeQwen1.5-7B; hf]"""
from repro.configs import reduce_config
from repro.models.common import ModelConfig
from repro.train import TrainConfig

CONFIG = ModelConfig(
    name="codeqwen1.5-7b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32, d_ff=13440,
    vocab_size=92416,
)

REDUCED = reduce_config(CONFIG)

TRAIN = TrainConfig(microbatches=8, remat="full")
