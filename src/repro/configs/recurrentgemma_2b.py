"""recurrentgemma-2b — 26L d2560 10H(kv1 MQA) ff7680 v256000, RG-LRU +
local attention (window 2048), pattern (rec, rec, attn).  O(1) state +
bounded window -> runs long_500k.  [arXiv:2402.19427; hf]"""
from repro.configs import reduce_config
from repro.models.common import ModelConfig
from repro.train import TrainConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, d_ff=7680,
    vocab_size=256000, head_dim=256, attn_window=2048, d_rnn=2560,
    conv_width=4, subquadratic=True, block_pattern=("rec", "rec", "attn"),
)

REDUCED = reduce_config(CONFIG)

TRAIN = TrainConfig(microbatches=8, remat="full")
