"""musicgen-medium — 48L d1536 24H(kv24 = MHA) ff6144 v2048, decoder-only
over EnCodec tokens.  Frontend STUBBED: input_specs() supplies precomputed
frame embeddings.  [arXiv:2306.05284; hf]"""
from repro.configs import reduce_config
from repro.models.common import ModelConfig
from repro.train import TrainConfig

CONFIG = ModelConfig(
    name="musicgen-medium", family="audio",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24, d_ff=6144,
    vocab_size=2048, input_mode="embeddings",
)

REDUCED = reduce_config(CONFIG)

TRAIN = TrainConfig(microbatches=8, remat="full")
