"""granite-20b — 52L d6144 48H(kv1 = MQA) ff24576 v49152, code model.
[arXiv:2405.04324; hf]"""
from repro.configs import reduce_config
from repro.models.common import ModelConfig
from repro.train import TrainConfig

CONFIG = ModelConfig(
    name="granite-20b", family="dense",
    n_layers=52, d_model=6144, n_heads=48, n_kv_heads=1, d_ff=24576,
    vocab_size=49152,
)

REDUCED = reduce_config(CONFIG)

TRAIN = TrainConfig(microbatches=16, remat="full")
