"""The paper's own evaluation applications (§4): GNMT + ResNet-18.

These are profiled by the benchmarks reproducing Tables 2-3 / Figs. 2-3 on
an 8-device data-parallel mesh (the paper's DGX-2 had 16 GPUs; 8 keeps the
matrices terminal-renderable — scale is a parameter).
"""
from repro.models.gnmt import GNMT
from repro.models.resnet import ResNet18


def gnmt_model(vocab: int = 4096, d: int = 256, layers: int = 2) -> GNMT:
    return GNMT(vocab=vocab, d=d, layers=layers)


def resnet18_model(num_classes: int = 200) -> ResNet18:
    return ResNet18(num_classes=num_classes)


GNMT_DATA = dict(vocab_size=4096, src_len=48, tgt_len=48, global_batch=32)
RESNET_DATA = dict(num_classes=200, global_batch=64, image_size=64)
