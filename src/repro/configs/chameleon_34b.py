"""chameleon-34b — 48L d8192 64H(kv8) ff22016 v65536, early-fusion VQ image
tokens.  Modality frontend STUBBED: input_specs() supplies precomputed
patch-token embeddings (B,S,D).  [arXiv:2405.09818; unverified]"""
from repro.configs import reduce_config
from repro.models.common import ModelConfig
from repro.train import TrainConfig

CONFIG = ModelConfig(
    name="chameleon-34b", family="vlm",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=22016,
    vocab_size=65536, qk_norm=True, input_mode="embeddings",
)

REDUCED = reduce_config(CONFIG)

TRAIN = TrainConfig(microbatches=16, remat="full", accum_dtype="bfloat16")
