"""granite-3-2b — 40L d2048 32H(kv8) ff8192 v49155 (not TP-divisible:
embedding replicated over model axis by the rules fallback).
[hf:ibm-granite/granite-3.0-2b-base; hf]"""
from repro.configs import reduce_config
from repro.models.common import ModelConfig
from repro.train import TrainConfig

CONFIG = ModelConfig(
    name="granite-3-2b", family="dense",
    n_layers=40, d_model=2048, n_heads=32, n_kv_heads=8, d_ff=8192,
    vocab_size=49155,
)

REDUCED = reduce_config(CONFIG)

TRAIN = TrainConfig(microbatches=8, remat="full")
