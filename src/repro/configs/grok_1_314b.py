"""grok-1-314b — 64L d6144 48H(kv8) ff32768 v131072, MoE 8e top-2.
[hf:xai-org/grok-1; unverified]"""
from repro.configs import reduce_config
from repro.models.common import ModelConfig
from repro.train import TrainConfig

CONFIG = ModelConfig(
    name="grok-1-314b", family="moe",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=32768,
    vocab_size=131072, n_experts=8, top_k=2,
    optimizer="adafactor", opt_state_dtype="bfloat16", param_dtype="bfloat16",
)

REDUCED = reduce_config(CONFIG)

# 314B on 256 chips: adafactor + bf16 moments + bf16 grad comms to fit HBM
TRAIN = TrainConfig(microbatches=8, remat="full", accum_dtype="bfloat16")
