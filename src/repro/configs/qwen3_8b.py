"""qwen3-8b — 36L d4096 32H(kv8) ff12288 v151936, qk-norm.
[hf:Qwen/Qwen3-8B; hf]"""
from repro.configs import reduce_config
from repro.models.common import ModelConfig
from repro.train import TrainConfig

CONFIG = ModelConfig(
    name="qwen3-8b", family="dense",
    n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=12288,
    vocab_size=151936, qk_norm=True, head_dim=128,
)

REDUCED = reduce_config(CONFIG)

TRAIN = TrainConfig(microbatches=8, remat="full")
