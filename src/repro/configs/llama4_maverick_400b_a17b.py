"""llama4-maverick-400b-a17b — 48L d5120 40H(kv8) ff8192 v202048, MoE 128e
top-1, early fusion.  [hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""
from repro.configs import reduce_config
from repro.models.common import ModelConfig
from repro.train import TrainConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=8192,
    vocab_size=202048, n_experts=128, top_k=1,
    optimizer="adafactor", opt_state_dtype="bfloat16", param_dtype="bfloat16",
)

REDUCED = reduce_config(CONFIG)

TRAIN = TrainConfig(microbatches=8, remat="full", accum_dtype="bfloat16")
