"""Fault-tolerant checkpointing: atomic, manifest-driven, elastic-restorable.

Design (what matters at 1000+ nodes, scaled to this container):

* **atomic** — write into ``step_<n>.tmp``, fsync, rename; a crash mid-save
  never corrupts the latest checkpoint;
* **manifest** — ``manifest.json`` lists every leaf (path, shape, dtype) so
  restore validates structure before touching arrays and can restore into a
  *different mesh* (elastic restart: arrays are stored unsharded here, and
  re-sharded by the caller's ``device_put``; on real multi-host storage this
  becomes one shard-file per host, same manifest);
* **async** — :class:`AsyncCheckpointer` snapshots to host memory
  synchronously (cheap) and writes to disk on a worker thread, so the train
  loop never blocks on I/O;
* **retention** — keep the last ``keep`` checkpoints, delete older ones.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

_STEP_RE = re.compile(r"^step_(\d+)$")


def _flatten_with_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out


def save_checkpoint(directory: str, step: int, state, keep: int = 3) -> str:
    """Atomically save ``state`` (pytree of arrays) at ``step``."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    manifest = {"step": int(step), "leaves": []}
    arrays = {}
    for i, (key, leaf) in enumerate(_flatten_with_paths(state)):
        arr = np.asarray(jax.device_get(leaf))
        name = f"leaf_{i}"
        arrays[name] = arr
        manifest["leaves"].append(
            {"key": key, "name": name, "shape": list(arr.shape),
             "dtype": str(arr.dtype)})
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(directory, keep)
    return final


def _gc(directory: str, keep: int):
    steps = sorted(
        int(m.group(1)) for m in
        (_STEP_RE.match(d) for d in os.listdir(directory)) if m)
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(directory, f"step_{s}"), ignore_errors=True)


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for m in
             (_STEP_RE.match(d) for d in os.listdir(directory)) if m]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int, target,
                       shardings=None):
    """Restore into ``target``'s structure; optionally device_put with
    ``shardings`` (elastic restore into any mesh)."""
    path = os.path.join(directory, f"step_{step}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))

    target_flat = _flatten_with_paths(target)
    by_key = {e["key"]: e for e in manifest["leaves"]}
    leaves = []
    for key, leaf in target_flat:
        if key not in by_key:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        e = by_key[key]
        arr = data[e["name"]]
        want = tuple(getattr(leaf, "shape", arr.shape))
        if tuple(arr.shape) != want:
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs {want}")
        leaves.append(arr.astype(str(leaf.dtype))
                      if hasattr(leaf, "dtype") else arr)
    _, treedef = jax.tree_util.tree_flatten(target)
    restored = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        restored = jax.device_put(restored, shardings)
    return restored


class AsyncCheckpointer:
    """Snapshot synchronously, persist on a background thread."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, state):
        self.wait()
        # synchronous host snapshot — decoupled from device buffers
        snapshot = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                state)

        def work():
            try:
                save_checkpoint(self.directory, step, snapshot, self.keep)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
